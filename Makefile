# Repo-level tooling. `make check` is the CI gate: build, tests, format,
# and lints over the rust crate.

.PHONY: check build test test-faults fmt clippy doc bench bench-build examples-build

check: build test test-faults fmt clippy doc bench-build examples-build

build:
	cd rust && cargo build --release

# --release reuses the artifacts from `build` (no second debug
# compile) and keeps the CNV-sized equivalence tests fast.
test:
	cd rust && cargo test -q --release

# Serving-robustness suite on its own: fault injection (errors, panics,
# stalls) against the batcher — bounded admission, deadlines, shard
# restart, degraded modes, shutdown semantics. Part of `test` too; this
# target gives CI a separately-visible gate.
test-faults:
	cd rust && cargo test -q --release --test serving_faults

fmt:
	cd rust && cargo fmt --check

clippy:
	cd rust && cargo clippy --all-targets -- -D warnings

# Doc build (doc-link rot gate; CI runs this too). -D warnings turns
# broken intra-doc links into failures — a plain `cargo doc` exits 0.
doc:
	cd rust && RUSTDOCFLAGS="-D warnings" cargo doc --no-deps

# Interpreter-vs-plan throughput comparison (plus the PJRT sections when
# artifacts are present). Writes machine-readable BENCH_PR7.json to the
# repo root (Melem/s, GMAC/s, plan-vs-interpreter speedups, the
# batched-CNV b1/b8/b32 batch-symbolic-vs-per-sample comparison, the
# integer-streamlined-vs-packed-float kernel-tier section, the PR-5
# resident-int-vs-convert-per-call section on TFC/CNV b1/b8, and the
# PR-6 scalar-vs-SIMD-vs-SIMD+pool microkernel section on CNV b1/b8/b32
# with the shards x intra-op serving sweep, and the PR-7 overload
# section: open-loop submitters against a cap-32 queue recording
# shed rate + p99 and asserting queue depth never exceeds the cap;
# also asserts the SIMD path clears 2x over scalar on CNV b32 when the
# host has AVX2/NEON).
bench:
	cd rust && cargo bench --bench bench_exec

# Compile-only check so benches can't rot (CI gate; no measurements run).
bench-build:
	cd rust && cargo build --release --benches

# Compile-only check for the runnable walkthroughs in examples/.
examples-build:
	cd rust && cargo build --release --examples

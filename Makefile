# Repo-level tooling. `make check` is the CI gate: build, tests, format,
# and lints over the rust crate.

.PHONY: check build test fmt clippy bench

check: build test fmt clippy

build:
	cd rust && cargo build --release

# --release reuses the artifacts from `build` (no second debug
# compile) and keeps the CNV-sized equivalence tests fast.
test:
	cd rust && cargo test -q --release

fmt:
	cd rust && cargo fmt --check

clippy:
	cd rust && cargo clippy --all-targets -- -D warnings

# Interpreter-vs-plan throughput comparison (plus the PJRT sections when
# artifacts are present).
bench:
	cd rust && cargo bench --bench bench_exec

# Repo-level tooling. `make check` is the CI gate: build, tests, format,
# and lints over the rust crate.

.PHONY: check build test test-faults verify-zoo artifact-zoo fmt clippy doc bench bench-build examples-build miri

check: build test test-faults verify-zoo artifact-zoo fmt clippy doc bench-build examples-build

build:
	cd rust && cargo build --release

# --release reuses the artifacts from `build` (no second debug
# compile) and keeps the CNV-sized equivalence tests fast.
test:
	cd rust && cargo test -q --release

# Serving-robustness suite on its own: fault injection (errors, panics,
# stalls) against the batcher — bounded admission, deadlines, shard
# restart, degraded modes, shutdown semantics. Part of `test` too; this
# target gives CI a separately-visible gate.
test-faults:
	cd rust && cargo test -q --release --test serving_faults

# Static plan verification over the model zoo (negative-result suite):
# every float + streamlined plan, batch-1 and batch-8, across the
# compiler's option axes must verify with zero errors. Part of `test`
# too; this target gives CI a separately-visible gate.
verify-zoo:
	cd rust && cargo test -q --release --test verify_zoo

# Compiled-plan artifact roundtrip over the model zoo: every model is
# compiled to a sectioned .qpln artifact, loaded back zero-copy, and
# must answer byte-identically (float + streamlined, batch-1/batch-8);
# corruption modes must fail typed; a re-signed schedule tamper must
# trip the static verifier. Also exercises the CLI end to end:
# compile --zoo all, verify --artifact, and a serve --artifact run
# through the batcher. Part of `test` too; separately-visible CI gate.
artifact-zoo:
	cd rust && cargo test -q --release --test artifact_roundtrip
	cd rust && cargo run --release -q -- compile --zoo all --out-dir /tmp/qonnx-qpln
	cd rust && for m in /tmp/qonnx-qpln/*.qpln; do \
		cargo run --release -q -- verify --artifact $$m || exit 1; done
	cd rust && cargo run --release -q -- serve \
		--artifact /tmp/qonnx-qpln/TFC-w2a2.qpln \
		--requests 64 --clients 4 --shards 2

# Concurrency/UB analysis under miri (needs `rustup +nightly component
# add miri`): the unsafe surface — arena slot recycling, the SIMD
# microkernels (scalar path; miri has no AVX2/NEON), and the worker
# pool's queue/latch handoffs. Scoped to those modules: whole-suite
# miri is hours, these are the only unsafe-bearing paths.
miri:
	cd rust && QONNX_FORCE_SCALAR=1 \
		MIRIFLAGS="-Zmiri-env-forward=QONNX_FORCE_SCALAR -Zmiri-env-forward=QONNX_INTRAOP_THREADS" \
		QONNX_INTRAOP_THREADS=2 \
		cargo +nightly miri test --lib -- plan::arena tensor::simd tensor::qgemm runtime::pool

fmt:
	cd rust && cargo fmt --check

clippy:
	cd rust && cargo clippy --all-targets -- -D warnings

# Doc build (doc-link rot gate; CI runs this too). -D warnings turns
# broken intra-doc links into failures — a plain `cargo doc` exits 0.
doc:
	cd rust && RUSTDOCFLAGS="-D warnings" cargo doc --no-deps

# Interpreter-vs-plan throughput comparison (plus the PJRT sections when
# artifacts are present). Writes machine-readable BENCH_PR7.json to the
# repo root (Melem/s, GMAC/s, plan-vs-interpreter speedups, the
# batched-CNV b1/b8/b32 batch-symbolic-vs-per-sample comparison, the
# integer-streamlined-vs-packed-float kernel-tier section, the PR-5
# resident-int-vs-convert-per-call section on TFC/CNV b1/b8, and the
# PR-6 scalar-vs-SIMD-vs-SIMD+pool microkernel section on CNV b1/b8/b32
# with the shards x intra-op serving sweep, and the PR-7 overload
# section: open-loop submitters against a cap-32 queue recording
# shed rate + p99 and asserting queue depth never exceeds the cap;
# also asserts the SIMD path clears 2x over scalar on CNV b32 when the
# host has AVX2/NEON). The PR-9 tracing-overhead section (BENCH_PR9.json)
# measures untraced vs observed vs recorded CNV b8 runs and asserts the
# fully-traced path stays within 5% of the untraced baseline (the
# untraced run IS the disabled path, so disabled overhead is ~0 by
# construction).
bench:
	cd rust && cargo bench --bench bench_exec

# Compile-only check so benches can't rot (CI gate; no measurements run).
bench-build:
	cd rust && cargo build --release --benches

# Compile-only check for the runnable walkthroughs in examples/.
examples-build:
	cd rust && cargo build --release --examples

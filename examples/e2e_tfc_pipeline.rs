//! END-TO-END driver (DESIGN.md §5, "E2E driver" row): exercises every
//! layer of the stack on a real small workload.
//!
//! 1. QAT-train a TFC-w2a2 MLP on synth-digits (logging the loss curve);
//! 2. export it as a QONNX graph; clean + datatype-infer it;
//! 3. measure accuracy through the Rust reference executor;
//! 4. lower to QCDQ and to FINN MultiThreshold form, verifying bit-exact
//!    equivalence on the test set;
//! 5. load the AOT PJRT artifact (JAX/Pallas-compiled TFC) and serve
//!    batched requests through the L3 coordinator, reporting
//!    latency/throughput, cross-checking PJRT vs reference executor.
//!
//! Run: `make artifacts && cargo run --release --example e2e_tfc_pipeline`

use qonnx::coordinator::{Batcher, BatcherConfig, InferenceEngine, PjrtEngine, ReferenceEngine};
use qonnx::exec::{self, ExecOptions};
use qonnx::ir::json::{load_model, save_model};
use qonnx::runtime::{artifacts_dir, PjrtRuntime};
use qonnx::tensor::Tensor;
use qonnx::training::{train_mlp, QatConfig};
use qonnx::zoo::{synth_digits, Dataset};
use qonnx::{metrics, transforms};
use std::collections::BTreeMap;
use std::sync::Arc;
use std::time::Instant;

#[rustfmt::skip] // hand-formatted walkthrough (predates fmt enforcement)
fn graph_accuracy(g: &qonnx::ir::ModelGraph, data: &Dataset) -> anyhow::Result<f32> {
    let mut g = g.clone();
    g.inputs[0].shape = Some(vec![data.len(), 784]);
    g.outputs[0].shape = Some(vec![data.len(), 10]);
    let mut inputs = BTreeMap::new();
    inputs.insert(g.inputs[0].name.clone(), Tensor::new(vec![data.len(), 784], data.images.clone()));
    let out = exec::execute(&g, &inputs)?;
    let logits = out.outputs.values().next().unwrap().as_f32()?.to_vec();
    let mut correct = 0usize;
    for i in 0..data.len() {
        let row = &logits[i * 10..(i + 1) * 10];
        let pred = row.iter().enumerate().max_by(|a, b| a.1.partial_cmp(b.1).unwrap()).unwrap().0;
        if pred == data.labels[i] {
            correct += 1;
        }
    }
    Ok(100.0 * correct as f32 / data.len() as f32)
}

#[rustfmt::skip] // hand-formatted walkthrough (predates fmt enforcement)
fn main() -> anyhow::Result<()> {
    // ---------------- 1. train ----------------------------------------
    let train = synth_digits(2000, 100);
    let test = synth_digits(500, 101);
    let mut cfg = QatConfig::tfc(2, 2);
    cfg.epochs = 20;
    println!("[1/5] QAT training TFC-w2a2 on {} synth-digits, {} epochs", train.len(), cfg.epochs);
    let t0 = Instant::now();
    let mut model = train_mlp(&train, &cfg)?;
    println!("      trained in {:.1}s; loss curve:", t0.elapsed().as_secs_f64());
    for (i, l) in model.loss_curve.iter().enumerate() {
        if i % 4 == 0 || i + 1 == model.loss_curve.len() {
            println!("        epoch {:>3}: {l:.4}", i + 1);
        }
    }
    println!("      internal accuracy: {:.2}%", model.accuracy(&test));

    // ---------------- 2. export + clean -------------------------------
    let mut g = model.to_qonnx(1)?;
    transforms::cleanup(&mut g)?;
    transforms::infer_datatypes(&mut g)?;
    let report = metrics::analyze(&g)?;
    let out_path = std::env::temp_dir().join("e2e_tfc_w2a2.qonnx.json");
    save_model(&g, out_path.to_str().unwrap())?;
    let g = load_model(out_path.to_str().unwrap())?; // disk round-trip
    println!(
        "[2/5] exported QONNX graph: {} nodes, {} MACs, BOPs(Eq.5) {:.3e}, {} weight bits",
        g.nodes.len(),
        report.macs(),
        report.bops(),
        report.total_weight_bits()
    );

    // ---------------- 3. accuracy via reference executor --------------
    let acc = graph_accuracy(&g, &test)?;
    println!("[3/5] reference-executor accuracy on {} test samples: {acc:.2}%", test.len());
    anyhow::ensure!(acc > 70.0, "e2e accuracy too low: {acc}%");

    // ---------------- 4. lowerings + equivalence ----------------------
    let mut qcdq = g.clone();
    transforms::lower_to_qcdq(&mut qcdq)?;
    let mut finn = g.clone();
    transforms::convert_to_finn(&mut finn)?;
    let probe = Tensor::new(vec![1, 784], test.image(0).to_vec());
    let y0 = exec::execute_simple(&g, &probe)?;
    let mut inputs = BTreeMap::new();
    inputs.insert(g.inputs[0].name.clone(), probe.clone());
    let y1 = exec::execute_with(&qcdq, &inputs, &ExecOptions { standard_onnx_only: true, ..Default::default() })?;
    let y2 = exec::execute_simple(&finn, &probe)?;
    assert_eq!(&y0, y1.outputs.values().next().unwrap());
    let acc_qcdq = graph_accuracy(&qcdq, &test)?;
    let acc_finn = graph_accuracy(&finn, &test)?;
    println!(
        "[4/5] lowered formats: QCDQ (standard-only backend) acc {acc_qcdq:.2}%, FINN MultiThreshold acc {acc_finn:.2}%"
    );
    anyhow::ensure!((acc_qcdq - acc).abs() < 0.5, "QCDQ accuracy drifted");
    anyhow::ensure!((acc_finn - acc).abs() < 1.5, "FINN accuracy drifted");
    let _ = y2;

    // ---------------- 5. serve through PJRT ---------------------------
    let stem = artifacts_dir().join("tfc_w2a2");
    if !stem.with_extension("hlo.txt").exists() {
        println!("[5/5] skipped serving: artifacts not built (run `make artifacts`)");
        return Ok(());
    }
    // cross-check: the python-exported QONNX JSON vs the PJRT executable
    let py_graph = load_model(artifacts_dir().join("tfc_w2a2.qonnx.json").to_str().unwrap())?;
    let rt = PjrtRuntime::cpu()?;
    let (compiled, meta) = rt.load_artifact(&stem)?;
    let x = Tensor::new(vec![8, 784], meta.probe_input.clone());
    let mut e = ReferenceEngine::new(py_graph)?;
    let y_ref = e.infer_batch(&x)?;
    let y_pjrt = compiled.execute(&x)?;
    let max_err = y_ref
        .as_f32()?
        .iter()
        .zip(y_pjrt.as_f32()?)
        .fold(0f32, |m, (a, b)| m.max((a - b).abs()));
    println!("[5/5] PJRT vs Rust-reference-executor parity on shared weights: max abs err {max_err:.2e}");
    anyhow::ensure!(max_err < 1e-3, "cross-engine parity failed");

    let batcher = Arc::new(Batcher::start(
        move || {
            let rt = PjrtRuntime::cpu()?;
            Ok(Box::new(PjrtEngine::load(&rt, &stem)?) as Box<dyn InferenceEngine>)
        },
        BatcherConfig::default(),
    )?);
    let clients = 8;
    let per_client = 64;
    let t0 = Instant::now();
    let mut handles = Vec::new();
    for c in 0..clients {
        let b = batcher.clone();
        let data = test.clone();
        handles.push(std::thread::spawn(move || -> anyhow::Result<usize> {
            let mut correct = 0;
            for i in 0..per_client {
                let idx = (c * per_client + i) % data.len();
                let out = b.infer(data.image(idx).to_vec())?;
                let pred = out.iter().enumerate().max_by(|a, b| a.1.partial_cmp(b.1).unwrap()).unwrap().0;
                if pred == data.labels[idx] {
                    correct += 1;
                }
            }
            Ok(correct)
        }));
    }
    let mut correct = 0usize;
    for h in handles {
        correct += h.join().unwrap()?;
    }
    let elapsed = t0.elapsed();
    let stats = batcher.stats();
    println!(
        "      served {} requests in {:.3}s: {:.0} req/s, mean latency {:.0}us, mean batch {:.2}",
        stats.requests,
        elapsed.as_secs_f64(),
        stats.requests as f64 / elapsed.as_secs_f64(),
        stats.mean_latency_us(),
        stats.mean_batch_occupancy()
    );
    // note: the artifact carries the python-side (untrained, seeded)
    // weights — this step demonstrates parity + serving, not accuracy.
    let _ = correct;
    println!("      all {} responses received (artifact weights are untrained; accuracy is step 3's)", clients * per_client);
    println!("\nE2E pipeline complete ✓");
    Ok(())
}

//! The FINN ingestion flow on CNV-w2a2 — reproduces the paper's Figures
//! 1–3 (raw export → cleaned → channels-last) and §VI-D (QONNX →
//! FINN-ONNX MultiThreshold conversion), verifying numerical equivalence
//! at every step.
//!
//! Run: `cargo run --release --example finn_flow`

use qonnx::exec;
use qonnx::tensor::{nchw_to_nhwc, Tensor};
use qonnx::transforms;
use qonnx::zoo::cnv;
use std::collections::BTreeMap;

#[rustfmt::skip] // hand-formatted walkthrough (predates fmt enforcement)
fn conv_fc_transition(g: &qonnx::ir::ModelGraph) -> String {
    // print the node window around the conv->FC transition (the region the
    // paper's figures show)
    let names: Vec<String> = g
        .nodes
        .iter()
        .map(|n| format!("  {:<16} {}", n.op_type, n.name))
        .collect();
    let pos = g
        .nodes
        .iter()
        .position(|n| n.op_type == "Reshape" || n.op_type == "Shape")
        .unwrap_or(0)
        .saturating_sub(3);
    names[pos..(pos + 9).min(names.len())].join("\n")
}

#[rustfmt::skip] // hand-formatted walkthrough (predates fmt enforcement)
fn main() -> anyhow::Result<()> {
    let x = Tensor::new(vec![1, 3, 32, 32], (0..3072).map(|i| (i % 251) as f32 / 251.0).collect());

    // ---- Fig. 1: raw export ------------------------------------------
    let g_raw = cnv(2, 2, 42, true)?;
    println!("Fig. 1 (raw export): {} nodes; conv->FC transition:", g_raw.nodes.len());
    println!("{}", conv_fc_transition(&g_raw));
    let y_raw = exec::execute_simple(&g_raw, &x)?;

    // ---- Fig. 2: after cleanup ---------------------------------------
    let mut g_clean = g_raw.clone();
    transforms::cleanup(&mut g_clean)?;
    println!("\nFig. 2 (cleaned): {} nodes; transition now:", g_clean.nodes.len());
    println!("{}", conv_fc_transition(&g_clean));
    println!(
        "  intermediate shapes known: conv5 act = {:?}",
        g_clean.tensor_shape("conv5_act")
    );
    let y_clean = exec::execute_simple(&g_clean, &x)?;
    assert_eq!(y_raw, y_clean);
    println!("  equivalence vs raw export: bit-exact ✓");

    // ---- Fig. 3: channels-last ---------------------------------------
    let mut g_cl = g_clean.clone();
    transforms::to_channels_last(&mut g_cl)?;
    println!("\nFig. 3 (channels-last): input {:?}", g_cl.inputs[0].shape);
    println!(
        "  conv5 act is now NHWC: {:?} (channels moved last)",
        g_cl.tensor_shape("conv5_act")
    );
    let mut inputs = BTreeMap::new();
    inputs.insert("x".to_string(), nchw_to_nhwc(&x)?);
    let y_cl = exec::execute(&g_cl, &inputs)?.outputs.into_values().next().unwrap();
    assert_eq!(y_clean, y_cl);
    println!("  equivalence vs NCHW: bit-exact ✓");

    // ---- §VI-D: FINN ingestion ---------------------------------------
    let mut g_finn = g_clean.clone();
    transforms::convert_to_finn(&mut g_finn)?;
    let h = g_finn.op_histogram();
    println!("\nFINN-ONNX dialect: {} MultiThreshold nodes, Quant left: {}",
        h.get("MultiThreshold").copied().unwrap_or(0),
        h.get("Quant").copied().unwrap_or(0) + h.get("BipolarQuant").copied().unwrap_or(0),
    );
    transforms::infer_shapes(&mut g_finn)?;
    transforms::infer_datatypes(&mut g_finn)?;
    let y_finn = exec::execute_simple(&g_finn, &x)?;
    let max_err = y_clean
        .as_f32()?
        .iter()
        .zip(y_finn.as_f32()?)
        .fold(0f32, |m, (a, b)| m.max((a - b).abs()));
    println!("  FINN-form equivalence: max abs err {max_err:.2e}");
    anyhow::ensure!(max_err < 1e-4);

    println!("\nfinn_flow complete ✓");
    Ok(())
}

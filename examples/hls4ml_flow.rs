//! The hls4ml ingestion flow (paper §VI-A + §VI-C): a QKeras-style model
//! is converted to QONNX (Fig. 4), then ingested hls4ml-style — constants
//! quantized in place to integers, dequantization scales propagated below
//! the linear ops — and finally analyzed for accumulator bit growth (the
//! §V overflow-analysis use case).
//!
//! Run: `cargo run --release --example hls4ml_flow`

use qonnx::exec;
use qonnx::tensor::Tensor;
use qonnx::transforms;
use qonnx::zoo::{keras_to_qonnx, KerasLayer, KerasModel, QuantizedBits};

#[rustfmt::skip] // hand-formatted walkthrough (predates fmt enforcement)
fn main() -> anyhow::Result<()> {
    // ---- Fig. 4: keras-like -> QONNX ----------------------------------
    let model = KerasModel {
        name: "hls4ml_demo".into(),
        input_dim: 16,
        layers: vec![
            KerasLayer::QDense {
                units: 32,
                kernel_quantizer: QuantizedBits { bits: 6, integer: 0 },
                bias_quantizer: Some(QuantizedBits { bits: 6, integer: 0 }),
            },
            KerasLayer::QActivationRelu { bits: 4 },
            KerasLayer::QDense {
                units: 10,
                kernel_quantizer: QuantizedBits { bits: 6, integer: 0 },
                bias_quantizer: None,
            },
            KerasLayer::Softmax,
        ],
    };
    let mut g = keras_to_qonnx(&model, 7)?;
    transforms::cleanup(&mut g)?;
    println!("Fig. 4 QONNX form ({} nodes):\n{}", g.nodes.len(), g.summary());
    let x = Tensor::new(vec![1, 16], (0..16).map(|i| (i as f32 - 8.0) * 0.1).collect());
    let y0 = exec::execute_simple(&g, &x)?;

    // ---- hls4ml ingestion ---------------------------------------------
    let mut h = g.clone();
    transforms::hls4ml_ingest(&mut h)?;
    println!("\nhls4ml-ingested form ({} nodes):\n{}", h.nodes.len(), h.summary());
    // weights are integer-valued now
    let int_inits: Vec<&String> = h.initializers.keys().filter(|k| k.contains("_int")).collect();
    println!("integer constants: {int_inits:?}");
    for k in &int_inits {
        assert!(
            h.initializers[*k].as_f32()?.iter().all(|v| v.fract() == 0.0),
            "{k} is not integer-valued"
        );
    }
    let y1 = exec::execute_simple(&h, &x)?;
    let max_err = y0
        .as_f32()?
        .iter()
        .zip(y1.as_f32()?)
        .fold(0f32, |m, (a, b)| m.max((a - b).abs()));
    println!("equivalence after scale propagation: max abs err {max_err:.2e}");
    anyhow::ensure!(max_err < 1e-5);

    // ---- accumulator-width analysis (paper §V) ------------------------
    transforms::infer_shapes(&mut h)?;
    transforms::infer_datatypes(&mut h)?;
    println!("\nper-tensor datatype annotations:");
    let mut any = false;
    for name in h.initializers.keys() {
        let dt = h.tensor_datatype(name);
        if dt != qonnx::datatypes::DataType::Float32 {
            println!("  initializer {:<24} -> {}", name, dt);
            any = true;
        }
    }
    for n in &h.nodes {
        for o in &n.outputs {
            let dt = h.tensor_datatype(o);
            if dt != qonnx::datatypes::DataType::Float32 {
                println!("  {:<18} {:<22} -> {}", n.op_type, o, dt);
                any = true;
            }
        }
    }
    // integer-domain accumulator growth demo: unit-scale 4-bit MatMul
    {
        use qonnx::ir::GraphBuilder;
        let mut b = GraphBuilder::new("acc_demo");
        b.input("x", vec![1, 64]);
        b.quant("x", "xq", 1.0, 0.0, 4.0, true, false, "ROUND");
        b.initializer("w", Tensor::full(vec![64, 8], 7.0));
        b.node("MatMul", &["xq", "w"], &["acc"], &[]);
        b.output("acc", vec![1, 8]);
        let mut d = b.finish()?;
        transforms::cleanup(&mut d)?;
        transforms::infer_datatypes(&mut d)?;
        println!(
            "  accumulator-width demo: INT4 x INT4 over k=64 -> {}",
            d.tensor_datatype("acc")
        );
        any = true;
    }
    let _ = any;
    println!("\nhls4ml_flow complete ✓");
    Ok(())
}

//! Quickstart: build a quantized model with the public API, clean it,
//! execute it, lower it to the backward-compatible QCDQ format, and prove
//! the lowered graph runs on a backend that knows nothing about QONNX.
//!
//! Run: `cargo run --release --example quickstart`

use qonnx::exec::{self, ExecOptions};
use qonnx::ir::GraphBuilder;
use qonnx::tensor::Tensor;
use qonnx::transforms;
use qonnx::zoo::{keras_to_qonnx, KerasModel};
use std::collections::BTreeMap;

#[rustfmt::skip] // hand-formatted walkthrough (predates fmt enforcement)
fn main() -> anyhow::Result<()> {
    // --- 1. build a small quantized MLP with the graph builder ---------
    let mut b = GraphBuilder::new("quickstart");
    b.input("x", vec![1, 16]);
    b.quant("x", "x_q", 1.0 / 16.0, 0.0, 8.0, false, false, "ROUND");
    b.initializer("w", Tensor::new(vec![16, 4], (0..64).map(|i| ((i % 13) as f32 - 6.0) * 0.1).collect()));
    b.quant("w", "w_q", 0.05, 0.0, 4.0, true, true, "ROUND");
    b.node("MatMul", &["x_q", "w_q"], &["h"], &[]);
    b.node("Relu", &["h"], &["r"], &[]);
    b.quant("r", "y", 0.25, 0.0, 4.0, false, false, "ROUND");
    b.output("y", vec![1, 4]);
    let mut g = b.finish()?;
    println!("built graph:\n{}", g.summary());

    // --- 2. clean + annotate datatypes ---------------------------------
    transforms::cleanup(&mut g)?;
    transforms::infer_datatypes(&mut g)?;
    println!("after cleanup, output datatype: {}", g.tensor_datatype("y"));

    // --- 3. execute with the reference executor ------------------------
    let x = Tensor::new(vec![1, 16], (0..16).map(|i| i as f32 / 16.0).collect());
    let y = exec::execute_simple(&g, &x)?;
    println!("QONNX execution: {:?}", y.as_f32()?);

    // --- 4. lower to QCDQ (paper §IV) and re-run on a *standard* backend
    let mut qcdq = g.clone();
    transforms::lower_to_qcdq(&mut qcdq)?;
    println!("\nQCDQ graph ops: {:?}", qcdq.op_histogram());
    let mut inputs = BTreeMap::new();
    inputs.insert("x".to_string(), x.clone());
    let opts = ExecOptions { standard_onnx_only: true, ..Default::default() };
    let y_qcdq = exec::execute_with(&qcdq, &inputs, &opts)?;
    assert_eq!(&y, y_qcdq.outputs.values().next().unwrap());
    println!("QCDQ execution on standard-ONNX-only backend: bit-exact match ✓");

    // --- 5. the QKeras-style ingestion path (paper §VI-A, Fig. 4) ------
    let mut keras = keras_to_qonnx(&KerasModel::fig4_example(), 1)?;
    transforms::cleanup(&mut keras)?;
    println!("\nconverted keras-like model:\n{}", keras.summary());
    Ok(())
}

//! Model-zoo report: regenerate the Table III metric columns and the
//! Fig. 5 series quickly (metrics only; `cargo bench` / `qonnx table3`
//! adds trained accuracy).
//!
//! Run: `cargo run --release --example zoo_report [-- --full-res]`

use qonnx::{metrics, transforms, zoo};

#[rustfmt::skip] // hand-formatted walkthrough (predates fmt enforcement)
fn main() -> anyhow::Result<()> {
    let full = std::env::args().any(|a| a == "--full-res");
    let mobilenet_res = if full { 224 } else { 64 };
    println!(
        "{:<18} {:<9} {:>10} {:>14} {:>16} {:>16} {:>11} {:>14}",
        "Model", "Dataset", "Acc(paper)", "MACs", "BOPs(Eq.5)", "MAC-BOPs", "Weights", "WeightBits"
    );
    for name in zoo::ZOO_NAMES {
        let res = if name.starts_with("MobileNet") { mobilenet_res } else { 32 };
        let mut g = zoo::build(name, 1, res)?;
        transforms::cleanup(&mut g)?;
        let r = metrics::analyze(&g)?;
        println!(
            "{:<18} {:<9} {:>10.2} {:>14} {:>16.4e} {:>16.4e} {:>11} {:>14}",
            name,
            zoo::dataset_of(name),
            zoo::paper_accuracy(name).unwrap_or(0.0),
            r.macs(),
            r.bops(),
            r.mac_bops(),
            r.weights(),
            r.total_weight_bits()
        );
    }
    if !full {
        println!("\n(MobileNet at reduced {mobilenet_res}x{mobilenet_res} input; pass --full-res for the paper's 224x224)");
    }
    Ok(())
}

"""AOT compile path: lower the L2 JAX models (with L1 Pallas kernels) to
HLO *text* artifacts for the Rust PJRT runtime, plus matching QONNX JSON
graphs for cross-checking.

HLO text -- NOT serialized HloModuleProto -- is the interchange format:
jax >= 0.5 emits protos with 64-bit instruction ids that xla_extension
0.5.1 rejects; the text parser reassigns ids (see /opt/xla-example).

Usage: python -m compile.aot --out ../artifacts
"""

import argparse
import functools
import json
import os

import jax
import numpy as np
from jax._src.lib import xla_client as xc

from . import model, qonnx_export
from .kernels import quant_pallas as k

BATCH = 8
VARIANTS = [(1, 1), (1, 2), (2, 2)]


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True)
    # plain as_hlo_text() ELIDES large constants ("constant({...})"), which
    # silently zeroes baked weights after the text round-trip -- print with
    # full constant payloads instead.
    opts = xc._xla.HloPrintOptions()
    opts.print_large_constants = True
    # xla_extension 0.5.1's text parser predates source_end_line/column
    # metadata fields -- strip metadata entirely.
    opts.print_metadata = False
    return comp.as_hlo_module().to_string(opts)


def export_tfc(out_dir: str, w_bits: int, a_bits: int) -> dict:
    params = model.make_tfc_params(w_bits, a_bits)
    fn = functools.partial(model.tfc_forward, params)
    spec = jax.ShapeDtypeStruct((BATCH, 784), np.float32)
    lowered = jax.jit(fn).lower(spec)
    tag = f"tfc_w{w_bits}a{a_bits}"
    hlo_path = os.path.join(out_dir, f"{tag}.hlo.txt")
    with open(hlo_path, "w") as f:
        f.write(to_hlo_text(lowered))
    with open(os.path.join(out_dir, f"{tag}.qonnx.json"), "w") as f:
        f.write(qonnx_export.tfc_to_qonnx_json(params, BATCH))
    # probe vector for runtime self-checks: input + expected output
    rng = np.random.default_rng(99)
    x = rng.uniform(0.0, 1.0, size=(BATCH, 784)).astype(np.float32)
    (y,) = model.tfc_forward_ref(params, x)
    meta = {
        "name": tag,
        "batch": BATCH,
        "input_shape": [BATCH, 784],
        "output_shape": [BATCH, 10],
        "probe_input": x.reshape(-1).tolist(),
        "probe_output": np.asarray(y).reshape(-1).tolist(),
    }
    with open(os.path.join(out_dir, f"{tag}.meta.json"), "w") as f:
        json.dump(meta, f)
    return meta


def export_quant_op(out_dir: str, bits: int, rows: int = 256, cols: int = 256):
    """Standalone Quant kernel artifact for runtime microbenches."""
    fn = lambda x: (k.quant(x, 0.125, 0.0, bits, signed=True),)  # noqa: E731
    spec = jax.ShapeDtypeStruct((rows, cols), np.float32)
    lowered = jax.jit(fn).lower(spec)
    path = os.path.join(out_dir, f"quant_b{bits}_{rows}x{cols}.hlo.txt")
    with open(path, "w") as f:
        f.write(to_hlo_text(lowered))


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="../artifacts",
                    help="artifact output directory (or a single .hlo.txt "
                         "path, in which case its directory is used)")
    args = ap.parse_args()
    out_dir = args.out
    if out_dir.endswith(".hlo.txt"):
        out_dir = os.path.dirname(out_dir) or "."
    os.makedirs(out_dir, exist_ok=True)
    for (w, a) in VARIANTS:
        meta = export_tfc(out_dir, w, a)
        print(f"exported {meta['name']} (batch {meta['batch']})")
    for bits in (2, 4, 8):
        export_quant_op(out_dir, bits)
        print(f"exported quant_b{bits} kernel")
    # the Makefile's sentinel artifact: default model = TFC-w2a2
    import shutil
    shutil.copyfile(os.path.join(out_dir, "tfc_w2a2.hlo.txt"),
                    os.path.join(out_dir, "model.hlo.txt"))
    print("wrote model.hlo.txt (default: tfc_w2a2)")


if __name__ == "__main__":
    main()

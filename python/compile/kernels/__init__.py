"""QONNX Pallas kernels (L1) and their pure-jnp oracle."""

from . import quant_pallas, ref  # noqa: F401

"""L1: Pallas kernels for the QONNX quantization operators.

All kernels are built with ``interpret=True`` -- the CPU PJRT plugin cannot
execute Mosaic custom-calls, so interpret mode is the correctness path and
real-TPU lowering is a compile-only target (see DESIGN.md Hardware
Adaptation).

TPU mapping notes (structure over wallclock -- interpret mode gives
CPU-numpy timings only):

* ``quant``/``bipolar_quant``/``trunc`` are elementwise VPU work. Rows are
  tiled with a 1-D grid and ``BlockSpec`` so each block's working set
  (one ``block_rows x cols`` f32 tile in and out) stays well inside the
  ~16 MiB VMEM budget; quantization parameters are compile-time constants
  folded into the kernel, costing no VMEM bandwidth.
* ``quant_linear`` tiles M x N output blocks with the full K panel per
  block: ``jnp.dot(..., preferred_element_type=jnp.float32)`` targets the
  MXU with an f32 accumulator (the "high-precision output" column of
  Table I), and the activation quantizer is fused into the same block so
  the accumulator never round-trips through HBM.
"""

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def quant_bounds_py(signed: bool, narrow: bool, bit_width: float):
    """Pure-Python Eq. 2-3 bounds (jnp constants become tracers inside
    jit as of jax 0.8, so static params must never touch jnp)."""
    if signed:
        lo = -(2.0 ** (bit_width - 1.0)) + (1.0 if narrow else 0.0)
        hi = 2.0 ** (bit_width - 1.0) - 1.0
    else:
        lo = 0.0
        hi = 2.0 ** bit_width - 1.0 - (1.0 if narrow else 0.0)
    return lo, hi


def _round_expr(v, mode: str):
    if mode == "ROUND":
        return jnp.round(v)
    if mode == "ROUND_TO_ZERO":
        return jnp.trunc(v)
    if mode == "CEIL":
        return jnp.ceil(v)
    if mode == "FLOOR":
        return jnp.floor(v)
    raise ValueError(f"unknown rounding_mode {mode!r}")


def _row_blocks(shape, block_rows):
    """Split the leading axis into grid blocks (elementwise kernels)."""
    rows = shape[0] if len(shape) > 1 else shape[0]
    block_rows = min(block_rows, rows)
    if rows % block_rows != 0:
        block_rows = 1  # ragged: fall back to row-at-a-time
    return block_rows


def quant(x, scale, zero_point, bit_width, *, signed=True, narrow=False,
          rounding_mode="ROUND", block_rows=128):
    """Pallas ``Quant``: fused quantize->dequantize (Eq. 1 + Eq. 4).

    ``scale``/``zero_point``/``bit_width`` are static Python floats folded
    into the kernel (the weight/static-activation case the AOT path needs).
    """
    x = jnp.asarray(x, jnp.float32)
    orig_shape = x.shape
    if x.ndim == 1:
        x = x[None, :]
    lo, hi = quant_bounds_py(signed, narrow, float(bit_width))
    s, z = float(scale), float(zero_point)

    def kernel(x_ref, o_ref):
        v = x_ref[...]
        q = jnp.clip(_round_expr(v / s + z, rounding_mode), lo, hi)
        o_ref[...] = ((q - z) * s).astype(jnp.float32)

    rows, cols = x.shape[0], int(math.prod(x.shape[1:]))
    x2 = x.reshape(rows, cols)
    br = _row_blocks(x2.shape, block_rows)
    out = pl.pallas_call(
        kernel,
        grid=(rows // br,),
        in_specs=[pl.BlockSpec((br, cols), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((br, cols), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((rows, cols), jnp.float32),
        interpret=True,
    )(x2)
    return out.reshape(orig_shape)


def bipolar_quant(x, scale, *, block_rows=128):
    """Pallas ``BipolarQuant``: y = scale * sign_{>=0}(x)."""
    x = jnp.asarray(x, jnp.float32)
    orig_shape = x.shape
    if x.ndim == 1:
        x = x[None, :]
    s = float(scale)

    def kernel(x_ref, o_ref):
        o_ref[...] = jnp.where(x_ref[...] >= 0, s, -s).astype(jnp.float32)

    rows, cols = x.shape[0], int(math.prod(x.shape[1:]))
    x2 = x.reshape(rows, cols)
    br = _row_blocks(x2.shape, block_rows)
    out = pl.pallas_call(
        kernel,
        grid=(rows // br,),
        in_specs=[pl.BlockSpec((br, cols), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((br, cols), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((rows, cols), jnp.float32),
        interpret=True,
    )(x2)
    return out.reshape(orig_shape)


def trunc(x, scale, zero_point, in_bit_width, out_bit_width,
          *, rounding_mode="FLOOR", block_rows=128):
    """Pallas ``Trunc``: right-shift LSBs away, scale/zero preserved."""
    x = jnp.asarray(x, jnp.float32)
    orig_shape = x.shape
    if x.ndim == 1:
        x = x[None, :]
    s, z = float(scale), float(zero_point)
    shift = 2.0 ** (float(in_bit_width) - float(out_bit_width))

    def kernel(x_ref, o_ref):
        q = jnp.round(x_ref[...] / s + z)
        q = _round_expr(q / shift, rounding_mode)
        o_ref[...] = ((q - z) * s).astype(jnp.float32)

    rows, cols = x.shape[0], int(math.prod(x.shape[1:]))
    x2 = x.reshape(rows, cols)
    br = _row_blocks(x2.shape, block_rows)
    out = pl.pallas_call(
        kernel,
        grid=(rows // br,),
        in_specs=[pl.BlockSpec((br, cols), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((br, cols), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((rows, cols), jnp.float32),
        interpret=True,
    )(x2)
    return out.reshape(orig_shape)


@functools.partial(jax.jit, static_argnames=(
    "w_bits", "a_bits", "narrow_w", "block_m", "block_n"))
def _noop(*a, **k):  # pragma: no cover - placeholder for jit cache symmetry
    return None


def quant_linear(x, w, w_scale, a_scale, w_bits, a_bits, *, narrow_w=True,
                 bias=None, block_m=8, block_n=128):
    """Fused quantized dense layer as one Pallas kernel.

    Weight qdq + ``x @ wq`` (MXU, f32 accumulator) + bias + activation qdq,
    all inside one M x N output tile so the wide accumulator never leaves
    VMEM. Reference: ``ref.quant_linear``.
    """
    x = jnp.asarray(x, jnp.float32)
    w = jnp.asarray(w, jnp.float32)
    m, k = x.shape
    k2, n = w.shape
    assert k == k2, (x.shape, w.shape)
    w_lo, w_hi = quant_bounds_py(True, narrow_w, float(w_bits))
    a_lo, a_hi = quant_bounds_py(True, False, float(a_bits))
    ws, as_ = float(w_scale), float(a_scale)
    has_bias = bias is not None
    bm = m if m % block_m != 0 else block_m
    bn = n if n % block_n != 0 else block_n

    def kernel(*refs):
        if has_bias:
            x_ref, w_ref, b_ref, o_ref = refs
        else:
            x_ref, w_ref, o_ref = refs
        wq = jnp.clip(jnp.round(w_ref[...] / ws), w_lo, w_hi) * ws
        z = jnp.dot(x_ref[...], wq, preferred_element_type=jnp.float32)
        if has_bias:
            z = z + b_ref[...]
        q = jnp.clip(jnp.round(z / as_), a_lo, a_hi)
        o_ref[...] = (q * as_).astype(jnp.float32)

    in_specs = [
        pl.BlockSpec((bm, k), lambda i, j: (i, 0)),
        pl.BlockSpec((k, bn), lambda i, j: (0, j)),
    ]
    args = [x, w]
    if has_bias:
        in_specs.append(pl.BlockSpec((bn,), lambda i, j: (j,)))
        args.append(jnp.asarray(bias, jnp.float32))
    return pl.pallas_call(
        kernel,
        grid=(m // bm, n // bn),
        in_specs=in_specs,
        out_specs=pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        interpret=True,
    )(*args)


def vmem_estimate_bytes(block_m, block_n, k, has_bias=False):
    """Static VMEM footprint estimate for a quant_linear tile (f32)."""
    tile_in = block_m * k          # x panel
    tile_w = k * block_n           # weight panel
    tile_out = block_m * block_n   # accumulator/output
    tile_b = block_n if has_bias else 0
    return 4 * (tile_in + tile_w + tile_out + tile_b)

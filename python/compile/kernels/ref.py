"""Pure-jnp oracle for the QONNX operator semantics (paper Table II).

This is the correctness reference the Pallas kernels (and, transitively,
the Rust executor -- see rust/tests/pjrt_parity.rs) are checked against.
Semantics mirror Eq. 1-4 of the paper and rust/src/ops/quant.rs exactly.
"""

import jax.numpy as jnp

ROUNDING_MODES = ("ROUND", "ROUND_TO_ZERO", "CEIL", "FLOOR")


def apply_rounding(v, mode: str):
    """QONNX rounding_mode semantics. ROUND is round-half-to-even."""
    if mode == "ROUND":
        return jnp.round(v)  # numpy rounds half to even
    if mode == "ROUND_TO_ZERO":
        return jnp.trunc(v)
    if mode == "CEIL":
        return jnp.ceil(v)
    if mode == "FLOOR":
        return jnp.floor(v)
    raise ValueError(f"unknown rounding_mode {mode!r}")


def quant_bounds(signed: bool, narrow: bool, bit_width: float):
    """Integer clamp bounds per Eqs. 2-3 + QONNX narrow/fractional widths."""
    bit_width = jnp.asarray(bit_width, jnp.float32)
    if signed:
        lo = -(2.0 ** (bit_width - 1.0)) + (1.0 if narrow else 0.0)
        hi = 2.0 ** (bit_width - 1.0) - 1.0
    else:
        lo = jnp.zeros_like(bit_width)
        hi = 2.0**bit_width - 1.0 - (1.0 if narrow else 0.0)
    return lo, hi


def quant(x, scale, zero_point, bit_width, *, signed=True, narrow=False,
          rounding_mode="ROUND"):
    """QONNX ``Quant``: fused quantize(Eq. 1) -> dequantize(Eq. 4)."""
    lo, hi = quant_bounds(signed, narrow, bit_width)
    q = apply_rounding(x / scale + zero_point, rounding_mode)
    q = jnp.clip(q, lo, hi)
    return ((q - zero_point) * scale).astype(jnp.float32)


def bipolar_quant(x, scale):
    """QONNX ``BipolarQuant``: scale * (+1 if x >= 0 else -1)."""
    return jnp.where(x >= 0, scale, -scale).astype(jnp.float32)


def trunc(x, scale, zero_point, in_bit_width, out_bit_width,
          *, rounding_mode="FLOOR"):
    """QONNX ``Trunc``: drop LSBs; input scale/zero_point preserved."""
    q = jnp.round(x / scale + zero_point)
    shift = 2.0 ** (jnp.asarray(in_bit_width, jnp.float32)
                    - jnp.asarray(out_bit_width, jnp.float32))
    q = apply_rounding(q / shift, rounding_mode)
    return ((q - zero_point) * scale).astype(jnp.float32)


def quant_linear(x, w, w_scale, a_scale, w_bits, a_bits,
                 *, narrow_w=True, bias=None):
    """Quantized dense layer: qdq weights, matmul, qdq activations.

    The reference for the fused Pallas ``quant_linear`` kernel.
    """
    wq = quant(w, w_scale, 0.0, w_bits, signed=True, narrow=narrow_w)
    z = jnp.dot(x, wq, preferred_element_type=jnp.float32)
    if bias is not None:
        z = z + bias
    return quant(z, a_scale, 0.0, a_bits, signed=True, narrow=False)

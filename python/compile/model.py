"""L2: the quantized TFC model forward pass in JAX, calling the L1 Pallas
kernels. Lowered once by aot.py; never imported at runtime.

Weights are generated deterministically (seeded) and exported BOTH as the
HLO artifact (weights baked as constants) and as a `.qonnx.json` QONNX
graph, so the Rust side can cross-check the PJRT executable against its
own reference executor on the *same* model -- the Brevitas-style export
path of paper §VI-B.
"""

import numpy as np

from .kernels import quant_pallas as k
from .kernels import ref

TFC_DIMS = [784, 64, 64, 64, 10]
INPUT_SCALE = 1.0 / 255.0


def make_tfc_params(w_bits: int, a_bits: int, seed: int = 7):
    """Deterministic He-initialized TFC parameters + quant scales."""
    rng = np.random.default_rng(seed)
    layers = []
    for i in range(len(TFC_DIMS) - 1):
        fin, fout = TFC_DIMS[i], TFC_DIMS[i + 1]
        w = rng.normal(0.0, np.sqrt(2.0 / fin), size=(fin, fout)).astype(np.float32)
        bias = rng.normal(0.0, 0.05, size=(fout,)).astype(np.float32)
        qmax = 2.0 ** (w_bits - 1) - 1 if w_bits > 1 else 1.0
        w_scale = float(np.abs(w).max() / qmax) if w_bits > 1 else float(np.abs(w).mean())
        a_scale = 1.0 / (2.0 ** (a_bits - 1) - 1) if a_bits > 1 else 1.0
        layers.append({
            "w": w,
            "bias": bias,
            "w_scale": w_scale,
            "a_scale": a_scale if i + 2 < len(TFC_DIMS) else None,
        })
    return {"layers": layers, "w_bits": w_bits, "a_bits": a_bits}


def tfc_forward(params, x):
    """Quantized forward pass. ``x``: [batch, 784] float32 in [0, 1]."""
    w_bits = params["w_bits"]
    a_bits = params["a_bits"]
    h = k.quant(x, INPUT_SCALE, 0.0, 8, signed=False)
    for layer in params["layers"]:
        w, bias = layer["w"], layer["bias"]
        if layer["a_scale"] is not None and w_bits > 1 and a_bits > 1:
            # hot path: fused Pallas quant-linear kernel
            h = k.quant_linear(h, w, layer["w_scale"], layer["a_scale"],
                               w_bits, a_bits, bias=bias)
        else:
            # bipolar / output layers: composed kernels
            if w_bits == 1:
                wq = k.bipolar_quant(w, layer["w_scale"])
            else:
                wq = k.quant(w, layer["w_scale"], 0.0, w_bits,
                             signed=True, narrow=True)
            import jax.numpy as jnp
            z = jnp.dot(h, wq, preferred_element_type=jnp.float32) + bias
            if layer["a_scale"] is None:
                h = z  # logits stay float
            elif a_bits == 1:
                h = k.bipolar_quant(z, layer["a_scale"])
            else:
                h = k.quant(z, layer["a_scale"], 0.0, a_bits, signed=True)
    return (h,)


def tfc_forward_ref(params, x):
    """Same forward pass through the pure-jnp oracle (no Pallas)."""
    import jax.numpy as jnp
    w_bits = params["w_bits"]
    a_bits = params["a_bits"]
    h = ref.quant(x, INPUT_SCALE, 0.0, 8, signed=False)
    for layer in params["layers"]:
        w, bias = layer["w"], layer["bias"]
        if w_bits == 1:
            wq = ref.bipolar_quant(w, layer["w_scale"])
        else:
            wq = ref.quant(w, layer["w_scale"], 0.0, w_bits, signed=True, narrow=True)
        z = jnp.dot(h, wq, preferred_element_type=jnp.float32) + bias
        if layer["a_scale"] is None:
            h = z
        elif a_bits == 1:
            h = ref.bipolar_quant(z, layer["a_scale"])
        else:
            h = ref.quant(z, layer["a_scale"], 0.0, a_bits, signed=True)
    return (h,)

"""Export the JAX TFC model as a `.qonnx.json` QONNX graph.

This is the Brevitas-style exporter of paper §VI-B: training-framework
quantizer configuration is partially evaluated into constants and emitted
as QONNX ``Quant``/``BipolarQuant`` nodes. The JSON schema mirrors
rust/src/ir/json.rs (`format: qonnx.json/v1`).
"""

import json

DOMAIN_QONNX = "qonnx.custom_op.general"


def _tensor(arr):
    import numpy as np
    a = np.asarray(arr, dtype=np.float32)
    return {"shape": [int(d) for d in a.shape],
            "dtype": "f32",
            "data": [float(v) for v in a.reshape(-1)]}


def _scalar(v):
    return {"shape": [], "dtype": "f32", "data": [float(v)]}


def _node(op, inputs, outputs, name, domain="", attrs=None):
    return {"name": name, "op_type": op, "domain": domain,
            "inputs": inputs, "outputs": outputs, "attrs": attrs or {}}


def _quant_node(builder, x, y, scale, zero_point, bit_width, signed, narrow,
                name):
    builder["initializers"][f"{y}_scale"] = _scalar(scale)
    builder["initializers"][f"{y}_zeropt"] = _scalar(zero_point)
    builder["initializers"][f"{y}_bitwidth"] = _scalar(bit_width)
    builder["nodes"].append(_node(
        "Quant", [x, f"{y}_scale", f"{y}_zeropt", f"{y}_bitwidth"], [y],
        name, DOMAIN_QONNX,
        {"signed": {"i": 1 if signed else 0},
         "narrow": {"i": 1 if narrow else 0},
         "rounding_mode": {"s": "ROUND"}}))


def _bipolar_node(builder, x, y, scale, name):
    builder["initializers"][f"{y}_scale"] = _scalar(scale)
    builder["nodes"].append(_node(
        "BipolarQuant", [x, f"{y}_scale"], [y], name, DOMAIN_QONNX))


def tfc_to_qonnx_json(params, batch: int) -> str:
    """Serialize the model of compile.model.make_tfc_params as QONNX."""
    w_bits = params["w_bits"]
    a_bits = params["a_bits"]
    g = {
        "format": "qonnx.json/v1",
        "name": f"TFC-w{w_bits}a{a_bits}",
        "doc": "exported from python/compile (Brevitas-style QONNX export)",
        "opset": {"": 16, DOMAIN_QONNX: 1},
        "inputs": [{"name": "x", "shape": [batch, 784]}],
        "outputs": [{"name": "logits", "shape": [batch, 10]}],
        "nodes": [],
        "initializers": {},
        "value_info": {},
    }
    _quant_node(g, "x", "x_q", 1.0 / 255.0, 0.0, 8.0, False, False, "inq")
    cur = "x_q"
    n_layers = len(params["layers"])
    for i, layer in enumerate(params["layers"]):
        w_name, wq_name = f"fc{i}_w", f"fc{i}_wq"
        g["initializers"][w_name] = _tensor(layer["w"])
        if w_bits == 1:
            _bipolar_node(g, w_name, wq_name, layer["w_scale"], f"wq{i}")
        else:
            _quant_node(g, w_name, wq_name, layer["w_scale"], 0.0,
                        float(w_bits), True, True, f"wq{i}")
        mm = f"fc{i}_out"
        g["nodes"].append(_node("MatMul", [cur, wq_name], [mm], f"mm{i}"))
        b_name, biased = f"fc{i}_bias", f"fc{i}_biased"
        g["initializers"][b_name] = _tensor(layer["bias"])
        g["nodes"].append(_node("Add", [mm, b_name], [biased], f"add{i}"))
        cur = biased
        if layer["a_scale"] is not None:
            aq = f"act{i}_q"
            if a_bits == 1:
                _bipolar_node(g, cur, aq, layer["a_scale"], f"aq{i}")
            else:
                _quant_node(g, cur, aq, layer["a_scale"], 0.0, float(a_bits),
                            True, False, f"aq{i}")
            cur = aq
        elif i == n_layers - 1:
            g["nodes"].append(_node("Identity", [cur], ["logits"], "out"))
    return json.dumps(g)

"""AOT artifact checks: HLO text structure + QONNX JSON well-formedness."""

import json
import os

import numpy as np
import pytest

from compile import aot, model, qonnx_export

ART = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts")


def test_qonnx_json_schema():
    params = model.make_tfc_params(2, 2)
    doc = json.loads(qonnx_export.tfc_to_qonnx_json(params, 8))
    assert doc["format"] == "qonnx.json/v1"
    for field in ("name", "doc", "opset", "inputs", "outputs", "nodes",
                  "initializers", "value_info"):
        assert field in doc
    ops = [n["op_type"] for n in doc["nodes"]]
    assert ops.count("MatMul") == 4
    assert ops.count("Quant") == 5 + 3  # input + 4 weights + 3 act (w2a2)
    # every node input resolves
    produced = set(doc["initializers"]) | {i["name"] for i in doc["inputs"]}
    for n in doc["nodes"]:
        for t in n["inputs"]:
            assert t in produced, f"dangling input {t}"
        produced.update(n["outputs"])
    assert "logits" in produced


def test_bipolar_export_uses_bipolar_nodes():
    params = model.make_tfc_params(1, 1)
    doc = json.loads(qonnx_export.tfc_to_qonnx_json(params, 8))
    ops = [n["op_type"] for n in doc["nodes"]]
    assert ops.count("BipolarQuant") == 4 + 3
    assert ops.count("Quant") == 1  # input only


def test_hlo_text_lowering():
    params = model.make_tfc_params(2, 2)
    import functools
    import jax
    fn = functools.partial(model.tfc_forward, params)
    spec = jax.ShapeDtypeStruct((8, 784), np.float32)
    text = aot.to_hlo_text(jax.jit(fn).lower(spec))
    assert text.startswith("HloModule")
    assert "ENTRY" in text
    assert "f32[8,784]" in text
    assert "f32[8,10]" in text


@pytest.mark.skipif(not os.path.isdir(ART), reason="artifacts not built")
def test_artifact_probe_vectors_exist():
    for tag in ("tfc_w1a1", "tfc_w1a2", "tfc_w2a2"):
        meta_path = os.path.join(ART, f"{tag}.meta.json")
        if not os.path.exists(meta_path):
            pytest.skip("artifacts incomplete")
        meta = json.load(open(meta_path))
        assert len(meta["probe_input"]) == meta["batch"] * 784
        assert len(meta["probe_output"]) == meta["batch"] * 10
        assert os.path.exists(os.path.join(ART, f"{tag}.hlo.txt"))
        assert os.path.exists(os.path.join(ART, f"{tag}.qonnx.json"))

"""Pallas kernels vs the pure-jnp oracle -- the core L1 correctness signal.

Sweeps shapes, bit widths, signedness, narrow-range, and rounding modes
(the hypothesis-style parameter grid for this environment).
"""

import numpy as np
import pytest

from compile.kernels import quant_pallas as qp
from compile.kernels import ref

SHAPES = [(1, 8), (3, 5), (64,), (128, 32), (2, 3, 4)]
BITS = [2, 3, 4, 5, 8]


def _data(shape, seed=0, scale=4.0):
    rng = np.random.default_rng(seed)
    return (rng.normal(0.0, scale, size=shape)).astype(np.float32)


@pytest.mark.parametrize("shape", SHAPES)
@pytest.mark.parametrize("bits", BITS)
def test_quant_matches_ref_shapes_bits(shape, bits):
    x = _data(shape, seed=bits)
    got = qp.quant(x, 0.25, 0.0, bits, signed=True)
    want = ref.quant(x, 0.25, 0.0, bits, signed=True)
    np.testing.assert_allclose(got, want)


@pytest.mark.parametrize("signed", [True, False])
@pytest.mark.parametrize("narrow", [True, False])
def test_quant_signedness_narrow(signed, narrow):
    x = _data((16, 16), seed=3)
    got = qp.quant(x, 0.5, 0.0, 4, signed=signed, narrow=narrow)
    want = ref.quant(x, 0.5, 0.0, 4, signed=signed, narrow=narrow)
    np.testing.assert_allclose(got, want)


@pytest.mark.parametrize("mode", ref.ROUNDING_MODES)
def test_quant_rounding_modes(mode):
    # include exact .5 grid points to pin tie behavior
    x = np.array([[-1.5, -0.5, 0.5, 1.5, 2.5, 0.26, -0.74]], np.float32)
    got = qp.quant(x, 1.0, 0.0, 8, rounding_mode=mode)
    want = ref.quant(x, 1.0, 0.0, 8, rounding_mode=mode)
    np.testing.assert_allclose(got, want)


def test_quant_zero_point():
    x = _data((8, 8), seed=5)
    got = qp.quant(x, 0.25, 3.0, 4, signed=False)
    want = ref.quant(x, 0.25, 3.0, 4, signed=False)
    np.testing.assert_allclose(got, want)


def test_quant_fractional_bit_width():
    # paper §V: non-power-of-two integer intervals via float bit_width
    x = _data((8, 8), seed=6, scale=200.0)
    got = qp.quant(x, 1.0, 0.0, 7.5, signed=True)
    want = ref.quant(x, 1.0, 0.0, 7.5, signed=True)
    np.testing.assert_allclose(got, want)


def test_quant_saturates():
    x = np.array([[1e6, -1e6]], np.float32)
    y = np.asarray(qp.quant(x, 1.0, 0.0, 4, signed=True))
    assert y[0, 0] == 7.0 and y[0, 1] == -8.0


def test_quant_output_on_grid():
    x = _data((32, 32), seed=7)
    y = np.asarray(qp.quant(x, 0.125, 0.0, 6, signed=True))
    q = y / 0.125
    np.testing.assert_allclose(q, np.round(q), atol=1e-5)


@pytest.mark.parametrize("shape", SHAPES)
def test_bipolar_matches_ref(shape):
    x = _data(shape, seed=11)
    np.testing.assert_allclose(qp.bipolar_quant(x, 0.5), ref.bipolar_quant(x, 0.5))


def test_bipolar_zero_maps_positive():
    x = np.zeros((4, 4), np.float32)
    assert np.all(np.asarray(qp.bipolar_quant(x, 1.0)) == 1.0)


@pytest.mark.parametrize("mode", ["FLOOR", "CEIL", "ROUND"])
def test_trunc_matches_ref(mode):
    x = np.arange(0, 256, dtype=np.float32).reshape(16, 16)
    got = qp.trunc(x, 1.0, 0.0, 10, 8, rounding_mode=mode)
    want = ref.trunc(x, 1.0, 0.0, 10, 8, rounding_mode=mode)
    np.testing.assert_allclose(got, want)


def test_trunc_avgpool_shift():
    # 10-bit sum truncated to 8 bits = floor(x / 4)
    x = np.array([[100.0, 203.0, 1023.0]], np.float32)
    y = np.asarray(qp.trunc(x, 1.0, 0.0, 10, 8))
    np.testing.assert_allclose(y, [[25.0, 50.0, 255.0]])


@pytest.mark.parametrize("m,k,n", [(8, 16, 32), (8, 784, 64), (1, 7, 3), (5, 11, 13)])
def test_quant_linear_matches_ref(m, k, n):
    rng = np.random.default_rng(m * 100 + n)
    x = rng.normal(size=(m, k)).astype(np.float32)
    w = rng.normal(size=(k, n)).astype(np.float32)
    got = qp.quant_linear(x, w, 0.125, 0.25, 4, 4)
    want = ref.quant_linear(x, w, 0.125, 0.25, 4, 4)
    np.testing.assert_allclose(got, want, rtol=1e-6, atol=1e-6)


def test_quant_linear_with_bias():
    rng = np.random.default_rng(9)
    x = rng.normal(size=(8, 16)).astype(np.float32)
    w = rng.normal(size=(16, 128)).astype(np.float32)
    b = rng.normal(size=(128,)).astype(np.float32)
    got = qp.quant_linear(x, w, 0.125, 0.25, 2, 2, bias=b)
    want = ref.quant_linear(x, w, 0.125, 0.25, 2, 2, bias=b)
    np.testing.assert_allclose(got, want, rtol=1e-6, atol=1e-6)


def test_quant_linear_block_shapes_dont_change_result():
    rng = np.random.default_rng(10)
    x = rng.normal(size=(16, 64)).astype(np.float32)
    w = rng.normal(size=(64, 256)).astype(np.float32)
    a = qp.quant_linear(x, w, 0.1, 0.2, 4, 4, block_m=8, block_n=128)
    b = qp.quant_linear(x, w, 0.1, 0.2, 4, 4, block_m=16, block_n=64)
    np.testing.assert_allclose(a, b)


def test_vmem_estimate_within_budget():
    # the TFC hot layer: 8x784 @ 784x64 tile fits VMEM easily
    bytes_ = qp.vmem_estimate_bytes(8, 64, 784, has_bias=True)
    assert bytes_ < 16 * 1024 * 1024

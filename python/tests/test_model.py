"""L2 model checks: Pallas-kernel forward vs oracle forward, shapes,
determinism, grid properties."""

import numpy as np
import pytest

from compile import model


@pytest.mark.parametrize("w,a", [(1, 1), (1, 2), (2, 2)])
def test_forward_matches_ref(w, a):
    params = model.make_tfc_params(w, a)
    rng = np.random.default_rng(1)
    x = rng.uniform(0, 1, size=(8, 784)).astype(np.float32)
    (y_pallas,) = model.tfc_forward(params, x)
    (y_ref,) = model.tfc_forward_ref(params, x)
    np.testing.assert_allclose(np.asarray(y_pallas), np.asarray(y_ref),
                               rtol=1e-5, atol=1e-5)


def test_forward_shape_and_determinism():
    params = model.make_tfc_params(2, 2)
    x = np.full((8, 784), 0.5, np.float32)
    (a,) = model.tfc_forward(params, x)
    (b,) = model.tfc_forward(params, x)
    assert a.shape == (8, 10)
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_params_deterministic():
    p1 = model.make_tfc_params(2, 2, seed=7)
    p2 = model.make_tfc_params(2, 2, seed=7)
    for l1, l2 in zip(p1["layers"], p2["layers"]):
        np.testing.assert_array_equal(l1["w"], l2["w"])


def test_hidden_activations_quantized():
    # run 2 layers manually and check the intermediate lands on the a-grid
    params = model.make_tfc_params(2, 2)
    from compile.kernels import ref
    x = np.random.default_rng(3).uniform(0, 1, (4, 784)).astype(np.float32)
    h = ref.quant(x, model.INPUT_SCALE, 0.0, 8, signed=False)
    layer = params["layers"][0]
    wq = ref.quant(layer["w"], layer["w_scale"], 0.0, 2, signed=True, narrow=True)
    import jax.numpy as jnp
    z = jnp.dot(h, wq) + layer["bias"]
    aq = ref.quant(z, layer["a_scale"], 0.0, 2, signed=True)
    grid = np.asarray(aq) / layer["a_scale"]
    np.testing.assert_allclose(grid, np.round(grid), atol=1e-4)
    assert grid.min() >= -2.0 and grid.max() <= 1.0

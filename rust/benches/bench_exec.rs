//! End-to-end execution performance: reference executor vs PJRT artifact
//! engine, Quant-kernel microbenches (Rust op vs Pallas-compiled HLO), and
//! the serving batcher's throughput/latency trade-off. This is the §Perf
//! measurement harness of EXPERIMENTS.md.

use qonnx::bench_support::{bench, bench_for, section};
use qonnx::coordinator::{
    Batcher, BatcherConfig, InferenceEngine, PjrtEngine, PlannedEngine, ReferenceEngine,
    SubmitError,
};
use qonnx::ir::Node;
use qonnx::plan::{ExecutionPlan, PlanOptions};
use qonnx::runtime::{artifacts_dir, PjrtRuntime};
use qonnx::tensor::Tensor;
use qonnx::zoo::{cnv, tfc_batch, TfcParams};
use qonnx::{exec, ops, transforms};
use std::collections::BTreeMap;
use std::fmt::Write as _;
use std::sync::Arc;
use std::time::Duration;

/// Machine-readable results for CI trend tracking (`make bench` writes
/// this to the repo root as BENCH_PR7.json).
#[derive(Default)]
struct BenchJson {
    entries: Vec<(String, f64)>,
}

impl BenchJson {
    fn record(&mut self, key: &str, value: f64) {
        self.entries.push((key.to_string(), value));
    }

    fn write(&self, path: &str) {
        let mut s = String::from("{\n");
        for (i, (k, v)) in self.entries.iter().enumerate() {
            let comma = if i + 1 == self.entries.len() { "" } else { "," };
            let _ = writeln!(s, "  \"{k}\": {v:.4}{comma}");
        }
        s.push_str("}\n");
        match std::fs::write(path, &s) {
            Ok(()) => println!("\nwrote {path}"),
            Err(e) => println!("\nfailed to write {path}: {e}"),
        }
    }
}

fn main() -> anyhow::Result<()> {
    let mut json = BenchJson::default();
    section("Quant operator microbench (256x256 tensor)");
    let x = Tensor::new(vec![256, 256], (0..65536).map(|i| (i % 509) as f32 * 0.01 - 2.5).collect());
    let quant_node = Node::new("Quant", &["x", "s", "z", "b"], &["y"])
        .with_attr("signed", 1i64)
        .with_attr("rounding_mode", "ROUND");
    let s = Tensor::scalar(0.125);
    let z = Tensor::scalar(0.0);
    let b4 = Tensor::scalar(4.0);
    let st = bench("rust Quant op (int4, 64k elems)", 3, 50, || {
        ops::quant::quant_op(&quant_node, &[&x, &s, &z, &b4]).unwrap()
    });
    println!("{}", st.report());
    println!(
        "  -> {:.1} Melem/s",
        65536.0 / st.mean.as_secs_f64() / 1e6
    );
    json.record("quant_int4_melem_per_s", 65536.0 / st.mean.as_secs_f64() / 1e6);

    let quant_artifact = artifacts_dir().join("quant_b4_256x256.hlo.txt");
    if quant_artifact.exists() {
        let rt = PjrtRuntime::cpu()?;
        let m = rt.load_hlo_text(&quant_artifact, vec![256, 256], vec![256, 256])?;
        let st = bench("PJRT Pallas-quant artifact (int4, 64k elems)", 3, 50, || m.execute(&x).unwrap());
        println!("{}", st.report());
        println!("  -> {:.1} Melem/s", 65536.0 / st.mean.as_secs_f64() / 1e6);
    } else {
        println!("(PJRT quant artifact missing — run `make artifacts`)");
    }

    section("compiled ExecutionPlan vs name-keyed interpreter (zoo TFC-w2a2)");
    // the tentpole comparison: one plan compiled up front (weight quants
    // folded, weights Arc-resident, slot-indexed hot loop) vs the
    // interpreter re-resolving names/topo/dispatch per request.
    for batch in [1usize, 8] {
        let gt = tfc_batch(&TfcParams::random(2, 2, 5), batch)?;
        let plan = ExecutionPlan::compile(&gt)?;
        let mut inputs = BTreeMap::new();
        inputs.insert(
            gt.inputs[0].name.clone(),
            Tensor::new(
                vec![batch, 784],
                (0..batch * 784).map(|i| (i % 255) as f32 / 255.0).collect(),
            ),
        );
        let st_i = bench(
            &format!("name-keyed interpreter TFC-w2a2 b{batch}"),
            3,
            if batch == 1 { 300 } else { 100 },
            || exec::interpret(&gt, &inputs).unwrap(),
        );
        println!("{}", st_i.report());
        let st_p = bench(
            &format!("compiled plan        TFC-w2a2 b{batch}"),
            3,
            if batch == 1 { 300 } else { 100 },
            || plan.run(&inputs).unwrap(),
        );
        println!("{}", st_p.report());
        println!(
            "  -> plan speedup over interpreter (b{batch}): {:.2}x  ({:.0} vs {:.0} req/s)",
            st_i.mean.as_secs_f64() / st_p.mean.as_secs_f64(),
            1.0 / st_p.mean.as_secs_f64(),
            1.0 / st_i.mean.as_secs_f64(),
        );
        json.record(
            &format!("tfc_b{batch}_plan_vs_interp_speedup"),
            st_i.mean.as_secs_f64() / st_p.mean.as_secs_f64(),
        );
        json.record(&format!("tfc_b{batch}_plan_req_per_s"), 1.0 / st_p.mean.as_secs_f64());
        if batch == 1 {
            let st_c = bench("plan compile (one-time) TFC-w2a2", 3, 50, || {
                ExecutionPlan::compile(&gt).unwrap()
            });
            println!("{}", st_c.report());
            println!(
                "  plan: {} steps / {} slots ({} nodes folded, {} elided)",
                plan.step_count(),
                plan.slot_count(),
                plan.folded_count(),
                plan.elided_count()
            );
        }
    }

    section("TFC inference latency (batch 8)");
    let g = tfc_batch(&TfcParams::random(2, 2, 5), 8)?;
    let mut plan_engine = PlannedEngine::new(&g)?;
    let mut ref_engine = ReferenceEngine::new(g)?;
    let xb = Tensor::full(vec![8, 784], 0.5);
    let st = bench("reference executor TFC-w2a2 b8", 3, 30, || ref_engine.infer_batch(&xb).unwrap());
    println!("{}", st.report());
    let st_pe = bench("planned engine TFC-w2a2 b8", 3, 100, || plan_engine.infer_batch(&xb).unwrap());
    println!("{}", st_pe.report());
    println!(
        "  -> planned engine speedup over reference engine: {:.1}x",
        st.mean.as_secs_f64() / st_pe.mean.as_secs_f64()
    );
    let tfc_stem = artifacts_dir().join("tfc_w2a2");
    if tfc_stem.with_extension("hlo.txt").exists() {
        let rt = PjrtRuntime::cpu()?;
        let mut pjrt_engine = PjrtEngine::load(&rt, &tfc_stem)?;
        let st_p = bench("PJRT artifact TFC-w2a2 b8", 3, 100, || pjrt_engine.infer_batch(&xb).unwrap());
        println!("{}", st_p.report());
        println!(
            "  -> PJRT speedup over reference executor: {:.1}x",
            st.mean.as_secs_f64() / st_p.mean.as_secs_f64()
        );
    }

    section("CNV-w2a2 single-image inference (interpreter vs generic plan vs packed plan)");
    let mut cg = cnv(2, 2, 3, false)?;
    transforms::cleanup(&mut cg)?;
    let xc = Tensor::full(vec![1, 3, 32, 32], 0.4);
    let mut cin = BTreeMap::new();
    cin.insert(cg.inputs[0].name.clone(), xc);
    let st = bench_for("name-keyed interpreter CNV-w2a2 (59M MACs)", Duration::from_secs(3), || {
        exec::interpret(&cg, &cin).unwrap()
    });
    println!("{}", st.report());
    println!(
        "  -> effective {:.2} GMAC/s",
        59.46e6 / st.mean.as_secs_f64() / 1e9
    );
    // the PR 1 plan path: fn-pointer dispatch + slot arena, but generic
    // kernels (per-request weight transpose, vec!-allocated scratch)
    let generic_opts = PlanOptions { specialize: false, ..Default::default() };
    let gplan = ExecutionPlan::compile_with(&cg, &generic_opts)?;
    let st_gp = bench_for("generic plan (PR1 path) CNV-w2a2", Duration::from_secs(3), || {
        gplan.run(&cin).unwrap()
    });
    println!("{}", st_gp.report());
    println!(
        "  -> effective {:.2} GMAC/s, {:.2}x over interpreter",
        59.46e6 / st_gp.mean.as_secs_f64() / 1e9,
        st.mean.as_secs_f64() / st_gp.mean.as_secs_f64()
    );
    // the PR 2 path: prepacked weights, fused epilogues, arena scratch
    let cplan = ExecutionPlan::compile(&cg)?;
    println!(
        "  packed plan: {} steps, {} packed kernels, {} fused epilogues",
        cplan.step_count(),
        cplan.packed_count(),
        cplan.fused_epilogue_count()
    );
    let st_cp = bench_for("packed plan CNV-w2a2 (59M MACs)", Duration::from_secs(3), || {
        cplan.run(&cin).unwrap()
    });
    println!("{}", st_cp.report());
    println!(
        "  -> effective {:.2} GMAC/s, {:.2}x over interpreter, {:.2}x over generic plan",
        59.46e6 / st_cp.mean.as_secs_f64() / 1e9,
        st.mean.as_secs_f64() / st_cp.mean.as_secs_f64(),
        st_gp.mean.as_secs_f64() / st_cp.mean.as_secs_f64()
    );
    json.record("cnv_b1_interp_gmac_per_s", 59.46e6 / st.mean.as_secs_f64() / 1e9);
    json.record("cnv_b1_generic_plan_gmac_per_s", 59.46e6 / st_gp.mean.as_secs_f64() / 1e9);
    json.record("cnv_b1_packed_plan_gmac_per_s", 59.46e6 / st_cp.mean.as_secs_f64() / 1e9);
    json.record(
        "cnv_b1_plan_vs_interp_speedup",
        st.mean.as_secs_f64() / st_cp.mean.as_secs_f64(),
    );
    json.record(
        "cnv_b1_packed_vs_pr1_plan_speedup",
        st_gp.mean.as_secs_f64() / st_cp.mean.as_secs_f64(),
    );

    section("batched CNV-w2a2: batch-symbolic plan vs per-sample plan vs interpreter");
    // The PR-3 tentpole measurement: one batch-symbolic plan invocation
    // on [n, 3, 32, 32] vs n per-sample invocations of the same plan.
    // The interpreter cannot execute batched CNV at all (its Reshape
    // keeps the batch-1-baked target), so its per-image rate from the
    // b1 measurement above IS its per-sample serving rate.
    let interp_img_per_s = 1.0 / st.mean.as_secs_f64();
    json.record("cnv_interp_img_per_s", interp_img_per_s);
    let in_name = cg.inputs[0].name.clone();
    let out_name = cg.outputs[0].name.clone();
    println!(
        "  plan: {} batch-symbolic reshapes; interpreter baseline {:.1} img/s",
        cplan.batch_symbolic_count(),
        interp_img_per_s
    );
    let free = qonnx::plan::RunConfig {
        shape_check: qonnx::plan::ShapeCheck::FreeBatch,
        record_intermediates: false,
    };
    for batch in [1usize, 8, 32] {
        let xb = Tensor::new(
            vec![batch, 3, 32, 32],
            (0..batch * 3072).map(|i| (i % 253) as f32 / 253.0).collect(),
        );
        // correctness first: batched row i == per-sample run on row i
        let yb = cplan
            .run_cfg(|n| (n == in_name).then_some(&xb), &free)?
            .outputs
            .remove(&out_name)
            .unwrap();
        let rows = xb.as_f32()?;
        for r in 0..batch {
            let img = Tensor::new(vec![1, 3, 32, 32], rows[r * 3072..(r + 1) * 3072].to_vec());
            let mut m = BTreeMap::new();
            m.insert(in_name.clone(), img);
            let y1 = cplan.run(&m)?.remove(&out_name).unwrap();
            assert_eq!(
                &yb.as_f32()?[r * 10..(r + 1) * 10],
                y1.as_f32()?,
                "batched row {r} diverged from per-sample run"
            );
        }
        let st_b = bench_for(
            &format!("batch-symbolic plan CNV-w2a2 b{batch} (one invocation)"),
            Duration::from_secs(2),
            || cplan.run_cfg(|n| (n == in_name).then_some(&xb), &free).unwrap(),
        );
        println!("{}", st_b.report());
        let st_s = bench_for(
            &format!("per-sample plan      CNV-w2a2 b{batch} ({batch} invocations)"),
            Duration::from_secs(2),
            || {
                for r in 0..batch {
                    let img =
                        Tensor::new(vec![1, 3, 32, 32], rows[r * 3072..(r + 1) * 3072].to_vec());
                    let mut m = BTreeMap::new();
                    m.insert(in_name.clone(), img);
                    cplan.run(&m).unwrap();
                }
            },
        );
        println!("{}", st_s.report());
        let batched_ips = batch as f64 / st_b.mean.as_secs_f64();
        let per_sample_ips = batch as f64 / st_s.mean.as_secs_f64();
        println!(
            "  -> b{batch}: batched {batched_ips:.1} img/s, per-sample {per_sample_ips:.1} img/s \
             ({:.2}x), interpreter {interp_img_per_s:.1} img/s ({:.2}x)",
            st_s.mean.as_secs_f64() / st_b.mean.as_secs_f64(),
            batched_ips / interp_img_per_s
        );
        json.record(&format!("cnv_b{batch}_batched_plan_img_per_s"), batched_ips);
        json.record(&format!("cnv_b{batch}_per_sample_plan_img_per_s"), per_sample_ips);
        json.record(
            &format!("cnv_b{batch}_batched_vs_per_sample_speedup"),
            st_s.mean.as_secs_f64() / st_b.mean.as_secs_f64(),
        );
        json.record(
            &format!("cnv_b{batch}_batched_vs_interp_speedup"),
            batched_ips / interp_img_per_s,
        );
    }

    section("integer streamlined plan vs packed float plan (TFC/CNV, b1/b8)");
    // The PR-4 tentpole measurement: the streamline pass lowers the zoo
    // models to integer-domain form (Quant acts -> integer
    // MultiThreshold, i8 weights) and the plan's quantized tier executes
    // them with i8 panels + i32 accumulators + fused thresholds. Both
    // plans here are batch-symbolic, so each batch runs in ONE invocation.
    for model in ["TFC-w2a2", "CNV-w2a2"] {
        let mut g = qonnx::zoo::build(model, 1, 32)?;
        transforms::cleanup(&mut g)?;
        let sl = qonnx::streamline::try_streamline(&g)?;
        if !sl.report.ok {
            println!("({model} did not streamline — skipping)\n{}", sl.report.render());
            continue;
        }
        let fplan = ExecutionPlan::compile(&g)?;
        let qplan = ExecutionPlan::compile(&sl.graph)?;
        println!(
            "{model}: float plan {} steps / {} packed; integer plan {} steps / {} quantized, \
             {} fused thresholds",
            fplan.step_count(),
            fplan.packed_count(),
            qplan.step_count(),
            qplan.quant_kernel_count(),
            qplan.fused_epilogue_count()
        );
        let in_name = g.inputs[0].name.clone();
        let in_shape = g.inputs[0].shape.clone().unwrap();
        let free = qonnx::plan::RunConfig {
            shape_check: qonnx::plan::ShapeCheck::FreeBatch,
            record_intermediates: false,
        };
        let key = if model.starts_with("TFC") { "tfc" } else { "cnv" };
        for batch in [1usize, 8] {
            let mut shape = in_shape.clone();
            shape[0] = batch;
            let numel: usize = shape.iter().product();
            let xb = Tensor::new(
                shape,
                (0..numel).map(|i| (i % 251) as f32 / 251.0).collect(),
            );
            let st_f = bench(
                &format!("float  plan {model} b{batch}"),
                3,
                if model.starts_with("TFC") { 200 } else { 10 },
                || fplan.run_cfg(|n| (n == in_name).then_some(&xb), &free).unwrap(),
            );
            println!("{}", st_f.report());
            let st_q = bench(
                &format!("integer plan {model} b{batch}"),
                3,
                if model.starts_with("TFC") { 200 } else { 10 },
                || qplan.run_cfg(|n| (n == in_name).then_some(&xb), &free).unwrap(),
            );
            println!("{}", st_q.report());
            let speedup = st_f.mean.as_secs_f64() / st_q.mean.as_secs_f64();
            println!(
                "  -> b{batch}: integer tier {:.2}x over packed float ({:.1} vs {:.1} req/s)",
                speedup,
                batch as f64 / st_q.mean.as_secs_f64(),
                batch as f64 / st_f.mean.as_secs_f64(),
            );
            json.record(
                &format!("{key}_b{batch}_int_plan_req_per_s"),
                batch as f64 / st_q.mean.as_secs_f64(),
            );
            json.record(&format!("{key}_b{batch}_int_vs_float_speedup"), speedup);
        }
    }

    section("resident-int plan vs convert-per-call plan (TFC/CNV, b1/b8)");
    // The PR-5 tentpole measurement: with integer residency, activations
    // stay in i8/i32 slots between quantized kernels (the MultiThreshold
    // emits integer levels in place and the next GEMM reads i8 panels);
    // convert-per-call (the PR-4 behavior, int_residency: false) writes
    // every intermediate back to f32 and re-validates + converts on entry
    // to every quantized kernel.
    for model in ["TFC-w2a2", "CNV-w2a2"] {
        let mut g = qonnx::zoo::build(model, 1, 32)?;
        transforms::cleanup(&mut g)?;
        let sl = qonnx::streamline::try_streamline(&g)?;
        if !sl.report.ok {
            println!("({model} did not streamline — skipping)\n{}", sl.report.render());
            continue;
        }
        let rplan = ExecutionPlan::compile(&sl.graph)?;
        let convert_opts = PlanOptions { int_residency: false, ..Default::default() };
        let cplan = ExecutionPlan::compile_with(&sl.graph, &convert_opts)?;
        let int_slots = rplan
            .slot_dtypes()
            .iter()
            .filter(|d| matches!(d, qonnx::tensor::DType::I8 | qonnx::tensor::DType::I32))
            .count();
        println!(
            "{model}: {} integer-resident values, {int_slots}/{} integer slots \
             (convert-per-call plan: {})",
            rplan.resident_int_count(),
            rplan.slot_count(),
            cplan.resident_int_count()
        );
        let in_name = g.inputs[0].name.clone();
        let in_shape = g.inputs[0].shape.clone().unwrap();
        let free = qonnx::plan::RunConfig {
            shape_check: qonnx::plan::ShapeCheck::FreeBatch,
            record_intermediates: false,
        };
        let key = if model.starts_with("TFC") { "tfc" } else { "cnv" };
        for batch in [1usize, 8] {
            let mut shape = in_shape.clone();
            shape[0] = batch;
            let numel: usize = shape.iter().product();
            let xb = Tensor::new(shape, (0..numel).map(|i| (i % 249) as f32 / 249.0).collect());
            // correctness before speed: residency must not change bytes
            let yr = rplan.run_cfg(|n| (n == in_name).then_some(&xb), &free)?;
            let yc = cplan.run_cfg(|n| (n == in_name).then_some(&xb), &free)?;
            assert_eq!(yr.outputs, yc.outputs, "residency changed values on {model} b{batch}");
            let iters = if model.starts_with("TFC") { 200 } else { 10 };
            let st_c = bench(
                &format!("convert-per-call {model} b{batch}"),
                3,
                iters,
                || cplan.run_cfg(|n| (n == in_name).then_some(&xb), &free).unwrap(),
            );
            println!("{}", st_c.report());
            let st_r = bench(
                &format!("resident-int     {model} b{batch}"),
                3,
                iters,
                || rplan.run_cfg(|n| (n == in_name).then_some(&xb), &free).unwrap(),
            );
            println!("{}", st_r.report());
            let speedup = st_c.mean.as_secs_f64() / st_r.mean.as_secs_f64();
            println!(
                "  -> b{batch}: resident-int {:.2}x over convert-per-call ({:.1} vs {:.1} req/s)",
                speedup,
                batch as f64 / st_r.mean.as_secs_f64(),
                batch as f64 / st_c.mean.as_secs_f64(),
            );
            json.record(
                &format!("{key}_b{batch}_resident_int_req_per_s"),
                batch as f64 / st_r.mean.as_secs_f64(),
            );
            json.record(&format!("{key}_b{batch}_resident_vs_convert_speedup"), speedup);
        }
    }

    section("quantized kernel tier: scalar vs SIMD vs SIMD+pool (CNV-w2a2)");
    // The PR-6 tentpole measurement: the i8xi8->i32 microkernel
    // (tensor::simd — AVX2 sign-split maddubs / NEON vmull_s8) and the
    // persistent intra-op worker pool (runtime::pool). Scalar is the SAME
    // compiled plan flipped via QONNX_FORCE_SCALAR at run time; thread
    // counts are pinned through the pool's per-thread intra-op limit.
    // Every variant is byte-identical (i32 accumulation is order-free).
    {
        use qonnx::runtime::pool;
        use qonnx::tensor::simd;
        let mut g = qonnx::zoo::build("CNV-w2a2", 1, 32)?;
        transforms::cleanup(&mut g)?;
        let sl = qonnx::streamline::try_streamline(&g)?;
        if sl.report.ok {
            let qplan = ExecutionPlan::compile(&sl.graph)?;
            println!(
                "active isa {} | pool {} threads | plan:\n{}",
                simd::active_isa(),
                pool::global().threads(),
                qplan.summary().lines().last().unwrap_or("")
            );
            let in_name = g.inputs[0].name.clone();
            let free = qonnx::plan::RunConfig {
                shape_check: qonnx::plan::ShapeCheck::FreeBatch,
                record_intermediates: false,
            };
            let mut simd_b32_speedup = None;
            for batch in [1usize, 8, 32] {
                let xb = Tensor::new(
                    vec![batch, 3, 32, 32],
                    (0..batch * 3072).map(|i| (i % 247) as f32 / 247.0).collect(),
                );
                let secs = if batch == 1 { 1 } else { 2 };
                // scalar kernels, 1 thread: the pre-SIMD baseline
                std::env::set_var("QONNX_FORCE_SCALAR", "1");
                pool::set_thread_intraop_limit(1);
                let st_scalar = bench_for(
                    &format!("scalar      1-thread CNV b{batch}"),
                    Duration::from_secs(secs),
                    || qplan.run_cfg(|n| (n == in_name).then_some(&xb), &free).unwrap(),
                );
                println!("{}", st_scalar.report());
                std::env::remove_var("QONNX_FORCE_SCALAR");
                // SIMD microkernel, still 1 thread: pure-kernel speedup
                let st_simd = bench_for(
                    &format!("simd        1-thread CNV b{batch}"),
                    Duration::from_secs(secs),
                    || qplan.run_cfg(|n| (n == in_name).then_some(&xb), &free).unwrap(),
                );
                println!("{}", st_simd.report());
                // SIMD + pool: full substrate
                pool::set_thread_intraop_limit(usize::MAX);
                let st_pool = bench_for(
                    &format!("simd + pool          CNV b{batch}"),
                    Duration::from_secs(secs),
                    || qplan.run_cfg(|n| (n == in_name).then_some(&xb), &free).unwrap(),
                );
                println!("{}", st_pool.report());
                let simd_x = st_scalar.mean.as_secs_f64() / st_simd.mean.as_secs_f64();
                let pool_x = st_scalar.mean.as_secs_f64() / st_pool.mean.as_secs_f64();
                println!(
                    "  -> b{batch}: simd {simd_x:.2}x over scalar, simd+pool {pool_x:.2}x \
                     ({:.1} img/s)",
                    batch as f64 / st_pool.mean.as_secs_f64()
                );
                json.record(&format!("cnv_b{batch}_simd_vs_scalar_speedup"), simd_x);
                json.record(&format!("cnv_b{batch}_simd_pool_vs_scalar_speedup"), pool_x);
                json.record(
                    &format!("cnv_b{batch}_kernel_tier_img_per_s"),
                    batch as f64 / st_pool.mean.as_secs_f64(),
                );
                if batch == 32 {
                    simd_b32_speedup = Some(simd_x);
                }
            }
            // the acceptance floor: on hosts with a real SIMD path the
            // microkernel must clear 2x over the scalar loop at b32
            if simd::active_isa().is_simd() {
                let x = simd_b32_speedup.unwrap();
                assert!(x >= 2.0, "SIMD microkernel below the 2x floor on CNV b32: {x:.2}x");
            } else {
                println!("(no SIMD path on this host — 2x floor assertion skipped)");
            }
        } else {
            println!("(CNV-w2a2 did not streamline — kernel-tier section skipped)");
        }
    }

    section("sharded batcher over one Arc'd CNV plan (8 clients x 16 req)");
    // shards share ONE compiled plan (PlannedEngine::share) — throughput
    // scales with workers while packed weights stay resident once. The
    // sweep trades request-parallelism (shards) against intra-op
    // parallelism (per-shard pool budget): 'auto' divides the pool evenly.
    {
        let template = PlannedEngine::from_zoo("CNV-w2a2")?;
        for (shards, intraop) in
            [(1usize, None), (2, None), (4, None), (1, Some(4usize)), (4, Some(1))]
        {
            let t = template.share();
            let batcher = Arc::new(Batcher::start_sharded(
                move || Ok(Box::new(t.share()) as Box<dyn InferenceEngine>),
                BatcherConfig {
                    max_wait: Duration::from_micros(200),
                    intraop_threads: intraop,
                    ..Default::default()
                },
                shards,
            )?);
            let t0 = std::time::Instant::now();
            let mut handles = Vec::new();
            for c in 0..8 {
                let b = batcher.clone();
                handles.push(std::thread::spawn(move || {
                    for i in 0..16 {
                        let v = (c * 16 + i) as f32 / 128.0;
                        b.infer(vec![v; 3072]).unwrap();
                    }
                }));
            }
            for h in handles {
                h.join().unwrap();
            }
            let el = t0.elapsed();
            let stats = batcher.stats();
            let rps = stats.requests as f64 / el.as_secs_f64();
            let label =
                intraop.map(|t| t.to_string()).unwrap_or_else(|| "auto".to_string());
            println!(
                "{shards} shard(s) x {label:>4} intra-op: {:>7.1} req/s, mean latency \
                 {:>8.0}us, mean batch {:>5.2}",
                rps,
                stats.mean_latency_us(),
                stats.mean_batch_occupancy()
            );
            json.record(&format!("cnv_serve_shards{shards}_intraop_{label}_req_per_s"), rps);
        }
    }

    section("serving throughput vs batching window (PJRT engine, 8 clients)");
    if tfc_stem.with_extension("hlo.txt").exists() {
        for wait_us in [0u64, 200, 1000, 5000] {
            let stem = tfc_stem.clone();
            let batcher = Arc::new(Batcher::start(
                move || {
                    let rt = PjrtRuntime::cpu()?;
                    Ok(Box::new(PjrtEngine::load(&rt, &stem)?) as Box<dyn InferenceEngine>)
                },
                BatcherConfig { max_wait: Duration::from_micros(wait_us), ..Default::default() },
            )?);
            let t0 = std::time::Instant::now();
            let mut handles = Vec::new();
            for c in 0..8 {
                let b = batcher.clone();
                handles.push(std::thread::spawn(move || {
                    for i in 0..64 {
                        let v = (c * 64 + i) as f32 / 512.0;
                        b.infer(vec![v; 784]).unwrap();
                    }
                }));
            }
            for h in handles {
                h.join().unwrap();
            }
            let el = t0.elapsed();
            let stats = batcher.stats();
            println!(
                "max_wait {:>6}us: {:>7.0} req/s, mean latency {:>7.0}us, mean batch {:>5.2}",
                wait_us,
                stats.requests as f64 / el.as_secs_f64(),
                stats.mean_latency_us(),
                stats.mean_batch_occupancy()
            );
        }
    }

    section("GEMM substrate (blocked vs prepacked)");
    let a = Tensor::new(vec![256, 256], (0..65536).map(|i| (i % 13) as f32 - 6.0).collect());
    let bm = Tensor::new(vec![256, 256], (0..65536).map(|i| (i % 7) as f32 - 3.0).collect());
    let st = bench("gemm 256x256x256 (pack per call)", 3, 20, || a.matmul2d(&bm).unwrap());
    println!("{}", st.report());
    println!(
        "  -> {:.2} GFLOP/s",
        2.0 * 256f64.powi(3) / st.mean.as_secs_f64() / 1e9
    );
    json.record("gemm_256_gflop_per_s", 2.0 * 256f64.powi(3) / st.mean.as_secs_f64() / 1e9);
    let bp = qonnx::tensor::PackedB::pack(256, 256, bm.as_f32()?);
    let st_pp = bench("gemm 256x256x256 (prepacked B)", 3, 20, || {
        let mut out = vec![0f32; 256 * 256];
        qonnx::tensor::gemm_prepacked(256, 256, &bp, a.as_f32().unwrap(), &mut out);
        out
    });
    println!("{}", st_pp.report());
    println!(
        "  -> {:.2} GFLOP/s",
        2.0 * 256f64.powi(3) / st_pp.mean.as_secs_f64() / 1e9
    );
    json.record(
        "gemm_256_prepacked_gflop_per_s",
        2.0 * 256f64.powi(3) / st_pp.mean.as_secs_f64() / 1e9,
    );

    section("overload: bounded admission + shed rate (CNV plan, queue cap 32)");
    // open-loop submitters push far past capacity: the server sheds typed
    // at admission instead of queueing unboundedly, so queue depth (and
    // with it tail latency) stays bounded by cap x service time
    {
        let template = PlannedEngine::from_zoo("CNV-w2a2")?;
        let t = template.share();
        let batcher = Arc::new(Batcher::start_sharded(
            move || Ok(Box::new(t.share()) as Box<dyn InferenceEngine>),
            BatcherConfig {
                max_wait: Duration::from_micros(200),
                queue_capacity: Some(32),
                ..Default::default()
            },
            2,
        )?);
        let total = 4 * 256u64;
        let mut handles = Vec::new();
        for c in 0..4u64 {
            let b = batcher.clone();
            handles.push(std::thread::spawn(move || {
                let mut shed = 0u64;
                let mut responses = Vec::new();
                for i in 0..256u64 {
                    let v = (c * 256 + i) as f32 / 1024.0;
                    match b.submit(vec![v; 3072]) {
                        Ok(r) => responses.push(r),
                        Err(SubmitError::Shed { .. }) => shed += 1,
                        Err(e) => panic!("unexpected submit error: {e}"),
                    }
                }
                for r in responses {
                    r.wait().unwrap();
                }
                shed
            }));
        }
        let shed: u64 = handles.into_iter().map(|h| h.join().unwrap()).sum();
        let m = batcher.metrics();
        let shed_rate = shed as f64 / total as f64;
        let p99 = m.latency().quantile_us(0.99);
        assert!(
            m.queue_depth_peak() <= 32,
            "queue depth exceeded the cap: {}",
            m.queue_depth_peak()
        );
        println!(
            "submitted {total}, completed {}, shed {shed} ({:.1}% shed), p50 {}us, \
             p99 {p99}us, peak depth {}",
            m.completed(),
            100.0 * shed_rate,
            m.latency().quantile_us(0.5),
            m.queue_depth_peak()
        );
        json.record("overload_shed_rate", shed_rate);
        json.record("overload_p99_us", p99 as f64);
        json.record("overload_completed", m.completed() as f64);
    }

    section("static plan verifier wall-time (PR-8, per zoo model)");
    // the verifier re-derives slot liveness, dtype flow, value ranges and
    // fusion legality per compile — it must stay a compile-time footnote,
    // not a serving-path tax. Measured per zoo model (float + streamlined
    // where the model lowers), recorded to BENCH_PR8.json, and guarded:
    // the largest model (CNV) must verify well under a second.
    let mut json8 = BenchJson::default();
    {
        let mut cnv_verify_ms = 0.0f64;
        for name in ["TFC-w2a2", "CNV-w2a2"] {
            let mut g = qonnx::zoo::build(name, 1, 32)?;
            transforms::cleanup(&mut g)?;
            let plan = ExecutionPlan::compile(&g)?;
            let st = bench(&format!("verify {name} (float plan)"), 2, 10, || {
                let report = qonnx::verify::verify_plan(&plan, &g);
                assert!(!report.has_errors(), "{}", report.render());
                report
            });
            println!("{}", st.report());
            let key = name.split('-').next().unwrap_or(name).to_lowercase();
            json8.record(&format!("{key}_float_verify_ms"), st.mean.as_secs_f64() * 1e3);
            if key == "cnv" {
                cnv_verify_ms = cnv_verify_ms.max(st.mean.as_secs_f64() * 1e3);
            }

            let sl = qonnx::streamline::try_streamline(&g)?;
            if sl.report.ok {
                let splan = ExecutionPlan::compile(&sl.graph)?;
                let st = bench(&format!("verify {name} (streamlined plan)"), 2, 10, || {
                    let report = qonnx::verify::verify_plan(&splan, &sl.graph);
                    assert!(!report.has_errors(), "{}", report.render());
                    report
                });
                println!("{}", st.report());
                json8.record(
                    &format!("{key}_streamlined_verify_ms"),
                    st.mean.as_secs_f64() * 1e3,
                );
                if key == "cnv" {
                    cnv_verify_ms = cnv_verify_ms.max(st.mean.as_secs_f64() * 1e3);
                }
            }
        }
        // ceiling: verification of the biggest zoo model stays far below
        // its own compile, so deny-by-default debug compiles and the
        // verify-zoo CI gate stay cheap
        assert!(
            cnv_verify_ms < 500.0,
            "CNV plan verification regressed to {cnv_verify_ms:.1} ms (ceiling 500 ms)"
        );
        json8.record("cnv_verify_ceiling_ms", 500.0);
    }
    json8.write(concat!(env!("CARGO_MANIFEST_DIR"), "/../BENCH_PR8.json"));

    section("tracing overhead: untraced vs observed vs recorded run (PR-9, CNV b8)");
    // The PR-9 guarantee: profiling must be pay-for-what-you-use. The
    // untraced baseline IS the disabled path — run_cfg_scratch and
    // run_profiled share one run_inner body, and without an observer the
    // per-step probe is a single branch on None — so "disabled ≈ 0
    // overhead" holds by construction and the baseline here measures it.
    // With an observer attached (per-step Instant + arena counters) and
    // additionally a TraceRecorder (ring-buffer event per step), the
    // end-to-end cost on the streamlined CNV plan at b8 must stay <= 5%.
    let mut json9 = BenchJson::default();
    {
        use qonnx::plan::{RunConfig, ScratchArena, ShapeCheck, StepObserver};
        let mut g = qonnx::zoo::build("CNV-w2a2", 1, 32)?;
        transforms::cleanup(&mut g)?;
        let sl = qonnx::streamline::try_streamline(&g)?;
        let graph = if sl.report.ok { sl.graph } else { g };
        let plan = ExecutionPlan::compile(&graph)?;
        let in_name = graph.inputs[0].name.clone();
        let xb = Tensor::new(
            vec![8, 3, 32, 32],
            (0..8 * 3072).map(|i| (i % 241) as f32 / 241.0).collect(),
        );
        let free = RunConfig { shape_check: ShapeCheck::FreeBatch, record_intermediates: false };
        let mut scratch = ScratchArena::new();
        // warm the arena + one-time weight packing out of the measurement
        plan.run_cfg_scratch(|n| (n == in_name).then_some(&xb), &free, &mut scratch)?;
        let st_off = bench_for("untraced (disabled path)  CNV b8", Duration::from_secs(3), || {
            plan.run_cfg_scratch(|n| (n == in_name).then_some(&xb), &free, &mut scratch).unwrap()
        });
        println!("{}", st_off.report());
        let st_obs = bench_for("observer, no recorder     CNV b8", Duration::from_secs(3), || {
            let mut obs = StepObserver::new();
            plan.run_profiled(|n| (n == in_name).then_some(&xb), &free, &mut scratch, &mut obs)
                .unwrap()
        });
        println!("{}", st_obs.report());
        let rec = Arc::new(qonnx::trace::TraceRecorder::new(1 << 16));
        let st_tr = bench_for("observer + TraceRecorder  CNV b8", Duration::from_secs(3), || {
            let mut obs = StepObserver::with_trace(rec.clone());
            plan.run_profiled(|n| (n == in_name).then_some(&xb), &free, &mut scratch, &mut obs)
                .unwrap()
        });
        println!("{}", st_tr.report());
        let over_obs = st_obs.mean.as_secs_f64() / st_off.mean.as_secs_f64() - 1.0;
        let over_tr = st_tr.mean.as_secs_f64() / st_off.mean.as_secs_f64() - 1.0;
        println!(
            "  -> observer overhead {:+.2}%, observer+recorder overhead {:+.2}%",
            over_obs * 100.0,
            over_tr * 100.0
        );
        json9.record("cnv_b8_untraced_ms", st_off.mean.as_secs_f64() * 1e3);
        json9.record("cnv_b8_observer_overhead_pct", over_obs * 100.0);
        json9.record("cnv_b8_traced_overhead_pct", over_tr * 100.0);
        // the acceptance ceiling: tracing enabled end-to-end stays <= 5%
        assert!(
            over_tr <= 0.05,
            "tracing overhead above the 5% ceiling on CNV b8: {:.2}%",
            over_tr * 100.0
        );
        json9.record("tracing_overhead_ceiling_pct", 5.0);
    }
    json9.write(concat!(env!("CARGO_MANIFEST_DIR"), "/../BENCH_PR9.json"));

    section("compiled-plan artifacts: cold start vs full compile (PR-10, TFC/CNV, b1/b8)");
    // The PR-10 tentpole measurement: everything the compile step
    // produces is persisted once to a sectioned `.qpln` artifact, and a
    // cold start deserializes the frozen schedule + borrows the packed
    // weight panels zero-copy from the file buffer — no streamlining, no
    // re-packing, no verification. The floor: loading must be >= 5x
    // faster than the full compile path, with byte-identical outputs.
    let mut json10 = BenchJson::default();
    {
        for model in ["TFC-w2a2", "CNV-w2a2"] {
            let mut g = qonnx::zoo::build(model, 1, 32)?;
            transforms::cleanup(&mut g)?;
            let key = if model.starts_with("TFC") { "tfc" } else { "cnv" };
            let path = std::env::temp_dir()
                .join(format!("qonnx_bench10_{}_{key}.qpln", std::process::id()));
            let mut compiled = PlannedEngine::compile_to_artifact(&g, &path)?;
            let bytes = std::fs::metadata(&path)?.len();

            // correctness before speed: cold-start engine byte-identical
            // to the in-process-compiled engine at b1 and b8
            let mut cold = PlannedEngine::from_artifact(&path)?;
            let in_dim = compiled.input_dim();
            for batch in [1usize, 8] {
                let x = Tensor::new(
                    vec![batch, in_dim],
                    (0..batch * in_dim).map(|i| (i % 239) as f32 / 239.0).collect(),
                );
                let yc = compiled.infer_batch(&x)?;
                let ya = cold.infer_batch(&x)?;
                assert_eq!(yc, ya, "{model} b{batch}: artifact outputs diverged");
            }
            // and the zero-copy contract holds: no panel was re-packed
            let zc = qonnx::plan::artifact::read_artifact(&path)
                .map_err(anyhow::Error::new)?
                .zero_copy_report();
            assert_eq!(zc.owned_panels, 0, "{model}: re-packed panels: {zc:?}");

            let iters = if model.starts_with("TFC") { 30 } else { 10 };
            let st_c = bench(
                &format!("full compile (streamline+pack) {model}"),
                2,
                iters,
                || PlannedEngine::new_auto(&g).unwrap(),
            );
            println!("{}", st_c.report());
            let st_l = bench(
                &format!("artifact cold start (zero-copy) {model}"),
                2,
                iters,
                || PlannedEngine::from_artifact(&path).unwrap(),
            );
            println!("{}", st_l.report());
            let speedup = st_c.mean.as_secs_f64() / st_l.mean.as_secs_f64();
            println!(
                "  -> {model}: cold start {speedup:.1}x faster than full compile \
                 ({:.2} ms vs {:.2} ms; artifact {bytes} B, {} panels / {} B mapped)",
                st_l.mean.as_secs_f64() * 1e3,
                st_c.mean.as_secs_f64() * 1e3,
                zc.mapped_panels,
                zc.mapped_bytes,
            );
            json10.record(&format!("{key}_full_compile_ms"), st_c.mean.as_secs_f64() * 1e3);
            json10.record(&format!("{key}_artifact_load_ms"), st_l.mean.as_secs_f64() * 1e3);
            json10.record(&format!("{key}_load_vs_compile_speedup"), speedup);
            json10.record(&format!("{key}_artifact_bytes"), bytes as f64);
            json10.record(&format!("{key}_mapped_panel_bytes"), zc.mapped_bytes as f64);
            // the acceptance floor: artifact load >= 5x faster than the
            // full compile path it replaces
            assert!(
                speedup >= 5.0,
                "{model}: artifact load below the 5x floor vs full compile: {speedup:.2}x"
            );
            std::fs::remove_file(&path).ok();
        }
        json10.record("load_vs_compile_floor", 5.0);
    }
    json10.write(concat!(env!("CARGO_MANIFEST_DIR"), "/../BENCH_PR10.json"));

    json.write(concat!(env!("CARGO_MANIFEST_DIR"), "/../BENCH_PR7.json"));
    Ok(())
}

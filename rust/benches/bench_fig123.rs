//! Regenerates Figures 1–3: the CNV-w2a2 conv→FC transition before
//! cleaning, after cleaning, and after channels-last conversion — with
//! node-count evidence and pass timings.

use qonnx::bench_support::{bench, section};
use qonnx::transforms;
use qonnx::zoo::cnv;

fn main() -> anyhow::Result<()> {
    section("Fig. 1 — raw Brevitas-style export");
    let raw = cnv(2, 2, 42, true)?;
    let h = raw.op_histogram();
    println!("nodes: {} | exporter clutter: Shape {} Gather {} Unsqueeze {} Concat {} Identity {}",
        raw.nodes.len(),
        h.get("Shape").unwrap_or(&0),
        h.get("Gather").unwrap_or(&0),
        h.get("Unsqueeze").unwrap_or(&0),
        h.get("Concat").unwrap_or(&0),
        h.get("Identity").unwrap_or(&0),
    );
    println!("intermediate shapes annotated: {}", raw.value_info.values().filter(|v| v.shape.is_some()).count());

    section("Fig. 2 — after cleanup (shape inference + folding + collapse)");
    let mut cleaned = raw.clone();
    transforms::cleanup(&mut cleaned)?;
    let h2 = cleaned.op_histogram();
    println!(
        "nodes: {} | Reshape {} (chain collapsed), exporter ops remaining: {}",
        cleaned.nodes.len(),
        h2.get("Reshape").unwrap_or(&0),
        h2.get("Shape").unwrap_or(&0) + h2.get("Gather").unwrap_or(&0) + h2.get("Concat").unwrap_or(&0),
    );
    println!(
        "intermediate shapes annotated: {} (e.g. conv5_act = {:?})",
        cleaned.value_info.values().filter(|v| v.shape.is_some()).count(),
        cleaned.tensor_shape("conv5_act"),
    );

    section("Fig. 3 — after channels-last conversion");
    let mut cl = cleaned.clone();
    transforms::to_channels_last(&mut cl)?;
    println!(
        "input: {:?} -> {:?}; conv5_act: {:?} (channels last)",
        cleaned.inputs[0].shape, cl.inputs[0].shape,
        cl.tensor_shape("conv5_act"),
    );
    println!(
        "layout-wrapped ops: {}",
        cl.nodes.iter().filter(|n| n.attr_str_or("data_layout", "NCHW") == "NHWC").count()
    );

    section("pass timings (CNV-w2a2, 36-node graph)");
    let s1 = bench("cleanup (full pipeline)", 1, 10, || {
        let mut g = raw.clone();
        transforms::cleanup(&mut g).unwrap();
        g.nodes.len()
    });
    println!("{}", s1.report());
    let s2 = bench("to_channels_last", 1, 10, || {
        let mut g = cleaned.clone();
        transforms::to_channels_last(&mut g).unwrap();
        g.nodes.len()
    });
    println!("{}", s2.report());
    Ok(())
}

//! Regenerates Figure 4: the QKeras-style model and its QONNX conversion
//! side by side, plus conversion timing.

use qonnx::bench_support::{bench_for, section};
use qonnx::transforms;
use qonnx::zoo::{keras_to_qonnx, KerasLayer, KerasModel};
use std::time::Duration;

fn main() -> anyhow::Result<()> {
    let model = KerasModel::fig4_example();
    section("Fig. 4 (left) — QKeras-style model description");
    println!("input_dim = {}", model.input_dim);
    for l in &model.layers {
        match l {
            KerasLayer::QDense { units, kernel_quantizer, bias_quantizer } => println!(
                "  QDense(units={units}, kernel_quantizer=quantized_bits({},{}), bias_quantizer={})",
                kernel_quantizer.bits,
                kernel_quantizer.integer,
                bias_quantizer
                    .map(|q| format!("quantized_bits({},{})", q.bits, q.integer))
                    .unwrap_or_else(|| "None".into()),
            ),
            KerasLayer::QActivationRelu { bits } => println!("  QActivation(quantized_relu({bits}))"),
            KerasLayer::Relu => println!("  Activation(relu)"),
            KerasLayer::Softmax => println!("  Activation(softmax)"),
        }
    }

    section("Fig. 4 (right) — converted QONNX graph");
    let mut g = keras_to_qonnx(&model, 1)?;
    transforms::cleanup(&mut g)?;
    transforms::infer_datatypes(&mut g)?;
    println!("{}", g.summary());

    section("conversion timing");
    let s = bench_for("keras-like -> QONNX conversion + cleanup", Duration::from_millis(300), || {
        let mut g = keras_to_qonnx(&model, 1).unwrap();
        transforms::cleanup(&mut g).unwrap();
        g.nodes.len()
    });
    println!("{}", s.report());
    Ok(())
}

//! Regenerates Figure 5: accuracy as a function of BOPs across the zoo,
//! grouped by dataset tier, marker size = total weight bits.
//!
//! The accuracy axis is measured by QAT on the synthetic substitutes
//! (DESIGN.md §3) with enough noise that precision differences show; the
//! BOPs/weight-bit axes come from the actual zoo graphs. The shape to
//! reproduce: within a tier, more BOPs (higher precision) → higher
//! accuracy; tiers order MNIST > CIFAR in absolute accuracy on comparable
//! task difficulty. Set QONNX_BENCH_FAST=1 for a quick pass.

use qonnx::bench_support::section;
use qonnx::{metrics, training, transforms, zoo};

fn main() -> anyhow::Result<()> {
    let fast = std::env::var("QONNX_BENCH_FAST").is_ok();
    let epochs = if fast { 6 } else { 30 };
    section("Fig. 5 series — accuracy vs BOPs (marker = total weight bits)");
    println!(
        "{:<18} {:<9} {:>16} {:>14} {:>10} {:>10}",
        "model", "dataset", "BOPs(Eq.5)", "weight_bits", "acc paper", "acc ours"
    );
    let mut series: Vec<(String, f64, f64)> = Vec::new();
    for name in zoo::ZOO_NAMES {
        let res = if name.starts_with("MobileNet") { if fast { 64 } else { 224 } } else { 32 };
        let mut g = zoo::build(name, 1, res)?;
        transforms::cleanup(&mut g)?;
        let r = metrics::analyze(&g)?;
        let acc = accuracy_for(name, epochs, fast)?;
        println!(
            "{:<18} {:<9} {:>16.4e} {:>14} {:>10.2} {:>10}",
            name,
            zoo::dataset_of(name),
            r.bops(),
            r.total_weight_bits(),
            zoo::paper_accuracy(name).unwrap_or(0.0),
            acc.map(|a| format!("{a:.2}")).unwrap_or_else(|| "cited".into()),
        );
        if let Some(a) = acc {
            series.push((name.to_string(), r.bops(), f64::from(a)));
        }
    }

    section("shape check (the paper's monotone trend within each tier)");
    for tier in ["TFC", "CNV"] {
        let pts: Vec<&(String, f64, f64)> = series.iter().filter(|(n, _, _)| n.starts_with(tier)).collect();
        let mut ok = true;
        for w in pts.windows(2) {
            // zoo order is ascending precision: BOPs and accuracy should rise
            if w[1].1 < w[0].1 || w[1].2 + 3.0 < w[0].2 {
                ok = false;
            }
        }
        println!(
            "{tier}: BOPs ascending with precision: {} | accuracy non-degrading: {}",
            pts.windows(2).all(|w| w[1].1 > w[0].1),
            ok
        );
    }
    Ok(())
}

fn accuracy_for(name: &str, epochs: usize, fast: bool) -> anyhow::Result<Option<f32>> {
    let wa = name.rsplit('-').next().unwrap();
    let a_pos = wa.find('a').unwrap();
    let (w, a): (u32, u32) = (wa[1..a_pos].parse().unwrap(), wa[a_pos + 1..].parse().unwrap());
    Ok(match zoo::dataset_of(name) {
        "MNIST" => {
            let train = zoo::synth_digits_noisy(if fast { 400 } else { 2000 }, 100, 0.3);
            let test = zoo::synth_digits_noisy(500, 101, 0.3);
            let mut cfg = training::QatConfig::tfc(w, a);
            cfg.epochs = epochs;
            let mut m = training::train_mlp(&train, &cfg)?;
            Some(m.accuracy(&test))
        }
        "CIFAR-10" => {
            let train = zoo::synth_cifar(if fast { 300 } else { 1500 }, 200);
            let test = zoo::synth_cifar(500, 201);
            let mut cfg = training::QatConfig::tfc(w, a);
            cfg.hidden = vec![128, 64];
            cfg.epochs = epochs;
            let mut m = training::train_mlp(&train, &cfg)?;
            Some(m.accuracy(&test))
        }
        _ => None,
    })
}

//! Regenerates paper Table I: the format-capability matrix, with each
//! cell backed by a live representability probe, plus probe timings.

use qonnx::bench_support::{bench_for, section};
use qonnx::formats;
use std::time::Duration;

fn main() {
    section("Table I — ONNX-based QNN IR comparison (probe-backed)");
    print!("{}", formats::render_table());

    section("evidence per cell");
    for row in formats::probe_all() {
        println!("{}", row.format);
        for (c, yes, ev) in &row.verdicts {
            println!("  {:<28} {:<4} {}", c.title(), if *yes { "yes" } else { "no" }, ev);
        }
    }

    section("probe timing");
    let s = bench_for("full Table I probe suite", Duration::from_millis(500), formats::probe_all);
    println!("{}", s.report());
}

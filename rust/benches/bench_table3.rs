//! Regenerates paper Table III: the model-zoo metric columns for all seven
//! entries plus measured QAT accuracy on the synthetic substitutes
//! (DESIGN.md §3). Set QONNX_BENCH_FAST=1 for a quick pass.

use qonnx::bench_support::section;
use qonnx::{metrics, training, transforms, zoo};

fn main() -> anyhow::Result<()> {
    let fast = std::env::var("QONNX_BENCH_FAST").is_ok();
    section("Table III — the QONNX model zoo (paper vs measured)");
    println!(
        "{:<18} {:<9} {:>6} {:>6} {:>14} {:>16} {:>16} {:>11} {:>14} {:>10} {:>10}",
        "Model", "Dataset", "w", "a", "MACs", "BOPs(Eq.5)", "MAC-BOPs", "Weights", "WeightBits", "acc paper", "acc ours"
    );
    // paper Table III reference values for the metric columns
    let paper: &[(&str, u64, f64, u64, u64)] = &[
        ("MobileNet-w4a4", 557_381_408, 74_070_028_288.0, 4_208_224, 16_839_808),
        ("CNV-w1a1", 57_906_176, 107_672_576.0, 1_542_848, 1_542_848),
        ("CNV-w1a2", 57_906_176, 165_578_752.0, 1_542_848, 1_542_848),
        ("CNV-w2a2", 57_906_176, 331_157_504.0, 1_542_848, 3_085_696),
        ("TFC-w1a1", 59_008, 59_008.0, 59_008, 59_008),
        ("TFC-w1a2", 59_008, 118_016.0, 59_008, 59_008),
        ("TFC-w2a2", 59_008, 236_032.0, 59_008, 118_016),
    ];
    for (name, p_macs, p_bops, p_weights, p_wbits) in paper {
        let res = if name.starts_with("MobileNet") { if fast { 64 } else { 224 } } else { 32 };
        let mut g = zoo::build(name, 1, res)?;
        transforms::cleanup(&mut g)?;
        let r = metrics::analyze(&g)?;
        let acc = measured_accuracy(name, fast)?;
        println!(
            "{:<18} {:<9} {:>6} {:>6} {:>14} {:>16.4e} {:>16.4e} {:>11} {:>14} {:>10.2} {:>10}",
            name,
            zoo::dataset_of(name),
            r.layers.iter().map(|l| l.weight_bits).min().unwrap_or(32),
            r.layers.iter().map(|l| l.act_bits).filter(|&b| b < 32).min().unwrap_or(32),
            r.macs(),
            r.bops(),
            r.mac_bops(),
            r.weights(),
            r.total_weight_bits(),
            zoo::paper_accuracy(name).unwrap_or(0.0),
            acc,
        );
        println!(
            "{:<18} {:<9} {:>6} {:>6} {:>14} {:>16.4e} {:>16} {:>11} {:>14}   (paper row)",
            "", "", "", "", p_macs, p_bops, "-", p_weights, p_wbits
        );
    }
    println!("\nNotes:");
    println!("* weights/weight-bits match Table III exactly for TFC and CNV;");
    println!("  MobileNet differs by one stem kernel (864 weights, 0.02%).");
    println!("* MACs for CNV: ours counts the 8-bit first conv the zoo script skips.");
    println!("* BOPs: ours applies Eq.5 per output position; the zoo script's");
    println!("  convention differs — orderings across bit widths are preserved.");
    println!("* accuracy: measured by QAT on the synthetic substitutes (DESIGN.md §3);");
    println!("  MobileNet/ImageNet accuracy is cited, not re-measured.");
    Ok(())
}

fn measured_accuracy(name: &str, fast: bool) -> anyhow::Result<String> {
    let wa = name.rsplit('-').next().unwrap();
    let a_pos = wa.find('a').unwrap();
    let (w, a): (u32, u32) = (wa[1..a_pos].parse().unwrap(), wa[a_pos + 1..].parse().unwrap());
    let epochs = if fast { 6 } else { 25 };
    Ok(match zoo::dataset_of(name) {
        "MNIST" => {
            let train = zoo::synth_digits_noisy(if fast { 400 } else { 2000 }, 100, 0.25);
            let test = zoo::synth_digits_noisy(500, 101, 0.25);
            let mut cfg = training::QatConfig::tfc(w, a);
            cfg.epochs = epochs;
            let mut m = training::train_mlp(&train, &cfg)?;
            format!("{:.2}", m.accuracy(&test))
        }
        "CIFAR-10" => {
            let train = zoo::synth_cifar(if fast { 300 } else { 1500 }, 200);
            let test = zoo::synth_cifar(500, 201);
            let mut cfg = training::QatConfig::tfc(w, a);
            cfg.hidden = vec![128, 64];
            cfg.epochs = epochs;
            let mut m = training::train_mlp(&train, &cfg)?;
            format!("{:.2}", m.accuracy(&test))
        }
        _ => "cited".to_string(),
    })
}

//! Per-pass transform timings (ablation over the cleanup pipeline and the
//! format lowerings) on CNV-w2a2 and the MobileNet graph.

use qonnx::bench_support::{bench, section};
use qonnx::transforms;
use qonnx::zoo::{cnv, mobilenet, tfc, TfcParams};

fn main() -> anyhow::Result<()> {
    let raw = cnv(2, 2, 42, true)?;
    let mut cleaned = raw.clone();
    transforms::cleanup(&mut cleaned)?;

    section("cleanup pipeline ablation (CNV-w2a2 raw export)");
    println!("{}", bench("infer_shapes", 1, 10, || {
        let mut g = raw.clone();
        g.sort_topologically().unwrap();
        transforms::infer_shapes(&mut g).unwrap()
    }).report());
    println!("{}", bench("fold_constants (after shapes)", 1, 10, || {
        let mut g = raw.clone();
        g.sort_topologically().unwrap();
        transforms::infer_shapes(&mut g).unwrap();
        transforms::fold_constants(&mut g).unwrap()
    }).report());
    println!("{}", bench("full cleanup", 1, 10, || {
        let mut g = raw.clone();
        transforms::cleanup(&mut g).unwrap()
    }).report());
    println!("{}", bench("infer_datatypes", 1, 10, || {
        let mut g = cleaned.clone();
        transforms::infer_datatypes(&mut g).unwrap()
    }).report());

    section("format lowerings (TFC-w2a2)");
    let tfc_g = tfc(&TfcParams::random(2, 2, 5))?;
    println!("{}", bench("lower_to_qcdq", 1, 50, || {
        let mut g = tfc_g.clone();
        transforms::lower_to_qcdq(&mut g).unwrap()
    }).report());
    println!("{}", bench("convert_to_finn (MultiThreshold)", 1, 50, || {
        let mut g = tfc_g.clone();
        transforms::convert_to_finn(&mut g).unwrap()
    }).report());
    println!("{}", bench("hls4ml_ingest", 1, 50, || {
        let mut g = tfc_g.clone();
        transforms::hls4ml_ingest(&mut g).unwrap()
    }).report());
    println!("{}", bench("roundtrip qcdq -> qonnx", 1, 50, || {
        let mut g = tfc_g.clone();
        transforms::lower_to_qcdq(&mut g).unwrap();
        transforms::raise_qcdq_to_qonnx(&mut g).unwrap()
    }).report());

    section("large-graph scaling (MobileNet, 86 nodes, 4.2M params)");
    let mn = mobilenet(4, 4, 64, 1)?;
    println!("{}", bench("cleanup (MobileNet @64px)", 1, 3, || {
        let mut g = mn.clone();
        transforms::cleanup(&mut g).unwrap()
    }).report());
    println!("{}", bench("channels-last (MobileNet @64px)", 1, 3, || {
        let mut g = mn.clone();
        transforms::cleanup(&mut g).unwrap();
        transforms::to_channels_last(&mut g).unwrap()
    }).report());
    Ok(())
}

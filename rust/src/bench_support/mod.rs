//! Minimal benchmark harness (criterion is not in the vendored crate set).
//!
//! Warmup + timed iterations with mean/min/p50/p95 reporting, and a small
//! table printer shared by the `benches/bench_*` binaries that regenerate
//! the paper's tables and figures.

use std::time::{Duration, Instant};

/// Timing statistics over the measured iterations.
#[derive(Debug, Clone)]
pub struct Stats {
    pub name: String,
    pub iters: usize,
    pub mean: Duration,
    pub min: Duration,
    pub p50: Duration,
    pub p95: Duration,
}

impl Stats {
    pub fn mean_us(&self) -> f64 {
        self.mean.as_secs_f64() * 1e6
    }

    /// One-line report.
    pub fn report(&self) -> String {
        format!(
            "{:<44} {:>10.1} us/iter  (min {:>8.1}, p50 {:>8.1}, p95 {:>8.1}, n={})",
            self.name,
            self.mean.as_secs_f64() * 1e6,
            self.min.as_secs_f64() * 1e6,
            self.p50.as_secs_f64() * 1e6,
            self.p95.as_secs_f64() * 1e6,
            self.iters
        )
    }
}

/// Benchmark a closure: `warmup` untimed runs, then `iters` timed runs.
/// The closure's return value is black-boxed to keep the optimizer honest.
pub fn bench<T>(name: &str, warmup: usize, iters: usize, mut f: impl FnMut() -> T) -> Stats {
    for _ in 0..warmup {
        black_box(f());
    }
    let mut samples = Vec::with_capacity(iters);
    for _ in 0..iters {
        let t0 = Instant::now();
        black_box(f());
        samples.push(t0.elapsed());
    }
    samples.sort_unstable();
    let total: Duration = samples.iter().sum();
    Stats {
        name: name.to_string(),
        iters,
        mean: total / iters as u32,
        min: samples[0],
        p50: samples[iters / 2],
        p95: samples[(iters * 95 / 100).min(iters - 1)],
    }
}

/// Auto-calibrated variant: picks an iteration count that takes roughly
/// `budget` and runs it.
pub fn bench_for<T>(name: &str, budget: Duration, mut f: impl FnMut() -> T) -> Stats {
    let t0 = Instant::now();
    black_box(f());
    let one = t0.elapsed().max(Duration::from_nanos(100));
    let iters = (budget.as_secs_f64() / one.as_secs_f64()).clamp(3.0, 10_000.0) as usize;
    bench(name, (iters / 10).max(1), iters, f)
}

/// Optimizer barrier (std::hint::black_box wrapper for older call sites).
pub fn black_box<T>(x: T) -> T {
    std::hint::black_box(x)
}

/// Print a section header in bench output.
pub fn section(title: &str) {
    println!("\n=== {title} ===");
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stats_sane() {
        let s = bench("noop", 2, 50, || 1 + 1);
        assert_eq!(s.iters, 50);
        assert!(s.min <= s.mean);
        assert!(s.p50 <= s.p95);
    }

    #[test]
    fn bench_for_calibrates() {
        let s = bench_for("sleepless", Duration::from_millis(5), || {
            std::hint::black_box((0..100).sum::<u64>())
        });
        assert!(s.iters >= 3);
    }
}

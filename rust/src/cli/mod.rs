//! `qonnx` command-line interface (hand-rolled arg parsing; no clap in the
//! vendored crate set).

use crate::ir::json::{load_model, save_model};
use crate::tensor::Tensor;
use crate::{coordinator, exec, formats, metrics, runtime, training, transforms, zoo};
use anyhow::{bail, Context, Result};
use std::collections::BTreeMap;
use std::path::PathBuf;

pub const USAGE: &str = "\
qonnx — arbitrary-precision quantized NN toolkit (QONNX reproduction)

USAGE: qonnx <COMMAND> [ARGS]

Model transformation:
  clean <in> <out>           cleanup pipeline (shape inference, folding, ...)
  channels-last <in> <out>   convert NCHW graph to NHWC (Fig. 3)
  to-qcdq <in> <out>         lower QONNX -> QuantizeLinear+Clip+DequantizeLinear
  to-qop <in> <out>          lower QONNX -> quantized operators with clipping
  to-finn <in> <out>         FINN ingestion (weights folded, Quant -> MultiThreshold)
  to-hls4ml <in> <out>       hls4ml ingestion (integer constants, scales propagated)
  raise-qcdq <in> <out>      fuse QCDQ triples back into Quant nodes

Inspection & execution:
  summary <model>            print the node listing with shapes/datatypes
  verify <model>             statically verify the compiled plan: slot
  verify --zoo <name>        lifetimes/aliasing, dtype flow vs the slot
                             table, 2^24 accumulator bounds + threshold
                             monotonicity re-proved from the graph, and
                             fusion/schedule legality. Verifies the float
                             plan, plus the streamlined integer plan when
                             the model lowers cleanly. Exits nonzero on
                             any error-severity diagnostic.
  plan <model> [--verify]    compile and print the execution plan schedule
                             (incl. the per-slot dtype + bytes table and a
                             'kernel substrate' line: detected ISA —
                             avx2/neon/scalar, QONNX_FORCE_SCALAR=1 to
                             override — intra-op pool width, and how many
                             quantized kernels carry SIMD weight tiles)
  streamline <model> [--out <file>]
                             lower the model to integer-domain form (Quant
                             activations -> integer MultiThreshold, integer
                             weights, scales pushed to the graph edge);
                             reports which nodes lowered and why any did
                             not, and the quantized-kernel plan it unlocks
  stats <model>              MACs / BOPs / weight bits report
  datatypes <in> <out>       run arbitrary-precision datatype inference
  exec <model> [--seed N] [--engine plan|interp]
                             execute on random input (compiled plan by
                             default; 'interp' = name-keyed interpreter)
  profile <model|zoo-name> [--batch N] [--runs N] [--trace <out.json>]
                             run the compiled plan under the per-step
                             profiler (the streamlined integer tier when
                             the model lowers cleanly): N timed runs
                             (default 10) after one warmup, then a
                             per-step table — mean wall time, share of
                             the plan, achieved GMAC/s and effective
                             GBOP/s joined against the Eq.-5 static
                             complexity model (stats), arena
                             alloc/reuse counts — plus whole-plan
                             totals and the kernel substrate line.
                             --trace also writes a Chrome-trace JSON
                             (chrome://tracing / Perfetto) with one
                             'exec' event per step per run.
  zoo <name> <out>           materialize a model-zoo entry (e.g. CNV-w2a2)

Compiled-plan artifacts (.qpln):
  compile <model> [--out <file.qpln>]
  compile --zoo <name|all> [--out <file>] [--out-dir <dir>]
                             compile to a sectioned binary artifact: the
                             frozen schedule, kernel descriptors, fused
                             epilogues, threshold rows, and the prepacked
                             weight panels (incl. SIMD tiles) stored
                             64-byte aligned for zero-copy loading. Tier
                             selection matches serving: streamlined
                             integer plan when the model lowers cleanly,
                             float plan otherwise. '--zoo all' writes
                             <name>.qpln per zoo entry into --out-dir
                             (default '.'). Every section carries a CRC32;
                             the header records the packing ISA.
  verify --artifact <path>   run the static plan verifier on the plan
                             deserialized from a .qpln artifact, re-proved
                             against the model graph embedded in it —
                             catches artifacts whose schedule was
                             corrupted in ways the checksums cannot see
                             (e.g. a valid re-signed file with swapped
                             steps)
  serve --artifact <path.qpln>
                             instant cold start: serve straight from the
                             artifact — no graph parse, no streamlining,
                             no weight re-packing; weight panels are
                             borrowed zero-copy from one shared mapping
                             across all shards (a non-.qpln --artifact
                             value still names a PJRT artifact stem)

Paper experiments:
  table1                     regenerate Table I (format capability matrix)
  table3 [--fast]            regenerate Table III (zoo metrics + accuracy)
  fig5 [--fast]              regenerate Fig. 5 series (accuracy vs BOPs)

Training & serving:
  train --w N --a N [--epochs N] [--out <file>]   QAT on synth-digits
  infer <artifact-stem>      load + self-check a PJRT artifact
  serve [--artifact <stem>] [--zoo <name>] [--requests N] [--clients N]
        [--shards N] [--intraop-threads N] [--queue-cap N]
        [--deadline-ms N] [--metrics] [--trace <out.json>]
                             batching server demo; serves a zoo model via
                             the compiled ExecutionPlan when no PJRT
                             artifact is present (or --zoo is given) —
                             streamlined to the integer kernel tier when
                             the model lowers cleanly, float plan
                             otherwise. --shards runs N batcher workers
                             sharing ONE compiled plan (PJRT shards each
                             load their own artifact copy).
                             --intraop-threads caps each shard's kernel
                             fan-out on the shared worker pool (default:
                             pool threads / shards, so shards x intra-op
                             stays <= cores); startup reports the ISA and
                             thread configuration.
                             Robust serving: --queue-cap bounds the request
                             queue — when full, submission fails with a
                             typed Shed{queue_depth} error instead of
                             queueing without limit (the demo clients back
                             off and retry). --deadline-ms attaches a
                             deadline to every request; an expired request
                             gets a typed DeadlineExceeded response instead
                             of spending a batch slot. Shards that panic
                             are supervised: restarted with capped backoff,
                             and the run reports health (live/dead shards,
                             restart count). --metrics prints the serving
                             metrics exposition (latency p50/p95/p99, queue
                             depth + peak, shed/deadline/restart counters,
                             batch-size histogram + close reasons) after
                             the run, every series labeled with the
                             served model's kebab-case name. --trace
                             records request-lifecycle spans (admission/
                             shed, queue wait, batch-form with close
                             reason, execute, scatter, typed failures,
                             restarts) and writes Chrome-trace JSON at
                             shutdown, rotating an existing file to
                             <path>.1. Fault injection (deterministic,
                             for soak testing): set QONNX_FAULT_SEED=N
                             [QONNX_FAULT_RATE=0.1]
                             [QONNX_FAULT_KIND=error|panic|stall:<ms>] to
                             make engine calls fail on a seeded schedule —
                             the server sheds, restarts, and typed-fails
                             instead of hanging
";

fn parse_flag(args: &[String], key: &str) -> Option<String> {
    args.iter().position(|a| a == key).and_then(|i| args.get(i + 1).cloned())
}

fn has_flag(args: &[String], key: &str) -> bool {
    args.iter().any(|a| a == key)
}

/// Entry point for the binary.
pub fn run(args: Vec<String>) -> Result<()> {
    let Some(cmd) = args.first().cloned() else {
        println!("{USAGE}");
        return Ok(());
    };
    let rest = &args[1..];
    match cmd.as_str() {
        "clean" | "channels-last" | "to-qcdq" | "to-qop" | "to-finn" | "to-hls4ml"
        | "raise-qcdq" | "datatypes" => transform_cmd(&cmd, rest),
        "summary" => {
            let g = load_model(rest.first().context("usage: summary <model>")?)?;
            println!("{}", g.summary());
            Ok(())
        }
        "plan" => {
            let g = load_model(rest.first().context("usage: plan <model> [--verify]")?)?;
            let plan = crate::plan::ExecutionPlan::compile(&g)?;
            println!("{}", plan.summary());
            if has_flag(rest, "--verify") {
                let report = crate::verify::verify_plan(&plan, &g);
                print!("{}", report.render());
                if report.has_errors() {
                    bail!("plan verification failed");
                }
            }
            Ok(())
        }
        "verify" => verify_cmd(rest),
        "compile" => compile_cmd(rest),
        "streamline" => streamline_cmd(rest),
        "stats" => stats_cmd(rest),
        "exec" => exec_cmd(rest),
        "profile" => profile_cmd(rest),
        "zoo" => zoo_cmd(rest),
        "table1" => {
            println!("{}", formats::render_table());
            Ok(())
        }
        "table3" => table3_cmd(rest),
        "fig5" => fig5_cmd(rest),
        "train" => train_cmd(rest),
        "infer" => infer_cmd(rest),
        "serve" => serve_cmd(rest),
        "help" | "--help" | "-h" => {
            println!("{USAGE}");
            Ok(())
        }
        other => bail!("unknown command '{other}'\n{USAGE}"),
    }
}

fn transform_cmd(cmd: &str, rest: &[String]) -> Result<()> {
    let (input, output) = (
        rest.first().with_context(|| format!("usage: {cmd} <in> <out>"))?,
        rest.get(1).with_context(|| format!("usage: {cmd} <in> <out>"))?,
    );
    let mut g = load_model(input)?;
    let before = g.nodes.len();
    match cmd {
        "clean" => {
            transforms::cleanup(&mut g)?;
        }
        "channels-last" => {
            transforms::cleanup(&mut g)?;
            transforms::to_channels_last(&mut g)?;
        }
        "to-qcdq" => {
            transforms::lower_to_qcdq(&mut g)?;
        }
        "to-qop" => {
            transforms::lower_to_qop_clip(&mut g)?;
        }
        "to-finn" => {
            transforms::cleanup(&mut g)?;
            transforms::convert_to_finn(&mut g)?;
        }
        "to-hls4ml" => {
            transforms::cleanup(&mut g)?;
            transforms::hls4ml_ingest(&mut g)?;
        }
        "raise-qcdq" => {
            transforms::raise_qcdq_to_qonnx(&mut g)?;
        }
        "datatypes" => {
            transforms::infer_shapes(&mut g)?;
            transforms::infer_datatypes(&mut g)?;
        }
        _ => unreachable!(),
    }
    save_model(&g, output)?;
    println!("{cmd}: {} -> {} nodes, wrote {output}", before, g.nodes.len());
    Ok(())
}

/// `verify <model>` / `verify --zoo <name>`: statically verify the
/// compiled plan(s) — the float plan, and the streamlined integer plan
/// when the model lowers cleanly. Exits nonzero on any error-severity
/// diagnostic.
fn verify_cmd(rest: &[String]) -> Result<()> {
    if let Some(path) = parse_flag(rest, "--artifact") {
        // verify the DESERIALIZED plan against the graph embedded in the
        // artifact: checksums catch bit rot, but a structurally valid
        // artifact can still carry an illegal schedule — the static
        // verifier re-proves slot lifetimes, dtype flow, accumulator
        // bounds, and schedule legality on what will actually serve
        let loaded = crate::plan::artifact::read_artifact(std::path::Path::new(&path))
            .with_context(|| format!("loading artifact {path}"))?;
        let graph = loaded.graph()?;
        println!("— artifact plan ({path}) —");
        let report = crate::verify::verify_plan(&loaded.plan, &graph);
        print!("{}", report.render());
        if report.has_errors() {
            bail!("plan verification failed");
        }
        return Ok(());
    }
    let g = if let Some(name) = parse_flag(rest, "--zoo") {
        let mut g = zoo::build(&name, 1, 32)?;
        transforms::cleanup(&mut g)?;
        g
    } else {
        load_model(rest.first().context("usage: verify <model> | verify --zoo <name>")?)?
    };
    let mut failed = false;
    println!("— float plan —");
    let plan = crate::plan::ExecutionPlan::compile(&g)?;
    let report = crate::verify::verify_plan(&plan, &g);
    print!("{}", report.render());
    failed |= report.has_errors();

    let sl = crate::streamline::try_streamline(&g)?;
    if sl.report.ok {
        println!("— streamlined integer plan —");
        let splan = crate::plan::ExecutionPlan::compile(&sl.graph)?;
        let sreport = crate::verify::verify_plan(&splan, &sl.graph);
        print!("{}", sreport.render());
        failed |= sreport.has_errors();
    } else {
        println!("(model does not streamline — float plan only)");
    }
    if failed {
        bail!("plan verification failed");
    }
    Ok(())
}

/// `compile <model|--zoo name|--zoo all>`: compile to `.qpln` artifacts
/// for instant cold start (see [`crate::plan::artifact`]).
fn compile_cmd(rest: &[String]) -> Result<()> {
    let out = parse_flag(rest, "--out").map(PathBuf::from);
    if let Some(name) = parse_flag(rest, "--zoo") {
        if name == "all" {
            let dir = parse_flag(rest, "--out-dir").map(PathBuf::from).unwrap_or_else(|| ".".into());
            std::fs::create_dir_all(&dir)
                .with_context(|| format!("creating output dir {}", dir.display()))?;
            for n in zoo::ZOO_NAMES {
                compile_zoo_entry(n, &dir.join(format!("{n}.qpln")))?;
            }
            return Ok(());
        }
        let path = out.unwrap_or_else(|| PathBuf::from(format!("{name}.qpln")));
        return compile_zoo_entry(&name, &path);
    }
    let input = rest
        .first()
        .context("usage: compile <model> [--out <file.qpln>] | compile --zoo <name|all>")?;
    let mut g = load_model(input)?;
    transforms::cleanup(&mut g)?;
    let path = out.unwrap_or_else(|| PathBuf::from(input).with_extension("qpln"));
    compile_graph_to(&g, &path)
}

/// Build a zoo entry exactly like serving does (resolution 32, cleaned)
/// so `serve --artifact <name>.qpln` is bit-identical to `serve --zoo`.
fn compile_zoo_entry(name: &str, path: &std::path::Path) -> Result<()> {
    let mut g = zoo::build(name, 1, 32)?;
    transforms::cleanup(&mut g)?;
    compile_graph_to(&g, path)
}

fn compile_graph_to(g: &crate::ir::ModelGraph, path: &std::path::Path) -> Result<()> {
    let engine = coordinator::PlannedEngine::compile_to_artifact(g, path)?;
    let plan = engine.plan_handle();
    let bytes = std::fs::metadata(path).map(|m| m.len()).unwrap_or(0);
    println!(
        "wrote {} ({bytes} bytes): {} plan, {} steps, {} packed + {} quantized kernels, isa {}",
        path.display(),
        if engine.streamlined() { "streamlined integer" } else { "float" },
        plan.step_count(),
        plan.packed_count(),
        plan.quant_kernel_count(),
        crate::tensor::simd::active_isa(),
    );
    Ok(())
}

/// `streamline <model> [--out <file>]`: lower to integer-domain form and
/// report, node by node, what lowered and why anything didn't.
fn streamline_cmd(rest: &[String]) -> Result<()> {
    let input = rest.first().context("usage: streamline <model> [--out <file>]")?;
    let g = load_model(input)?;
    let att = crate::streamline::try_streamline(&g)?;
    print!("{}", att.report.render());
    if !att.report.ok {
        println!("(model left unchanged — the float plan remains the serving tier)");
        return Ok(());
    }
    let plan = crate::plan::ExecutionPlan::compile(&att.graph)?;
    let int_slots = plan
        .slot_dtypes()
        .iter()
        .filter(|d| matches!(d, crate::tensor::DType::I8 | crate::tensor::DType::I32))
        .count();
    println!(
        "integer plan: {} quantized kernels, {} fused epilogues, {} integer-resident values \
         ({int_slots}/{} integer slots), {} steps total",
        plan.quant_kernel_count(),
        plan.fused_epilogue_count(),
        plan.resident_int_count(),
        plan.slot_count(),
        plan.step_count()
    );
    if let Some(out) = parse_flag(rest, "--out") {
        save_model(&att.graph, &out)?;
        println!("wrote streamlined model to {out}");
    }
    Ok(())
}

fn stats_cmd(rest: &[String]) -> Result<()> {
    let mut g = load_model(rest.first().context("usage: stats <model>")?)?;
    transforms::infer_shapes(&mut g).ok();
    let r = metrics::analyze(&g)?;
    println!(
        "{:<24} {:>14} {:>18} {:>12} {:>8} {:>8}",
        "layer", "MACs", "BOPs(Eq.5)", "weights", "w bits", "a bits"
    );
    for l in &r.layers {
        println!(
            "{:<24} {:>14} {:>18.0} {:>12} {:>8} {:>8}",
            l.node_name, l.macs, l.bops, l.weights, l.weight_bits, l.act_bits
        );
    }
    println!(
        "TOTAL  MACs={} BOPs={:.3e} MAC-BOPs={:.3e} weights={} total_weight_bits={}",
        r.macs(),
        r.bops(),
        r.mac_bops(),
        r.weights(),
        r.total_weight_bits()
    );
    Ok(())
}

fn exec_cmd(rest: &[String]) -> Result<()> {
    let g = load_model(rest.first().context("usage: exec <model>")?)?;
    let seed: u64 = parse_flag(rest, "--seed").map(|s| s.parse()).transpose()?.unwrap_or(1);
    let engine = parse_flag(rest, "--engine").unwrap_or_else(|| "plan".to_string());
    let mut rng = zoo::rng::Rng::new(seed);
    let mut inputs = BTreeMap::new();
    for vi in &g.inputs {
        if g.initializers.contains_key(&vi.name) {
            continue;
        }
        let shape = vi.shape.clone().context("graph input lacks a shape")?;
        let n: usize = shape.iter().product();
        let data: Vec<f32> = (0..n).map(|_| rng.uniform()).collect();
        inputs.insert(vi.name.clone(), Tensor::new(shape, data));
    }
    let r = match engine.as_str() {
        "plan" => exec::execute(&g, &inputs)?,
        "interp" | "interpreter" => exec::interpret(&g, &inputs)?,
        other => bail!("unknown engine '{other}' (expected 'plan' or 'interp')"),
    };
    for (name, t) in &r.outputs {
        let v = t.as_f32()?;
        let show = &v[..v.len().min(16)];
        println!("{name} {:?} = {show:?}{}", t.shape(), if v.len() > 16 { " ..." } else { "" });
    }
    Ok(())
}

fn profile_cmd(rest: &[String]) -> Result<()> {
    let target = rest
        .first()
        .context("usage: profile <model|zoo-name> [--batch N] [--runs N] [--trace <out.json>]")?;
    let batch: usize = parse_flag(rest, "--batch").map(|s| s.parse()).transpose()?.unwrap_or(1);
    let runs: usize = parse_flag(rest, "--runs").map(|s| s.parse()).transpose()?.unwrap_or(10);
    let trace_path = parse_flag(rest, "--trace");
    if batch == 0 || runs == 0 {
        bail!("--batch and --runs must be at least 1");
    }

    // a file path profiles that model; anything else resolves in the zoo
    let (model_name, mut g) = if std::path::Path::new(target).exists() {
        let name = std::path::Path::new(target)
            .file_stem()
            .map(|s| s.to_string_lossy().into_owned())
            .unwrap_or_else(|| "model".into());
        (name, load_model(target)?)
    } else {
        let res = if target.starts_with("MobileNet") { 224 } else { 32 };
        (target.clone(), zoo::build(target, 1, res)?)
    };
    transforms::cleanup(&mut g)?;
    // Eq.-5 static complexity model on the cleaned graph: joined per step
    // into achieved GMAC/s / GBOP/s columns (unmodeled rows print '-')
    let report = metrics::analyze(&g).ok();

    // profile the tier that would actually serve: the streamlined
    // integer-domain plan when the whole model lowers cleanly
    let sl = crate::streamline::try_streamline(&g)?;
    let streamlined = sl.report.ok;
    let graph = if streamlined { sl.graph } else { g };
    if streamlined {
        println!("('{model_name}' streamlined: profiling the integer-domain quantized plan)");
    }
    let plan = crate::plan::ExecutionPlan::compile(&graph)?;
    if batch > 1 && !plan.batch_blockers().is_empty() {
        bail!("plan cannot serve batch {batch}: {:?}", plan.batch_blockers());
    }

    let recorder = trace_path.as_ref().map(|_| {
        let r = std::sync::Arc::new(crate::trace::TraceRecorder::new(1 << 16));
        crate::trace::install_global(r.clone());
        r
    });

    // random inputs at the requested batch (leading dim freed below)
    let mut rng = zoo::rng::Rng::new(1);
    let mut inputs = BTreeMap::new();
    for vi in &graph.inputs {
        if graph.initializers.contains_key(&vi.name) {
            continue;
        }
        let mut shape = vi.shape.clone().context("graph input lacks a shape")?;
        if !shape.is_empty() {
            shape[0] = batch;
        }
        let n: usize = shape.iter().product();
        let data: Vec<f32> = (0..n).map(|_| rng.uniform()).collect();
        inputs.insert(vi.name.clone(), Tensor::new(shape, data));
    }
    let cfg = crate::plan::RunConfig {
        shape_check: crate::plan::ShapeCheck::FreeBatch,
        record_intermediates: false,
    };
    let mut scratch = crate::plan::ScratchArena::new();
    // one warmup run fills the arena pools and does the one-time weight
    // packing, so the profiled runs see steady-state behaviour
    plan.run_cfg_scratch(|n| inputs.get(n), &cfg, &mut scratch)?;
    let mut obs = match &recorder {
        Some(r) => crate::plan::StepObserver::with_trace(r.clone()),
        None => crate::plan::StepObserver::new(),
    };
    for _ in 0..runs {
        plan.run_profiled(|n| inputs.get(n), &cfg, &mut scratch, &mut obs)?;
    }
    let profile = crate::trace::profile::StepProfile::build(
        &model_name,
        obs.samples(),
        report.as_ref(),
        batch as u64,
    );
    print!("{}", profile.render_table());
    if let Some(path) = trace_path {
        let rec = recorder.expect("recorder exists whenever --trace is set");
        if std::path::Path::new(&path).exists() {
            let _ = std::fs::rename(&path, format!("{path}.1"));
        }
        let tracks = rec.drain();
        std::fs::write(&path, crate::trace::chrome::chrome_trace_json(&tracks))
            .with_context(|| format!("writing Chrome trace to {path}"))?;
        println!("wrote Chrome trace: {} thread track(s) -> {path}", tracks.len());
    }
    Ok(())
}

fn zoo_cmd(rest: &[String]) -> Result<()> {
    let name = rest.first().context("usage: zoo <name> <out>")?;
    let out = rest.get(1).context("usage: zoo <name> <out>")?;
    let g = zoo::build(name, 1, 224)?;
    save_model(&g, out)?;
    println!("wrote {name} ({} nodes) to {out}", g.nodes.len());
    Ok(())
}

/// Table III: metrics for all zoo entries (+ QAT accuracy unless --fast).
fn table3_cmd(rest: &[String]) -> Result<()> {
    let fast = has_flag(rest, "--fast");
    println!(
        "{:<18} {:<9} {:>9} {:>6} {:>6} {:>14} {:>16} {:>11} {:>14} {:>9} {:>9}",
        "Model", "Dataset", "Acc(paper)", "w", "a", "MACs", "BOPs(Eq.5)", "Weights", "WeightBits", "Acc(ours)", "note"
    );
    for name in zoo::ZOO_NAMES {
        let res = if name.starts_with("MobileNet") { if fast { 64 } else { 224 } } else { 32 };
        let mut g = zoo::build(name, 1, res)?;
        transforms::cleanup(&mut g)?;
        let r = metrics::analyze(&g)?;
        let (w, a) = parse_wa(name);
        let (acc, note) = measured_accuracy(name, w, a, fast)?;
        println!(
            "{:<18} {:<9} {:>9.2} {:>6} {:>6} {:>14} {:>16.3e} {:>11} {:>14} {:>9} {:>9}",
            name,
            zoo::dataset_of(name),
            zoo::paper_accuracy(name).unwrap_or(0.0),
            w,
            a,
            r.macs(),
            r.bops(),
            r.weights(),
            r.total_weight_bits(),
            acc,
            note
        );
    }
    Ok(())
}

fn parse_wa(name: &str) -> (u32, u32) {
    let wa = name.rsplit('-').next().unwrap();
    let a_pos = wa.find('a').unwrap();
    (wa[1..a_pos].parse().unwrap(), wa[a_pos + 1..].parse().unwrap())
}

/// Train-and-measure accuracy for the trainable tiers (MNIST directly;
/// CIFAR via an MLP proxy per DESIGN.md §3); cite-only for ImageNet.
fn measured_accuracy(name: &str, w: u32, a: u32, fast: bool) -> Result<(String, &'static str)> {
    let epochs = if fast { 6 } else { 25 };
    match zoo::dataset_of(name) {
        "MNIST" => {
            let train = zoo::synth_digits_noisy(if fast { 400 } else { 2000 }, 100, 0.25);
            let test = zoo::synth_digits_noisy(500, 101, 0.25);
            let mut cfg = training::QatConfig::tfc(w, a);
            cfg.epochs = epochs;
            let mut m = training::train_mlp(&train, &cfg)?;
            Ok((format!("{:.2}", m.accuracy(&test)), "synth-digits"))
        }
        "CIFAR-10" => {
            let train = zoo::synth_cifar(if fast { 300 } else { 1500 }, 200);
            let test = zoo::synth_cifar(500, 201);
            let mut cfg = training::QatConfig::tfc(w, a);
            cfg.hidden = vec![128, 64];
            cfg.epochs = epochs;
            let mut m = training::train_mlp(&train, &cfg)?;
            Ok((format!("{:.2}", m.accuracy(&test)), "synth-cifar/mlp-proxy"))
        }
        _ => Ok(("-".into(), "paper value cited")),
    }
}

/// Fig. 5 series: (model, dataset, BOPs, total weight bits, accuracy).
fn fig5_cmd(rest: &[String]) -> Result<()> {
    let fast = has_flag(rest, "--fast");
    println!("# Fig. 5: accuracy vs BOPs; marker size = total weight bits");
    println!("{:<18} {:<9} {:>16} {:>14} {:>10} {:>10}", "model", "dataset", "BOPs(Eq.5)", "weight_bits", "acc_paper", "acc_ours");
    for name in zoo::ZOO_NAMES {
        let res = if name.starts_with("MobileNet") { if fast { 64 } else { 224 } } else { 32 };
        let mut g = zoo::build(name, 1, res)?;
        transforms::cleanup(&mut g)?;
        let r = metrics::analyze(&g)?;
        let (w, a) = parse_wa(name);
        let (acc, _) = measured_accuracy(name, w, a, fast)?;
        println!(
            "{:<18} {:<9} {:>16.4e} {:>14} {:>10.2} {:>10}",
            name,
            zoo::dataset_of(name),
            r.bops(),
            r.total_weight_bits(),
            zoo::paper_accuracy(name).unwrap_or(0.0),
            acc
        );
    }
    Ok(())
}

fn train_cmd(rest: &[String]) -> Result<()> {
    let w: u32 = parse_flag(rest, "--w").map(|s| s.parse()).transpose()?.unwrap_or(2);
    let a: u32 = parse_flag(rest, "--a").map(|s| s.parse()).transpose()?.unwrap_or(2);
    let epochs: usize = parse_flag(rest, "--epochs").map(|s| s.parse()).transpose()?.unwrap_or(20);
    let out = parse_flag(rest, "--out");
    let train = zoo::synth_digits(2000, 100);
    let test = zoo::synth_digits(500, 101);
    let mut cfg = training::QatConfig::tfc(w, a);
    cfg.epochs = epochs;
    println!("training TFC-w{w}a{a} on {} synth-digits for {epochs} epochs...", train.len());
    let mut m = training::train_mlp(&train, &cfg)?;
    for (i, l) in m.loss_curve.iter().enumerate() {
        println!("epoch {:>3}: loss {l:.4}", i + 1);
    }
    println!("test accuracy: {:.2}%", m.accuracy(&test));
    if let Some(path) = out {
        let g = m.to_qonnx(1)?;
        save_model(&g, &path)?;
        println!("wrote QONNX model to {path}");
    }
    Ok(())
}

fn infer_cmd(rest: &[String]) -> Result<()> {
    let stem = rest.first().context("usage: infer <artifact-stem>")?;
    let rt = runtime::PjrtRuntime::cpu()?;
    println!("PJRT platform: {}", rt.platform());
    let (model, meta) = rt.load_artifact(&PathBuf::from(stem))?;
    let err = model.self_check(&meta)?;
    println!("artifact {}: batch {}, probe max abs err {err:.2e}", meta.name, meta.batch);
    Ok(())
}

/// Wrap an engine in the fault injector when env-hook injection is on.
fn wrap_faulty(
    engine: Box<dyn coordinator::InferenceEngine>,
    inj: &Option<coordinator::FaultInjector>,
) -> Box<dyn coordinator::InferenceEngine> {
    match inj {
        Some(f) => Box::new(coordinator::FaultyEngine::new(engine, f.clone())),
        None => engine,
    }
}

/// Per-client outcome tally for the serve demo.
#[derive(Default)]
struct ClientTally {
    ok: u64,
    deadline: u64,
    faulted: u64,
    shed_events: u64,
    gave_up: u64,
}

fn serve_cmd(rest: &[String]) -> Result<()> {
    let stem = parse_flag(rest, "--artifact")
        .map(PathBuf::from)
        .unwrap_or_else(|| runtime::artifacts_dir().join("tfc_w2a2"));
    let requests: usize = parse_flag(rest, "--requests").map(|s| s.parse()).transpose()?.unwrap_or(256);
    let clients: usize = parse_flag(rest, "--clients").map(|s| s.parse()).transpose()?.unwrap_or(8);
    let shards: usize = parse_flag(rest, "--shards").map(|s| s.parse()).transpose()?.unwrap_or(1);
    let intraop: Option<usize> =
        parse_flag(rest, "--intraop-threads").map(|s| s.parse()).transpose()?;
    let queue_cap: Option<usize> = parse_flag(rest, "--queue-cap").map(|s| s.parse()).transpose()?;
    let deadline_ms: Option<u64> =
        parse_flag(rest, "--deadline-ms").map(|s| s.parse()).transpose()?;
    let show_metrics = has_flag(rest, "--metrics");
    let zoo_name = parse_flag(rest, "--zoo");
    let trace_path = parse_flag(rest, "--trace");
    let artifact_requested = has_flag(rest, "--artifact");
    // a `.qpln` value is a compiled-plan artifact (instant cold start);
    // anything else keeps the original meaning of a PJRT artifact stem
    let plan_artifact = artifact_requested && stem.extension().is_some_and(|e| e == "qpln");
    let have_artifact = !plan_artifact && stem.with_extension("hlo.txt").exists();
    if artifact_requested && zoo_name.is_some() {
        bail!("--artifact and --zoo are mutually exclusive (pick one engine)");
    }
    if plan_artifact && !stem.exists() {
        bail!("compiled-plan artifact {stem:?} not found (build one with `qonnx compile`)");
    }
    if artifact_requested && !plan_artifact && !have_artifact {
        bail!("artifact {stem:?} not found (missing {:?})", stem.with_extension("hlo.txt"));
    }
    if shards == 0 {
        bail!("--shards must be at least 1");
    }

    // deterministic fault injection (QONNX_FAULT_SEED env hooks): every
    // shard's engine gets wrapped; failures become typed responses,
    // restarts, and health deltas instead of hangs
    let fault = coordinator::FaultInjector::from_env();
    if fault.is_some() {
        println!(
            "fault injection ON (QONNX_FAULT_SEED set; rate {}, kind {})",
            std::env::var("QONNX_FAULT_RATE").unwrap_or_else(|_| "0.1".into()),
            std::env::var("QONNX_FAULT_KIND").unwrap_or_else(|_| "error".into()),
        );
    }

    // the shards × intra-op trade: request-parallelism across shards,
    // kernel-parallelism inside each, bounded by the shared pool
    let pool_threads = crate::runtime::pool::global().threads();
    let budget = intraop.unwrap_or_else(|| (pool_threads / shards).max(1));
    println!(
        "kernel substrate: isa {} ({}), pool {pool_threads} threads, \
         {shards} shard(s) x {budget} intra-op",
        crate::tensor::simd::active_isa(),
        if crate::tensor::simd::force_scalar() { "forced scalar" } else { "detected" },
    );
    // request-lifecycle tracing: one bounded recorder shared by the
    // admission path, the shard workers, and (via the global hook) the
    // intra-op pool threads; drained to Chrome-trace JSON at shutdown
    let recorder = trace_path.as_ref().map(|_| {
        let r = std::sync::Arc::new(crate::trace::TraceRecorder::new(1 << 15));
        crate::trace::install_global(r.clone());
        r
    });
    let cfg = coordinator::BatcherConfig {
        intraop_threads: intraop,
        queue_capacity: queue_cap,
        trace: recorder.clone(),
        ..Default::default()
    };

    // stable per-model metrics label, resolved before the engine branch
    // below consumes the flag values
    let model_name = if plan_artifact || (zoo_name.is_none() && have_artifact) {
        stem.file_stem()
            .map(|s| s.to_string_lossy().into_owned())
            .unwrap_or_else(|| "artifact".into())
    } else {
        zoo_name.clone().unwrap_or_else(|| "TFC-w2a2".to_string())
    };

    let batcher = if plan_artifact {
        // instant cold start: the artifact is loaded ONCE; every shard
        // serves an Arc-shared view of the deserialized plan, weight
        // panels borrowed zero-copy from the single shared mapping
        let start = std::time::Instant::now();
        let template = coordinator::PlannedEngine::from_artifact(&stem)?;
        println!(
            "(loaded compiled-plan artifact {stem:?} in {:.1}ms — no re-pack, no re-streamline)",
            start.elapsed().as_secs_f64() * 1e3
        );
        if template.streamlined() {
            println!("(artifact serves the integer-domain quantized plan)");
        }
        if shards > 1 {
            println!("({shards} batcher shards sharing one loaded artifact)");
        }
        let inj = fault.clone();
        coordinator::Batcher::start_sharded(
            move || {
                let engine = Box::new(template.share()) as Box<dyn coordinator::InferenceEngine>;
                Ok(wrap_faulty(engine, &inj))
            },
            cfg,
            shards,
        )?
    } else if zoo_name.is_none() && have_artifact {
        // PJRT executables are thread-affine: each shard loads its own
        let inj = fault.clone();
        coordinator::Batcher::start_sharded(
            move || {
                let rt = runtime::PjrtRuntime::cpu()?;
                let engine = Box::new(coordinator::PjrtEngine::load(&rt, &stem)?)
                    as Box<dyn coordinator::InferenceEngine>;
                Ok(wrap_faulty(engine, &inj))
            },
            cfg,
            shards,
        )?
    } else {
        // no compiled artifact (or an explicit zoo request): serve the
        // model natively through a compiled ExecutionPlan. The plan is
        // compiled ONCE here; every shard serves an Arc-shared view of it
        let name = model_name.clone();
        if !have_artifact {
            println!("(no PJRT artifact at {stem:?} — serving '{name}' via the compiled ExecutionPlan)");
        }
        let template = coordinator::PlannedEngine::from_zoo(&name)?;
        if template.streamlined() {
            println!("('{name}' streamlined: serving the integer-domain quantized plan)");
        }
        if shards > 1 {
            println!("({shards} batcher shards sharing one compiled plan)");
        }
        let inj = fault.clone();
        coordinator::Batcher::start_sharded(
            move || {
                let engine = Box::new(template.share()) as Box<dyn coordinator::InferenceEngine>;
                Ok(wrap_faulty(engine, &inj))
            },
            cfg,
            shards,
        )?
    };
    // row lengths come from the engine's startup handshake, so both
    // branches serve correctly-sized requests for any model
    let (in_dim, out_dim) = (batcher.input_dim(), batcher.output_dim());
    let fault_mode = fault.is_some();
    let deadline = deadline_ms.map(std::time::Duration::from_millis);
    let batcher = std::sync::Arc::new(batcher);
    println!("serving with {clients} clients x {} requests each...", requests / clients);
    let start = std::time::Instant::now();
    let mut handles = Vec::new();
    for c in 0..clients {
        let b = batcher.clone();
        let per_client = requests / clients;
        handles.push(std::thread::spawn(move || -> Result<ClientTally> {
            let mut rng = zoo::rng::Rng::new(c as u64 + 1);
            let mut tally = ClientTally::default();
            for _ in 0..per_client {
                let input: Vec<f32> = (0..in_dim).map(|_| rng.uniform()).collect();
                let opts = coordinator::SubmitOptions { deadline, submit_timeout: None };
                let mut attempts = 0usize;
                loop {
                    attempts += 1;
                    match b.submit_with(input.clone(), opts) {
                        Ok(resp) => {
                            match resp.wait() {
                                Ok(out) => {
                                    anyhow::ensure!(out.len() == out_dim);
                                    tally.ok += 1;
                                }
                                Err(coordinator::ServeError::DeadlineExceeded { .. }) => {
                                    tally.deadline += 1;
                                }
                                Err(
                                    e @ (coordinator::ServeError::Engine { .. }
                                    | coordinator::ServeError::ShardPanicked { .. }),
                                ) => {
                                    // with injection on, typed failures are
                                    // the point; without it they are real
                                    if fault_mode {
                                        tally.faulted += 1;
                                    } else {
                                        return Err(anyhow::Error::new(e));
                                    }
                                }
                                Err(e) => return Err(anyhow::Error::new(e)),
                            }
                            break;
                        }
                        // typed shed: back off briefly and retry
                        Err(coordinator::SubmitError::Shed { .. }) => {
                            tally.shed_events += 1;
                            if attempts >= 64 {
                                tally.gave_up += 1;
                                break;
                            }
                            std::thread::sleep(std::time::Duration::from_micros(200));
                        }
                        Err(e) => return Err(anyhow::Error::new(e)),
                    }
                }
            }
            Ok(tally)
        }));
    }
    let mut total = ClientTally::default();
    for h in handles {
        let t = h.join().unwrap()?;
        total.ok += t.ok;
        total.deadline += t.deadline;
        total.faulted += t.faulted;
        total.shed_events += t.shed_events;
        total.gave_up += t.gave_up;
    }
    let elapsed = start.elapsed();
    let stats = batcher.stats();
    let health = batcher.health();
    println!(
        "served {} requests in {:.3}s  ({:.0} req/s, mean latency {:.0}us, max {}us, mean batch {:.2})",
        stats.requests,
        elapsed.as_secs_f64(),
        stats.requests as f64 / elapsed.as_secs_f64(),
        stats.mean_latency_us(),
        stats.max_latency_us,
        stats.mean_batch_occupancy()
    );
    println!(
        "outcomes: {} ok, {} deadline-exceeded, {} faulted, {} shed events ({} gave up)",
        total.ok, total.deadline, total.faulted, total.shed_events, total.gave_up
    );
    println!(
        "health: {}/{} shards live, {} restarts, {} permanently dead",
        health.live, health.shards, health.restarts, health.dead
    );
    if show_metrics {
        // per-model scrape: every series carries the served model's
        // kebab-case label so multi-model scrapes stay distinguishable
        let registry = metrics::serving::MetricsRegistry::new();
        registry.register(&model_name, batcher.metrics());
        print!("{}", registry.render_text());
    }
    if let Some(path) = trace_path {
        // drop the batcher first: shutdown flushes the final batch spans
        // and the workers' queue-wait events before we drain
        drop(batcher);
        let rec = recorder.expect("recorder exists whenever --trace is set");
        if std::path::Path::new(&path).exists() {
            let _ = std::fs::rename(&path, format!("{path}.1"));
        }
        let tracks = rec.drain();
        let dropped: u64 = tracks.iter().map(|t| t.dropped).sum();
        std::fs::write(&path, crate::trace::chrome::chrome_trace_json(&tracks))
            .with_context(|| format!("writing Chrome trace to {path}"))?;
        println!(
            "wrote Chrome trace: {} thread track(s), {dropped} dropped event(s) -> {path} \
             (load in chrome://tracing or ui.perfetto.dev)",
            tracks.len()
        );
    }
    Ok(())
}

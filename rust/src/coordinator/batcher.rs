//! Dynamic micro-batching request loop with explicit failure handling:
//! bounded admission, request deadlines, and supervised worker shards.
//!
//! Requests enter ONE shared, capacity-limited queue
//! ([`BatcherConfig::queue_capacity`]); when the queue is full, [`Batcher::submit`]
//! returns a typed [`SubmitError::Shed`] instead of growing without bound
//! (callers willing to wait pass [`SubmitOptions::submit_timeout`]). Each
//! worker drains up to `engine.max_batch()` requests or waits at most
//! `max_wait` for stragglers (size-or-deadline triggering, the standard
//! serving-batcher policy), executes one fused inference, and scatters the
//! rows back to per-request channels. Requests may carry a deadline
//! ([`SubmitOptions::deadline`]): already-expired requests are dropped at
//! drain time with a typed [`ServeError::DeadlineExceeded`] instead of
//! spending batch slots on dead work, and the batch closes early when the
//! oldest member's deadline nears.
//!
//! Failure is a first-class result, not a hang: engine errors and panics
//! fail the in-flight batch with typed [`ServeError`]s, a panicked shard is
//! restarted by the supervisor (`super::supervisor`) with capped
//! exponential backoff, queue locking is poison-recovering (one crashed
//! shard cannot wedge the others), and shutdown drains or typed-fails every
//! queued request — a submitted request ALWAYS gets a definitive response.
//!
//! [`Batcher::start_sharded`] runs N workers over the shared queue: each
//! worker holds the queue lock only while *draining* its batch and releases
//! it before running inference, so shards overlap compute. Engines built
//! from a shared template (e.g. [`super::PlannedEngine::share`]) make every
//! shard serve the same `Arc`'d compiled plan. Serving counters and the
//! latency histogram live in [`crate::metrics::serving`]
//! ([`Batcher::metrics`]).

use super::engine::InferenceEngine;
use super::supervisor::{
    self, DegradedPolicy, Health, InflightEntry, ShardPhase, ShardState, SupervisorConfig,
};
use crate::metrics::serving::{BatchCloseReason, ServingMetrics};
use crate::tensor::Tensor;
use crate::trace::TraceRecorder;
use anyhow::{anyhow, ensure, Result};
use std::collections::VecDeque;
use std::fmt;
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::{mpsc, Arc, Condvar, Mutex, MutexGuard, PoisonError};
use std::time::{Duration, Instant};

/// Lock a mutex, recovering from poison: a worker that panicked while
/// holding the lock must not wedge every other shard (the guarded state
/// is a request queue / phase tag, valid at every await point).
pub(crate) fn lock_recover<T>(m: &Mutex<T>) -> MutexGuard<'_, T> {
    m.lock().unwrap_or_else(PoisonError::into_inner)
}

/// The multi-call engine factory shape retained for shard restarts.
pub(crate) type EngineFactory = dyn Fn() -> Result<Box<dyn InferenceEngine>> + Send + Sync;

/// Per-request response channel (typed result).
pub(crate) type RespSender = mpsc::Sender<Result<Vec<f32>, ServeError>>;

/// Batcher tuning.
#[derive(Debug, Clone)]
pub struct BatcherConfig {
    /// max time the first request of a batch waits for company
    pub max_wait: Duration,
    /// per-shard intra-op thread cap for the shared kernel pool
    /// ([`crate::runtime::pool`]). `None` divides the pool evenly:
    /// `(pool threads / shards).max(1)`, so shards × intra-op ≤ cores —
    /// request-parallelism is traded against per-request parallelism
    /// instead of oversubscribing.
    pub intraop_threads: Option<usize>,
    /// Bounded admission: max queued (not yet drained) requests. When the
    /// queue is full, `submit` sheds with [`SubmitError::Shed`] instead of
    /// enqueueing. `None` = unbounded (the legacy behavior).
    pub queue_capacity: Option<usize>,
    /// Shard supervision: restart backoff, deadline sweep cadence,
    /// degraded-mode policy ([`SupervisorConfig`]).
    pub supervisor: SupervisorConfig,
    /// Request-lifecycle tracing. `None` (default) is a per-site branch
    /// and nothing more; with a recorder installed, admission/shed
    /// instants, queue-wait and batch/execute/scatter spans, and typed
    /// failure events are recorded (see [`crate::trace`] for the span
    /// taxonomy and [`crate::trace::chrome`] for the export).
    pub trace: Option<Arc<TraceRecorder>>,
}

impl Default for BatcherConfig {
    fn default() -> Self {
        BatcherConfig {
            max_wait: Duration::from_millis(2),
            intraop_threads: None,
            queue_capacity: None,
            supervisor: SupervisorConfig::default(),
            trace: None,
        }
    }
}

/// Why a request was refused at admission (before entering the queue).
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum SubmitError {
    /// The bounded queue is full; the request was shed (not enqueued).
    Shed { queue_depth: usize },
    /// The server has shut down (or is shutting down).
    ShutDown,
    /// Every shard is dead and unrevivable — nothing can serve.
    NoLiveShards,
    /// Some shards are permanently dead and the configured policy
    /// ([`DegradedPolicy::RefuseWhenDegraded`]) refuses degraded service.
    Degraded { live: usize, shards: usize },
    /// Input row length does not match the engine's input dim.
    InvalidInput { got: usize, want: usize },
}

impl fmt::Display for SubmitError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            SubmitError::Shed { queue_depth } => {
                write!(f, "request shed: queue full at depth {queue_depth}")
            }
            SubmitError::ShutDown => write!(f, "server is shut down"),
            SubmitError::NoLiveShards => {
                write!(f, "no live shards (all workers dead and unrevivable)")
            }
            SubmitError::Degraded { live, shards } => write!(
                f,
                "server degraded ({live}/{shards} shards live) and policy refuses degraded service"
            ),
            SubmitError::InvalidInput { got, want } => {
                write!(f, "input length {got} != {want}")
            }
        }
    }
}

impl std::error::Error for SubmitError {}

/// Why an admitted request failed to produce an output row.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum ServeError {
    /// The request's deadline passed before a result was produced.
    /// `missed_by` is zero when the *caller's* wait timed out
    /// (client-side enforcement) and positive when the server dropped or
    /// swept the expired request.
    DeadlineExceeded { missed_by: Duration },
    /// `infer_batch` returned an error (or produced invalid output).
    Engine { message: String },
    /// The worker serving this request's batch panicked; the shard is
    /// being restarted by the supervisor.
    ShardPanicked { message: String },
    /// The server shut down before this request could be served.
    ShutDown,
    /// Every shard died (restart budget exhausted) with this request
    /// still queued.
    NoLiveShards,
    /// The response channel disconnected without a response — a serving
    /// bug if it ever surfaces; typed so callers never panic on it.
    ChannelClosed,
}

impl fmt::Display for ServeError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            ServeError::DeadlineExceeded { missed_by } => {
                write!(f, "deadline exceeded (missed by {missed_by:?})")
            }
            ServeError::Engine { message } => write!(f, "engine error: {message}"),
            ServeError::ShardPanicked { message } => {
                write!(f, "shard panicked while serving this batch: {message}")
            }
            ServeError::ShutDown => write!(f, "server shut down before serving this request"),
            ServeError::NoLiveShards => {
                write!(f, "all shards dead (restart budget exhausted) with request queued")
            }
            ServeError::ChannelClosed => write!(f, "response channel closed without a response"),
        }
    }
}

impl std::error::Error for ServeError {}

/// Per-submit options: deadline and admission wait.
#[derive(Debug, Clone, Copy, Default)]
pub struct SubmitOptions {
    /// Drop the request (typed [`ServeError::DeadlineExceeded`]) if no
    /// result is produced within this duration of submission. Enforced
    /// server-side (drain-time drop, batch-close, supervisor sweep of
    /// stalled shards) AND client-side in [`Response::wait`].
    pub deadline: Option<Duration>,
    /// When the bounded queue is full, wait up to this long for space
    /// instead of shedding immediately.
    pub submit_timeout: Option<Duration>,
}

pub(crate) struct Request {
    pub(crate) input: Vec<f32>,
    pub(crate) enqueued: Instant,
    pub(crate) deadline: Option<Instant>,
    pub(crate) resp: RespSender,
}

struct QueueState {
    q: VecDeque<Request>,
    /// false once shutdown begins: submits are refused
    open: bool,
}

/// State shared between submitting clients, worker shards, and the
/// supervisor thread.
pub(crate) struct ServerShared {
    queue: Mutex<QueueState>,
    /// signaled on enqueue (wakes a draining worker)
    work: Condvar,
    /// signaled on dequeue (wakes `submit_timeout` waiters)
    space: Condvar,
    pub(crate) cfg: BatcherConfig,
    pub(crate) shards: Vec<ShardState>,
    pub(crate) metrics: Arc<ServingMetrics>,
    stats: Stats,
    pub(crate) shutdown: AtomicBool,
    /// dims advertised at startup; a restarted shard must agree
    expect_in: AtomicUsize,
    expect_out: AtomicUsize,
}

/// Aggregated serving statistics.
#[derive(Debug, Default, Clone)]
pub struct ServerStats {
    pub requests: u64,
    pub batches: u64,
    pub total_latency_us: u64,
    pub max_latency_us: u64,
}

impl ServerStats {
    pub fn mean_latency_us(&self) -> f64 {
        if self.requests == 0 {
            0.0
        } else {
            self.total_latency_us as f64 / self.requests as f64
        }
    }

    pub fn mean_batch_occupancy(&self) -> f64 {
        if self.batches == 0 {
            0.0
        } else {
            self.requests as f64 / self.batches as f64
        }
    }
}

#[derive(Default)]
struct Stats {
    requests: AtomicU64,
    batches: AtomicU64,
    total_latency_us: AtomicU64,
    max_latency_us: AtomicU64,
}

impl ServerShared {
    /// Record a served row: latency stats + metrics, then deliver.
    fn deliver_ok(&self, req: &Request, row: Vec<f32>) {
        let lat = req.enqueued.elapsed().as_micros() as u64;
        self.stats.requests.fetch_add(1, Ordering::Relaxed);
        self.stats.total_latency_us.fetch_add(lat, Ordering::Relaxed);
        self.stats.max_latency_us.fetch_max(lat, Ordering::Relaxed);
        self.metrics.record_latency_us(lat);
        let _ = req.resp.send(Ok(row));
    }

    /// Deliver a typed failure (to a queued/in-flight request's channel),
    /// counting it in stats and metrics.
    pub(crate) fn deliver_err_to(&self, resp: &RespSender, err: ServeError) {
        self.stats.requests.fetch_add(1, Ordering::Relaxed);
        match &err {
            ServeError::DeadlineExceeded { .. } => self.metrics.inc_deadline_exceeded(),
            _ => self.metrics.inc_failed(1),
        }
        if let Some(t) = &self.cfg.trace {
            let name = match &err {
                ServeError::DeadlineExceeded { .. } => "deadline-exceeded",
                ServeError::Engine { .. } => "engine-error",
                ServeError::ShardPanicked { .. } => "shard-panic",
                ServeError::ShutDown => "shutdown",
                ServeError::NoLiveShards => "no-live-shards",
                ServeError::ChannelClosed => "channel-closed",
            };
            t.instant("request", name, &[]);
        }
        let _ = resp.send(Err(err));
    }

    fn deliver_err(&self, req: &Request, err: ServeError) {
        self.deliver_err_to(&req.resp, err);
    }

    /// First worker to claim wins; later (restarted) workers must agree.
    fn claim_dims(&self, in_dim: usize, out_dim: usize) -> bool {
        let a = self.expect_in.compare_exchange(0, in_dim, Ordering::SeqCst, Ordering::SeqCst);
        let b = self.expect_out.compare_exchange(0, out_dim, Ordering::SeqCst, Ordering::SeqCst);
        let in_ok = match a {
            Ok(_) => true,
            Err(prev) => prev == in_dim,
        };
        let out_ok = match b {
            Ok(_) => true,
            Err(prev) => prev == out_dim,
        };
        in_ok && out_ok
    }

    /// Take the queue, block for the first request, gather a batch until
    /// `max_batch` / `max_wait` / the oldest member's deadline closes it.
    /// Already-expired requests are dropped (typed) instead of spending
    /// batch slots. Returns the batch plus *why* it closed (the
    /// [`BatchCloseReason`] recorded per batch in metrics and traces);
    /// `None` at shutdown with an empty queue.
    fn drain_batch(&self, max_batch: usize) -> Option<(Vec<Request>, BatchCloseReason)> {
        let mut batch: Vec<Request> = Vec::new();
        let mut reason = BatchCloseReason::Window;
        let depth_after = {
            let mut q = lock_recover(&self.queue);
            // block (poll-free: condvar with a shutdown-check timeout)
            // for the first request of the batch
            loop {
                let now = Instant::now();
                match q.q.pop_front() {
                    Some(r) => {
                        if let Some(d) = r.deadline {
                            if d <= now {
                                self.deliver_err(
                                    &r,
                                    ServeError::DeadlineExceeded {
                                        missed_by: now.duration_since(d),
                                    },
                                );
                                continue;
                            }
                        }
                        batch.push(r);
                        break;
                    }
                    None => {
                        if self.shutdown.load(Ordering::Relaxed) {
                            return None;
                        }
                        let (g, _) = self
                            .work
                            .wait_timeout(q, Duration::from_millis(20))
                            .unwrap_or_else(PoisonError::into_inner);
                        q = g;
                    }
                }
            }
            // gather: close at max_wait OR the nearest member deadline,
            // whichever comes first (don't let stragglers starve a
            // deadline-bearing request of its service window)
            let window = Instant::now() + self.cfg.max_wait;
            let mut close = window;
            if let Some(d) = batch[0].deadline {
                close = close.min(d);
            }
            // a close earlier than the window can only mean a member
            // deadline clamped it
            let expiry_reason = |close: Instant| {
                if close < window {
                    BatchCloseReason::Deadline
                } else {
                    BatchCloseReason::Window
                }
            };
            while batch.len() < max_batch {
                let now = Instant::now();
                if now >= close {
                    reason = expiry_reason(close);
                    break;
                }
                match q.q.pop_front() {
                    Some(r) => {
                        if let Some(d) = r.deadline {
                            if d <= now {
                                self.deliver_err(
                                    &r,
                                    ServeError::DeadlineExceeded {
                                        missed_by: now.duration_since(d),
                                    },
                                );
                                continue;
                            }
                            close = close.min(d);
                        }
                        batch.push(r);
                    }
                    None => {
                        if self.shutdown.load(Ordering::Relaxed) {
                            reason = BatchCloseReason::Shutdown;
                            break;
                        }
                        let (g, timeout) = self
                            .work
                            .wait_timeout(q, close.duration_since(now))
                            .unwrap_or_else(PoisonError::into_inner);
                        q = g;
                        if timeout.timed_out() && q.q.is_empty() {
                            reason = expiry_reason(close);
                            break;
                        }
                    }
                }
            }
            if batch.len() >= max_batch {
                reason = BatchCloseReason::Full;
            }
            q.q.len()
        };
        self.space.notify_all();
        self.metrics.set_queue_depth(depth_after);
        Some((batch, reason))
    }

    /// Remove and typed-fail every queued request whose deadline passed
    /// (supervisor sweep: catches requests stuck behind stalled shards).
    pub(crate) fn sweep_expired_queue(&self, now: Instant) {
        let mut expired = Vec::new();
        let depth = {
            let mut q = lock_recover(&self.queue);
            let mut i = 0;
            while i < q.q.len() {
                if q.q[i].deadline.is_some_and(|d| d <= now) {
                    if let Some(r) = q.q.remove(i) {
                        expired.push(r);
                        continue;
                    }
                }
                i += 1;
            }
            q.q.len()
        };
        if expired.is_empty() {
            return;
        }
        self.metrics.set_queue_depth(depth);
        self.space.notify_all();
        for r in expired {
            let d = r.deadline.expect("swept requests carry deadlines");
            self.deliver_err(&r, ServeError::DeadlineExceeded { missed_by: now.duration_since(d) });
        }
    }

    /// Typed-fail expired deadline-bearing requests currently in-flight on
    /// a (possibly stalled) shard. The worker's own later scatter to the
    /// same channel is harmless — the caller has already consumed this.
    pub(crate) fn sweep_expired_inflight(&self, now: Instant) {
        for shard in &self.shards {
            let mut expired = Vec::new();
            {
                let mut inf = lock_recover(&shard.inflight);
                let mut i = 0;
                while i < inf.len() {
                    if inf[i].deadline.is_some_and(|d| d <= now) {
                        expired.push(inf.swap_remove(i));
                        continue;
                    }
                    i += 1;
                }
            }
            for e in expired {
                let d = e.deadline.expect("filtered on deadline");
                self.deliver_err_to(
                    &e.resp,
                    ServeError::DeadlineExceeded { missed_by: now.duration_since(d) },
                );
            }
        }
    }

    /// Drain the whole queue, failing every request with `err` — used
    /// when no shard can ever serve again, and at shutdown for leftovers.
    pub(crate) fn fail_queue(&self, err: &ServeError) {
        let drained: Vec<Request> = {
            let mut q = lock_recover(&self.queue);
            q.q.drain(..).collect()
        };
        if drained.is_empty() {
            return;
        }
        self.metrics.set_queue_depth(0);
        self.space.notify_all();
        for r in drained {
            self.deliver_err(&r, err.clone());
        }
    }

    /// Typed-fail whatever shard `idx` still has registered in flight
    /// (used when an unresponsive worker is abandoned at shutdown).
    pub(crate) fn fail_inflight(&self, idx: usize, err: &ServeError) {
        let drained: Vec<InflightEntry> =
            lock_recover(&self.shards[idx].inflight).drain(..).collect();
        for e in drained {
            self.deliver_err_to(&e.resp, err.clone());
        }
    }

    pub(crate) fn begin_close(&self) {
        self.shutdown.store(true, Ordering::SeqCst);
        lock_recover(&self.queue).open = false;
        self.work.notify_all();
        self.space.notify_all();
    }
}

/// Extract a human-readable message from a panic payload.
pub(crate) fn panic_message(payload: &(dyn std::any::Any + Send)) -> String {
    if let Some(s) = payload.downcast_ref::<&str>() {
        (*s).to_string()
    } else if let Some(s) = payload.downcast_ref::<String>() {
        s.clone()
    } else {
        "panic payload of unknown type".to_string()
    }
}

/// Spawn one shard worker: build the engine inside the thread (PJRT
/// handles are thread-affine and `!Send`), report readiness (startup
/// handshake) or phase (restart), then serve drained batches until
/// shutdown or an engine panic. Used both at startup and by the
/// supervisor for restarts.
pub(crate) fn spawn_worker(
    shared: Arc<ServerShared>,
    factory: Arc<EngineFactory>,
    idx: usize,
    ready: Option<mpsc::Sender<Result<(usize, usize)>>>,
) -> std::io::Result<std::thread::JoinHandle<()>> {
    std::thread::Builder::new().name(format!("qonnx-shard-{idx}")).spawn(move || {
        // budget this shard's intra-op fan-out so that across all shards
        // the pool is not oversubscribed
        let shards = shared.shards.len();
        let budget = shared
            .cfg
            .intraop_threads
            .unwrap_or_else(|| (crate::runtime::pool::global().threads() / shards).max(1));
        crate::runtime::pool::set_thread_intraop_limit(budget);
        if let Some(t) = &shared.cfg.trace {
            // name this shard's track before any event lands on it
            t.register_current_thread();
        }
        let mut engine = match factory() {
            Ok(e) => e,
            Err(e) => {
                let reason = format!("engine factory failed: {e:#}");
                supervisor::set_phase(&shared.shards[idx], ShardPhase::Dead { reason });
                if let Some(tx) = ready {
                    let _ = tx.send(Err(e));
                }
                return;
            }
        };
        let in_dim = engine.input_dim();
        let out_dim = engine.output_dim();
        if !shared.claim_dims(in_dim, out_dim) {
            let reason = format!(
                "engine reports dims {in_dim}/{out_dim}, server advertises {}/{}",
                shared.expect_in.load(Ordering::SeqCst),
                shared.expect_out.load(Ordering::SeqCst)
            );
            supervisor::set_phase(&shared.shards[idx], ShardPhase::Dead { reason: reason.clone() });
            if let Some(tx) = ready {
                let _ = tx.send(Err(anyhow!("{reason}")));
            }
            return;
        }
        supervisor::set_phase(&shared.shards[idx], ShardPhase::Live);
        if let Some(tx) = ready {
            let _ = tx.send(Ok((in_dim, out_dim)));
        }
        let max_batch = engine.max_batch().min(1024);
        loop {
            let Some((batch, close)) = shared.drain_batch(max_batch) else {
                return; // shutdown with an empty queue
            };
            if serve_batch(&shared, idx, engine.as_mut(), in_dim, out_dim, batch, close) {
                return; // engine panicked; the supervisor takes over
            }
        }
    })
}

/// Fuse, execute (unlocked — shards overlap), scatter. Every request in
/// the batch gets a definitive response on every path: rows on success,
/// typed [`ServeError`]s on engine error, invalid output, or panic.
/// Returns `true` when the worker must die (engine panicked).
fn serve_batch(
    shared: &ServerShared,
    idx: usize,
    engine: &mut dyn InferenceEngine,
    in_dim: usize,
    out_dim: usize,
    batch: Vec<Request>,
    close: BatchCloseReason,
) -> bool {
    let n = batch.len();
    let trace = shared.cfg.trace.as_deref();
    if let Some(t) = trace {
        // queue-wait per request, placed on this shard's track as a
        // complete event spanning submit → drain
        let now = t.now_ns();
        for r in &batch {
            let start = t.ns_since_epoch(r.enqueued);
            t.complete("request", "queued", start, now.saturating_sub(start), &[]);
        }
    }
    let _batch_span = trace.map(|t| {
        t.span("shard", format!("batch:{}", close.label()), &[("batch_size", n as i64)])
    });
    let mut data = Vec::with_capacity(n * in_dim);
    for r in &batch {
        data.extend_from_slice(&r.input);
    }
    // register the in-flight batch so the supervisor can typed-fail it
    // (deadline sweep over a stalled engine, shutdown of an abandoned
    // worker) instead of leaving callers on a hung recv
    {
        let mut inf = lock_recover(&shared.shards[idx].inflight);
        inf.clear();
        inf.extend(
            batch.iter().map(|r| InflightEntry { deadline: r.deadline, resp: r.resp.clone() }),
        );
    }
    let input = Tensor::new(vec![n, in_dim], data);
    // the execute span lives inside the unwind scope: a panicking
    // engine drops the guard during unwinding, so spans stay balanced
    // even on the paths the supervisor has to clean up
    let result = catch_unwind(AssertUnwindSafe(|| {
        let _exec_span = trace.map(|t| t.span("shard", "execute", &[]));
        engine.infer_batch(&input)
    }));
    lock_recover(&shared.shards[idx].inflight).clear();
    shared.stats.batches.fetch_add(1, Ordering::Relaxed);
    shared.metrics.observe_batch(n, close);
    let _scatter_span = trace.map(|t| t.span("shard", "scatter", &[]));
    match result {
        Ok(Ok(y)) => {
            match y.as_f32() {
                Ok(rows) if rows.len() == n * out_dim => {
                    for (i, req) in batch.iter().enumerate() {
                        shared.deliver_ok(req, rows[i * out_dim..(i + 1) * out_dim].to_vec());
                    }
                }
                _ => {
                    // a non-f32 (or mis-sized) engine output fails THIS
                    // batch's requests, not the shard
                    shared.metrics.inc_engine_error();
                    let message = format!(
                        "engine produced invalid output: dtype {} shape {:?} (want [{n}, {out_dim}] f32)",
                        y.dtype(),
                        y.shape()
                    );
                    for req in &batch {
                        shared.deliver_err(req, ServeError::Engine { message: message.clone() });
                    }
                }
            }
            false
        }
        Ok(Err(e)) => {
            shared.metrics.inc_engine_error();
            let message = format!("{e:#}");
            for req in &batch {
                shared.deliver_err(req, ServeError::Engine { message: message.clone() });
            }
            false
        }
        Err(payload) => {
            let message = panic_message(payload.as_ref());
            shared.metrics.inc_shard_panic();
            for req in &batch {
                shared.deliver_err(req, ServeError::ShardPanicked { message: message.clone() });
            }
            supervisor::set_phase(
                &shared.shards[idx],
                ShardPhase::Dead { reason: format!("engine panicked: {message}") },
            );
            true
        }
    }
}

/// Handle to one submitted request's pending result.
pub struct Response {
    rx: mpsc::Receiver<Result<Vec<f32>, ServeError>>,
    deadline: Option<Instant>,
}

impl Response {
    /// Block for the result. When the request carries a deadline, the
    /// wait is bounded by it (client-side enforcement — even a wedged
    /// server cannot hold the caller past its deadline); `missed_by` is
    /// zero for a client-side timeout, positive when the server itself
    /// dropped the expired request.
    pub fn wait(self) -> Result<Vec<f32>, ServeError> {
        match self.deadline {
            None => self.rx.recv().unwrap_or(Err(ServeError::ChannelClosed)),
            Some(d) => {
                let now = Instant::now();
                if now >= d {
                    // already past deadline: one non-blocking look for a
                    // result that raced in, then typed timeout
                    return match self.rx.try_recv() {
                        Ok(r) => r,
                        Err(_) => {
                            Err(ServeError::DeadlineExceeded { missed_by: Duration::ZERO })
                        }
                    };
                }
                match self.rx.recv_timeout(d.duration_since(now)) {
                    Ok(r) => r,
                    Err(mpsc::RecvTimeoutError::Timeout) => {
                        Err(ServeError::DeadlineExceeded { missed_by: Duration::ZERO })
                    }
                    Err(mpsc::RecvTimeoutError::Disconnected) => Err(ServeError::ChannelClosed),
                }
            }
        }
    }

    /// The raw receiver (no client-side deadline enforcement) — for
    /// callers that want to observe exactly what the server delivered.
    pub fn into_receiver(self) -> mpsc::Receiver<Result<Vec<f32>, ServeError>> {
        self.rx
    }
}

/// A running batching server around one or more [`InferenceEngine`]
/// worker shards, supervised for fault tolerance.
pub struct Batcher {
    shared: Arc<ServerShared>,
    in_dim: usize,
    out_dim: usize,
    supervisor: Option<std::thread::JoinHandle<()>>,
}

impl Batcher {
    /// Start a single worker thread. The engine is built *inside* the
    /// worker by `factory` (PJRT handles are thread-affine and `!Send`).
    pub fn start<F>(factory: F, cfg: BatcherConfig) -> Result<Batcher>
    where
        F: FnOnce() -> Result<Box<dyn InferenceEngine>> + Send + 'static,
    {
        // adapt the one-shot factory to the sharded (multi-call) shape;
        // a supervisor restart of this shard reports the factory spent
        let cell = Mutex::new(Some(factory));
        Batcher::start_sharded(
            move || {
                let f = lock_recover(&cell)
                    .take()
                    .ok_or_else(|| anyhow!("single-shot engine factory called twice"))?;
                f()
            },
            cfg,
            1,
        )
    }

    /// Start `shards` worker threads over ONE shared request queue. The
    /// factory runs once per worker, inside that worker's thread, and is
    /// RETAINED: the supervisor re-invokes it to restart a shard whose
    /// engine panicked. Engines that can share compiled state should hand
    /// out views of it (e.g. one [`super::PlannedEngine`] template
    /// `share()`d per call). A worker holds the queue lock only while
    /// draining its batch — inference runs unlocked, so shards execute
    /// concurrently.
    pub fn start_sharded<F>(factory: F, cfg: BatcherConfig, shards: usize) -> Result<Batcher>
    where
        F: Fn() -> Result<Box<dyn InferenceEngine>> + Send + Sync + 'static,
    {
        ensure!(shards >= 1, "need at least one batcher shard");
        let factory: Arc<EngineFactory> = Arc::new(factory);
        let shared = Arc::new(ServerShared {
            queue: Mutex::new(QueueState { q: VecDeque::new(), open: true }),
            work: Condvar::new(),
            space: Condvar::new(),
            cfg,
            shards: (0..shards).map(|_| ShardState::new()).collect(),
            metrics: Arc::new(ServingMetrics::new()),
            stats: Stats::default(),
            shutdown: AtomicBool::new(false),
            expect_in: AtomicUsize::new(0),
            expect_out: AtomicUsize::new(0),
        });
        let (ready_tx, ready_rx) = mpsc::channel::<Result<(usize, usize)>>();
        let mut handles = Vec::with_capacity(shards);
        for i in 0..shards {
            handles.push(
                spawn_worker(shared.clone(), factory.clone(), i, Some(ready_tx.clone()))
                    .expect("spawning batcher shard worker"),
            );
        }
        drop(ready_tx);
        // all shards must come up (engine built) before we serve
        let mut dims: Option<(usize, usize)> = None;
        let mut startup_err: Option<anyhow::Error> = None;
        for _ in 0..shards {
            match ready_rx.recv() {
                Ok(Ok(d)) => match dims {
                    None => dims = Some(d),
                    Some(prev) if prev != d => {
                        startup_err =
                            Some(anyhow!("shard engines disagree on dims: {prev:?} vs {d:?}"));
                        break;
                    }
                    Some(_) => {}
                },
                Ok(Err(e)) => {
                    startup_err = Some(e);
                    break;
                }
                // a worker died without reporting (factory panic)
                Err(_) => {
                    startup_err = Some(anyhow!("engine factory thread died"));
                    break;
                }
            }
        }
        if startup_err.is_none() && dims.is_none() {
            startup_err = Some(anyhow!("no shard reported dims"));
        }
        if let Some(e) = startup_err {
            // wind the healthy shards back down before reporting
            shared.begin_close();
            for h in handles {
                let _ = h.join();
            }
            return Err(e);
        }
        let (in_dim, out_dim) = dims.expect("checked above");
        let sup = supervisor::spawn(shared.clone(), factory, handles);
        Ok(Batcher { shared, in_dim, out_dim, supervisor: Some(sup) })
    }

    /// Input row length, as reported by the engine at startup.
    pub fn input_dim(&self) -> usize {
        self.in_dim
    }

    /// Output row length, as reported by the engine at startup.
    pub fn output_dim(&self) -> usize {
        self.out_dim
    }

    /// Submit one input row with default options (no deadline; shed
    /// immediately when the bounded queue is full).
    pub fn submit(&self, input: Vec<f32>) -> Result<Response, SubmitError> {
        self.submit_with(input, SubmitOptions::default())
    }

    /// Submit one input row; typed admission errors, optional deadline.
    pub fn submit_with(
        &self,
        input: Vec<f32>,
        opts: SubmitOptions,
    ) -> Result<Response, SubmitError> {
        if input.len() != self.in_dim {
            return Err(SubmitError::InvalidInput { got: input.len(), want: self.in_dim });
        }
        let h = self.health();
        if h.all_dead() {
            return Err(SubmitError::NoLiveShards);
        }
        if self.shared.cfg.supervisor.degraded == DegradedPolicy::RefuseWhenDegraded && h.dead > 0 {
            return Err(SubmitError::Degraded { live: h.live, shards: h.shards });
        }
        let now = Instant::now();
        let deadline = opts.deadline.map(|d| now + d);
        let give_up = opts.submit_timeout.map(|t| now + t);
        let (resp_tx, resp_rx) = mpsc::channel();
        let req = Request { input, enqueued: now, deadline, resp: resp_tx };
        let depth = {
            let mut q = lock_recover(&self.shared.queue);
            loop {
                if !q.open {
                    return Err(SubmitError::ShutDown);
                }
                match self.shared.cfg.queue_capacity {
                    Some(cap) if q.q.len() >= cap => {
                        let Some(until) = give_up else {
                            self.shared.metrics.inc_shed();
                            if let Some(t) = &self.shared.cfg.trace {
                                t.instant("request", "shed", &[("queue_depth", q.q.len() as i64)]);
                            }
                            return Err(SubmitError::Shed { queue_depth: q.q.len() });
                        };
                        let now = Instant::now();
                        if now >= until {
                            self.shared.metrics.inc_shed();
                            if let Some(t) = &self.shared.cfg.trace {
                                t.instant("request", "shed", &[("queue_depth", q.q.len() as i64)]);
                            }
                            return Err(SubmitError::Shed { queue_depth: q.q.len() });
                        }
                        let (g, _) = self
                            .shared
                            .space
                            .wait_timeout(q, until.duration_since(now))
                            .unwrap_or_else(PoisonError::into_inner);
                        q = g;
                    }
                    _ => {
                        q.q.push_back(req);
                        break q.q.len();
                    }
                }
            }
        };
        self.shared.metrics.set_queue_depth(depth);
        if let Some(t) = &self.shared.cfg.trace {
            t.instant("request", "admit", &[("queue_depth", depth as i64)]);
            t.counter("queue", "queue_depth", depth as i64);
        }
        self.shared.work.notify_one();
        Ok(Response { rx: resp_rx, deadline })
    }

    /// Blocking convenience: submit and wait (anyhow-typed for callers
    /// that don't match on the failure kind).
    pub fn infer(&self, input: Vec<f32>) -> Result<Vec<f32>> {
        let resp = self.submit(input).map_err(anyhow::Error::new)?;
        resp.wait().map_err(anyhow::Error::new)
    }

    pub fn stats(&self) -> ServerStats {
        ServerStats {
            requests: self.shared.stats.requests.load(Ordering::Relaxed),
            batches: self.shared.stats.batches.load(Ordering::Relaxed),
            total_latency_us: self.shared.stats.total_latency_us.load(Ordering::Relaxed),
            max_latency_us: self.shared.stats.max_latency_us.load(Ordering::Relaxed),
        }
    }

    /// Live/starting/dead shard counts and the cumulative restart total.
    pub fn health(&self) -> Health {
        supervisor::health_of(&self.shared.shards, self.shared.cfg.supervisor.max_restarts)
    }

    /// Serving counters / latency histogram (shared handle; scrapeable).
    pub fn metrics(&self) -> Arc<ServingMetrics> {
        self.shared.metrics.clone()
    }

    /// Text exposition of [`Batcher::metrics`] (Prometheus-style lines).
    pub fn metrics_text(&self) -> String {
        self.shared.metrics.render_text()
    }

    fn begin_shutdown(&mut self) {
        self.shared.begin_close();
        if let Some(h) = self.supervisor.take() {
            let _ = h.join();
        }
        // anything still queued (all shards dead, or raced the final
        // drain) gets a definitive typed response — never a hung recv
        self.shared.fail_queue(&ServeError::ShutDown);
    }

    /// Stop all worker shards and wait for them. Already-queued requests
    /// still drain through live shards; anything left (e.g. every shard
    /// dead) is failed with a typed [`ServeError::ShutDown`].
    pub fn shutdown(mut self) -> ServerStats {
        self.begin_shutdown();
        self.stats()
    }
}

impl Drop for Batcher {
    fn drop(&mut self) {
        self.begin_shutdown();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::ReferenceEngine;
    use crate::zoo::{tfc_batch, TfcParams};

    fn ref_engine() -> Result<Box<dyn InferenceEngine>> {
        let g = tfc_batch(&TfcParams::random(2, 2, 5), 1).unwrap();
        Ok(Box::new(ReferenceEngine::new(g)?))
    }

    /// Test engine: echoes its input back after an optional stall.
    struct SlowEcho {
        delay: Duration,
    }

    impl InferenceEngine for SlowEcho {
        fn name(&self) -> String {
            "slow-echo".into()
        }
        fn input_dim(&self) -> usize {
            4
        }
        fn output_dim(&self) -> usize {
            4
        }
        fn max_batch(&self) -> usize {
            usize::MAX
        }
        fn infer_batch(&mut self, batch: &Tensor) -> Result<Tensor> {
            if !self.delay.is_zero() {
                std::thread::sleep(self.delay);
            }
            Ok(batch.clone())
        }
    }

    fn echo(delay: Duration) -> impl Fn() -> Result<Box<dyn InferenceEngine>> + Send + Sync {
        move || Ok(Box::new(SlowEcho { delay }) as Box<dyn InferenceEngine>)
    }

    #[test]
    fn single_request_roundtrip() {
        let b = Batcher::start(ref_engine, BatcherConfig::default()).unwrap();
        let y = b.infer(vec![0.5; 784]).unwrap();
        assert_eq!(y.len(), 10);
        let stats = b.shutdown();
        assert_eq!(stats.requests, 1);
    }

    #[test]
    fn failing_factory_reported() {
        let r = Batcher::start(|| anyhow::bail!("no such artifact"), BatcherConfig::default());
        assert!(r.is_err());
    }

    #[test]
    fn concurrent_requests_get_batched() {
        let b = Arc::new(
            Batcher::start(
                ref_engine,
                BatcherConfig { max_wait: Duration::from_millis(20), ..Default::default() },
            )
            .unwrap(),
        );
        let mut handles = Vec::new();
        for i in 0..16 {
            let b = b.clone();
            handles
                .push(std::thread::spawn(move || b.infer(vec![i as f32 / 16.0; 784]).unwrap()));
        }
        for h in handles {
            assert_eq!(h.join().unwrap().len(), 10);
        }
        let stats = b.stats();
        assert_eq!(stats.requests, 16);
        assert!(stats.batches < 16, "no batching happened: {} batches", stats.batches);
        assert!(stats.mean_batch_occupancy() > 1.0);
    }

    #[test]
    fn batched_results_match_individual() {
        let mut solo = ref_engine().unwrap();
        let b = Batcher::start(ref_engine, BatcherConfig::default()).unwrap();
        let input: Vec<f32> = (0..784).map(|i| (i % 7) as f32 / 7.0).collect();
        let batched = b.infer(input.clone()).unwrap();
        let direct = solo.infer_batch(&Tensor::new(vec![1, 784], input)).unwrap();
        assert_eq!(batched, direct.as_f32().unwrap());
    }

    #[test]
    fn wrong_input_len_rejected() {
        let b = Batcher::start(ref_engine, BatcherConfig::default()).unwrap();
        assert_eq!(
            b.submit(vec![0.0; 3]).err(),
            Some(SubmitError::InvalidInput { got: 3, want: 784 })
        );
    }

    #[test]
    fn sharded_batcher_serves_concurrent_requests() {
        use crate::coordinator::PlannedEngine;
        let template = PlannedEngine::from_zoo("TFC-w2a2").unwrap();
        let mut direct = template.share();
        let b = Arc::new(
            Batcher::start_sharded(
                move || Ok(Box::new(template.share()) as Box<dyn InferenceEngine>),
                BatcherConfig { max_wait: Duration::from_millis(5), ..Default::default() },
                3,
            )
            .unwrap(),
        );
        let mut handles = Vec::new();
        for i in 0..24 {
            let b = b.clone();
            handles.push(std::thread::spawn(move || {
                let input: Vec<f32> = (0..784).map(|j| ((i + j) % 11) as f32 / 11.0).collect();
                (input.clone(), b.infer(input).unwrap())
            }));
        }
        for h in handles {
            let (input, served) = h.join().unwrap();
            let want = direct.infer_batch(&Tensor::new(vec![1, 784], input)).unwrap();
            assert_eq!(served, want.as_f32().unwrap(), "sharded result diverged");
        }
        assert_eq!(b.stats().requests, 24);
        let h = b.health();
        assert_eq!((h.shards, h.live, h.dead), (3, 3, 0));
        assert!(!h.degraded());
    }

    #[test]
    fn pinned_intraop_budget_still_serves() {
        // shards with an explicit 1-thread intra-op cap run the kernels
        // inline (no pool fan-out) and must produce identical rows
        let b = Batcher::start_sharded(
            ref_engine,
            BatcherConfig { intraop_threads: Some(1), ..Default::default() },
            2,
        )
        .unwrap();
        let mut solo = ref_engine().unwrap();
        let input: Vec<f32> = (0..784).map(|i| (i % 5) as f32 / 5.0).collect();
        let served = b.infer(input.clone()).unwrap();
        let want = solo.infer_batch(&Tensor::new(vec![1, 784], input)).unwrap();
        assert_eq!(served, want.as_f32().unwrap());
    }

    #[test]
    fn zero_shards_rejected() {
        let r =
            Batcher::start_sharded(|| anyhow::bail!("never called"), BatcherConfig::default(), 0);
        assert!(r.is_err());
    }

    #[test]
    fn bounded_queue_sheds_with_typed_error() {
        let b = Batcher::start_sharded(
            echo(Duration::from_millis(30)),
            BatcherConfig {
                max_wait: Duration::from_micros(100),
                queue_capacity: Some(2),
                ..Default::default()
            },
            1,
        )
        .unwrap();
        let mut pending = Vec::new();
        let mut shed = 0u64;
        for _ in 0..24 {
            match b.submit(vec![0.25; 4]) {
                Ok(r) => pending.push(r),
                Err(SubmitError::Shed { queue_depth }) => {
                    assert_eq!(queue_depth, 2, "shed must report the full queue's depth");
                    shed += 1;
                }
                Err(e) => panic!("unexpected admission error: {e}"),
            }
        }
        assert!(shed > 0, "24 instant submits against a 30ms engine must shed");
        let m = b.metrics();
        assert_eq!(m.shed(), shed);
        assert!(m.queue_depth_peak() <= 2, "depth peaked at {}", m.queue_depth_peak());
        // every ACCEPTED request still resolves
        for r in pending {
            assert_eq!(r.wait().unwrap(), vec![0.25; 4]);
        }
        b.shutdown();
    }

    #[test]
    fn submit_timeout_waits_for_space() {
        let b = Batcher::start_sharded(
            echo(Duration::from_millis(20)),
            BatcherConfig {
                max_wait: Duration::from_micros(100),
                queue_capacity: Some(2),
                ..Default::default()
            },
            1,
        )
        .unwrap();
        let mut pending = Vec::new();
        let mut saw_shed = false;
        for _ in 0..16 {
            match b.submit(vec![0.5; 4]) {
                Ok(r) => pending.push(r),
                Err(SubmitError::Shed { .. }) => {
                    saw_shed = true;
                    break;
                }
                Err(e) => panic!("unexpected admission error: {e}"),
            }
        }
        assert!(saw_shed, "queue never filled");
        // a caller willing to wait gets admitted once the worker drains
        let r = b
            .submit_with(
                vec![0.75; 4],
                SubmitOptions { submit_timeout: Some(Duration::from_secs(10)), ..Default::default() },
            )
            .expect("submit_timeout caller should be admitted when space frees");
        assert_eq!(r.wait().unwrap(), vec![0.75; 4]);
        for r in pending {
            assert_eq!(r.wait().unwrap(), vec![0.5; 4]);
        }
        b.shutdown();
    }

    #[test]
    fn expired_request_dropped_at_drain_with_typed_error() {
        // supervisor sweep effectively disabled (long tick): the typed
        // DeadlineExceeded must come from the worker's drain-time drop
        let b = Batcher::start_sharded(
            echo(Duration::from_millis(50)),
            BatcherConfig {
                max_wait: Duration::from_micros(100),
                supervisor: SupervisorConfig { tick: Duration::from_secs(30), ..Default::default() },
                ..Default::default()
            },
            1,
        )
        .unwrap();
        // occupy the worker, then queue a request that expires behind it
        let first = b.submit(vec![0.0; 4]).unwrap();
        std::thread::sleep(Duration::from_millis(10));
        let doomed = b
            .submit_with(
                vec![1.0; 4],
                SubmitOptions { deadline: Some(Duration::from_millis(1)), ..Default::default() },
            )
            .unwrap();
        // observe the server's own delivery (no client-side enforcement)
        let rx = doomed.into_receiver();
        match rx.recv_timeout(Duration::from_secs(10)).unwrap() {
            Err(ServeError::DeadlineExceeded { missed_by }) => {
                assert!(missed_by > Duration::ZERO, "drain-time drop reports real lateness");
            }
            other => panic!("expected server-side DeadlineExceeded, got {other:?}"),
        }
        assert_eq!(b.metrics().deadline_exceeded(), 1);
        assert_eq!(first.wait().unwrap(), vec![0.0; 4]);
        b.shutdown();
    }

    #[test]
    fn client_side_deadline_bounds_wait() {
        let b = Batcher::start_sharded(
            echo(Duration::from_millis(200)),
            BatcherConfig {
                supervisor: SupervisorConfig { tick: Duration::from_secs(30), ..Default::default() },
                ..Default::default()
            },
            1,
        )
        .unwrap();
        let t0 = Instant::now();
        let r = b
            .submit_with(
                vec![0.0; 4],
                SubmitOptions { deadline: Some(Duration::from_millis(20)), ..Default::default() },
            )
            .unwrap();
        match r.wait() {
            Err(ServeError::DeadlineExceeded { .. }) => {}
            other => panic!("expected DeadlineExceeded, got {other:?}"),
        }
        assert!(
            t0.elapsed() < Duration::from_millis(150),
            "caller was held past its deadline: {:?}",
            t0.elapsed()
        );
        b.shutdown();
    }

    #[test]
    fn poisoned_queue_lock_is_recovered() {
        let b = Batcher::start(echo(Duration::ZERO), BatcherConfig::default()).unwrap();
        assert_eq!(b.infer(vec![1.0; 4]).unwrap(), vec![1.0; 4]);
        // poison the queue mutex from a doomed thread
        let shared = b.shared.clone();
        let _ = std::thread::spawn(move || {
            let _guard = shared.queue.lock().unwrap();
            panic!("poisoning the batcher queue lock on purpose");
        })
        .join();
        assert!(b.shared.queue.is_poisoned());
        // submit and the worker both recover the lock and keep serving
        assert_eq!(b.infer(vec![2.0; 4]).unwrap(), vec![2.0; 4]);
        b.shutdown();
    }

    #[test]
    fn non_f32_engine_output_fails_batch_not_shard() {
        struct BadDtype;
        impl InferenceEngine for BadDtype {
            fn name(&self) -> String {
                "bad-dtype".into()
            }
            fn input_dim(&self) -> usize {
                4
            }
            fn output_dim(&self) -> usize {
                4
            }
            fn max_batch(&self) -> usize {
                usize::MAX
            }
            fn infer_batch(&mut self, batch: &Tensor) -> Result<Tensor> {
                let n = batch.shape()[0];
                Ok(Tensor::new_i8(vec![n, 4], vec![0; n * 4]))
            }
        }
        let b = Batcher::start(
            || Ok(Box::new(BadDtype) as Box<dyn InferenceEngine>),
            BatcherConfig::default(),
        )
        .unwrap();
        match b.submit(vec![0.0; 4]).unwrap().wait() {
            Err(ServeError::Engine { message }) => {
                assert!(message.contains("invalid output"), "{message}");
            }
            other => panic!("expected typed engine error, got {other:?}"),
        }
        // the shard survived the bad output and still serves (still
        // erroring, but typed — and alive)
        assert!(matches!(
            b.submit(vec![0.0; 4]).unwrap().wait(),
            Err(ServeError::Engine { .. })
        ));
        assert_eq!(b.health().live, 1);
        assert_eq!(b.metrics().engine_errors(), 2);
        b.shutdown();
    }

    #[test]
    fn batch_close_reasons_sum_to_batches() {
        let b = Batcher::start(echo(Duration::ZERO), BatcherConfig::default()).unwrap();
        b.infer(vec![1.0; 4]).unwrap();
        b.infer(vec![2.0; 4]).unwrap();
        let m = b.metrics();
        let total: u64 =
            BatchCloseReason::ALL.iter().map(|&r| m.batch_closes(r)).sum();
        assert_eq!(total, m.batches(), "every batch carries exactly one close reason");
        assert_eq!(m.batch_size().count(), m.batches());
        b.shutdown();
    }

    #[test]
    fn lifecycle_trace_has_admit_queued_and_balanced_spans() {
        let rec = Arc::new(TraceRecorder::new(1024));
        let b = Batcher::start(
            echo(Duration::ZERO),
            BatcherConfig { trace: Some(rec.clone()), ..Default::default() },
        )
        .unwrap();
        assert_eq!(b.infer(vec![0.5; 4]).unwrap(), vec![0.5; 4]);
        b.shutdown();
        let dump = rec.drain();
        let (mut saw_admit, mut saw_queued, mut saw_batch, mut saw_exec) =
            (false, false, false, false);
        for t in &dump {
            let mut depth = 0i64;
            for e in &t.events {
                match e.kind {
                    crate::trace::EventKind::SpanBegin => depth += 1,
                    crate::trace::EventKind::SpanEnd => depth -= 1,
                    _ => {}
                }
                assert!(depth >= 0, "span End before Begin on {}", t.thread_name);
                saw_admit |= e.name == "admit";
                saw_queued |= e.name == "queued";
                saw_batch |= e.name.starts_with("batch:");
                saw_exec |= e.name == "execute";
            }
            assert_eq!(depth, 0, "unbalanced spans on {}", t.thread_name);
        }
        assert!(saw_admit, "missing admission instant");
        assert!(saw_queued, "missing queue-wait event");
        assert!(saw_batch, "missing batch-form span");
        assert!(saw_exec, "missing execute span");
    }

    #[test]
    fn shutdown_drains_queued_requests_definitively() {
        let b = Batcher::start_sharded(
            echo(Duration::from_millis(15)),
            BatcherConfig { max_wait: Duration::from_micros(100), ..Default::default() },
            1,
        )
        .unwrap();
        let pending: Vec<Response> =
            (0..6).map(|_| b.submit(vec![0.125; 4]).unwrap()).collect();
        let stats = b.shutdown();
        assert!(stats.requests >= 6, "all queued requests counted: {}", stats.requests);
        for r in pending {
            match r.wait() {
                Ok(row) => assert_eq!(row, vec![0.125; 4]),
                Err(ServeError::ShutDown) => {}
                other => panic!("queued request got non-definitive response: {other:?}"),
            }
        }
    }
}

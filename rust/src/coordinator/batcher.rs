//! Dynamic micro-batching request loop, optionally sharded.
//!
//! Requests enter an mpsc queue; a worker drains up to
//! `engine.max_batch()` of them or waits at most `max_wait` for stragglers
//! (size-or-deadline triggering, the standard serving-batcher policy),
//! executes one fused inference, and scatters the rows back to per-request
//! channels. Latency and batch-occupancy stats are recorded for the bench
//! harness.
//!
//! [`Batcher::start_sharded`] runs N such workers over ONE shared queue:
//! each worker holds the queue lock only while *draining* its batch and
//! releases it before running inference, so shards overlap compute.
//! Engines built from a shared template (e.g.
//! [`super::PlannedEngine::share`]) make every shard serve the same
//! `Arc`'d compiled plan — packed weights resident once, one
//! scratch arena per worker.

use super::engine::InferenceEngine;
use crate::tensor::Tensor;
use anyhow::{anyhow, ensure, Result};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{mpsc, Arc, Mutex};
use std::time::{Duration, Instant};

/// Batcher tuning.
#[derive(Debug, Clone)]
pub struct BatcherConfig {
    /// max time the first request of a batch waits for company
    pub max_wait: Duration,
    /// per-shard intra-op thread cap for the shared kernel pool
    /// ([`crate::runtime::pool`]). `None` divides the pool evenly:
    /// `(pool threads / shards).max(1)`, so shards × intra-op ≤ cores —
    /// request-parallelism is traded against per-request parallelism
    /// instead of oversubscribing.
    pub intraop_threads: Option<usize>,
}

impl Default for BatcherConfig {
    fn default() -> Self {
        BatcherConfig { max_wait: Duration::from_millis(2), intraop_threads: None }
    }
}

struct Request {
    input: Vec<f32>,
    enqueued: Instant,
    resp: mpsc::Sender<Result<Vec<f32>>>,
}

/// Aggregated serving statistics.
#[derive(Debug, Default, Clone)]
pub struct ServerStats {
    pub requests: u64,
    pub batches: u64,
    pub total_latency_us: u64,
    pub max_latency_us: u64,
}

impl ServerStats {
    pub fn mean_latency_us(&self) -> f64 {
        if self.requests == 0 {
            0.0
        } else {
            self.total_latency_us as f64 / self.requests as f64
        }
    }

    pub fn mean_batch_occupancy(&self) -> f64 {
        if self.batches == 0 {
            0.0
        } else {
            self.requests as f64 / self.batches as f64
        }
    }
}

/// A running batching server around one or more [`InferenceEngine`]
/// worker shards.
pub struct Batcher {
    /// `None` once shutdown began — dropping the sender disconnects the
    /// queue so every idle shard wakes immediately instead of each
    /// burning a 50 ms poll in turn.
    tx: Option<mpsc::Sender<Request>>,
    in_dim: usize,
    out_dim: usize,
    stats: Arc<Stats>,
    workers: Vec<std::thread::JoinHandle<()>>,
    shutdown: Arc<AtomicBool>,
}

#[derive(Default)]
struct Stats {
    requests: AtomicU64,
    batches: AtomicU64,
    total_latency_us: AtomicU64,
    max_latency_us: AtomicU64,
}

impl Batcher {
    /// Start a single worker thread. The engine is built *inside* the
    /// worker by `factory` (PJRT handles are thread-affine and `!Send`).
    pub fn start<F>(factory: F, cfg: BatcherConfig) -> Result<Batcher>
    where
        F: FnOnce() -> Result<Box<dyn InferenceEngine>> + Send + 'static,
    {
        // adapt the one-shot factory to the sharded (multi-call) shape
        let cell = Mutex::new(Some(factory));
        Batcher::start_sharded(
            move || {
                let f = cell
                    .lock()
                    .unwrap()
                    .take()
                    .ok_or_else(|| anyhow!("single-shot engine factory called twice"))?;
                f()
            },
            cfg,
            1,
        )
    }

    /// Start `shards` worker threads over ONE shared request queue. The
    /// factory runs once per worker, inside that worker's thread; engines
    /// that can share compiled state should hand out views of it (e.g.
    /// one [`super::PlannedEngine`] template `share()`d per shard, so all
    /// workers serve the same `Arc`'d plan). A worker holds the queue
    /// lock only while draining its batch — inference runs unlocked, so
    /// shards execute concurrently.
    pub fn start_sharded<F>(factory: F, cfg: BatcherConfig, shards: usize) -> Result<Batcher>
    where
        F: Fn() -> Result<Box<dyn InferenceEngine>> + Send + Sync + 'static,
    {
        ensure!(shards >= 1, "need at least one batcher shard");
        let (tx, rx) = mpsc::channel::<Request>();
        let rx = Arc::new(Mutex::new(rx));
        let factory = Arc::new(factory);
        let stats: Arc<Stats> = Arc::default();
        let shutdown = Arc::new(AtomicBool::new(false));
        let (ready_tx, ready_rx) = mpsc::channel::<Result<(usize, usize)>>();
        let mut workers = Vec::with_capacity(shards);
        for _ in 0..shards {
            let factory = factory.clone();
            let rx = rx.clone();
            let cfg = cfg.clone();
            let ready_tx = ready_tx.clone();
            let worker_stats = stats.clone();
            let worker_shutdown = shutdown.clone();
            workers.push(std::thread::spawn(move || {
                // budget this shard's intra-op fan-out so that across all
                // shards the pool is not oversubscribed
                let budget = cfg.intraop_threads.unwrap_or_else(|| {
                    (crate::runtime::pool::global().threads() / shards).max(1)
                });
                crate::runtime::pool::set_thread_intraop_limit(budget);
                let mut engine = match factory() {
                    Ok(e) => {
                        let _ = ready_tx.send(Ok((e.input_dim(), e.output_dim())));
                        e
                    }
                    Err(e) => {
                        let _ = ready_tx.send(Err(e));
                        return;
                    }
                };
                // release the handshake sender now: if another shard dies
                // (factory panic) the channel disconnects once the healthy
                // shards have reported, instead of blocking startup forever
                drop(ready_tx);
                let in_dim = engine.input_dim();
                let out_dim = engine.output_dim();
                let max_batch = engine.max_batch().min(1024);
                loop {
                    // take the queue, block for the first request (with a
                    // poll so shutdown works), drain the batch, release
                    let batch = {
                        let rx = rx.lock().unwrap();
                        let first = match rx.recv_timeout(Duration::from_millis(50)) {
                            Ok(r) => r,
                            Err(mpsc::RecvTimeoutError::Timeout) => {
                                drop(rx);
                                if worker_shutdown.load(Ordering::Relaxed) {
                                    return;
                                }
                                continue;
                            }
                            Err(mpsc::RecvTimeoutError::Disconnected) => return,
                        };
                        let mut batch = vec![first];
                        let deadline = Instant::now() + cfg.max_wait;
                        while batch.len() < max_batch {
                            let now = Instant::now();
                            if now >= deadline {
                                break;
                            }
                            match rx.recv_timeout(deadline - now) {
                                Ok(r) => batch.push(r),
                                Err(_) => break,
                            }
                        }
                        batch
                    };
                    // fuse, execute (unlocked — shards overlap), scatter
                    let n = batch.len();
                    let mut data = Vec::with_capacity(n * in_dim);
                    for r in &batch {
                        data.extend_from_slice(&r.input);
                    }
                    let result = engine.infer_batch(&Tensor::new(vec![n, in_dim], data));
                    worker_stats.batches.fetch_add(1, Ordering::Relaxed);
                    match result {
                        Ok(y) => {
                            let rows = y.as_f32().expect("engine output must be f32");
                            for (i, req) in batch.into_iter().enumerate() {
                                let lat = req.enqueued.elapsed().as_micros() as u64;
                                worker_stats.requests.fetch_add(1, Ordering::Relaxed);
                                worker_stats.total_latency_us.fetch_add(lat, Ordering::Relaxed);
                                worker_stats.max_latency_us.fetch_max(lat, Ordering::Relaxed);
                                let row = rows[i * out_dim..(i + 1) * out_dim].to_vec();
                                let _ = req.resp.send(Ok(row));
                            }
                        }
                        Err(e) => {
                            let msg = format!("{e:#}");
                            for req in batch {
                                worker_stats.requests.fetch_add(1, Ordering::Relaxed);
                                let _ = req.resp.send(Err(anyhow!("{msg}")));
                            }
                        }
                    }
                }
            }));
        }
        drop(ready_tx);
        // all shards must come up (engine built) before we serve
        let mut dims: Option<(usize, usize)> = None;
        for _ in 0..shards {
            let d = ready_rx
                .recv()
                .map_err(|_| anyhow!("engine factory thread died"))??;
            match dims {
                None => dims = Some(d),
                Some(prev) => {
                    ensure!(prev == d, "shard engines disagree on dims: {prev:?} vs {d:?}")
                }
            }
        }
        let (in_dim, out_dim) = dims.expect("shards >= 1");
        Ok(Batcher { tx: Some(tx), in_dim, out_dim, stats, workers, shutdown })
    }

    /// Input row length, as reported by the engine at startup.
    pub fn input_dim(&self) -> usize {
        self.in_dim
    }

    /// Output row length, as reported by the engine at startup.
    pub fn output_dim(&self) -> usize {
        self.out_dim
    }

    /// Submit one input row; returns a receiver for the output row.
    pub fn submit(&self, input: Vec<f32>) -> Result<mpsc::Receiver<Result<Vec<f32>>>> {
        ensure!(input.len() == self.in_dim, "input length {} != {}", input.len(), self.in_dim);
        let (resp_tx, resp_rx) = mpsc::channel();
        self.tx
            .as_ref()
            .ok_or_else(|| anyhow!("server is shut down"))?
            .send(Request { input, enqueued: Instant::now(), resp: resp_tx })
            .map_err(|_| anyhow!("server is shut down"))?;
        Ok(resp_rx)
    }

    /// Blocking convenience: submit and wait.
    pub fn infer(&self, input: Vec<f32>) -> Result<Vec<f32>> {
        self.submit(input)?.recv()?
    }

    pub fn stats(&self) -> ServerStats {
        ServerStats {
            requests: self.stats.requests.load(Ordering::Relaxed),
            batches: self.stats.batches.load(Ordering::Relaxed),
            total_latency_us: self.stats.total_latency_us.load(Ordering::Relaxed),
            max_latency_us: self.stats.max_latency_us.load(Ordering::Relaxed),
        }
    }

    /// Stop all worker shards and wait for them. Already-queued requests
    /// still drain (disconnect only fires on an empty queue); idle
    /// shards wake immediately.
    pub fn shutdown(mut self) -> ServerStats {
        self.shutdown.store(true, Ordering::Relaxed);
        self.tx = None; // disconnect the queue
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
        self.stats()
    }
}

impl Drop for Batcher {
    fn drop(&mut self) {
        self.shutdown.store(true, Ordering::Relaxed);
        self.tx = None; // disconnect the queue
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::ReferenceEngine;
    use crate::zoo::{tfc_batch, TfcParams};

    fn ref_engine() -> Result<Box<dyn InferenceEngine>> {
        let g = tfc_batch(&TfcParams::random(2, 2, 5), 1).unwrap();
        Ok(Box::new(ReferenceEngine::new(g)?))
    }

    #[test]
    fn single_request_roundtrip() {
        let b = Batcher::start(ref_engine, BatcherConfig::default()).unwrap();
        let y = b.infer(vec![0.5; 784]).unwrap();
        assert_eq!(y.len(), 10);
        let stats = b.shutdown();
        assert_eq!(stats.requests, 1);
    }

    #[test]
    fn failing_factory_reported() {
        let r = Batcher::start(
            || anyhow::bail!("no such artifact"),
            BatcherConfig::default(),
        );
        assert!(r.is_err());
    }

    #[test]
    fn concurrent_requests_get_batched() {
        let b = Arc::new(
            Batcher::start(
                ref_engine,
                BatcherConfig { max_wait: Duration::from_millis(20), ..Default::default() },
            )
            .unwrap(),
        );
        let mut handles = Vec::new();
        for i in 0..16 {
            let b = b.clone();
            handles.push(std::thread::spawn(move || {
                b.infer(vec![i as f32 / 16.0; 784]).unwrap()
            }));
        }
        for h in handles {
            assert_eq!(h.join().unwrap().len(), 10);
        }
        let stats = b.stats();
        assert_eq!(stats.requests, 16);
        assert!(stats.batches < 16, "no batching happened: {} batches", stats.batches);
        assert!(stats.mean_batch_occupancy() > 1.0);
    }

    #[test]
    fn batched_results_match_individual() {
        let mut solo = ref_engine().unwrap();
        let b = Batcher::start(ref_engine, BatcherConfig::default()).unwrap();
        let input: Vec<f32> = (0..784).map(|i| (i % 7) as f32 / 7.0).collect();
        let batched = b.infer(input.clone()).unwrap();
        let direct = solo.infer_batch(&Tensor::new(vec![1, 784], input)).unwrap();
        assert_eq!(batched, direct.as_f32().unwrap());
    }

    #[test]
    fn wrong_input_len_rejected() {
        let b = Batcher::start(ref_engine, BatcherConfig::default()).unwrap();
        assert!(b.submit(vec![0.0; 3]).is_err());
    }

    #[test]
    fn sharded_batcher_serves_concurrent_requests() {
        use crate::coordinator::PlannedEngine;
        let template = PlannedEngine::from_zoo("TFC-w2a2").unwrap();
        let mut direct = template.share();
        let b = Arc::new(
            Batcher::start_sharded(
                move || Ok(Box::new(template.share()) as Box<dyn InferenceEngine>),
                BatcherConfig { max_wait: Duration::from_millis(5), ..Default::default() },
                3,
            )
            .unwrap(),
        );
        let mut handles = Vec::new();
        for i in 0..24 {
            let b = b.clone();
            handles.push(std::thread::spawn(move || {
                let input: Vec<f32> = (0..784).map(|j| ((i + j) % 11) as f32 / 11.0).collect();
                (input.clone(), b.infer(input).unwrap())
            }));
        }
        for h in handles {
            let (input, served) = h.join().unwrap();
            let want = direct.infer_batch(&Tensor::new(vec![1, 784], input)).unwrap();
            assert_eq!(served, want.as_f32().unwrap(), "sharded result diverged");
        }
        assert_eq!(b.stats().requests, 24);
    }

    #[test]
    fn pinned_intraop_budget_still_serves() {
        // shards with an explicit 1-thread intra-op cap run the kernels
        // inline (no pool fan-out) and must produce identical rows
        let b = Batcher::start_sharded(
            ref_engine,
            BatcherConfig { intraop_threads: Some(1), ..Default::default() },
            2,
        )
        .unwrap();
        let mut solo = ref_engine().unwrap();
        let input: Vec<f32> = (0..784).map(|i| (i % 5) as f32 / 5.0).collect();
        let served = b.infer(input.clone()).unwrap();
        let want = solo.infer_batch(&Tensor::new(vec![1, 784], input)).unwrap();
        assert_eq!(served, want.as_f32().unwrap());
    }

    #[test]
    fn zero_shards_rejected() {
        let r = Batcher::start_sharded(
            || anyhow::bail!("never called"),
            BatcherConfig::default(),
            0,
        );
        assert!(r.is_err());
    }
}

//! Inference engines behind one trait: the PJRT hot path, the compiled
//! [`crate::plan::ExecutionPlan`] native path, and the name-keyed
//! interpreter verification path. Any of them can be wrapped in
//! [`super::FaultyEngine`] to inject deterministic errors/panics/stalls
//! for robustness testing.
//!
//! `infer_batch` failures are contract events, not process events: the
//! batcher converts an `Err` into typed per-request failures and a panic
//! into a supervised shard restart, so engines should return `Err` for
//! anything recoverable and reserve panics for genuine bugs.

use crate::exec;
use crate::ir::ModelGraph;
use crate::plan::artifact::{self, AdapterMeta, EngineMeta, LoadedArtifact};
use crate::plan::{ExecutionPlan, RunConfig, ScratchArena, ShapeCheck};
use crate::runtime::{ArtifactMeta, CompiledModel, PjrtRuntime};
use crate::tensor::Tensor;
use anyhow::{bail, ensure, Context, Result};
use std::collections::BTreeMap;
use std::path::Path;
use std::sync::Arc;

/// A model that maps a `[n, in_dim]` batch to `[n, out_dim]` outputs.
///
/// Not `Send`: PJRT executables hold thread-affine handles, so the
/// [`super::Batcher`] constructs its engine *inside* the worker thread via
/// a factory closure.
pub trait InferenceEngine {
    fn name(&self) -> String;
    fn input_dim(&self) -> usize;
    fn output_dim(&self) -> usize;
    /// Largest batch the engine can take in one call (PJRT artifacts have
    /// a fixed compiled batch; the batcher pads up to it).
    fn max_batch(&self) -> usize;
    fn infer_batch(&mut self, batch: &Tensor) -> Result<Tensor>;
}

/// PJRT-compiled artifact engine (fixed batch; pads internally).
pub struct PjrtEngine {
    model: CompiledModel,
    meta: ArtifactMeta,
}

impl PjrtEngine {
    /// Load `<stem>.hlo.txt` / `<stem>.meta.json`, compile, and self-check
    /// against the build-time probe vector.
    pub fn load(rt: &PjrtRuntime, stem: &Path) -> Result<PjrtEngine> {
        let (model, meta) = rt.load_artifact(stem)?;
        let err = model.self_check(&meta)?;
        ensure!(err < 1e-3, "artifact {:?} failed its probe self-check ({err})", stem);
        Ok(PjrtEngine { model, meta })
    }
}

impl InferenceEngine for PjrtEngine {
    fn name(&self) -> String {
        format!("pjrt:{}", self.meta.name)
    }

    fn input_dim(&self) -> usize {
        self.meta.input_shape[1]
    }

    fn output_dim(&self) -> usize {
        self.meta.output_shape[1]
    }

    fn max_batch(&self) -> usize {
        self.meta.batch
    }

    fn infer_batch(&mut self, batch: &Tensor) -> Result<Tensor> {
        let n = batch.shape()[0];
        ensure!(n <= self.meta.batch, "batch {n} exceeds compiled batch {}", self.meta.batch);
        let dim = self.input_dim();
        let out_dim = self.output_dim();
        if n == self.meta.batch {
            return self.model.execute(batch);
        }
        // pad to the compiled batch, slice the result back
        let mut padded = vec![0f32; self.meta.batch * dim];
        padded[..n * dim].copy_from_slice(batch.as_f32()?);
        let y = self.model.execute(&Tensor::new(vec![self.meta.batch, dim], padded))?;
        let data = y.as_f32()?[..n * out_dim].to_vec();
        Ok(Tensor::new(vec![n, out_dim], data))
    }
}

/// How the engine's flat `[n, in_dim]` request rows map onto the graph's
/// declared input.
#[derive(Clone, Copy)]
enum EdgeAdapter {
    /// `[n, in_dim]` graphs: the batch tensor binds directly.
    Dense,
    /// NCHW graphs (`[_, c, h, w]` input, e.g. CNV): the whole `[n,
    /// in_dim]` request batch is re-viewed as one `[n, c, h, w]` tensor
    /// and executed in ONE plan invocation — the batch-symbolic compile
    /// pass made the plan's reshape targets batch-preserving, so no
    /// per-sample loop runs at the edge.
    Nchw { c: usize, h: usize, w: usize },
}

/// Compiled-plan engine over a QONNX graph (any batch size).
///
/// Compiles the graph **once** into an owned, `Arc`-shared
/// [`ExecutionPlan`] — weights `Arc`-resident and prepacked, weight-quant
/// subgraphs folded at compile time, slot arena sized — then serves every
/// request (any batch) against that plan with zero per-call graph work.
/// A persistent [`ScratchArena`] carries kernel scratch and recycled
/// intermediate buffers across requests. This is the native serving path
/// when no PJRT artifact is present. Dense `[n, dim]` graphs batch
/// directly; NCHW graphs (CNV) bind the request batch as one
/// `[n, c, h, w]` tensor (native batched execution — the plan is
/// batch-symbolic, see [`crate::plan`] module docs).
///
/// **Batchability probe:** construction fails loudly when the plan
/// reports [`ExecutionPlan::batch_blockers`] — a constant reshape target
/// that bakes a batch > 1, or a wildcard target the batch-symbolic pass
/// could not prove (run `cleanup` first) — instead of surfacing the
/// problem later as per-batch errors from the batcher.
///
/// **Streamline tier:** [`PlannedEngine::new_auto`] (which
/// [`PlannedEngine::from_zoo`] uses) first attempts
/// [`crate::streamline::try_streamline`]; when the model lowers cleanly
/// to integer-domain form the engine serves the streamlined graph
/// through the plan's quantized kernel tier, with the float plan as the
/// fallback for everything else ([`PlannedEngine::new`] always takes
/// the float path — it is the byte-exact baseline). Batch binding at
/// the NCHW edge stays **f32** either way: the engine binds the request
/// rows as one float tensor and the plan's boundary `MultiThreshold`
/// performs the single f32→integer conversion, after which activations
/// stay resident in `i8`/`i32` slots through the quantized tier (see
/// [`crate::plan`]'s dtype-aware-slots docs).
///
/// [`PlannedEngine::share`] hands out additional engines over the SAME
/// compiled plan (one `Arc` clone; packed weights and schedule resident
/// once) with their own scratch arenas — this is how sharded batcher
/// workers serve one model without duplicating it per worker. Graphs
/// without inferred intermediate shapes should go through
/// [`crate::transforms::cleanup`] first so the batch-symbolic pass can
/// prove its rewrites (the zoo path does).
pub struct PlannedEngine {
    plan: Arc<ExecutionPlan<'static>>,
    model_name: String,
    input_name: String,
    output_name: String,
    in_dim: usize,
    out_dim: usize,
    adapter: EdgeAdapter,
    streamlined: bool,
    scratch: ScratchArena,
}

impl PlannedEngine {
    /// Compile a `[n, in_dim] -> [n, out_dim]` (or NCHW-input) graph
    /// into a resident plan (float tier — the exact baseline).
    pub fn new(graph: &ModelGraph) -> Result<PlannedEngine> {
        PlannedEngine::build(graph, false)
    }

    /// Like [`PlannedEngine::new`], but first attempts to streamline the
    /// model into integer-domain form; the quantized plan serves when the
    /// whole graph lowers cleanly, the float plan otherwise.
    pub fn new_auto(graph: &ModelGraph) -> Result<PlannedEngine> {
        match crate::streamline::try_streamline(graph) {
            Ok(att) if att.report.ok => PlannedEngine::build(&att.graph, true),
            _ => PlannedEngine::build(graph, false),
        }
    }

    fn build(graph: &ModelGraph, streamlined: bool) -> Result<PlannedEngine> {
        ensure!(graph.inputs.len() == 1 && graph.outputs.len() == 1, "single-input/output graphs only");
        let in_shape = graph.inputs[0].shape.clone().unwrap_or_default();
        let out_shape = graph.outputs[0].shape.clone().unwrap_or_default();
        ensure!(out_shape.len() == 2, "[n, dim] graph outputs only");
        let (in_dim, adapter) = match in_shape.as_slice() {
            [_, dim] => (*dim, EdgeAdapter::Dense),
            [_, c, h, w] => (c * h * w, EdgeAdapter::Nchw { c: *c, h: *h, w: *w }),
            other => bail!("unsupported input shape {other:?} (want [n, dim] or [n, c, h, w])"),
        };
        let plan = ExecutionPlan::compile(graph)?;
        // compile-time batchability probe: fail construction loudly
        // instead of surfacing per-batch errors from the batcher later
        ensure!(
            plan.batch_blockers().is_empty(),
            "graph '{}' cannot serve batched requests: {} (run `cleanup` first, or fix the \
             reshape target)",
            graph.name,
            plan.batch_blockers().join("; ")
        );
        let plan = Arc::new(plan.into_owned());
        Ok(PlannedEngine {
            plan,
            model_name: graph.name.clone(),
            input_name: graph.inputs[0].name.clone(),
            output_name: graph.outputs[0].name.clone(),
            in_dim,
            out_dim: out_shape[1],
            adapter,
            streamlined,
            scratch: ScratchArena::new(),
        })
    }

    /// Whether this engine serves the integer-domain streamlined form
    /// (quantized kernel tier) rather than the float plan.
    pub fn streamlined(&self) -> bool {
        self.streamlined
    }

    fn engine_meta(&self) -> EngineMeta {
        EngineMeta {
            model_name: self.model_name.clone(),
            input_name: self.input_name.clone(),
            output_name: self.output_name.clone(),
            in_dim: self.in_dim,
            out_dim: self.out_dim,
            adapter: match self.adapter {
                EdgeAdapter::Dense => AdapterMeta::Dense,
                EdgeAdapter::Nchw { c, h, w } => AdapterMeta::Nchw { c, h, w },
            },
            streamlined: self.streamlined,
        }
    }

    /// Persist this engine's compiled plan (schedule, kernels, prepacked
    /// weights) plus its serving metadata as a `.qpln` artifact. `graph`
    /// must be the graph the engine was actually built from — the
    /// streamlined form when [`PlannedEngine::streamlined`] — so the
    /// embedded GRAPH section matches the plan for `verify --artifact`;
    /// [`PlannedEngine::compile_to_artifact`] handles that pairing.
    pub fn save_artifact(&self, graph: &ModelGraph, path: &Path) -> Result<()> {
        artifact::write_artifact(&self.plan, graph, Some(&self.engine_meta()), path)
    }

    /// Compile `graph` exactly like [`PlannedEngine::new_auto`]
    /// (streamlined integer plan when the model lowers cleanly, float
    /// plan otherwise) and persist the result as an artifact at `path`.
    /// Returns the live engine, so one compile serves both immediate
    /// requests and future instant cold starts.
    pub fn compile_to_artifact(graph: &ModelGraph, path: &Path) -> Result<PlannedEngine> {
        match crate::streamline::try_streamline(graph) {
            Ok(att) if att.report.ok => {
                let e = PlannedEngine::build(&att.graph, true)?;
                e.save_artifact(&att.graph, path)?;
                Ok(e)
            }
            _ => {
                let e = PlannedEngine::build(graph, false)?;
                e.save_artifact(graph, path)?;
                Ok(e)
            }
        }
    }

    /// Instant cold start: reconstruct a serving engine straight from a
    /// `.qpln` artifact. No graph parse, no streamlining, no packing, no
    /// plan verification happens here — weight panels are borrowed
    /// zero-copy from the loaded buffer (see [`crate::plan::artifact`]).
    pub fn from_artifact(path: &Path) -> Result<PlannedEngine> {
        let loaded = artifact::read_artifact(path)
            .with_context(|| format!("loading artifact {}", path.display()))?;
        PlannedEngine::from_loaded(loaded)
    }

    /// Build the engine from an already-loaded artifact (the sharded
    /// serving path loads once and [`PlannedEngine::share`]s).
    pub fn from_loaded(loaded: LoadedArtifact) -> Result<PlannedEngine> {
        let meta = loaded
            .engine
            .context("artifact has no engine section (was it written via save_artifact?)")?;
        Ok(PlannedEngine {
            plan: Arc::new(loaded.plan),
            model_name: meta.model_name,
            input_name: meta.input_name,
            output_name: meta.output_name,
            in_dim: meta.in_dim,
            out_dim: meta.out_dim,
            adapter: match meta.adapter {
                AdapterMeta::Dense => EdgeAdapter::Dense,
                AdapterMeta::Nchw { c, h, w } => EdgeAdapter::Nchw { c, h, w },
            },
            streamlined: meta.streamlined,
            scratch: ScratchArena::new(),
        })
    }

    /// A second engine over the SAME compiled plan: clones the `Arc` (no
    /// weight or schedule duplication) and starts a fresh per-engine
    /// [`ScratchArena`]. Sharded batcher workers each take one.
    pub fn share(&self) -> PlannedEngine {
        PlannedEngine {
            plan: self.plan.clone(),
            model_name: self.model_name.clone(),
            input_name: self.input_name.clone(),
            output_name: self.output_name.clone(),
            in_dim: self.in_dim,
            out_dim: self.out_dim,
            adapter: self.adapter,
            streamlined: self.streamlined,
            scratch: ScratchArena::new(),
        }
    }

    /// The shared compiled plan (inspection / share-count assertions).
    pub fn plan_handle(&self) -> Arc<ExecutionPlan<'static>> {
        self.plan.clone()
    }

    /// Run one bound input tensor through the resident plan.
    fn run_one(&mut self, t: &Tensor) -> Result<Tensor> {
        // The plan is batch-symbolic: the leading axis is free, rank and
        // trailing dims still validated — one plan serves every batch
        // size (no per-batch graph clones, unlike the reference engine).
        let cfg = RunConfig { shape_check: ShapeCheck::FreeBatch, record_intermediates: false };
        let mut r =
            self.plan.run_cfg_scratch(|n| (n == self.input_name).then_some(t), &cfg, &mut self.scratch)?;
        r.outputs
            .remove(&self.output_name)
            .with_context(|| format!("plan did not produce output '{}'", self.output_name))
    }

    /// Build and compile a model-zoo entry by Table III name
    /// (e.g. `TFC-w2a2`). Serves the integer-domain streamlined form
    /// when the model lowers cleanly (the zoo models do); use
    /// [`PlannedEngine::from_zoo_float`] for the float baseline.
    pub fn from_zoo(name: &str) -> Result<PlannedEngine> {
        let mut g = crate::zoo::build(name, 1, 32)?;
        crate::transforms::cleanup(&mut g)?;
        PlannedEngine::new_auto(&g)
    }

    /// The float-plan (non-streamlined) variant of
    /// [`PlannedEngine::from_zoo`]: bit-exact with the interpreter on the
    /// original graph.
    pub fn from_zoo_float(name: &str) -> Result<PlannedEngine> {
        let mut g = crate::zoo::build(name, 1, 32)?;
        crate::transforms::cleanup(&mut g)?;
        PlannedEngine::new(&g)
    }

    /// The compiled schedule (for logging / inspection).
    pub fn plan_summary(&self) -> String {
        self.plan.summary()
    }
}

impl InferenceEngine for PlannedEngine {
    fn name(&self) -> String {
        format!("plan:{}", self.model_name)
    }

    fn input_dim(&self) -> usize {
        self.in_dim
    }

    fn output_dim(&self) -> usize {
        self.out_dim
    }

    fn max_batch(&self) -> usize {
        usize::MAX
    }

    fn infer_batch(&mut self, batch: &Tensor) -> Result<Tensor> {
        let shape = batch.shape();
        ensure!(
            shape.len() == 2 && shape[1] == self.in_dim,
            "batch shape {shape:?} incompatible with [n, {}]",
            self.in_dim
        );
        match self.adapter {
            EdgeAdapter::Dense => self.run_one(batch),
            EdgeAdapter::Nchw { c, h, w } => {
                // native batched NCHW: the request rows ARE the [n, c, h,
                // w] tensor (row-major NCHW flattens to exactly the flat
                // row layout) — one plan invocation, no per-sample loop
                let n = shape[0];
                let img = batch.reshape(vec![n, c, h, w])?;
                let y = self.run_one(&img)?;
                ensure!(
                    y.numel() == n * self.out_dim,
                    "plan produced {} values for batch {n}, expected {}",
                    y.numel(),
                    n * self.out_dim
                );
                y.reshape(vec![n, self.out_dim])
            }
        }
    }
}

/// Reference-interpreter engine over a QONNX graph (any batch size).
pub struct ReferenceEngine {
    graph: ModelGraph,
    input_name: String,
    output_name: String,
    in_dim: usize,
    out_dim: usize,
    /// re-shaped graph cache by batch size (§Perf: cloning the graph —
    /// including all weight initializers — per request dominated latency)
    by_batch: std::collections::BTreeMap<usize, ModelGraph>,
}

impl ReferenceEngine {
    pub fn new(graph: ModelGraph) -> Result<ReferenceEngine> {
        ensure!(graph.inputs.len() == 1 && graph.outputs.len() == 1, "single-input/output graphs only");
        let in_shape = graph.inputs[0].shape.clone().unwrap_or_default();
        let out_shape = graph.outputs[0].shape.clone().unwrap_or_default();
        ensure!(in_shape.len() == 2 && out_shape.len() == 2, "[n, dim] graphs only");
        Ok(ReferenceEngine {
            input_name: graph.inputs[0].name.clone(),
            output_name: graph.outputs[0].name.clone(),
            in_dim: in_shape[1],
            out_dim: out_shape[1],
            graph,
            by_batch: Default::default(),
        })
    }
}

impl InferenceEngine for ReferenceEngine {
    fn name(&self) -> String {
        format!("reference:{}", self.graph.name)
    }

    fn input_dim(&self) -> usize {
        self.in_dim
    }

    fn output_dim(&self) -> usize {
        self.out_dim
    }

    fn max_batch(&self) -> usize {
        usize::MAX
    }

    fn infer_batch(&mut self, batch: &Tensor) -> Result<Tensor> {
        let n = batch.shape()[0];
        // the graph declares a fixed batch; re-declare to the live one
        // (cached — cloning weights per request dominated latency)
        let g = self.by_batch.entry(n).or_insert_with(|| {
            let mut g = self.graph.clone();
            g.inputs[0].shape = Some(vec![n, self.in_dim]);
            g.outputs[0].shape = Some(vec![n, self.out_dim]);
            g
        });
        let mut inputs = BTreeMap::new();
        inputs.insert(self.input_name.clone(), batch.clone());
        // explicitly the name-keyed interpreter: this engine is the
        // verification baseline for PlannedEngine
        let r = exec::interpret(g, &inputs)?;
        Ok(r.outputs[&self.output_name].clone())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::zoo::{tfc_batch, TfcParams};

    #[test]
    fn planned_engine_matches_reference_engine() {
        let g = tfc_batch(&TfcParams::random(2, 2, 5), 1).unwrap();
        let mut planned = PlannedEngine::new(&g).unwrap();
        let mut reference = ReferenceEngine::new(g).unwrap();
        assert_eq!(planned.input_dim(), 784);
        assert_eq!(planned.output_dim(), 10);
        for n in [1usize, 3, 8] {
            let x = Tensor::new(
                vec![n, 784],
                (0..n * 784).map(|i| (i % 13) as f32 / 13.0).collect(),
            );
            let yp = planned.infer_batch(&x).unwrap();
            let yr = reference.infer_batch(&x).unwrap();
            assert_eq!(yp, yr, "batch {n}");
        }
    }

    #[test]
    fn planned_engine_nchw_batched_run_matches_per_sample_exec() {
        // tiny conv->flatten->matmul graph with a batch-1 reshape baked in,
        // the same topology shape as CNV's conv->FC transition; the
        // batch-symbolic plan runs the whole request batch natively
        let mut b = crate::ir::GraphBuilder::new("tinyconv");
        b.input("x", vec![1, 2, 4, 4]);
        b.initializer(
            "w",
            Tensor::new(vec![3, 2, 3, 3], (0..54).map(|v| (v % 7) as f32 * 0.25 - 0.75).collect()),
        );
        b.node(
            "Conv",
            &["x", "w"],
            &["c"],
            &[
                ("kernel_shape", crate::ir::AttrValue::Ints(vec![3, 3])),
                ("pads", crate::ir::AttrValue::Ints(vec![1, 1, 1, 1])),
            ],
        );
        b.initializer("target", Tensor::new_i64(vec![2], vec![1, 48]));
        b.node("Reshape", &["c", "target"], &["flat"], &[]);
        b.initializer(
            "fcw",
            Tensor::new(vec![48, 5], (0..240).map(|v| (v % 9) as f32 * 0.1 - 0.4).collect()),
        );
        b.node("MatMul", &["flat", "fcw"], &["y"], &[]);
        b.output("y", vec![1, 5]);
        let g = b.finish().unwrap();

        let mut e = PlannedEngine::new(&g).unwrap();
        assert_eq!(e.input_dim(), 32);
        assert_eq!(e.output_dim(), 5);
        // the baked [1, 48] target was rewritten batch-preserving
        assert_eq!(e.plan_handle().batch_symbolic_count(), 1, "{}", e.plan_summary());
        let rows: Vec<f32> = (0..2 * 32).map(|i| (i % 13) as f32 / 13.0 - 0.4).collect();
        let y = e.infer_batch(&Tensor::new(vec![2, 32], rows.clone())).unwrap();
        assert_eq!(y.shape(), &[2, 5]);
        for r in 0..2 {
            let img = Tensor::new(vec![1, 2, 4, 4], rows[r * 32..(r + 1) * 32].to_vec());
            let want = exec::execute_simple(&g, &img).unwrap();
            assert_eq!(&y.as_f32().unwrap()[r * 5..(r + 1) * 5], want.as_f32().unwrap(), "row {r}");
        }
    }

    #[test]
    fn planned_engine_accepts_declared_batch_nchw_inputs() {
        // graphs exported with a fixed batch > 1 also serve per-row
        let mut b = crate::ir::GraphBuilder::new("b4");
        b.input("x", vec![4, 2, 3, 3]);
        b.node("Relu", &["x"], &["r"], &[]);
        b.node("Flatten", &["r"], &["y"], &[]);
        b.output("y", vec![4, 18]);
        let g = b.finish().unwrap();
        let mut e = PlannedEngine::new(&g).unwrap();
        assert_eq!(e.input_dim(), 18);
        assert_eq!(e.output_dim(), 18);
        let rows: Vec<f32> = (0..3 * 18).map(|i| i as f32 * 0.5 - 10.0).collect();
        let y = e.infer_batch(&Tensor::new(vec![3, 18], rows.clone())).unwrap();
        assert_eq!(y.shape(), &[3, 18]);
        assert_eq!(
            y.as_f32().unwrap(),
            rows.iter().map(|&v| v.max(0.0)).collect::<Vec<_>>().as_slice()
        );
    }

    #[test]
    fn shared_engines_use_one_plan_and_agree() {
        let template = PlannedEngine::from_zoo("TFC-w2a2").unwrap();
        let plan = template.plan_handle();
        let mut a = template.share();
        let mut b = template.share();
        // one compiled plan behind all three engines (+ our handle)
        assert!(Arc::ptr_eq(&a.plan_handle(), &b.plan_handle()));
        assert_eq!(Arc::strong_count(&plan), 4);
        let x = Tensor::new(vec![2, 784], (0..2 * 784).map(|i| (i % 19) as f32 / 19.0).collect());
        let ya = a.infer_batch(&x).unwrap();
        let yb = b.infer_batch(&x).unwrap();
        assert_eq!(ya, yb);
    }

    #[test]
    fn construction_fails_loudly_on_unbatchable_reshape() {
        // a reshape target that bakes batch 4: the old behavior was
        // per-batch errors from the batcher; now construction reports it
        let mut b = crate::ir::GraphBuilder::new("baked");
        b.input("x", vec![4, 2, 3, 3]);
        b.node("Relu", &["x"], &["r"], &[]);
        b.initializer("target", Tensor::new_i64(vec![2], vec![4, 18]));
        b.node("Reshape", &["r", "target"], &["y"], &[]);
        b.output("y", vec![4, 18]);
        let g = b.finish().unwrap();
        let err = PlannedEngine::new(&g).unwrap_err().to_string();
        assert!(err.contains("cannot serve batched requests"), "{err}");
        assert!(err.contains("bakes batch 4"), "{err}");

        // an unproven wildcard target fails with the cleanup hint ...
        let mut b2 = crate::ir::GraphBuilder::new("wild");
        b2.input("x", vec![1, 2, 3, 3]);
        b2.node("Relu", &["x"], &["r"], &[]);
        b2.initializer("target", Tensor::new_i64(vec![2], vec![1, -1]));
        b2.node("Reshape", &["r", "target"], &["y"], &[]);
        b2.output("y", vec![1, 18]);
        let g2 = b2.finish().unwrap();
        let err2 = PlannedEngine::new(&g2).unwrap_err().to_string();
        assert!(err2.contains("cleanup"), "{err2}");
        // ... and succeeds once cleanup has inferred the shapes
        let mut g3 = g2.clone();
        crate::transforms::cleanup(&mut g3).unwrap();
        assert!(PlannedEngine::new(&g3).is_ok());
    }

    #[test]
    fn from_zoo_serves_streamlined_integer_plan() {
        let mut auto = PlannedEngine::from_zoo("TFC-w2a2").unwrap();
        assert!(auto.streamlined(), "TFC-w2a2 must streamline cleanly:\n{}", auto.plan_summary());
        assert!(
            auto.plan_handle().quant_kernel_count() >= 3,
            "{}",
            auto.plan_summary()
        );
        let mut float = PlannedEngine::from_zoo_float("TFC-w2a2").unwrap();
        assert!(!float.streamlined());
        let x = Tensor::new(vec![2, 784], (0..2 * 784).map(|i| (i % 13) as f32 / 13.0).collect());
        let ya = auto.infer_batch(&x).unwrap();
        let yf = float.infer_batch(&x).unwrap();
        assert_eq!(ya.shape(), yf.shape());
        // integer-domain vs float plan: equal up to rare rounding-boundary
        // level flips at the model's one non-dyadic (1/255) scale — the
        // documented tolerance at the scaled output edge
        for (a, b) in ya.as_f32().unwrap().iter().zip(yf.as_f32().unwrap()) {
            assert!((a - b).abs() <= 0.5, "streamlined {a} vs float {b}");
        }
        // shared views keep the streamlined flag and agree bit-exactly
        let mut shared = auto.share();
        assert!(shared.streamlined());
        assert_eq!(shared.infer_batch(&x).unwrap(), ya);
    }

    #[test]
    fn engine_artifact_roundtrip_is_byte_identical_and_zero_copy() {
        let mut g = crate::zoo::build("TFC-w1a2", 1, 32).unwrap();
        crate::transforms::cleanup(&mut g).unwrap();
        let path = std::env::temp_dir()
            .join(format!("qonnx_engine_rt_{}.qpln", std::process::id()));
        let mut compiled = PlannedEngine::compile_to_artifact(&g, &path).unwrap();
        let mut cold = PlannedEngine::from_artifact(&path).unwrap();
        assert_eq!(cold.streamlined(), compiled.streamlined());
        assert_eq!(cold.input_dim(), compiled.input_dim());
        assert_eq!(cold.output_dim(), compiled.output_dim());
        // loading performed zero weight-panel re-packing: every matmul/gemm
        // panel is borrowed straight from the artifact buffer
        let loaded = artifact::read_artifact(&path).unwrap();
        let zc = loaded.zero_copy_report();
        assert_eq!(zc.owned_panels, 0, "{zc:?}");
        assert!(zc.mapped_panels >= 1, "{zc:?}");
        for n in [1usize, 8] {
            let x = Tensor::new(
                vec![n, 784],
                (0..n * 784).map(|i| (i % 17) as f32 / 17.0).collect(),
            );
            let yc = compiled.infer_batch(&x).unwrap();
            let ya = cold.infer_batch(&x).unwrap();
            assert_eq!(yc, ya, "batch {n} must be byte-identical");
        }
        // sharded serving: one loaded artifact, many engines, one plan
        let mut s1 = cold.share();
        let mut s2 = cold.share();
        assert!(Arc::ptr_eq(&s1.plan_handle(), &s2.plan_handle()));
        let x = Tensor::new(vec![2, 784], vec![0.25; 2 * 784]);
        assert_eq!(s1.infer_batch(&x).unwrap(), s2.infer_batch(&x).unwrap());
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn planned_engine_rejects_bad_batch_shape() {
        let g = tfc_batch(&TfcParams::random(2, 2, 5), 1).unwrap();
        let mut planned = PlannedEngine::new(&g).unwrap();
        assert!(planned.infer_batch(&Tensor::zeros(vec![2, 783])).is_err());
        assert!(planned.infer_batch(&Tensor::zeros(vec![784])).is_err());
    }

    #[test]
    fn reference_engine_any_batch() {
        let g = tfc_batch(&TfcParams::random(2, 2, 5), 1).unwrap();
        let mut e = ReferenceEngine::new(g).unwrap();
        assert_eq!(e.input_dim(), 784);
        for n in [1usize, 3, 8] {
            let y = e.infer_batch(&Tensor::zeros(vec![n, 784])).unwrap();
            assert_eq!(y.shape(), &[n, 10]);
        }
    }

    #[test]
    fn pjrt_engine_pads_partial_batches() {
        let stem = crate::runtime::artifacts_dir().join("tfc_w2a2");
        if !stem.with_extension("hlo.txt").exists() {
            eprintln!("skipping: artifacts not built");
            return;
        }
        let rt = PjrtRuntime::cpu().unwrap();
        let mut e = PjrtEngine::load(&rt, &stem).unwrap();
        assert_eq!(e.max_batch(), 8);
        let full = e.infer_batch(&Tensor::full(vec![8, 784], 0.5)).unwrap();
        let part = e.infer_batch(&Tensor::full(vec![3, 784], 0.5)).unwrap();
        assert_eq!(part.shape(), &[3, 10]);
        // padded execution must agree with full-batch rows
        assert_eq!(&full.as_f32().unwrap()[..30], part.as_f32().unwrap());
    }
}

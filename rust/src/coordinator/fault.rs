//! Deterministic fault injection for the serving core.
//!
//! [`FaultyEngine`] wraps any [`InferenceEngine`] and makes `infer_batch`
//! error, panic, or stall on schedule, driven by a shared
//! [`FaultInjector`]. Tests script exact sequences with
//! [`FaultInjector::arm`]; the CLI (and CI soak runs) enable seeded random
//! injection through environment hooks ([`FaultInjector::from_env`]):
//!
//! ```text
//! QONNX_FAULT_SEED=7           # u64 seed — presence enables injection
//! QONNX_FAULT_RATE=0.1         # per-call injection probability (default 0.1)
//! QONNX_FAULT_KIND=error       # error | panic | stall:<ms> (default error)
//! ```
//!
//! Injection is deterministic given (seed, rate, kind): the decision
//! sequence comes from the repo's xorshift [`crate::zoo::rng::Rng`], so a
//! failing run reproduces exactly from its seed.

use super::engine::InferenceEngine;
use crate::tensor::Tensor;
use crate::zoo::rng::Rng;
use anyhow::{bail, Result};
use std::collections::VecDeque;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex, MutexGuard, PoisonError};
use std::time::Duration;

fn lock_recover<T>(m: &Mutex<T>) -> MutexGuard<'_, T> {
    m.lock().unwrap_or_else(PoisonError::into_inner)
}

/// What one `infer_batch` call should do.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FaultAction {
    /// Delegate to the wrapped engine (no fault).
    Serve,
    /// Return an error from `infer_batch`.
    Error,
    /// Panic inside `infer_batch` (exercises shard supervision).
    Panic,
    /// Sleep before delegating (exercises deadlines and sweeps).
    Stall(Duration),
}

struct SeededFaults {
    rng: Rng,
    rate: f64,
    kind: FaultAction,
}

struct InjectorState {
    /// Scripted actions consumed first, in order.
    script: VecDeque<FaultAction>,
    /// Seeded random injection (env hooks / soak runs).
    seeded: Option<SeededFaults>,
    /// What an unscripted, unseeded call does.
    default: FaultAction,
}

/// Shared, clonable schedule of faults for one or more [`FaultyEngine`]s.
///
/// Decision order per call: scripted action if any is queued, else a
/// seeded random draw if seeded mode is on, else the default action.
#[derive(Clone)]
pub struct FaultInjector {
    state: Arc<Mutex<InjectorState>>,
    calls: Arc<AtomicU64>,
    injected: Arc<AtomicU64>,
}

impl Default for FaultInjector {
    fn default() -> Self {
        FaultInjector::new()
    }
}

impl FaultInjector {
    /// No faults until armed/seeded: every call serves.
    pub fn new() -> FaultInjector {
        FaultInjector {
            state: Arc::new(Mutex::new(InjectorState {
                script: VecDeque::new(),
                seeded: None,
                default: FaultAction::Serve,
            })),
            calls: Arc::new(AtomicU64::new(0)),
            injected: Arc::new(AtomicU64::new(0)),
        }
    }

    /// Queue one scripted action (consumed by the next `infer_batch`).
    pub fn arm(&self, action: FaultAction) {
        lock_recover(&self.state).script.push_back(action);
    }

    /// Set the action taken when nothing is scripted and seeding is off.
    pub fn set_default(&self, action: FaultAction) {
        lock_recover(&self.state).default = action;
    }

    /// Enable seeded random injection: each unscripted call injects
    /// `kind` with probability `rate`, deterministically from `seed`.
    pub fn seeded(&self, seed: u64, rate: f64, kind: FaultAction) {
        lock_recover(&self.state).seeded =
            Some(SeededFaults { rng: Rng::new(seed), rate, kind });
    }

    /// Build an injector from `QONNX_FAULT_SEED` / `QONNX_FAULT_RATE` /
    /// `QONNX_FAULT_KIND`; `None` when no seed is set (injection off).
    pub fn from_env() -> Option<FaultInjector> {
        let seed: u64 = std::env::var("QONNX_FAULT_SEED").ok()?.trim().parse().ok()?;
        let rate: f64 = std::env::var("QONNX_FAULT_RATE")
            .ok()
            .and_then(|v| v.trim().parse().ok())
            .unwrap_or(0.1);
        let kind = match std::env::var("QONNX_FAULT_KIND").ok().as_deref().map(str::trim) {
            None | Some("") | Some("error") => FaultAction::Error,
            Some("panic") => FaultAction::Panic,
            Some(s) => match s.strip_prefix("stall:").and_then(|ms| ms.parse::<u64>().ok()) {
                Some(ms) => FaultAction::Stall(Duration::from_millis(ms)),
                None => FaultAction::Error,
            },
        };
        let inj = FaultInjector::new();
        inj.seeded(seed, rate, kind);
        Some(inj)
    }

    /// Decide what the next `infer_batch` call does.
    pub fn next_action(&self) -> FaultAction {
        self.calls.fetch_add(1, Ordering::Relaxed);
        let action = {
            let mut s = lock_recover(&self.state);
            if let Some(a) = s.script.pop_front() {
                a
            } else if let Some(seeded) = s.seeded.as_mut() {
                if f64::from(seeded.rng.uniform()) < seeded.rate {
                    seeded.kind
                } else {
                    FaultAction::Serve
                }
            } else {
                s.default
            }
        };
        if action != FaultAction::Serve {
            self.injected.fetch_add(1, Ordering::Relaxed);
        }
        action
    }

    /// Total `infer_batch` calls seen across wrapped engines.
    pub fn calls(&self) -> u64 {
        self.calls.load(Ordering::Relaxed)
    }

    /// How many of those calls had a fault injected.
    pub fn injected(&self) -> u64 {
        self.injected.load(Ordering::Relaxed)
    }
}

/// An [`InferenceEngine`] wrapper that injects faults per the shared
/// [`FaultInjector`] schedule. Wrap the factory's engine to drive
/// integration tests (or env-hook soak runs) against the batcher.
pub struct FaultyEngine {
    inner: Box<dyn InferenceEngine>,
    injector: FaultInjector,
}

impl FaultyEngine {
    pub fn new(inner: Box<dyn InferenceEngine>, injector: FaultInjector) -> FaultyEngine {
        FaultyEngine { inner, injector }
    }
}

impl InferenceEngine for FaultyEngine {
    fn name(&self) -> String {
        format!("faulty:{}", self.inner.name())
    }

    fn input_dim(&self) -> usize {
        self.inner.input_dim()
    }

    fn output_dim(&self) -> usize {
        self.inner.output_dim()
    }

    fn max_batch(&self) -> usize {
        self.inner.max_batch()
    }

    fn infer_batch(&mut self, batch: &Tensor) -> Result<Tensor> {
        let call = self.injector.calls();
        match self.injector.next_action() {
            FaultAction::Serve => self.inner.infer_batch(batch),
            FaultAction::Error => bail!("injected engine error (call #{call})"),
            FaultAction::Panic => panic!("injected engine panic (call #{call})"),
            FaultAction::Stall(d) => {
                std::thread::sleep(d);
                self.inner.infer_batch(batch)
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn script_runs_before_default() {
        let inj = FaultInjector::new();
        inj.set_default(FaultAction::Error);
        inj.arm(FaultAction::Serve);
        inj.arm(FaultAction::Panic);
        assert_eq!(inj.next_action(), FaultAction::Serve);
        assert_eq!(inj.next_action(), FaultAction::Panic);
        assert_eq!(inj.next_action(), FaultAction::Error);
        assert_eq!(inj.calls(), 3);
        assert_eq!(inj.injected(), 2);
    }

    #[test]
    fn seeded_sequences_are_deterministic() {
        let a = FaultInjector::new();
        let b = FaultInjector::new();
        a.seeded(42, 0.3, FaultAction::Error);
        b.seeded(42, 0.3, FaultAction::Error);
        let sa: Vec<FaultAction> = (0..64).map(|_| a.next_action()).collect();
        let sb: Vec<FaultAction> = (0..64).map(|_| b.next_action()).collect();
        assert_eq!(sa, sb);
        assert!(sa.contains(&FaultAction::Error), "rate 0.3 over 64 draws must inject");
        assert!(sa.contains(&FaultAction::Serve));
        assert_eq!(a.injected(), sa.iter().filter(|&&x| x != FaultAction::Serve).count() as u64);
    }

    #[test]
    fn stall_kind_parses_from_env_shape() {
        // exercise the kind parser through seeded(); from_env itself is
        // covered by the integration suite (env mutation is process-wide)
        let inj = FaultInjector::new();
        inj.seeded(1, 1.0, FaultAction::Stall(Duration::from_millis(3)));
        assert_eq!(inj.next_action(), FaultAction::Stall(Duration::from_millis(3)));
    }
}

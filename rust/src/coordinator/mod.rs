//! L3 coordinator: a fault-tolerant batching inference server over the
//! QONNX toolchain.
//!
//! The paper's contribution lives in the IR/compiler (L2/L1), so the
//! coordinator is a thin-but-real serving loop: a bounded request queue
//! with typed admission ([`SubmitError`]), a dynamic micro-batcher (size-,
//! deadline-, or request-deadline-triggered), supervised worker shards
//! running one of three engines — the PJRT artifact engine (hot path), the
//! compiled [`PlannedEngine`] (native path: serves zoo models when no PJRT
//! artifact is present), or the interpreter-backed [`ReferenceEngine`]
//! (verification path) — and latency/throughput accounting
//! ([`crate::metrics::serving`]).
//!
//! Since the batch-symbolic plan work, [`PlannedEngine`] executes a whole
//! `[n, c, h, w]` request batch in one plan invocation (no per-sample
//! NCHW loop), and [`Batcher::start_sharded`] runs several workers over
//! one queue — each holding a [`PlannedEngine::share`] view of the SAME
//! `Arc`'d compiled plan, so sharding adds zero duplicate packed weights.
//!
//! Robustness (see the `batcher` and `supervisor` module docs): a
//! request is either shed at admission with a typed [`SubmitError`] or
//! guaranteed a definitive [`ServeError`]-typed response — engine panics
//! restart the shard ([`Batcher::health`]), deadlines bound every wait,
//! and shutdown drains or typed-fails everything still queued.
//! [`FaultyEngine`] + [`FaultInjector`] provide the deterministic
//! fault-injection harness the integration tests (and `QONNX_FAULT_SEED`
//! env hooks) drive this machinery with.

mod batcher;
mod engine;
mod fault;
mod supervisor;

pub use batcher::{
    Batcher, BatcherConfig, Response, ServeError, ServerStats, SubmitError, SubmitOptions,
};
pub use engine::{InferenceEngine, PjrtEngine, PlannedEngine, ReferenceEngine};
pub use fault::{FaultAction, FaultInjector, FaultyEngine};
pub use supervisor::{DegradedPolicy, Health, SupervisorConfig};

//! L3 coordinator: a batching inference server over the QONNX toolchain.
//!
//! The paper's contribution lives in the IR/compiler (L2/L1), so the
//! coordinator is a thin-but-real serving loop: a request queue, a dynamic
//! micro-batcher (size- or deadline-triggered), worker shards running one
//! of three engines — the PJRT artifact engine (hot path), the compiled
//! [`PlannedEngine`] (native path: serves zoo models when no PJRT
//! artifact is present), or the interpreter-backed [`ReferenceEngine`]
//! (verification path) — and latency/throughput accounting.
//!
//! Since the batch-symbolic plan work, [`PlannedEngine`] executes a whole
//! `[n, c, h, w]` request batch in one plan invocation (no per-sample
//! NCHW loop), and [`Batcher::start_sharded`] runs several workers over
//! one queue — each holding a [`PlannedEngine::share`] view of the SAME
//! `Arc`'d compiled plan, so sharding adds zero duplicate packed weights.

mod batcher;
mod engine;

pub use batcher::{Batcher, BatcherConfig, ServerStats};
pub use engine::{InferenceEngine, PjrtEngine, PlannedEngine, ReferenceEngine};

//! L3 coordinator: a batching inference server over the QONNX toolchain.
//!
//! The paper's contribution lives in the IR/compiler (L2/L1), so the
//! coordinator is a thin-but-real serving loop: a request queue, a dynamic
//! micro-batcher (size- or deadline-triggered), a worker running one of
//! three engines — the PJRT artifact engine (hot path), the compiled
//! [`PlannedEngine`] (native path: serves zoo models when no PJRT
//! artifact is present), or the interpreter-backed [`ReferenceEngine`]
//! (verification path) — and latency/throughput accounting.

mod batcher;
mod engine;

pub use batcher::{Batcher, BatcherConfig, ServerStats};
pub use engine::{InferenceEngine, PjrtEngine, PlannedEngine, ReferenceEngine};

//! Shard supervision for the [`super::Batcher`]: detect worker death
//! (engine panic, or a thread that exited without reporting), restart dead
//! shards through the retained engine factory with capped exponential
//! backoff, sweep expired deadlines out of the queue and out of stalled
//! shards' in-flight batches, and typed-fail everything when no shard can
//! ever serve again.
//!
//! The supervisor is one thread per [`super::Batcher`]. It owns the worker
//! `JoinHandle`s: liveness is `JoinHandle::is_finished` (catches silent
//! thread death, not just the panic path that tags its own phase), and at
//! shutdown it joins every worker — bounded by
//! [`SupervisorConfig::shutdown_grace`], after which an unresponsive
//! (stalled-in-`infer_batch`) worker is abandoned and its registered
//! in-flight requests are failed with a typed error so no caller hangs.
//!
//! Restart policy: a dead shard waits `restart_backoff * 2^restarts`
//! (capped at `max_backoff`) before the factory is re-invoked, up to
//! `max_restarts` times; after that the shard is `Failed` and counts as
//! permanently dead in [`Health`]. [`DegradedPolicy`] decides whether a
//! server with permanently-dead shards keeps serving on the survivors or
//! refuses admission.

use super::batcher::{
    lock_recover, spawn_worker, EngineFactory, RespSender, ServeError, ServerShared,
};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

/// What admission does when some shards are permanently dead
/// (restart budget exhausted) but others still serve.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum DegradedPolicy {
    /// Keep serving on the surviving shards (reduced capacity).
    #[default]
    ServeDegraded,
    /// Refuse new requests ([`super::SubmitError::Degraded`]) so load
    /// balancers fail over instead of piling onto reduced capacity.
    RefuseWhenDegraded,
}

/// Supervision tuning (part of [`super::BatcherConfig`]).
#[derive(Debug, Clone)]
pub struct SupervisorConfig {
    /// Max restarts per shard before it is permanently `Failed`.
    pub max_restarts: u32,
    /// Backoff before the first restart; doubles per restart.
    pub restart_backoff: Duration,
    /// Cap on the exponential backoff.
    pub max_backoff: Duration,
    /// Deadline-sweep cadence (queue + in-flight batches of stalled
    /// shards). Liveness/restart checks run more often regardless.
    pub tick: Duration,
    /// At shutdown, how long to wait for workers to drain before an
    /// unresponsive worker is abandoned (its in-flight requests are
    /// failed with a typed error).
    pub shutdown_grace: Duration,
    /// Admission policy once shards are permanently dead.
    pub degraded: DegradedPolicy,
}

impl Default for SupervisorConfig {
    fn default() -> Self {
        SupervisorConfig {
            max_restarts: 8,
            restart_backoff: Duration::from_millis(5),
            max_backoff: Duration::from_secs(1),
            tick: Duration::from_millis(2),
            shutdown_grace: Duration::from_secs(5),
            degraded: DegradedPolicy::ServeDegraded,
        }
    }
}

/// One shard's lifecycle phase.
pub(crate) enum ShardPhase {
    /// Engine being built (startup or restart in progress).
    Starting,
    /// Serving.
    Live,
    /// Worker died (reason recorded); the supervisor will schedule a
    /// restart or mark it `Failed`.
    Dead { reason: String },
    /// Waiting out the restart backoff; respawn at `at`.
    Backoff { at: Instant },
    /// Permanently dead: restart budget exhausted (or respawn failed).
    Failed { reason: String },
}

/// A request registered as in-flight on a shard (the batch the worker is
/// currently executing) — enough for the supervisor to typed-fail it.
pub(crate) struct InflightEntry {
    pub(crate) deadline: Option<Instant>,
    pub(crate) resp: RespSender,
}

pub(crate) struct ShardState {
    pub(crate) phase: Mutex<ShardPhase>,
    pub(crate) restarts: AtomicU64,
    pub(crate) inflight: Mutex<Vec<InflightEntry>>,
}

impl ShardState {
    pub(crate) fn new() -> ShardState {
        ShardState {
            phase: Mutex::new(ShardPhase::Starting),
            restarts: AtomicU64::new(0),
            inflight: Mutex::new(Vec::new()),
        }
    }
}

pub(crate) fn set_phase(shard: &ShardState, phase: ShardPhase) {
    *lock_recover(&shard.phase) = phase;
}

/// Shard-level health snapshot ([`super::Batcher::health`]).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Health {
    /// Configured shard count.
    pub shards: usize,
    /// Shards currently serving.
    pub live: usize,
    /// Shards starting up or awaiting a scheduled restart.
    pub starting: usize,
    /// Shards permanently dead (restart budget exhausted).
    pub dead: usize,
    /// Cumulative restarts across all shards.
    pub restarts: u64,
}

impl Health {
    /// Serving below configured capacity.
    pub fn degraded(&self) -> bool {
        self.live < self.shards
    }

    /// Nothing serves and nothing will: every shard permanently dead.
    pub fn all_dead(&self) -> bool {
        self.live + self.starting == 0
    }
}

pub(crate) fn health_of(shards: &[ShardState], max_restarts: u32) -> Health {
    let mut h = Health { shards: shards.len(), live: 0, starting: 0, dead: 0, restarts: 0 };
    for s in shards {
        let restarts = s.restarts.load(Ordering::Relaxed);
        h.restarts += restarts;
        match &*lock_recover(&s.phase) {
            ShardPhase::Live => h.live += 1,
            ShardPhase::Starting | ShardPhase::Backoff { .. } => h.starting += 1,
            // freshly dead: revivable until the budget runs out
            ShardPhase::Dead { .. } => {
                if restarts < u64::from(max_restarts) {
                    h.starting += 1;
                } else {
                    h.dead += 1;
                }
            }
            ShardPhase::Failed { .. } => h.dead += 1,
        }
    }
    h
}

fn backoff_for(restarts: u64, cfg: &SupervisorConfig) -> Duration {
    let mult = 1u32 << restarts.min(16) as u32;
    cfg.restart_backoff.saturating_mul(mult).min(cfg.max_backoff)
}

/// Spawn the supervisor thread. It takes ownership of the worker handles
/// and runs until `shared.shutdown` is set, then joins the workers
/// (bounded by `shutdown_grace`).
pub(crate) fn spawn(
    shared: Arc<ServerShared>,
    factory: Arc<EngineFactory>,
    handles: Vec<JoinHandle<()>>,
) -> JoinHandle<()> {
    std::thread::Builder::new()
        .name("qonnx-supervisor".into())
        .spawn(move || supervise(&shared, &factory, handles))
        .expect("spawning batcher supervisor")
}

fn supervise(
    shared: &Arc<ServerShared>,
    factory: &Arc<EngineFactory>,
    mut handles: Vec<JoinHandle<()>>,
) {
    let cfg = shared.cfg.supervisor.clone();
    // liveness/restart checks run every poll; expensive-ish deadline
    // sweeps every `tick` — and the poll stays short so shutdown is
    // responsive even under a long sweep tick
    let poll = cfg.tick.min(Duration::from_millis(5)).max(Duration::from_micros(500));
    let mut last_sweep = Instant::now();
    while !shared.shutdown.load(Ordering::Relaxed) {
        let now = Instant::now();
        for idx in 0..shared.shards.len() {
            let shard = &shared.shards[idx];
            let finished = handles[idx].is_finished();
            let mut respawn = false;
            {
                let mut phase = lock_recover(&shard.phase);
                // a thread that exited without tagging its phase (silent
                // death) is dead even though it still claims Live; at
                // shutdown workers exit Live on purpose, but then this
                // loop has already stopped
                if finished && matches!(&*phase, ShardPhase::Live | ShardPhase::Starting) {
                    *phase = ShardPhase::Dead {
                        reason: "worker thread exited unexpectedly".to_string(),
                    };
                }
                match &*phase {
                    ShardPhase::Dead { reason } => {
                        let restarts = shard.restarts.load(Ordering::Relaxed);
                        if restarts >= u64::from(cfg.max_restarts) {
                            let reason = format!(
                                "{reason} (restart budget of {} exhausted)",
                                cfg.max_restarts
                            );
                            *phase = ShardPhase::Failed { reason };
                        } else {
                            *phase =
                                ShardPhase::Backoff { at: now + backoff_for(restarts, &cfg) };
                        }
                    }
                    ShardPhase::Backoff { at } if *at <= now && finished => {
                        *phase = ShardPhase::Starting;
                        respawn = true;
                    }
                    _ => {}
                }
            }
            if respawn {
                shard.restarts.fetch_add(1, Ordering::Relaxed);
                shared.metrics.inc_shard_restart();
                if let Some(t) = &shared.cfg.trace {
                    t.instant("shard", "shard-restart", &[("shard", idx as i64)]);
                }
                match spawn_worker(shared.clone(), factory.clone(), idx, None) {
                    Ok(h) => {
                        // the old handle is finished (checked above)
                        let _ = std::mem::replace(&mut handles[idx], h).join();
                    }
                    Err(e) => set_phase(
                        shard,
                        ShardPhase::Failed { reason: format!("respawn failed: {e}") },
                    ),
                }
            }
        }
        if last_sweep.elapsed() >= cfg.tick {
            let now = Instant::now();
            shared.sweep_expired_queue(now);
            shared.sweep_expired_inflight(now);
            last_sweep = now;
        }
        // nothing serves and nothing will: don't strand queued requests
        if health_of(&shared.shards, cfg.max_restarts).all_dead() {
            shared.fail_queue(&ServeError::NoLiveShards);
        }
        std::thread::sleep(poll);
    }
    // shutdown: workers drain the queue and exit on their own; join them,
    // abandoning any worker stalled inside infer_batch past the grace
    // window (its registered in-flight requests are typed-failed so no
    // caller hangs on recv)
    let t0 = Instant::now();
    for (idx, h) in handles.drain(..).enumerate() {
        loop {
            if h.is_finished() {
                let _ = h.join();
                break;
            }
            if t0.elapsed() >= cfg.shutdown_grace {
                shared.fail_inflight(idx, &ServeError::ShutDown);
                break; // detach: a stalled engine cannot be interrupted
            }
            std::thread::sleep(Duration::from_millis(1));
        }
    }
}

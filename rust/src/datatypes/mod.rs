//! Arbitrary-precision datatype system.
//!
//! QONNX carries quantized values inside float32 tensors; what makes a tensor
//! "INT4" or "BIPOLAR" is an *annotation* constraining the set of values the
//! container may hold. This module is the Rust analog of
//! `qonnx.core.datatype`: a closed vocabulary of container datatypes with
//! range queries, membership tests, and canonical-name round-tripping.
//!
//! Supported kinds:
//! * `FLOAT32` — unconstrained.
//! * `BIPOLAR` — {-1, +1} (1 bit of information, FINN convention).
//! * `BINARY`  — {0, 1}.
//! * `TERNARY` — {-1, 0, +1}.
//! * `INT<n>` / `UINT<n>` for 1 ≤ n ≤ 64 — signed two's-complement /
//!   unsigned integer ranges.
//! * `FIXED<i,f>` — signed fixed point with `i` total bits, `f` fractional
//!   bits (classic `ap_fixed<i,i-f>` semantics: step `2^-f`).
//! * `SCALEDINT<n>` — integer grid of unknown (float) scale; used by
//!   datatype inference when a Quant scale is not unitary.

use std::fmt;

/// A per-tensor arbitrary-precision datatype annotation.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum DataType {
    Float32,
    Bipolar,
    Binary,
    Ternary,
    Int(u8),
    Uint(u8),
    /// `Fixed(total_bits, frac_bits)`, signed.
    Fixed(u8, u8),
    /// Integer grid with an unknown floating scale attached downstream.
    ScaledInt(u8),
}

impl DataType {
    /// Smallest representable value (as f64 so INT64 is exact enough for
    /// range checks; quantized NN practice stays far below 2^53).
    pub fn min(&self) -> f64 {
        match *self {
            DataType::Float32 => f64::from(f32::MIN),
            DataType::Bipolar | DataType::Ternary => -1.0,
            DataType::Binary => 0.0,
            DataType::Int(n) | DataType::ScaledInt(n) => -((1i128 << (n - 1)) as f64),
            DataType::Uint(_) => 0.0,
            DataType::Fixed(n, f) => -((1i128 << (n - 1)) as f64) / (1i128 << f) as f64,
        }
    }

    /// Largest representable value.
    pub fn max(&self) -> f64 {
        match *self {
            DataType::Float32 => f64::from(f32::MAX),
            DataType::Bipolar | DataType::Ternary | DataType::Binary => 1.0,
            DataType::Int(n) | DataType::ScaledInt(n) => ((1i128 << (n - 1)) - 1) as f64,
            DataType::Uint(n) => ((1i128 << n) - 1) as f64,
            DataType::Fixed(n, f) => ((1i128 << (n - 1)) - 1) as f64 / (1i128 << f) as f64,
        }
    }

    /// Number of bits needed to store one element of this type.
    pub fn bitwidth(&self) -> u32 {
        match *self {
            DataType::Float32 => 32,
            DataType::Bipolar | DataType::Binary => 1,
            DataType::Ternary => 2,
            DataType::Int(n) | DataType::Uint(n) | DataType::ScaledInt(n) => u32::from(n),
            DataType::Fixed(n, _) => u32::from(n),
        }
    }

    /// Whether the type can represent negative numbers.
    pub fn signed(&self) -> bool {
        match *self {
            DataType::Float32 | DataType::Bipolar | DataType::Ternary => true,
            DataType::Binary | DataType::Uint(_) => false,
            DataType::Int(_) | DataType::Fixed(_, _) | DataType::ScaledInt(_) => true,
        }
    }

    /// Whether the type is an integer grid (step 1) — excludes FLOAT32 and
    /// FIXED with fractional bits.
    pub fn is_integer(&self) -> bool {
        match *self {
            DataType::Float32 => false,
            DataType::Fixed(_, f) => f == 0,
            _ => true,
        }
    }

    /// Membership test: can `v` be stored in a tensor of this datatype?
    pub fn allowed(&self, v: f64) -> bool {
        match *self {
            DataType::Float32 => v.is_finite(),
            DataType::Bipolar => v == -1.0 || v == 1.0,
            DataType::Binary => v == 0.0 || v == 1.0,
            DataType::Ternary => v == -1.0 || v == 0.0 || v == 1.0,
            DataType::Int(_) | DataType::Uint(_) | DataType::ScaledInt(_) => {
                v.fract() == 0.0 && v >= self.min() && v <= self.max()
            }
            DataType::Fixed(_, f) => {
                let scaled = v * (1i128 << f) as f64;
                scaled.fract() == 0.0 && v >= self.min() && v <= self.max()
            }
        }
    }

    /// The smallest integer datatype covering the inclusive range
    /// `[lo, hi]`; used by accumulator-width inference.
    pub fn smallest_covering(lo: f64, hi: f64) -> DataType {
        debug_assert!(lo <= hi);
        if lo >= 0.0 {
            for n in 1..=64u8 {
                if hi <= DataType::Uint(n).max() {
                    return DataType::Uint(n);
                }
            }
            DataType::Uint(64)
        } else {
            for n in 2..=64u8 {
                let d = DataType::Int(n);
                if lo >= d.min() && hi <= d.max() {
                    return d;
                }
            }
            DataType::Int(64)
        }
    }

    /// Canonical QONNX name, e.g. `INT4`, `UINT8`, `FIXED<8,4>`.
    pub fn canonical_name(&self) -> String {
        match *self {
            DataType::Float32 => "FLOAT32".into(),
            DataType::Bipolar => "BIPOLAR".into(),
            DataType::Binary => "BINARY".into(),
            DataType::Ternary => "TERNARY".into(),
            DataType::Int(n) => format!("INT{n}"),
            DataType::Uint(n) => format!("UINT{n}"),
            DataType::Fixed(n, f) => format!("FIXED<{n},{f}>"),
            DataType::ScaledInt(n) => format!("SCALEDINT<{n}>"),
        }
    }

    /// Parse a canonical name back into a datatype.
    pub fn from_name(name: &str) -> Option<DataType> {
        match name {
            "FLOAT32" => return Some(DataType::Float32),
            "BIPOLAR" => return Some(DataType::Bipolar),
            "BINARY" => return Some(DataType::Binary),
            "TERNARY" => return Some(DataType::Ternary),
            _ => {}
        }
        if let Some(rest) = name.strip_prefix("UINT") {
            return rest.parse::<u8>().ok().filter(|&n| (1..=64).contains(&n)).map(DataType::Uint);
        }
        if let Some(rest) = name.strip_prefix("INT") {
            return rest.parse::<u8>().ok().filter(|&n| (1..=64).contains(&n)).map(DataType::Int);
        }
        if let Some(rest) = name.strip_prefix("FIXED<") {
            let inner = rest.strip_suffix('>')?;
            let (a, b) = inner.split_once(',')?;
            let n = a.trim().parse::<u8>().ok()?;
            let f = b.trim().parse::<u8>().ok()?;
            if n >= 1 && f <= n {
                return Some(DataType::Fixed(n, f));
            }
            return None;
        }
        if let Some(rest) = name.strip_prefix("SCALEDINT<") {
            let inner = rest.strip_suffix('>')?;
            return inner.trim().parse::<u8>().ok().filter(|&n| (1..=64).contains(&n)).map(DataType::ScaledInt);
        }
        None
    }

    /// The datatype implied by a Quant node's (signed, narrow, bit_width)
    /// attributes assuming unit scale and zero offset. Fractional bit widths
    /// get a container of `ceil(bit_width)` bits.
    pub fn from_quant_params(signed: bool, narrow: bool, bit_width: f64) -> DataType {
        let nb = bit_width.ceil() as u8;
        if signed {
            if narrow && nb == 2 {
                // [-1, 1]
                DataType::Ternary
            } else {
                DataType::Int(nb.max(1))
            }
        } else if nb == 1 {
            DataType::Binary
        } else {
            DataType::Uint(nb)
        }
    }
}

impl fmt::Display for DataType {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.canonical_name())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn int_ranges_match_paper_eqs_2_3() {
        // Eq. 2/3 with nb=8 signed: [-128, 127]; unsigned: [0, 255].
        assert_eq!(DataType::Int(8).min(), -128.0);
        assert_eq!(DataType::Int(8).max(), 127.0);
        assert_eq!(DataType::Uint(8).min(), 0.0);
        assert_eq!(DataType::Uint(8).max(), 255.0);
    }

    #[test]
    fn low_precision_ranges() {
        assert_eq!(DataType::Int(2).min(), -2.0);
        assert_eq!(DataType::Int(2).max(), 1.0);
        assert_eq!(DataType::Uint(1).max(), 1.0);
        assert_eq!(DataType::Uint(4).max(), 15.0);
        assert_eq!(DataType::Int(3).min(), -4.0);
    }

    #[test]
    fn special_types() {
        assert!(DataType::Bipolar.allowed(-1.0));
        assert!(DataType::Bipolar.allowed(1.0));
        assert!(!DataType::Bipolar.allowed(0.0));
        assert!(DataType::Ternary.allowed(0.0));
        assert!(!DataType::Binary.allowed(-1.0));
        assert_eq!(DataType::Bipolar.bitwidth(), 1);
        assert_eq!(DataType::Ternary.bitwidth(), 2);
    }

    #[test]
    fn fixed_point() {
        let d = DataType::Fixed(8, 4);
        assert_eq!(d.min(), -8.0);
        assert!((d.max() - 7.9375).abs() < 1e-12);
        assert!(d.allowed(0.0625));
        assert!(!d.allowed(0.03));
        assert!(d.is_integer() == false);
    }

    #[test]
    fn membership_int() {
        let d = DataType::Int(4);
        assert!(d.allowed(-8.0));
        assert!(d.allowed(7.0));
        assert!(!d.allowed(8.0));
        assert!(!d.allowed(0.5));
    }

    #[test]
    fn name_roundtrip() {
        for d in [
            DataType::Float32,
            DataType::Bipolar,
            DataType::Binary,
            DataType::Ternary,
            DataType::Int(2),
            DataType::Int(17),
            DataType::Uint(1),
            DataType::Uint(32),
            DataType::Fixed(12, 5),
            DataType::ScaledInt(9),
        ] {
            assert_eq!(DataType::from_name(&d.canonical_name()), Some(d), "{d}");
        }
        assert_eq!(DataType::from_name("INT0"), None);
        assert_eq!(DataType::from_name("UINT65"), None);
        assert_eq!(DataType::from_name("bogus"), None);
    }

    #[test]
    fn smallest_covering_ranges() {
        assert_eq!(DataType::smallest_covering(0.0, 1.0), DataType::Uint(1));
        assert_eq!(DataType::smallest_covering(0.0, 255.0), DataType::Uint(8));
        assert_eq!(DataType::smallest_covering(-1.0, 1.0), DataType::Int(2));
        assert_eq!(DataType::smallest_covering(-128.0, 127.0), DataType::Int(8));
        assert_eq!(DataType::smallest_covering(-129.0, 0.0), DataType::Int(9));
    }

    #[test]
    fn from_quant_params_matches_table_ii_example() {
        // "at 8 bits if signed is true and narrow is false, the target is
        // [-128, 127]" — INT8 covers that.
        assert_eq!(DataType::from_quant_params(true, false, 8.0), DataType::Int(8));
        assert_eq!(DataType::from_quant_params(false, false, 1.0), DataType::Binary);
        assert_eq!(DataType::from_quant_params(true, true, 2.0), DataType::Ternary);
        // fractional widths round the container up
        assert_eq!(DataType::from_quant_params(true, false, 7.5), DataType::Int(8));
    }
}

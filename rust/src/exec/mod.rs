//! Graph execution: compiled plans (default) and the name-keyed
//! reference interpreter (verification baseline).
//!
//! Like the paper's Python execution utility, execution exists first to
//! *verify* model semantics; unlike it, the default path here is
//! production-shaped: [`execute_with`] lowers the graph into a
//! [`crate::plan::ExecutionPlan`] (names resolved to dense slots, topo
//! order frozen, kernel dispatch pre-resolved, initializers borrowed
//! rather than cloned) and runs that. Engines that serve repeated
//! requests compile the plan once and reuse it
//! ([`crate::coordinator::PlannedEngine`]).
//!
//! The original name-keyed interpreter survives as [`interpret_with`]:
//! it walks the topo order per call and resolves tensors through a
//! name-keyed map. It is the independent baseline the plan executor is
//! equivalence-tested against (`tests/plan_equiv.rs`), and it no longer
//! clones initializers per request either — the context borrows them.
//!
//! [`ExecOptions::standard_onnx_only`] restricts execution to
//! standard-ONNX operators — simulating an existing 8-bit backend that
//! knows nothing about QONNX, which is how we demonstrate the paper's
//! QCDQ backward-compatibility claim (§IV). Both executors honor it with
//! the same error surface.

use crate::ir::{ModelGraph, DOMAIN_FINN, DOMAIN_QONNX};
use crate::ops;
use crate::plan::{ExecutionPlan, PlanOptions, RtVal, RunConfig, ShapeCheck};
use crate::tensor::Tensor;
use anyhow::{bail, Context, Result};
use std::collections::BTreeMap;

/// Executor configuration.
#[derive(Debug, Clone, Default)]
pub struct ExecOptions {
    /// Reject QONNX/FINN-domain nodes — emulates a stock ONNX backend.
    pub standard_onnx_only: bool,
    /// Record every intermediate tensor (for shape inference / debugging).
    pub keep_intermediates: bool,
}

/// Execution result: outputs plus (optionally) all intermediates.
#[derive(Debug)]
pub struct ExecResult {
    pub outputs: BTreeMap<String, Tensor>,
    pub intermediates: BTreeMap<String, Tensor>,
}

/// Execute `graph` on named inputs (compiled-plan path).
pub fn execute(graph: &ModelGraph, inputs: &BTreeMap<String, Tensor>) -> Result<ExecResult> {
    execute_with(graph, inputs, &ExecOptions::default())
}

/// Execute with explicit options.
///
/// Thin wrapper over the plan subsystem: compiles a *borrowed* plan (no
/// tensor copies — callers running the same graph repeatedly should
/// compile an [`ExecutionPlan`] once and call [`ExecutionPlan::run`]
/// themselves, or use [`crate::coordinator::PlannedEngine`]).
pub fn execute_with(
    graph: &ModelGraph,
    inputs: &BTreeMap<String, Tensor>,
    opts: &ExecOptions,
) -> Result<ExecResult> {
    let popts = PlanOptions {
        standard_onnx_only: opts.standard_onnx_only,
        // epilogue fusion hides fused nodes' intermediate names, so shape
        // inference (and any keep_intermediates caller) compiles unfused;
        // integer residency likewise changes intermediate *containers*,
        // so recording callers keep the all-f32 interpreter view
        fuse_epilogues: !opts.keep_intermediates,
        int_residency: !opts.keep_intermediates,
        ..Default::default()
    };
    let plan = ExecutionPlan::compile_with(graph, &popts)?;
    let cfg =
        RunConfig { shape_check: ShapeCheck::Exact, record_intermediates: opts.keep_intermediates };
    let r = plan.run_cfg(|n| inputs.get(n), &cfg)?;
    let mut intermediates = r.intermediates;
    if opts.keep_intermediates {
        // parity with the interpreter's context: initializers consumed
        // only by compile-time-folded subgraphs (or unused) are not plan
        // preloads, but callers still expect them by name
        for (k, t) in &graph.initializers {
            intermediates.entry(k.clone()).or_insert_with(|| t.clone());
        }
    }
    Ok(ExecResult { outputs: r.outputs, intermediates })
}

/// Execute via the name-keyed reference interpreter.
pub fn interpret(graph: &ModelGraph, inputs: &BTreeMap<String, Tensor>) -> Result<ExecResult> {
    interpret_with(graph, inputs, &ExecOptions::default())
}

/// The legacy name-keyed interpreter: per-call topo sort, name-keyed
/// context, string dispatch per node. Kept as the verification baseline
/// for the compiled plan. Initializers and inputs are *borrowed* into
/// the context (they used to be cloned per request).
pub fn interpret_with<'a>(
    graph: &'a ModelGraph,
    inputs: &'a BTreeMap<String, Tensor>,
    opts: &ExecOptions,
) -> Result<ExecResult> {
    let mut ctx: BTreeMap<&'a str, RtVal<'a>> = BTreeMap::new();
    for (k, t) in &graph.initializers {
        ctx.insert(k.as_str(), RtVal::Ref(t));
    }
    for vi in &graph.inputs {
        if graph.initializers.contains_key(&vi.name) {
            continue;
        }
        let t = inputs
            .get(&vi.name)
            .with_context(|| format!("missing input tensor '{}'", vi.name))?;
        if let Some(shape) = &vi.shape {
            if t.shape() != shape.as_slice() {
                bail!(
                    "input '{}' shape {:?} does not match declared {:?}",
                    vi.name,
                    t.shape(),
                    shape
                );
            }
        }
        ctx.insert(vi.name.as_str(), RtVal::Ref(t));
    }

    let order = graph.topo_order()?;
    for i in order {
        let node = &graph.nodes[i];
        if opts.standard_onnx_only && (node.domain == DOMAIN_QONNX || node.domain == DOMAIN_FINN) {
            bail!(
                "node '{}' ({}, domain '{}') is not a standard ONNX op — \
                 this backend only executes the stock operator set",
                node.name,
                node.op_type,
                node.domain
            );
        }
        let mut ins: Vec<&Tensor> = Vec::with_capacity(node.inputs.len());
        for name in node.present_inputs() {
            ins.push(
                ctx.get(name)
                    .with_context(|| format!("node '{}' input '{name}' not computed", node.name))?
                    .tensor(),
            );
        }
        let outs = ops::execute_node(node, &ins)
            .with_context(|| format!("executing node '{}' ({})", node.name, node.op_type))?;
        if outs.len() != node.outputs.len() {
            bail!(
                "node '{}' produced {} outputs, declared {}",
                node.name,
                outs.len(),
                node.outputs.len()
            );
        }
        drop(ins);
        for (name, t) in node.outputs.iter().zip(outs) {
            ctx.insert(name.as_str(), RtVal::Owned(t));
        }
    }

    let mut outputs = BTreeMap::new();
    for vi in &graph.outputs {
        let t = ctx
            .get(vi.name.as_str())
            .with_context(|| format!("graph output '{}' was not produced", vi.name))?;
        outputs.insert(vi.name.clone(), t.tensor().clone());
    }
    let intermediates = if opts.keep_intermediates {
        ctx.into_iter().map(|(k, v)| (k.to_string(), v.into_tensor())).collect()
    } else {
        BTreeMap::new()
    };
    Ok(ExecResult { outputs, intermediates })
}

/// Convenience: single-input single-output execution. Returns the
/// graph's *declared* output (by name), independent of map ordering.
pub fn execute_simple(graph: &ModelGraph, input: &Tensor) -> Result<Tensor> {
    anyhow::ensure!(graph.inputs.len() == 1, "execute_simple wants exactly 1 graph input");
    anyhow::ensure!(graph.outputs.len() == 1, "execute_simple wants exactly 1 graph output");
    let mut m = BTreeMap::new();
    m.insert(graph.inputs[0].name.clone(), input.clone());
    let mut r = execute(graph, &m)?;
    let name = &graph.outputs[0].name;
    r.outputs
        .remove(name)
        .with_context(|| format!("graph output '{name}' missing from results"))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ir::GraphBuilder;

    fn quant_relu_graph() -> ModelGraph {
        let mut b = GraphBuilder::new("qr");
        b.input("x", vec![1, 4]);
        b.node("Relu", &["x"], &["a"], &[]);
        b.quant("a", "y", 0.5, 0.0, 4.0, false, false, "ROUND");
        b.output("y", vec![1, 4]);
        b.finish().unwrap()
    }

    #[test]
    fn executes_quant_relu() {
        let g = quant_relu_graph();
        let x = Tensor::new(vec![1, 4], vec![-1.0, 0.3, 0.26, 99.0]);
        let y = execute_simple(&g, &x).unwrap();
        // relu then uint4 quant at scale .5: max 7.5
        assert_eq!(y.as_f32().unwrap(), &[0.0, 0.5, 0.5, 7.5]);
    }

    #[test]
    fn plan_path_matches_interpreter() {
        let g = quant_relu_graph();
        let mut m = BTreeMap::new();
        m.insert("x".to_string(), Tensor::new(vec![1, 4], vec![-1.0, 0.3, 0.26, 99.0]));
        let a = execute(&g, &m).unwrap();
        let b = interpret(&g, &m).unwrap();
        assert_eq!(a.outputs, b.outputs);
    }

    #[test]
    fn standard_only_rejects_qonnx_nodes() {
        let g = quant_relu_graph();
        let mut m = BTreeMap::new();
        m.insert("x".to_string(), Tensor::zeros(vec![1, 4]));
        let opts = ExecOptions { standard_onnx_only: true, ..Default::default() };
        let err = execute_with(&g, &m, &opts).unwrap_err();
        assert!(err.to_string().contains("not a standard ONNX op"));
        let err = interpret_with(&g, &m, &opts).unwrap_err();
        assert!(err.to_string().contains("not a standard ONNX op"));
    }

    #[test]
    fn missing_input_reported() {
        let g = quant_relu_graph();
        let m = BTreeMap::new();
        for r in [execute(&g, &m), interpret(&g, &m)] {
            let err = r.unwrap_err().to_string();
            assert!(err.contains("missing input tensor"), "{err}");
        }
    }

    #[test]
    fn shape_mismatch_reported() {
        let g = quant_relu_graph();
        let mut m = BTreeMap::new();
        m.insert("x".to_string(), Tensor::zeros(vec![2, 4]));
        for r in [execute(&g, &m), interpret(&g, &m)] {
            let err = r.unwrap_err().to_string();
            assert!(err.contains("does not match declared"), "{err}");
        }
    }

    #[test]
    fn intermediates_recorded() {
        let g = quant_relu_graph();
        let mut m = BTreeMap::new();
        m.insert("x".to_string(), Tensor::new(vec![1, 4], vec![1.0; 4]));
        let opts = ExecOptions { keep_intermediates: true, ..Default::default() };
        let r = execute_with(&g, &m, &opts).unwrap();
        assert!(r.intermediates.contains_key("a"));
        let r = interpret_with(&g, &m, &opts).unwrap();
        assert!(r.intermediates.contains_key("a"));
    }

    #[test]
    fn intermediates_include_fold_only_initializers() {
        // `w` is consumed only by a compile-time-folded weight Quant; the
        // plan path must still expose it by name like the interpreter does.
        let mut b = GraphBuilder::new("foldw");
        b.input("x", vec![1, 2]);
        b.node("Relu", &["x"], &["r"], &[]);
        b.initializer("w", Tensor::new(vec![2, 2], vec![0.3, -0.6, 0.9, 0.1]));
        b.quant("w", "wq", 0.25, 0.0, 4.0, true, true, "ROUND");
        b.node("MatMul", &["r", "wq"], &["y"], &[]);
        b.output("y", vec![1, 2]);
        let g = b.finish().unwrap();
        let mut m = BTreeMap::new();
        m.insert("x".to_string(), Tensor::new(vec![1, 2], vec![1.0, 2.0]));
        let opts = ExecOptions { keep_intermediates: true, ..Default::default() };
        let planned = execute_with(&g, &m, &opts).unwrap();
        let interp = interpret_with(&g, &m, &opts).unwrap();
        for key in interp.intermediates.keys() {
            assert!(planned.intermediates.contains_key(key), "plan path missing '{key}'");
        }
    }

    #[test]
    fn execute_simple_returns_declared_output() {
        let g = quant_relu_graph();
        let x = Tensor::new(vec![1, 4], vec![1.0; 4]);
        let y = execute_simple(&g, &x).unwrap();
        let mut m = BTreeMap::new();
        m.insert("x".to_string(), x);
        let r = execute(&g, &m).unwrap();
        assert_eq!(&y, &r.outputs[&g.outputs[0].name]);
    }
}

//! Node-level reference graph executor.
//!
//! Like the paper's Python execution utility, this executor exists to
//! *verify* model semantics, not to be fast (the fast path is the PJRT
//! runtime). It walks the graph in topological order, materializing every
//! intermediate tensor.
//!
//! [`ExecOptions::standard_onnx_only`] restricts execution to standard-ONNX
//! operators — simulating an existing 8-bit backend that knows nothing
//! about QONNX, which is how we demonstrate the paper's QCDQ
//! backward-compatibility claim (§IV).

use crate::ir::{ModelGraph, DOMAIN_FINN, DOMAIN_QONNX};
use crate::ops;
use crate::tensor::Tensor;
use anyhow::{bail, Context, Result};
use std::collections::BTreeMap;

/// Executor configuration.
#[derive(Debug, Clone, Default)]
pub struct ExecOptions {
    /// Reject QONNX/FINN-domain nodes — emulates a stock ONNX backend.
    pub standard_onnx_only: bool,
    /// Record every intermediate tensor (for shape inference / debugging).
    pub keep_intermediates: bool,
}

/// Execution result: outputs plus (optionally) all intermediates.
#[derive(Debug)]
pub struct ExecResult {
    pub outputs: BTreeMap<String, Tensor>,
    pub intermediates: BTreeMap<String, Tensor>,
}

/// Execute `graph` on named inputs.
pub fn execute(graph: &ModelGraph, inputs: &BTreeMap<String, Tensor>) -> Result<ExecResult> {
    execute_with(graph, inputs, &ExecOptions::default())
}

/// Execute with explicit options.
pub fn execute_with(
    graph: &ModelGraph,
    inputs: &BTreeMap<String, Tensor>,
    opts: &ExecOptions,
) -> Result<ExecResult> {
    let mut ctx: BTreeMap<String, Tensor> = BTreeMap::new();
    for (k, t) in &graph.initializers {
        ctx.insert(k.clone(), t.clone());
    }
    for vi in &graph.inputs {
        if graph.initializers.contains_key(&vi.name) {
            continue;
        }
        let t = inputs
            .get(&vi.name)
            .with_context(|| format!("missing input tensor '{}'", vi.name))?;
        if let Some(shape) = &vi.shape {
            if t.shape() != shape.as_slice() {
                bail!(
                    "input '{}' shape {:?} does not match declared {:?}",
                    vi.name,
                    t.shape(),
                    shape
                );
            }
        }
        ctx.insert(vi.name.clone(), t.clone());
    }

    let order = graph.topo_order()?;
    for i in order {
        let node = &graph.nodes[i];
        if opts.standard_onnx_only && (node.domain == DOMAIN_QONNX || node.domain == DOMAIN_FINN) {
            bail!(
                "node '{}' ({}, domain '{}') is not a standard ONNX op — \
                 this backend only executes the stock operator set",
                node.name,
                node.op_type,
                node.domain
            );
        }
        let mut ins: Vec<&Tensor> = Vec::with_capacity(node.inputs.len());
        for name in node.present_inputs() {
            ins.push(
                ctx.get(name)
                    .with_context(|| format!("node '{}' input '{name}' not computed", node.name))?,
            );
        }
        let outs = ops::execute_node(node, &ins)
            .with_context(|| format!("executing node '{}' ({})", node.name, node.op_type))?;
        if outs.len() != node.outputs.len() {
            bail!(
                "node '{}' produced {} outputs, declared {}",
                node.name,
                outs.len(),
                node.outputs.len()
            );
        }
        for (name, t) in node.outputs.iter().zip(outs) {
            ctx.insert(name.clone(), t);
        }
    }

    let mut outputs = BTreeMap::new();
    for vi in &graph.outputs {
        let t = ctx
            .get(&vi.name)
            .with_context(|| format!("graph output '{}' was not produced", vi.name))?;
        outputs.insert(vi.name.clone(), t.clone());
    }
    let intermediates = if opts.keep_intermediates { ctx } else { BTreeMap::new() };
    Ok(ExecResult { outputs, intermediates })
}

/// Convenience: single-input single-output execution.
pub fn execute_simple(graph: &ModelGraph, input: &Tensor) -> Result<Tensor> {
    anyhow::ensure!(graph.inputs.len() == 1, "execute_simple wants exactly 1 graph input");
    anyhow::ensure!(graph.outputs.len() == 1, "execute_simple wants exactly 1 graph output");
    let mut m = BTreeMap::new();
    m.insert(graph.inputs[0].name.clone(), input.clone());
    let r = execute(graph, &m)?;
    Ok(r.outputs.values().next().unwrap().clone())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ir::GraphBuilder;

    fn quant_relu_graph() -> ModelGraph {
        let mut b = GraphBuilder::new("qr");
        b.input("x", vec![1, 4]);
        b.node("Relu", &["x"], &["a"], &[]);
        b.quant("a", "y", 0.5, 0.0, 4.0, false, false, "ROUND");
        b.output("y", vec![1, 4]);
        b.finish().unwrap()
    }

    #[test]
    fn executes_quant_relu() {
        let g = quant_relu_graph();
        let x = Tensor::new(vec![1, 4], vec![-1.0, 0.3, 0.26, 99.0]);
        let y = execute_simple(&g, &x).unwrap();
        // relu then uint4 quant at scale .5: max 7.5
        assert_eq!(y.as_f32().unwrap(), &[0.0, 0.5, 0.5, 7.5]);
    }

    #[test]
    fn standard_only_rejects_qonnx_nodes() {
        let g = quant_relu_graph();
        let mut m = BTreeMap::new();
        m.insert("x".to_string(), Tensor::zeros(vec![1, 4]));
        let opts = ExecOptions { standard_onnx_only: true, ..Default::default() };
        let err = execute_with(&g, &m, &opts).unwrap_err();
        assert!(err.to_string().contains("not a standard ONNX op"));
    }

    #[test]
    fn missing_input_reported() {
        let g = quant_relu_graph();
        let m = BTreeMap::new();
        assert!(execute(&g, &m).is_err());
    }

    #[test]
    fn shape_mismatch_reported() {
        let g = quant_relu_graph();
        let mut m = BTreeMap::new();
        m.insert("x".to_string(), Tensor::zeros(vec![2, 4]));
        assert!(execute(&g, &m).is_err());
    }

    #[test]
    fn intermediates_recorded() {
        let g = quant_relu_graph();
        let mut m = BTreeMap::new();
        m.insert("x".to_string(), Tensor::new(vec![1, 4], vec![1.0; 4]));
        let opts = ExecOptions { keep_intermediates: true, ..Default::default() };
        let r = execute_with(&g, &m, &opts).unwrap();
        assert!(r.intermediates.contains_key("a"));
    }
}

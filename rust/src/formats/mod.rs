//! The six ONNX-based QNN format descriptors of Table I, with
//! *code-backed* capability probes.
//!
//! Each cell of Table I is, where possible, demonstrated by running an
//! actual witness: constructing a QONNX graph exercising the capability
//! and attempting to lower/execute it in the target format. Cells that are
//! definitional properties of the upstream ONNX spec (e.g. "the quantized
//! operator format duplicates Conv as QLinearConv") are encoded as
//! constants with the spec reference in the evidence string.

use crate::ir::GraphBuilder;
use crate::tensor::Tensor;
use crate::transforms::{lower_to_qcdq, lower_to_qop_clip};

/// One Table I capability column.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Capability {
    ArbitraryPrecision,
    RoundingVariants,
    Below8Bits,
    WeightsOnly,
    AvoidOpDuplication,
    HighPrecisionOutput,
}

pub const CAPABILITIES: &[Capability] = &[
    Capability::ArbitraryPrecision,
    Capability::RoundingVariants,
    Capability::Below8Bits,
    Capability::WeightsOnly,
    Capability::AvoidOpDuplication,
    Capability::HighPrecisionOutput,
];

impl Capability {
    pub fn title(&self) -> &'static str {
        match self {
            Capability::ArbitraryPrecision => "Arbitrary precision",
            Capability::RoundingVariants => "Rounding variants",
            Capability::Below8Bits => "Below 8-bits precision",
            Capability::WeightsOnly => "Weights-only quantization",
            Capability::AvoidOpDuplication => "Avoid op. duplication",
            Capability::HighPrecisionOutput => "High-precision output",
        }
    }
}

/// A Table I row: format × capability verdicts with evidence.
#[derive(Debug, Clone)]
pub struct FormatRow {
    pub format: &'static str,
    pub verdicts: Vec<(Capability, bool, String)>,
}

impl FormatRow {
    pub fn supports(&self, c: Capability) -> bool {
        self.verdicts.iter().find(|(v, _, _)| *v == c).map(|(_, s, _)| *s).unwrap_or(false)
    }
}

/// Witness graph: a single Quant node at the given precision/mode.
fn quant_witness(bits: f32, mode: &str) -> crate::ir::ModelGraph {
    let mut b = GraphBuilder::new("witness");
    b.input("x", vec![1, 4]);
    b.quant("x", "y", 0.5, 0.0, bits, true, false, mode);
    b.output("y", vec![1, 4]);
    b.finish().unwrap()
}

/// Witness graph: weights-only quantization (float activations).
fn weights_only_witness() -> crate::ir::ModelGraph {
    let mut b = GraphBuilder::new("wo");
    b.input("x", vec![1, 4]);
    b.initializer("w", Tensor::zeros(vec![4, 2]));
    b.quant("w", "wq", 0.5, 0.0, 4.0, true, false, "ROUND");
    b.node("MatMul", &["x", "wq"], &["y"], &[]);
    b.output("y", vec![1, 2]);
    b.finish().unwrap()
}

/// Probe all six formats. Each verdict carries a one-line evidence string
/// (probe result or spec citation).
pub fn probe_all() -> Vec<FormatRow> {
    use Capability::*;
    let spec = |s: &str| s.to_string();

    // --- QONNX: probes run through the reference executor -------------
    let qonnx_arbitrary = crate::exec::execute_simple(&quant_witness(17.0, "ROUND"), &Tensor::zeros(vec![1, 4])).is_ok();
    let qonnx_rounding = crate::exec::execute_simple(&quant_witness(4.0, "FLOOR"), &Tensor::zeros(vec![1, 4])).is_ok();
    let qonnx_below8 = crate::exec::execute_simple(&quant_witness(3.0, "ROUND"), &Tensor::zeros(vec![1, 4])).is_ok();
    let qonnx_weights_only =
        crate::exec::execute_simple(&weights_only_witness(), &Tensor::zeros(vec![1, 4])).is_ok();

    // --- QCDQ: probes via the lowering pass ---------------------------
    let qcdq_below8 = lower_to_qcdq(&mut quant_witness(4.0, "ROUND")).is_ok();
    let qcdq_arbitrary = lower_to_qcdq(&mut quant_witness(9.0, "ROUND")).is_ok();
    let qcdq_rounding = lower_to_qcdq(&mut quant_witness(4.0, "FLOOR")).is_ok();
    let qcdq_weights_only = lower_to_qcdq(&mut weights_only_witness()).is_ok();

    // --- quantized operator with clipping: probes via its lowering ----
    let qop_weights_only = lower_to_qop_clip(&mut weights_only_witness()).is_ok();

    vec![
        FormatRow {
            format: "QONNX (this work)",
            verdicts: vec![
                (ArbitraryPrecision, qonnx_arbitrary, spec("probe: 17-bit Quant executed")),
                (RoundingVariants, qonnx_rounding, spec("probe: FLOOR-mode Quant executed")),
                (Below8Bits, qonnx_below8, spec("probe: 3-bit Quant executed")),
                (WeightsOnly, qonnx_weights_only, spec("probe: Quant on weights only executed")),
                (AvoidOpDuplication, true, spec("3 ops (Quant/BipolarQuant/Trunc) cover all layers")),
                (HighPrecisionOutput, true, spec("outputs stay float32; no fused requantization")),
            ],
        },
        FormatRow {
            format: "QCDQ (this work)",
            verdicts: vec![
                (ArbitraryPrecision, qcdq_arbitrary, spec("probe: 9-bit lowering refused (QuantizeLinear is 8-bit)")),
                (RoundingVariants, qcdq_rounding, spec("probe: FLOOR lowering refused (QuantizeLinear rounds half-even)")),
                (Below8Bits, qcdq_below8, spec("probe: 4-bit lowered to QuantizeLinear+Clip+DequantizeLinear")),
                (WeightsOnly, qcdq_weights_only, spec("probe: weight-only Quant lowered")),
                (AvoidOpDuplication, true, spec("reuses QuantizeLinear/Clip/DequantizeLinear for every layer")),
                (HighPrecisionOutput, true, spec("no fused output requantization; DQ output is float32")),
            ],
        },
        FormatRow {
            format: "Quantized op. with clipping (this work)",
            verdicts: vec![
                (ArbitraryPrecision, false, spec("QLinear* ops are int8-only (ONNX opset 16)")),
                (RoundingVariants, false, spec("QLinear* requantization rounding is fixed")),
                (Below8Bits, true, spec("probe below: Clip narrows the fused 8-bit output")),
                (WeightsOnly, qop_weights_only, spec("probe: weights-only pattern refused (needs full QLinear pattern)")),
                (AvoidOpDuplication, false, spec("Conv/QLinearConv, MatMul/QLinearMatMul duplicated")),
                (HighPrecisionOutput, false, spec("output requantization is fused into the operator")),
            ],
        },
        FormatRow {
            format: "QDQ [ONNX]",
            verdicts: vec![
                (ArbitraryPrecision, false, spec("QuantizeLinear output restricted to 8-bit types")),
                (RoundingVariants, false, spec("round-half-even only")),
                (Below8Bits, false, spec("no clipping mechanism; 8-bit grid only")),
                (WeightsOnly, true, spec("QDQ pairs attach to any tensor")),
                (AvoidOpDuplication, true, spec("two ops reused everywhere")),
                (HighPrecisionOutput, true, spec("standard operators run on dequantized float32")),
            ],
        },
        FormatRow {
            format: "Integer op. [ONNX]",
            verdicts: vec![
                (ArbitraryPrecision, false, spec("ConvInteger/MatMulInteger are int8-only")),
                (RoundingVariants, false, spec("no rounding control")),
                (Below8Bits, false, spec("int8 inputs only")),
                (WeightsOnly, false, spec("both operands must be integer")),
                (AvoidOpDuplication, false, spec("ConvInteger duplicates Conv")),
                (HighPrecisionOutput, true, spec("int32 accumulator exposed (probe in ops::qlinear tests)")),
            ],
        },
        FormatRow {
            format: "Quantized op. [ONNX]",
            verdicts: vec![
                (ArbitraryPrecision, false, spec("QLinear* ops are int8-only")),
                (RoundingVariants, false, spec("fixed requantization rounding")),
                (Below8Bits, false, spec("no clipping in the stock format")),
                (WeightsOnly, false, spec("operator carries input+weight+output quantization")),
                (AvoidOpDuplication, false, spec("QLinearConv duplicates Conv")),
                (HighPrecisionOutput, false, spec("fused requantization to int8")),
            ],
        },
    ]
}

/// Render the Table I matrix as text (the bench prints this).
pub fn render_table() -> String {
    let rows = probe_all();
    let mut s = String::new();
    s.push_str(&format!("{:<42}", "Format"));
    for c in CAPABILITIES {
        s.push_str(&format!("{:<28}", c.title()));
    }
    s.push('\n');
    for row in &rows {
        s.push_str(&format!("{:<42}", row.format));
        for c in CAPABILITIES {
            s.push_str(&format!("{:<28}", if row.supports(*c) { "yes" } else { "no" }));
        }
        s.push('\n');
    }
    s
}

#[cfg(test)]
mod tests {
    use super::*;
    use Capability::*;

    /// The expected Table I matrix, row by row (paper Table I).
    #[test]
    fn matches_paper_table_i() {
        let rows = probe_all();
        let expect: &[(&str, [bool; 6])] = &[
            ("QONNX", [true, true, true, true, true, true]),
            ("QCDQ", [false, false, true, true, true, true]),
            ("Quantized op. with clipping", [false, false, true, false, false, false]),
            ("QDQ", [false, false, false, true, true, true]),
            ("Integer op.", [false, false, false, false, false, true]),
            ("Quantized op.", [false, false, false, false, false, false]),
        ];
        for (i, (name, caps)) in expect.iter().enumerate() {
            assert!(rows[i].format.starts_with(name), "row {i}: {} vs {name}", rows[i].format);
            for (j, c) in CAPABILITIES.iter().enumerate() {
                assert_eq!(
                    rows[i].supports(*c),
                    caps[j],
                    "{name} / {:?} disagrees with Table I",
                    c
                );
            }
        }
    }

    #[test]
    fn render_includes_all_rows() {
        let t = render_table();
        assert_eq!(t.lines().count(), 7);
        assert!(t.contains("QONNX"));
    }

    #[test]
    fn every_cell_has_evidence() {
        for row in probe_all() {
            assert_eq!(row.verdicts.len(), 6);
            for (c, _, ev) in &row.verdicts {
                assert!(!ev.is_empty(), "{} / {:?} lacks evidence", row.format, c);
            }
        }
    }

    #[test]
    fn qonnx_strictly_dominates() {
        // the paper's point: QONNX is the only all-yes row
        let rows = probe_all();
        assert!(CAPABILITIES.iter().all(|c| rows[0].supports(*c)));
        for row in &rows[1..] {
            assert!(CAPABILITIES.iter().any(|c| !row.supports(*c)), "{} ties QONNX", row.format);
        }
    }
}

//! Node attribute values — the ONNX AttributeProto payloads we need.

use crate::tensor::Tensor;
use anyhow::{bail, Result};

/// An attribute value attached to a [`crate::ir::Node`].
#[derive(Debug, Clone, PartialEq)]
pub enum AttrValue {
    Int(i64),
    Float(f32),
    Str(String),
    Ints(Vec<i64>),
    Floats(Vec<f32>),
    Tensor(Tensor),
}

impl AttrValue {
    pub fn as_int(&self) -> Result<i64> {
        match self {
            AttrValue::Int(v) => Ok(*v),
            other => bail!("attribute is not an int: {other:?}"),
        }
    }

    pub fn as_float(&self) -> Result<f32> {
        match self {
            AttrValue::Float(v) => Ok(*v),
            AttrValue::Int(v) => Ok(*v as f32),
            other => bail!("attribute is not a float: {other:?}"),
        }
    }

    pub fn as_str(&self) -> Result<&str> {
        match self {
            AttrValue::Str(v) => Ok(v),
            other => bail!("attribute is not a string: {other:?}"),
        }
    }

    pub fn as_ints(&self) -> Result<&[i64]> {
        match self {
            AttrValue::Ints(v) => Ok(v),
            other => bail!("attribute is not an int list: {other:?}"),
        }
    }

    pub fn as_floats(&self) -> Result<&[f32]> {
        match self {
            AttrValue::Floats(v) => Ok(v),
            other => bail!("attribute is not a float list: {other:?}"),
        }
    }

    pub fn as_tensor(&self) -> Result<&Tensor> {
        match self {
            AttrValue::Tensor(v) => Ok(v),
            other => bail!("attribute is not a tensor: {other:?}"),
        }
    }
}

impl From<i64> for AttrValue {
    fn from(v: i64) -> Self {
        AttrValue::Int(v)
    }
}
impl From<f32> for AttrValue {
    fn from(v: f32) -> Self {
        AttrValue::Float(v)
    }
}
impl From<&str> for AttrValue {
    fn from(v: &str) -> Self {
        AttrValue::Str(v.to_string())
    }
}
impl From<String> for AttrValue {
    fn from(v: String) -> Self {
        AttrValue::Str(v)
    }
}
impl From<Vec<i64>> for AttrValue {
    fn from(v: Vec<i64>) -> Self {
        AttrValue::Ints(v)
    }
}
impl From<Vec<f32>> for AttrValue {
    fn from(v: Vec<f32>) -> Self {
        AttrValue::Floats(v)
    }
}
impl From<Tensor> for AttrValue {
    fn from(v: Tensor) -> Self {
        AttrValue::Tensor(v)
    }
}
impl From<bool> for AttrValue {
    fn from(v: bool) -> Self {
        AttrValue::Int(i64::from(v))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn accessors() {
        assert_eq!(AttrValue::from(3i64).as_int().unwrap(), 3);
        assert_eq!(AttrValue::from(2.5f32).as_float().unwrap(), 2.5);
        // int coerces to float (ONNX exporters are sloppy here)
        assert_eq!(AttrValue::from(2i64).as_float().unwrap(), 2.0);
        assert_eq!(AttrValue::from("ROUND").as_str().unwrap(), "ROUND");
        assert_eq!(AttrValue::from(vec![1i64, 2]).as_ints().unwrap(), &[1, 2]);
        assert!(AttrValue::from(1i64).as_str().is_err());
        assert_eq!(AttrValue::from(true).as_int().unwrap(), 1);
    }
}

//! Fluent graph construction API used by the model zoo and tests.

use super::{AttrValue, ModelGraph, Node, ValueInfo, DOMAIN_QONNX};
use crate::datatypes::DataType;
use crate::tensor::Tensor;

/// Builder for [`ModelGraph`]s.
///
/// ```no_run
/// // (no_run: doctest binaries lack the xla rpath in this environment)
/// use qonnx::ir::GraphBuilder;
/// use qonnx::tensor::Tensor;
/// let mut b = GraphBuilder::new("tiny");
/// b.input("x", vec![1, 4]);
/// b.initializer("w", Tensor::zeros(vec![4, 2]));
/// b.node("MatMul", &["x", "w"], &["y"], &[]);
/// b.output("y", vec![1, 2]);
/// let g = b.finish().unwrap();
/// assert_eq!(g.nodes.len(), 1);
/// ```
pub struct GraphBuilder {
    graph: ModelGraph,
    counter: usize,
}

impl GraphBuilder {
    pub fn new(name: &str) -> GraphBuilder {
        let mut graph = ModelGraph::new(name);
        graph.opset.insert(String::new(), 16);
        graph.opset.insert(DOMAIN_QONNX.to_string(), 1);
        GraphBuilder { graph, counter: 0 }
    }

    /// Declare a graph input.
    pub fn input(&mut self, name: &str, shape: Vec<usize>) -> &mut Self {
        self.graph.inputs.push(ValueInfo::new(name, shape));
        self
    }

    /// Declare a graph input with a datatype annotation.
    pub fn input_dt(&mut self, name: &str, shape: Vec<usize>, dt: DataType) -> &mut Self {
        self.graph.inputs.push(ValueInfo::new(name, shape).with_dtype(dt));
        self
    }

    /// Declare a graph output.
    pub fn output(&mut self, name: &str, shape: Vec<usize>) -> &mut Self {
        self.graph.outputs.push(ValueInfo::new(name, shape));
        self
    }

    /// Declare a graph output with unknown shape (filled by shape inference).
    pub fn output_unknown(&mut self, name: &str) -> &mut Self {
        self.graph.outputs.push(ValueInfo::unknown(name));
        self
    }

    /// Bind a constant tensor.
    pub fn initializer(&mut self, name: &str, t: Tensor) -> &mut Self {
        self.graph.initializers.insert(name.to_string(), t);
        self
    }

    /// Bind a scalar f32 constant.
    pub fn scalar(&mut self, name: &str, v: f32) -> &mut Self {
        self.initializer(name, Tensor::scalar(v))
    }

    /// Append a standard-domain node with attributes.
    pub fn node(
        &mut self,
        op_type: &str,
        inputs: &[&str],
        outputs: &[&str],
        attrs: &[(&str, AttrValue)],
    ) -> &mut Self {
        self.node_in_domain("", op_type, inputs, outputs, attrs)
    }

    /// Append a node in an explicit domain.
    pub fn node_in_domain(
        &mut self,
        domain: &str,
        op_type: &str,
        inputs: &[&str],
        outputs: &[&str],
        attrs: &[(&str, AttrValue)],
    ) -> &mut Self {
        let mut n = Node::new(op_type, inputs, outputs)
            .with_domain(domain)
            .with_name(&format!("{}_{}", op_type, self.counter));
        self.counter += 1;
        for (k, v) in attrs {
            n.attrs.insert((*k).to_string(), v.clone());
        }
        self.graph.nodes.push(n);
        self
    }

    /// Append a QONNX `Quant` node with scalar scale/zero-point/bit-width
    /// initializers; returns the output tensor name.
    #[allow(clippy::too_many_arguments)]
    pub fn quant(
        &mut self,
        x: &str,
        y: &str,
        scale: f32,
        zero_point: f32,
        bit_width: f32,
        signed: bool,
        narrow: bool,
        rounding_mode: &str,
    ) -> &mut Self {
        let s = format!("{y}_scale");
        let z = format!("{y}_zeropt");
        let b = format!("{y}_bitwidth");
        self.scalar(&s, scale);
        self.scalar(&z, zero_point);
        self.scalar(&b, bit_width);
        self.node_in_domain(
            DOMAIN_QONNX,
            "Quant",
            &[x, &s, &z, &b],
            &[y],
            &[
                ("signed", AttrValue::from(signed)),
                ("narrow", AttrValue::from(narrow)),
                ("rounding_mode", AttrValue::from(rounding_mode)),
            ],
        )
    }

    /// Quant with a tensor-valued scale (channel-wise).
    #[allow(clippy::too_many_arguments)]
    pub fn quant_tensor_scale(
        &mut self,
        x: &str,
        y: &str,
        scale: Tensor,
        zero_point: f32,
        bit_width: f32,
        signed: bool,
        narrow: bool,
    ) -> &mut Self {
        let s = format!("{y}_scale");
        let z = format!("{y}_zeropt");
        let b = format!("{y}_bitwidth");
        self.initializer(&s, scale);
        self.scalar(&z, zero_point);
        self.scalar(&b, bit_width);
        self.node_in_domain(
            DOMAIN_QONNX,
            "Quant",
            &[x, &s, &z, &b],
            &[y],
            &[
                ("signed", AttrValue::from(signed)),
                ("narrow", AttrValue::from(narrow)),
                ("rounding_mode", AttrValue::from("ROUND")),
            ],
        )
    }

    /// Append a QONNX `BipolarQuant` node with scalar scale.
    pub fn bipolar_quant(&mut self, x: &str, y: &str, scale: f32) -> &mut Self {
        let s = format!("{y}_scale");
        self.scalar(&s, scale);
        self.node_in_domain(DOMAIN_QONNX, "BipolarQuant", &[x, &s], &[y], &[])
    }

    /// Validate and return the graph.
    pub fn finish(mut self) -> anyhow::Result<ModelGraph> {
        self.graph.sort_topologically()?;
        self.graph.validate()?;
        Ok(self.graph)
    }

    /// Return the graph without validation (for intentionally-odd test
    /// graphs, e.g. raw-export shapes for Fig. 1).
    pub fn finish_unchecked(self) -> ModelGraph {
        self.graph
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn builds_quant_chain() {
        let mut b = GraphBuilder::new("t");
        b.input("x", vec![1, 8]);
        b.quant("x", "xq", 0.5, 0.0, 4.0, true, false, "ROUND");
        b.output("xq", vec![1, 8]);
        let g = b.finish().unwrap();
        assert_eq!(g.nodes.len(), 1);
        assert_eq!(g.nodes[0].domain, DOMAIN_QONNX);
        assert!(g.initializers.contains_key("xq_scale"));
        assert_eq!(g.initializers["xq_bitwidth"].scalar_value().unwrap(), 4.0);
    }

    #[test]
    fn node_names_unique() {
        let mut b = GraphBuilder::new("t");
        b.input("x", vec![1]);
        b.node("Relu", &["x"], &["a"], &[]);
        b.node("Relu", &["a"], &["y"], &[]);
        b.output("y", vec![1]);
        let g = b.finish().unwrap();
        assert_ne!(g.nodes[0].name, g.nodes[1].name);
    }
}

//! `ModelGraph`: the ONNX ModelProto/GraphProto analog plus QONNX tensor
//! datatype annotations, with the structural queries the transform passes
//! need (producer/consumer maps, topological sort, rewiring helpers).

use super::node::Node;
use crate::datatypes::DataType;
use crate::tensor::Tensor;
use anyhow::{anyhow, bail, Result};
use std::collections::{BTreeMap, BTreeSet};

/// Shape/datatype annotation for a graph input, output, or internal tensor.
#[derive(Debug, Clone, PartialEq)]
pub struct ValueInfo {
    pub name: String,
    /// Known static shape, if inferred/declared.
    pub shape: Option<Vec<usize>>,
    /// QONNX arbitrary-precision annotation (container is always float32).
    pub dtype: DataType,
}

impl ValueInfo {
    pub fn new(name: &str, shape: Vec<usize>) -> ValueInfo {
        ValueInfo { name: name.to_string(), shape: Some(shape), dtype: DataType::Float32 }
    }

    pub fn unknown(name: &str) -> ValueInfo {
        ValueInfo { name: name.to_string(), shape: None, dtype: DataType::Float32 }
    }

    pub fn with_dtype(mut self, dt: DataType) -> ValueInfo {
        self.dtype = dt;
        self
    }
}

/// A QONNX model: graph structure + initializers + annotations.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct ModelGraph {
    pub name: String,
    /// Documentation / provenance string.
    pub doc: String,
    /// Graph inputs (excluding initializers).
    pub inputs: Vec<ValueInfo>,
    /// Graph outputs.
    pub outputs: Vec<ValueInfo>,
    /// Nodes in (not necessarily topological) order.
    pub nodes: Vec<Node>,
    /// Constant tensors bound to input names.
    pub initializers: BTreeMap<String, Tensor>,
    /// Shape/datatype annotations for intermediate tensors.
    pub value_info: BTreeMap<String, ValueInfo>,
    /// Opset-style metadata (domain -> version); informational.
    pub opset: BTreeMap<String, i64>,
}

impl ModelGraph {
    pub fn new(name: &str) -> ModelGraph {
        ModelGraph { name: name.to_string(), ..Default::default() }
    }

    // ------------------------------------------------------------------
    // Structural queries
    // ------------------------------------------------------------------

    /// Index of the node producing `tensor`, if any.
    pub fn producer(&self, tensor: &str) -> Option<usize> {
        self.nodes.iter().position(|n| n.outputs.iter().any(|o| o == tensor))
    }

    /// Indices of nodes consuming `tensor`.
    pub fn consumers(&self, tensor: &str) -> Vec<usize> {
        self.nodes
            .iter()
            .enumerate()
            .filter(|(_, n)| n.inputs.iter().any(|i| i == tensor))
            .map(|(i, _)| i)
            .collect()
    }

    /// Whether `tensor` is a graph output.
    pub fn is_output(&self, tensor: &str) -> bool {
        self.outputs.iter().any(|o| o.name == tensor)
    }

    /// Whether `tensor` is a graph input.
    pub fn is_input(&self, tensor: &str) -> bool {
        self.inputs.iter().any(|o| o.name == tensor)
    }

    /// Constant lookup: initializer bound to `name`.
    pub fn initializer(&self, name: &str) -> Option<&Tensor> {
        self.initializers.get(name)
    }

    /// Shape annotation for any tensor (inputs, outputs, value_info).
    pub fn tensor_shape(&self, name: &str) -> Option<Vec<usize>> {
        if let Some(t) = self.initializers.get(name) {
            return Some(t.shape().to_vec());
        }
        for vi in self.inputs.iter().chain(self.outputs.iter()) {
            if vi.name == name {
                return vi.shape.clone();
            }
        }
        self.value_info.get(name).and_then(|vi| vi.shape.clone())
    }

    /// QONNX datatype annotation for a tensor (defaults to FLOAT32).
    pub fn tensor_datatype(&self, name: &str) -> DataType {
        for vi in self.inputs.iter().chain(self.outputs.iter()) {
            if vi.name == name {
                return vi.dtype;
            }
        }
        self.value_info.get(name).map(|vi| vi.dtype).unwrap_or(DataType::Float32)
    }

    /// Set the shape annotation for a tensor.
    pub fn set_tensor_shape(&mut self, name: &str, shape: Vec<usize>) {
        for vi in self.inputs.iter_mut().chain(self.outputs.iter_mut()) {
            if vi.name == name {
                vi.shape = Some(shape);
                return;
            }
        }
        self.value_info
            .entry(name.to_string())
            .or_insert_with(|| ValueInfo::unknown(name))
            .shape = Some(shape);
    }

    /// Set the QONNX datatype annotation for a tensor.
    pub fn set_tensor_datatype(&mut self, name: &str, dt: DataType) {
        for vi in self.inputs.iter_mut().chain(self.outputs.iter_mut()) {
            if vi.name == name {
                vi.dtype = dt;
                return;
            }
        }
        self.value_info
            .entry(name.to_string())
            .or_insert_with(|| ValueInfo::unknown(name))
            .dtype = dt;
    }

    /// All tensor names referenced anywhere in the graph.
    pub fn all_tensor_names(&self) -> BTreeSet<String> {
        let mut out = BTreeSet::new();
        for n in &self.nodes {
            for t in n.present_inputs() {
                out.insert(t.to_string());
            }
            for t in &n.outputs {
                out.insert(t.clone());
            }
        }
        for vi in self.inputs.iter().chain(self.outputs.iter()) {
            out.insert(vi.name.clone());
        }
        out.extend(self.initializers.keys().cloned());
        out
    }

    /// A fresh tensor name with the given prefix, unique in this graph.
    pub fn fresh_name(&self, prefix: &str) -> String {
        let names = self.all_tensor_names();
        let mut i = 0usize;
        loop {
            let cand = format!("{prefix}_{i}");
            if !names.contains(&cand) && self.nodes.iter().all(|n| n.name != cand) {
                return cand;
            }
            i += 1;
        }
    }

    // ------------------------------------------------------------------
    // Structural edits
    // ------------------------------------------------------------------

    /// Remove node at `idx`, rewiring its single input to its single output
    /// consumers (identity-removal semantics).
    pub fn remove_node_rewire(&mut self, idx: usize) -> Result<()> {
        let node = self.nodes[idx].clone();
        let src = node
            .present_inputs()
            .next()
            .ok_or_else(|| anyhow!("cannot rewire node '{}' with no inputs", node.name))?
            .to_string();
        let dst = node.outputs.first().cloned().ok_or_else(|| anyhow!("node has no output"))?;
        self.nodes.remove(idx);
        if self.is_output(&dst) {
            // keep graph output name stable: repoint the producer of src
            if let Some(p) = self.producer(&src) {
                for o in &mut self.nodes[p].outputs {
                    if *o == src {
                        *o = dst.clone();
                    }
                }
                // anything else consuming src must follow the rename
                for n in &mut self.nodes {
                    for i in &mut n.inputs {
                        if *i == src {
                            *i = dst.clone();
                        }
                    }
                }
            } else if let Some(t) = self.initializers.remove(&src) {
                self.initializers.insert(dst.clone(), t);
            } else {
                bail!("cannot rewire: output '{dst}' fed by graph input '{src}'");
            }
        } else {
            for n in &mut self.nodes {
                for i in &mut n.inputs {
                    if *i == dst {
                        *i = src.clone();
                    }
                }
            }
        }
        Ok(())
    }

    /// Topologically sorted node indices. Errors on cycles.
    pub fn topo_order(&self) -> Result<Vec<usize>> {
        let n = self.nodes.len();
        // tensor -> producing node
        let mut producer_of: BTreeMap<&str, usize> = BTreeMap::new();
        for (i, node) in self.nodes.iter().enumerate() {
            for o in &node.outputs {
                producer_of.insert(o.as_str(), i);
            }
        }
        let mut indeg = vec![0usize; n];
        let mut succ: Vec<Vec<usize>> = vec![Vec::new(); n];
        for (i, node) in self.nodes.iter().enumerate() {
            for inp in node.present_inputs() {
                if let Some(&p) = producer_of.get(inp) {
                    succ[p].push(i);
                    indeg[i] += 1;
                }
            }
        }
        let mut queue: Vec<usize> = (0..n).filter(|&i| indeg[i] == 0).collect();
        let mut order = Vec::with_capacity(n);
        while let Some(i) = queue.pop() {
            order.push(i);
            for &s in &succ[i] {
                indeg[s] -= 1;
                if indeg[s] == 0 {
                    queue.push(s);
                }
            }
        }
        if order.len() != n {
            bail!("graph '{}' contains a cycle", self.name);
        }
        Ok(order)
    }

    /// Re-order `self.nodes` topologically in place.
    pub fn sort_topologically(&mut self) -> Result<()> {
        let order = self.topo_order()?;
        let mut new_nodes = Vec::with_capacity(self.nodes.len());
        for i in order {
            new_nodes.push(self.nodes[i].clone());
        }
        self.nodes = new_nodes;
        Ok(())
    }

    /// Basic well-formedness checks: unique outputs, inputs resolvable,
    /// acyclic.
    pub fn validate(&self) -> Result<()> {
        let mut produced: BTreeSet<&str> = BTreeSet::new();
        for node in &self.nodes {
            for o in &node.outputs {
                if !produced.insert(o.as_str()) {
                    bail!("tensor '{o}' produced by more than one node");
                }
            }
        }
        let available: BTreeSet<&str> = self
            .inputs
            .iter()
            .map(|vi| vi.name.as_str())
            .chain(self.initializers.keys().map(|s| s.as_str()))
            .chain(produced.iter().copied())
            .collect();
        for node in &self.nodes {
            for inp in node.present_inputs() {
                if !available.contains(inp) {
                    bail!("node '{}' ({}) input '{inp}' is not produced anywhere", node.name, node.op_type);
                }
            }
        }
        for out in &self.outputs {
            if !available.contains(out.name.as_str()) {
                bail!("graph output '{}' is not produced", out.name);
            }
        }
        self.topo_order()?;
        Ok(())
    }

    /// Count of nodes by op_type — handy for Fig. 1/2/3 style comparisons.
    pub fn op_histogram(&self) -> BTreeMap<String, usize> {
        let mut h = BTreeMap::new();
        for n in &self.nodes {
            *h.entry(n.op_type.clone()).or_insert(0) += 1;
        }
        h
    }

    /// Human-readable node listing (op sequence), used by the figure
    /// regeneration benches.
    pub fn summary(&self) -> String {
        let mut s = String::new();
        s.push_str(&format!("graph {} ({} nodes)\n", self.name, self.nodes.len()));
        for n in &self.nodes {
            let shapes: Vec<String> = n
                .outputs
                .iter()
                .map(|o| match self.tensor_shape(o) {
                    Some(sh) => format!("{o}:{sh:?}:{}", self.tensor_datatype(o)),
                    None => format!("{o}:?"),
                })
                .collect();
            s.push_str(&format!("  {:<18} {:<14} -> {}\n", n.op_type, n.name, shapes.join(", ")));
        }
        s
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn diamond() -> ModelGraph {
        // in -> A -> (b, c); b -> B -> d; c -> C -> e; (d,e) -> D -> out
        let mut g = ModelGraph::new("diamond");
        g.inputs.push(ValueInfo::new("in", vec![1]));
        g.outputs.push(ValueInfo::new("out", vec![1]));
        g.nodes.push(Node::new("Relu", &["b"], &["d"]).with_name("B"));
        g.nodes.push(Node::new("Add", &["d", "e"], &["out"]).with_name("D"));
        g.nodes.push(Node::new("Split2", &["in"], &["b", "c"]).with_name("A"));
        g.nodes.push(Node::new("Relu", &["c"], &["e"]).with_name("C"));
        g
    }

    #[test]
    fn producer_consumer() {
        let g = diamond();
        assert_eq!(g.nodes[g.producer("d").unwrap()].name, "B");
        assert_eq!(g.producer("in"), None);
        let cons = g.consumers("d");
        assert_eq!(cons.len(), 1);
        assert_eq!(g.nodes[cons[0]].name, "D");
    }

    #[test]
    fn topo_sort_fixes_order() {
        let mut g = diamond();
        g.sort_topologically().unwrap();
        let pos = |name: &str| g.nodes.iter().position(|n| n.name == name).unwrap();
        assert!(pos("A") < pos("B"));
        assert!(pos("A") < pos("C"));
        assert!(pos("B") < pos("D"));
        assert!(pos("C") < pos("D"));
        g.validate().unwrap();
    }

    #[test]
    fn cycle_detected() {
        let mut g = ModelGraph::new("cyc");
        g.inputs.push(ValueInfo::new("in", vec![1]));
        g.nodes.push(Node::new("Add", &["in", "b"], &["a"]).with_name("n0"));
        g.nodes.push(Node::new("Relu", &["a"], &["b"]).with_name("n1"));
        assert!(g.topo_order().is_err());
    }

    #[test]
    fn validate_catches_dangling_input() {
        let mut g = ModelGraph::new("bad");
        g.inputs.push(ValueInfo::new("in", vec![1]));
        g.nodes.push(Node::new("Relu", &["nonexistent"], &["y"]).with_name("r"));
        assert!(g.validate().is_err());
    }

    #[test]
    fn remove_node_rewire_middle() {
        let mut g = ModelGraph::new("chain");
        g.inputs.push(ValueInfo::new("in", vec![1]));
        g.outputs.push(ValueInfo::new("out", vec![1]));
        g.nodes.push(Node::new("Relu", &["in"], &["a"]).with_name("r0"));
        g.nodes.push(Node::new("Identity", &["a"], &["b"]).with_name("id"));
        g.nodes.push(Node::new("Relu", &["b"], &["out"]).with_name("r1"));
        g.remove_node_rewire(1).unwrap();
        assert_eq!(g.nodes.len(), 2);
        assert_eq!(g.nodes[1].inputs[0], "a");
        g.validate().unwrap();
    }

    #[test]
    fn remove_node_rewire_at_output() {
        let mut g = ModelGraph::new("chain");
        g.inputs.push(ValueInfo::new("in", vec![1]));
        g.outputs.push(ValueInfo::new("out", vec![1]));
        g.nodes.push(Node::new("Relu", &["in"], &["a"]).with_name("r0"));
        g.nodes.push(Node::new("Identity", &["a"], &["out"]).with_name("id"));
        g.remove_node_rewire(1).unwrap();
        assert_eq!(g.nodes.len(), 1);
        assert_eq!(g.nodes[0].outputs[0], "out");
        g.validate().unwrap();
    }

    #[test]
    fn annotations() {
        let mut g = diamond();
        g.set_tensor_datatype("d", crate::datatypes::DataType::Int(4));
        assert_eq!(g.tensor_datatype("d"), crate::datatypes::DataType::Int(4));
        assert_eq!(g.tensor_datatype("e"), crate::datatypes::DataType::Float32);
        g.set_tensor_shape("d", vec![1, 2]);
        assert_eq!(g.tensor_shape("d"), Some(vec![1, 2]));
    }

    #[test]
    fn fresh_names_unique() {
        let g = diamond();
        let n1 = g.fresh_name("b");
        assert!(!g.all_tensor_names().contains(&n1));
    }
}

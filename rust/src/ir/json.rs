//! Hand-rolled JSON (de)serialization for model files (`.qonnx.json`).
//!
//! The vendored crate set has no serde, so this module provides a minimal
//! but complete JSON value type, parser, and printer, plus the mapping
//! between [`ModelGraph`] and JSON. Field names mirror ONNX protobuf.

use super::{AttrValue, ModelGraph, Node, ValueInfo};
use crate::datatypes::DataType;
use crate::tensor::Tensor;
use anyhow::{anyhow, bail, Context, Result};
use std::collections::BTreeMap;

/// A JSON value.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn obj(entries: Vec<(&str, Json)>) -> Json {
        Json::Obj(entries.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    pub fn req(&self, key: &str) -> Result<&Json> {
        self.get(key).ok_or_else(|| anyhow!("missing field '{key}'"))
    }

    pub fn as_str(&self) -> Result<&str> {
        match self {
            Json::Str(s) => Ok(s),
            other => bail!("expected string, got {other:?}"),
        }
    }

    pub fn as_f64(&self) -> Result<f64> {
        match self {
            Json::Num(n) => Ok(*n),
            other => bail!("expected number, got {other:?}"),
        }
    }

    pub fn as_i64(&self) -> Result<i64> {
        Ok(self.as_f64()? as i64)
    }

    pub fn as_arr(&self) -> Result<&[Json]> {
        match self {
            Json::Arr(a) => Ok(a),
            other => bail!("expected array, got {other:?}"),
        }
    }

    pub fn as_obj(&self) -> Result<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Ok(m),
            other => bail!("expected object, got {other:?}"),
        }
    }

    /// Serialize to a compact JSON string.
    pub fn to_string(&self) -> String {
        let mut s = String::new();
        self.write(&mut s);
        s
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 1e15 {
                    out.push_str(&format!("{}", *n as i64));
                } else {
                    // {:?} on f64 gives the shortest round-tripping repr
                    out.push_str(&format!("{n:?}"));
                }
            }
            Json::Str(s) => {
                out.push('"');
                for c in s.chars() {
                    match c {
                        '"' => out.push_str("\\\""),
                        '\\' => out.push_str("\\\\"),
                        '\n' => out.push_str("\\n"),
                        '\r' => out.push_str("\\r"),
                        '\t' => out.push_str("\\t"),
                        c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
                        c => out.push(c),
                    }
                }
                out.push('"');
            }
            Json::Arr(items) => {
                out.push('[');
                for (i, item) in items.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    item.write(out);
                }
                out.push(']');
            }
            Json::Obj(map) => {
                out.push('{');
                for (i, (k, v)) in map.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    Json::Str(k.clone()).write(out);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }

    /// Parse a JSON document.
    pub fn parse(text: &str) -> Result<Json> {
        let bytes = text.as_bytes();
        let mut pos = 0usize;
        let v = parse_value(bytes, &mut pos)?;
        skip_ws(bytes, &mut pos);
        if pos != bytes.len() {
            bail!("trailing characters at offset {pos}");
        }
        Ok(v)
    }
}

fn skip_ws(b: &[u8], pos: &mut usize) {
    while *pos < b.len() && matches!(b[*pos], b' ' | b'\t' | b'\n' | b'\r') {
        *pos += 1;
    }
}

fn parse_value(b: &[u8], pos: &mut usize) -> Result<Json> {
    skip_ws(b, pos);
    if *pos >= b.len() {
        bail!("unexpected end of input");
    }
    match b[*pos] {
        b'{' => parse_obj(b, pos),
        b'[' => parse_arr(b, pos),
        b'"' => Ok(Json::Str(parse_string(b, pos)?)),
        b't' => {
            expect(b, pos, "true")?;
            Ok(Json::Bool(true))
        }
        b'f' => {
            expect(b, pos, "false")?;
            Ok(Json::Bool(false))
        }
        b'n' => {
            expect(b, pos, "null")?;
            Ok(Json::Null)
        }
        _ => parse_num(b, pos),
    }
}

fn expect(b: &[u8], pos: &mut usize, word: &str) -> Result<()> {
    if b.len() - *pos >= word.len() && &b[*pos..*pos + word.len()] == word.as_bytes() {
        *pos += word.len();
        Ok(())
    } else {
        bail!("expected '{word}' at offset {}", *pos);
    }
}

fn parse_num(b: &[u8], pos: &mut usize) -> Result<Json> {
    let start = *pos;
    while *pos < b.len()
        && matches!(b[*pos], b'-' | b'+' | b'.' | b'e' | b'E' | b'0'..=b'9')
    {
        *pos += 1;
    }
    let s = std::str::from_utf8(&b[start..*pos])?;
    let n: f64 = s.parse().with_context(|| format!("bad number '{s}' at offset {start}"))?;
    Ok(Json::Num(n))
}

fn parse_string(b: &[u8], pos: &mut usize) -> Result<String> {
    if *pos >= b.len() || b[*pos] != b'"' {
        bail!("expected string at offset {}", *pos);
    }
    *pos += 1;
    let mut out = String::new();
    loop {
        if *pos >= b.len() {
            bail!("unterminated string");
        }
        match b[*pos] {
            b'"' => {
                *pos += 1;
                return Ok(out);
            }
            b'\\' => {
                *pos += 1;
                if *pos >= b.len() {
                    bail!("unterminated escape");
                }
                match b[*pos] {
                    b'"' => out.push('"'),
                    b'\\' => out.push('\\'),
                    b'/' => out.push('/'),
                    b'n' => out.push('\n'),
                    b'r' => out.push('\r'),
                    b't' => out.push('\t'),
                    b'b' => out.push('\u{8}'),
                    b'f' => out.push('\u{c}'),
                    b'u' => {
                        if *pos + 4 >= b.len() {
                            bail!("bad unicode escape");
                        }
                        let hex = std::str::from_utf8(&b[*pos + 1..*pos + 5])?;
                        let cp = u32::from_str_radix(hex, 16)?;
                        out.push(char::from_u32(cp).ok_or_else(|| anyhow!("bad codepoint"))?);
                        *pos += 4;
                    }
                    c => bail!("bad escape '\\{}'", c as char),
                }
                *pos += 1;
            }
            _ => {
                // consume one UTF-8 scalar
                let rest = std::str::from_utf8(&b[*pos..]).map_err(|_| anyhow!("bad utf8"))?;
                let c = rest.chars().next().unwrap();
                out.push(c);
                *pos += c.len_utf8();
            }
        }
    }
}

fn parse_arr(b: &[u8], pos: &mut usize) -> Result<Json> {
    *pos += 1; // '['
    let mut items = Vec::new();
    skip_ws(b, pos);
    if *pos < b.len() && b[*pos] == b']' {
        *pos += 1;
        return Ok(Json::Arr(items));
    }
    loop {
        items.push(parse_value(b, pos)?);
        skip_ws(b, pos);
        if *pos >= b.len() {
            bail!("unterminated array");
        }
        match b[*pos] {
            b',' => *pos += 1,
            b']' => {
                *pos += 1;
                return Ok(Json::Arr(items));
            }
            c => bail!("expected ',' or ']', got '{}'", c as char),
        }
    }
}

fn parse_obj(b: &[u8], pos: &mut usize) -> Result<Json> {
    *pos += 1; // '{'
    let mut map = BTreeMap::new();
    skip_ws(b, pos);
    if *pos < b.len() && b[*pos] == b'}' {
        *pos += 1;
        return Ok(Json::Obj(map));
    }
    loop {
        skip_ws(b, pos);
        let key = parse_string(b, pos)?;
        skip_ws(b, pos);
        if *pos >= b.len() || b[*pos] != b':' {
            bail!("expected ':' after object key");
        }
        *pos += 1;
        map.insert(key, parse_value(b, pos)?);
        skip_ws(b, pos);
        if *pos >= b.len() {
            bail!("unterminated object");
        }
        match b[*pos] {
            b',' => *pos += 1,
            b'}' => {
                *pos += 1;
                return Ok(Json::Obj(map));
            }
            c => bail!("expected ',' or '}}', got '{}'", c as char),
        }
    }
}

// ----------------------------------------------------------------------
// Model <-> Json
// ----------------------------------------------------------------------

fn tensor_to_json(t: &Tensor) -> Json {
    let shape = Json::Arr(t.shape().iter().map(|&d| Json::Num(d as f64)).collect());
    // i8/i32-resident tensors (plan residency containers) serialize by
    // value; graph-level tensors are f32/i64 in practice
    let dtype = t.dtype().name();
    let data = if t.dtype() == crate::tensor::DType::F32 {
        Json::Arr(t.as_f32().unwrap().iter().map(|&v| Json::Num(f64::from(v))).collect())
    } else {
        Json::Arr(t.to_f64_vec().into_iter().map(Json::Num).collect())
    };
    Json::obj(vec![("shape", shape), ("dtype", Json::Str(dtype.into())), ("data", data)])
}

fn tensor_from_json(j: &Json) -> Result<Tensor> {
    let shape: Vec<usize> = j
        .req("shape")?
        .as_arr()?
        .iter()
        .map(|v| v.as_i64().map(|x| x as usize))
        .collect::<Result<_>>()?;
    let data = j.req("data")?.as_arr()?;
    match j.req("dtype")?.as_str()? {
        "f32" => Ok(Tensor::new(shape, data.iter().map(|v| v.as_f64().map(|x| x as f32)).collect::<Result<_>>()?)),
        "i64" => Ok(Tensor::new_i64(shape, data.iter().map(|v| v.as_i64()).collect::<Result<_>>()?)),
        "i8" => Ok(Tensor::new_i8(
            shape,
            data.iter()
                .map(|v| {
                    let x = v.as_i64()?;
                    i8::try_from(x).map_err(|_| anyhow::anyhow!("value {x} does not fit i8"))
                })
                .collect::<Result<_>>()?,
        )),
        "i32" => Ok(Tensor::new_i32(
            shape,
            data.iter()
                .map(|v| {
                    let x = v.as_i64()?;
                    i32::try_from(x).map_err(|_| anyhow::anyhow!("value {x} does not fit i32"))
                })
                .collect::<Result<_>>()?,
        )),
        other => bail!("unknown tensor dtype '{other}'"),
    }
}

fn attr_to_json(a: &AttrValue) -> Json {
    match a {
        AttrValue::Int(v) => Json::obj(vec![("i", Json::Num(*v as f64))]),
        AttrValue::Float(v) => Json::obj(vec![("f", Json::Num(f64::from(*v)))]),
        AttrValue::Str(v) => Json::obj(vec![("s", Json::Str(v.clone()))]),
        AttrValue::Ints(v) => Json::obj(vec![("ints", Json::Arr(v.iter().map(|&x| Json::Num(x as f64)).collect()))]),
        AttrValue::Floats(v) => {
            Json::obj(vec![("floats", Json::Arr(v.iter().map(|&x| Json::Num(f64::from(x))).collect()))])
        }
        AttrValue::Tensor(t) => Json::obj(vec![("t", tensor_to_json(t))]),
    }
}

fn attr_from_json(j: &Json) -> Result<AttrValue> {
    let obj = j.as_obj()?;
    if let Some(v) = obj.get("i") {
        return Ok(AttrValue::Int(v.as_i64()?));
    }
    if let Some(v) = obj.get("f") {
        return Ok(AttrValue::Float(v.as_f64()? as f32));
    }
    if let Some(v) = obj.get("s") {
        return Ok(AttrValue::Str(v.as_str()?.to_string()));
    }
    if let Some(v) = obj.get("ints") {
        return Ok(AttrValue::Ints(v.as_arr()?.iter().map(|x| x.as_i64()).collect::<Result<_>>()?));
    }
    if let Some(v) = obj.get("floats") {
        return Ok(AttrValue::Floats(
            v.as_arr()?.iter().map(|x| x.as_f64().map(|f| f as f32)).collect::<Result<_>>()?,
        ));
    }
    if let Some(v) = obj.get("t") {
        return Ok(AttrValue::Tensor(tensor_from_json(v)?));
    }
    bail!("unrecognized attribute encoding: {j:?}")
}

fn vi_to_json(vi: &ValueInfo) -> Json {
    let mut fields = vec![("name", Json::Str(vi.name.clone()))];
    if let Some(shape) = &vi.shape {
        fields.push(("shape", Json::Arr(shape.iter().map(|&d| Json::Num(d as f64)).collect())));
    }
    if vi.dtype != DataType::Float32 {
        fields.push(("qonnx_datatype", Json::Str(vi.dtype.canonical_name())));
    }
    Json::obj(fields)
}

fn vi_from_json(j: &Json) -> Result<ValueInfo> {
    let name = j.req("name")?.as_str()?.to_string();
    let shape = match j.get("shape") {
        Some(arr) => Some(
            arr.as_arr()?
                .iter()
                .map(|v| v.as_i64().map(|x| x as usize))
                .collect::<Result<Vec<usize>>>()?,
        ),
        None => None,
    };
    let dtype = match j.get("qonnx_datatype") {
        Some(s) => {
            let name = s.as_str()?;
            DataType::from_name(name).ok_or_else(|| anyhow!("unknown datatype '{name}'"))?
        }
        None => DataType::Float32,
    };
    Ok(ValueInfo { name, shape, dtype })
}

pub(crate) fn node_to_json(n: &Node) -> Json {
    Json::obj(vec![
        ("name", Json::Str(n.name.clone())),
        ("op_type", Json::Str(n.op_type.clone())),
        ("domain", Json::Str(n.domain.clone())),
        ("inputs", Json::Arr(n.inputs.iter().map(|s| Json::Str(s.clone())).collect())),
        ("outputs", Json::Arr(n.outputs.iter().map(|s| Json::Str(s.clone())).collect())),
        (
            "attrs",
            Json::Obj(n.attrs.iter().map(|(k, v)| (k.clone(), attr_to_json(v))).collect()),
        ),
    ])
}

pub(crate) fn node_from_json(j: &Json) -> Result<Node> {
    let mut n = Node::new(j.req("op_type")?.as_str()?, &[], &[]);
    n.name = j.req("name")?.as_str()?.to_string();
    n.domain = j.req("domain")?.as_str()?.to_string();
    n.inputs = j.req("inputs")?.as_arr()?.iter().map(|v| v.as_str().map(String::from)).collect::<Result<_>>()?;
    n.outputs = j.req("outputs")?.as_arr()?.iter().map(|v| v.as_str().map(String::from)).collect::<Result<_>>()?;
    for (k, v) in j.req("attrs")?.as_obj()? {
        n.attrs.insert(k.clone(), attr_from_json(v)?);
    }
    Ok(n)
}

/// Serialize a model to its `.qonnx.json` representation.
pub fn model_to_json(g: &ModelGraph) -> String {
    Json::obj(vec![
        ("format", Json::Str("qonnx.json/v1".into())),
        ("name", Json::Str(g.name.clone())),
        ("doc", Json::Str(g.doc.clone())),
        (
            "opset",
            Json::Obj(g.opset.iter().map(|(k, v)| (k.clone(), Json::Num(*v as f64))).collect()),
        ),
        ("inputs", Json::Arr(g.inputs.iter().map(vi_to_json).collect())),
        ("outputs", Json::Arr(g.outputs.iter().map(vi_to_json).collect())),
        ("nodes", Json::Arr(g.nodes.iter().map(node_to_json).collect())),
        (
            "initializers",
            Json::Obj(g.initializers.iter().map(|(k, t)| (k.clone(), tensor_to_json(t))).collect()),
        ),
        (
            "value_info",
            Json::Obj(g.value_info.iter().map(|(k, vi)| (k.clone(), vi_to_json(vi))).collect()),
        ),
    ])
    .to_string()
}

/// Parse a `.qonnx.json` document back into a model.
pub fn model_from_json(text: &str) -> Result<ModelGraph> {
    let j = Json::parse(text)?;
    let fmt = j.req("format")?.as_str()?;
    if fmt != "qonnx.json/v1" {
        bail!("unsupported model format '{fmt}'");
    }
    let mut g = ModelGraph::new(j.req("name")?.as_str()?);
    g.doc = j.req("doc")?.as_str()?.to_string();
    for (k, v) in j.req("opset")?.as_obj()? {
        g.opset.insert(k.clone(), v.as_i64()?);
    }
    for vi in j.req("inputs")?.as_arr()? {
        g.inputs.push(vi_from_json(vi)?);
    }
    for vi in j.req("outputs")?.as_arr()? {
        g.outputs.push(vi_from_json(vi)?);
    }
    for n in j.req("nodes")?.as_arr()? {
        g.nodes.push(node_from_json(n)?);
    }
    for (k, t) in j.req("initializers")?.as_obj()? {
        g.initializers.insert(k.clone(), tensor_from_json(t)?);
    }
    for (k, vi) in j.req("value_info")?.as_obj()? {
        g.value_info.insert(k.clone(), vi_from_json(vi)?);
    }
    Ok(g)
}

/// Write a model to disk.
pub fn save_model(g: &ModelGraph, path: &str) -> Result<()> {
    std::fs::write(path, model_to_json(g)).with_context(|| format!("writing {path}"))
}

/// Read a model from disk.
pub fn load_model(path: &str) -> Result<ModelGraph> {
    let text = std::fs::read_to_string(path).with_context(|| format!("reading {path}"))?;
    model_from_json(&text)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ir::GraphBuilder;

    #[test]
    fn json_value_roundtrip() {
        let src = r#"{"a": [1, 2.5, -3e2], "b": "hi\nthere", "c": {"d": true, "e": null}}"#;
        let v = Json::parse(src).unwrap();
        let re = Json::parse(&v.to_string()).unwrap();
        assert_eq!(v, re);
        assert_eq!(v.get("a").unwrap().as_arr().unwrap()[2].as_f64().unwrap(), -300.0);
        assert_eq!(v.get("b").unwrap().as_str().unwrap(), "hi\nthere");
    }

    #[test]
    fn json_rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("\"unterminated").is_err());
        assert!(Json::parse("{}extra").is_err());
    }

    #[test]
    fn float_precision_roundtrip() {
        let t = Tensor::new(vec![3], vec![0.1, -1.0e-7, 3.4e38]);
        let j = tensor_to_json(&t);
        let back = tensor_from_json(&Json::parse(&j.to_string()).unwrap()).unwrap();
        assert_eq!(t, back);
    }

    #[test]
    fn model_roundtrip() {
        let mut b = GraphBuilder::new("rt");
        b.input("x", vec![1, 4]);
        b.quant("x", "xq", 0.125, 0.0, 4.0, true, true, "ROUND");
        b.node("Relu", &["xq"], &["y"], &[]);
        b.output("y", vec![1, 4]);
        let mut g = b.finish().unwrap();
        g.set_tensor_datatype("xq", crate::datatypes::DataType::Int(4));
        let text = model_to_json(&g);
        let back = model_from_json(&text).unwrap();
        assert_eq!(g, back);
        assert_eq!(back.tensor_datatype("xq"), crate::datatypes::DataType::Int(4));
    }

    #[test]
    fn model_roundtrip_via_disk() {
        let mut b = GraphBuilder::new("disk");
        b.input("x", vec![2, 2]);
        b.initializer("w", Tensor::new(vec![2, 2], vec![1., 2., 3., 4.]));
        b.node("MatMul", &["x", "w"], &["y"], &[]);
        b.output("y", vec![2, 2]);
        let g = b.finish().unwrap();
        let path = std::env::temp_dir().join("qonnx_rt_test.qonnx.json");
        save_model(&g, path.to_str().unwrap()).unwrap();
        let back = load_model(path.to_str().unwrap()).unwrap();
        assert_eq!(g, back);
    }
}

//! The QONNX graph intermediate representation.
//!
//! An in-memory mirror of the ONNX GraphProto structure (nodes with named
//! inputs/outputs, initializers, value infos) plus QONNX's per-tensor
//! arbitrary-precision datatype annotations. Serialized as JSON
//! (`.qonnx.json`) since protobuf is out of scope for this environment; the
//! structure maps 1:1 onto ONNX protobuf fields.

mod attr;
mod builder;
mod graph;
pub mod json;
mod node;

pub use attr::AttrValue;
pub use builder::GraphBuilder;
pub use graph::{ModelGraph, ValueInfo};
pub use node::Node;

/// Operator domain for standard ONNX ops.
pub const DOMAIN_ONNX: &str = "";
/// Operator domain for QONNX dialect ops (Quant, BipolarQuant, Trunc).
pub const DOMAIN_QONNX: &str = "qonnx.custom_op.general";
/// Operator domain for FINN dialect ops (MultiThreshold, Im2Col).
pub const DOMAIN_FINN: &str = "finn.custom_op.general";

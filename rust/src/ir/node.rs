//! Graph node: the ONNX NodeProto analog.

use super::attr::AttrValue;
use anyhow::{anyhow, Result};
use std::collections::BTreeMap;

/// A single operator instance in the graph.
#[derive(Debug, Clone, PartialEq)]
pub struct Node {
    /// Unique node name within the graph (may be empty on import; the
    /// cleanup pass assigns unique names).
    pub name: String,
    /// Operator type, e.g. `Conv`, `Quant`.
    pub op_type: String,
    /// Operator domain — `""` for standard ONNX, see [`crate::ir::DOMAIN_QONNX`].
    pub domain: String,
    /// Input tensor names; `""` marks an omitted optional input.
    pub inputs: Vec<String>,
    /// Output tensor names.
    pub outputs: Vec<String>,
    /// Attributes.
    pub attrs: BTreeMap<String, AttrValue>,
}

impl Node {
    pub fn new(op_type: &str, inputs: &[&str], outputs: &[&str]) -> Node {
        Node {
            name: String::new(),
            op_type: op_type.to_string(),
            domain: String::new(),
            inputs: inputs.iter().map(|s| s.to_string()).collect(),
            outputs: outputs.iter().map(|s| s.to_string()).collect(),
            attrs: BTreeMap::new(),
        }
    }

    pub fn with_domain(mut self, domain: &str) -> Node {
        self.domain = domain.to_string();
        self
    }

    pub fn with_name(mut self, name: &str) -> Node {
        self.name = name.to_string();
        self
    }

    pub fn with_attr(mut self, key: &str, value: impl Into<AttrValue>) -> Node {
        self.attrs.insert(key.to_string(), value.into());
        self
    }

    /// Required attribute lookup.
    pub fn attr(&self, key: &str) -> Result<&AttrValue> {
        self.attrs
            .get(key)
            .ok_or_else(|| anyhow!("node '{}' ({}) missing attribute '{key}'", self.name, self.op_type))
    }

    /// Integer attribute with default.
    pub fn attr_int_or(&self, key: &str, default: i64) -> i64 {
        self.attrs.get(key).and_then(|a| a.as_int().ok()).unwrap_or(default)
    }

    /// Float attribute with default.
    pub fn attr_float_or(&self, key: &str, default: f32) -> f32 {
        self.attrs.get(key).and_then(|a| a.as_float().ok()).unwrap_or(default)
    }

    /// String attribute with default.
    pub fn attr_str_or(&self, key: &str, default: &str) -> String {
        self.attrs
            .get(key)
            .and_then(|a| a.as_str().ok())
            .unwrap_or(default)
            .to_string()
    }

    /// Int-list attribute with default.
    pub fn attr_ints_or(&self, key: &str, default: &[i64]) -> Vec<i64> {
        self.attrs
            .get(key)
            .and_then(|a| a.as_ints().ok())
            .map(|v| v.to_vec())
            .unwrap_or_else(|| default.to_vec())
    }

    /// Non-empty (present) inputs.
    pub fn present_inputs(&self) -> impl Iterator<Item = &str> {
        self.inputs.iter().filter(|s| !s.is_empty()).map(|s| s.as_str())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn construction_and_attrs() {
        let n = Node::new("Quant", &["x", "s", "z", "bw"], &["y"])
            .with_domain(crate::ir::DOMAIN_QONNX)
            .with_name("q0")
            .with_attr("signed", 1i64)
            .with_attr("rounding_mode", "ROUND");
        assert_eq!(n.op_type, "Quant");
        assert_eq!(n.attr("signed").unwrap().as_int().unwrap(), 1);
        assert_eq!(n.attr_str_or("rounding_mode", "FLOOR"), "ROUND");
        assert_eq!(n.attr_int_or("narrow", 0), 0);
        assert!(n.attr("missing").is_err());
    }

    #[test]
    fn optional_inputs_skipped() {
        let n = Node::new("Conv", &["x", "w", ""], &["y"]);
        let present: Vec<&str> = n.present_inputs().collect();
        assert_eq!(present, vec!["x", "w"]);
    }
}

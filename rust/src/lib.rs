//! # qonnx — Arbitrary-Precision Quantized Neural Network toolkit
//!
//! A Rust implementation of the QONNX intermediate representation and
//! compiler toolchain from *"QONNX: Representing Arbitrary-Precision
//! Quantized Neural Networks"* (Pappalardo et al., 2022), plus a
//! PJRT-backed inference runtime fed by JAX/Pallas AOT artifacts.
//!
//! Layer map (see DESIGN.md):
//! * [`datatypes`], [`tensor`], [`ir`] — the IR substrate.
//! * [`ops`], [`exec`], [`plan`] — operator semantics + executors.
//! * [`transforms`] — graph passes (cleanup, shape inference, lowering).
//! * [`metrics`], [`zoo`], [`training`] — model zoo, BOPs/MACs, QAT.
//! * [`formats`] — the six ONNX-based QNN format descriptors (Table I).
//! * [`runtime`], [`coordinator`] — PJRT artifact execution + serving.
//!
//! # Architecture
//!
//! Execution is split into a **compile step** and a **run step**, the way
//! a serving system wants it, while keeping a naive interpreter around as
//! the semantic baseline:
//!
//! ```text
//!   ModelGraph ──(transforms)──► ModelGraph
//!        │
//!        ├─► exec::interpret*       name-keyed interpreter: per-call topo
//!        │                          sort, BTreeMap<String, Tensor> context,
//!        │                          string dispatch. Verification baseline.
//!        │
//!        ├─► plan::ExecutionPlan    compiled once: names → dense slots,
//!        │      │                   frozen schedule, kernel fn-pointers,
//!        │      │                   constant subgraphs (weight quantizers!)
//!        │      │                   folded at compile time, initializers
//!        │      │                   borrowed/Arc — never cloned per call,
//!        │      │                   last-use pass + SlotArena slot reuse.
//!        │      │                   Kernel tiers: folded → packed+fused
//!        │      │                   (PackedConv/Gemm/MatMul: weights
//!        │      │                   transposed + panel-packed once,
//!        │      │                   conv epilogues fused into the
//!        │      │                   scatter loop) → generic OpFn.
//!        │      └─► plan.run(..)    slot-indexed hot loop; kernels draw
//!        │                          im2col/GEMM/output buffers from a
//!        │                          ScratchArena that also recycles
//!        │                          released intermediates — kernel
//!        │                          scratch hits a zero-alloc steady
//!        │                          state on warm runs.
//!        │
//!        └─► runtime (PJRT)         AOT Pallas/HLO artifacts.
//!
//!   tensor::gemm / gemm_prepacked  MC/KC/NC cache-blocked GEMM over
//!                                  PackedB panels; deterministic
//!                                  ascending-k accumulation keeps every
//!                                  path (naive/serial/packed/threaded)
//!                                  bit-identical.
//!
//!   coordinator::Batcher ──► InferenceEngine   (1..N worker shards over
//!        │                                      one request queue)
//!        ├─ PjrtEngine        compiled artifact (fixed batch, pads)
//!        ├─ PlannedEngine     Arc<ExecutionPlan<'static>>, any batch
//!        │                    size natively (plans are batch-symbolic:
//!        │                    baked batch-1 reshape targets rewritten
//!        │                    at compile time); share() gives every
//!        │                    shard a view of ONE plan
//!        └─ ReferenceEngine   interpreter, verification
//! ```
//!
//! `exec::execute*` is a thin wrapper that compiles a borrowed plan per
//! call; engines compile once and reuse. The two executors are
//! equivalence-tested against each other across the model zoo and the
//! format-lowering round-trips (`tests/plan_equiv.rs`).

pub mod bench_support;
pub mod cli;
pub mod coordinator;
pub mod datatypes;
pub mod exec;
pub mod formats;
pub mod ir;
pub mod metrics;
pub mod ops;
pub mod plan;
pub mod runtime;
pub mod tensor;
pub mod testutil;
pub mod training;
pub mod transforms;
pub mod zoo;

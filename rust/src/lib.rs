//! # qonnx — Arbitrary-Precision Quantized Neural Network toolkit
//!
//! A Rust implementation of the QONNX intermediate representation and
//! compiler toolchain from *"QONNX: Representing Arbitrary-Precision
//! Quantized Neural Networks"* (Pappalardo et al., 2022), plus a
//! PJRT-backed inference runtime fed by JAX/Pallas AOT artifacts.
//!
//! Layer map (see DESIGN.md):
//! * [`datatypes`], [`tensor`], [`ir`] — the IR substrate.
//! * [`ops`], [`exec`], [`plan`] — operator semantics + executors.
//! * [`transforms`] — graph passes (cleanup, shape inference, lowering).
//! * [`streamline`] — integer-domain lowering (Quant → MultiThreshold,
//!   integer weights, scales pushed to the graph edge).
//! * [`metrics`], [`zoo`], [`training`] — model zoo, BOPs/MACs, QAT.
//! * [`formats`] — the six ONNX-based QNN format descriptors (Table I).
//! * [`runtime`], [`coordinator`] — PJRT artifact execution + serving.
//!
//! # Architecture
//!
//! Execution is split into a **compile step** and a **run step**, the way
//! a serving system wants it, while keeping a naive interpreter around as
//! the semantic baseline:
//!
//! ```text
//!   ModelGraph ──(transforms)──► ModelGraph
//!        │            │
//!        │            └─(streamline)──► integer-domain ModelGraph
//!        │                              Quant acts → MultiThreshold
//!        │                              emitting raw integer levels,
//!        │                              weights folded to integers,
//!        │                              BatchNorm absorbed into
//!        │                              thresholds, ONE residual Mul at
//!        │                              the graph edge.
//!        │
//!        ├─► exec::interpret*       name-keyed interpreter: per-call topo
//!        │                          sort, BTreeMap<String, Tensor> context,
//!        │                          string dispatch. Verification baseline.
//!        │
//!        ├─► plan::ExecutionPlan    compiled once: names → dense slots,
//!        │      │                   frozen schedule, kernel fn-pointers,
//!        │      │                   constant subgraphs (weight quantizers!)
//!        │      │                   folded at compile time, initializers
//!        │      │                   borrowed/Arc — never cloned per call,
//!        │      │                   last-use pass + SlotArena slot reuse.
//!        │      │                   Kernel tiers: folded → quantized
//!        │      │                   (QuantConv/Gemm/MatMul: i8 weight
//!        │      │                   panels, i32 accumulate, integer
//!        │      │                   MultiThreshold fused in the scatter
//!        │      │                   loop — selected via infer_ranges
//!        │      │                   proofs) → packed+fused float
//!        │      │                   (PackedConv/Gemm/MatMul: weights
//!        │      │                   transposed + panel-packed once,
//!        │      │                   elementwise epilogues fused into
//!        │      │                   the write-back) → generic OpFn.
//!        │      │
//!        │      │                   Slots are dtype-aware (tensor::DType):
//!        │      │                   the residency pass keeps quantized
//!        │      │                   activations resident in i8/i32 slots
//!        │      │                   between kernels —
//!        │      │
//!        │      │                     f32 in ─► Threshold(i8/i32)   ◄ graph edge: one f32→int cast
//!        │      │                         i8 ─► QuantConv+mt ─► i8  ◄ i8 panels, i32 acc,
//!        │      │                         i8 ─► MaxPool/Reshape     ◄ dtype pass-through
//!        │      │                         i8 ─► QuantGemm ─► f32    ◄ float-tier neighbor /
//!        │      │                        f32 ─► Mul (de-scale)        graph output: f32 emitted
//!        │      │                                                     in the scatter loop
//!        │      │
//!        │      │                   casts live only at tier boundaries,
//!        │      │                   inside the boundary kernels; values
//!        │      │                   (< 2^24, exact in f32) never change.
//!        │      └─► plan.run(..)    slot-indexed hot loop; kernels draw
//!        │                          im2col/GEMM/output buffers from a
//!        │                          ScratchArena with per-dtype pools
//!        │                          (f32/i32/i8) that also recycles
//!        │                          released intermediates by container —
//!        │                          kernel scratch hits a zero-alloc
//!        │                          steady state on warm runs.
//!        │
//!        └─► runtime (PJRT)         AOT Pallas/HLO artifacts.
//!
//!   tensor::gemm / gemm_prepacked  MC/KC/NC cache-blocked GEMM over
//!                                  PackedB panels; deterministic
//!                                  ascending-k accumulation keeps every
//!                                  path (naive/serial/packed/threaded)
//!                                  bit-identical.
//!   tensor::qgemm_prepacked        the integer twin: i8 PackedBi8
//!                                  panels, i32 accumulators — exact, so
//!                                  order-free; bounded below 2^24 at
//!                                  plan compile so results are also
//!                                  exact in their f32 containers.
//!   tensor::qgemm_prepacked_i8     the same kernel over i8-RESIDENT
//!                                  activations (1-byte panels both
//!                                  sides) — what the residency pass
//!                                  feeds when the previous layer's
//!                                  levels fit i8.
//!
//!   tensor::simd                   the i8×i8→i32 microkernel layer under
//!                                  qgemm: one ISA probe at startup
//!                                  (AVX2 via a saturation-free
//!                                  sign-split maddubs ladder, NEON via
//!                                  vmull_s8/vpadalq — scalar panels
//!                                  otherwise, or under
//!                                  QONNX_FORCE_SCALAR=1), with PackedBi8
//!                                  repacked into interleaved K×8 tiles
//!                                  at plan-compile time. i32 accumulation
//!                                  is order-free, so every ISA produces
//!                                  byte-identical plans.
//!   runtime::pool                  the persistent intra-op worker pool:
//!                                  gemm/qgemm/im2col fan row- and
//!                                  column-chunks onto it instead of
//!                                  spawning OS threads per call. Sized
//!                                  by available_parallelism (or
//!                                  QONNX_INTRAOP_THREADS); serving
//!                                  shards cap their per-request fan-out
//!                                  (BatcherConfig::intraop_threads) so
//!                                  shards × intra-op ≤ cores.
//!
//!   coordinator::Batcher ──► InferenceEngine   (1..N worker shards over
//!        │                                      one request queue)
//!        ├─ PjrtEngine        compiled artifact (fixed batch, pads)
//!        ├─ PlannedEngine     Arc<ExecutionPlan<'static>>, any batch
//!        │                    size natively (plans are batch-symbolic:
//!        │                    baked batch-1 reshape targets rewritten
//!        │                    at compile time; unbatchable targets fail
//!        │                    engine construction loudly); from_zoo /
//!        │                    new_auto serve the streamlined integer
//!        │                    form when the model lowers cleanly, the
//!        │                    float plan otherwise; share() gives every
//!        │                    shard a view of ONE plan
//!        └─ ReferenceEngine   interpreter, verification
//! ```
//!
//! `exec::execute*` is a thin wrapper that compiles a borrowed plan per
//! call; engines compile once and reuse. The two executors are
//! equivalence-tested against each other across the model zoo and the
//! format-lowering round-trips (`tests/plan_equiv.rs`).
//!
//! ## Serving robustness
//!
//! The serving core guarantees every admitted request a *definitive typed
//! outcome* — no request ever hangs on a dead shard or vanishes in a
//! shutdown. The request lifecycle:
//!
//! ```text
//!   submit ──► ADMISSION       bounded queue (BatcherConfig::queue_capacity):
//!        │                     full ⇒ typed SubmitError::Shed {queue_depth}
//!        │                     (or wait up to SubmitOptions::submit_timeout);
//!        │                     all-dead ⇒ NoLiveShards; degraded + refuse
//!        │                     policy ⇒ Degraded.
//!        │
//!        ├──► DEADLINE         optional per-request deadline: expired
//!        │                     requests are dropped at drain time (and by
//!        │                     the supervisor's sweep while queued or
//!        │                     in-flight) with ServeError::DeadlineExceeded;
//!        │                     batches close early when the oldest member's
//!        │                     deadline nears; Response::wait enforces the
//!        │                     bound client-side too, so a stalled engine
//!        │                     cannot hold the caller past its deadline.
//!        │
//!        ├──► SUPERVISION      engine panics are caught per batch: the
//!        │                     batch's requests fail typed
//!        │                     (ShardPanicked), the shard is marked dead,
//!        │                     and the supervisor thread restarts it from
//!        │                     the retained engine factory with capped
//!        │                     exponential backoff (SupervisorConfig) up
//!        │                     to max_restarts. Queue locks recover from
//!        │                     poisoning, so one panicking worker never
//!        │                     wedges survivors. Batcher::health reports
//!        │                     live/starting/dead/restarts.
//!        │
//!        └──► DEGRADED/END     with some shards dead the server keeps
//!                              serving (DegradedPolicy::ServeDegraded,
//!                              default) or sheds at admission
//!                              (RefuseWhenDegraded); when every shard is
//!                              permanently dead, queued + in-flight
//!                              requests fail typed (NoLiveShards), and
//!                              shutdown() typed-fails whatever is still
//!                              queued (ShutDown) after the grace period.
//! ```
//!
//! [`coordinator::FaultyEngine`] + [`coordinator::FaultInjector`] inject
//! deterministic errors/panics/stalls (scripted, or seeded via
//! `QONNX_FAULT_SEED` env hooks) to drive this machinery in
//! `tests/serving_faults.rs`; [`metrics::serving::ServingMetrics`] counts
//! sheds/deadline-misses/panics/restarts and tracks a log-bucketed latency
//! histogram (p50/p95/p99) exportable as text (`serve --metrics`).
//!
//! ## Static verification
//!
//! The executor's hot loop trusts the compiled schedule completely — it
//! indexes slots unchecked, recycles released buffers by container, and
//! lets quantized kernels skip per-element validation on proven-integer
//! inputs. [`verify`] re-derives every one of those claims *statically*
//! from the plan and its source graph: slot liveness (read-before-write,
//! double release, overwrite-live), dtype flow (declared kernel
//! containers vs. the slot table, integer-edge justification),
//! arithmetic safety (the `< 2^24` accumulator bound recomputed from
//! claimed ranges, range containment against [`transforms::infer_ranges`],
//! threshold monotonicity, container fit) and fusion/schedule legality
//! (sole-consumer proofs replayed from the graph). Findings come back as
//! a typed [`verify::VerifyReport`]. [`plan::PlanOptions::verify`] runs
//! it at the tail of every compile — **deny-by-default in debug builds**,
//! explicit in release (`qonnx verify`, `plan --verify`, the
//! `verify_zoo` suite over the model zoo). `verify::mutate` provides
//! single-fault plan mutators that self-test the verifier: every
//! mutation class must trip its expected diagnostic code.
//!
//! ## Artifact format (instant cold start)
//!
//! Everything the compile step produces — the frozen schedule, kernel
//! descriptors, fused epilogues, threshold rows, and the prepacked
//! weight panels — can be persisted as a sectioned `.qpln` binary
//! ([`plan::artifact`]) and reconstructed without re-running any of it:
//!
//! ```text
//!   ┌──────────────────────────────────────────────────────────────┐
//!   │ header (64 B): magic "QPLNART\0" · format version · endian   │
//!   │ tag · section count · packing-ISA name                       │
//!   ├──────────────────────────────────────────────────────────────┤
//!   │ section table: one 32-B entry per section                    │
//!   │ {id, offset, len, CRC32}                                     │
//!   ├── 64-byte aligned ───────────────────────────────────────────┤
//!   │ META  — JSON plan skeleton: schedule, kernel descriptors,    │
//!   │         epilogues, slot/dtype tables, engine metadata        │
//!   │ GRAPH — the source ModelGraph (qonnx.json/v1), so the static │
//!   │         verifier can re-prove the plan (`verify --artifact`) │
//!   │ F32 / I8 / I32 / I64 — raw blobs: PackedB/PackedBi8 panels   │
//!   │         (incl. interleaved SIMD tiles), threshold rows,      │
//!   │         folded constants; every entry 64-byte aligned        │
//!   └──────────────────────────────────────────────────────────────┘
//! ```
//!
//! **Version/checksum contract**: magic, endian tag, and format version
//! gate the file before anything is parsed; every section carries a
//! CRC32 checked before decode; the header records the SIMD ISA the
//! weight tiles were packed for and loading refuses a mismatch. Every
//! corruption mode is a typed [`plan::artifact::ArtifactError`] — never
//! UB, never a panic.
//!
//! **Zero-copy rule**: the loader reads the file once into a 64-byte-
//! aligned buffer and weight panels *borrow* their ranges from it
//! through [`tensor::WeightStore`] — kernels are agnostic to
//! owned-vs-mapped panels, and loading performs **zero** re-packing,
//! re-streamlining, or re-verification on the hot path (pointer
//! provenance asserted by `zero_copy_report()` in the tests). One
//! loaded artifact serves every shard:
//! [`coordinator::PlannedEngine::from_artifact`] /
//! `share()`, `qonnx compile` / `serve --artifact model.qpln` on the
//! CLI. Cold constants (folded outputs not preloaded into slots) are
//! flagged in the META section as groundwork for spilling them out of
//! resident memory.
//!
//! ## Observability
//!
//! [`trace`] is the runtime's always-compiled observability layer.
//! Three contracts:
//!
//! * **Recorder** — [`trace::TraceRecorder`] keeps a bounded per-thread
//!   ring of typed events (span begin/end, instant, complete, counter)
//!   with monotonic-clock timestamps. Writers never block each other
//!   across threads, a full ring overwrites oldest and counts the
//!   overwrite exactly (`dropped`), and [`trace::TraceRecorder::drain`]
//!   snapshots every thread's events for export
//!   ([`trace::chrome::chrome_trace_json`] → Perfetto/`chrome://tracing`).
//! * **Span taxonomy** — serving emits `request` admission/shed/queued
//!   and typed failure events, `shard` batch-form (close reason:
//!   full/window/deadline/shutdown) → execute → scatter spans plus
//!   restart instants, `exec` per-step kernel events, and `queue`
//!   depth counters; see the [`trace`] module docs for the full table.
//!   [`plan::StepObserver`] feeds per-step samples (wall time, kernel
//!   tag, arena alloc-vs-reuse) into
//!   [`trace::profile::StepProfile`], which joins them with the static
//!   Eq.-5 complexity model ([`metrics::ModelReport`]) to report
//!   achieved GMAC/s and effective GBOP/s (`qonnx profile`).
//! * **Overhead guarantee** — tracing off is the default and costs one
//!   branch per site (an `Option`/relaxed-atomic test: the executor's
//!   unprofiled entry points pass a statically-`None` observer, and the
//!   batcher checks its config's `Option` recorder); tracing on stays
//!   within single-digit percent on CNV b8 (asserted by `make bench`'s
//!   tracing-overhead section).

pub mod bench_support;
pub mod cli;
pub mod coordinator;
pub mod datatypes;
pub mod exec;
pub mod formats;
pub mod ir;
pub mod metrics;
pub mod ops;
pub mod plan;
pub mod runtime;
pub mod streamline;
pub mod tensor;
pub mod testutil;
pub mod trace;
pub mod training;
pub mod transforms;
pub mod verify;
pub mod zoo;

//! # qonnx — Arbitrary-Precision Quantized Neural Network toolkit
//!
//! A Rust implementation of the QONNX intermediate representation and
//! compiler toolchain from *"QONNX: Representing Arbitrary-Precision
//! Quantized Neural Networks"* (Pappalardo et al., 2022), plus a
//! PJRT-backed inference runtime fed by JAX/Pallas AOT artifacts.
//!
//! Layer map (see DESIGN.md):
//! * [`datatypes`], [`tensor`], [`ir`] — the IR substrate.
//! * [`ops`], [`exec`] — operator semantics + reference executor.
//! * [`transforms`] — graph passes (cleanup, shape inference, lowering).
//! * [`metrics`], [`zoo`], [`training`] — model zoo, BOPs/MACs, QAT.
//! * [`formats`] — the six ONNX-based QNN format descriptors (Table I).
//! * [`runtime`], [`coordinator`] — PJRT artifact execution + serving.

pub mod bench_support;
pub mod cli;
pub mod coordinator;
pub mod datatypes;
pub mod exec;
pub mod formats;
pub mod ir;
pub mod metrics;
pub mod ops;
pub mod runtime;
pub mod tensor;
pub mod testutil;
pub mod training;
pub mod transforms;
pub mod zoo;

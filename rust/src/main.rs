//! `qonnx` binary: CLI over the QONNX toolkit (see `qonnx help`).

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    if let Err(e) = qonnx::cli::run(args) {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

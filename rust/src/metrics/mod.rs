//! Model complexity metrics: MACs, BOPs (paper Eq. 5), weight counts and
//! total weight bits — the columns of Table III and the axes of Fig. 5.
//! Serving-side observability (latency histogram, queue gauge, shed and
//! restart counters with a scrapeable text export) lives in [`serving`].
//!
//! BOPs for one convolutional layer with `b_w`-bit weights, `b_a`-bit
//! activations, `n` input channels, `m` output channels and `k×k` filters
//! (Eq. 5, from Baskin et al.):
//!
//! ```text
//! BOPs ≈ m n k² (b_a b_w + b_a + b_w + log2(n k²))
//! ```
//!
//! applied per output position (conv layers multiply by `oh·ow`; fully
//! connected layers use `k = 1` and a single position). We also report the
//! simpler MAC-weighted metric `Σ MACs·b_a·b_w` since published zoo
//! numbers mix conventions; EXPERIMENTS.md compares both against Table III.

pub mod serving;

use crate::datatypes::DataType;
use crate::ir::ModelGraph;
use anyhow::Result;

/// Per-layer complexity report.
#[derive(Debug, Clone)]
pub struct LayerReport {
    pub node_name: String,
    pub op_type: String,
    /// multiply-accumulates
    pub macs: u64,
    /// Eq. 5 bit operations
    pub bops: f64,
    /// MACs · b_a · b_w
    pub mac_bops: f64,
    pub weights: u64,
    pub weight_bits: u32,
    pub act_bits: u32,
}

/// Whole-model complexity report (Table III row).
#[derive(Debug, Clone, Default)]
pub struct ModelReport {
    pub model_name: String,
    pub layers: Vec<LayerReport>,
}

impl ModelReport {
    pub fn macs(&self) -> u64 {
        self.layers.iter().map(|l| l.macs).sum()
    }
    pub fn bops(&self) -> f64 {
        self.layers.iter().map(|l| l.bops).sum()
    }
    pub fn mac_bops(&self) -> f64 {
        self.layers.iter().map(|l| l.mac_bops).sum()
    }
    pub fn weights(&self) -> u64 {
        self.layers.iter().map(|l| l.weights).sum()
    }
    pub fn total_weight_bits(&self) -> u64 {
        self.layers.iter().map(|l| l.weights * u64::from(l.weight_bits)).sum()
    }
}

/// Eq. 5 for a single output position.
pub fn bops_eq5(m: u64, n: u64, k: u64, b_a: u32, b_w: u32) -> f64 {
    let nk2 = (n * k * k) as f64;
    (m as f64) * nk2 * ((b_a * b_w) as f64 + b_a as f64 + b_w as f64 + nk2.log2())
}

/// Bit width of the quantization feeding tensor `name`: from a producing
/// `Quant`/`BipolarQuant`/`MultiThreshold`, a datatype annotation, or 32.
fn tensor_bits(graph: &ModelGraph, name: &str) -> u32 {
    if let Some(p) = graph.producer(name) {
        let node = &graph.nodes[p];
        match node.op_type.as_str() {
            "Quant" => {
                if let Some(t) = graph.initializer(&node.inputs[3]) {
                    if let Ok(v) = t.scalar_value() {
                        return v.ceil() as u32;
                    }
                }
            }
            "BipolarQuant" => return 1,
            "MultiThreshold" => {
                if let Some(t) = graph.initializer(&node.inputs[1]) {
                    let steps = t.shape()[1] as f64;
                    return (steps + 1.0).log2().ceil().max(1.0) as u32;
                }
            }
            // look through shape-preserving / normalization ops
            "Reshape" | "Flatten" | "Transpose" | "MaxPool" | "Identity" | "Relu"
            | "BatchNormalization" | "Squeeze" | "Unsqueeze" | "Pad" => {
                return tensor_bits(graph, &node.inputs[0]);
            }
            _ => {}
        }
    }
    match graph.tensor_datatype(name) {
        DataType::Float32 => 32,
        dt => dt.bitwidth(),
    }
}

/// Analyze a model graph (shapes must be inferred for conv spatial dims).
pub fn analyze(graph: &ModelGraph) -> Result<ModelReport> {
    let mut report = ModelReport { model_name: graph.name.clone(), ..Default::default() };
    for node in &graph.nodes {
        let (m, n, k, positions, weights) = match node.op_type.as_str() {
            "Conv" | "QLinearConv" | "ConvInteger" => {
                let w_name = if node.op_type == "Conv" { &node.inputs[1] } else { &node.inputs[3] };
                let Some(ws) = graph.tensor_shape(w_name) else { continue };
                // weights [M, C/g, kh, kw]
                let (m, cg, kh, kw) = (ws[0], ws[1], ws[2], ws[3]);
                let Some(os) = graph.tensor_shape(&node.outputs[0]) else { continue };
                let positions = if os.len() == 4 { os[2] * os[3] } else { 1 };
                debug_assert!(kh == kw || kh != kw); // arbitrary kernels allowed
                (m as u64, cg as u64, kh as u64, positions as u64, (m * cg * kh * kw) as u64)
            }
            "MatMul" | "Gemm" | "QLinearMatMul" | "MatMulInteger" => {
                let w_name = if node.op_type == "QLinearMatMul" { &node.inputs[3] } else { &node.inputs[1] };
                let Some(ws) = graph.tensor_shape(w_name) else { continue };
                if ws.len() != 2 {
                    continue;
                }
                let (kdim, m) = if node.op_type == "Gemm" && node.attr_int_or("transB", 0) != 0 {
                    (ws[1], ws[0])
                } else {
                    (ws[0], ws[1])
                };
                (m as u64, kdim as u64, 1u64, 1u64, (kdim * m) as u64)
            }
            _ => continue,
        };
        let w_name = if matches!(node.op_type.as_str(), "QLinearConv" | "QLinearMatMul") {
            &node.inputs[3]
        } else {
            &node.inputs[1]
        };
        let b_w = tensor_bits(graph, w_name);
        let b_a = tensor_bits(graph, &node.inputs[0]);
        let macs = m * n * k * k * positions;
        report.layers.push(LayerReport {
            node_name: node.name.clone(),
            op_type: node.op_type.clone(),
            macs,
            bops: bops_eq5(m, n, k, b_a, b_w) * positions as f64,
            mac_bops: macs as f64 * f64::from(b_a) * f64::from(b_w),
            weights,
            weight_bits: b_w,
            act_bits: b_a,
        });
    }
    Ok(report)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ir::GraphBuilder;
    use crate::tensor::Tensor;
    use crate::transforms::cleanup;

    #[test]
    fn bops_eq5_fc_layer() {
        // FC: k=1, n=64, m=64, 1-bit/1-bit: 64·64·(1+1+1+6) = 36864
        assert_eq!(bops_eq5(64, 64, 1, 1, 1), 36864.0);
    }

    #[test]
    fn analyze_quantized_mlp() {
        let mut b = GraphBuilder::new("mlp");
        b.input("x", vec![1, 784]);
        b.quant("x", "xq", 1.0, 0.0, 8.0, false, false, "ROUND");
        b.initializer("w1", Tensor::zeros(vec![784, 64]));
        b.quant("w1", "w1q", 1.0, 0.0, 2.0, true, false, "ROUND");
        b.node("MatMul", &["xq", "w1q"], &["h"], &[]);
        b.quant("h", "hq", 1.0, 0.0, 2.0, true, false, "ROUND");
        b.initializer("w2", Tensor::zeros(vec![64, 10]));
        b.quant("w2", "w2q", 1.0, 0.0, 2.0, true, false, "ROUND");
        b.node("MatMul", &["hq", "w2q"], &["y"], &[]);
        b.output("y", vec![1, 10]);
        let mut g = b.finish().unwrap();
        cleanup(&mut g).unwrap();
        let r = analyze(&g).unwrap();
        assert_eq!(r.layers.len(), 2);
        assert_eq!(r.macs(), 784 * 64 + 64 * 10);
        assert_eq!(r.weights(), 784 * 64 + 64 * 10);
        assert_eq!(r.total_weight_bits(), (784 * 64 + 64 * 10) * 2);
        // first layer: 8-bit act, 2-bit weights
        assert_eq!(r.layers[0].act_bits, 8);
        assert_eq!(r.layers[0].weight_bits, 2);
        assert_eq!(r.layers[1].act_bits, 2);
        assert_eq!(r.layers[0].mac_bops, (784.0 * 64.0) * 16.0);
    }

    #[test]
    fn analyze_conv_counts_spatial_positions() {
        let mut b = GraphBuilder::new("c");
        b.input("x", vec![1, 3, 32, 32]);
        b.initializer("w", Tensor::zeros(vec![64, 3, 3, 3]));
        b.node("Conv", &["x", "w"], &["y"], &[("kernel_shape", vec![3i64, 3].into())]);
        b.output_unknown("y");
        let mut g = b.finish().unwrap();
        cleanup(&mut g).unwrap();
        let r = analyze(&g).unwrap();
        // out 30x30: 64·3·9·900
        assert_eq!(r.macs(), 64 * 3 * 9 * 900);
        assert_eq!(r.weights(), 1728);
        // float weights: 32-bit
        assert_eq!(r.total_weight_bits(), 1728 * 32);
    }

    #[test]
    fn bits_seen_through_batchnorm_and_pool() {
        let mut b = GraphBuilder::new("bn");
        b.input("x", vec![1, 4, 4, 4]);
        b.quant("x", "xq", 1.0, 0.0, 3.0, true, false, "ROUND");
        b.node("MaxPool", &["xq"], &["p"], &[("kernel_shape", vec![2i64, 2].into())]);
        b.initializer("w", Tensor::zeros(vec![8, 4, 1, 1]));
        b.node("Conv", &["p", "w"], &["y"], &[("kernel_shape", vec![1i64, 1].into())]);
        b.output_unknown("y");
        let mut g = b.finish().unwrap();
        cleanup(&mut g).unwrap();
        let r = analyze(&g).unwrap();
        assert_eq!(r.layers[0].act_bits, 3);
    }
}

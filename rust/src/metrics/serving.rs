//! Serving-side observability: a log-bucketed latency histogram
//! (p50/p95/p99 without per-request allocation), a queue-depth gauge,
//! and the shed/deadline/restart counters that back the fault-tolerant
//! serving core — plus a scrapeable text export
//! ([`ServingMetrics::render_text`], Prometheus-style exposition).
//!
//! Everything is atomic: workers, the supervisor, and submitting clients
//! all record concurrently with no locks on the hot path. The histogram
//! buckets by power-of-two microseconds (40 buckets cover sub-µs through
//! ~6 days), so quantiles are exact to within a factor-2 bucket bound —
//! plenty for p99 trend tracking and SLO floors.

use std::sync::atomic::{AtomicU64, Ordering};

const BUCKETS: usize = 40;

/// Upper bound (inclusive, µs) of histogram bucket `i`.
fn bucket_bound(i: usize) -> u64 {
    if i == 0 {
        0
    } else {
        (1u64 << i) - 1
    }
}

/// Bucket index for a latency of `us` microseconds: bucket 0 holds 0µs,
/// bucket `i` holds `[2^(i-1), 2^i)`.
fn bucket_of(us: u64) -> usize {
    (64 - us.leading_zeros() as usize).min(BUCKETS - 1)
}

/// Lock-free log-bucketed latency histogram (microseconds).
pub struct LatencyHistogram {
    buckets: [AtomicU64; BUCKETS],
    count: AtomicU64,
    sum_us: AtomicU64,
}

impl Default for LatencyHistogram {
    fn default() -> Self {
        LatencyHistogram {
            buckets: std::array::from_fn(|_| AtomicU64::new(0)),
            count: AtomicU64::new(0),
            sum_us: AtomicU64::new(0),
        }
    }
}

impl LatencyHistogram {
    pub fn record_us(&self, us: u64) {
        self.buckets[bucket_of(us)].fetch_add(1, Ordering::Relaxed);
        self.count.fetch_add(1, Ordering::Relaxed);
        self.sum_us.fetch_add(us, Ordering::Relaxed);
    }

    pub fn count(&self) -> u64 {
        self.count.load(Ordering::Relaxed)
    }

    pub fn sum_us(&self) -> u64 {
        self.sum_us.load(Ordering::Relaxed)
    }

    pub fn mean_us(&self) -> f64 {
        let n = self.count();
        if n == 0 {
            0.0
        } else {
            self.sum_us() as f64 / n as f64
        }
    }

    /// Upper bound (µs) of the bucket containing the `q`-quantile
    /// (`0.0 < q <= 1.0`); 0 when nothing has been recorded.
    pub fn quantile_us(&self, q: f64) -> u64 {
        let n = self.count();
        if n == 0 {
            return 0;
        }
        let target = ((q.clamp(0.0, 1.0) * n as f64).ceil() as u64).max(1);
        let mut seen = 0u64;
        for (i, b) in self.buckets.iter().enumerate() {
            seen += b.load(Ordering::Relaxed);
            if seen >= target {
                return bucket_bound(i);
            }
        }
        bucket_bound(BUCKETS - 1)
    }
}

/// Counters, gauges, and the latency histogram for one [`crate::coordinator::Batcher`].
///
/// Shared (`Arc`) between the batcher's workers, its supervisor, and any
/// scraper holding [`crate::coordinator::Batcher::metrics`].
#[derive(Default)]
pub struct ServingMetrics {
    latency: LatencyHistogram,
    queue_depth: AtomicU64,
    queue_depth_peak: AtomicU64,
    completed: AtomicU64,
    shed: AtomicU64,
    deadline_exceeded: AtomicU64,
    engine_errors: AtomicU64,
    shard_panics: AtomicU64,
    shard_restarts: AtomicU64,
    failed: AtomicU64,
    batches: AtomicU64,
}

impl ServingMetrics {
    pub fn new() -> ServingMetrics {
        ServingMetrics::default()
    }

    /// Record one served request's end-to-end latency.
    pub fn record_latency_us(&self, us: u64) {
        self.latency.record_us(us);
        self.completed.fetch_add(1, Ordering::Relaxed);
    }

    /// Set the queue-depth gauge (tracks the peak as a high-water mark).
    pub fn set_queue_depth(&self, depth: usize) {
        self.queue_depth.store(depth as u64, Ordering::Relaxed);
        self.queue_depth_peak.fetch_max(depth as u64, Ordering::Relaxed);
    }

    pub fn inc_shed(&self) {
        self.shed.fetch_add(1, Ordering::Relaxed);
    }

    pub fn inc_deadline_exceeded(&self) {
        self.deadline_exceeded.fetch_add(1, Ordering::Relaxed);
    }

    /// One engine `infer_batch` error (per batch, not per request —
    /// failed requests are counted by [`ServingMetrics::inc_failed`]).
    pub fn inc_engine_error(&self) {
        self.engine_errors.fetch_add(1, Ordering::Relaxed);
    }

    /// One engine panic (per batch, not per request).
    pub fn inc_shard_panic(&self) {
        self.shard_panics.fetch_add(1, Ordering::Relaxed);
    }

    pub fn inc_shard_restart(&self) {
        self.shard_restarts.fetch_add(1, Ordering::Relaxed);
    }

    pub fn inc_failed(&self, requests: u64) {
        self.failed.fetch_add(requests, Ordering::Relaxed);
    }

    pub fn inc_batch(&self) {
        self.batches.fetch_add(1, Ordering::Relaxed);
    }

    pub fn latency(&self) -> &LatencyHistogram {
        &self.latency
    }

    pub fn queue_depth(&self) -> u64 {
        self.queue_depth.load(Ordering::Relaxed)
    }

    pub fn queue_depth_peak(&self) -> u64 {
        self.queue_depth_peak.load(Ordering::Relaxed)
    }

    pub fn completed(&self) -> u64 {
        self.completed.load(Ordering::Relaxed)
    }

    pub fn shed(&self) -> u64 {
        self.shed.load(Ordering::Relaxed)
    }

    pub fn deadline_exceeded(&self) -> u64 {
        self.deadline_exceeded.load(Ordering::Relaxed)
    }

    pub fn engine_errors(&self) -> u64 {
        self.engine_errors.load(Ordering::Relaxed)
    }

    pub fn shard_panics(&self) -> u64 {
        self.shard_panics.load(Ordering::Relaxed)
    }

    pub fn shard_restarts(&self) -> u64 {
        self.shard_restarts.load(Ordering::Relaxed)
    }

    pub fn failed(&self) -> u64 {
        self.failed.load(Ordering::Relaxed)
    }

    pub fn batches(&self) -> u64 {
        self.batches.load(Ordering::Relaxed)
    }

    /// Scrapeable text exposition (Prometheus-style lines).
    pub fn render_text(&self) -> String {
        let mut s = String::new();
        let mut line = |k: &str, v: u64| {
            s.push_str(k);
            s.push(' ');
            s.push_str(&v.to_string());
            s.push('\n');
        };
        line("qonnx_requests_completed_total", self.completed());
        line("qonnx_requests_shed_total", self.shed());
        line("qonnx_requests_deadline_exceeded_total", self.deadline_exceeded());
        line("qonnx_requests_failed_total", self.failed());
        line("qonnx_engine_errors_total", self.engine_errors());
        line("qonnx_shard_panics_total", self.shard_panics());
        line("qonnx_shard_restarts_total", self.shard_restarts());
        line("qonnx_batches_total", self.batches());
        line("qonnx_queue_depth", self.queue_depth());
        line("qonnx_queue_depth_peak", self.queue_depth_peak());
        line("qonnx_request_latency_us_count", self.latency.count());
        line("qonnx_request_latency_us_sum", self.latency.sum_us());
        for (q, label) in [(0.5, "0.5"), (0.95, "0.95"), (0.99, "0.99")] {
            s.push_str(&format!(
                "qonnx_request_latency_us{{quantile=\"{label}\"}} {}\n",
                self.latency.quantile_us(q)
            ));
        }
        s
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bucket_indexing_is_log2() {
        assert_eq!(bucket_of(0), 0);
        assert_eq!(bucket_of(1), 1);
        assert_eq!(bucket_of(2), 2);
        assert_eq!(bucket_of(3), 2);
        assert_eq!(bucket_of(4), 3);
        assert_eq!(bucket_of(1023), 10);
        assert_eq!(bucket_of(1024), 11);
        assert_eq!(bucket_of(u64::MAX), BUCKETS - 1);
        assert_eq!(bucket_bound(0), 0);
        assert_eq!(bucket_bound(10), 1023);
    }

    #[test]
    fn quantiles_walk_cumulative_counts() {
        let h = LatencyHistogram::default();
        // 90 fast requests (~100us), 9 medium (~1000us), 1 slow (~100_000us)
        for _ in 0..90 {
            h.record_us(100);
        }
        for _ in 0..9 {
            h.record_us(1000);
        }
        h.record_us(100_000);
        assert_eq!(h.count(), 100);
        // p50 lands in the 100us bucket: [64, 127]
        assert_eq!(h.quantile_us(0.5), 127);
        // p95 lands in the 1000us bucket: [512, 1023]
        assert_eq!(h.quantile_us(0.95), 1023);
        // p99 still in the 1000us bucket (99th of 100 = the last medium)
        assert_eq!(h.quantile_us(0.99), 1023);
        // p100 catches the slow one: [65536, 131071]
        assert_eq!(h.quantile_us(1.0), 131_071);
        assert!((h.mean_us() - (90.0 * 100.0 + 9.0 * 1000.0 + 100_000.0) / 100.0).abs() < 1e-9);
    }

    #[test]
    fn empty_histogram_reports_zero() {
        let h = LatencyHistogram::default();
        assert_eq!(h.quantile_us(0.5), 0);
        assert_eq!(h.mean_us(), 0.0);
    }

    #[test]
    fn gauge_tracks_peak() {
        let m = ServingMetrics::new();
        m.set_queue_depth(3);
        m.set_queue_depth(9);
        m.set_queue_depth(1);
        assert_eq!(m.queue_depth(), 1);
        assert_eq!(m.queue_depth_peak(), 9);
    }

    #[test]
    fn text_export_has_all_series() {
        let m = ServingMetrics::new();
        m.record_latency_us(250);
        m.inc_shed();
        m.inc_deadline_exceeded();
        m.inc_shard_restart();
        m.inc_batch();
        let text = m.render_text();
        for series in [
            "qonnx_requests_completed_total 1",
            "qonnx_requests_shed_total 1",
            "qonnx_requests_deadline_exceeded_total 1",
            "qonnx_shard_restarts_total 1",
            "qonnx_batches_total 1",
            "qonnx_queue_depth 0",
            "qonnx_request_latency_us{quantile=\"0.99\"}",
        ] {
            assert!(text.contains(series), "missing {series} in:\n{text}");
        }
    }
}

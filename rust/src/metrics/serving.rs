//! Serving-side observability: a log-bucketed latency histogram
//! (p50/p95/p99 without per-request allocation), a queue-depth gauge,
//! and the shed/deadline/restart counters that back the fault-tolerant
//! serving core — plus a scrapeable text export
//! ([`ServingMetrics::render_text`], Prometheus-style exposition).
//!
//! Everything is atomic: workers, the supervisor, and submitting clients
//! all record concurrently with no locks on the hot path. The histogram
//! buckets by power-of-two microseconds (40 buckets cover sub-µs through
//! ~6 days), so quantiles are exact to within a factor-2 bucket bound —
//! plenty for p99 trend tracking and SLO floors.
//!
//! A multi-model deployment folds several batchers into one scrape with
//! [`MetricsRegistry`]: each registered model's series carry a stable
//! kebab-case `model` label (see [`kebab_label`]), so counters from
//! different models never conflate.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

const BUCKETS: usize = 40;

/// Upper bound (inclusive, µs) of histogram bucket `i`.
fn bucket_bound(i: usize) -> u64 {
    if i == 0 {
        0
    } else {
        (1u64 << i) - 1
    }
}

/// Bucket index for a latency of `us` microseconds: bucket 0 holds 0µs,
/// bucket `i` holds `[2^(i-1), 2^i)`.
fn bucket_of(us: u64) -> usize {
    (64 - us.leading_zeros() as usize).min(BUCKETS - 1)
}

/// Lock-free log-bucketed latency histogram (microseconds).
pub struct LatencyHistogram {
    buckets: [AtomicU64; BUCKETS],
    count: AtomicU64,
    sum_us: AtomicU64,
}

impl Default for LatencyHistogram {
    fn default() -> Self {
        LatencyHistogram {
            buckets: std::array::from_fn(|_| AtomicU64::new(0)),
            count: AtomicU64::new(0),
            sum_us: AtomicU64::new(0),
        }
    }
}

impl LatencyHistogram {
    pub fn record_us(&self, us: u64) {
        self.buckets[bucket_of(us)].fetch_add(1, Ordering::Relaxed);
        self.count.fetch_add(1, Ordering::Relaxed);
        self.sum_us.fetch_add(us, Ordering::Relaxed);
    }

    pub fn count(&self) -> u64 {
        self.count.load(Ordering::Relaxed)
    }

    pub fn sum_us(&self) -> u64 {
        self.sum_us.load(Ordering::Relaxed)
    }

    pub fn mean_us(&self) -> f64 {
        let n = self.count();
        if n == 0 {
            0.0
        } else {
            self.sum_us() as f64 / n as f64
        }
    }

    /// Upper bound (µs) of the bucket containing the `q`-quantile
    /// (`0.0 < q <= 1.0`); 0 when nothing has been recorded.
    pub fn quantile_us(&self, q: f64) -> u64 {
        let n = self.count();
        if n == 0 {
            return 0;
        }
        let target = ((q.clamp(0.0, 1.0) * n as f64).ceil() as u64).max(1);
        let mut seen = 0u64;
        for (i, b) in self.buckets.iter().enumerate() {
            seen += b.load(Ordering::Relaxed);
            if seen >= target {
                return bucket_bound(i);
            }
        }
        bucket_bound(BUCKETS - 1)
    }
}

/// Why a formed batch left the queue — the drain loop's exit condition,
/// recorded per batch by [`ServingMetrics::observe_batch`] and exported
/// as `qonnx_batches_closed_total{reason="…"}`.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum BatchCloseReason {
    /// The batch reached `max_batch` requests.
    Full,
    /// The batching window (`max_wait`) expired.
    Window,
    /// The batch closed early because its oldest member's deadline was
    /// nearer than the window.
    Deadline,
    /// Shutdown flushed whatever was queued.
    Shutdown,
}

impl BatchCloseReason {
    /// Every reason, in export order.
    pub const ALL: [BatchCloseReason; 4] = [
        BatchCloseReason::Full,
        BatchCloseReason::Window,
        BatchCloseReason::Deadline,
        BatchCloseReason::Shutdown,
    ];

    /// Stable label value (kebab-case, matches the export).
    pub fn label(self) -> &'static str {
        match self {
            BatchCloseReason::Full => "full",
            BatchCloseReason::Window => "window",
            BatchCloseReason::Deadline => "deadline",
            BatchCloseReason::Shutdown => "shutdown",
        }
    }

    fn idx(self) -> usize {
        self as usize
    }
}

/// Counters, gauges, and the latency histogram for one [`crate::coordinator::Batcher`].
///
/// Shared (`Arc`) between the batcher's workers, its supervisor, and any
/// scraper holding [`crate::coordinator::Batcher::metrics`].
#[derive(Default)]
pub struct ServingMetrics {
    latency: LatencyHistogram,
    queue_depth: AtomicU64,
    queue_depth_peak: AtomicU64,
    completed: AtomicU64,
    shed: AtomicU64,
    deadline_exceeded: AtomicU64,
    engine_errors: AtomicU64,
    shard_panics: AtomicU64,
    shard_restarts: AtomicU64,
    failed: AtomicU64,
    batches: AtomicU64,
    /// Batch-size distribution (the histogram's log2 buckets hold
    /// request counts, not µs — quantiles are factor-2 bounds).
    batch_size: LatencyHistogram,
    batch_close: [AtomicU64; 4],
}

impl ServingMetrics {
    pub fn new() -> ServingMetrics {
        ServingMetrics::default()
    }

    /// Record one served request's end-to-end latency.
    pub fn record_latency_us(&self, us: u64) {
        self.latency.record_us(us);
        self.completed.fetch_add(1, Ordering::Relaxed);
    }

    /// Set the queue-depth gauge (tracks the peak as a high-water mark).
    pub fn set_queue_depth(&self, depth: usize) {
        self.queue_depth.store(depth as u64, Ordering::Relaxed);
        self.queue_depth_peak.fetch_max(depth as u64, Ordering::Relaxed);
    }

    pub fn inc_shed(&self) {
        self.shed.fetch_add(1, Ordering::Relaxed);
    }

    pub fn inc_deadline_exceeded(&self) {
        self.deadline_exceeded.fetch_add(1, Ordering::Relaxed);
    }

    /// One engine `infer_batch` error (per batch, not per request —
    /// failed requests are counted by [`ServingMetrics::inc_failed`]).
    pub fn inc_engine_error(&self) {
        self.engine_errors.fetch_add(1, Ordering::Relaxed);
    }

    /// One engine panic (per batch, not per request).
    pub fn inc_shard_panic(&self) {
        self.shard_panics.fetch_add(1, Ordering::Relaxed);
    }

    pub fn inc_shard_restart(&self) {
        self.shard_restarts.fetch_add(1, Ordering::Relaxed);
    }

    pub fn inc_failed(&self, requests: u64) {
        self.failed.fetch_add(requests, Ordering::Relaxed);
    }

    pub fn inc_batch(&self) {
        self.batches.fetch_add(1, Ordering::Relaxed);
    }

    /// Record one formed batch: increments the batch counter, the
    /// batch-size histogram, and the per-close-reason counter. The
    /// drain loop calls this instead of [`ServingMetrics::inc_batch`].
    pub fn observe_batch(&self, size: usize, reason: BatchCloseReason) {
        self.batches.fetch_add(1, Ordering::Relaxed);
        self.batch_size.record_us(size as u64);
        self.batch_close[reason.idx()].fetch_add(1, Ordering::Relaxed);
    }

    /// Batch-size distribution (bucket values are request counts).
    pub fn batch_size(&self) -> &LatencyHistogram {
        &self.batch_size
    }

    /// Batches that closed for `reason`.
    pub fn batch_closes(&self, reason: BatchCloseReason) -> u64 {
        self.batch_close[reason.idx()].load(Ordering::Relaxed)
    }

    pub fn latency(&self) -> &LatencyHistogram {
        &self.latency
    }

    pub fn queue_depth(&self) -> u64 {
        self.queue_depth.load(Ordering::Relaxed)
    }

    pub fn queue_depth_peak(&self) -> u64 {
        self.queue_depth_peak.load(Ordering::Relaxed)
    }

    pub fn completed(&self) -> u64 {
        self.completed.load(Ordering::Relaxed)
    }

    pub fn shed(&self) -> u64 {
        self.shed.load(Ordering::Relaxed)
    }

    pub fn deadline_exceeded(&self) -> u64 {
        self.deadline_exceeded.load(Ordering::Relaxed)
    }

    pub fn engine_errors(&self) -> u64 {
        self.engine_errors.load(Ordering::Relaxed)
    }

    pub fn shard_panics(&self) -> u64 {
        self.shard_panics.load(Ordering::Relaxed)
    }

    pub fn shard_restarts(&self) -> u64 {
        self.shard_restarts.load(Ordering::Relaxed)
    }

    pub fn failed(&self) -> u64 {
        self.failed.load(Ordering::Relaxed)
    }

    pub fn batches(&self) -> u64 {
        self.batches.load(Ordering::Relaxed)
    }

    /// Scrapeable text exposition (Prometheus-style lines), unlabeled —
    /// the single-model `serve --metrics` surface. Equivalent to
    /// [`ServingMetrics::render_text_for`] with no model.
    pub fn render_text(&self) -> String {
        self.render_text_for(None)
    }

    /// Text exposition with an optional stable `model` label on every
    /// series (the name is kebab-cased via [`kebab_label`] so the label
    /// set stays stable like `verify` codes). Multi-model scrapes are
    /// composed by [`MetricsRegistry::render_text`].
    pub fn render_text_for(&self, model: Option<&str>) -> String {
        let model = model.map(kebab_label);
        let m = model.as_deref();
        let mut s = String::new();
        let counters: [(&str, u64); 12] = [
            ("qonnx_requests_completed_total", self.completed()),
            ("qonnx_requests_shed_total", self.shed()),
            ("qonnx_requests_deadline_exceeded_total", self.deadline_exceeded()),
            ("qonnx_requests_failed_total", self.failed()),
            ("qonnx_engine_errors_total", self.engine_errors()),
            ("qonnx_shard_panics_total", self.shard_panics()),
            ("qonnx_shard_restarts_total", self.shard_restarts()),
            ("qonnx_batches_total", self.batches()),
            ("qonnx_queue_depth", self.queue_depth()),
            ("qonnx_queue_depth_peak", self.queue_depth_peak()),
            ("qonnx_request_latency_us_count", self.latency.count()),
            ("qonnx_request_latency_us_sum", self.latency.sum_us()),
        ];
        for (k, v) in counters {
            series(&mut s, k, m, None, v);
        }
        for (q, label) in [(0.5, "0.5"), (0.95, "0.95"), (0.99, "0.99")] {
            series(
                &mut s,
                "qonnx_request_latency_us",
                m,
                Some(("quantile", label)),
                self.latency.quantile_us(q),
            );
        }
        series(&mut s, "qonnx_batch_size_count", m, None, self.batch_size.count());
        series(&mut s, "qonnx_batch_size_sum", m, None, self.batch_size.sum_us());
        for (q, label) in [(0.5, "0.5"), (0.99, "0.99")] {
            series(
                &mut s,
                "qonnx_batch_size",
                m,
                Some(("quantile", label)),
                self.batch_size.quantile_us(q),
            );
        }
        for reason in BatchCloseReason::ALL {
            series(
                &mut s,
                "qonnx_batches_closed_total",
                m,
                Some(("reason", reason.label())),
                self.batch_closes(reason),
            );
        }
        s
    }
}

/// Append one exposition line, composing the optional `model` label with
/// at most one extra label pair. No labels → `name value` (the exact
/// single-model format older scrapers already parse).
fn series(out: &mut String, name: &str, model: Option<&str>, extra: Option<(&str, &str)>, v: u64) {
    out.push_str(name);
    if model.is_some() || extra.is_some() {
        out.push('{');
        let mut first = true;
        if let Some(mo) = model {
            out.push_str(&format!("model=\"{mo}\""));
            first = false;
        }
        if let Some((k, val)) = extra {
            if !first {
                out.push(',');
            }
            out.push_str(&format!("{k}=\"{val}\""));
        }
        out.push('}');
    }
    out.push(' ');
    out.push_str(&v.to_string());
    out.push('\n');
}

/// Canonicalize a model name into a stable kebab-case label value:
/// ASCII-lowercased alphanumerics, every other run collapsed to one
/// `-`, no leading/trailing dash (`"CNV_w2a2"` → `"cnv-w2a2"`). Empty
/// input falls back to `"model"` so a label value is never empty.
pub fn kebab_label(name: &str) -> String {
    let mut out = String::with_capacity(name.len());
    let mut pending_dash = false;
    for c in name.chars() {
        if c.is_ascii_alphanumeric() {
            if pending_dash && !out.is_empty() {
                out.push('-');
            }
            pending_dash = false;
            out.push(c.to_ascii_lowercase());
        } else {
            pending_dash = true;
        }
    }
    if out.is_empty() {
        "model".to_string()
    } else {
        out
    }
}

/// Folds several models' [`ServingMetrics`] into one scrape: each
/// registered entry renders with its stable kebab-case `model` label
/// ([`ServingMetrics::render_text_for`]), so a multi-model server
/// exposes one text endpoint without conflating counters.
#[derive(Default)]
pub struct MetricsRegistry {
    entries: Mutex<Vec<(String, Arc<ServingMetrics>)>>,
}

impl MetricsRegistry {
    pub fn new() -> MetricsRegistry {
        MetricsRegistry::default()
    }

    /// Register (or replace) a model's metrics handle; returns the
    /// kebab-case label the model's series will carry.
    pub fn register(&self, model: &str, metrics: Arc<ServingMetrics>) -> String {
        let name = kebab_label(model);
        let mut entries = self.entries.lock().unwrap_or_else(|p| p.into_inner());
        if let Some(slot) = entries.iter_mut().find(|(n, _)| *n == name) {
            slot.1 = metrics;
        } else {
            entries.push((name.clone(), metrics));
        }
        name
    }

    /// One scrape covering every registered model, in registration
    /// order, every series `model`-labeled.
    pub fn render_text(&self) -> String {
        let entries = self.entries.lock().unwrap_or_else(|p| p.into_inner());
        let mut s = String::new();
        for (name, m) in entries.iter() {
            s.push_str(&m.render_text_for(Some(name)));
        }
        s
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bucket_indexing_is_log2() {
        assert_eq!(bucket_of(0), 0);
        assert_eq!(bucket_of(1), 1);
        assert_eq!(bucket_of(2), 2);
        assert_eq!(bucket_of(3), 2);
        assert_eq!(bucket_of(4), 3);
        assert_eq!(bucket_of(1023), 10);
        assert_eq!(bucket_of(1024), 11);
        assert_eq!(bucket_of(u64::MAX), BUCKETS - 1);
        assert_eq!(bucket_bound(0), 0);
        assert_eq!(bucket_bound(10), 1023);
    }

    #[test]
    fn quantiles_walk_cumulative_counts() {
        let h = LatencyHistogram::default();
        // 90 fast requests (~100us), 9 medium (~1000us), 1 slow (~100_000us)
        for _ in 0..90 {
            h.record_us(100);
        }
        for _ in 0..9 {
            h.record_us(1000);
        }
        h.record_us(100_000);
        assert_eq!(h.count(), 100);
        // p50 lands in the 100us bucket: [64, 127]
        assert_eq!(h.quantile_us(0.5), 127);
        // p95 lands in the 1000us bucket: [512, 1023]
        assert_eq!(h.quantile_us(0.95), 1023);
        // p99 still in the 1000us bucket (99th of 100 = the last medium)
        assert_eq!(h.quantile_us(0.99), 1023);
        // p100 catches the slow one: [65536, 131071]
        assert_eq!(h.quantile_us(1.0), 131_071);
        assert!((h.mean_us() - (90.0 * 100.0 + 9.0 * 1000.0 + 100_000.0) / 100.0).abs() < 1e-9);
    }

    #[test]
    fn empty_histogram_reports_zero() {
        let h = LatencyHistogram::default();
        assert_eq!(h.quantile_us(0.5), 0);
        assert_eq!(h.mean_us(), 0.0);
    }

    #[test]
    fn gauge_tracks_peak() {
        let m = ServingMetrics::new();
        m.set_queue_depth(3);
        m.set_queue_depth(9);
        m.set_queue_depth(1);
        assert_eq!(m.queue_depth(), 1);
        assert_eq!(m.queue_depth_peak(), 9);
    }

    #[test]
    fn text_export_has_all_series() {
        let m = ServingMetrics::new();
        m.record_latency_us(250);
        m.inc_shed();
        m.inc_deadline_exceeded();
        m.inc_shard_restart();
        m.inc_batch();
        let text = m.render_text();
        for series in [
            "qonnx_requests_completed_total 1",
            "qonnx_requests_shed_total 1",
            "qonnx_requests_deadline_exceeded_total 1",
            "qonnx_shard_restarts_total 1",
            "qonnx_batches_total 1",
            "qonnx_queue_depth 0",
            "qonnx_request_latency_us{quantile=\"0.99\"}",
        ] {
            assert!(text.contains(series), "missing {series} in:\n{text}");
        }
    }

    #[test]
    fn observe_batch_feeds_histogram_and_reason_counters() {
        let m = ServingMetrics::new();
        m.observe_batch(8, BatchCloseReason::Full);
        m.observe_batch(3, BatchCloseReason::Window);
        m.observe_batch(1, BatchCloseReason::Deadline);
        assert_eq!(m.batches(), 3);
        assert_eq!(m.batch_size().count(), 3);
        assert_eq!(m.batch_size().sum_us(), 12);
        assert_eq!(m.batch_closes(BatchCloseReason::Full), 1);
        assert_eq!(m.batch_closes(BatchCloseReason::Window), 1);
        assert_eq!(m.batch_closes(BatchCloseReason::Deadline), 1);
        assert_eq!(m.batch_closes(BatchCloseReason::Shutdown), 0);
        let total: u64 = BatchCloseReason::ALL.iter().map(|&r| m.batch_closes(r)).sum();
        assert_eq!(total, m.batches());
        let text = m.render_text();
        assert!(text.contains("qonnx_batch_size_count 3"), "{text}");
        assert!(text.contains("qonnx_batch_size_sum 12"), "{text}");
        assert!(text.contains("qonnx_batches_closed_total{reason=\"full\"} 1"), "{text}");
        assert!(text.contains("qonnx_batches_closed_total{reason=\"shutdown\"} 0"), "{text}");
    }

    #[test]
    fn kebab_label_is_stable_and_never_empty() {
        assert_eq!(kebab_label("CNV-w2a2"), "cnv-w2a2");
        assert_eq!(kebab_label("CNV_w2a2.onnx"), "cnv-w2a2-onnx");
        assert_eq!(kebab_label("  weird//Name  "), "weird-name");
        assert_eq!(kebab_label("___"), "model");
        assert_eq!(kebab_label(""), "model");
        // idempotent: registering an already-kebab name changes nothing
        assert_eq!(kebab_label(&kebab_label("TFC w1a1")), "tfc-w1a1");
    }

    #[test]
    fn model_label_composes_with_quantile_and_reason() {
        let m = ServingMetrics::new();
        m.record_latency_us(100);
        m.observe_batch(4, BatchCloseReason::Full);
        let text = m.render_text_for(Some("CNV-w2a2"));
        assert!(
            text.contains("qonnx_requests_completed_total{model=\"cnv-w2a2\"} 1"),
            "{text}"
        );
        assert!(
            text.contains("qonnx_request_latency_us{model=\"cnv-w2a2\",quantile=\"0.99\"}"),
            "{text}"
        );
        assert!(
            text.contains("qonnx_batches_closed_total{model=\"cnv-w2a2\",reason=\"full\"} 1"),
            "{text}"
        );
        // no unlabeled series leak into the labeled export
        assert!(!text.contains("_total "), "{text}");
    }

    #[test]
    fn registry_folds_models_into_one_scrape() {
        let reg = MetricsRegistry::new();
        let a = Arc::new(ServingMetrics::new());
        let b = Arc::new(ServingMetrics::new());
        a.record_latency_us(10);
        b.inc_shed();
        assert_eq!(reg.register("TFC-w1a1", a.clone()), "tfc-w1a1");
        assert_eq!(reg.register("CNV w2a2", b), "cnv-w2a2");
        let text = reg.render_text();
        assert!(text.contains("qonnx_requests_completed_total{model=\"tfc-w1a1\"} 1"), "{text}");
        assert!(text.contains("qonnx_requests_shed_total{model=\"cnv-w2a2\"} 1"), "{text}");
        // re-registering the same model replaces the handle
        let a2 = Arc::new(ServingMetrics::new());
        reg.register("TFC-w1a1", a2);
        let text = reg.render_text();
        assert!(text.contains("qonnx_requests_completed_total{model=\"tfc-w1a1\"} 0"), "{text}");
    }
}

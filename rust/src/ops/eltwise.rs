//! Elementwise operators: activations, broadcast arithmetic, batch norm.

use crate::ir::Node;
use crate::tensor::{DType, Tensor};
use anyhow::{ensure, Result};

/// `Relu` is dtype-polymorphic: integer-resident activations (the plan's
/// residency containers) clamp on the integer grid — bit-identical to the
/// f32 clamp on the same (exactly representable) values.
pub fn relu(_node: &Node, inputs: &[&Tensor]) -> Result<Vec<Tensor>> {
    let x = inputs[0];
    Ok(vec![match x.dtype() {
        DType::I8 => {
            Tensor::new_i8(x.shape().to_vec(), x.as_i8()?.iter().map(|&v| v.max(0)).collect())
        }
        DType::I32 => {
            Tensor::new_i32(x.shape().to_vec(), x.as_i32()?.iter().map(|&v| v.max(0)).collect())
        }
        _ => x.map(|v| v.max(0.0))?,
    }])
}

pub fn sign(_node: &Node, inputs: &[&Tensor]) -> Result<Vec<Tensor>> {
    Ok(vec![inputs[0].map(|v| {
        if v > 0.0 {
            1.0
        } else if v < 0.0 {
            -1.0
        } else {
            0.0
        }
    })?])
}

pub fn sigmoid(_node: &Node, inputs: &[&Tensor]) -> Result<Vec<Tensor>> {
    Ok(vec![inputs[0].map(|v| 1.0 / (1.0 + (-v).exp()))?])
}

pub fn tanh(_node: &Node, inputs: &[&Tensor]) -> Result<Vec<Tensor>> {
    Ok(vec![inputs[0].map(f32::tanh)?])
}

/// `Softmax` along `axis` (default -1), numerically stabilized.
pub fn softmax(node: &Node, inputs: &[&Tensor]) -> Result<Vec<Tensor>> {
    let x = inputs[0];
    let rank = x.rank() as i64;
    let mut axis = node.attr_int_or("axis", -1);
    if axis < 0 {
        axis += rank;
    }
    ensure!(axis == rank - 1, "Softmax only supported along the last axis");
    let inner = *x.shape().last().unwrap();
    let outer = x.numel() / inner;
    let src = x.as_f32()?;
    let mut out = vec![0f32; x.numel()];
    for r in 0..outer {
        let row = &src[r * inner..(r + 1) * inner];
        let m = row.iter().copied().fold(f32::NEG_INFINITY, f32::max);
        let mut denom = 0f32;
        for (i, &v) in row.iter().enumerate() {
            let e = (v - m).exp();
            out[r * inner + i] = e;
            denom += e;
        }
        for v in &mut out[r * inner..(r + 1) * inner] {
            *v /= denom;
        }
    }
    Ok(vec![Tensor::new(x.shape().to_vec(), out)])
}

pub fn add(_node: &Node, inputs: &[&Tensor]) -> Result<Vec<Tensor>> {
    Ok(vec![inputs[0].binary_op(inputs[1], |a, b| a + b)?])
}

pub fn sub(_node: &Node, inputs: &[&Tensor]) -> Result<Vec<Tensor>> {
    Ok(vec![inputs[0].binary_op(inputs[1], |a, b| a - b)?])
}

pub fn mul(_node: &Node, inputs: &[&Tensor]) -> Result<Vec<Tensor>> {
    Ok(vec![inputs[0].binary_op(inputs[1], |a, b| a * b)?])
}

pub fn div(_node: &Node, inputs: &[&Tensor]) -> Result<Vec<Tensor>> {
    Ok(vec![inputs[0].binary_op(inputs[1], |a, b| a / b)?])
}

/// Inference-mode `BatchNormalization(x, scale, bias, mean, var)`.
pub fn batch_norm(node: &Node, inputs: &[&Tensor]) -> Result<Vec<Tensor>> {
    ensure!(inputs.len() == 5, "BatchNormalization wants 5 inputs");
    let (x, scale, bias, mean, var) = (inputs[0], inputs[1], inputs[2], inputs[3], inputs[4]);
    let eps = node.attr_float_or("epsilon", 1e-5);
    let c = scale.numel();
    // reshape channel params to broadcast over NCHW (or [N, C] for dense);
    // the channels-last wrapper (`data_layout = "NHWC"`) broadcasts over
    // the trailing channel axis instead.
    let nhwc = node.attr_str_or("data_layout", "NCHW") == "NHWC";
    let bshape = if x.rank() == 4 && !nhwc { vec![1, c, 1, 1] } else { vec![c] };
    let scale_b = scale.reshape(bshape.clone())?;
    let bias_b = bias.reshape(bshape.clone())?;
    let mean_b = mean.reshape(bshape.clone())?;
    let var_b = var.reshape(bshape)?;
    let centered = x.binary_op(&mean_b, |a, m| a - m)?;
    let denom = var_b.map(|v| (v + eps).sqrt())?;
    let normed = centered.binary_op(&denom, |a, d| a / d)?;
    let scaled = normed.binary_op(&scale_b, |a, s| a * s)?;
    Ok(vec![scaled.binary_op(&bias_b, |a, b| a + b)?])
}

#[cfg(test)]
mod tests {
    use super::*;

    fn t(v: Vec<f32>) -> Tensor {
        let n = v.len();
        Tensor::new(vec![n], v)
    }

    #[test]
    fn activations() {
        let x = t(vec![-1.0, 0.0, 2.0]);
        let n = Node::new("Relu", &["x"], &["y"]);
        assert_eq!(relu(&n, &[&x]).unwrap()[0].as_f32().unwrap(), &[0.0, 0.0, 2.0]);
        assert_eq!(sign(&n, &[&x]).unwrap()[0].as_f32().unwrap(), &[-1.0, 0.0, 1.0]);
        let s = sigmoid(&n, &[&t(vec![0.0])]).unwrap();
        assert!((s[0].as_f32().unwrap()[0] - 0.5).abs() < 1e-6);
    }

    #[test]
    fn softmax_rows_sum_to_one() {
        let n = Node::new("Softmax", &["x"], &["y"]);
        let x = Tensor::new(vec![2, 3], vec![1., 2., 3., 1000., 1000., 1000.]);
        let y = softmax(&n, &[&x]).unwrap();
        let v = y[0].as_f32().unwrap();
        assert!((v[0..3].iter().sum::<f32>() - 1.0).abs() < 1e-5);
        // large values don't overflow (stabilized)
        assert!((v[3] - 1.0 / 3.0).abs() < 1e-5);
        assert!(v[2] > v[1] && v[1] > v[0]);
    }

    #[test]
    fn arithmetic_broadcast() {
        let n = Node::new("Add", &["a", "b"], &["y"]);
        let a = Tensor::new(vec![2, 2], vec![1., 2., 3., 4.]);
        let b = Tensor::scalar(10.0);
        assert_eq!(add(&n, &[&a, &b]).unwrap()[0].as_f32().unwrap(), &[11., 12., 13., 14.]);
        assert_eq!(sub(&n, &[&a, &b]).unwrap()[0].as_f32().unwrap(), &[-9., -8., -7., -6.]);
        assert_eq!(mul(&n, &[&a, &b]).unwrap()[0].as_f32().unwrap(), &[10., 20., 30., 40.]);
        assert_eq!(div(&n, &[&a, &b]).unwrap()[0].as_f32().unwrap(), &[0.1, 0.2, 0.3, 0.4]);
    }

    #[test]
    fn batch_norm_normalizes() {
        let n = Node::new("BatchNormalization", &["x", "s", "b", "m", "v"], &["y"]);
        let x = Tensor::new(vec![1, 2, 1, 1], vec![4.0, 10.0]);
        let scale = t(vec![1.0, 2.0]);
        let bias = t(vec![0.0, 1.0]);
        let mean = t(vec![4.0, 8.0]);
        let var = t(vec![1.0, 4.0]);
        let y = batch_norm(&n, &[&x, &scale, &bias, &mean, &var]).unwrap();
        let v = y[0].as_f32().unwrap();
        assert!((v[0] - 0.0).abs() < 1e-3);
        assert!((v[1] - 3.0).abs() < 1e-3); // (10-8)/2 * 2 + 1
    }

    #[test]
    fn batch_norm_dense_rank2() {
        let n = Node::new("BatchNormalization", &["x", "s", "b", "m", "v"], &["y"]);
        let x = Tensor::new(vec![1, 2], vec![1.0, 2.0]);
        let one = t(vec![1.0, 1.0]);
        let zero = t(vec![0.0, 0.0]);
        let y = batch_norm(&n, &[&x, &one, &zero, &zero, &one]).unwrap();
        let v = y[0].as_f32().unwrap();
        assert!((v[0] - 1.0).abs() < 1e-3 && (v[1] - 2.0).abs() < 1e-3);
    }
}

//! Linear-algebra operators: `Conv` (im2col + GEMM, with groups for
//! depthwise-separable MobileNet), `Gemm`, `MatMul`.

use crate::ir::Node;
use crate::tensor::{conv_out_dim, gemm, im2col_group_into, Tensor};
use anyhow::{ensure, Result};
use std::borrow::Cow;

/// Conv hyper-parameters, resolved once from the attribute map.
///
/// Shared between the generic [`conv_impl`] and the plan's compiled
/// `PackedConv` kernel (which resolves them a single time at
/// plan-compile instead of per request).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ConvParams {
    pub kh: usize,
    pub kw: usize,
    pub stride_h: usize,
    pub stride_w: usize,
    pub pads: [usize; 4], // top, left, bottom, right
    pub group: usize,
}

/// Resolve conv hyper-parameters from a node's attributes and the weight
/// shape (kernel_shape defaults to the weight's trailing dims).
pub fn conv_params(node: &Node, w_shape: &[usize]) -> Result<ConvParams> {
    let ks = node.attr_ints_or("kernel_shape", &[w_shape[2] as i64, w_shape[3] as i64]);
    ensure!(ks.len() == 2, "only 2-D conv supported, kernel_shape {ks:?}");
    let strides = node.attr_ints_or("strides", &[1, 1]);
    let pads = node.attr_ints_or("pads", &[0, 0, 0, 0]);
    ensure!(pads.len() == 4, "pads must be [t,l,b,r]");
    let dil = node.attr_ints_or("dilations", &[1, 1]);
    ensure!(dil.iter().all(|&d| d == 1), "dilations != 1 unsupported");
    Ok(ConvParams {
        kh: ks[0] as usize,
        kw: ks[1] as usize,
        stride_h: strides[0] as usize,
        stride_w: strides[1] as usize,
        pads: [pads[0] as usize, pads[1] as usize, pads[2] as usize, pads[3] as usize],
        group: node.attr_int_or("group", 1) as usize,
    })
}

/// Transpose group `g`'s weight rows (`[mg, k]` slices of a flattened
/// `[M, C/g, kh, kw]` tensor) into a `[k, mg]` matrix — the GEMM rhs
/// layout. Shared by the generic conv, the plan's `PackedConv` (f32),
/// and the quantized tier's `QuantConv` (i8), each packing once at
/// compile time; keeping one impl is what guarantees all paths multiply
/// identical matrices.
pub(crate) fn transpose_group_weights<T: Copy + Default>(
    ws: &[T],
    g: usize,
    mg: usize,
    k: usize,
) -> Vec<T> {
    let mut wt = vec![T::default(); k * mg];
    for mi in 0..mg {
        let wrow = &ws[(g * mg + mi) * k..(g * mg + mi + 1) * k];
        for (ki, &wv) in wrow.iter().enumerate() {
            wt[ki * mg + mi] = wv;
        }
    }
    wt
}

/// Shared conv implementation (also used by `QLinearConv`/`ConvInteger`).
/// `x` NCHW, `w` [M, C/group, kh, kw], optional bias `[M]`.
pub fn conv_impl(node: &Node, x: &Tensor, w: &Tensor, bias: Option<&Tensor>) -> Result<Tensor> {
    ensure!(x.rank() == 4, "Conv input must be NCHW, got {:?}", x.shape());
    ensure!(w.rank() == 4, "Conv weight must be [M,C/g,kh,kw], got {:?}", w.shape());
    let p = conv_params(node, w.shape())?;
    let (n, c, h, width) = (x.shape()[0], x.shape()[1], x.shape()[2], x.shape()[3]);
    let m = w.shape()[0];
    let cg = w.shape()[1];
    ensure!(c == cg * p.group, "channel mismatch: x has {c}, w wants {} x group {}", cg, p.group);
    ensure!(m % p.group == 0, "output channels {m} not divisible by group {}", p.group);
    let oh = conv_out_dim(h, p.kh, p.stride_h, p.pads[0], p.pads[2]);
    let ow = conv_out_dim(width, p.kw, p.stride_w, p.pads[1], p.pads[3]);
    let mg = m / p.group;

    let mut out = vec![0f32; n * m * oh * ow];
    let ws = w.as_f32()?;
    let xs = x.as_f32()?;
    let k = cg * p.kh * p.kw;
    let rows = n * oh * ow;
    let mut cols = vec![0f32; rows * k];
    let mut prod = vec![0f32; rows * mg];
    for g in 0..p.group {
        if g > 0 {
            prod.fill(0.0); // gemm accumulates; padding zeros in cols persist
        }
        // per-group channel window sliced inside im2col — no input clone
        im2col_group_into(
            xs, n, c, h, width, g * cg, cg, p.kh, p.kw, p.stride_h, p.stride_w, p.pads, &mut cols,
        );
        // weights for this group as [mg, cg*kh*kw], transposed to [k, mg]
        let wt = transpose_group_weights(ws, g, mg, k);
        // cols [n*oh*ow, k] x wt [k, mg] -> [n*oh*ow, mg]
        gemm(rows, k, mg, &cols, &wt, &mut prod);
        // scatter into NCHW out
        for b in 0..n {
            for mi in 0..mg {
                let oc = g * mg + mi;
                let dst_base = (b * m + oc) * oh * ow;
                for pix in 0..oh * ow {
                    out[dst_base + pix] = prod[(b * oh * ow + pix) * mg + mi];
                }
            }
        }
    }
    let mut result = Tensor::new(vec![n, m, oh, ow], out);
    if let Some(b) = bias {
        ensure!(b.numel() == m, "bias length {} != output channels {m}", b.numel());
        let b4 = b.reshape(vec![1, m, 1, 1])?;
        result = result.binary_op(&b4, |a, c| a + c)?;
    }
    Ok(result)
}

/// ONNX `Conv`, plus the QONNX channels-last wrapper: with
/// `data_layout = "NHWC"` the node consumes/produces NHWC tensors (weights
/// stay OIHW) — the paper's Fig. 3 wrapper-node mechanism.
pub fn conv(node: &Node, inputs: &[&Tensor]) -> Result<Vec<Tensor>> {
    ensure!(inputs.len() >= 2, "Conv wants >= 2 inputs");
    let bias = inputs.get(2).copied();
    if node.attr_str_or("data_layout", "NCHW") == "NHWC" {
        let x = crate::tensor::nhwc_to_nchw(inputs[0])?;
        let y = conv_impl(node, &x, inputs[1], bias)?;
        return Ok(vec![crate::tensor::nchw_to_nhwc(&y)?]);
    }
    Ok(vec![conv_impl(node, inputs[0], inputs[1], bias)?])
}

/// ONNX `Gemm`: `alpha * A' B' + beta * C`.
pub fn gemm_op(node: &Node, inputs: &[&Tensor]) -> Result<Vec<Tensor>> {
    ensure!(inputs.len() >= 2, "Gemm wants >= 2 inputs");
    let alpha = node.attr_float_or("alpha", 1.0);
    let beta = node.attr_float_or("beta", 1.0);
    // borrow untransposed operands — no clone on the common transA/B = 0 path
    let a: Cow<Tensor> = if node.attr_int_or("transA", 0) != 0 {
        Cow::Owned(inputs[0].transpose(&[1, 0])?)
    } else {
        Cow::Borrowed(inputs[0])
    };
    let b: Cow<Tensor> = if node.attr_int_or("transB", 0) != 0 {
        Cow::Owned(inputs[1].transpose(&[1, 0])?)
    } else {
        Cow::Borrowed(inputs[1])
    };
    let mut y = a.matmul2d(&b)?;
    if alpha != 1.0 {
        y = y.map(|v| v * alpha)?;
    }
    if let Some(c) = inputs.get(2) {
        let scaled_c: Cow<Tensor> =
            if beta != 1.0 { Cow::Owned(c.map(|v| v * beta)?) } else { Cow::Borrowed(c) };
        y = y.binary_op(&scaled_c, |p, q| p + q)?;
    }
    Ok(vec![y])
}

/// ONNX `MatMul` (2-D, plus batched 3-D lhs over shared 2-D rhs).
pub fn matmul(_node: &Node, inputs: &[&Tensor]) -> Result<Vec<Tensor>> {
    ensure!(inputs.len() == 2, "MatMul wants 2 inputs");
    let (a, b) = (inputs[0], inputs[1]);
    if a.rank() == 2 && b.rank() == 2 {
        return Ok(vec![a.matmul2d(b)?]);
    }
    // batched lhs [batch.., m, k] x rhs [k, n]
    ensure!(b.rank() == 2 && a.rank() > 2, "unsupported MatMul ranks {:?} x {:?}", a.shape(), b.shape());
    let k = *a.shape().last().unwrap();
    let rows: usize = a.numel() / k;
    let flat = a.reshape(vec![rows, k])?;
    let y = flat.matmul2d(b)?;
    let mut out_shape = a.shape().to_vec();
    *out_shape.last_mut().unwrap() = b.shape()[1];
    Ok(vec![y.reshape(out_shape)?])
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn conv_3x3_identity_kernel() {
        let n = Node::new("Conv", &["x", "w"], &["y"])
            .with_attr("kernel_shape", vec![3i64, 3])
            .with_attr("pads", vec![1i64, 1, 1, 1]);
        let x = Tensor::new(vec![1, 1, 3, 3], (1..=9).map(|v| v as f32).collect());
        // delta kernel: passes input through
        let mut wdata = vec![0f32; 9];
        wdata[4] = 1.0;
        let w = Tensor::new(vec![1, 1, 3, 3], wdata);
        let y = conv(&n, &[&x, &w]).unwrap();
        assert_eq!(y[0].shape(), &[1, 1, 3, 3]);
        assert_eq!(y[0].as_f32().unwrap(), x.as_f32().unwrap());
    }

    #[test]
    fn conv_sum_kernel_no_pad() {
        let n = Node::new("Conv", &["x", "w"], &["y"]).with_attr("kernel_shape", vec![2i64, 2]);
        let x = Tensor::new(vec![1, 1, 2, 2], vec![1., 2., 3., 4.]);
        let w = Tensor::new(vec![1, 1, 2, 2], vec![1., 1., 1., 1.]);
        let y = conv(&n, &[&x, &w]).unwrap();
        assert_eq!(y[0].shape(), &[1, 1, 1, 1]);
        assert_eq!(y[0].as_f32().unwrap(), &[10.0]);
    }

    #[test]
    fn conv_bias_and_multichannel() {
        let n = Node::new("Conv", &["x", "w", "b"], &["y"]).with_attr("kernel_shape", vec![1i64, 1]);
        let x = Tensor::new(vec![1, 2, 1, 1], vec![3.0, 5.0]);
        let w = Tensor::new(vec![2, 2, 1, 1], vec![1.0, 1.0, 2.0, 0.0]);
        let b = Tensor::new(vec![2], vec![10.0, 20.0]);
        let y = conv(&n, &[&x, &w, &b]).unwrap();
        assert_eq!(y[0].as_f32().unwrap(), &[18.0, 26.0]);
    }

    #[test]
    fn depthwise_conv_groups() {
        // group = channels: each channel convolved independently (MobileNet)
        let n = Node::new("Conv", &["x", "w"], &["y"])
            .with_attr("kernel_shape", vec![1i64, 1])
            .with_attr("group", 2i64);
        let x = Tensor::new(vec![1, 2, 1, 2], vec![1., 2., 3., 4.]);
        let w = Tensor::new(vec![2, 1, 1, 1], vec![10.0, 100.0]);
        let y = conv(&n, &[&x, &w]).unwrap();
        assert_eq!(y[0].as_f32().unwrap(), &[10., 20., 300., 400.]);
    }

    #[test]
    fn conv_stride_output_shape() {
        let n = Node::new("Conv", &["x", "w"], &["y"])
            .with_attr("kernel_shape", vec![3i64, 3])
            .with_attr("strides", vec![2i64, 2])
            .with_attr("pads", vec![1i64, 1, 1, 1]);
        let x = Tensor::zeros(vec![1, 3, 32, 32]);
        let w = Tensor::zeros(vec![8, 3, 3, 3]);
        let y = conv(&n, &[&x, &w]).unwrap();
        assert_eq!(y[0].shape(), &[1, 8, 16, 16]);
    }

    #[test]
    fn gemm_full() {
        let n = Node::new("Gemm", &["a", "b", "c"], &["y"])
            .with_attr("alpha", 2.0f32)
            .with_attr("beta", 3.0f32)
            .with_attr("transB", 1i64);
        let a = Tensor::new(vec![1, 2], vec![1.0, 2.0]);
        let b = Tensor::new(vec![2, 2], vec![1.0, 0.0, 0.0, 1.0]); // transB: same
        let c = Tensor::new(vec![1, 2], vec![1.0, 1.0]);
        let y = gemm_op(&n, &[&a, &b, &c]).unwrap();
        // 2*[1,2] + 3*[1,1] = [5,7]
        assert_eq!(y[0].as_f32().unwrap(), &[5.0, 7.0]);
    }

    #[test]
    fn matmul_batched() {
        let n = Node::new("MatMul", &["a", "b"], &["y"]);
        let a = Tensor::new(vec![2, 1, 2], vec![1., 2., 3., 4.]);
        let b = Tensor::new(vec![2, 1], vec![1., 1.]);
        let y = matmul(&n, &[&a, &b]).unwrap();
        assert_eq!(y[0].shape(), &[2, 1, 1]);
        assert_eq!(y[0].as_f32().unwrap(), &[3.0, 7.0]);
    }
}

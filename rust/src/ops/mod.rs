//! Operator semantics.
//!
//! Each operator is a pure function `(node, inputs) -> outputs` over
//! [`Tensor`]s. The registry dispatches on `op_type`; domains are used by
//! the executor to optionally *restrict* the available op set (e.g. to
//! prove the paper's claim that QCDQ graphs run on a standard-ONNX-only
//! backend).
//!
//! Families:
//! * [`quant`] — the QONNX dialect: `Quant`, `BipolarQuant`, `Trunc` (Table II).
//! * [`qlinear`] — ONNX quantization ops: `QuantizeLinear`,
//!   `DequantizeLinear`, `Clip`, `QLinearConv`, `QLinearMatMul`,
//!   `ConvInteger`, `MatMulInteger`.
//! * [`linalg`] — `Conv`, `Gemm`, `MatMul`.
//! * [`pool`] — `MaxPool`, `AveragePool`, `GlobalAveragePool`.
//! * [`eltwise`] — activations, broadcast arithmetic, `BatchNormalization`.
//! * [`shape_ops`] — structural ops (`Reshape`, `Transpose`, `Shape`, ...).
//! * [`multithreshold`] — FINN dialect `MultiThreshold`.

pub mod eltwise;
pub mod linalg;
pub mod multithreshold;
pub mod pool;
pub mod qlinear;
pub mod quant;
pub mod shape_ops;

use crate::ir::Node;
use crate::tensor::Tensor;
use anyhow::{anyhow, Result};

/// Operator implementation signature.
pub type OpFn = fn(&Node, &[&Tensor]) -> Result<Vec<Tensor>>;

/// Look up the implementation for an op type. Returns `None` for unknown
/// ops (the executor reports these with node context).
pub fn lookup(op_type: &str) -> Option<OpFn> {
    Some(match op_type {
        // QONNX dialect
        "Quant" => quant::quant_op,
        "BipolarQuant" => quant::bipolar_quant_op,
        "Trunc" => quant::trunc_op,
        // ONNX quantization
        "QuantizeLinear" => qlinear::quantize_linear,
        "DequantizeLinear" => qlinear::dequantize_linear,
        "Clip" => qlinear::clip,
        "QLinearConv" => qlinear::qlinear_conv,
        "QLinearMatMul" => qlinear::qlinear_matmul,
        "ConvInteger" => qlinear::conv_integer,
        "MatMulInteger" => qlinear::matmul_integer,
        // linear algebra
        "Conv" => linalg::conv,
        "Gemm" => linalg::gemm_op,
        "MatMul" => linalg::matmul,
        // pooling
        "MaxPool" => pool::max_pool,
        "AveragePool" => pool::average_pool,
        "GlobalAveragePool" => pool::global_average_pool,
        // elementwise
        "Relu" => eltwise::relu,
        "Sign" => eltwise::sign,
        "Sigmoid" => eltwise::sigmoid,
        "Tanh" => eltwise::tanh,
        "Softmax" => eltwise::softmax,
        "Add" => eltwise::add,
        "Sub" => eltwise::sub,
        "Mul" => eltwise::mul,
        "Div" => eltwise::div,
        "BatchNormalization" => eltwise::batch_norm,
        // structural
        "Reshape" => shape_ops::reshape,
        "Transpose" => shape_ops::transpose,
        "Flatten" => shape_ops::flatten,
        "Pad" => shape_ops::pad,
        "Concat" => shape_ops::concat,
        "Shape" => shape_ops::shape_op,
        "Gather" => shape_ops::gather,
        "Unsqueeze" => shape_ops::unsqueeze,
        "Squeeze" => shape_ops::squeeze,
        "Identity" => shape_ops::identity,
        "Constant" => shape_ops::constant,
        "ArgMax" => shape_ops::argmax,
        // FINN dialect
        "MultiThreshold" => multithreshold::multi_threshold,
        _ => return None,
    })
}

/// Resolve a node's kernel function once, with node context on failure.
///
/// This is the *resolved-dispatch* entry point: the plan compiler
/// ([`crate::plan`]) calls it per node at compile time and stores the
/// returned function pointer in the step table, so the per-request hot
/// loop never string-matches `op_type`. The name-keyed interpreter calls
/// it per node per request via [`execute_node`].
pub fn kernel_for(node: &Node) -> Result<OpFn> {
    lookup(&node.op_type)
        .ok_or_else(|| anyhow!("no implementation for op '{}' (node '{}')", node.op_type, node.name))
}

/// Execute one node against resolved input tensors (string dispatch).
pub fn execute_node(node: &Node, inputs: &[&Tensor]) -> Result<Vec<Tensor>> {
    kernel_for(node)?(node, inputs)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn registry_covers_dialects() {
        for op in ["Quant", "BipolarQuant", "Trunc", "MultiThreshold", "Conv", "QLinearConv"] {
            assert!(lookup(op).is_some(), "{op} missing");
        }
        assert!(lookup("NotAnOp").is_none());
    }

    #[test]
    fn kernel_for_reports_node_context() {
        let n = crate::ir::Node::new("NotAnOp", &["x"], &["y"]).with_name("bad");
        let err = kernel_for(&n).unwrap_err().to_string();
        assert!(err.contains("NotAnOp") && err.contains("bad"), "{err}");
    }
}

//! FINN dialect `MultiThreshold` (paper §VI-D).
//!
//! FINN expresses an arbitrarily-quantized activation as a multi-step
//! function: `y = out_scale * count(x >= T[c, i]) + out_bias`, with one row
//! of sorted thresholds per channel. Converting `Quant` activations into
//! `MultiThreshold` is how QONNX enters the FINN compiler.

use crate::ir::Node;
use crate::tensor::Tensor;
use anyhow::{ensure, Result};

/// Count thresholds `<= x` via binary search over a sorted row.
#[inline]
pub fn threshold_count(row: &[f32], x: f32) -> usize {
    // partition point: number of t with x >= t
    let mut lo = 0usize;
    let mut hi = row.len();
    while lo < hi {
        let mid = (lo + hi) / 2;
        if x >= row[mid] {
            lo = mid + 1;
        } else {
            hi = mid;
        }
    }
    lo
}

/// Integer-domain [`threshold_count`]: same partition-point semantics over
/// an `i32` row. When both the value and the thresholds are exact
/// integers (the streamlined form — see [`crate::streamline`]), this is
/// bit-equivalent to the f32 search, with no float comparisons at all;
/// the plan's quantized kernels run it as their fused epilogue.
#[inline]
pub fn threshold_count_i32(row: &[i32], x: i32) -> usize {
    let mut lo = 0usize;
    let mut hi = row.len();
    while lo < hi {
        let mid = (lo + hi) / 2;
        if x >= row[mid] {
            lo = mid + 1;
        } else {
            hi = mid;
        }
    }
    lo
}

/// `MultiThreshold(x, thresholds) -> y`.
///
/// * `x`: `[N, C, ...]` (channels-first) or `[N, ..., C]` with
///   `data_layout = "NHWC"`, or `[N, C]` dense.
/// * `thresholds`: `[C, T]` or `[1, T]` (shared across channels), rows
///   sorted ascending.
/// * attrs: `out_scale` (default 1.0), `out_bias` (default 0.0).
pub fn multi_threshold(node: &Node, inputs: &[&Tensor]) -> Result<Vec<Tensor>> {
    ensure!(inputs.len() == 2, "MultiThreshold wants 2 inputs");
    let (x, th) = (inputs[0], inputs[1]);
    ensure!(th.rank() == 2, "thresholds must be [C, T], got {:?}", th.shape());
    let out_scale = node.attr_float_or("out_scale", 1.0);
    let out_bias = node.attr_float_or("out_bias", 0.0);
    let layout = node.attr_str_or("data_layout", "NCHW");

    let (tc, tt) = (th.shape()[0], th.shape()[1]);
    let ths = th.as_f32()?;
    for c in 0..tc {
        let row = &ths[c * tt..(c + 1) * tt];
        ensure!(
            row.windows(2).all(|w| w[0] <= w[1]),
            "threshold row {c} is not sorted ascending"
        );
    }

    let channels = match (x.rank(), layout.as_str()) {
        (2, _) => x.shape()[1],
        (4, "NCHW") => x.shape()[1],
        (4, "NHWC") => x.shape()[3],
        (r, l) => anyhow::bail!("unsupported MultiThreshold input rank {r} / layout {l}"),
    };
    ensure!(tc == channels || tc == 1, "threshold channels {tc} != input channels {channels}");

    let src = x.as_f32()?;
    let mut out = vec![0f32; x.numel()];
    // channel index for a flat position
    let chan_of = |flat: usize| -> usize {
        match (x.rank(), layout.as_str()) {
            (2, _) => flat % x.shape()[1],
            (4, "NCHW") => (flat / (x.shape()[2] * x.shape()[3])) % x.shape()[1],
            (4, "NHWC") => flat % x.shape()[3],
            _ => unreachable!(),
        }
    };
    for (flat, (&v, o)) in src.iter().zip(out.iter_mut()).enumerate() {
        let c = if tc == 1 { 0 } else { chan_of(flat) };
        let row = &ths[c * tt..(c + 1) * tt];
        *o = out_scale * threshold_count(row, v) as f32 + out_bias;
    }
    Ok(vec![Tensor::new(x.shape().to_vec(), out)])
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ir::DOMAIN_FINN;

    #[test]
    fn threshold_count_boundaries() {
        let row = [0.5f32, 1.5, 2.5];
        assert_eq!(threshold_count(&row, 0.0), 0);
        assert_eq!(threshold_count(&row, 0.5), 1); // inclusive
        assert_eq!(threshold_count(&row, 2.0), 2);
        assert_eq!(threshold_count(&row, 99.0), 3);
    }

    #[test]
    fn threshold_count_i32_matches_f32_on_integer_grids() {
        let row_i = [-3i32, 0, 0, 7];
        let row_f: Vec<f32> = row_i.iter().map(|&t| t as f32).collect();
        for x in -5i32..=9 {
            assert_eq!(
                threshold_count_i32(&row_i, x),
                threshold_count(&row_f, x as f32),
                "x={x}"
            );
        }
    }

    #[test]
    fn mimics_uint2_relu_quant() {
        // uint2 ReLU quant with scale 1: thresholds at 0.5, 1.5, 2.5
        let n = Node::new("MultiThreshold", &["x", "t"], &["y"]).with_domain(DOMAIN_FINN);
        let x = Tensor::new(vec![1, 1], vec![1.7]);
        let t = Tensor::new(vec![1, 3], vec![0.5, 1.5, 2.5]);
        let y = multi_threshold(&n, &[&x, &t]).unwrap();
        assert_eq!(y[0].as_f32().unwrap(), &[2.0]);
    }

    #[test]
    fn out_scale_bias_bipolar() {
        // sign() as MultiThreshold: 1 threshold at 0, out = 2*count - 1
        let n = Node::new("MultiThreshold", &["x", "t"], &["y"])
            .with_domain(DOMAIN_FINN)
            .with_attr("out_scale", 2.0f32)
            .with_attr("out_bias", -1.0f32);
        let x = Tensor::new(vec![1, 4], vec![-3.0, -0.1, 0.0, 2.0]);
        let t = Tensor::new(vec![1, 1], vec![0.0]);
        let y = multi_threshold(&n, &[&x, &t]).unwrap();
        assert_eq!(y[0].as_f32().unwrap(), &[-1.0, -1.0, 1.0, 1.0]);
    }

    #[test]
    fn per_channel_thresholds_nchw() {
        let n = Node::new("MultiThreshold", &["x", "t"], &["y"]).with_domain(DOMAIN_FINN);
        let x = Tensor::new(vec![1, 2, 1, 2], vec![1.0, 5.0, 1.0, 5.0]);
        // channel 0 thresholds {2,4}; channel 1 thresholds {0,1}
        let t = Tensor::new(vec![2, 2], vec![2.0, 4.0, 0.0, 1.0]);
        let y = multi_threshold(&n, &[&x, &t]).unwrap();
        assert_eq!(y[0].as_f32().unwrap(), &[0.0, 2.0, 2.0, 2.0]);
    }

    #[test]
    fn nhwc_layout() {
        let n = Node::new("MultiThreshold", &["x", "t"], &["y"])
            .with_domain(DOMAIN_FINN)
            .with_attr("data_layout", "NHWC");
        let x = Tensor::new(vec![1, 1, 1, 2], vec![1.0, 1.0]);
        let t = Tensor::new(vec![2, 1], vec![0.5, 2.0]);
        let y = multi_threshold(&n, &[&x, &t]).unwrap();
        assert_eq!(y[0].as_f32().unwrap(), &[1.0, 0.0]);
    }

    #[test]
    fn rejects_unsorted() {
        let n = Node::new("MultiThreshold", &["x", "t"], &["y"]).with_domain(DOMAIN_FINN);
        let x = Tensor::new(vec![1, 1], vec![1.0]);
        let t = Tensor::new(vec![1, 2], vec![2.0, 1.0]);
        assert!(multi_threshold(&n, &[&x, &t]).is_err());
    }
}

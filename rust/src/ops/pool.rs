//! Pooling operators over NCHW tensors.

use crate::ir::Node;
use crate::tensor::{conv_out_dim, DType, Tensor};
use anyhow::{ensure, Result};

struct PoolParams {
    kh: usize,
    kw: usize,
    stride_h: usize,
    stride_w: usize,
    pads: [usize; 4],
}

fn pool_params(node: &Node) -> Result<PoolParams> {
    let ks = node.attr("kernel_shape")?.as_ints()?.to_vec();
    ensure!(ks.len() == 2, "only 2-D pooling supported");
    let strides = node.attr_ints_or("strides", &ks);
    let pads = node.attr_ints_or("pads", &[0, 0, 0, 0]);
    Ok(PoolParams {
        kh: ks[0] as usize,
        kw: ks[1] as usize,
        stride_h: strides[0] as usize,
        stride_w: strides[1] as usize,
        pads: [pads[0] as usize, pads[1] as usize, pads[2] as usize, pads[3] as usize],
    })
}

fn pool_generic(
    x: &Tensor,
    p: &PoolParams,
    init: f32,
    acc: impl Fn(f32, f32) -> f32,
    finish: impl Fn(f32, usize) -> f32,
    count_pad: bool,
) -> Result<Tensor> {
    ensure!(x.rank() == 4, "pooling wants NCHW, got {:?}", x.shape());
    let (n, c, h, w) = (x.shape()[0], x.shape()[1], x.shape()[2], x.shape()[3]);
    let oh = conv_out_dim(h, p.kh, p.stride_h, p.pads[0], p.pads[2]);
    let ow = conv_out_dim(w, p.kw, p.stride_w, p.pads[1], p.pads[3]);
    let src = x.as_f32()?;
    let mut out = vec![0f32; n * c * oh * ow];
    for b in 0..n {
        for ch in 0..c {
            let src_base = (b * c + ch) * h * w;
            let dst_base = (b * c + ch) * oh * ow;
            for oy in 0..oh {
                for ox in 0..ow {
                    let mut v = init;
                    let mut cnt = 0usize;
                    for ky in 0..p.kh {
                        let iy = oy * p.stride_h + ky;
                        if iy < p.pads[0] || iy - p.pads[0] >= h {
                            continue;
                        }
                        for kx in 0..p.kw {
                            let ix = ox * p.stride_w + kx;
                            if ix < p.pads[1] || ix - p.pads[1] >= w {
                                continue;
                            }
                            v = acc(v, src[src_base + (iy - p.pads[0]) * w + (ix - p.pads[1])]);
                            cnt += 1;
                        }
                    }
                    let denom = if count_pad { p.kh * p.kw } else { cnt };
                    out[dst_base + oy * ow + ox] = finish(v, denom);
                }
            }
        }
    }
    Ok(Tensor::new(vec![n, c, oh, ow], out))
}

/// Run a NCHW pooling body under the channels-last wrapper convention:
/// with `data_layout = "NHWC"` inputs/outputs are NHWC (Fig. 3 wrappers).
fn with_layout(
    node: &Node,
    x: &Tensor,
    body: impl Fn(&Tensor) -> Result<Tensor>,
) -> Result<Vec<Tensor>> {
    if node.attr_str_or("data_layout", "NCHW") == "NHWC" {
        let nchw = crate::tensor::nhwc_to_nchw(x)?;
        return Ok(vec![crate::tensor::nchw_to_nhwc(&body(&nchw)?)?]);
    }
    Ok(vec![body(x)?])
}

/// Integer-resident NCHW max pool (plan residency containers): same
/// window walk as [`pool_generic`] with `Ord::max` — the comparison order
/// of exactly representable integers matches the f32 path bit for bit.
fn max_pool_int<T: Copy + Ord>(x: &Tensor, p: &PoolParams, src: &[T], init: T) -> (Vec<usize>, Vec<T>) {
    let (n, c, h, w) = (x.shape()[0], x.shape()[1], x.shape()[2], x.shape()[3]);
    let oh = conv_out_dim(h, p.kh, p.stride_h, p.pads[0], p.pads[2]);
    let ow = conv_out_dim(w, p.kw, p.stride_w, p.pads[1], p.pads[3]);
    let mut out = Vec::with_capacity(n * c * oh * ow);
    for b in 0..n {
        for ch in 0..c {
            let src_base = (b * c + ch) * h * w;
            for oy in 0..oh {
                for ox in 0..ow {
                    let mut v = init;
                    for ky in 0..p.kh {
                        let iy = oy * p.stride_h + ky;
                        if iy < p.pads[0] || iy - p.pads[0] >= h {
                            continue;
                        }
                        for kx in 0..p.kw {
                            let ix = ox * p.stride_w + kx;
                            if ix < p.pads[1] || ix - p.pads[1] >= w {
                                continue;
                            }
                            v = v.max(src[src_base + (iy - p.pads[0]) * w + (ix - p.pads[1])]);
                        }
                    }
                    out.push(v);
                }
            }
        }
    }
    (vec![n, c, oh, ow], out)
}

/// Pads strictly smaller than the kernel on every side: every window
/// then overlaps at least one real input element. THE guard for the
/// integer pooling paths (an empty window yields `-inf` on the f32 path,
/// which no integer container can represent).
fn windows_nonempty(p: &PoolParams) -> bool {
    p.pads[0] < p.kh && p.pads[2] < p.kh && p.pads[1] < p.kw && p.pads[3] < p.kw
}

/// Whether every pooling window of this `MaxPool` node is guaranteed to
/// overlap at least one real input element — the plan's residency pass
/// routes integer containers through a `MaxPool` only when this holds
/// (the op's own integer fast path uses the same predicate).
pub fn max_pool_windows_nonempty(node: &Node) -> bool {
    match pool_params(node) {
        Ok(p) => windows_nonempty(&p),
        Err(_) => false,
    }
}

/// ONNX `MaxPool`. Dtype-polymorphic on the NCHW path: integer-resident
/// inputs pool on the integer grid (the monotone op preserves it).
pub fn max_pool(node: &Node, inputs: &[&Tensor]) -> Result<Vec<Tensor>> {
    ensure!(inputs.len() == 1, "MaxPool wants 1 input");
    let p = pool_params(node)?;
    let x = inputs[0];
    if node.attr_str_or("data_layout", "NCHW") == "NCHW" && x.rank() == 4 && windows_nonempty(&p) {
        match x.dtype() {
            DType::I8 => {
                let (shape, out) = max_pool_int(x, &p, x.as_i8()?, i8::MIN);
                return Ok(vec![Tensor::new_i8(shape, out)]);
            }
            DType::I32 => {
                let (shape, out) = max_pool_int(x, &p, x.as_i32()?, i32::MIN);
                return Ok(vec![Tensor::new_i32(shape, out)]);
            }
            _ => {}
        }
    }
    with_layout(node, inputs[0], |x| {
        pool_generic(x, &p, f32::NEG_INFINITY, f32::max, |v, _| v, false)
    })
}

/// ONNX `AveragePool` (`count_include_pad` honored).
pub fn average_pool(node: &Node, inputs: &[&Tensor]) -> Result<Vec<Tensor>> {
    ensure!(inputs.len() == 1, "AveragePool wants 1 input");
    let p = pool_params(node)?;
    let count_pad = node.attr_int_or("count_include_pad", 0) != 0;
    with_layout(node, inputs[0], |x| {
        pool_generic(x, &p, 0.0, |a, b| a + b, |v, n| v / n as f32, count_pad)
    })
}

/// ONNX `GlobalAveragePool`: mean over all spatial positions per channel.
pub fn global_average_pool(node: &Node, inputs: &[&Tensor]) -> Result<Vec<Tensor>> {
    ensure!(inputs.len() == 1, "GlobalAveragePool wants 1 input");
    if node.attr_str_or("data_layout", "NCHW") == "NHWC" {
        let x = crate::tensor::nhwc_to_nchw(inputs[0])?;
        let y = global_average_pool(&Node::new("GlobalAveragePool", &[], &[]), &[&x])?;
        return Ok(vec![crate::tensor::nchw_to_nhwc(&y[0])?]);
    }
    let x = inputs[0];
    ensure!(x.rank() == 4, "GlobalAveragePool wants NCHW");
    let (n, c, h, w) = (x.shape()[0], x.shape()[1], x.shape()[2], x.shape()[3]);
    let src = x.as_f32()?;
    let mut out = vec![0f32; n * c];
    let area = (h * w) as f32;
    for i in 0..n * c {
        let s: f32 = src[i * h * w..(i + 1) * h * w].iter().sum();
        out[i] = s / area;
    }
    Ok(vec![Tensor::new(vec![n, c, 1, 1], out)])
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn max_pool_2x2() {
        let n = Node::new("MaxPool", &["x"], &["y"]).with_attr("kernel_shape", vec![2i64, 2]);
        let x = Tensor::new(vec![1, 1, 4, 4], (0..16).map(|v| v as f32).collect());
        let y = max_pool(&n, &[&x]).unwrap();
        assert_eq!(y[0].shape(), &[1, 1, 2, 2]);
        assert_eq!(y[0].as_f32().unwrap(), &[5., 7., 13., 15.]);
    }

    #[test]
    fn fully_padded_windows_decline_the_integer_path() {
        // pads >= kernel extent can produce an EMPTY window, whose f32
        // result is -inf — unrepresentable in any integer container, so
        // both the op fast path and the residency pass must decline
        let n = Node::new("MaxPool", &["x"], &["y"])
            .with_attr("kernel_shape", vec![2i64, 2])
            .with_attr("strides", vec![1i64, 1])
            .with_attr("pads", vec![2i64, 0, 0, 0]);
        assert!(!max_pool_windows_nonempty(&n));
        let ok = Node::new("MaxPool", &["x"], &["y"]).with_attr("kernel_shape", vec![2i64, 2]);
        assert!(max_pool_windows_nonempty(&ok));
        // the f32 semantics of the empty top window stay -inf
        let x = Tensor::new(vec![1, 1, 2, 2], vec![1., 2., 3., 4.]);
        let y = max_pool(&n, &[&x]).unwrap();
        assert_eq!(y[0].as_f32().unwrap()[0], f32::NEG_INFINITY);
    }

    #[test]
    fn max_pool_integer_containers_match_f32() {
        let n = Node::new("MaxPool", &["x"], &["y"])
            .with_attr("kernel_shape", vec![2i64, 2])
            .with_attr("strides", vec![2i64, 2]);
        let vals: Vec<i32> = (0..32).map(|v| (v * 7 % 23) - 11).collect();
        let xf = Tensor::new(vec![1, 2, 4, 4], vals.iter().map(|&v| v as f32).collect());
        let xi = Tensor::new_i32(vec![1, 2, 4, 4], vals.clone());
        let x8 = Tensor::new_i8(vec![1, 2, 4, 4], vals.iter().map(|&v| v as i8).collect());
        let yf = max_pool(&n, &[&xf]).unwrap();
        let yi = max_pool(&n, &[&xi]).unwrap();
        let y8 = max_pool(&n, &[&x8]).unwrap();
        assert_eq!(yi[0].shape(), yf[0].shape());
        let want: Vec<i32> = yf[0].as_f32().unwrap().iter().map(|&v| v as i32).collect();
        assert_eq!(yi[0].as_i32().unwrap(), want.as_slice());
        let want8: Vec<i8> = want.iter().map(|&v| v as i8).collect();
        assert_eq!(y8[0].as_i8().unwrap(), want8.as_slice());
    }

    #[test]
    fn avg_pool_2x2() {
        let n = Node::new("AveragePool", &["x"], &["y"]).with_attr("kernel_shape", vec![2i64, 2]);
        let x = Tensor::new(vec![1, 1, 2, 2], vec![1., 2., 3., 4.]);
        let y = average_pool(&n, &[&x]).unwrap();
        assert_eq!(y[0].as_f32().unwrap(), &[2.5]);
    }

    #[test]
    fn avg_pool_pad_exclusion() {
        let n = Node::new("AveragePool", &["x"], &["y"])
            .with_attr("kernel_shape", vec![2i64, 2])
            .with_attr("pads", vec![1i64, 1, 0, 0])
            .with_attr("strides", vec![1i64, 1]);
        let x = Tensor::new(vec![1, 1, 2, 2], vec![4., 4., 4., 4.]);
        let y = average_pool(&n, &[&x]).unwrap();
        // corner window sees only one real element; avg excludes padding
        assert_eq!(y[0].as_f32().unwrap()[0], 4.0);
    }

    #[test]
    fn global_avg_pool() {
        let n = Node::new("GlobalAveragePool", &["x"], &["y"]);
        let x = Tensor::new(vec![1, 2, 2, 2], vec![1., 2., 3., 4., 10., 20., 30., 40.]);
        let y = global_average_pool(&n, &[&x]).unwrap();
        assert_eq!(y[0].shape(), &[1, 2, 1, 1]);
        assert_eq!(y[0].as_f32().unwrap(), &[2.5, 25.0]);
    }
}

//! Standard-ONNX quantization operators: the substrate for the QDQ / QCDQ /
//! quantized-operator formats the paper compares against and lowers into
//! (paper §III–IV).
//!
//! `QuantizeLinear` is restricted to 8-bit output just like real ONNX —
//! that restriction is load-bearing for the paper's argument, so we keep it
//! and model sub-8-bit precision with an explicit `Clip` (the QCDQ trick).

use super::linalg;
use super::quant::round_half_even;
use crate::ir::Node;
use crate::tensor::{broadcast_shapes, BroadcastIter, Tensor};
use anyhow::{ensure, Result};

/// Saturation range for an 8-bit quantized tensor. ONNX picks the type from
/// the zero-point tensor dtype; our float-container IR carries it as the
/// node attribute `signed` (0 = uint8, the ONNX default).
fn q8_range(node: &Node) -> (f64, f64) {
    if node.attr_int_or("signed", 0) != 0 {
        (-128.0, 127.0)
    } else {
        (0.0, 255.0)
    }
}

/// `QuantizeLinear(x, y_scale, y_zero_point?) -> y` — Eq. 1 with fixed
/// 8-bit saturation; output is the *integer* value in a float container.
pub fn quantize_linear(node: &Node, inputs: &[&Tensor]) -> Result<Vec<Tensor>> {
    ensure!(inputs.len() >= 2, "QuantizeLinear wants >= 2 inputs");
    let x = inputs[0];
    let scale = inputs[1];
    let zeropt = inputs.get(2).copied();
    let (lo, hi) = q8_range(node);
    let mut out_shape = broadcast_shapes(x.shape(), scale.shape())?;
    if let Some(z) = zeropt {
        out_shape = broadcast_shapes(&out_shape, z.shape())?;
    }
    let xs = x.as_f32()?;
    let ss = scale.to_f64_vec();
    let zs = zeropt.map(|z| z.to_f64_vec()).unwrap_or_else(|| vec![0.0]);
    let z_shape: &[usize] = zeropt.map(|z| z.shape()).unwrap_or(&[]);
    let ix = BroadcastIter::new(x.shape(), &out_shape);
    let is = BroadcastIter::new(scale.shape(), &out_shape);
    let iz = BroadcastIter::new(z_shape, &out_shape);
    let mut out = Vec::with_capacity(out_shape.iter().product());
    for ((ox, os), oz) in ix.zip(is).zip(iz) {
        let q = round_half_even(f64::from(xs[ox]) / ss[os]) + zs[oz];
        out.push(q.clamp(lo, hi) as f32);
    }
    Ok(vec![Tensor::new(out_shape, out)])
}

/// `DequantizeLinear(x, x_scale, x_zero_point?) -> y` — Eq. 4.
pub fn dequantize_linear(_node: &Node, inputs: &[&Tensor]) -> Result<Vec<Tensor>> {
    ensure!(inputs.len() >= 2, "DequantizeLinear wants >= 2 inputs");
    let x = inputs[0];
    let scale = inputs[1];
    let zeropt = inputs.get(2).copied();
    let mut out_shape = broadcast_shapes(x.shape(), scale.shape())?;
    if let Some(z) = zeropt {
        out_shape = broadcast_shapes(&out_shape, z.shape())?;
    }
    let xs = x.as_f32()?;
    let ss = scale.to_f64_vec();
    let zs = zeropt.map(|z| z.to_f64_vec()).unwrap_or_else(|| vec![0.0]);
    let z_shape: &[usize] = zeropt.map(|z| z.shape()).unwrap_or(&[]);
    let ix = BroadcastIter::new(x.shape(), &out_shape);
    let is = BroadcastIter::new(scale.shape(), &out_shape);
    let iz = BroadcastIter::new(z_shape, &out_shape);
    let mut out = Vec::with_capacity(out_shape.iter().product());
    for ((ox, os), oz) in ix.zip(is).zip(iz) {
        out.push(((f64::from(xs[ox]) - zs[oz]) * ss[os]) as f32);
    }
    Ok(vec![Tensor::new(out_shape, out)])
}

/// `Clip(x, min?, max?) -> y` (opset 11+ input form; also accepts the
/// opset-6 `min`/`max` attributes). The QCDQ format's integer-clipping op.
pub fn clip(node: &Node, inputs: &[&Tensor]) -> Result<Vec<Tensor>> {
    ensure!(!inputs.is_empty(), "Clip wants >= 1 input");
    let x = inputs[0];
    let lo = match inputs.get(1) {
        Some(t) if t.numel() > 0 => t.scalar_value()?,
        _ => node.attr_float_or("min", f32::NEG_INFINITY),
    };
    let hi = match inputs.get(2) {
        Some(t) if t.numel() > 0 => t.scalar_value()?,
        _ => node.attr_float_or("max", f32::INFINITY),
    };
    Ok(vec![x.map(|v| v.clamp(lo, hi))?])
}

/// Shared requantization: `y = sat(round(acc * m) + y_zp)` with
/// `m = x_scale * w_scale / y_scale` — the fused output requantization the
/// quantized-operator format hardwires.
fn requantize(acc: &Tensor, multiplier: f64, y_zp: f64, lo: f64, hi: f64) -> Result<Tensor> {
    acc.map(|v| {
        let q = round_half_even(f64::from(v) * multiplier) + y_zp;
        q.clamp(lo, hi) as f32
    })
}

/// `QLinearConv` — quantized-operator-format convolution: int8 in/weights,
/// fused requantization to int8 out, int32 bias.
pub fn qlinear_conv(node: &Node, inputs: &[&Tensor]) -> Result<Vec<Tensor>> {
    ensure!(inputs.len() >= 8, "QLinearConv wants >= 8 inputs");
    let (x, x_scale, x_zp) = (inputs[0], inputs[1], inputs[2]);
    let (w, w_scale, w_zp) = (inputs[3], inputs[4], inputs[5]);
    let (y_scale, y_zp) = (inputs[6], inputs[7]);
    let bias = inputs.get(8).copied();
    ensure!(
        x_scale.numel() == 1 && x_zp.numel() == 1,
        "QLinearConv input quantization is restricted to per-tensor scale/zero-point (paper §III)"
    );
    ensure!(w_zp.numel() == 1, "per-tensor weight zero point only");

    // integer-domain conv over (x - x_zp), (w - w_zp)
    let xz = x_zp.scalar_value()?;
    let wz = w_zp.scalar_value()?;
    let x_int = x.map(|v| v - xz)?;
    let w_int = w.map(|v| v - wz)?;
    let acc = linalg::conv_impl(node, &x_int, &w_int, None)?;
    // bias is int32 with scale x_scale*w_scale (paper §II): added pre-requant
    let acc = match bias {
        Some(b) => {
            let bshape = vec![1, b.numel(), 1, 1];
            acc.binary_op(&b.reshape(bshape)?, |a, c| a + c)?
        }
        None => acc,
    };
    ensure!(w_scale.numel() == 1 || y_scale.numel() == 1, "channel-wise requant needs matching scales");
    let m = f64::from(x_scale.scalar_value()?) * f64::from(w_scale.scalar_value()?)
        / f64::from(y_scale.scalar_value()?);
    let (lo, hi) = q8_range(node);
    Ok(vec![requantize(&acc, m, f64::from(y_zp.scalar_value()?), lo, hi)?])
}

/// `QLinearMatMul` — quantized-operator-format matmul.
pub fn qlinear_matmul(node: &Node, inputs: &[&Tensor]) -> Result<Vec<Tensor>> {
    ensure!(inputs.len() == 8, "QLinearMatMul wants 8 inputs");
    let (a, a_scale, a_zp) = (inputs[0], inputs[1], inputs[2]);
    let (b, b_scale, b_zp) = (inputs[3], inputs[4], inputs[5]);
    let (y_scale, y_zp) = (inputs[6], inputs[7]);
    ensure!(a_scale.numel() == 1 && a_zp.numel() == 1 && b_zp.numel() == 1, "per-tensor only");
    let az = a_zp.scalar_value()?;
    let bz = b_zp.scalar_value()?;
    let acc = a.map(|v| v - az)?.matmul2d(&b.map(|v| v - bz)?)?;
    let m = f64::from(a_scale.scalar_value()?) * f64::from(b_scale.scalar_value()?)
        / f64::from(y_scale.scalar_value()?);
    let (lo, hi) = q8_range(node);
    Ok(vec![requantize(&acc, m, f64::from(y_zp.scalar_value()?), lo, hi)?])
}

/// `ConvInteger(x, w, x_zp?, w_zp?) -> int32 acc` — the integer-operator
/// format: no scales, wide output exposed (paper §III).
pub fn conv_integer(node: &Node, inputs: &[&Tensor]) -> Result<Vec<Tensor>> {
    ensure!(inputs.len() >= 2, "ConvInteger wants >= 2 inputs");
    let xz = inputs.get(2).map(|t| t.scalar_value()).transpose()?.unwrap_or(0.0);
    let wz = inputs.get(3).map(|t| t.scalar_value()).transpose()?.unwrap_or(0.0);
    let x_int = inputs[0].map(|v| v - xz)?;
    let w_int = inputs[1].map(|v| v - wz)?;
    Ok(vec![linalg::conv_impl(node, &x_int, &w_int, None)?])
}

/// `MatMulInteger(a, b, a_zp?, b_zp?) -> int32 acc`.
pub fn matmul_integer(_node: &Node, inputs: &[&Tensor]) -> Result<Vec<Tensor>> {
    ensure!(inputs.len() >= 2, "MatMulInteger wants >= 2 inputs");
    let az = inputs.get(2).map(|t| t.scalar_value()).transpose()?.unwrap_or(0.0);
    let bz = inputs.get(3).map(|t| t.scalar_value()).transpose()?.unwrap_or(0.0);
    Ok(vec![inputs[0].map(|v| v - az)?.matmul2d(&inputs[1].map(|v| v - bz)?)?])
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn quantize_linear_saturates_to_8bit() {
        let node = Node::new("QuantizeLinear", &["x", "s", "z"], &["y"]);
        let x = Tensor::new(vec![4], vec![-1000.0, -0.6, 0.6, 1000.0]);
        let s = Tensor::scalar(1.0);
        let z = Tensor::scalar(0.0);
        let y = quantize_linear(&node, &[&x, &s, &z]).unwrap();
        // default unsigned: [0, 255]
        assert_eq!(y[0].as_f32().unwrap(), &[0.0, 0.0, 1.0, 255.0]);
        let signed = node.clone().with_attr("signed", 1i64);
        let y = quantize_linear(&signed, &[&x, &s, &z]).unwrap();
        assert_eq!(y[0].as_f32().unwrap(), &[-128.0, -1.0, 1.0, 127.0]);
    }

    #[test]
    fn quantize_dequantize_roundtrip() {
        let qn = Node::new("QuantizeLinear", &["x", "s", "z"], &["q"]).with_attr("signed", 1i64);
        let dn = Node::new("DequantizeLinear", &["q", "s", "z"], &["y"]);
        let x = Tensor::new(vec![3], vec![0.49, -1.0, 2.26]);
        let s = Tensor::scalar(0.5);
        let z = Tensor::scalar(0.0);
        let q = quantize_linear(&qn, &[&x, &s, &z]).unwrap();
        let y = dequantize_linear(&dn, &[&q[0], &s, &z]).unwrap();
        assert_eq!(y[0].as_f32().unwrap(), &[0.5, -1.0, 2.5]);
    }

    #[test]
    fn clip_input_and_attr_forms() {
        let x = Tensor::new(vec![3], vec![-5.0, 0.5, 5.0]);
        // input form
        let n = Node::new("Clip", &["x", "lo", "hi"], &["y"]);
        let lo = Tensor::scalar(-1.0);
        let hi = Tensor::scalar(1.0);
        let y = clip(&n, &[&x, &lo, &hi]).unwrap();
        assert_eq!(y[0].as_f32().unwrap(), &[-1.0, 0.5, 1.0]);
        // attr form
        let n = Node::new("Clip", &["x"], &["y"]).with_attr("min", -2.0f32).with_attr("max", 2.0f32);
        let y = clip(&n, &[&x]).unwrap();
        assert_eq!(y[0].as_f32().unwrap(), &[-2.0, 0.5, 2.0]);
    }

    #[test]
    fn qcdq_models_int4_on_8bit_ops() {
        // paper §IV: QuantizeLinear -> Clip(int4 bounds) -> DequantizeLinear
        // equals direct 4-bit quantization.
        let qn = Node::new("QuantizeLinear", &["x", "s", "z"], &["q"]).with_attr("signed", 1i64);
        let cn = Node::new("Clip", &["q", "lo", "hi"], &["c"]);
        let dn = Node::new("DequantizeLinear", &["c", "s", "z"], &["y"]);
        let x = Tensor::new(vec![4], vec![-100.0, -3.2, 3.2, 100.0]);
        let s = Tensor::scalar(1.0);
        let z = Tensor::scalar(0.0);
        let q = quantize_linear(&qn, &[&x, &s, &z]).unwrap();
        let lo = Tensor::scalar(-8.0);
        let hi = Tensor::scalar(7.0);
        let c = clip(&cn, &[&q[0], &lo, &hi]).unwrap();
        let y = dequantize_linear(&dn, &[&c[0], &s, &z]).unwrap();
        assert_eq!(y[0].as_f32().unwrap(), &[-8.0, -3.0, 3.0, 7.0]);
    }

    #[test]
    fn matmul_integer_wide_accumulator() {
        let n = Node::new("MatMulInteger", &["a", "b"], &["y"]);
        let a = Tensor::new(vec![1, 2], vec![127.0, 127.0]);
        let b = Tensor::new(vec![2, 1], vec![127.0, 127.0]);
        let y = matmul_integer(&n, &[&a, &b]).unwrap();
        // 127*127*2 = 32258 — beyond int8, exposed as wide acc
        assert_eq!(y[0].as_f32().unwrap(), &[32258.0]);
    }

    #[test]
    fn matmul_integer_zero_points() {
        let n = Node::new("MatMulInteger", &["a", "b", "az", "bz"], &["y"]);
        let a = Tensor::new(vec![1, 2], vec![10.0, 10.0]);
        let b = Tensor::new(vec![2, 1], vec![5.0, 5.0]);
        let az = Tensor::scalar(10.0);
        let bz = Tensor::scalar(5.0);
        let y = matmul_integer(&n, &[&a, &b, &az, &bz]).unwrap();
        assert_eq!(y[0].as_f32().unwrap(), &[0.0]);
    }

    #[test]
    fn qlinear_matmul_requantizes() {
        let n = Node::new("QLinearMatMul", &["a", "as", "az", "b", "bs", "bz", "ys", "yz"], &["y"])
            .with_attr("signed", 1i64);
        let a = Tensor::new(vec![1, 2], vec![4.0, 4.0]);
        let b = Tensor::new(vec![2, 1], vec![4.0, 4.0]);
        let s1 = Tensor::scalar(0.5);
        let z0 = Tensor::scalar(0.0);
        // acc = 32, m = 0.5*0.5/0.25 = 1 -> 32
        let ys = Tensor::scalar(0.25);
        let y = qlinear_matmul(&n, &[&a, &s1, &z0, &b, &s1, &z0, &ys, &z0]).unwrap();
        assert_eq!(y[0].as_f32().unwrap(), &[32.0]);
        // tighter output scale saturates at 127
        let ys = Tensor::scalar(0.001);
        let y = qlinear_matmul(&n, &[&a, &s1, &z0, &b, &s1, &z0, &ys, &z0]).unwrap();
        assert_eq!(y[0].as_f32().unwrap(), &[127.0]);
    }

    #[test]
    fn qlinear_conv_1x1() {
        // 1x1 conv == per-pixel dot product
        let n = Node::new(
            "QLinearConv",
            &["x", "xs", "xz", "w", "ws", "wz", "ys", "yz", "b"],
            &["y"],
        )
        .with_attr("kernel_shape", vec![1i64, 1])
        .with_attr("signed", 1i64);
        let x = Tensor::new(vec![1, 1, 2, 2], vec![1.0, 2.0, 3.0, 4.0]);
        let w = Tensor::new(vec![1, 1, 1, 1], vec![2.0]);
        let one = Tensor::scalar(1.0);
        let zero = Tensor::scalar(0.0);
        let bias = Tensor::new(vec![1], vec![1.0]);
        let y = qlinear_conv(&n, &[&x, &one, &zero, &w, &one, &zero, &one, &zero, &bias]).unwrap();
        assert_eq!(y[0].shape(), &[1, 1, 2, 2]);
        assert_eq!(y[0].as_f32().unwrap(), &[3.0, 5.0, 7.0, 9.0]);
    }

    #[test]
    fn qlinear_conv_rejects_channelwise_input_scale() {
        // paper §III: "QLinearConv ... restrict input quantization to
        // per-tensor scale and zero point"
        let n = Node::new("QLinearConv", &["x", "xs", "xz", "w", "ws", "wz", "ys", "yz"], &["y"])
            .with_attr("kernel_shape", vec![1i64, 1]);
        let x = Tensor::new(vec![1, 2, 1, 1], vec![1.0, 2.0]);
        let xs = Tensor::new(vec![2], vec![1.0, 0.5]); // channel-wise: illegal
        let z = Tensor::scalar(0.0);
        let w = Tensor::new(vec![1, 2, 1, 1], vec![1.0, 1.0]);
        let one = Tensor::scalar(1.0);
        assert!(qlinear_conv(&n, &[&x, &xs, &z, &w, &one, &z, &one, &z]).is_err());
    }
}

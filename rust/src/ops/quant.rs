//! The QONNX dialect: `Quant`, `BipolarQuant`, `Trunc` (paper Table II,
//! Eqs. 1–4).
//!
//! All three fuse dequantization at the output: float32 in, float32 out,
//! with the quantized integer grid living *inside* the float container.

use crate::ir::Node;
use crate::tensor::{broadcast_shapes, BroadcastIter, Tensor};
use anyhow::{bail, ensure, Result};

/// Rounding modes accepted by `Quant`/`Trunc` (`rounding_mode` attribute).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum RoundingMode {
    /// Round half to even (banker's rounding) — QONNX `ROUND`.
    Round,
    /// Truncate toward zero — QONNX `ROUND_TO_ZERO`.
    RoundToZero,
    Ceil,
    Floor,
}

impl RoundingMode {
    pub fn from_str(s: &str) -> Result<RoundingMode> {
        Ok(match s {
            "ROUND" => RoundingMode::Round,
            "ROUND_TO_ZERO" => RoundingMode::RoundToZero,
            "CEIL" => RoundingMode::Ceil,
            "FLOOR" => RoundingMode::Floor,
            other => bail!("unknown rounding_mode '{other}'"),
        })
    }

    /// Apply the rounding function.
    pub fn apply(self, v: f64) -> f64 {
        match self {
            RoundingMode::Round => round_half_even(v),
            RoundingMode::RoundToZero => v.trunc(),
            RoundingMode::Ceil => v.ceil(),
            RoundingMode::Floor => v.floor(),
        }
    }
}

/// Smallest f32 strictly greater than `x`.
///
/// The threshold-construction passes ([`crate::transforms`]'s FINN
/// ingestion and [`crate::streamline`]) share this for their one-ULP tie
/// nudge: at a round-half-even tie the threshold must exclude the exact
/// boundary when the entered level is odd, and both lowerings must nudge
/// identically to stay bit-equivalent.
pub(crate) fn next_up(x: f32) -> f32 {
    if !x.is_finite() {
        return x;
    }
    if x == 0.0 {
        return f32::from_bits(1);
    }
    if x > 0.0 {
        f32::from_bits(x.to_bits() + 1)
    } else {
        f32::from_bits(x.to_bits() - 1)
    }
}

/// Round half to even, matching numpy's `np.round` / IEEE roundTiesToEven.
pub fn round_half_even(v: f64) -> f64 {
    let r = v.round(); // half away from zero
    if (v - v.trunc()).abs() == 0.5 {
        // tie: pick the even neighbor
        let floor = v.floor();
        if (floor % 2.0) == 0.0 {
            floor
        } else {
            floor + 1.0
        }
    } else {
        r
    }
}

/// Integer clamp bounds per Eqs. 2–3, extended with QONNX `narrow` and
/// float (fractional) bit widths (paper §V).
pub fn quant_bounds(signed: bool, narrow: bool, bit_width: f64) -> (f64, f64) {
    if signed {
        let min = -(2f64.powf(bit_width - 1.0)) + if narrow { 1.0 } else { 0.0 };
        let max = 2f64.powf(bit_width - 1.0) - 1.0;
        (min, max)
    } else {
        let min = 0.0;
        let max = 2f64.powf(bit_width) - 1.0 - if narrow { 1.0 } else { 0.0 };
        (min, max)
    }
}

/// Scalar quantize→dequantize per Eq. 1 + Eq. 4.
pub fn quantize_dequantize(
    x: f64,
    scale: f64,
    zero_point: f64,
    bit_width: f64,
    signed: bool,
    narrow: bool,
    mode: RoundingMode,
) -> f64 {
    let (qmin, qmax) = quant_bounds(signed, narrow, bit_width);
    let q = mode.apply(x / scale + zero_point).clamp(qmin, qmax);
    (q - zero_point) * scale
}

/// `Quant(x, scale, zero_point, bit_width) -> y` with broadcasting across
/// all four inputs (the paper's mechanism for channel-wise quantization —
/// including exotic cases like channel-wise *bit width*).
pub fn quant_op(node: &Node, inputs: &[&Tensor]) -> Result<Vec<Tensor>> {
    ensure!(inputs.len() == 4, "Quant wants 4 inputs, got {}", inputs.len());
    let (x, scale, zeropt, bitwidth) = (inputs[0], inputs[1], inputs[2], inputs[3]);
    let signed = node.attr_int_or("signed", 1) != 0;
    let narrow = node.attr_int_or("narrow", 0) != 0;
    let mode = RoundingMode::from_str(&node.attr_str_or("rounding_mode", "ROUND"))?;

    // output shape = broadcast of all inputs (normally == x.shape)
    let mut out_shape = x.shape().to_vec();
    for t in [scale, zeropt, bitwidth] {
        out_shape = broadcast_shapes(&out_shape, t.shape())?;
    }
    let xs = x.as_f32()?;
    let ss = scale.to_f64_vec();
    let zs = zeropt.to_f64_vec();
    let bs = bitwidth.to_f64_vec();
    for &b in &bs {
        ensure!(b >= 2.0 || (!signed && b >= 1.0), "Quant bit_width must be >= 2 (or 1 unsigned), got {b}");
    }
    for &s in &ss {
        ensure!(s > 0.0, "Quant scale must be positive, got {s}");
    }
    // §Perf fast path: scalar parameters (the overwhelmingly common case)
    // avoid the 4-way broadcast iterator and hoist the bounds/param
    // lookups out of the loop (~5x on the elementwise hot path). The
    // division is deliberately NOT strength-reduced to `* (1.0/s)`:
    // multiplying by the rounded reciprocal is up to 1 ulp off the true
    // quotient, and at a rounding-boundary tie that 1 ulp flips the
    // output by a full grid step — the fast path must stay bit-identical
    // to the broadcast path (and to `quantize_dequantize`).
    if ss.len() == 1 && zs.len() == 1 && bs.len() == 1 && out_shape == x.shape() {
        let (qmin, qmax) = quant_bounds(signed, narrow, bs[0]);
        let (s, z) = (ss[0], zs[0]);
        let out: Vec<f32> = xs
            .iter()
            .map(|&v| {
                let q = mode.apply(f64::from(v) / s + z).clamp(qmin, qmax);
                ((q - z) * s) as f32
            })
            .collect();
        return Ok(vec![Tensor::new(out_shape, out)]);
    }
    let n: usize = out_shape.iter().product();
    let mut out = Vec::with_capacity(n);
    let ix = BroadcastIter::new(x.shape(), &out_shape);
    let is = BroadcastIter::new(scale.shape(), &out_shape);
    let iz = BroadcastIter::new(zeropt.shape(), &out_shape);
    let ib = BroadcastIter::new(bitwidth.shape(), &out_shape);
    for (((ox, os), oz), ob) in ix.zip(is).zip(iz).zip(ib) {
        out.push(quantize_dequantize(f64::from(xs[ox]), ss[os], zs[oz], bs[ob], signed, narrow, mode) as f32);
    }
    Ok(vec![Tensor::new(out_shape, out)])
}

/// `BipolarQuant(x, scale) -> y`: y = scale * (+1 if x >= 0 else -1).
pub fn bipolar_quant_op(_node: &Node, inputs: &[&Tensor]) -> Result<Vec<Tensor>> {
    ensure!(inputs.len() == 2, "BipolarQuant wants 2 inputs, got {}", inputs.len());
    let (x, scale) = (inputs[0], inputs[1]);
    let out_shape = broadcast_shapes(x.shape(), scale.shape())?;
    let xs = x.as_f32()?;
    let ss = scale.to_f64_vec();
    for &s in &ss {
        ensure!(s > 0.0, "BipolarQuant scale must be positive, got {s}");
    }
    let ix = BroadcastIter::new(x.shape(), &out_shape);
    let is = BroadcastIter::new(scale.shape(), &out_shape);
    let mut out = Vec::with_capacity(out_shape.iter().product());
    for (ox, os) in ix.zip(is) {
        let q = if xs[ox] >= 0.0 { 1.0 } else { -1.0 };
        out.push((q * ss[os]) as f32);
    }
    Ok(vec![Tensor::new(out_shape, out)])
}

/// `Trunc(x, scale, zero_point, in_bit_width, out_bit_width) -> y`.
///
/// Truncates `in_bit_width - out_bit_width` LSBs of the quantized value.
/// With the input's scale/zero-point preserved on the output (paper §V),
/// the dequantized magnitude shrinks by `2^(in-out)` — exactly the
/// right-shift in a quantized average pool.
pub fn trunc_op(node: &Node, inputs: &[&Tensor]) -> Result<Vec<Tensor>> {
    ensure!(inputs.len() == 5, "Trunc wants 5 inputs, got {}", inputs.len());
    let (x, scale, zeropt, in_bw, out_bw) = (inputs[0], inputs[1], inputs[2], inputs[3], inputs[4]);
    let mode = RoundingMode::from_str(&node.attr_str_or("rounding_mode", "FLOOR"))?;
    let mut out_shape = x.shape().to_vec();
    for t in [scale, zeropt, in_bw, out_bw] {
        out_shape = broadcast_shapes(&out_shape, t.shape())?;
    }
    let xs = x.as_f32()?;
    let ss = scale.to_f64_vec();
    let zs = zeropt.to_f64_vec();
    let ibw = in_bw.to_f64_vec();
    let obw = out_bw.to_f64_vec();
    // 1-bit outputs are legal truncation targets (the quantized grid
    // still has 2^1 levels); what is *not* legal is widening — a
    // negative shift would amplify the magnitude instead of truncating.
    for &b in obw.iter() {
        ensure!(b >= 1.0, "Trunc out_bit_width must be >= 1, got {b}");
    }
    for &b in ibw.iter() {
        ensure!(b >= 1.0, "Trunc in_bit_width must be >= 1, got {b}");
    }
    // scalar widths (the common case) validate once up front — this also
    // covers zero-element outputs, which never reach the loop; broadcast
    // (per-channel) widths pair up per element inside the loop instead
    let widths_scalar = ibw.len() == 1 && obw.len() == 1;
    if widths_scalar {
        ensure!(
            ibw[0] >= obw[0],
            "Trunc out_bit_width {} exceeds in_bit_width {} (widening is not truncation)",
            obw[0],
            ibw[0]
        );
    }
    let ix = BroadcastIter::new(x.shape(), &out_shape);
    let is = BroadcastIter::new(scale.shape(), &out_shape);
    let iz = BroadcastIter::new(zeropt.shape(), &out_shape);
    let ii = BroadcastIter::new(in_bw.shape(), &out_shape);
    let io = BroadcastIter::new(out_bw.shape(), &out_shape);
    let mut out = Vec::with_capacity(out_shape.iter().product());
    for ((((ox, os), oz), oi), oo) in ix.zip(is).zip(iz).zip(ii).zip(io) {
        if !widths_scalar {
            ensure!(
                ibw[oi] >= obw[oo],
                "Trunc out_bit_width {} exceeds in_bit_width {} (widening is not truncation)",
                obw[oo],
                ibw[oi]
            );
        }
        let s = ss[os];
        let z = zs[oz];
        // recover the integer value under the declared input quantization
        let q = round_half_even(f64::from(xs[ox]) / s + z);
        let shift = 2f64.powf(ibw[oi] - obw[oo]);
        let q_trunc = mode.apply(q / shift);
        out.push(((q_trunc - z) * s) as f32);
    }
    Ok(vec![Tensor::new(out_shape, out)])
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ir::DOMAIN_QONNX;

    fn quant_node(signed: bool, narrow: bool, mode: &str) -> Node {
        Node::new("Quant", &["x", "s", "z", "b"], &["y"])
            .with_domain(DOMAIN_QONNX)
            .with_attr("signed", signed)
            .with_attr("narrow", narrow)
            .with_attr("rounding_mode", mode)
    }

    fn run_quant(
        xs: &[f32],
        scale: f32,
        zp: f32,
        bw: f32,
        signed: bool,
        narrow: bool,
        mode: &str,
    ) -> Vec<f32> {
        let x = Tensor::new(vec![xs.len()], xs.to_vec());
        let s = Tensor::scalar(scale);
        let z = Tensor::scalar(zp);
        let b = Tensor::scalar(bw);
        let node = quant_node(signed, narrow, mode);
        quant_op(&node, &[&x, &s, &z, &b]).unwrap()[0].as_f32().unwrap().to_vec()
    }

    #[test]
    fn round_half_even_matches_numpy() {
        assert_eq!(round_half_even(0.5), 0.0);
        assert_eq!(round_half_even(1.5), 2.0);
        assert_eq!(round_half_even(2.5), 2.0);
        assert_eq!(round_half_even(-0.5), -0.0);
        assert_eq!(round_half_even(-1.5), -2.0);
        assert_eq!(round_half_even(1.4), 1.0);
        assert_eq!(round_half_even(-1.6), -2.0);
    }

    #[test]
    fn bounds_table_ii_example() {
        // "at 8 bits if signed is true and narrow is false, the target is
        // [-128, 127]; if narrow is true, [-127, 127]"
        assert_eq!(quant_bounds(true, false, 8.0), (-128.0, 127.0));
        assert_eq!(quant_bounds(true, true, 8.0), (-127.0, 127.0));
        assert_eq!(quant_bounds(false, false, 8.0), (0.0, 255.0));
        assert_eq!(quant_bounds(false, true, 8.0), (0.0, 254.0));
    }

    #[test]
    fn fractional_bit_width_bounds() {
        // paper §V: nb = 7.5 gives a non-power-of-two interval
        let (lo, hi) = quant_bounds(true, false, 7.5);
        assert!(lo < -90.0 && lo > -91.0); // -2^6.5 = -90.50
        assert!(hi > 89.0 && hi < 90.0);
    }

    #[test]
    fn quant_int4_symmetric() {
        let y = run_quant(&[-2.0, -0.3, 0.0, 0.24, 0.26, 3.0], 0.5, 0.0, 4.0, true, false, "ROUND");
        // grid step 0.5, range q in [-8,7] -> y in [-4, 3.5]
        assert_eq!(y, vec![-2.0, -0.5, 0.0, 0.0, 0.5, 3.0]);
        let y = run_quant(&[-100.0, 100.0], 0.5, 0.0, 4.0, true, false, "ROUND");
        assert_eq!(y, vec![-4.0, 3.5]); // saturation
    }

    #[test]
    fn quant_unsigned_asymmetric() {
        // uint4, zero point 8: representable reals = (q-8)*s for q in [0,15]
        let y = run_quant(&[-10.0, 0.0, 10.0], 1.0, 8.0, 4.0, false, false, "ROUND");
        assert_eq!(y, vec![-8.0, 0.0, 7.0]);
    }

    #[test]
    fn quant_narrow_binary_weightlike() {
        // signed narrow 2-bit = {-1, 0, 1} ternary
        let y = run_quant(&[-5.0, -0.2, 0.7, 5.0], 1.0, 0.0, 2.0, true, true, "ROUND");
        assert_eq!(y, vec![-1.0, -0.0, 1.0, 1.0]);
    }

    #[test]
    fn rounding_modes_differ() {
        let x = [1.5f32, -1.5, 1.2, -1.2];
        assert_eq!(run_quant(&x, 1.0, 0.0, 8.0, true, false, "ROUND"), vec![2.0, -2.0, 1.0, -1.0]);
        assert_eq!(run_quant(&x, 1.0, 0.0, 8.0, true, false, "ROUND_TO_ZERO"), vec![1.0, -1.0, 1.0, -1.0]);
        assert_eq!(run_quant(&x, 1.0, 0.0, 8.0, true, false, "CEIL"), vec![2.0, -1.0, 2.0, -1.0]);
        assert_eq!(run_quant(&x, 1.0, 0.0, 8.0, true, false, "FLOOR"), vec![1.0, -2.0, 1.0, -2.0]);
    }

    #[test]
    fn channelwise_scale_broadcast() {
        // x [2,2], per-channel scale [2,1]
        let x = Tensor::new(vec![2, 2], vec![1.0, 2.0, 1.0, 2.0]);
        let s = Tensor::new(vec![2, 1], vec![1.0, 0.5]);
        let z = Tensor::scalar(0.0);
        let b = Tensor::scalar(8.0);
        let node = quant_node(true, false, "ROUND");
        let y = quant_op(&node, &[&x, &s, &z, &b]).unwrap();
        assert_eq!(y[0].as_f32().unwrap(), &[1.0, 2.0, 1.0, 2.0]);
        // row 1 snapped to 0.5 grid (values already on it)
        assert_eq!(y[0].shape(), &[2, 2]);
    }

    #[test]
    fn channelwise_bit_width_broadcast() {
        // the paper's "tensor-wise scale with channel-wise bit width"
        let x = Tensor::new(vec![2, 1], vec![100.0, 100.0]);
        let s = Tensor::scalar(1.0);
        let z = Tensor::scalar(0.0);
        let b = Tensor::new(vec![2, 1], vec![4.0, 8.0]);
        let node = quant_node(true, false, "ROUND");
        let y = quant_op(&node, &[&x, &s, &z, &b]).unwrap();
        assert_eq!(y[0].as_f32().unwrap(), &[7.0, 100.0]);
    }

    #[test]
    fn quant_rejects_bad_params() {
        let x = Tensor::scalar(1.0);
        let node = quant_node(true, false, "ROUND");
        // negative scale
        assert!(quant_op(&node, &[&x, &Tensor::scalar(-1.0), &Tensor::scalar(0.0), &Tensor::scalar(4.0)]).is_err());
        // bit width < 2
        assert!(quant_op(&node, &[&x, &Tensor::scalar(1.0), &Tensor::scalar(0.0), &Tensor::scalar(1.0)]).is_err());
        // bad rounding mode
        let bad = quant_node(true, false, "NEAREST");
        assert!(quant_op(&bad, &[&x, &Tensor::scalar(1.0), &Tensor::scalar(0.0), &Tensor::scalar(4.0)]).is_err());
    }

    #[test]
    fn scalar_fast_path_matches_broadcast_path_at_rounding_boundary() {
        // s = 0.102f32: x = 6.5 * s is exactly representable in f32, so
        // the true quotient x / s is exactly 6.5 — a round-half-even tie
        // that resolves to 6. Multiplying by the rounded reciprocal
        // instead gives x * (1.0/s) = 6.500000000000001, which rounds to
        // 7: a 1-ulp divergence becomes a full grid step. The scalar
        // fast path must therefore divide, exactly like the broadcast
        // path does.
        let s = 0.102f32;
        let x_val = (6.5 * f64::from(s)) as f32;
        assert_eq!(f64::from(x_val), 6.5 * f64::from(s), "tie input must be exact in f32");
        let x = Tensor::new(vec![2], vec![x_val, -x_val]);
        let scale = Tensor::scalar(s);
        let node = quant_node(true, false, "ROUND");
        // scalar params select the fast path ...
        let fast =
            quant_op(&node, &[&x, &scale, &Tensor::scalar(0.0), &Tensor::scalar(4.0)]).unwrap();
        // ... a length-2 zero point (same values) forces the broadcast path
        let z2 = Tensor::new(vec![2], vec![0.0, 0.0]);
        let broad = quant_op(&node, &[&x, &scale, &z2, &Tensor::scalar(4.0)]).unwrap();
        assert_eq!(fast[0], broad[0], "fast path diverged from broadcast path");
        // the tie resolves to the even integer 6 (and -6.5 to -6)
        assert_eq!(fast[0].as_f32().unwrap(), &[6.0 * s, -6.0 * s]);
    }

    #[test]
    fn bipolar_quant_signs() {
        let x = Tensor::new(vec![4], vec![-3.0, -0.0, 0.0, 2.0]);
        let s = Tensor::scalar(0.25);
        let node = Node::new("BipolarQuant", &["x", "s"], &["y"]).with_domain(DOMAIN_QONNX);
        let y = bipolar_quant_op(&node, &[&x, &s]).unwrap();
        assert_eq!(y[0].as_f32().unwrap(), &[-0.25, 0.25, 0.25, 0.25]);
    }

    #[test]
    fn trunc_right_shift_avg_pool_usecase() {
        // sum of four uint8 values = 10 bits; truncate back to 8 bits = /4
        let node = Node::new("Trunc", &["x", "s", "z", "i", "o"], &["y"]).with_domain(DOMAIN_QONNX);
        let x = Tensor::new(vec![2], vec![100.0, 203.0]);
        let (s, z) = (Tensor::scalar(1.0), Tensor::scalar(0.0));
        let (i, o) = (Tensor::scalar(10.0), Tensor::scalar(8.0));
        let y = trunc_op(&node, &[&x, &s, &z, &i, &o]).unwrap();
        // FLOOR(100/4)=25, FLOOR(203/4)=50
        assert_eq!(y[0].as_f32().unwrap(), &[25.0, 50.0]);
    }

    #[test]
    fn trunc_rounding_mode_round() {
        let node = Node::new("Trunc", &["x", "s", "z", "i", "o"], &["y"])
            .with_domain(DOMAIN_QONNX)
            .with_attr("rounding_mode", "ROUND");
        let x = Tensor::new(vec![1], vec![203.0]);
        let (s, z) = (Tensor::scalar(1.0), Tensor::scalar(0.0));
        let (i, o) = (Tensor::scalar(10.0), Tensor::scalar(8.0));
        let y = trunc_op(&node, &[&x, &s, &z, &i, &o]).unwrap();
        // 203/4 = 50.75 -> 51
        assert_eq!(y[0].as_f32().unwrap(), &[51.0]);
    }

    #[test]
    fn trunc_to_one_bit_is_legal() {
        // binarizing truncation: 2-bit -> 1-bit drops one LSB (shift 2)
        let node = Node::new("Trunc", &["x", "s", "z", "i", "o"], &["y"]).with_domain(DOMAIN_QONNX);
        let x = Tensor::new(vec![3], vec![3.0, 1.0, 0.0]);
        let (s, z) = (Tensor::scalar(1.0), Tensor::scalar(0.0));
        let (i, o) = (Tensor::scalar(2.0), Tensor::scalar(1.0));
        let y = trunc_op(&node, &[&x, &s, &z, &i, &o]).unwrap();
        assert_eq!(y[0].as_f32().unwrap(), &[1.0, 0.0, 0.0]);
    }

    #[test]
    fn trunc_rejects_widening_and_sub_one_widths() {
        let node = Node::new("Trunc", &["x", "s", "z", "i", "o"], &["y"]).with_domain(DOMAIN_QONNX);
        let x = Tensor::new(vec![1], vec![5.0]);
        let (s, z) = (Tensor::scalar(1.0), Tensor::scalar(0.0));
        // out wider than in: a negative shift would *amplify*, not truncate
        let err = trunc_op(&node, &[&x, &s, &z, &Tensor::scalar(4.0), &Tensor::scalar(8.0)])
            .unwrap_err()
            .to_string();
        assert!(err.contains("exceeds in_bit_width"), "{err}");
        // widths below 1 bit are meaningless
        assert!(trunc_op(&node, &[&x, &s, &z, &Tensor::scalar(4.0), &Tensor::scalar(0.0)]).is_err());
        // equal widths are a legal no-op shift
        let y = trunc_op(&node, &[&x, &s, &z, &Tensor::scalar(4.0), &Tensor::scalar(4.0)]).unwrap();
        assert_eq!(y[0].as_f32().unwrap(), &[5.0]);
    }

    #[test]
    fn trunc_respects_scale() {
        // scale 0.5: x=12.5 -> q=25; shift 2 bits -> floor(25/4)=6 -> y=3.0
        let node = Node::new("Trunc", &["x", "s", "z", "i", "o"], &["y"]).with_domain(DOMAIN_QONNX);
        let x = Tensor::new(vec![1], vec![12.5]);
        let (s, z) = (Tensor::scalar(0.5), Tensor::scalar(0.0));
        let (i, o) = (Tensor::scalar(8.0), Tensor::scalar(6.0));
        let y = trunc_op(&node, &[&x, &s, &z, &i, &o]).unwrap();
        assert_eq!(y[0].as_f32().unwrap(), &[3.0]);
    }
}

//! Structural operators: reshapes, transposes, shape queries, etc.
//!
//! These include the `Shape`/`Gather`/`Unsqueeze`/`Concat` chain that
//! PyTorch exporters emit for flatten operations — the structure the
//! paper's Fig. 2 cleanup collapses into a single `Reshape`.

use crate::ir::Node;
use crate::tensor::Tensor;
use anyhow::{bail, ensure, Result};

/// ONNX `Reshape(x, shape)` — supports `-1` (infer) and `0` (copy dim).
pub fn reshape(_node: &Node, inputs: &[&Tensor]) -> Result<Vec<Tensor>> {
    ensure!(inputs.len() == 2, "Reshape wants 2 inputs");
    let x = inputs[0];
    let target = inputs[1].to_i64_vec();
    Ok(vec![x.reshape(resolve_reshape(x.shape(), &target)?)?])
}

/// Resolve an ONNX reshape target against an input shape.
pub fn resolve_reshape(in_shape: &[usize], target: &[i64]) -> Result<Vec<usize>> {
    let numel: usize = in_shape.iter().product();
    let mut out = Vec::with_capacity(target.len());
    let mut infer_at = None;
    for (i, &d) in target.iter().enumerate() {
        match d {
            -1 => {
                ensure!(infer_at.is_none(), "multiple -1 in reshape target");
                infer_at = Some(i);
                out.push(1);
            }
            0 => {
                ensure!(i < in_shape.len(), "0-dim copy out of range");
                out.push(in_shape[i]);
            }
            d if d > 0 => out.push(d as usize),
            d => bail!("bad reshape dim {d}"),
        }
    }
    if let Some(i) = infer_at {
        let known: usize = out.iter().product();
        ensure!(known > 0 && numel % known == 0, "cannot infer -1: {numel} / {known}");
        out[i] = numel / known;
    }
    ensure!(out.iter().product::<usize>() == numel, "reshape {in_shape:?} -> {target:?} loses elements");
    Ok(out)
}

/// ONNX `Transpose` with `perm` attribute (default reverse).
pub fn transpose(node: &Node, inputs: &[&Tensor]) -> Result<Vec<Tensor>> {
    let x = inputs[0];
    let perm: Vec<usize> = match node.attrs.get("perm") {
        Some(a) => a.as_ints()?.iter().map(|&v| v as usize).collect(),
        None => (0..x.rank()).rev().collect(),
    };
    Ok(vec![x.transpose(&perm)?])
}

/// ONNX `Flatten` around `axis` (default 1).
pub fn flatten(node: &Node, inputs: &[&Tensor]) -> Result<Vec<Tensor>> {
    let x = inputs[0];
    let mut axis = node.attr_int_or("axis", 1);
    if axis < 0 {
        axis += x.rank() as i64;
    }
    let axis = axis as usize;
    let outer: usize = x.shape()[..axis].iter().product();
    let inner: usize = x.shape()[axis..].iter().product();
    Ok(vec![x.reshape(vec![outer, inner])?])
}

/// ONNX `Pad` (constant mode): pads from input[1] or `pads` attribute.
pub fn pad(node: &Node, inputs: &[&Tensor]) -> Result<Vec<Tensor>> {
    let x = inputs[0];
    let pads: Vec<i64> = match inputs.get(1) {
        Some(t) => t.to_i64_vec(),
        None => node.attr("pads")?.as_ints()?.to_vec(),
    };
    let value = match inputs.get(2) {
        Some(t) => t.scalar_value()?,
        None => node.attr_float_or("value", 0.0),
    };
    let mode = node.attr_str_or("mode", "constant");
    ensure!(mode == "constant", "only constant-mode Pad supported");
    let rank = x.rank();
    ensure!(pads.len() == 2 * rank, "pads length {} != 2*rank {rank}", pads.len());
    let mut out_shape = Vec::with_capacity(rank);
    for d in 0..rank {
        let total = x.shape()[d] as i64 + pads[d] + pads[rank + d];
        ensure!(total >= 0, "negative padded dim");
        out_shape.push(total as usize);
    }
    let src = x.as_f32()?;
    let mut out = vec![value; out_shape.iter().product()];
    let in_strides = x.strides();
    let out_strides = crate::tensor::strides_for(&out_shape);
    let mut idx = vec![0usize; rank];
    'outer: loop {
        let mut src_off = 0;
        let mut dst_off = 0;
        for d in 0..rank {
            src_off += idx[d] * in_strides[d];
            dst_off += (idx[d] as i64 + pads[d]) as usize * out_strides[d];
        }
        out[dst_off] = src[src_off];
        // advance
        for d in (0..rank).rev() {
            idx[d] += 1;
            if idx[d] < x.shape()[d] {
                continue 'outer;
            }
            idx[d] = 0;
        }
        break;
    }
    Ok(vec![Tensor::new(out_shape, out)])
}

/// ONNX `Concat` along `axis`.
pub fn concat(node: &Node, inputs: &[&Tensor]) -> Result<Vec<Tensor>> {
    ensure!(!inputs.is_empty(), "Concat wants >= 1 input");
    let mut axis = node.attr("axis")?.as_int()?;
    let rank = inputs[0].rank() as i64;
    if axis < 0 {
        axis += rank;
    }
    let axis = axis as usize;
    // i64 concat (shape chains) vs f32 concat
    if inputs[0].is_i64() {
        ensure!(inputs[0].rank() == 1, "i64 Concat supports rank-1 only");
        let mut data = Vec::new();
        for t in inputs {
            data.extend_from_slice(t.as_i64()?);
        }
        let n = data.len();
        return Ok(vec![Tensor::new_i64(vec![n], data)]);
    }
    let mut out_shape = inputs[0].shape().to_vec();
    let mut axis_total = 0usize;
    for t in inputs {
        ensure!(t.rank() == out_shape.len(), "Concat rank mismatch");
        for d in 0..out_shape.len() {
            if d != axis {
                ensure!(t.shape()[d] == out_shape[d], "Concat non-axis dim mismatch");
            }
        }
        axis_total += t.shape()[axis];
    }
    out_shape[axis] = axis_total;
    let outer: usize = out_shape[..axis].iter().product();
    let inner: usize = out_shape[axis + 1..].iter().product();
    let mut out = Vec::with_capacity(out_shape.iter().product());
    for o in 0..outer {
        for t in inputs {
            let ta = t.shape()[axis];
            let src = t.as_f32()?;
            out.extend_from_slice(&src[o * ta * inner..(o + 1) * ta * inner]);
        }
    }
    Ok(vec![Tensor::new(out_shape, out)])
}

/// ONNX `Shape` — emits the input's shape as a rank-1 i64 tensor.
pub fn shape_op(_node: &Node, inputs: &[&Tensor]) -> Result<Vec<Tensor>> {
    let s: Vec<i64> = inputs[0].shape().iter().map(|&d| d as i64).collect();
    let n = s.len();
    Ok(vec![Tensor::new_i64(vec![n], s)])
}

/// ONNX `Gather` along `axis` with i64 indices (rank-1 data fast path for
/// the exporter shape chains, general f32 gather otherwise).
pub fn gather(node: &Node, inputs: &[&Tensor]) -> Result<Vec<Tensor>> {
    ensure!(inputs.len() == 2, "Gather wants 2 inputs");
    let (data, indices) = (inputs[0], inputs[1]);
    let axis = node.attr_int_or("axis", 0);
    let idx = indices.to_i64_vec();
    if data.is_i64() {
        ensure!(data.rank() == 1 && axis == 0, "i64 Gather supports rank-1 axis-0");
        let src = data.as_i64()?;
        let mut out = Vec::with_capacity(idx.len());
        for &i in &idx {
            let i = if i < 0 { i + src.len() as i64 } else { i } as usize;
            ensure!(i < src.len(), "Gather index {i} out of range");
            out.push(src[i]);
        }
        // scalar indices produce rank-0 output
        return Ok(vec![if indices.rank() == 0 {
            Tensor::new_i64(vec![], out)
        } else {
            let n = out.len();
            Tensor::new_i64(vec![n], out)
        }]);
    }
    ensure!(axis == 0, "f32 Gather supports axis 0 only");
    let src = data.as_f32()?;
    let row: usize = data.shape()[1..].iter().product();
    let mut out = Vec::with_capacity(idx.len() * row);
    for &i in &idx {
        let i = if i < 0 { i + data.shape()[0] as i64 } else { i } as usize;
        ensure!(i < data.shape()[0], "Gather index {i} out of range");
        out.extend_from_slice(&src[i * row..(i + 1) * row]);
    }
    let mut out_shape: Vec<usize> = indices.shape().to_vec();
    out_shape.extend_from_slice(&data.shape()[1..]);
    Ok(vec![Tensor::new(out_shape, out)])
}

fn resolve_axes(node: &Node, inputs: &[&Tensor], rank: i64) -> Result<Vec<i64>> {
    let mut axes = match inputs.get(1) {
        Some(t) => t.to_i64_vec(),
        None => node.attr_ints_or("axes", &[]),
    };
    for a in &mut axes {
        if *a < 0 {
            *a += rank;
        }
    }
    axes.sort_unstable();
    Ok(axes)
}

/// ONNX `Unsqueeze` (axes from attr or input).
pub fn unsqueeze(node: &Node, inputs: &[&Tensor]) -> Result<Vec<Tensor>> {
    let x = inputs[0];
    let out_rank = x.rank() as i64 + resolve_axes(node, inputs, x.rank() as i64 + 1)?.len() as i64;
    let axes = resolve_axes(node, inputs, out_rank)?;
    let mut shape: Vec<usize> = x.shape().to_vec();
    for &a in &axes {
        shape.insert(a as usize, 1);
    }
    if x.is_i64() {
        let data = x.as_i64()?.to_vec();
        return Ok(vec![Tensor::new_i64(shape, data)]);
    }
    Ok(vec![x.reshape(shape)?])
}

/// ONNX `Squeeze` (axes from attr or input; empty = all unit dims).
pub fn squeeze(node: &Node, inputs: &[&Tensor]) -> Result<Vec<Tensor>> {
    let x = inputs[0];
    let axes = resolve_axes(node, inputs, x.rank() as i64)?;
    let shape: Vec<usize> = if axes.is_empty() {
        x.shape().iter().copied().filter(|&d| d != 1).collect()
    } else {
        x.shape()
            .iter()
            .enumerate()
            .filter(|(i, _)| !axes.contains(&(*i as i64)))
            .map(|(_, &d)| d)
            .collect()
    };
    if x.is_i64() {
        let data = x.as_i64()?.to_vec();
        return Ok(vec![Tensor::new_i64(shape, data)]);
    }
    Ok(vec![x.reshape(shape)?])
}

/// ONNX `Identity`.
pub fn identity(_node: &Node, inputs: &[&Tensor]) -> Result<Vec<Tensor>> {
    Ok(vec![inputs[0].clone()])
}

/// ONNX `Constant` — value from the `value` tensor attribute.
pub fn constant(node: &Node, _inputs: &[&Tensor]) -> Result<Vec<Tensor>> {
    Ok(vec![node.attr("value")?.as_tensor()?.clone()])
}

/// ONNX `ArgMax` along `axis` (used for classification accuracy).
pub fn argmax(node: &Node, inputs: &[&Tensor]) -> Result<Vec<Tensor>> {
    let x = inputs[0];
    let rank = x.rank() as i64;
    let mut axis = node.attr_int_or("axis", 0);
    if axis < 0 {
        axis += rank;
    }
    ensure!(axis == rank - 1, "ArgMax only along last axis");
    let keepdims = node.attr_int_or("keepdims", 1) != 0;
    let inner = *x.shape().last().unwrap();
    let outer = x.numel() / inner;
    let src = x.as_f32()?;
    let mut out = Vec::with_capacity(outer);
    for r in 0..outer {
        let row = &src[r * inner..(r + 1) * inner];
        let mut best = 0usize;
        for (i, &v) in row.iter().enumerate() {
            if v > row[best] {
                best = i;
            }
        }
        out.push(best as i64);
    }
    let mut shape: Vec<usize> = x.shape()[..x.rank() - 1].to_vec();
    if keepdims {
        shape.push(1);
    }
    Ok(vec![Tensor::new_i64(shape, out)])
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn reshape_infer_and_copy() {
        assert_eq!(resolve_reshape(&[2, 3, 4], &[-1, 4]).unwrap(), vec![6, 4]);
        assert_eq!(resolve_reshape(&[2, 3, 4], &[0, -1]).unwrap(), vec![2, 12]);
        assert!(resolve_reshape(&[2, 3], &[-1, -1]).is_err());
        assert!(resolve_reshape(&[2, 3], &[4, 2]).is_err());
    }

    #[test]
    fn exporter_shape_chain_collapses_to_flatten() {
        // Shape -> Gather(0) -> Unsqueeze -> Concat([-1]) -> Reshape:
        // the PyTorch flatten idiom from Fig. 1, executed node by node.
        let x = Tensor::new(vec![2, 3, 2, 2], (0..24).map(|v| v as f32).collect());
        let s = shape_op(&Node::new("Shape", &["x"], &["s"]), &[&x]).unwrap();
        let g = gather(
            &Node::new("Gather", &["s", "i"], &["g"]).with_attr("axis", 0i64),
            &[&s[0], &Tensor::new_i64(vec![], vec![0])],
        )
        .unwrap();
        assert_eq!(g[0].rank(), 0);
        let u = unsqueeze(
            &Node::new("Unsqueeze", &["g"], &["u"]).with_attr("axes", vec![0i64]),
            &[&g[0]],
        )
        .unwrap();
        assert_eq!(u[0].shape(), &[1]);
        let c = concat(
            &Node::new("Concat", &["u", "m"], &["c"]).with_attr("axis", 0i64),
            &[&u[0], &Tensor::new_i64(vec![1], vec![-1])],
        )
        .unwrap();
        assert_eq!(c[0].as_i64().unwrap(), &[2, -1]);
        let r = reshape(&Node::new("Reshape", &["x", "c"], &["y"]), &[&x, &c[0]]).unwrap();
        assert_eq!(r[0].shape(), &[2, 12]);
    }

    #[test]
    fn pad_2d() {
        let n = Node::new("Pad", &["x"], &["y"]).with_attr("pads", vec![0i64, 1, 0, 1]);
        let x = Tensor::new(vec![1, 2], vec![5.0, 6.0]);
        let y = pad(&n, &[&x]).unwrap();
        assert_eq!(y[0].shape(), &[1, 4]);
        assert_eq!(y[0].as_f32().unwrap(), &[0.0, 5.0, 6.0, 0.0]);
    }

    #[test]
    fn concat_f32_axis1() {
        let n = Node::new("Concat", &["a", "b"], &["y"]).with_attr("axis", 1i64);
        let a = Tensor::new(vec![2, 1], vec![1., 2.]);
        let b = Tensor::new(vec![2, 2], vec![3., 4., 5., 6.]);
        let y = concat(&n, &[&a, &b]).unwrap();
        assert_eq!(y[0].shape(), &[2, 3]);
        assert_eq!(y[0].as_f32().unwrap(), &[1., 3., 4., 2., 5., 6.]);
    }

    #[test]
    fn flatten_axis_variants() {
        let x = Tensor::new(vec![2, 3, 4], (0..24).map(|v| v as f32).collect());
        let y = flatten(&Node::new("Flatten", &["x"], &["y"]), &[&x]).unwrap();
        assert_eq!(y[0].shape(), &[2, 12]);
        let y = flatten(&Node::new("Flatten", &["x"], &["y"]).with_attr("axis", 0i64), &[&x]).unwrap();
        assert_eq!(y[0].shape(), &[1, 24]);
    }

    #[test]
    fn squeeze_unsqueeze_roundtrip() {
        let x = Tensor::new(vec![2, 3], (0..6).map(|v| v as f32).collect());
        let u = unsqueeze(&Node::new("Unsqueeze", &["x"], &["y"]).with_attr("axes", vec![0i64, 3]), &[&x]).unwrap();
        assert_eq!(u[0].shape(), &[1, 2, 3, 1]);
        let s = squeeze(&Node::new("Squeeze", &["y"], &["z"]), &[&u[0]]).unwrap();
        assert_eq!(s[0].shape(), &[2, 3]);
    }

    #[test]
    fn argmax_last_axis() {
        let n = Node::new("ArgMax", &["x"], &["y"]).with_attr("axis", -1i64).with_attr("keepdims", 0i64);
        let x = Tensor::new(vec![2, 3], vec![1., 5., 2., 9., 0., 3.]);
        let y = argmax(&n, &[&x]).unwrap();
        assert_eq!(y[0].as_i64().unwrap(), &[1, 0]);
    }

    #[test]
    fn constant_emits_attr_tensor() {
        let t = Tensor::new(vec![2], vec![1.5, 2.5]);
        let n = Node::new("Constant", &[], &["y"]).with_attr("value", t.clone());
        assert_eq!(constant(&n, &[]).unwrap()[0], t);
    }
}

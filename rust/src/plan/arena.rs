//! Arenas: the compile-time tensor-slot allocator ([`SlotArena`]) and the
//! run-time scratch-buffer pool ([`ScratchArena`]).
//!
//! The plan compiler runs a register-allocation style linear scan over the
//! frozen step schedule: every value (graph input, preloaded constant,
//! node output) is assigned a *physical slot*, and slots whose value has
//! passed its last use are recycled for later values. The [`SlotArena`] is
//! the compile-time allocator for that scan; at run time the plan
//! materializes `capacity()` slots once and indexes them directly — no
//! name-keyed map, and peak live tensors is bounded by the schedule's
//! high-water mark rather than the total tensor count.
//!
//! The [`ScratchArena`] is the run-time counterpart: compiled kernels
//! draw their working buffers (im2col matrices, GEMM products, output
//! tensors) from it instead of `vec!`-allocating per call, and the
//! executor returns released intermediates' storage to it — so kernel
//! scratch reaches a zero-allocation steady state (small bookkeeping
//! vectors and buffers that leave as graph outputs still allocate).

/// Compile-time slot allocator with a free list.
#[derive(Debug, Default, Clone)]
pub struct SlotArena {
    free: Vec<u32>,
    next: u32,
}

impl SlotArena {
    pub fn new() -> SlotArena {
        SlotArena::default()
    }

    /// Allocate a slot, preferring a recycled one.
    pub fn alloc(&mut self) -> u32 {
        self.free.pop().unwrap_or_else(|| {
            let s = self.next;
            self.next += 1;
            s
        })
    }

    /// Return a slot to the free list (its value passed its last use).
    pub fn release(&mut self, slot: u32) {
        debug_assert!(slot < self.next, "released slot {slot} was never allocated");
        self.free.push(slot);
    }

    /// Total distinct slots ever allocated — the run-time slot-vector size
    /// and the schedule's high-water mark of live tensors.
    pub fn capacity(&self) -> usize {
        self.next as usize
    }

    /// Currently live (allocated, not released) slots.
    pub fn live(&self) -> usize {
        self.next as usize - self.free.len()
    }
}

/// Cap on pooled buffers: enough for every live scratch/output buffer of
/// a deep model's widest region without hoarding unbounded memory.
const SCRATCH_POOL_CAP: usize = 16;

/// Run-time f32 buffer pool — the scratch side of the kernel invocation
/// contract ([`super::CompiledKernel::invoke`] takes `&mut ScratchArena`).
///
/// `take(len)` hands out a zero-filled buffer of exactly `len` elements,
/// reusing the best-fitting pooled allocation; `give` returns storage for
/// later reuse. The executor keeps one arena per run (engines keep one
/// across requests), so conv im2col/product buffers and recycled
/// intermediate outputs reach a steady state with zero heap traffic.
#[derive(Debug, Default)]
pub struct ScratchArena {
    free: Vec<Vec<f32>>,
    /// Separate pool for the quantized tier's `i32` working buffers
    /// (activation conversions, integer im2col, accumulators).
    free_i32: Vec<Vec<i32>>,
}

impl ScratchArena {
    pub fn new() -> ScratchArena {
        ScratchArena::default()
    }

    /// A zero-filled buffer of exactly `len` elements. Prefers the pooled
    /// buffer whose capacity fits `len` most tightly (falls back to the
    /// largest, which then grows in place).
    pub fn take(&mut self, len: usize) -> Vec<f32> {
        let mut buf = self.pick(len);
        buf.clear();
        buf.resize(len, 0.0);
        buf
    }

    /// A buffer of exactly `len` elements whose contents are
    /// **unspecified** (stale data from a previous use may remain). For
    /// outputs that every-element-overwrite before reading — skips the
    /// full zeroing memset that [`ScratchArena::take`] pays.
    pub fn take_uninit(&mut self, len: usize) -> Vec<f32> {
        let mut buf = self.pick(len);
        // no clear(): an equal-length reuse is a no-op, a shorter one
        // truncates, and only a longer one zero-fills the gap
        buf.resize(len, 0.0);
        buf
    }

    /// Best-fit pooled buffer for `len` (or a fresh allocation).
    fn pick(&mut self, len: usize) -> Vec<f32> {
        let mut best: Option<usize> = None;
        for (i, b) in self.free.iter().enumerate() {
            let cap = b.capacity();
            best = match best {
                None => Some(i),
                Some(j) => {
                    let bj = self.free[j].capacity();
                    let better = if bj >= len { cap >= len && cap < bj } else { cap > bj };
                    Some(if better { i } else { j })
                }
            };
        }
        match best {
            Some(i) => self.free.swap_remove(i),
            None => Vec::with_capacity(len),
        }
    }

    /// Return a buffer's storage to the pool. When the pool is full the
    /// smallest resident buffer is evicted (largest allocations are the
    /// ones worth keeping).
    pub fn give(&mut self, buf: Vec<f32>) {
        if buf.capacity() == 0 {
            return;
        }
        if self.free.len() < SCRATCH_POOL_CAP {
            self.free.push(buf);
            return;
        }
        if let Some((i, _)) = self
            .free
            .iter()
            .enumerate()
            .min_by_key(|(_, b)| b.capacity())
        {
            if self.free[i].capacity() < buf.capacity() {
                self.free[i] = buf;
            }
        }
    }

    /// Buffers currently pooled (diagnostics).
    pub fn pooled(&self) -> usize {
        self.free.len()
    }

    /// A zero-filled `i32` buffer of exactly `len` elements (quantized
    /// kernel tier). Same best-fit policy as [`ScratchArena::take`].
    pub fn take_i32(&mut self, len: usize) -> Vec<i32> {
        let mut buf = self.pick_i32(len);
        buf.clear();
        buf.resize(len, 0);
        buf
    }

    /// An `i32` buffer of exactly `len` elements with **unspecified**
    /// contents (counterpart of [`ScratchArena::take_uninit`]).
    pub fn take_i32_uninit(&mut self, len: usize) -> Vec<i32> {
        let mut buf = self.pick_i32(len);
        buf.resize(len, 0);
        buf
    }

    fn pick_i32(&mut self, len: usize) -> Vec<i32> {
        let mut best: Option<usize> = None;
        for (i, b) in self.free_i32.iter().enumerate() {
            let cap = b.capacity();
            best = match best {
                None => Some(i),
                Some(j) => {
                    let bj = self.free_i32[j].capacity();
                    let better = if bj >= len { cap >= len && cap < bj } else { cap > bj };
                    Some(if better { i } else { j })
                }
            };
        }
        match best {
            Some(i) => self.free_i32.swap_remove(i),
            None => Vec::with_capacity(len),
        }
    }

    /// Return an `i32` buffer's storage to the pool.
    pub fn give_i32(&mut self, buf: Vec<i32>) {
        if buf.capacity() == 0 {
            return;
        }
        if self.free_i32.len() < SCRATCH_POOL_CAP {
            self.free_i32.push(buf);
            return;
        }
        if let Some((i, _)) =
            self.free_i32.iter().enumerate().min_by_key(|(_, b)| b.capacity())
        {
            if self.free_i32[i].capacity() < buf.capacity() {
                self.free_i32[i] = buf;
            }
        }
    }

    /// `i32` buffers currently pooled (diagnostics).
    pub fn pooled_i32(&self) -> usize {
        self.free_i32.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn reuses_released_slots() {
        let mut a = SlotArena::new();
        let s0 = a.alloc();
        let s1 = a.alloc();
        assert_ne!(s0, s1);
        a.release(s0);
        assert_eq!(a.alloc(), s0, "freed slot is recycled");
        assert_eq!(a.capacity(), 2);
        assert_eq!(a.live(), 2);
    }

    #[test]
    fn capacity_is_high_water_mark() {
        let mut a = SlotArena::new();
        // chain pattern: alloc, release, alloc, release ... stays at 1 slot
        let mut s = a.alloc();
        for _ in 0..10 {
            a.release(s);
            s = a.alloc();
        }
        assert_eq!(a.capacity(), 1);
    }

    #[test]
    fn scratch_zero_fills_reused_buffers() {
        let mut s = ScratchArena::new();
        let mut b = s.take(4);
        b.copy_from_slice(&[1.0, 2.0, 3.0, 4.0]);
        s.give(b);
        assert_eq!(s.pooled(), 1);
        let b2 = s.take(3);
        assert_eq!(b2, vec![0.0; 3], "reused buffer must come back zeroed");
        let b3 = s.take(8); // pool empty again; fresh allocation
        assert_eq!(b3.len(), 8);
    }

    #[test]
    fn scratch_best_fit_prefers_tightest_buffer() {
        let mut s = ScratchArena::new();
        s.give(Vec::with_capacity(100));
        s.give(Vec::with_capacity(10));
        let b = s.take(8);
        assert!(b.capacity() < 100, "should pick the 10-cap buffer");
        assert_eq!(s.pooled(), 1);
    }

    #[test]
    fn scratch_take_uninit_sizes_without_guaranteeing_contents() {
        let mut s = ScratchArena::new();
        let mut b = s.take(4);
        b.copy_from_slice(&[1.0, 2.0, 3.0, 4.0]);
        s.give(b);
        // only the length is guaranteed; contents are unspecified
        assert_eq!(s.take_uninit(4).len(), 4);
        assert_eq!(s.take_uninit(7).len(), 7);
    }

    #[test]
    fn scratch_pool_is_bounded() {
        let mut s = ScratchArena::new();
        for i in 0..2 * SCRATCH_POOL_CAP {
            s.give(Vec::with_capacity(i + 1));
        }
        assert!(s.pooled() <= SCRATCH_POOL_CAP);
    }

    #[test]
    fn i32_pool_mirrors_f32_pool() {
        let mut s = ScratchArena::new();
        let mut b = s.take_i32(4);
        b.copy_from_slice(&[1, 2, 3, 4]);
        s.give_i32(b);
        assert_eq!(s.pooled_i32(), 1);
        assert_eq!(s.pooled(), 0, "i32 pool is separate from the f32 pool");
        let b2 = s.take_i32(3);
        assert_eq!(b2, vec![0; 3], "reused i32 buffer must come back zeroed");
        assert_eq!(s.take_i32_uninit(7).len(), 7);
        for i in 0..2 * SCRATCH_POOL_CAP {
            s.give_i32(Vec::with_capacity(i + 1));
        }
        assert!(s.pooled_i32() <= SCRATCH_POOL_CAP);
    }
}

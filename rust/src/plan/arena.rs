//! Physical tensor-slot arena.
//!
//! The plan compiler runs a register-allocation style linear scan over the
//! frozen step schedule: every value (graph input, preloaded constant,
//! node output) is assigned a *physical slot*, and slots whose value has
//! passed its last use are recycled for later values. The arena is the
//! compile-time allocator for that scan; at run time the plan materializes
//! `capacity()` slots once and indexes them directly — no name-keyed map,
//! and peak live tensors is bounded by the schedule's high-water mark
//! rather than the total tensor count.

/// Compile-time slot allocator with a free list.
#[derive(Debug, Default, Clone)]
pub struct SlotArena {
    free: Vec<u32>,
    next: u32,
}

impl SlotArena {
    pub fn new() -> SlotArena {
        SlotArena::default()
    }

    /// Allocate a slot, preferring a recycled one.
    pub fn alloc(&mut self) -> u32 {
        self.free.pop().unwrap_or_else(|| {
            let s = self.next;
            self.next += 1;
            s
        })
    }

    /// Return a slot to the free list (its value passed its last use).
    pub fn release(&mut self, slot: u32) {
        debug_assert!(slot < self.next, "released slot {slot} was never allocated");
        self.free.push(slot);
    }

    /// Total distinct slots ever allocated — the run-time slot-vector size
    /// and the schedule's high-water mark of live tensors.
    pub fn capacity(&self) -> usize {
        self.next as usize
    }

    /// Currently live (allocated, not released) slots.
    pub fn live(&self) -> usize {
        self.next as usize - self.free.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn reuses_released_slots() {
        let mut a = SlotArena::new();
        let s0 = a.alloc();
        let s1 = a.alloc();
        assert_ne!(s0, s1);
        a.release(s0);
        assert_eq!(a.alloc(), s0, "freed slot is recycled");
        assert_eq!(a.capacity(), 2);
        assert_eq!(a.live(), 2);
    }

    #[test]
    fn capacity_is_high_water_mark() {
        let mut a = SlotArena::new();
        // chain pattern: alloc, release, alloc, release ... stays at 1 slot
        let mut s = a.alloc();
        for _ in 0..10 {
            a.release(s);
            s = a.alloc();
        }
        assert_eq!(a.capacity(), 1);
    }
}

//! Arenas: the compile-time tensor-slot allocator ([`SlotArena`]) and the
//! run-time scratch-buffer pool ([`ScratchArena`]).
//!
//! The plan compiler runs a register-allocation style linear scan over the
//! frozen step schedule: every value (graph input, preloaded constant,
//! node output) is assigned a *physical slot*, and slots whose value has
//! passed its last use are recycled for later values. The [`SlotArena`] is
//! the compile-time allocator for that scan; at run time the plan
//! materializes `capacity()` slots once and indexes them directly — no
//! name-keyed map, and peak live tensors is bounded by the schedule's
//! high-water mark rather than the total tensor count.
//!
//! Both arenas are **dtype-aware** (PR 5): slots carry the container type
//! ([`DType`]) the residency pass proved for their values, and a slot is
//! only ever recycled for a value of the *same* dtype — an `i8` activation
//! slot never comes back as `f32` storage, so the plan's slot-dtype table
//! is a static fact about the schedule, not a per-run observation.
//!
//! The [`ScratchArena`] is the run-time counterpart: compiled kernels
//! draw their working buffers (im2col matrices, GEMM products, output
//! tensors) from it instead of `vec!`-allocating per call, and the
//! executor returns released intermediates' storage to it — so kernel
//! scratch reaches a zero-allocation steady state (small bookkeeping
//! vectors and buffers that leave as graph outputs still allocate).
//! Buffers are pooled per `(dtype, capacity)`: separate best-fit pools for
//! `f32`, `i32`, and `i8` storage, with [`ScratchArena::recycle`] routing
//! a released tensor's buffer to the pool matching its container.

use crate::tensor::{DType, Tensor, TensorData};

/// Compile-time slot allocator with per-dtype free lists. Each slot is
/// permanently typed at allocation; `release` returns it to its own
/// dtype's free list, so recycling can never alias containers.
#[derive(Debug, Default, Clone)]
pub struct SlotArena {
    /// Container type of every slot ever allocated (index = slot id).
    dtypes: Vec<DType>,
    /// Free lists keyed by dtype (parallel to the small DType universe).
    free_f32: Vec<u32>,
    free_i8: Vec<u32>,
    free_i32: Vec<u32>,
    free_i64: Vec<u32>,
}

impl SlotArena {
    pub fn new() -> SlotArena {
        SlotArena::default()
    }

    fn free_list(&mut self, dt: DType) -> &mut Vec<u32> {
        match dt {
            DType::F32 => &mut self.free_f32,
            DType::I8 => &mut self.free_i8,
            DType::I32 => &mut self.free_i32,
            DType::I64 => &mut self.free_i64,
        }
    }

    /// Allocate an `f32` slot (the pre-residency default).
    pub fn alloc(&mut self) -> u32 {
        self.alloc_dtype(DType::F32)
    }

    /// Allocate a slot of container type `dt`, preferring a recycled slot
    /// of the *same* dtype.
    pub fn alloc_dtype(&mut self, dt: DType) -> u32 {
        if let Some(s) = self.free_list(dt).pop() {
            return s;
        }
        let s = self.dtypes.len() as u32;
        self.dtypes.push(dt);
        s
    }

    /// Return a slot to its dtype's free list (its value passed its last
    /// use).
    pub fn release(&mut self, slot: u32) {
        debug_assert!((slot as usize) < self.dtypes.len(), "released slot {slot} was never allocated");
        let dt = self.dtypes[slot as usize];
        self.free_list(dt).push(slot);
    }

    /// Total distinct slots ever allocated — the run-time slot-vector size
    /// and the schedule's high-water mark of live tensors.
    pub fn capacity(&self) -> usize {
        self.dtypes.len()
    }

    /// Currently live (allocated, not released) slots.
    pub fn live(&self) -> usize {
        self.dtypes.len()
            - self.free_f32.len()
            - self.free_i8.len()
            - self.free_i32.len()
            - self.free_i64.len()
    }

    /// Container type per slot (index = slot id).
    pub fn dtypes(&self) -> &[DType] {
        &self.dtypes
    }
}

/// Cap on pooled buffers per dtype: enough for every live scratch/output
/// buffer of a deep model's widest region without hoarding unbounded
/// memory.
const SCRATCH_POOL_CAP: usize = 16;

/// One best-fit buffer pool for a single element type. `(dtype, capacity)`
/// keying falls out of the structure: each element type has its own pool,
/// and within a pool `pick` selects by capacity.
#[derive(Debug)]
struct Pool<T> {
    bufs: Vec<Vec<T>>,
    /// Buffers handed out as fresh heap allocations (pool was empty).
    fresh: u64,
    /// Buffers handed out from the pool (alloc avoided).
    reused: u64,
    /// Buffers returned to the pool (recycle path taken).
    recycled: u64,
}

impl<T> Default for Pool<T> {
    fn default() -> Pool<T> {
        Pool { bufs: Vec::new(), fresh: 0, reused: 0, recycled: 0 }
    }
}

impl<T: Copy + Default> Pool<T> {
    /// A zero-filled buffer of exactly `len` elements.
    fn take(&mut self, len: usize) -> Vec<T> {
        let mut buf = self.pick(len);
        buf.clear();
        buf.resize(len, T::default());
        buf
    }

    /// A buffer of exactly `len` elements with **unspecified** contents.
    fn take_uninit(&mut self, len: usize) -> Vec<T> {
        let mut buf = self.pick(len);
        // no clear(): an equal-length reuse is a no-op, a shorter one
        // truncates, and only a longer one zero-fills the gap
        buf.resize(len, T::default());
        buf
    }

    /// Best-fit pooled buffer for `len` (or a fresh allocation).
    fn pick(&mut self, len: usize) -> Vec<T> {
        let mut best: Option<usize> = None;
        for (i, b) in self.bufs.iter().enumerate() {
            let cap = b.capacity();
            best = match best {
                None => Some(i),
                Some(j) => {
                    let bj = self.bufs[j].capacity();
                    let better = if bj >= len { cap >= len && cap < bj } else { cap > bj };
                    Some(if better { i } else { j })
                }
            };
        }
        match best {
            Some(i) => {
                self.reused += 1;
                self.bufs.swap_remove(i)
            }
            None => {
                self.fresh += 1;
                Vec::with_capacity(len)
            }
        }
    }

    /// Return a buffer's storage. When the pool is full the smallest
    /// resident buffer is evicted (largest allocations are worth keeping).
    fn give(&mut self, buf: Vec<T>) {
        if buf.capacity() == 0 {
            return;
        }
        self.recycled += 1;
        if self.bufs.len() < SCRATCH_POOL_CAP {
            self.bufs.push(buf);
            return;
        }
        if let Some((i, _)) = self.bufs.iter().enumerate().min_by_key(|(_, b)| b.capacity()) {
            if self.bufs[i].capacity() < buf.capacity() {
                self.bufs[i] = buf;
            }
        }
    }

    fn len(&self) -> usize {
        self.bufs.len()
    }
}

/// Cumulative arena traffic counters, summed across the per-dtype pools.
/// Monotonic over an arena's lifetime; the executor's step profiler
/// subtracts snapshots to attribute alloc-vs-recycle traffic per step.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct ScratchCounters {
    /// Buffers handed out as fresh heap allocations (pool miss).
    pub fresh_allocs: u64,
    /// Buffers handed out from a pool (heap allocation avoided).
    pub pool_reuses: u64,
    /// Buffers returned for later reuse (`give*`/`recycle`).
    pub recycled: u64,
}

impl std::ops::Sub for ScratchCounters {
    type Output = ScratchCounters;
    fn sub(self, rhs: ScratchCounters) -> ScratchCounters {
        ScratchCounters {
            fresh_allocs: self.fresh_allocs.saturating_sub(rhs.fresh_allocs),
            pool_reuses: self.pool_reuses.saturating_sub(rhs.pool_reuses),
            recycled: self.recycled.saturating_sub(rhs.recycled),
        }
    }
}

/// Run-time buffer pool — the scratch side of the kernel invocation
/// contract ([`super::CompiledKernel::invoke`] takes `&mut ScratchArena`).
///
/// `take*(len)` hands out a buffer of exactly `len` elements, reusing the
/// best-fitting pooled allocation of the *same element type*; `give*`
/// returns storage for later reuse. The executor keeps one arena per run
/// (engines keep one across requests), so conv im2col/product buffers and
/// recycled intermediate outputs reach a steady state with zero heap
/// traffic. Pools are strictly segregated by dtype — an `i8` buffer can
/// never be handed back as `f32` scratch.
#[derive(Debug, Default)]
pub struct ScratchArena {
    pool_f32: Pool<f32>,
    /// Quantized tier's `i32` working buffers (integer im2col,
    /// accumulators, resident `i32` activations).
    pool_i32: Pool<i32>,
    /// Resident `i8` activation buffers (and `i8` im2col panels).
    pool_i8: Pool<i8>,
}

impl ScratchArena {
    pub fn new() -> ScratchArena {
        ScratchArena::default()
    }

    /// A zero-filled `f32` buffer of exactly `len` elements. Prefers the
    /// pooled buffer whose capacity fits `len` most tightly (falls back to
    /// the largest, which then grows in place).
    pub fn take(&mut self, len: usize) -> Vec<f32> {
        self.pool_f32.take(len)
    }

    /// An `f32` buffer of exactly `len` elements whose contents are
    /// **unspecified** (stale data from a previous use may remain). For
    /// outputs that every-element-overwrite before reading — skips the
    /// full zeroing memset that [`ScratchArena::take`] pays.
    pub fn take_uninit(&mut self, len: usize) -> Vec<f32> {
        self.pool_f32.take_uninit(len)
    }

    /// Return an `f32` buffer's storage to the pool.
    pub fn give(&mut self, buf: Vec<f32>) {
        self.pool_f32.give(buf);
    }

    /// `f32` buffers currently pooled (diagnostics).
    pub fn pooled(&self) -> usize {
        self.pool_f32.len()
    }

    /// A zero-filled `i32` buffer of exactly `len` elements (quantized
    /// kernel tier). Same best-fit policy as [`ScratchArena::take`].
    pub fn take_i32(&mut self, len: usize) -> Vec<i32> {
        self.pool_i32.take(len)
    }

    /// An `i32` buffer of exactly `len` elements with **unspecified**
    /// contents (counterpart of [`ScratchArena::take_uninit`]).
    pub fn take_i32_uninit(&mut self, len: usize) -> Vec<i32> {
        self.pool_i32.take_uninit(len)
    }

    /// Return an `i32` buffer's storage to the pool.
    pub fn give_i32(&mut self, buf: Vec<i32>) {
        self.pool_i32.give(buf);
    }

    /// `i32` buffers currently pooled (diagnostics).
    pub fn pooled_i32(&self) -> usize {
        self.pool_i32.len()
    }

    /// A zero-filled `i8` buffer of exactly `len` elements (resident
    /// activations / `i8` im2col panels).
    pub fn take_i8(&mut self, len: usize) -> Vec<i8> {
        self.pool_i8.take(len)
    }

    /// An `i8` buffer of exactly `len` elements with **unspecified**
    /// contents.
    pub fn take_i8_uninit(&mut self, len: usize) -> Vec<i8> {
        self.pool_i8.take_uninit(len)
    }

    /// Return an `i8` buffer's storage to the pool.
    pub fn give_i8(&mut self, buf: Vec<i8>) {
        self.pool_i8.give(buf);
    }

    /// `i8` buffers currently pooled (diagnostics).
    pub fn pooled_i8(&self) -> usize {
        self.pool_i8.len()
    }

    /// Cumulative alloc/reuse/recycle traffic across all three pools.
    pub fn counters(&self) -> ScratchCounters {
        ScratchCounters {
            fresh_allocs: self.pool_f32.fresh + self.pool_i32.fresh + self.pool_i8.fresh,
            pool_reuses: self.pool_f32.reused + self.pool_i32.reused + self.pool_i8.reused,
            recycled: self.pool_f32.recycled + self.pool_i32.recycled + self.pool_i8.recycled,
        }
    }

    /// Route a released tensor's storage to the pool matching its
    /// container. The executor calls this for every dead intermediate;
    /// i64 (shape) tensors are tiny and simply dropped.
    pub fn recycle(&mut self, t: Tensor) {
        match t.into_data() {
            TensorData::F32(v) => self.give(v),
            TensorData::I32(v) => self.give_i32(v),
            TensorData::I8(v) => self.give_i8(v),
            TensorData::I64(_) => {}
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn reuses_released_slots() {
        let mut a = SlotArena::new();
        let s0 = a.alloc();
        let s1 = a.alloc();
        assert_ne!(s0, s1);
        a.release(s0);
        assert_eq!(a.alloc(), s0, "freed slot is recycled");
        assert_eq!(a.capacity(), 2);
        assert_eq!(a.live(), 2);
    }

    #[test]
    fn capacity_is_high_water_mark() {
        let mut a = SlotArena::new();
        // chain pattern: alloc, release, alloc, release ... stays at 1 slot
        let mut s = a.alloc();
        for _ in 0..10 {
            a.release(s);
            s = a.alloc();
        }
        assert_eq!(a.capacity(), 1);
    }

    #[test]
    fn slots_recycle_within_their_dtype_only() {
        let mut a = SlotArena::new();
        let sf = a.alloc_dtype(DType::F32);
        let s8 = a.alloc_dtype(DType::I8);
        a.release(sf);
        a.release(s8);
        // an i8 request must get the i8 slot back, never the f32 one
        assert_eq!(a.alloc_dtype(DType::I8), s8);
        assert_eq!(a.alloc_dtype(DType::F32), sf);
        // a fresh dtype with an empty free list allocates a new slot
        let s32 = a.alloc_dtype(DType::I32);
        assert_eq!(s32 as usize, 2);
        assert_eq!(a.dtypes(), &[DType::F32, DType::I8, DType::I32]);
        assert_eq!(a.capacity(), 3);
    }

    #[test]
    fn scratch_zero_fills_reused_buffers() {
        let mut s = ScratchArena::new();
        let mut b = s.take(4);
        b.copy_from_slice(&[1.0, 2.0, 3.0, 4.0]);
        s.give(b);
        assert_eq!(s.pooled(), 1);
        let b2 = s.take(3);
        assert_eq!(b2, vec![0.0; 3], "reused buffer must come back zeroed");
        let b3 = s.take(8); // pool empty again; fresh allocation
        assert_eq!(b3.len(), 8);
    }

    #[test]
    fn scratch_best_fit_prefers_tightest_buffer() {
        let mut s = ScratchArena::new();
        s.give(Vec::with_capacity(100));
        s.give(Vec::with_capacity(10));
        let b = s.take(8);
        assert!(b.capacity() < 100, "should pick the 10-cap buffer");
        assert_eq!(s.pooled(), 1);
    }

    #[test]
    fn scratch_take_uninit_sizes_without_guaranteeing_contents() {
        let mut s = ScratchArena::new();
        let mut b = s.take(4);
        b.copy_from_slice(&[1.0, 2.0, 3.0, 4.0]);
        s.give(b);
        // only the length is guaranteed; contents are unspecified
        assert_eq!(s.take_uninit(4).len(), 4);
        assert_eq!(s.take_uninit(7).len(), 7);
    }

    #[test]
    fn scratch_pool_is_bounded() {
        let mut s = ScratchArena::new();
        for i in 0..2 * SCRATCH_POOL_CAP {
            s.give(Vec::with_capacity(i + 1));
        }
        assert!(s.pooled() <= SCRATCH_POOL_CAP);
    }

    #[test]
    fn i32_pool_mirrors_f32_pool() {
        let mut s = ScratchArena::new();
        let mut b = s.take_i32(4);
        b.copy_from_slice(&[1, 2, 3, 4]);
        s.give_i32(b);
        assert_eq!(s.pooled_i32(), 1);
        assert_eq!(s.pooled(), 0, "i32 pool is separate from the f32 pool");
        let b2 = s.take_i32(3);
        assert_eq!(b2, vec![0; 3], "reused i32 buffer must come back zeroed");
        assert_eq!(s.take_i32_uninit(7).len(), 7);
        for i in 0..2 * SCRATCH_POOL_CAP {
            s.give_i32(Vec::with_capacity(i + 1));
        }
        assert!(s.pooled_i32() <= SCRATCH_POOL_CAP);
    }

    #[test]
    fn counters_track_fresh_vs_reused_vs_recycled() {
        let mut s = ScratchArena::new();
        assert_eq!(s.counters(), ScratchCounters::default());
        let b = s.take(4); // pool empty: fresh allocation
        assert_eq!(s.counters().fresh_allocs, 1);
        assert_eq!(s.counters().pool_reuses, 0);
        s.give(b);
        assert_eq!(s.counters().recycled, 1);
        let _ = s.take(4); // pool hit
        let c = s.counters();
        assert_eq!((c.fresh_allocs, c.pool_reuses, c.recycled), (1, 1, 1));
        // per-dtype pools all feed the same aggregate
        let bi = s.take_i32(2);
        s.recycle(crate::tensor::Tensor::new_i8(vec![2], vec![1, 2]));
        s.give_i32(bi);
        let c2 = s.counters() - c;
        assert_eq!((c2.fresh_allocs, c2.pool_reuses, c2.recycled), (1, 0, 2));
        // zero-capacity give is not a recycle
        s.give(Vec::new());
        assert_eq!(s.counters().recycled, c.recycled + 2);
    }

    #[test]
    fn cross_dtype_recycling_never_aliases_pools() {
        let mut s = ScratchArena::new();
        // recycle() routes by the tensor's actual container
        s.recycle(Tensor::new_i8(vec![4], vec![1, 2, 3, 4]));
        s.recycle(Tensor::new_i32(vec![2], vec![5, 6]));
        s.recycle(Tensor::new(vec![3], vec![1.0, 2.0, 3.0]));
        s.recycle(Tensor::new_i64(vec![1], vec![9])); // dropped
        assert_eq!(s.pooled_i8(), 1);
        assert_eq!(s.pooled_i32(), 1);
        assert_eq!(s.pooled(), 1);
        // an i8 buffer handed back is never visible to the f32 pool: the
        // only pooled f32 buffer has capacity >= 3, while a (bigger) take
        // from the i8 pool must not shrink the f32 side
        let f = s.take(3);
        assert_eq!(f.len(), 3);
        assert_eq!(s.pooled(), 0);
        let b8 = s.take_i8(4);
        assert_eq!(b8, vec![0i8; 4], "reused i8 buffer must come back zeroed");
        assert_eq!(s.pooled_i8(), 0);
        assert_eq!(s.take_i8_uninit(6).len(), 6);
        for i in 0..2 * SCRATCH_POOL_CAP {
            s.give_i8(Vec::with_capacity(i + 1));
        }
        assert!(s.pooled_i8() <= SCRATCH_POOL_CAP);
    }
}

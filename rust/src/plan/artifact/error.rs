//! Typed failure modes for `.qpln` artifact loading.
//!
//! Every way a file can be unusable maps to a distinct variant — a
//! corrupt or mismatched artifact is always a clean typed error, never
//! UB and never a panic. The variants mirror the loader's validation
//! order: I/O, size, magic, endianness, version, section geometry,
//! checksums, ISA compatibility, and finally logical decode.

use std::fmt;

/// Why an artifact could not be loaded.
#[derive(Debug)]
pub enum ArtifactError {
    /// Underlying filesystem error (open/read/stat).
    Io(std::io::Error),
    /// File shorter than a declared extent (header, table, or section).
    Truncated { needed: u64, have: u64 },
    /// The first 8 bytes are not the `QPLNART\0` magic.
    BadMagic,
    /// The endian tag read back byte-swapped: the artifact was produced
    /// on a machine with different byte order (sections are stored
    /// native-order for zero-copy loading, so it cannot be used here).
    EndianMismatch,
    /// Format version not supported by this build.
    VersionSkew { found: u32, supported: u32 },
    /// A section payload does not start on the 64-byte alignment the
    /// zero-copy weight contract requires.
    MisalignedSection { id: u32, offset: u64 },
    /// A section's CRC32 does not match its payload (bit rot, torn
    /// write, or deliberate tampering).
    ChecksumMismatch { id: u32 },
    /// The artifact's interleaved weight tiles were packed for a
    /// different SIMD ISA than the one active in this process.
    IsaMismatch { packed: String, running: String },
    /// Sections verified but their contents do not decode to a plan
    /// (unknown section, bad JSON, out-of-range reference, …).
    Malformed(String),
}

impl fmt::Display for ArtifactError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            ArtifactError::Io(e) => write!(f, "artifact I/O error: {e}"),
            ArtifactError::Truncated { needed, have } => {
                write!(f, "artifact truncated: needs {needed} bytes, file has {have}")
            }
            ArtifactError::BadMagic => write!(f, "not a compiled-plan artifact (bad magic)"),
            ArtifactError::EndianMismatch => {
                write!(f, "artifact was written on a machine with different endianness")
            }
            ArtifactError::VersionSkew { found, supported } => {
                write!(f, "artifact format version {found} unsupported (this build reads v{supported})")
            }
            ArtifactError::MisalignedSection { id, offset } => {
                write!(f, "section {id} starts at offset {offset}, not 64-byte aligned")
            }
            ArtifactError::ChecksumMismatch { id } => {
                write!(f, "section {id} failed its CRC32 check (corrupt artifact)")
            }
            ArtifactError::IsaMismatch { packed, running } => {
                write!(
                    f,
                    "artifact weight tiles were packed for ISA '{packed}' but this process \
                     runs '{running}' — recompile the artifact on this machine"
                )
            }
            ArtifactError::Malformed(msg) => write!(f, "malformed artifact: {msg}"),
        }
    }
}

impl std::error::Error for ArtifactError {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        match self {
            ArtifactError::Io(e) => Some(e),
            _ => None,
        }
    }
}

impl From<std::io::Error> for ArtifactError {
    fn from(e: std::io::Error) -> ArtifactError {
        ArtifactError::Io(e)
    }
}

//! On-disk layout constants and primitives for `.qpln` compiled-plan
//! artifacts: the fixed 64-byte header, the section table, and the CRC32
//! used to checksum every section payload.
//!
//! All multi-byte fields are written in the producer's **native** byte
//! order; the header's endian tag (`0x01020304`) lets a consumer on a
//! foreign-endian machine detect the mismatch before interpreting any
//! other field (see [`crate::plan::artifact`] module docs for the
//! rationale: weight sections are reinterpret-cast in place, so a
//! byte-order conversion pass would defeat zero-copy loading).

use super::error::ArtifactError;

/// File magic: identifies a QONNX compiled-plan artifact.
pub const MAGIC: [u8; 8] = *b"QPLNART\0";
/// Current format version. Readers accept exactly this version.
pub const VERSION: u32 = 1;
/// Endianness sentinel: reads back as `0x01020304` only on a machine
/// with the producer's byte order.
pub const ENDIAN_TAG: u32 = 0x0102_0304;
/// Fixed header size in bytes (the section table follows immediately).
pub const HEADER_LEN: usize = 64;
/// Every section payload starts on this alignment — the contract that
/// lets weight panels be borrowed straight out of the loaded buffer
/// (see [`crate::tensor::WEIGHT_ALIGN`]).
pub const SECTION_ALIGN: usize = 64;
/// Size of one section-table entry in bytes.
pub const ENTRY_LEN: usize = 32;
/// Max ISA-name length storable in the header (NUL padded).
pub const ISA_NAME_LEN: usize = 12;

/// Section ids. Unknown ids are rejected (no forward-compat skipping in
/// v1: a plan is only executable when every part is understood).
pub const SEC_META: u32 = 1;
/// Streamlined/compiled source graph (`qonnx.json/v1`) for `verify`.
pub const SEC_GRAPH: u32 = 2;
/// Raw `f32` blob: packed float panels, bias vectors, float tensors.
pub const SEC_F32: u32 = 3;
/// Raw `i8` blob: quantized weight panels and interleaved SIMD tiles.
pub const SEC_I8: u32 = 4;
/// Raw `i32` blob: integer bias vectors, threshold rows, i32 tensors.
pub const SEC_I32: u32 = 5;
/// Raw `i64` blob: shape/index tensors.
pub const SEC_I64: u32 = 6;

/// One parsed section-table entry.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct SectionEntry {
    pub id: u32,
    pub offset: u64,
    pub len: u64,
    pub crc: u32,
}

const fn crc_table() -> [u32; 256] {
    let mut table = [0u32; 256];
    let mut i = 0;
    while i < 256 {
        let mut c = i as u32;
        let mut bit = 0;
        while bit < 8 {
            c = if c & 1 != 0 { 0xEDB8_8320 ^ (c >> 1) } else { c >> 1 };
            bit += 1;
        }
        table[i] = c;
        i += 1;
    }
    table
}

static CRC_TABLE: [u32; 256] = crc_table();

/// CRC-32 (IEEE 802.3 polynomial, the zlib/`crc32` convention).
pub fn crc32(bytes: &[u8]) -> u32 {
    let mut c = 0xFFFF_FFFFu32;
    for &b in bytes {
        c = CRC_TABLE[((c ^ u32::from(b)) & 0xFF) as usize] ^ (c >> 8);
    }
    c ^ 0xFFFF_FFFF
}

/// Bytes of padding needed to advance `off` to [`SECTION_ALIGN`].
pub fn pad_to_align(off: usize) -> usize {
    (SECTION_ALIGN - off % SECTION_ALIGN) % SECTION_ALIGN
}

/// Encode the fixed header. `isa` is the pack-time SIMD ISA name
/// ([`crate::tensor::simd::Isa::name`]); loading on a machine whose
/// active ISA differs is refused, because interleaved `i8` weight tiles
/// are laid out ISA-specifically.
pub fn encode_header(section_count: u32, isa: &str) -> Vec<u8> {
    assert!(isa.len() <= ISA_NAME_LEN, "ISA name '{isa}' exceeds header field");
    let mut h = vec![0u8; HEADER_LEN];
    h[0..8].copy_from_slice(&MAGIC);
    h[8..12].copy_from_slice(&VERSION.to_ne_bytes());
    h[12..16].copy_from_slice(&ENDIAN_TAG.to_ne_bytes());
    h[16..20].copy_from_slice(&section_count.to_ne_bytes());
    h[20..20 + isa.len()].copy_from_slice(isa.as_bytes());
    h
}

fn u32_at(b: &[u8], off: usize) -> u32 {
    u32::from_ne_bytes(b[off..off + 4].try_into().unwrap())
}

fn u64_at(b: &[u8], off: usize) -> u64 {
    u64::from_ne_bytes(b[off..off + 8].try_into().unwrap())
}

/// Parsed and validated header.
#[derive(Debug, Clone)]
pub struct Header {
    pub section_count: u32,
    pub isa: String,
}

/// Decode and validate the fixed header: magic, then endianness, then
/// version — strictly in that order, so a foreign-endian or truncated
/// file is reported as such rather than as garbage field values.
pub fn decode_header(file: &[u8]) -> Result<Header, ArtifactError> {
    if file.len() < HEADER_LEN {
        return Err(ArtifactError::Truncated { needed: HEADER_LEN as u64, have: file.len() as u64 });
    }
    if file[0..8] != MAGIC {
        return Err(ArtifactError::BadMagic);
    }
    if u32_at(file, 12) != ENDIAN_TAG {
        return Err(ArtifactError::EndianMismatch);
    }
    let version = u32_at(file, 8);
    if version != VERSION {
        return Err(ArtifactError::VersionSkew { found: version, supported: VERSION });
    }
    let section_count = u32_at(file, 16);
    // arbitrary sanity bound: v1 writes at most 6 sections
    if section_count == 0 || section_count > 64 {
        return Err(ArtifactError::Malformed(format!("implausible section count {section_count}")));
    }
    let isa_raw = &file[20..20 + ISA_NAME_LEN];
    let end = isa_raw.iter().position(|&b| b == 0).unwrap_or(ISA_NAME_LEN);
    let isa = std::str::from_utf8(&isa_raw[..end])
        .map_err(|_| ArtifactError::Malformed("non-UTF-8 ISA name in header".into()))?
        .to_string();
    Ok(Header { section_count, isa })
}

/// Encode one section-table entry.
pub fn encode_entry(e: &SectionEntry) -> [u8; ENTRY_LEN] {
    let mut b = [0u8; ENTRY_LEN];
    b[0..4].copy_from_slice(&e.id.to_ne_bytes());
    b[8..16].copy_from_slice(&e.offset.to_ne_bytes());
    b[16..24].copy_from_slice(&e.len.to_ne_bytes());
    b[24..28].copy_from_slice(&e.crc.to_ne_bytes());
    b
}

/// Decode the section table and validate every entry against the file:
/// 64-byte payload alignment, in-bounds extent, and payload checksum.
pub fn decode_table(file: &[u8], h: &Header) -> Result<Vec<SectionEntry>, ArtifactError> {
    let count = h.section_count as usize;
    let table_end = HEADER_LEN + count * ENTRY_LEN;
    if file.len() < table_end {
        return Err(ArtifactError::Truncated { needed: table_end as u64, have: file.len() as u64 });
    }
    let mut entries = Vec::with_capacity(count);
    for i in 0..count {
        let off = HEADER_LEN + i * ENTRY_LEN;
        let e = SectionEntry {
            id: u32_at(file, off),
            offset: u64_at(file, off + 8),
            len: u64_at(file, off + 16),
            crc: u32_at(file, off + 24),
        };
        if e.offset % SECTION_ALIGN as u64 != 0 {
            return Err(ArtifactError::MisalignedSection { id: e.id, offset: e.offset });
        }
        let end = e
            .offset
            .checked_add(e.len)
            .ok_or_else(|| ArtifactError::Malformed(format!("section {} extent overflows", e.id)))?;
        if end > file.len() as u64 {
            return Err(ArtifactError::Truncated { needed: end, have: file.len() as u64 });
        }
        if entries.iter().any(|p: &SectionEntry| p.id == e.id) {
            return Err(ArtifactError::Malformed(format!("duplicate section id {}", e.id)));
        }
        let payload = &file[e.offset as usize..end as usize];
        if crc32(payload) != e.crc {
            return Err(ArtifactError::ChecksumMismatch { id: e.id });
        }
        entries.push(e);
    }
    Ok(entries)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn crc32_matches_known_vectors() {
        // standard check values for the IEEE polynomial
        assert_eq!(crc32(b""), 0);
        assert_eq!(crc32(b"123456789"), 0xCBF4_3926);
        assert_eq!(crc32(b"The quick brown fox jumps over the lazy dog"), 0x414F_A339);
    }

    #[test]
    fn header_roundtrip() {
        let enc = encode_header(3, "avx2");
        assert_eq!(enc.len(), HEADER_LEN);
        let h = decode_header(&enc).unwrap();
        assert_eq!(h.section_count, 3);
        assert_eq!(h.isa, "avx2");
    }

    #[test]
    fn header_rejects_bad_magic_endian_version_in_order() {
        let good = encode_header(1, "scalar");

        let mut bad = good.clone();
        bad[0] = b'X';
        assert!(matches!(decode_header(&bad), Err(ArtifactError::BadMagic)));

        // endianness is checked before version, so a byte-swapped file
        // reports EndianMismatch even though its version field is garbage
        let mut swapped = good.clone();
        swapped[8..12].reverse();
        swapped[12..16].reverse();
        assert!(matches!(decode_header(&swapped), Err(ArtifactError::EndianMismatch)));

        let mut skew = good.clone();
        skew[8..12].copy_from_slice(&99u32.to_ne_bytes());
        assert!(matches!(
            decode_header(&skew),
            Err(ArtifactError::VersionSkew { found: 99, supported: VERSION })
        ));

        assert!(matches!(
            decode_header(&good[..32]),
            Err(ArtifactError::Truncated { .. })
        ));
    }

    #[test]
    fn entry_roundtrip_and_table_validation() {
        let payload = b"0123456789abcdef";
        let mut file = encode_header(1, "scalar");
        let entry = SectionEntry {
            id: SEC_META,
            offset: (HEADER_LEN + ENTRY_LEN + pad_to_align(HEADER_LEN + ENTRY_LEN)) as u64,
            len: payload.len() as u64,
            crc: crc32(payload),
        };
        file.extend_from_slice(&encode_entry(&entry));
        file.resize(entry.offset as usize, 0);
        file.extend_from_slice(payload);

        let h = decode_header(&file).unwrap();
        let table = decode_table(&file, &h).unwrap();
        assert_eq!(table, vec![entry]);

        // flipped payload byte -> checksum mismatch for that section
        let mut flipped = file.clone();
        let idx = entry.offset as usize + 3;
        flipped[idx] ^= 0x40;
        assert!(matches!(
            decode_table(&flipped, &h),
            Err(ArtifactError::ChecksumMismatch { id: SEC_META })
        ));

        // misaligned offset is rejected before any payload access
        let mut misaligned = file.clone();
        misaligned[HEADER_LEN + 8..HEADER_LEN + 16]
            .copy_from_slice(&(entry.offset + 1).to_ne_bytes());
        assert!(matches!(
            decode_table(&misaligned, &h),
            Err(ArtifactError::MisalignedSection { id: SEC_META, .. })
        ));

        // truncated payload -> Truncated with the needed extent
        let cut = &file[..file.len() - 4];
        assert!(matches!(decode_table(cut, &h), Err(ArtifactError::Truncated { .. })));
    }
}

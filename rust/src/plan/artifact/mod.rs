//! Compiled-plan artifacts: a sectioned binary format (`.qpln`) that
//! persists a fully compiled [`ExecutionPlan`] — schedule, kernel
//! descriptors, fused-epilogue metadata, threshold rows, and the raw
//! prepacked weight panels — so serving cold-starts by **loading**
//! instead of re-compiling.
//!
//! # On-disk layout
//!
//! ```text
//! offset 0    +--------------------------------------------------+
//!             | header (64 B): magic "QPLNART\0", version,       |
//!             |   endian tag 0x01020304, section count,          |
//!             |   pack-time SIMD ISA name                        |
//! offset 64   +--------------------------------------------------+
//!             | section table: 32 B per entry                    |
//!             |   { id, offset, len, crc32 }                     |
//!             +---- 64-byte aligned ----------------------------+
//!             | 1 META   plan + engine JSON (blob refs inside)   |
//!             +---- 64-byte aligned ----------------------------+
//!             | 2 GRAPH  compiled source graph (qonnx.json/v1)   |
//!             +---- 64-byte aligned ----------------------------+
//!             | 3 F32    raw f32 blob (panels 64-B aligned)      |
//!             | 4 I8     raw i8 blob (panels + SIMD tiles)       |
//!             | 5 I32    raw i32 blob                            |
//!             | 6 I64    raw i64 blob                            |
//!             +--------------------------------------------------+
//! ```
//!
//! # Version / checksum contract
//!
//! * The reader accepts exactly [`format::VERSION`]; skew is a typed
//!   refusal ([`ArtifactError::VersionSkew`]), never a best-effort parse.
//! * Every section payload carries a CRC32; a single flipped bit
//!   anywhere is caught before any decode
//!   ([`ArtifactError::ChecksumMismatch`]).
//! * Multi-byte fields and blobs are native-endian; the header's endian
//!   tag turns a foreign-endian file into
//!   [`ArtifactError::EndianMismatch`] up front.
//!
//! # Zero-copy rules
//!
//! Loading reads the file once into a single 64-byte-aligned buffer and
//! reconstructs the plan with every `PackedB` / `PackedBi8` panel (and
//! interleaved SIMD tile block) **borrowed** from that buffer through
//! [`crate::tensor::WeightStore::Mapped`] — zero weight-panel re-packing
//! on the load path, verified by [`LoadedArtifact::zero_copy_report`]
//! (pointer provenance against the backing buffer). Two invariants make
//! the borrow sound and fast:
//!
//! 1. every blob entry referenced as a panel starts 64-byte aligned in
//!    the file (writer pads; reader re-checks before mapping), and
//! 2. interleaved `i8` tiles are ISA-specific, so the header records the
//!    pack-time ISA and loading under a different active ISA is refused
//!    ([`ArtifactError::IsaMismatch`]) rather than silently re-packed.
//!
//! Small data — bias vectors, threshold rows, preload tensors, the
//! embedded graph — is copied out of the buffer at load; only the weight
//! panels dominate cold-start cost and footprint. Folded constants that
//! no preload references are marked `cold` in META (groundwork for
//! spilling them out of the resident image).

mod error;
pub mod format;
mod read;
mod write;

pub use error::ArtifactError;
pub use read::{read_artifact, read_section};
pub use write::write_artifact;

use crate::ir::json::Json;
use crate::ir::ModelGraph;
use crate::plan::kernel::CompiledKernel;
use crate::plan::ExecutionPlan;
use crate::tensor::{AlignedBytes, PackedB, PackedBi8, PanelElem, WeightStore};
use anyhow::{bail, Context, Result};
use format::{
    crc32, encode_entry, encode_header, pad_to_align, SectionEntry, ENTRY_LEN, HEADER_LEN,
    SEC_META,
};
use std::path::Path;
use std::sync::Arc;

/// How an engine's flat request rows bind to the plan input — the
/// persisted form of the engine's edge adapter.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum AdapterMeta {
    /// `[n, in_dim]` graphs: the batch binds directly.
    Dense,
    /// NCHW graphs: `[n, in_dim]` rows re-viewed as `[n, c, h, w]`.
    Nchw { c: usize, h: usize, w: usize },
}

/// Serving metadata persisted alongside the plan so
/// [`crate::coordinator::PlannedEngine`] reconstructs without the graph.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct EngineMeta {
    pub model_name: String,
    pub input_name: String,
    pub output_name: String,
    pub in_dim: usize,
    pub out_dim: usize,
    pub adapter: AdapterMeta,
    pub streamlined: bool,
}

/// Where a loaded plan's weight panels actually live — the zero-copy
/// assertion surface (see module docs).
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct ZeroCopyReport {
    /// Panels whose storage is borrowed from the artifact buffer
    /// (pointer-provenance checked, not just flag-checked).
    pub mapped_panels: usize,
    /// Panels that own a heap copy — must be 0 after a load.
    pub owned_panels: usize,
    /// Total bytes served straight from the mapped buffer.
    pub mapped_bytes: usize,
}

/// A validated, decoded artifact: the reconstructed plan plus the
/// serving metadata and embedded source graph that rode along.
///
/// The plan's mapped weight panels hold `Arc` handles on the backing
/// buffer, so the mapping outlives this struct for as long as the plan
/// (or any clone of its kernels) does.
pub struct LoadedArtifact {
    pub plan: ExecutionPlan<'static>,
    pub engine: Option<EngineMeta>,
    /// The compiled source graph (`qonnx.json/v1` text) for
    /// `verify --artifact`.
    pub graph_json: String,
    pub(crate) buf: Arc<AlignedBytes>,
}

fn tally<T: PanelElem>(buf: &AlignedBytes, s: &WeightStore<T>, rep: &mut ZeroCopyReport) {
    let slice = s.as_slice();
    let mapped =
        s.is_mapped() && !slice.is_empty() && buf.contains_ptr(slice.as_ptr().cast::<u8>());
    if mapped {
        rep.mapped_panels += 1;
        rep.mapped_bytes += std::mem::size_of_val(slice);
    } else {
        rep.owned_panels += 1;
    }
}

fn tally_b(buf: &AlignedBytes, pb: &PackedB, rep: &mut ZeroCopyReport) {
    tally(buf, pb.store(), rep);
}

fn tally_bi8(buf: &AlignedBytes, pb: &PackedBi8, rep: &mut ZeroCopyReport) {
    tally(buf, pb.store(), rep);
    if let Some((_, _, tiles)) = pb.simd_parts() {
        tally(buf, tiles, rep);
    }
}

impl LoadedArtifact {
    /// Parse the embedded source graph.
    pub fn graph(&self) -> Result<ModelGraph> {
        crate::ir::json::model_from_json(&self.graph_json).context("embedded GRAPH section")
    }

    /// Audit every weight panel's storage by pointer provenance: a panel
    /// counts as mapped only if its data pointer lies inside the
    /// artifact buffer. `owned_panels == 0` is the "zero re-packing"
    /// guarantee the loader makes.
    pub fn zero_copy_report(&self) -> ZeroCopyReport {
        let mut rep = ZeroCopyReport::default();
        for step in &self.plan.steps {
            match &step.kernel {
                CompiledKernel::Conv(c) => {
                    for pb in c.weights() {
                        tally_b(&self.buf, pb, &mut rep);
                    }
                }
                CompiledKernel::Gemm(g) => tally_b(&self.buf, g.packed_b(), &mut rep),
                CompiledKernel::MatMul(m) => tally_b(&self.buf, m.packed_b(), &mut rep),
                CompiledKernel::QConv(c) => {
                    for pb in c.weights() {
                        tally_bi8(&self.buf, pb, &mut rep);
                    }
                }
                CompiledKernel::QGemm(g) => tally_bi8(&self.buf, g.packed_b(), &mut rep),
                CompiledKernel::QMatMul(m) => tally_bi8(&self.buf, m.packed_b(), &mut rep),
                CompiledKernel::Op(_)
                | CompiledKernel::Threshold(_)
                | CompiledKernel::Reshape(_) => {}
            }
        }
        rep
    }
}

/// Replace the payload of section `id` in an existing artifact,
/// recomputing the layout and checksums (so the file stays *structurally*
/// valid — this is the corruption/mutation test hook, not a public
/// editing API).
pub fn rewrite_section(path: &Path, id: u32, payload: &[u8]) -> Result<(), ArtifactError> {
    let bytes = std::fs::read(path)?;
    let header = format::decode_header(&bytes)?;
    let entries = format::decode_table(&bytes, &header)?;
    if !entries.iter().any(|e| e.id == id) {
        return Err(ArtifactError::Malformed(format!("missing section id {id}")));
    }
    let mut out = encode_header(entries.len() as u32, &header.isa);
    out.resize(HEADER_LEN + entries.len() * ENTRY_LEN, 0);
    let mut new_entries = Vec::with_capacity(entries.len());
    for e in &entries {
        let p: &[u8] = if e.id == id {
            payload
        } else {
            &bytes[e.offset as usize..(e.offset + e.len) as usize]
        };
        out.resize(out.len() + pad_to_align(out.len()), 0);
        let offset = out.len() as u64;
        out.extend_from_slice(p);
        new_entries.push(SectionEntry { id: e.id, offset, len: p.len() as u64, crc: crc32(p) });
    }
    for (i, e) in new_entries.iter().enumerate() {
        let at = HEADER_LEN + i * ENTRY_LEN;
        out[at..at + ENTRY_LEN].copy_from_slice(&encode_entry(e));
    }
    std::fs::write(path, &out)?;
    Ok(())
}

/// Corrupt the frozen schedule inside an artifact while keeping the file
/// structurally valid (checksums recomputed): swaps the first and last
/// schedule steps, which inverts at least one producer/consumer
/// dependency in any multi-step plan. The static verifier must trip on
/// the decoded plan — this is the `verify --artifact` mutation self-test.
pub fn mutate_schedule(path: &Path) -> Result<()> {
    let meta = read_section(path, SEC_META)?;
    let text = String::from_utf8(meta).context("META section is not UTF-8")?;
    let mut root = Json::parse(&text)?;
    let Json::Obj(root_map) = &mut root else { bail!("META root is not an object") };
    let Some(Json::Obj(plan)) = root_map.get_mut("plan") else { bail!("META lacks a plan object") };
    let Some(Json::Arr(steps)) = plan.get_mut("steps") else { bail!("plan lacks a steps array") };
    if steps.len() < 2 {
        bail!("plan has {} step(s); schedule mutation needs at least 2", steps.len());
    }
    let last = steps.len() - 1;
    steps.swap(0, last);
    rewrite_section(path, SEC_META, root.to_string().as_bytes())?;
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ir::GraphBuilder;
    use crate::plan::{RunConfig, ScratchArena, ShapeCheck};
    use crate::tensor::Tensor;
    use std::path::PathBuf;

    fn tmp(tag: &str) -> PathBuf {
        std::env::temp_dir().join(format!("qonnx_artifact_{}_{tag}.qpln", std::process::id()))
    }

    /// conv -> reshape -> matmul -> relu: exercises PackedConv,
    /// BatchReshape, PackedMatMul and a fused epilogue in one graph.
    fn tiny_graph() -> crate::ir::ModelGraph {
        let mut b = GraphBuilder::new("tiny");
        b.input("x", vec![1, 2, 4, 4]);
        b.initializer(
            "w",
            Tensor::new(vec![3, 2, 3, 3], (0..54).map(|v| (v % 7) as f32 * 0.25 - 0.75).collect()),
        );
        b.node(
            "Conv",
            &["x", "w"],
            &["c"],
            &[
                ("kernel_shape", crate::ir::AttrValue::Ints(vec![3, 3])),
                ("pads", crate::ir::AttrValue::Ints(vec![1, 1, 1, 1])),
            ],
        );
        b.initializer("target", Tensor::new_i64(vec![2], vec![1, 48]));
        b.node("Reshape", &["c", "target"], &["flat"], &[]);
        b.initializer(
            "fcw",
            Tensor::new(vec![48, 5], (0..240).map(|v| (v % 9) as f32 * 0.1 - 0.4).collect()),
        );
        b.node("MatMul", &["flat", "fcw"], &["mm"], &[]);
        b.node("Relu", &["mm"], &["y"], &[]);
        b.output("y", vec![1, 5]);
        b.finish().unwrap()
    }

    fn run_plan(plan: &ExecutionPlan<'_>, x: &Tensor) -> Tensor {
        let cfg = RunConfig { shape_check: ShapeCheck::FreeBatch, record_intermediates: false };
        let mut scratch = ScratchArena::new();
        let mut r = plan.run_cfg_scratch(|n| (n == "x").then_some(x), &cfg, &mut scratch).unwrap();
        r.outputs.remove("y").unwrap()
    }

    #[test]
    fn roundtrip_is_byte_identical_and_zero_copy() {
        let g = tiny_graph();
        let plan = ExecutionPlan::compile(&g).unwrap();
        let path = tmp("roundtrip");
        write_artifact(&plan, &g, None, &path).unwrap();

        let loaded = read_artifact(&path).unwrap();
        assert_eq!(loaded.plan.summary(), plan.summary(), "schedules must match");
        let rep = loaded.zero_copy_report();
        assert_eq!(rep.owned_panels, 0, "loading must not re-pack any panel: {rep:?}");
        assert!(rep.mapped_panels >= 2, "conv + matmul panels expected: {rep:?}");
        assert!(rep.mapped_bytes > 0);

        for n in [1usize, 3] {
            let x = Tensor::new(
                vec![n, 2, 4, 4],
                (0..n * 32).map(|i| (i % 13) as f32 / 13.0 - 0.4).collect(),
            );
            assert_eq!(run_plan(&loaded.plan, &x), run_plan(&plan, &x), "batch {n}");
        }

        // embedded graph parses back to the compiled model
        let g2 = loaded.graph().unwrap();
        assert_eq!(g2.name, g.name);
        assert_eq!(g2.nodes.len(), g.nodes.len());
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn corruption_is_typed_never_ub() {
        let g = tiny_graph();
        let plan = ExecutionPlan::compile(&g).unwrap();
        let path = tmp("corrupt");
        write_artifact(&plan, &g, None, &path).unwrap();
        let good = std::fs::read(&path).unwrap();

        let reload = |bytes: &[u8]| -> Result<LoadedArtifact, ArtifactError> {
            std::fs::write(&path, bytes).unwrap();
            read_artifact(&path)
        };

        // truncation at several depths
        for cut in [4usize, format::HEADER_LEN - 1, good.len() / 2, good.len() - 3] {
            assert!(
                matches!(reload(&good[..cut]), Err(ArtifactError::Truncated { .. })),
                "cut at {cut}"
            );
        }
        // wrong magic
        let mut bad = good.clone();
        bad[1] ^= 0xFF;
        assert!(matches!(reload(&bad), Err(ArtifactError::BadMagic)));
        // version skew
        let mut bad = good.clone();
        bad[8..12].copy_from_slice(&7u32.to_ne_bytes());
        assert!(matches!(reload(&bad), Err(ArtifactError::VersionSkew { found: 7, .. })));
        // flipped payload byte -> checksum
        let mut bad = good.clone();
        let last = bad.len() - 1;
        bad[last] ^= 0x01;
        assert!(matches!(reload(&bad), Err(ArtifactError::ChecksumMismatch { .. })));
        // misaligned section offset (fix up no checksums: alignment is
        // checked before the payload is ever touched)
        let mut bad = good.clone();
        bad[format::HEADER_LEN + 8..format::HEADER_LEN + 16]
            .copy_from_slice(&65u64.to_ne_bytes());
        assert!(matches!(reload(&bad), Err(ArtifactError::MisalignedSection { .. })));
        // ISA skew
        let mut bad = good.clone();
        for (i, b) in b"other\0\0\0\0\0\0\0".iter().enumerate() {
            bad[20 + i] = *b;
        }
        assert!(matches!(reload(&bad), Err(ArtifactError::IsaMismatch { .. })));

        // the pristine bytes still load after all that
        assert!(reload(&good).is_ok());
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn schedule_mutation_keeps_file_valid_but_breaks_plan_verification() {
        let g = tiny_graph();
        let plan = ExecutionPlan::compile(&g).unwrap();
        let path = tmp("mutate");
        write_artifact(&plan, &g, None, &path).unwrap();

        mutate_schedule(&path).unwrap();
        // checksums were recomputed: the file loads fine...
        let loaded = read_artifact(&path).unwrap();
        // ...but the static verifier rejects the corrupted schedule
        let report = crate::verify::verify_plan(&loaded.plan, &loaded.graph().unwrap());
        assert!(report.has_errors(), "verifier must trip on a swapped schedule:\n{}", report.render());
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn rewrite_section_roundtrips_payloads() {
        let g = tiny_graph();
        let plan = ExecutionPlan::compile(&g).unwrap();
        let path = tmp("rewrite");
        write_artifact(&plan, &g, None, &path).unwrap();

        let meta = read_section(&path, SEC_META).unwrap();
        rewrite_section(&path, SEC_META, &meta).unwrap();
        assert_eq!(read_section(&path, SEC_META).unwrap(), meta);
        // unchanged payload -> artifact still loads and runs
        let loaded = read_artifact(&path).unwrap();
        let x = Tensor::new(vec![1, 2, 4, 4], (0..32).map(|i| i as f32 * 0.1).collect());
        assert_eq!(run_plan(&loaded.plan, &x), run_plan(&plan, &x));

        assert!(matches!(
            rewrite_section(&path, 99, b"zz"),
            Err(ArtifactError::Malformed(_))
        ));
        std::fs::remove_file(&path).ok();
    }
}

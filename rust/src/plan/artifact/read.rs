//! Loading a `.qpln` artifact back into a runnable [`ExecutionPlan`].
//!
//! The whole file is read once into a single 64-byte-aligned buffer
//! ([`AlignedBytes`] — the crate's "mapping"), validated outside-in
//! (magic → endianness → version → section geometry → checksums → ISA),
//! and only then decoded. Weight panels (`PackedB`/`PackedBi8` and
//! interleaved SIMD tiles) are **borrowed** from the buffer via
//! [`WeightStore::mapped`] — no re-pack, no re-streamline, no
//! re-verification on this path. Small data (bias vectors, threshold
//! rows, preload tensors) is copied out; only the panels matter for
//! cold-start cost, and mapping them keeps the hot-path kernels
//! byte-identical to the compiled-in-process plan.

use super::error::ArtifactError;
use super::format::{
    decode_header, decode_table, SectionEntry, SEC_F32, SEC_GRAPH, SEC_I32, SEC_I64, SEC_I8,
    SEC_META,
};
use super::{AdapterMeta, EngineMeta, LoadedArtifact};
use crate::ir::json::{node_from_json, Json};
use crate::ir::Node;
use crate::ops::linalg::ConvParams;
use crate::ops::quant::RoundingMode;
use crate::plan::kernel::{
    BatchReshape, CompiledKernel, Epilogue, GemmBias, PackedConv, PackedGemm, PackedMatMul,
};
use crate::plan::qkernel::{QThreshold, QuantConv, QuantGemm, QuantMatMul, ThresholdKernel};
use crate::plan::{ExecutionPlan, PlanConst, PlanInput, PlanOutput, Preload, Step};
use crate::tensor::simd::{active_isa, Isa};
use crate::tensor::{AlignedBytes, DType, PackedB, PackedBi8, Tensor, WeightStore, WEIGHT_ALIGN};
use anyhow::{anyhow, bail, ensure, Context, Result};
use std::borrow::Cow;
use std::collections::BTreeMap;
use std::io::Read;
use std::path::Path;
use std::sync::Arc;

/// Read `path` fully into one aligned buffer.
fn read_aligned(path: &Path) -> Result<AlignedBytes, ArtifactError> {
    let mut f = std::fs::File::open(path)?;
    let len = f.metadata()?.len();
    let len = usize::try_from(len)
        .map_err(|_| ArtifactError::Malformed(format!("file length {len} exceeds address space")))?;
    let mut buf = AlignedBytes::zeroed(len);
    f.read_exact(buf.as_mut_slice())?;
    Ok(buf)
}

/// Load and validate a `.qpln` artifact. Every failure mode is a typed
/// [`ArtifactError`]; a file that passes returns a plan whose weight
/// panels borrow the artifact buffer directly (zero-copy).
pub fn read_artifact(path: &Path) -> Result<LoadedArtifact, ArtifactError> {
    let buf = Arc::new(read_aligned(path)?);
    load_from(buf)
}

pub(super) fn load_from(buf: Arc<AlignedBytes>) -> Result<LoadedArtifact, ArtifactError> {
    let file = buf.as_slice();
    let header = decode_header(file)?;
    let entries = decode_table(file, &header)?;

    // ISA gate: interleaved i8 tiles are laid out per-ISA, so a mismatch
    // is a refusal, not a fallback — re-compiling is the correct fix.
    let running = active_isa();
    if header.isa != running.name() {
        return Err(ArtifactError::IsaMismatch {
            packed: header.isa.clone(),
            running: running.name().to_string(),
        });
    }

    let known = [SEC_META, SEC_GRAPH, SEC_F32, SEC_I8, SEC_I32, SEC_I64];
    if let Some(e) = entries.iter().find(|e| !known.contains(&e.id)) {
        return Err(ArtifactError::Malformed(format!("unknown section id {}", e.id)));
    }
    let span = |id: u32| -> Result<(usize, usize), ArtifactError> {
        entries
            .iter()
            .find(|e| e.id == id)
            .map(|e| (e.offset as usize, e.len as usize))
            .ok_or_else(|| ArtifactError::Malformed(format!("missing section id {id}")))
    };
    let meta_span = span(SEC_META)?;
    let graph_span = span(SEC_GRAPH)?;
    let reader = BlobReader {
        buf: &buf,
        f32s: span(SEC_F32)?,
        i8s: span(SEC_I8)?,
        i32s: span(SEC_I32)?,
        i64s: span(SEC_I64)?,
        isa: running,
    };

    let meta_text = std::str::from_utf8(&file[meta_span.0..meta_span.0 + meta_span.1])
        .map_err(|_| ArtifactError::Malformed("META section is not UTF-8".into()))?;
    let graph_json = std::str::from_utf8(&file[graph_span.0..graph_span.0 + graph_span.1])
        .map_err(|_| ArtifactError::Malformed("GRAPH section is not UTF-8".into()))?
        .to_string();

    let (plan, engine) = (|| -> Result<_> {
        let meta = Json::parse(meta_text)?;
        let plan = decode_plan(meta.req("plan")?, &reader)?;
        let engine = match meta.req("engine")? {
            Json::Null => None,
            ej => Some(decode_engine(ej)?),
        };
        Ok((plan, engine))
    })()
    .map_err(|e| ArtifactError::Malformed(format!("{e:#}")))?;

    Ok(LoadedArtifact { plan, engine, graph_json, buf })
}

/// Typed views over the four raw blob sections. `map_*` hands out
/// zero-copy [`WeightStore::Mapped`] ranges (weight panels); `copy_*`
/// materializes small vectors.
struct BlobReader<'a> {
    buf: &'a Arc<AlignedBytes>,
    f32s: (usize, usize),
    i8s: (usize, usize),
    i32s: (usize, usize),
    i64s: (usize, usize),
    isa: Isa,
}

impl BlobReader<'_> {
    /// Resolve an element range against a blob span, returning the
    /// absolute byte offset. All arithmetic is overflow-checked and the
    /// range must lie inside the section.
    fn resolve(&self, span: (usize, usize), off: usize, len: usize, size: usize) -> Result<usize> {
        let byte_off = off.checked_mul(size).ok_or_else(|| anyhow!("blob offset overflows"))?;
        let byte_len = len.checked_mul(size).ok_or_else(|| anyhow!("blob length overflows"))?;
        let end = byte_off.checked_add(byte_len).ok_or_else(|| anyhow!("blob extent overflows"))?;
        ensure!(
            end <= span.1,
            "blob range [{off}, +{len}) x{size} exceeds section of {} bytes",
            span.1
        );
        Ok(span.0 + byte_off)
    }

    fn bytes(&self, span: (usize, usize), off: usize, len: usize, size: usize) -> Result<&[u8]> {
        let abs = self.resolve(span, off, len, size)?;
        Ok(&self.buf.as_slice()[abs..abs + len * size])
    }

    /// Borrow an f32 weight panel straight out of the artifact buffer.
    fn map_f32(&self, off: usize, len: usize) -> Result<WeightStore<f32>> {
        let abs = self.resolve(self.f32s, off, len, 4)?;
        ensure!(abs % WEIGHT_ALIGN == 0, "f32 panel at byte {abs} violates 64-byte alignment");
        Ok(WeightStore::mapped(self.buf.clone(), abs, len))
    }

    /// Borrow an i8 weight panel (or SIMD tile block) zero-copy.
    fn map_i8(&self, off: usize, len: usize) -> Result<WeightStore<i8>> {
        let abs = self.resolve(self.i8s, off, len, 1)?;
        ensure!(abs % WEIGHT_ALIGN == 0, "i8 panel at byte {abs} violates 64-byte alignment");
        Ok(WeightStore::mapped(self.buf.clone(), abs, len))
    }

    fn copy_f32(&self, off: usize, len: usize) -> Result<Vec<f32>> {
        let b = self.bytes(self.f32s, off, len, 4)?;
        Ok(b.chunks_exact(4).map(|c| f32::from_ne_bytes(c.try_into().unwrap())).collect())
    }

    fn copy_i8(&self, off: usize, len: usize) -> Result<Vec<i8>> {
        let b = self.bytes(self.i8s, off, len, 1)?;
        Ok(b.iter().map(|&v| v as i8).collect())
    }

    fn copy_i32(&self, off: usize, len: usize) -> Result<Vec<i32>> {
        let b = self.bytes(self.i32s, off, len, 4)?;
        Ok(b.chunks_exact(4).map(|c| i32::from_ne_bytes(c.try_into().unwrap())).collect())
    }

    fn copy_i64(&self, off: usize, len: usize) -> Result<Vec<i64>> {
        let b = self.bytes(self.i64s, off, len, 8)?;
        Ok(b.chunks_exact(8).map(|c| i64::from_ne_bytes(c.try_into().unwrap())).collect())
    }
}

fn usize_of(j: &Json) -> Result<usize> {
    let v = j.as_i64()?;
    usize::try_from(v).map_err(|_| anyhow!("expected non-negative integer, got {v}"))
}

fn u32_of(j: &Json) -> Result<u32> {
    let v = j.as_i64()?;
    u32::try_from(v).map_err(|_| anyhow!("slot id {v} out of range"))
}

fn f32_of(j: &Json) -> Result<f32> {
    Ok(j.as_f64()? as f32)
}

fn blob_ref(j: &Json) -> Result<(usize, usize)> {
    Ok((usize_of(j.req("off")?)?, usize_of(j.req("len")?)?))
}

fn dtype_of(j: &Json) -> Result<DType> {
    let s = j.as_str()?;
    DType::from_name(s).ok_or_else(|| anyhow!("unknown dtype '{s}'"))
}

fn tensor_from_ref(j: &Json, r: &BlobReader<'_>) -> Result<Tensor> {
    let shape: Vec<usize> =
        j.req("shape")?.as_arr()?.iter().map(usize_of).collect::<Result<_>>()?;
    let (off, len) = blob_ref(j)?;
    let numel: usize = shape.iter().product();
    ensure!(numel == len, "tensor shape {shape:?} wants {numel} elements, ref has {len}");
    Ok(match dtype_of(j.req("dtype")?)? {
        DType::F32 => Tensor::new(shape, r.copy_f32(off, len)?),
        DType::I8 => Tensor::new_i8(shape, r.copy_i8(off, len)?),
        DType::I32 => Tensor::new_i32(shape, r.copy_i32(off, len)?),
        DType::I64 => Tensor::new_i64(shape, r.copy_i64(off, len)?),
    })
}

fn packed_b_from_json(j: &Json, r: &BlobReader<'_>) -> Result<PackedB> {
    let (k, n) = (usize_of(j.req("k")?)?, usize_of(j.req("n")?)?);
    let (off, len) = blob_ref(j)?;
    ensure!(len == k.checked_mul(n).ok_or_else(|| anyhow!("panel dims overflow"))?,
        "f32 panel length {len} != k*n ({k}x{n})");
    Ok(PackedB::from_store(k, n, r.map_f32(off, len)?))
}

fn packed_bi8_from_json(j: &Json, r: &BlobReader<'_>) -> Result<PackedBi8> {
    let (k, n) = (usize_of(j.req("k")?)?, usize_of(j.req("n")?)?);
    let (off, len) = blob_ref(j)?;
    ensure!(len == k.checked_mul(n).ok_or_else(|| anyhow!("panel dims overflow"))?,
        "i8 panel length {len} != k*n ({k}x{n})");
    let dense = matches!(j.req("dense")?, Json::Bool(true));
    let simd = match j.req("simd")? {
        Json::Null => None,
        sj => {
            let np = usize_of(sj.req("np")?)?;
            let (toff, tlen) = blob_ref(sj)?;
            Some((r.isa, np, r.map_i8(toff, tlen)?))
        }
    };
    Ok(PackedBi8::from_parts(k, n, r.map_i8(off, len)?, dense, simd))
}

fn conv_params_from_json(j: &Json) -> Result<ConvParams> {
    let v: Vec<usize> = j.as_arr()?.iter().map(usize_of).collect::<Result<_>>()?;
    ensure!(v.len() == 9, "conv params want 9 entries, got {}", v.len());
    Ok(ConvParams {
        kh: v[0],
        kw: v[1],
        stride_h: v[2],
        stride_w: v[3],
        pads: [v[4], v[5], v[6], v[7]],
        group: v[8],
    })
}

fn epilogue_from_json(j: &Json, r: &BlobReader<'_>) -> Result<Epilogue> {
    Ok(match j.req("t")?.as_str()? {
        "relu" => Epilogue::Relu,
        "quant" => Epilogue::Quant {
            s: j.req("s")?.as_f64()?,
            z: j.req("z")?.as_f64()?,
            qmin: j.req("qmin")?.as_f64()?,
            qmax: j.req("qmax")?.as_f64()?,
            mode: RoundingMode::from_str(j.req("mode")?.as_str()?)?,
        },
        "bipolar" => Epilogue::Bipolar { s: j.req("s")?.as_f64()? },
        "batchnorm" => {
            let take = |key: &str| -> Result<Vec<f32>> {
                let (off, len) = blob_ref(j.req(key)?)?;
                r.copy_f32(off, len)
            };
            Epilogue::BatchNorm {
                mean: take("mean")?,
                denom: take("denom")?,
                scale: take("scale")?,
                bias: take("bias")?,
            }
        }
        other => bail!("unknown epilogue kind '{other}'"),
    })
}

fn qthreshold_from_json(j: &Json, r: &BlobReader<'_>) -> Result<QThreshold> {
    let channels = usize_of(j.req("channels")?)?;
    let steps = usize_of(j.req("steps")?)?;
    let (off, len) = blob_ref(j.req("rows")?)?;
    ensure!(
        len == channels.checked_mul(steps).ok_or_else(|| anyhow!("threshold dims overflow"))?,
        "threshold rows {len} != channels*steps ({channels}x{steps})"
    );
    Ok(QThreshold::from_parts(
        channels,
        steps,
        r.copy_i32(off, len)?,
        f32_of(j.req("out_scale")?)?,
        f32_of(j.req("out_bias")?)?,
    ))
}

fn opt_qthreshold(j: &Json, r: &BlobReader<'_>) -> Result<Option<QThreshold>> {
    match j {
        Json::Null => Ok(None),
        t => Ok(Some(qthreshold_from_json(t, r)?)),
    }
}

fn epilogues_from_json(j: &Json, r: &BlobReader<'_>) -> Result<Vec<Epilogue>> {
    j.as_arr()?.iter().map(|e| epilogue_from_json(e, r)).collect()
}

fn kernel_from_json(
    j: &Json,
    nodes: &[Node],
    node_idx: usize,
    r: &BlobReader<'_>,
) -> Result<CompiledKernel> {
    Ok(match j.req("t")?.as_str()? {
        "op" => {
            let node = nodes
                .get(node_idx)
                .ok_or_else(|| anyhow!("step node index {node_idx} out of range"))?;
            CompiledKernel::Op(crate::ops::kernel_for(node)?)
        }
        "conv" => {
            let p = conv_params_from_json(j.req("p")?)?;
            let (m, cg, mg, k) = (
                usize_of(j.req("m")?)?,
                usize_of(j.req("cg")?)?,
                usize_of(j.req("mg")?)?,
                usize_of(j.req("k")?)?,
            );
            let weights = j
                .req("w")?
                .as_arr()?
                .iter()
                .map(|w| packed_b_from_json(w, r))
                .collect::<Result<Vec<_>>>()?;
            ensure!(weights.len() == p.group, "conv has {} panels for {} groups", weights.len(), p.group);
            let bias = match j.req("bias")? {
                Json::Null => None,
                b => {
                    let (off, len) = blob_ref(b)?;
                    Some(r.copy_f32(off, len)?)
                }
            };
            let ep = epilogues_from_json(j.req("ep")?, r)?;
            CompiledKernel::Conv(Arc::new(PackedConv::from_parts(p, m, cg, mg, k, weights, bias, ep)))
        }
        "gemm" => {
            let (k, n) = (usize_of(j.req("k")?)?, usize_of(j.req("n")?)?);
            let bias = match j.req("bias")?.req("t")?.as_str()? {
                "none" => GemmBias::None,
                "runtime" => GemmBias::Runtime,
                "folded" => GemmBias::Folded(tensor_from_ref(j.req("bias")?.req("v")?, r)?),
                other => bail!("unknown gemm bias kind '{other}'"),
            };
            CompiledKernel::Gemm(Arc::new(PackedGemm::from_parts(
                k,
                n,
                packed_b_from_json(j.req("b")?, r)?,
                f32_of(j.req("alpha")?)?,
                f32_of(j.req("beta")?)?,
                matches!(j.req("trans_a")?, Json::Bool(true)),
                bias,
                epilogues_from_json(j.req("ep")?, r)?,
            )))
        }
        "matmul" => {
            let (k, n) = (usize_of(j.req("k")?)?, usize_of(j.req("n")?)?);
            CompiledKernel::MatMul(Arc::new(PackedMatMul::from_parts(
                k,
                n,
                packed_b_from_json(j.req("b")?, r)?,
                epilogues_from_json(j.req("ep")?, r)?,
            )))
        }
        "qconv" => {
            let p = conv_params_from_json(j.req("p")?)?;
            let (m, cg, mg, k) = (
                usize_of(j.req("m")?)?,
                usize_of(j.req("cg")?)?,
                usize_of(j.req("mg")?)?,
                usize_of(j.req("k")?)?,
            );
            let weights = j
                .req("w")?
                .as_arr()?
                .iter()
                .map(|w| packed_bi8_from_json(w, r))
                .collect::<Result<Vec<_>>>()?;
            ensure!(weights.len() == p.group, "qconv has {} panels for {} groups", weights.len(), p.group);
            CompiledKernel::QConv(Arc::new(QuantConv::from_parts(
                p,
                m,
                cg,
                mg,
                k,
                weights,
                (j.req("lo")?.as_f64()?, j.req("hi")?.as_f64()?),
                opt_qthreshold(j.req("th")?, r)?,
                dtype_of(j.req("out")?)?,
            )))
        }
        "qgemm" => {
            let (k, n) = (usize_of(j.req("k")?)?, usize_of(j.req("n")?)?);
            let bias = match j.req("bias")? {
                Json::Null => None,
                b => {
                    let (off, len) = blob_ref(b)?;
                    Some(r.copy_i32(off, len)?)
                }
            };
            CompiledKernel::QGemm(Arc::new(QuantGemm::from_parts(
                k,
                n,
                packed_bi8_from_json(j.req("b")?, r)?,
                bias,
                (j.req("lo")?.as_f64()?, j.req("hi")?.as_f64()?),
                opt_qthreshold(j.req("th")?, r)?,
                dtype_of(j.req("out")?)?,
            )))
        }
        "qmatmul" => {
            let (k, n) = (usize_of(j.req("k")?)?, usize_of(j.req("n")?)?);
            CompiledKernel::QMatMul(Arc::new(QuantMatMul::from_parts(
                k,
                n,
                packed_bi8_from_json(j.req("b")?, r)?,
                (j.req("lo")?.as_f64()?, j.req("hi")?.as_f64()?),
                opt_qthreshold(j.req("th")?, r)?,
                dtype_of(j.req("out")?)?,
            )))
        }
        "threshold" => {
            let channels = usize_of(j.req("channels")?)?;
            let steps = usize_of(j.req("steps")?)?;
            let (off, len) = blob_ref(j.req("rows")?)?;
            ensure!(
                len == channels.checked_mul(steps).ok_or_else(|| anyhow!("threshold dims overflow"))?,
                "threshold rows {len} != channels*steps ({channels}x{steps})"
            );
            CompiledKernel::Threshold(Arc::new(ThresholdKernel::from_parts(
                channels,
                steps,
                r.copy_f32(off, len)?,
                f32_of(j.req("out_scale")?)?,
                f32_of(j.req("out_bias")?)?,
                dtype_of(j.req("out")?)?,
            )))
        }
        "reshape" => {
            let orig: Vec<i64> =
                j.req("orig")?.as_arr()?.iter().map(|v| v.as_i64()).collect::<Result<_>>()?;
            ensure!(!orig.is_empty(), "reshape target must not be empty");
            CompiledKernel::Reshape(Arc::new(BatchReshape::new(
                &orig,
                matches!(j.req("try_orig_first")?, Json::Bool(true)),
            )))
        }
        other => bail!("unknown kernel kind '{other}'"),
    })
}

fn decode_plan(p: &Json, r: &BlobReader<'_>) -> Result<ExecutionPlan<'static>> {
    let nodes: Vec<Node> =
        p.req("nodes")?.as_arr()?.iter().map(node_from_json).collect::<Result<_>>()?;

    // folded constants first: preloads Arc-share them by name, matching
    // what the compiler produced
    let mut folded_outputs = Vec::new();
    let mut folded_map: BTreeMap<String, Arc<Tensor>> = BTreeMap::new();
    for fj in p.req("folded")?.as_arr()? {
        let name = fj.req("name")?.as_str()?.to_string();
        let t = Arc::new(tensor_from_ref(fj.req("v")?, r)?);
        folded_map.insert(name.clone(), t.clone());
        folded_outputs.push((name, t));
    }

    let mut preloads = Vec::new();
    for pj in p.req("preloads")?.as_arr()? {
        let name = pj.req("name")?.as_str()?.to_string();
        let slot = u32_of(pj.req("slot")?)?;
        let value = match folded_map.get(&name) {
            Some(shared) => shared.clone(),
            None => Arc::new(tensor_from_ref(pj.req("v")?, r)?),
        };
        preloads.push(Preload { name, slot, value: PlanConst::Shared(value) });
    }

    let mut steps = Vec::new();
    for sj in p.req("steps")?.as_arr()? {
        let node_idx = usize_of(sj.req("node")?)?;
        let out_node_idx = usize_of(sj.req("out_node")?)?;
        ensure!(node_idx < nodes.len(), "step node index {node_idx} out of range");
        ensure!(out_node_idx < nodes.len(), "step out-node index {out_node_idx} out of range");
        steps.push(Step {
            node_idx,
            out_node_idx,
            kernel: kernel_from_json(sj.req("kernel")?, &nodes, node_idx, r)
                .with_context(|| format!("step for node {node_idx}"))?,
            inputs: sj.req("in")?.as_arr()?.iter().map(u32_of).collect::<Result<_>>()?,
            outputs: sj
                .req("out")?
                .as_arr()?
                .iter()
                .map(|o| match o {
                    Json::Null => Ok(None),
                    v => u32_of(v).map(Some),
                })
                .collect::<Result<_>>()?,
            release: sj.req("release")?.as_arr()?.iter().map(u32_of).collect::<Result<_>>()?,
        });
    }

    let mut inputs = Vec::new();
    for ij in p.req("inputs")?.as_arr()? {
        inputs.push(PlanInput {
            name: ij.req("name")?.as_str()?.to_string(),
            shape: match ij.req("shape")? {
                Json::Null => None,
                s => Some(s.as_arr()?.iter().map(usize_of).collect::<Result<_>>()?),
            },
            slot: match ij.req("slot")? {
                Json::Null => None,
                v => Some(u32_of(v)?),
            },
        });
    }

    let mut outputs = Vec::new();
    for oj in p.req("outputs")?.as_arr()? {
        outputs.push(PlanOutput {
            name: oj.req("name")?.as_str()?.to_string(),
            slot: u32_of(oj.req("slot")?)?,
        });
    }

    let slot_count = usize_of(p.req("slot_count")?)?;
    let slot_dtypes: Vec<DType> =
        p.req("slot_dtypes")?.as_arr()?.iter().map(dtype_of).collect::<Result<_>>()?;
    ensure!(
        slot_dtypes.len() == slot_count,
        "slot dtype table has {} entries for {slot_count} slots",
        slot_dtypes.len()
    );
    let slot_numel: Vec<Option<usize>> = p
        .req("slot_numel")?
        .as_arr()?
        .iter()
        .map(|v| match v {
            Json::Null => Ok(None),
            n => usize_of(n).map(Some),
        })
        .collect::<Result<_>>()?;
    ensure!(
        slot_numel.len() == slot_count,
        "slot numel table has {} entries for {slot_count} slots",
        slot_numel.len()
    );

    let mut alias_outputs = Vec::new();
    for aj in p.req("aliases")?.as_arr()? {
        let pair = aj.as_arr()?;
        ensure!(pair.len() == 2, "alias entry must be a [from, to] pair");
        alias_outputs.push((pair[0].as_str()?.to_string(), pair[1].as_str()?.to_string()));
    }

    let c = p.req("counters")?;
    Ok(ExecutionPlan {
        name: p.req("name")?.as_str()?.to_string(),
        nodes: Cow::Owned(nodes),
        steps,
        preloads,
        inputs,
        outputs,
        slot_count,
        slot_dtypes,
        slot_numel,
        folded_outputs,
        alias_outputs,
        node_count: usize_of(c.req("node")?)?,
        folded_count: usize_of(c.req("folded")?)?,
        elided_count: usize_of(c.req("elided")?)?,
        packed_count: usize_of(c.req("packed")?)?,
        quant_count: usize_of(c.req("quant")?)?,
        fused_count: usize_of(c.req("fused")?)?,
        resident_int_count: usize_of(c.req("resident_int")?)?,
        batch_symbolic_count: usize_of(c.req("batch_symbolic")?)?,
        batch_blockers: p
            .req("batch_blockers")?
            .as_arr()?
            .iter()
            .map(|b| b.as_str().map(String::from))
            .collect::<Result<_>>()?,
    })
}

fn decode_engine(j: &Json) -> Result<EngineMeta> {
    let aj = j.req("adapter")?;
    let adapter = match aj.req("t")?.as_str()? {
        "dense" => AdapterMeta::Dense,
        "nchw" => AdapterMeta::Nchw {
            c: usize_of(aj.req("c")?)?,
            h: usize_of(aj.req("h")?)?,
            w: usize_of(aj.req("w")?)?,
        },
        other => bail!("unknown adapter kind '{other}'"),
    };
    Ok(EngineMeta {
        model_name: j.req("model")?.as_str()?.to_string(),
        input_name: j.req("input")?.as_str()?.to_string(),
        output_name: j.req("output")?.as_str()?.to_string(),
        in_dim: usize_of(j.req("in_dim")?)?,
        out_dim: usize_of(j.req("out_dim")?)?,
        adapter,
        streamlined: matches!(j.req("streamlined")?, Json::Bool(true)),
    })
}

/// The raw bytes of one section (test and tooling hook).
pub fn read_section(path: &Path, id: u32) -> Result<Vec<u8>, ArtifactError> {
    let bytes = std::fs::read(path)?;
    let header = decode_header(&bytes)?;
    let entries = decode_table(&bytes, &header)?;
    let e: &SectionEntry = entries
        .iter()
        .find(|e| e.id == id)
        .ok_or_else(|| ArtifactError::Malformed(format!("missing section id {id}")))?;
    Ok(bytes[e.offset as usize..(e.offset + e.len) as usize].to_vec())
}

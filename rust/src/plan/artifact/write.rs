//! Serializing a compiled [`ExecutionPlan`] into a `.qpln` artifact.
//!
//! The writer walks the plan once, appending every weight panel and
//! constant tensor to a per-dtype raw blob (64-byte-aligned entries, so
//! the loader can borrow panels in place) and building a META JSON
//! document whose kernel descriptors reference blob ranges by element
//! offset. The source graph rides along as a section of its own so
//! `qonnx verify --artifact` can re-prove the plan against it.
//!
//! Float scalars (alpha/beta, quant params, proven ranges) are stored as
//! JSON numbers: the crate's JSON printer emits the shortest
//! round-tripping representation, so the reread value is bit-identical.
//! Vectors and tensors never go through text — they live in the typed
//! blobs verbatim.

use super::format::{
    crc32, encode_entry, encode_header, pad_to_align, SectionEntry, ENTRY_LEN, HEADER_LEN,
    SEC_F32, SEC_GRAPH, SEC_I32, SEC_I64, SEC_I8, SEC_META,
};
use super::{AdapterMeta, EngineMeta};
use crate::ir::json::{model_to_json, node_to_json, Json};
use crate::ir::ModelGraph;
use crate::ops::quant::RoundingMode;
use crate::plan::kernel::{BatchReshape, CompiledKernel, Epilogue, GemmBias, PackedConv};
use crate::plan::qkernel::QThreshold;
use crate::plan::ExecutionPlan;
use crate::tensor::simd::active_isa;
use crate::tensor::{DType, PackedB, PackedBi8, Tensor, WEIGHT_ALIGN};
use anyhow::{bail, Result};
use std::path::Path;

/// Per-dtype raw blob accumulators. Every entry starts 64-byte aligned
/// *within its blob*; blob sections themselves start 64-byte aligned in
/// the file, so entry starts are 64-byte aligned absolutely.
#[derive(Default)]
struct Blobs {
    f32v: Vec<f32>,
    i8v: Vec<i8>,
    i32v: Vec<i32>,
    i64v: Vec<i64>,
}

macro_rules! blob_push {
    ($name:ident, $field:ident, $ty:ty) => {
        /// Append `data`, padding so its byte offset within the blob is
        /// a multiple of [`WEIGHT_ALIGN`]; returns `(off, len)` in
        /// elements.
        fn $name(&mut self, data: &[$ty]) -> (usize, usize) {
            let size = std::mem::size_of::<$ty>();
            let elems_per_align = WEIGHT_ALIGN / size;
            let pad = (elems_per_align - self.$field.len() % elems_per_align) % elems_per_align;
            self.$field.resize(self.$field.len() + pad, 0 as $ty);
            let off = self.$field.len();
            self.$field.extend_from_slice(data);
            (off, data.len())
        }
    };
}

impl Blobs {
    blob_push!(push_f32, f32v, f32);
    blob_push!(push_i8, i8v, i8);
    blob_push!(push_i32, i32v, i32);
    blob_push!(push_i64, i64v, i64);
}

fn num(v: usize) -> Json {
    Json::Num(v as f64)
}

fn blob_ref(off: usize, len: usize) -> Json {
    Json::obj(vec![("off", num(off)), ("len", num(len))])
}

fn tensor_ref(t: &Tensor, blobs: &mut Blobs) -> Result<Json> {
    let (dt, (off, len)) = match t.dtype() {
        DType::F32 => ("f32", blobs.push_f32(t.as_f32()?)),
        DType::I8 => ("i8", blobs.push_i8(t.as_i8()?)),
        DType::I32 => ("i32", blobs.push_i32(t.as_i32()?)),
        DType::I64 => ("i64", blobs.push_i64(t.as_i64()?)),
    };
    Ok(Json::obj(vec![
        ("dtype", Json::Str(dt.into())),
        ("shape", Json::Arr(t.shape().iter().map(|&d| num(d)).collect())),
        ("off", num(off)),
        ("len", num(len)),
    ]))
}

fn packed_b_json(pb: &PackedB, blobs: &mut Blobs) -> Json {
    let (off, len) = blobs.push_f32(pb.store().as_slice());
    Json::obj(vec![
        ("k", num(pb.k())),
        ("n", num(pb.n())),
        ("off", num(off)),
        ("len", num(len)),
    ])
}

/// Serialize an i8 panel, including its interleaved SIMD tiles when
/// present. The tiles are laid out for exactly one ISA; `file_isa` is
/// what the header will record, and a panel packed for any *other* ISA
/// is a writer bug we refuse to persist.
fn packed_bi8_json(pb: &PackedBi8, file_isa: &str, blobs: &mut Blobs) -> Result<Json> {
    let (off, len) = blobs.push_i8(pb.store().as_slice());
    let simd = match pb.simd_parts() {
        None => Json::Null,
        Some((isa, np_total, tiles)) => {
            if isa.name() != file_isa {
                bail!(
                    "panel packed for ISA '{}' but artifact records '{file_isa}'",
                    isa.name()
                );
            }
            let (toff, tlen) = blobs.push_i8(tiles.as_slice());
            Json::obj(vec![("np", num(np_total)), ("off", num(toff)), ("len", num(tlen))])
        }
    };
    Ok(Json::obj(vec![
        ("k", num(pb.k())),
        ("n", num(pb.n())),
        ("dense", Json::Bool(pb.dense_hint())),
        ("off", num(off)),
        ("len", num(len)),
        ("simd", simd),
    ]))
}

fn rounding_mode_str(m: &RoundingMode) -> &'static str {
    match m {
        RoundingMode::Round => "ROUND",
        RoundingMode::RoundToZero => "ROUND_TO_ZERO",
        RoundingMode::Ceil => "CEIL",
        RoundingMode::Floor => "FLOOR",
    }
}

fn epilogue_json(e: &Epilogue, blobs: &mut Blobs) -> Json {
    match e {
        Epilogue::Relu => Json::obj(vec![("t", Json::Str("relu".into()))]),
        Epilogue::Quant { s, z, qmin, qmax, mode } => Json::obj(vec![
            ("t", Json::Str("quant".into())),
            ("s", Json::Num(*s)),
            ("z", Json::Num(*z)),
            ("qmin", Json::Num(*qmin)),
            ("qmax", Json::Num(*qmax)),
            ("mode", Json::Str(rounding_mode_str(mode).into())),
        ]),
        Epilogue::Bipolar { s } => {
            Json::obj(vec![("t", Json::Str("bipolar".into())), ("s", Json::Num(*s))])
        }
        Epilogue::BatchNorm { mean, denom, scale, bias } => {
            let m = blobs.push_f32(mean);
            let d = blobs.push_f32(denom);
            let s = blobs.push_f32(scale);
            let b = blobs.push_f32(bias);
            Json::obj(vec![
                ("t", Json::Str("batchnorm".into())),
                ("mean", blob_ref(m.0, m.1)),
                ("denom", blob_ref(d.0, d.1)),
                ("scale", blob_ref(s.0, s.1)),
                ("bias", blob_ref(b.0, b.1)),
            ])
        }
    }
}

fn qthreshold_json(t: &QThreshold, blobs: &mut Blobs) -> Json {
    let (off, len) = blobs.push_i32(t.rows());
    let (out_scale, out_bias) = t.out_params();
    Json::obj(vec![
        ("channels", num(t.channels())),
        ("steps", num(t.steps())),
        ("rows", blob_ref(off, len)),
        ("out_scale", Json::Num(f64::from(out_scale))),
        ("out_bias", Json::Num(f64::from(out_bias))),
    ])
}

fn conv_params_json(c: &PackedConv) -> Json {
    let p = c.params();
    Json::Arr(
        [p.kh, p.kw, p.stride_h, p.stride_w, p.pads[0], p.pads[1], p.pads[2], p.pads[3], p.group]
            .iter()
            .map(|&v| num(v))
            .collect(),
    )
}

fn reshape_json(r: &BatchReshape) -> Json {
    Json::obj(vec![
        ("t", Json::Str("reshape".into())),
        ("orig", Json::Arr(r.orig().iter().map(|&v| Json::Num(v as f64)).collect())),
        ("try_orig_first", Json::Bool(r.try_orig_first())),
    ])
}

fn kernel_json(k: &CompiledKernel, file_isa: &str, blobs: &mut Blobs) -> Result<Json> {
    Ok(match k {
        // re-resolved from the node table at load time
        CompiledKernel::Op(_) => Json::obj(vec![("t", Json::Str("op".into()))]),
        CompiledKernel::Conv(c) => {
            let (m, cg, mg, kd) = c.dims();
            let bias = match c.bias() {
                None => Json::Null,
                Some(b) => {
                    let (off, len) = blobs.push_f32(b);
                    blob_ref(off, len)
                }
            };
            Json::obj(vec![
                ("t", Json::Str("conv".into())),
                ("p", conv_params_json(c)),
                ("m", num(m)),
                ("cg", num(cg)),
                ("mg", num(mg)),
                ("k", num(kd)),
                ("w", Json::Arr(c.weights().iter().map(|pb| packed_b_json(pb, blobs)).collect())),
                ("bias", bias),
                ("ep", Json::Arr(c.epilogue().iter().map(|e| epilogue_json(e, blobs)).collect())),
            ])
        }
        CompiledKernel::Gemm(g) => {
            let (kd, n, alpha, beta, trans_a) = g.scalars();
            let bias = match g.bias() {
                GemmBias::None => Json::obj(vec![("t", Json::Str("none".into()))]),
                GemmBias::Runtime => Json::obj(vec![("t", Json::Str("runtime".into()))]),
                GemmBias::Folded(t) => Json::obj(vec![
                    ("t", Json::Str("folded".into())),
                    ("v", tensor_ref(t, blobs)?),
                ]),
            };
            Json::obj(vec![
                ("t", Json::Str("gemm".into())),
                ("k", num(kd)),
                ("n", num(n)),
                ("alpha", Json::Num(f64::from(alpha))),
                ("beta", Json::Num(f64::from(beta))),
                ("trans_a", Json::Bool(trans_a)),
                ("b", packed_b_json(g.packed_b(), blobs)),
                ("bias", bias),
                ("ep", Json::Arr(g.epilogue().iter().map(|e| epilogue_json(e, blobs)).collect())),
            ])
        }
        CompiledKernel::MatMul(m) => {
            let (kd, n) = m.dims();
            Json::obj(vec![
                ("t", Json::Str("matmul".into())),
                ("k", num(kd)),
                ("n", num(n)),
                ("b", packed_b_json(m.packed_b(), blobs)),
                ("ep", Json::Arr(m.epilogue().iter().map(|e| epilogue_json(e, blobs)).collect())),
            ])
        }
        CompiledKernel::QConv(c) => {
            let (m, cg, mg, kd) = c.dims();
            let p = c.params();
            let (lo, hi) = c.input_range();
            let w = c
                .weights()
                .iter()
                .map(|pb| packed_bi8_json(pb, file_isa, blobs))
                .collect::<Result<Vec<_>>>()?;
            Json::obj(vec![
                ("t", Json::Str("qconv".into())),
                (
                    "p",
                    Json::Arr(
                        [
                            p.kh, p.kw, p.stride_h, p.stride_w, p.pads[0], p.pads[1], p.pads[2],
                            p.pads[3], p.group,
                        ]
                        .iter()
                        .map(|&v| num(v))
                        .collect(),
                    ),
                ),
                ("m", num(m)),
                ("cg", num(cg)),
                ("mg", num(mg)),
                ("k", num(kd)),
                ("w", Json::Arr(w)),
                ("lo", Json::Num(lo)),
                ("hi", Json::Num(hi)),
                ("th", c.epilogue().map_or(Json::Null, |t| qthreshold_json(t, blobs))),
                ("out", Json::Str(c.out_dtype().name().into())),
            ])
        }
        CompiledKernel::QGemm(g) => {
            let (kd, n) = g.dims();
            let (lo, hi) = g.input_range();
            let bias = match g.bias() {
                None => Json::Null,
                Some(b) => {
                    let (off, len) = blobs.push_i32(b);
                    blob_ref(off, len)
                }
            };
            Json::obj(vec![
                ("t", Json::Str("qgemm".into())),
                ("k", num(kd)),
                ("n", num(n)),
                ("b", packed_bi8_json(g.packed_b(), file_isa, blobs)?),
                ("bias", bias),
                ("lo", Json::Num(lo)),
                ("hi", Json::Num(hi)),
                ("th", g.epilogue().map_or(Json::Null, |t| qthreshold_json(t, blobs))),
                ("out", Json::Str(g.out_dtype().name().into())),
            ])
        }
        CompiledKernel::QMatMul(m) => {
            let (kd, n) = m.dims();
            let (lo, hi) = m.input_range();
            Json::obj(vec![
                ("t", Json::Str("qmatmul".into())),
                ("k", num(kd)),
                ("n", num(n)),
                ("b", packed_bi8_json(m.packed_b(), file_isa, blobs)?),
                ("lo", Json::Num(lo)),
                ("hi", Json::Num(hi)),
                ("th", m.epilogue().map_or(Json::Null, |t| qthreshold_json(t, blobs))),
                ("out", Json::Str(m.out_dtype().name().into())),
            ])
        }
        CompiledKernel::Threshold(t) => {
            let (off, len) = blobs.push_f32(t.rows());
            let (out_scale, out_bias) = t.out_params();
            Json::obj(vec![
                ("t", Json::Str("threshold".into())),
                ("channels", num(t.channels())),
                ("steps", num(t.steps())),
                ("rows", blob_ref(off, len)),
                ("out_scale", Json::Num(f64::from(out_scale))),
                ("out_bias", Json::Num(f64::from(out_bias))),
                ("out", Json::Str(t.out_dtype().name().into())),
            ])
        }
        CompiledKernel::Reshape(r) => reshape_json(r),
    })
}

fn adapter_json(a: &AdapterMeta) -> Json {
    match a {
        AdapterMeta::Dense => Json::obj(vec![("t", Json::Str("dense".into()))]),
        AdapterMeta::Nchw { c, h, w } => Json::obj(vec![
            ("t", Json::Str("nchw".into())),
            ("c", num(*c)),
            ("h", num(*h)),
            ("w", num(*w)),
        ]),
    }
}

fn plan_meta_json(
    plan: &ExecutionPlan<'_>,
    engine: Option<&EngineMeta>,
    file_isa: &str,
    blobs: &mut Blobs,
) -> Result<Json> {
    let steps = plan
        .steps
        .iter()
        .map(|s| {
            Ok(Json::obj(vec![
                ("node", num(s.node_idx)),
                ("out_node", num(s.out_node_idx)),
                ("kernel", kernel_json(&s.kernel, file_isa, blobs)?),
                ("in", Json::Arr(s.inputs.iter().map(|&v| num(v as usize)).collect())),
                (
                    "out",
                    Json::Arr(
                        s.outputs
                            .iter()
                            .map(|o| o.map_or(Json::Null, |v| num(v as usize)))
                            .collect(),
                    ),
                ),
                ("release", Json::Arr(s.release.iter().map(|&v| num(v as usize)).collect())),
            ]))
        })
        .collect::<Result<Vec<_>>>()?;

    let preloads = plan
        .preloads
        .iter()
        .map(|p| {
            Ok(Json::obj(vec![
                ("name", Json::Str(p.name.clone())),
                ("slot", num(p.slot as usize)),
                ("v", tensor_ref(p.value.as_tensor(), blobs)?),
            ]))
        })
        .collect::<Result<Vec<_>>>()?;

    // constants consumed by a preload stay hot at run start; the rest are
    // record-keeping only ("cold") — groundwork for spilling them out of
    // the resident image entirely
    let hot: std::collections::BTreeSet<&str> =
        plan.preloads.iter().map(|p| p.name.as_str()).collect();
    let folded = plan
        .folded_outputs
        .iter()
        .map(|(name, t)| {
            Ok(Json::obj(vec![
                ("name", Json::Str(name.clone())),
                ("v", tensor_ref(t, blobs)?),
                ("cold", Json::Bool(!hot.contains(name.as_str()))),
            ]))
        })
        .collect::<Result<Vec<_>>>()?;

    let inputs = plan
        .inputs
        .iter()
        .map(|i| {
            Json::obj(vec![
                ("name", Json::Str(i.name.clone())),
                (
                    "shape",
                    i.shape
                        .as_ref()
                        .map_or(Json::Null, |s| Json::Arr(s.iter().map(|&d| num(d)).collect())),
                ),
                ("slot", i.slot.map_or(Json::Null, |s| num(s as usize))),
            ])
        })
        .collect();

    let outputs = plan
        .outputs
        .iter()
        .map(|o| {
            Json::obj(vec![("name", Json::Str(o.name.clone())), ("slot", num(o.slot as usize))])
        })
        .collect();

    let plan_json = Json::obj(vec![
        ("name", Json::Str(plan.name.clone())),
        ("nodes", Json::Arr(plan.nodes.iter().map(node_to_json).collect())),
        ("steps", Json::Arr(steps)),
        ("preloads", Json::Arr(preloads)),
        ("inputs", Json::Arr(inputs)),
        ("outputs", Json::Arr(outputs)),
        ("slot_count", num(plan.slot_count)),
        (
            "slot_dtypes",
            Json::Arr(plan.slot_dtypes.iter().map(|d| Json::Str(d.name().into())).collect()),
        ),
        (
            "slot_numel",
            Json::Arr(plan.slot_numel.iter().map(|n| n.map_or(Json::Null, num)).collect()),
        ),
        ("folded", Json::Arr(folded)),
        (
            "aliases",
            Json::Arr(
                plan.alias_outputs
                    .iter()
                    .map(|(a, b)| Json::Arr(vec![Json::Str(a.clone()), Json::Str(b.clone())]))
                    .collect(),
            ),
        ),
        (
            "counters",
            Json::obj(vec![
                ("node", num(plan.node_count)),
                ("folded", num(plan.folded_count)),
                ("elided", num(plan.elided_count)),
                ("packed", num(plan.packed_count)),
                ("quant", num(plan.quant_count)),
                ("fused", num(plan.fused_count)),
                ("resident_int", num(plan.resident_int_count)),
                ("batch_symbolic", num(plan.batch_symbolic_count)),
            ]),
        ),
        (
            "batch_blockers",
            Json::Arr(plan.batch_blockers.iter().map(|b| Json::Str(b.clone())).collect()),
        ),
    ]);

    let engine_json = engine.map_or(Json::Null, |e| {
        Json::obj(vec![
            ("model", Json::Str(e.model_name.clone())),
            ("input", Json::Str(e.input_name.clone())),
            ("output", Json::Str(e.output_name.clone())),
            ("in_dim", num(e.in_dim)),
            ("out_dim", num(e.out_dim)),
            ("adapter", adapter_json(&e.adapter)),
            ("streamlined", Json::Bool(e.streamlined)),
        ])
    });

    Ok(Json::obj(vec![("plan", plan_json), ("engine", engine_json)]))
}

/// Reinterpret a typed slice as raw bytes (native byte order — the
/// header's endian tag guards cross-machine reads).
fn bytes_of<T: Copy>(v: &[T]) -> &[u8] {
    // SAFETY: `v` is a live, initialized slice of a plain-old-data
    // numeric type (`f32`/`i8`/`i32`/`i64` at the call sites); every
    // byte of such values is initialized, the cast only narrows the
    // element type, and `size_of_val` gives the exact byte extent.
    unsafe { std::slice::from_raw_parts(v.as_ptr().cast::<u8>(), std::mem::size_of_val(v)) }
}

/// Serialize `plan` (with its source `graph`, and optionally the serving
/// metadata of the engine wrapping it) into a sectioned `.qpln` artifact
/// at `path`. See the crate-level "Artifact format" docs for the layout.
pub fn write_artifact(
    plan: &ExecutionPlan<'_>,
    graph: &ModelGraph,
    engine: Option<&EngineMeta>,
    path: &Path,
) -> Result<()> {
    let isa = active_isa().name();
    let mut blobs = Blobs::default();
    let meta = plan_meta_json(plan, engine, isa, &mut blobs)?.to_string();
    let graph_json = model_to_json(graph);

    let payloads: Vec<(u32, &[u8])> = vec![
        (SEC_META, meta.as_bytes()),
        (SEC_GRAPH, graph_json.as_bytes()),
        (SEC_F32, bytes_of(&blobs.f32v)),
        (SEC_I8, bytes_of(&blobs.i8v)),
        (SEC_I32, bytes_of(&blobs.i32v)),
        (SEC_I64, bytes_of(&blobs.i64v)),
    ];

    let mut out = encode_header(payloads.len() as u32, isa);
    out.resize(HEADER_LEN + payloads.len() * ENTRY_LEN, 0);
    let mut entries = Vec::with_capacity(payloads.len());
    for (id, p) in &payloads {
        out.resize(out.len() + pad_to_align(out.len()), 0);
        let offset = out.len() as u64;
        out.extend_from_slice(p);
        entries.push(SectionEntry { id: *id, offset, len: p.len() as u64, crc: crc32(p) });
    }
    for (i, e) in entries.iter().enumerate() {
        let at = HEADER_LEN + i * ENTRY_LEN;
        out[at..at + ENTRY_LEN].copy_from_slice(&encode_entry(e));
    }
    std::fs::write(path, &out)?;
    Ok(())
}

//! Plan compilation: topo-freeze, constant folding, identity elision,
//! last-use analysis, and linear-scan slot assignment.
//!
//! Compilation performs **no tensor copies**: initializers are borrowed
//! from the source graph, and only compile-time-folded results (e.g.
//! quantized weights) allocate new `Arc`-held tensors — once, not per run.

use super::arena::SlotArena;
use super::kernel::CompiledKernel;
use super::{ExecutionPlan, PlanConst, PlanInput, PlanOptions, PlanOutput, Preload, Step};
use crate::ir::{ModelGraph, DOMAIN_FINN, DOMAIN_QONNX};
use crate::ops;
use crate::tensor::Tensor;
use anyhow::{bail, Context, Result};
use std::borrow::Cow;
use std::collections::BTreeMap;
use std::sync::Arc;

const UNASSIGNED: u32 = u32::MAX;

/// Where a runtime value comes from.
#[derive(Clone, Copy)]
enum Def {
    Preload(usize),
    Input(usize),
    Step,
}

/// Per-value lifetime record for the linear scan.
struct VInfo {
    def: Def,
    /// Step index of the final read, if any.
    last_use: Option<usize>,
    /// Graph outputs are never released.
    persist: bool,
    slot: u32,
}

struct StepBuild {
    node_idx: usize,
    f: ops::OpFn,
    in_vals: Vec<usize>,
    out_vals: Vec<usize>,
}

/// Resolve an identity-elided name to its canonical runtime name.
fn canon<'g>(alias: &BTreeMap<&'g str, &'g str>, name: &'g str) -> &'g str {
    alias.get(name).copied().unwrap_or(name)
}

/// Materialize a constant as a runtime preload value on first use.
fn intern_const<'g>(
    name: &'g str,
    cv: PlanConst<'g>,
    persist: bool,
    values: &mut Vec<VInfo>,
    preloads: &mut Vec<(String, PlanConst<'g>)>,
    by_name: &mut BTreeMap<&'g str, usize>,
) -> usize {
    let vid = values.len();
    values.push(VInfo { def: Def::Preload(preloads.len()), last_use: None, persist, slot: UNASSIGNED });
    preloads.push((name.to_string(), cv));
    by_name.insert(name, vid);
    vid
}

pub(super) fn compile<'g>(graph: &'g ModelGraph, opts: &PlanOptions) -> Result<ExecutionPlan<'g>> {
    let order = graph.topo_order()?;

    // ------------------------------------------------------------------
    // Pass 1 — walk the frozen topo order: resolve each node's kernel
    // once, evaluate constant subgraphs now, and elide identities.
    // ------------------------------------------------------------------
    let mut consts: BTreeMap<&'g str, PlanConst<'g>> = BTreeMap::new();
    for (k, t) in &graph.initializers {
        consts.insert(k.as_str(), PlanConst::Borrowed(t));
    }
    let mut alias: BTreeMap<&'g str, &'g str> = BTreeMap::new();
    let mut folded_outputs: Vec<(String, Arc<Tensor>)> = Vec::new();
    let mut alias_outputs: Vec<(String, String)> = Vec::new();
    let mut kept: Vec<(usize, ops::OpFn)> = Vec::new();
    let mut folded_count = 0usize;
    let mut elided_count = 0usize;

    for &i in &order {
        let node = &graph.nodes[i];
        // Same rejection (and precedence) as the interpreter's hot loop.
        if opts.standard_onnx_only && (node.domain == DOMAIN_QONNX || node.domain == DOMAIN_FINN) {
            bail!(
                "node '{}' ({}, domain '{}') is not a standard ONNX op — \
                 this backend only executes the stock operator set",
                node.name,
                node.op_type,
                node.domain
            );
        }
        let f = ops::kernel_for(node)?;
        // Constant folding: every present input (through identity aliases)
        // is a compile-time constant. Covers `Constant` nodes (no inputs)
        // and whole weight-quantization subgraphs.
        let all_const = node.present_inputs().all(|n| consts.contains_key(canon(&alias, n)));
        if all_const {
            let ins: Vec<&Tensor> =
                node.present_inputs().map(|n| consts[canon(&alias, n)].as_tensor()).collect();
            let outs = f(node, &ins)
                .with_context(|| format!("executing node '{}' ({})", node.name, node.op_type))?;
            if outs.len() != node.outputs.len() {
                bail!(
                    "node '{}' produced {} outputs, declared {}",
                    node.name,
                    outs.len(),
                    node.outputs.len()
                );
            }
            drop(ins);
            for (name, t) in node.outputs.iter().zip(outs) {
                let a = Arc::new(t);
                folded_outputs.push((name.clone(), a.clone()));
                consts.insert(name.as_str(), PlanConst::Shared(a));
            }
            folded_count += 1;
            continue;
        }
        // Identity of a runtime value: pure slot alias, no runtime step.
        if node.op_type == "Identity" && node.outputs.len() == 1 {
            let mut present = node.present_inputs();
            if let (Some(src), None) = (present.next(), present.next()) {
                let c = canon(&alias, src);
                alias.insert(node.outputs[0].as_str(), c);
                alias_outputs.push((node.outputs[0].clone(), c.to_string()));
                elided_count += 1;
                continue;
            }
        }
        kept.push((i, f));
    }

    // ------------------------------------------------------------------
    // Pass 2 — build the runtime value graph: resolve every name to a
    // dense value id, recording defs and last uses.
    // ------------------------------------------------------------------
    let mut values: Vec<VInfo> = Vec::new();
    let mut by_name: BTreeMap<&'g str, usize> = BTreeMap::new();
    let mut preload_build: Vec<(String, PlanConst<'g>)> = Vec::new();
    let mut input_records: Vec<PlanInput> = Vec::new();

    for vi in &graph.inputs {
        if graph.initializers.contains_key(&vi.name) {
            continue; // initializer-shadowed input: the constant wins
        }
        let vid = values.len();
        values.push(VInfo {
            def: Def::Input(input_records.len()),
            last_use: None,
            persist: false,
            slot: UNASSIGNED,
        });
        by_name.insert(vi.name.as_str(), vid);
        input_records.push(PlanInput { name: vi.name.clone(), shape: vi.shape.clone(), slot: None });
    }

    let mut steps_build: Vec<StepBuild> = Vec::with_capacity(kept.len());
    for (node_idx, f) in kept {
        let step_idx = steps_build.len();
        let node = &graph.nodes[node_idx];
        let mut in_vals = Vec::with_capacity(node.inputs.len());
        for raw in node.present_inputs() {
            let name = canon(&alias, raw);
            let vid = match by_name.get(name) {
                Some(&v) => v,
                None => match consts.get(name).cloned() {
                    Some(cv) => intern_const(
                        name,
                        cv,
                        false,
                        &mut values,
                        &mut preload_build,
                        &mut by_name,
                    ),
                    None => bail!("node '{}' input '{raw}' not computed", node.name),
                },
            };
            values[vid].last_use = Some(step_idx);
            in_vals.push(vid);
        }
        let mut out_vals = Vec::with_capacity(node.outputs.len());
        for out in &node.outputs {
            let vid = values.len();
            values.push(VInfo { def: Def::Step, last_use: None, persist: false, slot: UNASSIGNED });
            by_name.insert(out.as_str(), vid);
            out_vals.push(vid);
        }
        steps_build.push(StepBuild { node_idx, f, in_vals, out_vals });
    }

    let mut output_build: Vec<(String, usize)> = Vec::with_capacity(graph.outputs.len());
    for vi in &graph.outputs {
        let name = canon(&alias, vi.name.as_str());
        let vid = match by_name.get(name) {
            Some(&v) => v,
            None => match consts.get(name).cloned() {
                // fully-folded output: resident constant, extracted per run
                Some(cv) => {
                    intern_const(name, cv, true, &mut values, &mut preload_build, &mut by_name)
                }
                None => bail!("graph output '{}' was not produced", vi.name),
            },
        };
        values[vid].persist = true;
        output_build.push((vi.name.clone(), vid));
    }

    // ------------------------------------------------------------------
    // Pass 3 — linear-scan slot assignment over the step timeline.
    // Values dying at step s are released (and recyclable) before step
    // s's outputs are allocated; dead outputs get no slot at all.
    // ------------------------------------------------------------------
    let mut deaths: Vec<Vec<usize>> = vec![Vec::new(); steps_build.len()];
    for (vid, v) in values.iter().enumerate() {
        if v.persist {
            continue;
        }
        if let Some(s) = v.last_use {
            deaths[s].push(vid);
        }
    }
    let mut arena = SlotArena::new();
    for v in values.iter_mut() {
        if matches!(v.def, Def::Step) {
            continue;
        }
        if v.persist || v.last_use.is_some() {
            v.slot = arena.alloc();
        }
    }
    let mut release_at: Vec<Vec<u32>> = vec![Vec::new(); steps_build.len()];
    for s in 0..steps_build.len() {
        for &vid in &deaths[s] {
            let slot = values[vid].slot;
            if slot != UNASSIGNED {
                arena.release(slot);
                release_at[s].push(slot);
            }
        }
        for &vid in &steps_build[s].out_vals {
            let v = &mut values[vid];
            if v.persist || v.last_use.is_some() {
                v.slot = arena.alloc();
            }
        }
    }

    // ------------------------------------------------------------------
    // Assemble.
    // ------------------------------------------------------------------
    let mut preload_slot = vec![UNASSIGNED; preload_build.len()];
    let mut input_slot = vec![UNASSIGNED; input_records.len()];
    for v in &values {
        match v.def {
            Def::Preload(i) => preload_slot[i] = v.slot,
            Def::Input(i) => input_slot[i] = v.slot,
            Def::Step => {}
        }
    }
    for (rec, &sl) in input_records.iter_mut().zip(&input_slot) {
        rec.slot = if sl == UNASSIGNED { None } else { Some(sl) };
    }
    let preloads: Vec<Preload<'g>> = preload_build
        .into_iter()
        .zip(preload_slot)
        .map(|((name, value), slot)| Preload { name, slot, value })
        .collect();

    let mut steps: Vec<Step> = Vec::with_capacity(steps_build.len());
    for (s, sb) in steps_build.into_iter().enumerate() {
        steps.push(Step {
            node_idx: sb.node_idx,
            kernel: CompiledKernel::Op(sb.f),
            inputs: sb.in_vals.iter().map(|&v| values[v].slot).collect(),
            outputs: sb
                .out_vals
                .iter()
                .map(|&v| {
                    let sl = values[v].slot;
                    if sl == UNASSIGNED {
                        None
                    } else {
                        Some(sl)
                    }
                })
                .collect(),
            release: std::mem::take(&mut release_at[s]),
        });
    }

    let outputs: Vec<PlanOutput> = output_build
        .into_iter()
        .map(|(name, vid)| PlanOutput { name, slot: values[vid].slot })
        .collect();

    Ok(ExecutionPlan {
        name: graph.name.clone(),
        nodes: Cow::Borrowed(graph.nodes.as_slice()),
        steps,
        preloads,
        inputs: input_records,
        outputs,
        slot_count: arena.capacity(),
        folded_outputs,
        alias_outputs,
        node_count: graph.nodes.len(),
        folded_count,
        elided_count,
    })
}

#[cfg(test)]
mod tests {
    use super::super::ExecutionPlan;
    use crate::ir::GraphBuilder;

    #[test]
    fn standard_only_rejects_at_compile_time() {
        let mut b = GraphBuilder::new("q");
        b.input("x", vec![1, 4]);
        b.quant("x", "y", 0.5, 0.0, 4.0, false, false, "ROUND");
        b.output("y", vec![1, 4]);
        let g = b.finish().unwrap();
        let opts = super::PlanOptions { standard_onnx_only: true };
        let err = ExecutionPlan::compile_with(&g, &opts).unwrap_err();
        assert!(err.to_string().contains("not a standard ONNX op"));
    }

    #[test]
    fn unknown_op_rejected_with_node_context() {
        let mut b = GraphBuilder::new("u");
        b.input("x", vec![1]);
        b.node("TotallyUnknown", &["x"], &["y"], &[]);
        b.output("y", vec![1]);
        let g = b.finish().unwrap();
        let err = ExecutionPlan::compile(&g).unwrap_err().to_string();
        assert!(err.contains("no implementation for op 'TotallyUnknown'"), "{err}");
    }

    #[test]
    fn dangling_input_rejected() {
        // bypass the builder's validate(): build the graph directly
        let mut g = crate::ir::ModelGraph::new("dangle");
        g.inputs.push(crate::ir::ValueInfo::new("x", vec![1]));
        g.outputs.push(crate::ir::ValueInfo::new("y", vec![1]));
        g.nodes.push(crate::ir::Node::new("Relu", &["nope"], &["y"]).with_name("r"));
        let err = ExecutionPlan::compile(&g).unwrap_err().to_string();
        assert!(err.contains("input 'nope' not computed"), "{err}");
    }
}

//! Plan compilation: topo-freeze, constant folding, identity elision,
//! kernel specialization (weight packing + epilogue fusion), the
//! batch-symbolic reshape rewrite, integer-residency planning, last-use
//! analysis, and dtype-aware linear-scan slot assignment.
//!
//! Compilation performs **no per-run tensor copies**: initializers are
//! borrowed from the source graph, and only compile-time-folded results
//! (e.g. quantized weights) and packed kernel state (transposed,
//! panel-packed weight matrices) allocate — once, not per run.
//!
//! Kernel specialization runs between folding and slot assignment: any
//! `Conv`/`Gemm`/`MatMul` whose weight operands are compile-time
//! constants is lowered to a prepacked kernel
//! ([`super::kernel::PackedConv`] & co.), and a packed conv/gemm/matmul
//! whose output feeds a *sole* elementwise consumer with constant
//! parameters (BatchNorm / Quant / BipolarQuant / Relu) absorbs that
//! consumer into its epilogue — the consumer's step disappears from the
//! schedule entirely.
//!
//! Above the float tier, the **quantized tier** is tried first: when the
//! value-range proofs from [`crate::transforms::infer_ranges`] show the
//! data input on a literal integer grid, the weights fit `i8`, and every
//! accumulator stays below `2^24`, the node lowers to an integer-domain
//! kernel ([`super::qkernel`]) and a sole-consumer `MultiThreshold` with
//! constant integer thresholds fuses into its scatter loop. This is the
//! execution tier the [`crate::streamline`] pass targets; graphs without
//! integer proofs are untouched by it.
//!
//! The **batch-symbolic pass** runs in the same walk: `Reshape` nodes
//! whose constant targets bake the declared batch of 1 into their
//! leading dim (conv-net flatten chains) become batch-preserving
//! [`super::kernel::BatchReshape`] kernels, so one plan natively serves
//! `[n, c, h, w]` batches with no per-sample loop at the engine edge.
//! (`Flatten` with the default `axis = 1` is already batch-preserving
//! and needs no rewrite.) All other kernels — packed conv/matmul, pools,
//! elementwise — iterate over the leading dim anyway, against the same
//! packed weights.
//!
//! The **integer-residency pass** (pass 1.75, [`plan_residency`]) then
//! negotiates each runtime value's *container*: a backward walk collects
//! which values some consumer forces to stay `f32` (graph outputs,
//! inputs of kernels with no integer path), and a forward walk lets
//! every integer-capable producer — quantized kernels, the standalone
//! [`super::qkernel::ThresholdKernel`] this pass installs, and the
//! dtype-polymorphic pass-through ops (`Reshape`/`Flatten`/`Squeeze`/
//! `Unsqueeze`/`MaxPool`/`Relu`) — emit the narrowest container its
//! consumers accept (`i8` when the proven levels fit, `i32` for
//! accumulator-domain edges, `f32` otherwise). Conversions therefore
//! happen only at tier boundaries, *inside* the boundary kernels: the
//! input `MultiThreshold` ingests the f32 graph edge, and a quantized
//! kernel whose consumer needs floats (the residual de-scale `Mul`, a
//! graph output, any float-tier neighbor) writes f32 in its scatter
//! loop. Slot assignment is then dtype-keyed, so the plan's slot-dtype
//! table is a static fact of the schedule.

use super::arena::SlotArena;
use super::kernel::{BatchReshape, CompiledKernel, Epilogue, PackedConv, PackedGemm, PackedMatMul};
use super::qkernel::{QThreshold, QuantConv, QuantGemm, QuantMatMul, ThresholdKernel};
use super::{ExecutionPlan, PlanConst, PlanInput, PlanOptions, PlanOutput, Preload, Step};
use crate::ir::{ModelGraph, Node, DOMAIN_FINN, DOMAIN_QONNX};
use crate::ops;
use crate::tensor::{DType, Tensor};
use crate::transforms::{infer_ranges, ValueRange};
use anyhow::{bail, Context, Result};
use std::borrow::Cow;
use std::collections::{BTreeMap, BTreeSet};
use std::sync::Arc;

const UNASSIGNED: u32 = u32::MAX;

/// Where a runtime value comes from.
#[derive(Clone, Copy)]
enum Def {
    Preload(usize),
    Input(usize),
    Step,
}

/// Per-value lifetime record for the linear scan.
struct VInfo {
    def: Def,
    /// Canonical tensor name (slot-table reporting).
    name: String,
    /// Container the value lives in (residency pass; `F32` default).
    dtype: DType,
    /// Step index of the final read, if any.
    last_use: Option<usize>,
    /// Graph outputs are never released.
    persist: bool,
    slot: u32,
}

/// One runtime step after specialization, before value numbering.
struct StepSpec<'g> {
    /// Node the kernel was compiled from (error context).
    node_idx: usize,
    /// Node whose outputs the step produces (last fused node, or
    /// `node_idx` when nothing was fused).
    out_node_idx: usize,
    kernel: CompiledKernel,
    /// Canonical names of the step's *runtime* inputs (packed kernels
    /// carry their constant operands internally).
    in_names: Vec<&'g str>,
}

struct StepBuild {
    node_idx: usize,
    out_node_idx: usize,
    kernel: CompiledKernel,
    in_vals: Vec<usize>,
    out_vals: Vec<usize>,
}

/// Resolve an identity-elided name to its canonical runtime name.
fn canon<'g>(alias: &BTreeMap<&'g str, &'g str>, name: &'g str) -> &'g str {
    alias.get(name).copied().unwrap_or(name)
}

/// Compile-time constant for `raw` (through identity aliases), if any.
/// `raw`'s lifetime is deliberately independent of the returned borrow so
/// callers can pass short-lived name slices (the epilogue-fusion closure
/// is higher-ranked over its input lifetime).
fn lookup<'a, 'g>(
    consts: &'a BTreeMap<&'g str, PlanConst<'g>>,
    alias: &'a BTreeMap<&'g str, &'g str>,
    raw: &str,
) -> Option<&'a Tensor> {
    let nm: &str = alias.get(raw).copied().unwrap_or(raw);
    consts.get(nm).map(|c| c.as_tensor())
}

/// Materialize a constant as a runtime preload value on first use.
fn intern_const<'g>(
    name: &'g str,
    cv: PlanConst<'g>,
    persist: bool,
    values: &mut Vec<VInfo>,
    preloads: &mut Vec<(String, PlanConst<'g>)>,
    by_name: &mut BTreeMap<&'g str, usize>,
) -> usize {
    let vid = values.len();
    values.push(VInfo {
        def: Def::Preload(preloads.len()),
        name: name.to_string(),
        dtype: cv.as_tensor().dtype(),
        last_use: None,
        persist,
        slot: UNASSIGNED,
    });
    preloads.push((name.to_string(), cv));
    by_name.insert(name, vid);
    vid
}

/// Try to lower a conv node with constant weights into a packed kernel.
fn spec_conv<'g>(
    node: &'g Node,
    consts: &BTreeMap<&'g str, PlanConst<'g>>,
    alias: &BTreeMap<&'g str, &'g str>,
) -> Option<(PackedConv, Vec<&'g str>)> {
    if node.inputs.len() < 2 || node.inputs[0].is_empty() || node.inputs[1].is_empty() {
        return None;
    }
    let w = lookup(consts, alias, node.inputs[1].as_str())?;
    let bias = match node.inputs.get(2).map(String::as_str).filter(|s| !s.is_empty()) {
        None => None,
        // a *runtime* bias declines packing (rare; generic path handles it)
        Some(nm) => Some(lookup(consts, alias, nm)?),
    };
    let pc = PackedConv::try_build(node, w, bias)?;
    Some((pc, vec![canon(alias, node.inputs[0].as_str())]))
}

/// Try to lower a Gemm node with a constant B into a packed kernel.
fn spec_gemm<'g>(
    node: &'g Node,
    consts: &BTreeMap<&'g str, PlanConst<'g>>,
    alias: &BTreeMap<&'g str, &'g str>,
) -> Option<(PackedGemm, Vec<&'g str>)> {
    if node.inputs.len() < 2 || node.inputs[0].is_empty() || node.inputs[1].is_empty() {
        return None;
    }
    let b = lookup(consts, alias, node.inputs[1].as_str())?;
    let c_name = node.inputs.get(2).map(String::as_str).filter(|s| !s.is_empty());
    let c_arg = c_name.map(|nm| lookup(consts, alias, nm));
    let pg = PackedGemm::try_build(node, b, c_arg)?;
    let mut ins = vec![canon(alias, node.inputs[0].as_str())];
    if matches!(c_arg, Some(None)) {
        // constant-B, runtime-C: C stays a runtime input
        ins.push(canon(alias, c_name.unwrap()));
    }
    Some((pg, ins))
}

/// Outcome of the batch-symbolic pass for one `Reshape` node.
enum ReshapeSpec<'g> {
    /// Rewritten into a batch-preserving kernel.
    Rewrite(BatchReshape, Vec<&'g str>),
    /// Already batch-safe (runtime target, `0`/`-1` leading dim, ...):
    /// runs generic, no batching concern.
    Neutral,
    /// The constant target *defeats* batching — the node runs generic at
    /// declared shapes, but the plan can never serve a larger leading
    /// batch. Engines that promise batched serving fail construction on
    /// these (see [`super::ExecutionPlan::batch_blockers`]).
    Blocked(String),
}

/// The batch-symbolic pass: try to rewrite a `Reshape` whose constant
/// target bakes the declared batch of 1 into its leading dim (the
/// conv-net flatten chain, e.g. CNV's `[1, 256]` — or `[1, -1]` for the
/// cleaned raw export) into a batch-preserving [`BatchReshape`] kernel.
///
/// Plain targets get the *fallback* kernel (original target tried first,
/// so anything the unrewritten plan accepted is byte-identical; larger
/// batches take the `[0, …]` copy-dim form). Targets containing a `-1`
/// wildcard resolve against any element count, so they are rewritten
/// unconditionally — but only when the graph's shape annotations prove
/// the data input's leading dim is 1 at declared shapes (`cleanup` /
/// `infer_shapes` provides these); otherwise the node stays generic
/// *and* is reported as a batchability blocker.
fn spec_batch_reshape<'g>(
    graph: &'g ModelGraph,
    node: &'g Node,
    consts: &BTreeMap<&'g str, PlanConst<'g>>,
    alias: &BTreeMap<&'g str, &'g str>,
) -> ReshapeSpec<'g> {
    if node.inputs.len() != 2 || node.inputs[0].is_empty() || node.inputs[1].is_empty() {
        return ReshapeSpec::Neutral;
    }
    // runtime targets (Shape->...->Concat chains) read the live batch and
    // adapt on their own; only *constant* targets can bake a batch in
    let Some(target) = lookup(consts, alias, node.inputs[1].as_str()) else {
        return ReshapeSpec::Neutral;
    };
    if !target.is_i64() || target.rank() != 1 {
        return ReshapeSpec::Neutral;
    }
    let Ok(dims) = target.as_i64() else {
        return ReshapeSpec::Neutral;
    };
    if dims.first().copied().unwrap_or(0) > 1 {
        return ReshapeSpec::Blocked(format!(
            "constant target {dims:?} bakes batch {} (> 1) into its leading dim",
            dims[0]
        ));
    }
    if dims.first() != Some(&1) {
        return ReshapeSpec::Neutral; // 0 / -1 leading dims are batch-safe
    }
    if dims.len() < 2 {
        return ReshapeSpec::Blocked(format!(
            "constant target {dims:?} collapses the batch dim entirely"
        ));
    }
    // positional copy-dims interact with the rewritten leading 0; decline
    if dims[1..].contains(&0) {
        return ReshapeSpec::Blocked(format!(
            "constant target {dims:?} mixes a baked batch 1 with positional copy-dims"
        ));
    }
    let has_wildcard = dims[1..].contains(&-1);
    if has_wildcard {
        // `[1, -1]` swallows any batch silently — rewrite only when the
        // input is provably batch-1-leading, where both forms agree
        let proven = graph
            .tensor_shape(node.inputs[0].as_str())
            .is_some_and(|s| s.first() == Some(&1));
        if !proven {
            return ReshapeSpec::Blocked(format!(
                "wildcard target {dims:?} bakes batch 1 but the input shape is not \
                 inferred (run `cleanup` first)"
            ));
        }
    }
    ReshapeSpec::Rewrite(
        BatchReshape::new(dims, !has_wildcard),
        vec![canon(alias, node.inputs[0].as_str())],
    )
}

/// Quantized-tier conv: constant `i8`-grid weights + a proven integral
/// input range.
fn spec_qconv<'g>(
    node: &'g Node,
    consts: &BTreeMap<&'g str, PlanConst<'g>>,
    alias: &BTreeMap<&'g str, &'g str>,
    ranges: &BTreeMap<String, ValueRange>,
) -> Option<(QuantConv, Vec<&'g str>)> {
    if node.inputs.len() < 2 || node.inputs[0].is_empty() || node.inputs[1].is_empty() {
        return None;
    }
    let w = lookup(consts, alias, node.inputs[1].as_str())?;
    let r = *ranges.get(node.inputs[0].as_str())?;
    let qc = QuantConv::try_build(node, w, r)?;
    Some((qc, vec![canon(alias, node.inputs[0].as_str())]))
}

/// Quantized-tier Gemm (constant integral B, no runtime C).
fn spec_qgemm<'g>(
    node: &'g Node,
    consts: &BTreeMap<&'g str, PlanConst<'g>>,
    alias: &BTreeMap<&'g str, &'g str>,
    ranges: &BTreeMap<String, ValueRange>,
) -> Option<(QuantGemm, Vec<&'g str>)> {
    if node.inputs.len() < 2 || node.inputs[0].is_empty() || node.inputs[1].is_empty() {
        return None;
    }
    let b = lookup(consts, alias, node.inputs[1].as_str())?;
    let c_name = node.inputs.get(2).map(String::as_str).filter(|s| !s.is_empty());
    let c_arg = c_name.map(|nm| lookup(consts, alias, nm));
    let r = *ranges.get(node.inputs[0].as_str())?;
    let qg = QuantGemm::try_build(node, b, c_arg, r)?;
    Some((qg, vec![canon(alias, node.inputs[0].as_str())]))
}

/// Quantized-tier MatMul (constant integral rhs).
fn spec_qmatmul<'g>(
    node: &'g Node,
    consts: &BTreeMap<&'g str, PlanConst<'g>>,
    alias: &BTreeMap<&'g str, &'g str>,
    ranges: &BTreeMap<String, ValueRange>,
) -> Option<(QuantMatMul, Vec<&'g str>)> {
    if node.inputs.len() != 2 || node.inputs[0].is_empty() || node.inputs[1].is_empty() {
        return None;
    }
    let b = lookup(consts, alias, node.inputs[1].as_str())?;
    let r = *ranges.get(node.inputs[0].as_str())?;
    let qm = QuantMatMul::try_build(b, r)?;
    Some((qm, vec![canon(alias, node.inputs[0].as_str())]))
}

/// Shared context for the sole-consumer epilogue fusion walks.
struct FuseCtx<'a, 'g> {
    graph: &'g ModelGraph,
    kept: &'a [(usize, ops::OpFn)],
    uses: &'a BTreeMap<&'g str, usize>,
    users: &'a BTreeMap<&'g str, Vec<usize>>,
    out_set: &'a BTreeSet<&'g str>,
    consts: &'a BTreeMap<&'g str, PlanConst<'g>>,
    alias: &'a BTreeMap<&'g str, &'g str>,
}

impl<'g> FuseCtx<'_, 'g> {
    /// The sole runtime consumer of `node_idx`'s single output, when that
    /// value is internal (not a graph output), used exactly once, the
    /// consumer appears later in the schedule, isn't already fused, and
    /// reads the value as its *data* (first) input.
    fn sole_consumer(&self, start_ki: usize, node_idx: usize, consumed: &[bool]) -> Option<usize> {
        let tail = &self.graph.nodes[node_idx];
        if tail.outputs.len() != 1 {
            return None;
        }
        let out_nm = canon(self.alias, tail.outputs[0].as_str());
        if self.out_set.contains(out_nm) || self.uses.get(out_nm).copied().unwrap_or(0) != 1 {
            return None;
        }
        let uk = match self.users.get(out_nm) {
            Some(v) if v.len() == 1 => v[0],
            _ => return None,
        };
        if consumed[uk] || uk <= start_ki {
            return None;
        }
        let unode = &self.graph.nodes[self.kept[uk].0];
        if unode.inputs.first().map(|s| canon(self.alias, s.as_str())) != Some(out_nm) {
            return None;
        }
        Some(uk)
    }

    /// Walk the sole-consumer chain from `start_node_idx` collecting
    /// fusable float epilogue stages. Returns the stages, the kept
    /// indices they came from (for the caller to mark consumed), and the
    /// node whose outputs the fused step now produces.
    fn float_epilogues(
        &self,
        start_ki: usize,
        start_node_idx: usize,
        out_channels: usize,
        allow_channelwise: bool,
        consumed: &[bool],
    ) -> (Vec<Epilogue>, Vec<usize>, usize) {
        let mut eps = Vec::new();
        let mut fused_kept = Vec::new();
        let mut out_node_idx = start_node_idx;
        loop {
            let Some(uk) = self.sole_consumer(start_ki, out_node_idx, consumed) else {
                break;
            };
            let unode = &self.graph.nodes[self.kept[uk].0];
            let ep = Epilogue::try_build(
                unode,
                |nm| lookup(self.consts, self.alias, nm),
                out_channels,
            );
            let ep = match ep {
                Some(e) if allow_channelwise || e.channel_independent() => e,
                _ => break,
            };
            eps.push(ep);
            fused_kept.push(uk);
            out_node_idx = self.kept[uk].0;
        }
        (eps, fused_kept, out_node_idx)
    }

    /// A sole-consumer `MultiThreshold` with constant integer thresholds
    /// (the quantized tier's fused activation). Returns the compiled
    /// stage, the consumer's kept index, and its node index.
    fn mt_epilogue(
        &self,
        start_ki: usize,
        node_idx: usize,
        out_channels: usize,
        consumed: &[bool],
    ) -> Option<(QThreshold, usize, usize)> {
        let uk = self.sole_consumer(start_ki, node_idx, consumed)?;
        let unode = &self.graph.nodes[self.kept[uk].0];
        if unode.op_type != "MultiThreshold" || unode.inputs.len() != 2 {
            return None;
        }
        let th = lookup(self.consts, self.alias, unode.inputs[1].as_str())?;
        let qt = QThreshold::try_build(unode, th, out_channels)?;
        Some((qt, uk, self.kept[uk].0))
    }
}

/// Try to lower a MatMul with a constant rhs into a packed kernel.
fn spec_matmul<'g>(
    node: &'g Node,
    consts: &BTreeMap<&'g str, PlanConst<'g>>,
    alias: &BTreeMap<&'g str, &'g str>,
) -> Option<(PackedMatMul, Vec<&'g str>)> {
    if node.inputs.len() != 2 || node.inputs[0].is_empty() || node.inputs[1].is_empty() {
        return None;
    }
    let b = lookup(consts, alias, node.inputs[1].as_str())?;
    let pm = PackedMatMul::try_build(b)?;
    Some((pm, vec![canon(alias, node.inputs[0].as_str())]))
}

/// Ops with an integer-container implementation that pass their input
/// dtype through unchanged — the structural/monotone interior of a
/// streamlined graph. (`MaxPool` only on the plain NCHW path; the NHWC
/// wrapper transposes through f32.)
pub(crate) fn residency_passthrough(node: &Node) -> bool {
    if node.outputs.len() != 1 {
        return false;
    }
    match node.op_type.as_str() {
        "Reshape" | "Flatten" | "Squeeze" | "Unsqueeze" | "Relu" => true,
        // a fully-padded window would need f32's -inf, so integer
        // containers are only routed through pools that can't have one
        "MaxPool" => {
            node.attr_str_or("data_layout", "NCHW") == "NCHW"
                && crate::ops::pool::max_pool_windows_nonempty(node)
        }
        _ => false,
    }
}

/// Pass 1.75 — integer-residency planning.
///
/// Backward walk: collect the values some consumer forces to stay `f32`
/// (graph outputs; every input of a kernel with no integer path;
/// transitively, the inputs of pass-through ops whose output must be
/// f32). Forward walk: let each integer-capable producer emit the
/// narrowest container its consumers accept, recording the decision in
/// `val_dtype` and configuring the kernels (`set_out_dtype`); standalone
/// constant-threshold `MultiThreshold` steps are specialized into
/// [`ThresholdKernel`]s here, which is what turns the graph-input edge
/// into the tier's single f32→int conversion point. Returns the number of
/// integer-resident runtime values.
fn plan_residency<'g>(
    graph: &'g ModelGraph,
    specs: &mut [StepSpec<'g>],
    consts: &BTreeMap<&'g str, PlanConst<'g>>,
    alias: &BTreeMap<&'g str, &'g str>,
    out_set: &BTreeSet<&'g str>,
    val_dtype: &mut BTreeMap<&'g str, DType>,
) -> usize {
    // Candidate standalone-MT specializations: generic MultiThreshold
    // steps whose thresholds are compile-time constants (runtime
    // thresholds keep the generic op, which then demands f32 neighbors).
    let mut mt_candidates: BTreeMap<usize, ThresholdKernel> = BTreeMap::new();
    for (si, spec) in specs.iter().enumerate() {
        if !matches!(spec.kernel, CompiledKernel::Op(_)) {
            continue;
        }
        let node = &graph.nodes[spec.node_idx];
        if node.op_type != "MultiThreshold" || spec.in_names.len() != 2 {
            continue;
        }
        let Some(th) = lookup(consts, alias, spec.in_names[1]) else {
            continue;
        };
        if let Some(tk) = ThresholdKernel::try_build(node, th) {
            mt_candidates.insert(si, tk);
        }
    }

    // Backward demand walk (specs are topo-ordered, so reverse order sees
    // every consumer before its producer).
    let mut f32_demand: BTreeSet<&'g str> = out_set.iter().copied().collect();
    for (si, spec) in specs.iter().enumerate().rev() {
        let node = &graph.nodes[spec.node_idx];
        let out_node = &graph.nodes[spec.out_node_idx];
        let out_demanded = out_node.outputs.iter().any(|o| f32_demand.contains(o.as_str()));
        match &spec.kernel {
            // integer-native: accept any container, emit what consumers
            // demand — no constraint propagates upstream
            CompiledKernel::QConv(_) | CompiledKernel::QGemm(_) | CompiledKernel::QMatMul(_) => {}
            // pass-throughs re-emit their input's container, so an f32
            // demand on the output travels to the data input
            CompiledKernel::Reshape(_) => {
                if out_demanded {
                    if let Some(&n0) = spec.in_names.first() {
                        f32_demand.insert(n0);
                    }
                }
            }
            CompiledKernel::Op(_) if mt_candidates.contains_key(&si) => {}
            CompiledKernel::Op(_) if residency_passthrough(node) => {
                if out_demanded {
                    if let Some(&n0) = spec.in_names.first() {
                        f32_demand.insert(n0);
                    }
                }
            }
            // everything else (generic ops, packed float kernels) has no
            // integer path: all runtime inputs must stay f32
            _ => {
                for &n in &spec.in_names {
                    f32_demand.insert(n);
                }
            }
        }
    }

    // Forward resolution: producers emit the narrowest container allowed.
    let mut count = 0usize;
    for (si, spec) in specs.iter_mut().enumerate() {
        let out_node = &graph.nodes[spec.out_node_idx];
        if out_node.outputs.len() != 1 {
            continue; // multi-output steps are generic (f32) by the walk above
        }
        let out_name: &'g str = out_node.outputs[0].as_str();
        let demanded = f32_demand.contains(out_name);
        let in0: DType = spec
            .in_names
            .first()
            .map(|&n| {
                val_dtype
                    .get(n)
                    .copied()
                    .or_else(|| lookup(consts, alias, n).map(Tensor::dtype))
                    .unwrap_or(DType::F32)
            })
            .unwrap_or(DType::F32);
        // standalone MT: specialize whenever it must ingest an integer
        // container or may emit one (otherwise the generic op is fine)
        if let Some(mut tk) = mt_candidates.remove(&si) {
            let dt = if demanded { DType::F32 } else { tk.preferred_out_dtype() };
            if in0 != DType::F32 || dt != DType::F32 {
                tk.set_out_dtype(dt);
                spec.kernel = CompiledKernel::Threshold(Arc::new(tk));
                // thresholds are baked into the kernel; only the data
                // tensor remains a runtime input
                spec.in_names.truncate(1);
                if dt != DType::F32 {
                    val_dtype.insert(out_name, dt);
                    count += 1;
                }
            }
            continue;
        }
        let node_idx = spec.node_idx;
        let chosen = match &mut spec.kernel {
            CompiledKernel::QConv(qc) => {
                let k = Arc::get_mut(qc).expect("plan kernels are unshared during compile");
                let dt = if demanded { DType::F32 } else { k.preferred_out_dtype() };
                k.set_out_dtype(dt);
                dt
            }
            CompiledKernel::QGemm(qg) => {
                let k = Arc::get_mut(qg).expect("plan kernels are unshared during compile");
                let dt = if demanded { DType::F32 } else { k.preferred_out_dtype() };
                k.set_out_dtype(dt);
                dt
            }
            CompiledKernel::QMatMul(qm) => {
                let k = Arc::get_mut(qm).expect("plan kernels are unshared during compile");
                let dt = if demanded { DType::F32 } else { k.preferred_out_dtype() };
                k.set_out_dtype(dt);
                dt
            }
            CompiledKernel::Reshape(_) => in0,
            CompiledKernel::Op(_) if residency_passthrough(&graph.nodes[node_idx]) => in0,
            _ => DType::F32,
        };
        if chosen != DType::F32 {
            val_dtype.insert(out_name, chosen);
            count += 1;
        }
    }
    count
}

pub(super) fn compile<'g>(graph: &'g ModelGraph, opts: &PlanOptions) -> Result<ExecutionPlan<'g>> {
    let order = graph.topo_order()?;

    // ------------------------------------------------------------------
    // Pass 1 — walk the frozen topo order: resolve each node's kernel
    // once, evaluate constant subgraphs now, and elide identities.
    // ------------------------------------------------------------------
    let mut consts: BTreeMap<&'g str, PlanConst<'g>> = BTreeMap::new();
    for (k, t) in &graph.initializers {
        consts.insert(k.as_str(), PlanConst::Borrowed(t));
    }
    let mut alias: BTreeMap<&'g str, &'g str> = BTreeMap::new();
    let mut folded_outputs: Vec<(String, Arc<Tensor>)> = Vec::new();
    let mut alias_outputs: Vec<(String, String)> = Vec::new();
    let mut kept: Vec<(usize, ops::OpFn)> = Vec::new();
    let mut folded_count = 0usize;
    let mut elided_count = 0usize;

    for &i in &order {
        let node = &graph.nodes[i];
        // Same rejection (and precedence) as the interpreter's hot loop.
        if opts.standard_onnx_only && (node.domain == DOMAIN_QONNX || node.domain == DOMAIN_FINN) {
            bail!(
                "node '{}' ({}, domain '{}') is not a standard ONNX op — \
                 this backend only executes the stock operator set",
                node.name,
                node.op_type,
                node.domain
            );
        }
        let f = ops::kernel_for(node)?;
        // Constant folding: every present input (through identity aliases)
        // is a compile-time constant. Covers `Constant` nodes (no inputs)
        // and whole weight-quantization subgraphs.
        let all_const = node.present_inputs().all(|n| consts.contains_key(canon(&alias, n)));
        if all_const {
            let ins: Vec<&Tensor> =
                node.present_inputs().map(|n| consts[canon(&alias, n)].as_tensor()).collect();
            let outs = f(node, &ins)
                .with_context(|| format!("executing node '{}' ({})", node.name, node.op_type))?;
            if outs.len() != node.outputs.len() {
                bail!(
                    "node '{}' produced {} outputs, declared {}",
                    node.name,
                    outs.len(),
                    node.outputs.len()
                );
            }
            drop(ins);
            for (name, t) in node.outputs.iter().zip(outs) {
                let a = Arc::new(t);
                folded_outputs.push((name.clone(), a.clone()));
                consts.insert(name.as_str(), PlanConst::Shared(a));
            }
            folded_count += 1;
            continue;
        }
        // Identity of a runtime value: pure slot alias, no runtime step.
        if node.op_type == "Identity" && node.outputs.len() == 1 {
            let mut present = node.present_inputs();
            if let (Some(src), None) = (present.next(), present.next()) {
                let c = canon(&alias, src);
                alias.insert(node.outputs[0].as_str(), c);
                alias_outputs.push((node.outputs[0].clone(), c.to_string()));
                elided_count += 1;
                continue;
            }
        }
        kept.push((i, f));
    }

    // ------------------------------------------------------------------
    // Pass 1.5 — kernel specialization and epilogue fusion. Nodes whose
    // weight operands are constants become prepacked kernels; a packed
    // conv absorbs a chain of sole-consumer elementwise stages.
    // ------------------------------------------------------------------
    // use counts over canonical names: runtime consumers + graph outputs
    let mut uses: BTreeMap<&'g str, usize> = BTreeMap::new();
    let mut users: BTreeMap<&'g str, Vec<usize>> = BTreeMap::new();
    for (ki, &(ni, _)) in kept.iter().enumerate() {
        for raw in graph.nodes[ni].present_inputs() {
            let nm = canon(&alias, raw);
            *uses.entry(nm).or_insert(0) += 1;
            users.entry(nm).or_default().push(ki);
        }
    }
    let out_set: BTreeSet<&'g str> =
        graph.outputs.iter().map(|vi| canon(&alias, vi.name.as_str())).collect();

    // Value-range proofs for the quantized tier. Computed once per
    // compile; the walk is cheap next to weight packing, and graphs
    // without integer grids simply prove nothing. The quantized tier is
    // a *specialization* — disabling `specialize` (the PR1-style generic
    // baseline) disables it too.
    let quantize = opts.quantize && opts.specialize;
    let ranges: BTreeMap<String, ValueRange> = if quantize {
        infer_ranges(graph).unwrap_or_default()
    } else {
        BTreeMap::new()
    };

    let ctx = FuseCtx {
        graph,
        kept: &kept,
        uses: &uses,
        users: &users,
        out_set: &out_set,
        consts: &consts,
        alias: &alias,
    };
    let mut consumed = vec![false; kept.len()];
    let mut specs: Vec<StepSpec<'g>> = Vec::with_capacity(kept.len());
    let mut packed_count = 0usize;
    let mut quant_count = 0usize;
    let mut fused_count = 0usize;
    let mut batch_symbolic_count = 0usize;
    let mut batch_blockers: Vec<String> = Vec::new();
    for (ki, &(node_idx, f)) in kept.iter().enumerate() {
        if consumed[ki] {
            continue;
        }
        let node = &graph.nodes[node_idx];
        // batch-symbolic pass: independent of `specialize` so even the
        // generic (PR-1-style) plan serves any leading batch
        if opts.batch_symbolic && node.op_type == "Reshape" {
            match spec_batch_reshape(graph, node, &consts, &alias) {
                ReshapeSpec::Rewrite(br, in_names) => {
                    batch_symbolic_count += 1;
                    specs.push(StepSpec {
                        node_idx,
                        out_node_idx: node_idx,
                        kernel: CompiledKernel::Reshape(Arc::new(br)),
                        in_names,
                    });
                    continue;
                }
                ReshapeSpec::Blocked(reason) => {
                    // the node still runs generic at declared shapes; the
                    // plan just can't promise batched serving
                    batch_blockers.push(format!("reshape '{}': {reason}", node.name));
                }
                ReshapeSpec::Neutral => {}
            }
        }
        // quantized tier first: strictly better than the float tier on
        // the (integer-proven) graphs it accepts, and exact on them
        if quantize {
            match node.op_type.as_str() {
                "Conv" => {
                    if let Some((mut qc, in_names)) = spec_qconv(node, &consts, &alias, &ranges) {
                        let mut out_node_idx = node_idx;
                        if opts.fuse_epilogues {
                            if let Some((qt, uk, onx)) =
                                ctx.mt_epilogue(ki, node_idx, qc.out_channels(), &consumed)
                            {
                                qc.set_epilogue(qt);
                                consumed[uk] = true;
                                fused_count += 1;
                                out_node_idx = onx;
                            }
                        }
                        quant_count += 1;
                        specs.push(StepSpec {
                            node_idx,
                            out_node_idx,
                            kernel: CompiledKernel::QConv(Arc::new(qc)),
                            in_names,
                        });
                        continue;
                    }
                }
                "Gemm" => {
                    if let Some((mut qg, in_names)) = spec_qgemm(node, &consts, &alias, &ranges) {
                        let mut out_node_idx = node_idx;
                        if opts.fuse_epilogues {
                            if let Some((qt, uk, onx)) =
                                ctx.mt_epilogue(ki, node_idx, qg.out_channels(), &consumed)
                            {
                                qg.set_epilogue(qt);
                                consumed[uk] = true;
                                fused_count += 1;
                                out_node_idx = onx;
                            }
                        }
                        quant_count += 1;
                        specs.push(StepSpec {
                            node_idx,
                            out_node_idx,
                            kernel: CompiledKernel::QGemm(Arc::new(qg)),
                            in_names,
                        });
                        continue;
                    }
                }
                "MatMul" => {
                    if let Some((mut qm, in_names)) = spec_qmatmul(node, &consts, &alias, &ranges) {
                        let mut out_node_idx = node_idx;
                        // MT fusion only when the output is provably
                        // rank-2: a batched (rank > 2) MatMul output is
                        // rejected by the generic MultiThreshold op, and
                        // fusing would turn that compile-visible fact
                        // into a runtime error on the fused path
                        let rank2 = graph
                            .tensor_shape(node.outputs[0].as_str())
                            .is_some_and(|s| s.len() == 2);
                        if opts.fuse_epilogues && rank2 {
                            if let Some((qt, uk, onx)) =
                                ctx.mt_epilogue(ki, node_idx, qm.out_channels(), &consumed)
                            {
                                qm.set_epilogue(qt);
                                consumed[uk] = true;
                                fused_count += 1;
                                out_node_idx = onx;
                            }
                        }
                        quant_count += 1;
                        specs.push(StepSpec {
                            node_idx,
                            out_node_idx,
                            kernel: CompiledKernel::QMatMul(Arc::new(qm)),
                            in_names,
                        });
                        continue;
                    }
                }
                _ => {}
            }
        }
        if opts.specialize {
            if node.op_type == "Conv" {
                if let Some((mut pc, in_names)) = spec_conv(node, &consts, &alias) {
                    // fuse sole-consumer elementwise chains into the scatter loop
                    let mut out_node_idx = node_idx;
                    if opts.fuse_epilogues {
                        let (eps, fused, onx) =
                            ctx.float_epilogues(ki, node_idx, pc.out_channels(), true, &consumed);
                        for e in eps {
                            pc.push_epilogue(e);
                        }
                        for uk in fused {
                            consumed[uk] = true;
                            fused_count += 1;
                        }
                        out_node_idx = onx;
                    }
                    packed_count += 1;
                    specs.push(StepSpec {
                        node_idx,
                        out_node_idx,
                        kernel: CompiledKernel::Conv(Arc::new(pc)),
                        in_names,
                    });
                    continue;
                }
            } else if node.op_type == "Gemm" {
                if let Some((mut pg, in_names)) = spec_gemm(node, &consts, &alias) {
                    let mut out_node_idx = node_idx;
                    if opts.fuse_epilogues {
                        let (eps, fused, onx) =
                            ctx.float_epilogues(ki, node_idx, pg.out_channels(), true, &consumed);
                        for e in eps {
                            pg.push_epilogue(e);
                        }
                        for uk in fused {
                            consumed[uk] = true;
                            fused_count += 1;
                        }
                        out_node_idx = onx;
                    }
                    packed_count += 1;
                    specs.push(StepSpec {
                        node_idx,
                        out_node_idx,
                        kernel: CompiledKernel::Gemm(Arc::new(pg)),
                        in_names,
                    });
                    continue;
                }
            } else if node.op_type == "MatMul" {
                if let Some((mut pm, in_names)) = spec_matmul(node, &consts, &alias) {
                    // a batched lhs changes the channel axis, so only
                    // channel-independent stages fuse here
                    let mut out_node_idx = node_idx;
                    if opts.fuse_epilogues {
                        let (eps, fused, onx) =
                            ctx.float_epilogues(ki, node_idx, pm.out_channels(), false, &consumed);
                        for e in eps {
                            pm.push_epilogue(e);
                        }
                        for uk in fused {
                            consumed[uk] = true;
                            fused_count += 1;
                        }
                        out_node_idx = onx;
                    }
                    packed_count += 1;
                    specs.push(StepSpec {
                        node_idx,
                        out_node_idx,
                        kernel: CompiledKernel::MatMul(Arc::new(pm)),
                        in_names,
                    });
                    continue;
                }
            }
        }
        specs.push(StepSpec {
            node_idx,
            out_node_idx: node_idx,
            kernel: CompiledKernel::Op(f),
            in_names: node.present_inputs().map(|n| canon(&alias, n)).collect(),
        });
    }

    // ------------------------------------------------------------------
    // Pass 1.75 — integer-residency planning: decide each runtime value's
    // container and configure the producing kernels (see
    // [`plan_residency`]). Rides on the quantized tier's proofs, so it is
    // gated the same way.
    // ------------------------------------------------------------------
    let mut val_dtype: BTreeMap<&'g str, DType> = BTreeMap::new();
    let mut resident_int_count = 0usize;
    if quantize && opts.int_residency {
        resident_int_count =
            plan_residency(graph, &mut specs, &consts, &alias, &out_set, &mut val_dtype);
    }

    // ------------------------------------------------------------------
    // Pass 2 — build the runtime value graph: resolve every name to a
    // dense value id, recording defs and last uses.
    // ------------------------------------------------------------------
    let mut values: Vec<VInfo> = Vec::new();
    let mut by_name: BTreeMap<&'g str, usize> = BTreeMap::new();
    let mut preload_build: Vec<(String, PlanConst<'g>)> = Vec::new();
    let mut input_records: Vec<PlanInput> = Vec::new();

    for vi in &graph.inputs {
        if graph.initializers.contains_key(&vi.name) {
            continue; // initializer-shadowed input: the constant wins
        }
        let vid = values.len();
        values.push(VInfo {
            def: Def::Input(input_records.len()),
            name: vi.name.clone(),
            dtype: DType::F32, // callers bind f32 data at the graph edge
            last_use: None,
            persist: false,
            slot: UNASSIGNED,
        });
        by_name.insert(vi.name.as_str(), vid);
        input_records.push(PlanInput { name: vi.name.clone(), shape: vi.shape.clone(), slot: None });
    }

    let mut steps_build: Vec<StepBuild> = Vec::with_capacity(specs.len());
    for spec in specs {
        let step_idx = steps_build.len();
        let node = &graph.nodes[spec.node_idx];
        let mut in_vals = Vec::with_capacity(spec.in_names.len());
        for name in spec.in_names {
            let vid = match by_name.get(name) {
                Some(&v) => v,
                None => match consts.get(name).cloned() {
                    Some(cv) => intern_const(
                        name,
                        cv,
                        false,
                        &mut values,
                        &mut preload_build,
                        &mut by_name,
                    ),
                    None => bail!("node '{}' input '{name}' not computed", node.name),
                },
            };
            values[vid].last_use = Some(step_idx);
            in_vals.push(vid);
        }
        let out_node = &graph.nodes[spec.out_node_idx];
        let mut out_vals = Vec::with_capacity(out_node.outputs.len());
        for out in &out_node.outputs {
            let vid = values.len();
            values.push(VInfo {
                def: Def::Step,
                name: out.clone(),
                dtype: val_dtype.get(out.as_str()).copied().unwrap_or(DType::F32),
                last_use: None,
                persist: false,
                slot: UNASSIGNED,
            });
            by_name.insert(out.as_str(), vid);
            out_vals.push(vid);
        }
        steps_build.push(StepBuild {
            node_idx: spec.node_idx,
            out_node_idx: spec.out_node_idx,
            kernel: spec.kernel,
            in_vals,
            out_vals,
        });
    }

    let mut output_build: Vec<(String, usize)> = Vec::with_capacity(graph.outputs.len());
    for vi in &graph.outputs {
        let name = canon(&alias, vi.name.as_str());
        let vid = match by_name.get(name) {
            Some(&v) => v,
            None => match consts.get(name).cloned() {
                // fully-folded output: resident constant, extracted per run
                Some(cv) => {
                    intern_const(name, cv, true, &mut values, &mut preload_build, &mut by_name)
                }
                None => bail!("graph output '{}' was not produced", vi.name),
            },
        };
        values[vid].persist = true;
        output_build.push((vi.name.clone(), vid));
    }

    // ------------------------------------------------------------------
    // Pass 3 — linear-scan slot assignment over the step timeline.
    // Values dying at step s are released (and recyclable) before step
    // s's outputs are allocated; dead outputs get no slot at all.
    // ------------------------------------------------------------------
    let mut deaths: Vec<Vec<usize>> = vec![Vec::new(); steps_build.len()];
    for (vid, v) in values.iter().enumerate() {
        if v.persist {
            continue;
        }
        if let Some(s) = v.last_use {
            deaths[s].push(vid);
        }
    }
    let mut arena = SlotArena::new();
    for v in values.iter_mut() {
        if matches!(v.def, Def::Step) {
            continue;
        }
        if v.persist || v.last_use.is_some() {
            v.slot = arena.alloc_dtype(v.dtype);
        }
    }
    let mut release_at: Vec<Vec<u32>> = vec![Vec::new(); steps_build.len()];
    for s in 0..steps_build.len() {
        for &vid in &deaths[s] {
            let slot = values[vid].slot;
            if slot != UNASSIGNED {
                arena.release(slot);
                release_at[s].push(slot);
            }
        }
        for &vid in &steps_build[s].out_vals {
            let v = &mut values[vid];
            if v.persist || v.last_use.is_some() {
                // dtype-keyed: an i8 value can only reuse an i8 slot
                v.slot = arena.alloc_dtype(v.dtype);
            }
        }
    }

    // Slot-dtype table + best-known per-slot footprint (from declared /
    // inferred shapes; `None` where no shape annotation exists).
    let slot_dtypes: Vec<DType> = arena.dtypes().to_vec();
    let mut slot_numel: Vec<Option<usize>> = vec![None; slot_dtypes.len()];
    for v in &values {
        if v.slot == UNASSIGNED {
            continue;
        }
        if let Some(shape) = graph.tensor_shape(&v.name) {
            let n: usize = shape.iter().product();
            let e = &mut slot_numel[v.slot as usize];
            *e = Some(e.map_or(n, |m| m.max(n)));
        }
    }

    // ------------------------------------------------------------------
    // Assemble.
    // ------------------------------------------------------------------
    let mut preload_slot = vec![UNASSIGNED; preload_build.len()];
    let mut input_slot = vec![UNASSIGNED; input_records.len()];
    for v in &values {
        match v.def {
            Def::Preload(i) => preload_slot[i] = v.slot,
            Def::Input(i) => input_slot[i] = v.slot,
            Def::Step => {}
        }
    }
    for (rec, &sl) in input_records.iter_mut().zip(&input_slot) {
        rec.slot = if sl == UNASSIGNED { None } else { Some(sl) };
    }
    let preloads: Vec<Preload<'g>> = preload_build
        .into_iter()
        .zip(preload_slot)
        .map(|((name, value), slot)| Preload { name, slot, value })
        .collect();

    let mut steps: Vec<Step> = Vec::with_capacity(steps_build.len());
    for (s, sb) in steps_build.into_iter().enumerate() {
        steps.push(Step {
            node_idx: sb.node_idx,
            out_node_idx: sb.out_node_idx,
            kernel: sb.kernel,
            inputs: sb.in_vals.iter().map(|&v| values[v].slot).collect(),
            outputs: sb
                .out_vals
                .iter()
                .map(|&v| {
                    let sl = values[v].slot;
                    if sl == UNASSIGNED {
                        None
                    } else {
                        Some(sl)
                    }
                })
                .collect(),
            release: std::mem::take(&mut release_at[s]),
        });
    }

    let outputs: Vec<PlanOutput> = output_build
        .into_iter()
        .map(|(name, vid)| PlanOutput { name, slot: values[vid].slot })
        .collect();

    Ok(ExecutionPlan {
        name: graph.name.clone(),
        nodes: Cow::Borrowed(graph.nodes.as_slice()),
        steps,
        preloads,
        inputs: input_records,
        outputs,
        slot_count: arena.capacity(),
        slot_dtypes,
        slot_numel,
        folded_outputs,
        alias_outputs,
        node_count: graph.nodes.len(),
        folded_count,
        elided_count,
        packed_count,
        quant_count,
        fused_count,
        resident_int_count,
        batch_symbolic_count,
        batch_blockers,
    })
}

#[cfg(test)]
mod tests {
    use super::super::ExecutionPlan;
    use crate::ir::GraphBuilder;
    use crate::tensor::Tensor;

    #[test]
    fn standard_only_rejects_at_compile_time() {
        let mut b = GraphBuilder::new("q");
        b.input("x", vec![1, 4]);
        b.quant("x", "y", 0.5, 0.0, 4.0, false, false, "ROUND");
        b.output("y", vec![1, 4]);
        let g = b.finish().unwrap();
        let opts = super::PlanOptions { standard_onnx_only: true, ..Default::default() };
        let err = ExecutionPlan::compile_with(&g, &opts).unwrap_err();
        assert!(err.to_string().contains("not a standard ONNX op"));
    }

    #[test]
    fn unknown_op_rejected_with_node_context() {
        let mut b = GraphBuilder::new("u");
        b.input("x", vec![1]);
        b.node("TotallyUnknown", &["x"], &["y"], &[]);
        b.output("y", vec![1]);
        let g = b.finish().unwrap();
        let err = ExecutionPlan::compile(&g).unwrap_err().to_string();
        assert!(err.contains("no implementation for op 'TotallyUnknown'"), "{err}");
    }

    #[test]
    fn dangling_input_rejected() {
        // bypass the builder's validate(): build the graph directly
        let mut g = crate::ir::ModelGraph::new("dangle");
        g.inputs.push(crate::ir::ValueInfo::new("x", vec![1]));
        g.outputs.push(crate::ir::ValueInfo::new("y", vec![1]));
        g.nodes.push(crate::ir::Node::new("Relu", &["nope"], &["y"]).with_name("r"));
        let err = ExecutionPlan::compile(&g).unwrap_err().to_string();
        assert!(err.contains("input 'nope' not computed"), "{err}");
    }

    #[test]
    fn constant_weight_matmul_is_packed() {
        let mut b = GraphBuilder::new("pack");
        b.input("x", vec![1, 2]);
        b.initializer("w", Tensor::new(vec![2, 3], vec![1., 0., 2., 0., 1., 3.]));
        b.node("MatMul", &["x", "w"], &["y"], &[]);
        b.output("y", vec![1, 3]);
        let g = b.finish().unwrap();
        let plan = ExecutionPlan::compile(&g).unwrap();
        assert_eq!(plan.packed_count(), 1, "{}", plan.summary());
        // the weight never becomes a runtime preload — it lives packed
        assert_eq!(plan.preload_count(), 0, "{}", plan.summary());
        let mut m = std::collections::BTreeMap::new();
        m.insert("x".to_string(), Tensor::new(vec![1, 2], vec![2.0, -1.0]));
        let out = plan.run(&m).unwrap();
        assert_eq!(out["y"].as_f32().unwrap(), &[2.0, -1.0, 1.0]);
    }

    #[test]
    fn conv_quant_chain_fuses_into_one_step() {
        let mut b = GraphBuilder::new("fuse");
        b.input("x", vec![1, 1, 4, 4]);
        b.initializer("w", Tensor::new(vec![2, 1, 1, 1], vec![1.0, -1.0]));
        b.node("Conv", &["x", "w"], &["c"], &[("kernel_shape", vec![1i64, 1].into())]);
        b.quant("c", "q", 0.5, 0.0, 4.0, true, false, "ROUND");
        b.node("Relu", &["q"], &["y"], &[]);
        b.output("y", vec![1, 2, 4, 4]);
        let g = b.finish().unwrap();
        let plan = ExecutionPlan::compile(&g).unwrap();
        // Conv + Quant + Relu collapse to one packed step
        assert_eq!(plan.step_count(), 1, "{}", plan.summary());
        assert_eq!(plan.fused_epilogue_count(), 2);
        let mut m = std::collections::BTreeMap::new();
        m.insert(
            "x".to_string(),
            Tensor::new(vec![1, 1, 4, 4], (0..16).map(|v| v as f32 * 0.3 - 2.0).collect()),
        );
        let fused = plan.run(&m).unwrap();
        let unfused_opts = super::PlanOptions { specialize: false, ..Default::default() };
        let unfused = ExecutionPlan::compile_with(&g, &unfused_opts).unwrap().run(&m).unwrap();
        assert_eq!(fused, unfused, "fusion must be bit-exact");
        let interp = crate::exec::interpret(&g, &m).unwrap();
        assert_eq!(interp.outputs, fused);
    }

    #[test]
    fn batch_symbolic_pass_rewrites_baked_reshape() {
        use super::super::{RunConfig, ShapeCheck};
        // conv -> reshape [1, 48] -> matmul: the CNV conv->FC shape
        let mut b = GraphBuilder::new("bsym");
        b.input("x", vec![1, 3, 4, 4]);
        b.initializer("w", Tensor::new(vec![3, 3, 1, 1], (0..9).map(|v| v as f32 * 0.5 - 2.0).collect()));
        b.node("Conv", &["x", "w"], &["c"], &[("kernel_shape", vec![1i64, 1].into())]);
        b.initializer("target", Tensor::new_i64(vec![2], vec![1, 48]));
        b.node("Reshape", &["c", "target"], &["flat"], &[]);
        b.initializer("fcw", Tensor::new(vec![48, 2], (0..96).map(|v| (v % 7) as f32 * 0.25 - 0.75).collect()));
        b.node("MatMul", &["flat", "fcw"], &["y"], &[]);
        b.output("y", vec![1, 2]);
        let g = b.finish().unwrap();
        let plan = ExecutionPlan::compile(&g).unwrap();
        assert_eq!(plan.batch_symbolic_count(), 1, "{}", plan.summary());
        // the rewritten target is baked into the kernel, not a preload
        assert_eq!(plan.preload_count(), 0, "{}", plan.summary());

        // batch 1 through the checked path is bit-identical to the interpreter
        let row: Vec<f32> = (0..48).map(|i| (i % 5) as f32 * 0.3 - 0.6).collect();
        let mut m = std::collections::BTreeMap::new();
        m.insert("x".to_string(), Tensor::new(vec![1, 3, 4, 4], row.clone()));
        let y1 = plan.run(&m).unwrap();
        assert_eq!(crate::exec::interpret(&g, &m).unwrap().outputs, y1);

        // batch 3 through one invocation == three per-sample runs
        let mut rows = Vec::new();
        for r in 0..3 {
            rows.extend(row.iter().map(|v| v + r as f32 * 0.1));
        }
        let x3 = Tensor::new(vec![3, 3, 4, 4], rows.clone());
        let cfg = RunConfig { shape_check: ShapeCheck::FreeBatch, record_intermediates: false };
        let y3 = plan.run_cfg(|n| (n == "x").then_some(&x3), &cfg).unwrap().outputs;
        assert_eq!(y3["y"].shape(), &[3, 2]);
        for r in 0..3 {
            let mut mi = std::collections::BTreeMap::new();
            mi.insert("x".to_string(), Tensor::new(vec![1, 3, 4, 4], rows[r * 48..(r + 1) * 48].to_vec()));
            let yi = plan.run(&mi).unwrap();
            assert_eq!(
                &y3["y"].as_f32().unwrap()[r * 2..(r + 1) * 2],
                yi["y"].as_f32().unwrap(),
                "row {r}"
            );
        }
    }

    #[test]
    fn batch_symbolic_pass_declines_without_proof_for_wildcards() {
        // [1, -1] resolves against any batch, so without a shape
        // annotation proving a batch-1-leading input it stays generic
        let mut b = GraphBuilder::new("bsym-wild");
        b.input("x", vec![1, 2, 2, 2]);
        b.node("Relu", &["x"], &["r"], &[]);
        b.initializer("target", Tensor::new_i64(vec![2], vec![1, -1]));
        b.node("Reshape", &["r", "target"], &["y"], &[]);
        b.output("y", vec![1, 8]);
        let g = b.finish().unwrap();
        let plan = ExecutionPlan::compile(&g).unwrap();
        assert_eq!(plan.batch_symbolic_count(), 0, "{}", plan.summary());

        // with inferred shapes the proof exists and the rewrite applies
        let mut g2 = g.clone();
        crate::transforms::infer_shapes(&mut g2).unwrap();
        let plan2 = ExecutionPlan::compile(&g2).unwrap();
        assert_eq!(plan2.batch_symbolic_count(), 1, "{}", plan2.summary());
        let x = Tensor::new(vec![2, 2, 2, 2], (0..16).map(|v| v as f32 - 8.0).collect());
        let cfg = super::super::RunConfig {
            shape_check: super::super::ShapeCheck::FreeBatch,
            record_intermediates: false,
        };
        let y = plan2.run_cfg(|n| (n == "x").then_some(&x), &cfg).unwrap().outputs;
        assert_eq!(y["y"].shape(), &[2, 8]);
    }

    #[test]
    fn quant_tier_selected_when_ranges_prove_integers() {
        // unit-scale Quant proves an integer grid -> the MatMul lowers to
        // QuantMatMul; disabling `quantize` gives the float tier with
        // byte-identical outputs (integer math is exact below 2^24)
        let mut b = GraphBuilder::new("qtier");
        b.input("x", vec![1, 8]);
        b.quant("x", "xq", 1.0, 0.0, 4.0, true, false, "ROUND");
        b.initializer(
            "w",
            Tensor::new(vec![8, 3], (0..24).map(|v| ((v % 5) as f32) - 2.0).collect()),
        );
        b.node("MatMul", &["xq", "w"], &["y"], &[]);
        b.output("y", vec![1, 3]);
        let g = b.finish().unwrap();
        let plan = ExecutionPlan::compile(&g).unwrap();
        assert_eq!(plan.quant_kernel_count(), 1, "{}", plan.summary());
        let mut m = std::collections::BTreeMap::new();
        m.insert(
            "x".to_string(),
            Tensor::new(vec![1, 8], vec![-9.0, -2.4, -0.6, 0.0, 0.4, 1.6, 3.0, 99.0]),
        );
        let got = plan.run(&m).unwrap();
        let float_opts = super::PlanOptions { quantize: false, ..Default::default() };
        let fplan = ExecutionPlan::compile_with(&g, &float_opts).unwrap();
        assert_eq!(fplan.quant_kernel_count(), 0);
        assert_eq!(fplan.run(&m).unwrap(), got, "quant tier must be bit-exact");
        assert_eq!(crate::exec::interpret(&g, &m).unwrap().outputs, got);
    }

    #[test]
    fn quant_tier_declines_scaled_grids() {
        // scale 0.5 -> values are not literal integers -> float tier
        let mut b = GraphBuilder::new("qdecline");
        b.input("x", vec![1, 4]);
        b.quant("x", "xq", 0.5, 0.0, 4.0, true, false, "ROUND");
        b.initializer("w", Tensor::new(vec![4, 2], vec![1.0; 8]));
        b.node("MatMul", &["xq", "w"], &["y"], &[]);
        b.output("y", vec![1, 2]);
        let g = b.finish().unwrap();
        let plan = ExecutionPlan::compile(&g).unwrap();
        assert_eq!(plan.quant_kernel_count(), 0, "{}", plan.summary());
        assert_eq!(plan.packed_count(), 1);
    }

    #[test]
    fn quant_matmul_fuses_multithreshold_consumer() {
        use crate::ir::AttrValue;
        // streamlined shape: MT (float input) -> integer MatMul -> MT
        let mut b = GraphBuilder::new("qmt");
        b.input("x", vec![1, 4]);
        b.initializer("t0", Tensor::new(vec![1, 3], vec![0.5, 1.5, 2.5]));
        b.node_in_domain(crate::ir::DOMAIN_FINN, "MultiThreshold", &["x", "t0"], &["xi"], &[]);
        b.initializer("w", Tensor::new(vec![4, 2], vec![1.0, -1.0, 2.0, 0.0, -2.0, 1.0, 1.0, 1.0]));
        b.node("MatMul", &["xi", "w"], &["acc"], &[]);
        b.initializer("t1", Tensor::new(vec![1, 2], vec![-1.0, 2.0]));
        b.node_in_domain(
            crate::ir::DOMAIN_FINN,
            "MultiThreshold",
            &["acc", "t1"],
            &["y"],
            &[("out_scale", AttrValue::Float(1.0)), ("out_bias", AttrValue::Float(-1.0))],
        );
        b.output("y", vec![1, 2]);
        let mut g = b.finish().unwrap();
        // MT fusion requires the MatMul output to be provably rank-2
        crate::transforms::infer_shapes(&mut g).unwrap();
        let plan = ExecutionPlan::compile(&g).unwrap();
        // input MT stays generic; MatMul+MT collapse into one quant step
        assert_eq!(plan.quant_kernel_count(), 1, "{}", plan.summary());
        assert_eq!(plan.fused_epilogue_count(), 1, "{}", plan.summary());
        assert_eq!(plan.step_count(), 2, "{}", plan.summary());
        let mut m = std::collections::BTreeMap::new();
        m.insert("x".to_string(), Tensor::new(vec![1, 4], vec![-1.0, 0.7, 1.6, 7.0]));
        let got = plan.run(&m).unwrap();
        let unfused_opts = super::PlanOptions { fuse_epilogues: false, ..Default::default() };
        let unfused = ExecutionPlan::compile_with(&g, &unfused_opts).unwrap();
        assert_eq!(unfused.run(&m).unwrap(), got, "MT fusion must be bit-exact");
        assert_eq!(crate::exec::interpret(&g, &m).unwrap().outputs, got);
    }

    #[test]
    fn gemm_and_matmul_fuse_float_epilogues() {
        // Gemm -> Quant -> Relu collapses into one packed step
        let mut b = GraphBuilder::new("gfuse");
        b.input("a", vec![2, 3]);
        b.initializer("w", Tensor::new(vec![3, 4], (0..12).map(|v| v as f32 * 0.3 - 1.5).collect()));
        b.initializer("c", Tensor::new(vec![1, 4], vec![0.5, -0.5, 0.0, 1.0]));
        b.node("Gemm", &["a", "w", "c"], &["g"], &[]);
        b.quant("g", "q", 0.5, 0.0, 4.0, true, false, "ROUND");
        b.node("Relu", &["q"], &["y"], &[]);
        b.output("y", vec![2, 4]);
        let g = b.finish().unwrap();
        let plan = ExecutionPlan::compile(&g).unwrap();
        assert_eq!(plan.step_count(), 1, "{}", plan.summary());
        assert_eq!(plan.fused_epilogue_count(), 2, "{}", plan.summary());
        let mut m = std::collections::BTreeMap::new();
        m.insert("a".to_string(), Tensor::new(vec![2, 3], vec![0.3, -0.9, 1.7, 0.0, 2.2, -1.1]));
        let got = plan.run(&m).unwrap();
        let unfused_opts = super::PlanOptions { fuse_epilogues: false, ..Default::default() };
        assert_eq!(
            ExecutionPlan::compile_with(&g, &unfused_opts).unwrap().run(&m).unwrap(),
            got,
            "gemm epilogue fusion must be bit-exact"
        );
        assert_eq!(crate::exec::interpret(&g, &m).unwrap().outputs, got);

        // MatMul -> BatchNorm does NOT fuse (batch-dependent channel
        // axis), but MatMul -> Relu does
        let mut b2 = GraphBuilder::new("mmfuse");
        b2.input("a", vec![2, 3]);
        b2.initializer("w", Tensor::new(vec![3, 2], vec![1.0, -0.5, 0.25, 2.0, -1.0, 0.75]));
        b2.node("MatMul", &["a", "w"], &["mm"], &[]);
        for (suffix, v) in [("scale", 2.0f32), ("bias", 0.5), ("mean", 0.1), ("var", 1.5)] {
            b2.initializer(&format!("bn_{suffix}"), Tensor::full(vec![2], v));
        }
        b2.node(
            "BatchNormalization",
            &["mm", "bn_scale", "bn_bias", "bn_mean", "bn_var"],
            &["bn"],
            &[],
        );
        b2.node("Relu", &["bn"], &["y"], &[]);
        b2.output("y", vec![2, 2]);
        let g2 = b2.finish().unwrap();
        let plan2 = ExecutionPlan::compile(&g2).unwrap();
        // BatchNorm breaks the chain: nothing fuses past it
        assert_eq!(plan2.fused_epilogue_count(), 0, "{}", plan2.summary());
        let mut m2 = std::collections::BTreeMap::new();
        m2.insert("a".to_string(), Tensor::new(vec![2, 3], vec![1.0, -2.0, 0.5, 0.0, 3.0, -1.0]));
        let got2 = plan2.run(&m2).unwrap();
        assert_eq!(crate::exec::interpret(&g2, &m2).unwrap().outputs, got2);
    }

    #[test]
    fn batch_blockers_recorded_for_unbatchable_targets() {
        // baked batch 4: runs at declared shapes, but flagged
        let mut b = GraphBuilder::new("baked");
        b.input("x", vec![4, 2, 3]);
        b.node("Relu", &["x"], &["r"], &[]);
        b.initializer("target", Tensor::new_i64(vec![2], vec![4, 6]));
        b.node("Reshape", &["r", "target"], &["y"], &[]);
        b.output("y", vec![4, 6]);
        let g = b.finish().unwrap();
        let plan = ExecutionPlan::compile(&g).unwrap();
        assert_eq!(plan.batch_symbolic_count(), 0);
        assert_eq!(plan.batch_blockers().len(), 1, "{}", plan.summary());
        assert!(plan.batch_blockers()[0].contains("bakes batch 4"), "{:?}", plan.batch_blockers());
        // it still executes at the declared batch
        let mut m = std::collections::BTreeMap::new();
        m.insert("x".to_string(), Tensor::full(vec![4, 2, 3], -1.0));
        assert_eq!(plan.run(&m).unwrap()["y"].shape(), &[4, 6]);

        // wildcard without inferred shapes: flagged with the cleanup hint
        let mut b2 = GraphBuilder::new("wild");
        b2.input("x", vec![1, 2, 2, 2]);
        b2.node("Relu", &["x"], &["r"], &[]);
        b2.initializer("target", Tensor::new_i64(vec![2], vec![1, -1]));
        b2.node("Reshape", &["r", "target"], &["y"], &[]);
        b2.output("y", vec![1, 8]);
        let g2 = b2.finish().unwrap();
        let plan2 = ExecutionPlan::compile(&g2).unwrap();
        assert_eq!(plan2.batch_symbolic_count(), 0);
        assert!(plan2.batch_blockers()[0].contains("cleanup"), "{:?}", plan2.batch_blockers());
        // ... and with shapes inferred the blocker disappears
        let mut g3 = g2.clone();
        crate::transforms::infer_shapes(&mut g3).unwrap();
        let plan3 = ExecutionPlan::compile(&g3).unwrap();
        assert_eq!(plan3.batch_symbolic_count(), 1);
        assert!(plan3.batch_blockers().is_empty());
    }

    #[test]
    fn residency_specializes_input_threshold_and_negotiates_containers() {
        use crate::tensor::DType;
        // x -> MT(const thresholds) -> integer MatMul -> y: the MT emits
        // resident i8 levels, the MatMul consumes them and emits f32 for
        // the graph output
        let mut b = GraphBuilder::new("resid");
        b.input("x", vec![1, 4]);
        b.initializer("t0", Tensor::new(vec![1, 3], vec![0.5, 1.5, 2.5]));
        b.node_in_domain(crate::ir::DOMAIN_FINN, "MultiThreshold", &["x", "t0"], &["xi"], &[]);
        b.initializer("w", Tensor::new(vec![4, 2], vec![1.0, -1.0, 2.0, 0.0, -2.0, 1.0, 1.0, 1.0]));
        b.node("MatMul", &["xi", "w"], &["y"], &[]);
        b.output("y", vec![1, 2]);
        let g = b.finish().unwrap();
        let plan = ExecutionPlan::compile(&g).unwrap();
        assert_eq!(plan.resident_int_count(), 1, "{}", plan.summary());
        let table = plan.step_table();
        assert_eq!(table[0].0, "Threshold(i8)", "{}", plan.summary());
        let mt_slot = table[0].1[0].unwrap() as usize;
        assert_eq!(plan.slot_dtypes()[mt_slot], DType::I8);
        // the graph output demands f32: the MatMul's slot stays f32
        assert_eq!(table[1].0, "QuantMatMul", "{}", plan.summary());
        let y_slot = table[1].1[0].unwrap() as usize;
        assert_eq!(plan.slot_dtypes()[y_slot], DType::F32);
        // residency is traffic-only: identical to convert-per-call and
        // the interpreter
        let mut m = std::collections::BTreeMap::new();
        m.insert("x".to_string(), Tensor::new(vec![1, 4], vec![-1.0, 0.7, 1.6, 7.0]));
        let got = plan.run(&m).unwrap();
        let off = super::PlanOptions { int_residency: false, ..Default::default() };
        let cplan = ExecutionPlan::compile_with(&g, &off).unwrap();
        assert_eq!(cplan.resident_int_count(), 0);
        assert_eq!(cplan.run(&m).unwrap(), got);
        assert_eq!(crate::exec::interpret(&g, &m).unwrap().outputs, got);
    }

    #[test]
    fn residency_declines_when_a_float_consumer_shares_the_value() {
        use crate::tensor::DType;
        // xi feeds both the integer MatMul and a generic Sigmoid: the
        // shared value must stay f32 and the MT stays generic
        let mut b = GraphBuilder::new("resid-shared");
        b.input("x", vec![1, 4]);
        b.initializer("t0", Tensor::new(vec![1, 3], vec![0.5, 1.5, 2.5]));
        b.node_in_domain(crate::ir::DOMAIN_FINN, "MultiThreshold", &["x", "t0"], &["xi"], &[]);
        b.initializer("w", Tensor::new(vec![4, 2], vec![1.0, 0.0, -1.0, 1.0, 2.0, -2.0, 0.0, 1.0]));
        b.node("MatMul", &["xi", "w"], &["y"], &[]);
        b.node("Sigmoid", &["xi"], &["s"], &[]);
        b.output("y", vec![1, 2]);
        b.output("s", vec![1, 4]);
        let g = b.finish().unwrap();
        let plan = ExecutionPlan::compile(&g).unwrap();
        assert_eq!(plan.resident_int_count(), 0, "{}", plan.summary());
        let table = plan.step_table();
        assert_eq!(table[0].0, "MultiThreshold", "generic MT kept:\n{}", plan.summary());
        assert!(plan.slot_dtypes().iter().all(|&d| d == DType::F32), "{}", plan.summary());
        let mut m = std::collections::BTreeMap::new();
        m.insert("x".to_string(), Tensor::new(vec![1, 4], vec![0.2, 1.1, 2.2, 3.3]));
        let got = plan.run(&m).unwrap();
        assert_eq!(crate::exec::interpret(&g, &m).unwrap().outputs, got);
    }

    #[test]
    fn fusion_declines_shared_or_output_values() {
        // conv output is also a graph output: the quant cannot be absorbed
        let mut b = GraphBuilder::new("nofuse");
        b.input("x", vec![1, 1, 2, 2]);
        b.initializer("w", Tensor::new(vec![1, 1, 1, 1], vec![2.0]));
        b.node("Conv", &["x", "w"], &["c"], &[("kernel_shape", vec![1i64, 1].into())]);
        b.quant("c", "q", 0.5, 0.0, 4.0, true, false, "ROUND");
        b.output("c", vec![1, 1, 2, 2]);
        b.output("q", vec![1, 1, 2, 2]);
        let g = b.finish().unwrap();
        let plan = ExecutionPlan::compile(&g).unwrap();
        assert_eq!(plan.fused_epilogue_count(), 0, "{}", plan.summary());
        assert_eq!(plan.step_count(), 2);
        let mut m = std::collections::BTreeMap::new();
        m.insert("x".to_string(), Tensor::new(vec![1, 1, 2, 2], vec![0.1, 0.6, -0.4, 2.0]));
        let got = plan.run(&m).unwrap();
        let interp = crate::exec::interpret(&g, &m).unwrap();
        assert_eq!(interp.outputs, got);
    }
}

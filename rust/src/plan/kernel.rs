//! Compiled per-step kernels.
//!
//! At plan-compile time every node's `op_type` is resolved exactly once
//! through the op registry ([`crate::ops::kernel_for`]) and frozen into a
//! [`CompiledKernel`], so the run-time loop dispatches through a stored
//! function pointer instead of string-matching on every node of every
//! request. Two node classes never reach a kernel at all: `Constant`
//! nodes (and any node whose inputs are all compile-time constants) are
//! folded into preloaded slots, and single-input `Identity` nodes are
//! elided by slot aliasing.

use crate::ir::Node;
use crate::ops::OpFn;
use crate::tensor::Tensor;
use anyhow::Result;

/// Resolved dispatch for one plan step.
#[derive(Debug, Clone, Copy)]
pub enum CompiledKernel {
    /// Registry operator function, resolved at compile time.
    Op(OpFn),
}

impl CompiledKernel {
    /// Run the kernel against resolved input tensors.
    #[inline]
    pub fn invoke(&self, node: &Node, inputs: &[&Tensor]) -> Result<Vec<Tensor>> {
        match self {
            CompiledKernel::Op(f) => f(node, inputs),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ops;

    #[test]
    fn invokes_resolved_op() {
        let node = Node::new("Relu", &["x"], &["y"]);
        let k = CompiledKernel::Op(ops::kernel_for(&node).unwrap());
        let x = Tensor::new(vec![3], vec![-1.0, 0.0, 2.0]);
        let out = k.invoke(&node, &[&x]).unwrap();
        assert_eq!(out[0].as_f32().unwrap(), &[0.0, 0.0, 2.0]);
    }
}

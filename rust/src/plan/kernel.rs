//! Compiled per-step kernels: generic op dispatch plus **specialized
//! prepacked kernels** built at plan-compile time.
//!
//! At plan-compile time every node's `op_type` is resolved exactly once
//! through the op registry ([`crate::ops::kernel_for`]) and frozen into a
//! [`CompiledKernel`], so the run-time loop dispatches through a stored
//! function pointer instead of string-matching on every node of every
//! request. Two node classes never reach a kernel at all: `Constant`
//! nodes (and any node whose inputs are all compile-time constants) are
//! folded into preloaded slots, and single-input `Identity` nodes are
//! elided by slot aliasing.
//!
//! Above the generic [`CompiledKernel::Op`] tier sit three *stateful*
//! kernels, built whenever a node's weight inputs are compile-time
//! constants:
//!
//! * [`PackedConv`] — conv hyper-params resolved once, per-group weights
//!   transposed and panel-packed once into a [`PackedB`], bias resolved
//!   once, and an optional fused elementwise epilogue (BatchNorm /
//!   Quant / BipolarQuant / Relu) applied inside the GEMM scatter loop
//!   instead of as separate full-tensor passes.
//! * [`PackedGemm`] — `transB` applied at pack time, `beta` folded into a
//!   pre-scaled bias, `alpha` applied in the accumulator write-back.
//! * [`PackedMatMul`] — constant rhs packed once; batched lhs handled
//!   without the reshape copy of the generic path.
//!
//! All three draw their working buffers (im2col matrices, GEMM products,
//! outputs) from the run's [`ScratchArena`] rather than allocating, and
//! all three are **bit-exact** with the generic ops: the packed GEMM
//! accumulates in the same ascending-k order (see
//! [`crate::tensor::gemm`]'s determinism contract) and every epilogue
//! stage replays the generic op's per-element arithmetic verbatim.

use super::arena::ScratchArena;
use super::qkernel::{QuantConv, QuantGemm, QuantMatMul, ThresholdKernel};
use crate::ir::Node;
use crate::ops::linalg::{conv_params, ConvParams};
use crate::ops::quant::{quant_bounds, RoundingMode};
use crate::ops::shape_ops::resolve_reshape;
use crate::ops::OpFn;
use crate::tensor::{conv_out_dim, gemm_prepacked, im2col_group_into, PackedB, Tensor};
use anyhow::{ensure, Result};
use std::borrow::Cow;
use std::sync::Arc;

/// Resolved dispatch for one plan step.
#[derive(Debug, Clone)]
pub enum CompiledKernel {
    /// Registry operator function, resolved at compile time.
    Op(OpFn),
    /// Conv with constant weights: packed once, arena-fed, fusable.
    Conv(Arc<PackedConv>),
    /// Gemm with a constant B operand.
    Gemm(Arc<PackedGemm>),
    /// MatMul with a constant rhs.
    MatMul(Arc<PackedMatMul>),
    /// Integer-domain conv (tier 3): `i8` panels, `i32` accumulate,
    /// `MultiThreshold` fusable (see [`crate::plan::qkernel`]).
    QConv(Arc<QuantConv>),
    /// Integer-domain Gemm.
    QGemm(Arc<QuantGemm>),
    /// Integer-domain MatMul.
    QMatMul(Arc<QuantMatMul>),
    /// Standalone `MultiThreshold` with constant thresholds, emitting its
    /// integer levels directly into their proven container (the
    /// resident-integer tier's boundary kernel — see
    /// [`crate::plan::qkernel::ThresholdKernel`]).
    Threshold(Arc<ThresholdKernel>),
    /// Reshape whose constant target baked a batch of 1 into its leading
    /// dim, rewritten batch-preserving (the batch-symbolic compile pass).
    Reshape(Arc<BatchReshape>),
}

impl CompiledKernel {
    /// Run the kernel against resolved input tensors, drawing scratch
    /// buffers from `scratch`. `inputs` holds only the step's *runtime*
    /// inputs — packed kernels carry their constants internally.
    #[inline]
    pub fn invoke(
        &self,
        node: &Node,
        inputs: &[&Tensor],
        scratch: &mut ScratchArena,
    ) -> Result<Vec<Tensor>> {
        match self {
            CompiledKernel::Op(f) => f(node, inputs),
            CompiledKernel::Conv(pc) => {
                ensure!(!inputs.is_empty(), "PackedConv wants the data tensor");
                Ok(vec![pc.run(inputs[0], scratch)?])
            }
            CompiledKernel::Gemm(pg) => Ok(vec![pg.run(inputs, scratch)?]),
            CompiledKernel::MatMul(pm) => {
                ensure!(!inputs.is_empty(), "PackedMatMul wants the lhs tensor");
                Ok(vec![pm.run(inputs[0], scratch)?])
            }
            CompiledKernel::QConv(qc) => {
                ensure!(!inputs.is_empty(), "QuantConv wants the data tensor");
                Ok(vec![qc.run(inputs[0], scratch)?])
            }
            CompiledKernel::QGemm(qg) => {
                ensure!(!inputs.is_empty(), "QuantGemm wants the A tensor");
                Ok(vec![qg.run(inputs[0], scratch)?])
            }
            CompiledKernel::QMatMul(qm) => {
                ensure!(!inputs.is_empty(), "QuantMatMul wants the lhs tensor");
                Ok(vec![qm.run(inputs[0], scratch)?])
            }
            CompiledKernel::Threshold(tk) => {
                ensure!(!inputs.is_empty(), "ThresholdKernel wants the data tensor");
                Ok(vec![tk.run(inputs[0], scratch)?])
            }
            CompiledKernel::Reshape(br) => {
                ensure!(!inputs.is_empty(), "BatchReshape wants the data tensor");
                Ok(vec![br.run(inputs[0])?])
            }
        }
    }

    /// Short display tag for schedule listings.
    pub fn tag(&self, node: &Node) -> String {
        match self {
            CompiledKernel::Op(_) => node.op_type.clone(),
            CompiledKernel::Conv(pc) if pc.epilogue.is_empty() => "PackedConv".to_string(),
            CompiledKernel::Conv(pc) => format!("PackedConv+{}ep", pc.epilogue.len()),
            CompiledKernel::Gemm(pg) if pg.epilogue.is_empty() => "PackedGemm".to_string(),
            CompiledKernel::Gemm(pg) => format!("PackedGemm+{}ep", pg.epilogue.len()),
            CompiledKernel::MatMul(pm) if pm.epilogue.is_empty() => "PackedMatMul".to_string(),
            CompiledKernel::MatMul(pm) => format!("PackedMatMul+{}ep", pm.epilogue.len()),
            CompiledKernel::QConv(qc) if qc.has_fused_threshold() => "QuantConv+mt".to_string(),
            CompiledKernel::QConv(_) => "QuantConv".to_string(),
            CompiledKernel::QGemm(qg) if qg.has_fused_threshold() => "QuantGemm+mt".to_string(),
            CompiledKernel::QGemm(_) => "QuantGemm".to_string(),
            CompiledKernel::QMatMul(qm) if qm.has_fused_threshold() => "QuantMatMul+mt".to_string(),
            CompiledKernel::QMatMul(_) => "QuantMatMul".to_string(),
            CompiledKernel::Threshold(tk) => format!("Threshold({})", tk.out_dtype()),
            CompiledKernel::Reshape(_) => "BatchReshape".to_string(),
        }
    }

    /// Whether this is a specialized prepacked float (tier-2) kernel.
    pub fn is_packed(&self) -> bool {
        matches!(
            self,
            CompiledKernel::Conv(_) | CompiledKernel::Gemm(_) | CompiledKernel::MatMul(_)
        )
    }

    /// Whether this is an integer-domain quantized (tier-3) kernel.
    pub fn is_quant(&self) -> bool {
        matches!(
            self,
            CompiledKernel::QConv(_) | CompiledKernel::QGemm(_) | CompiledKernel::QMatMul(_)
        )
    }

    /// ISA of the prebuilt interleaved SIMD weight tiles, for quantized
    /// kernels that carry them (`None` for float-tier kernels, and for
    /// quantized kernels packed under forced-scalar / unsupported ISAs).
    pub fn simd_isa(&self) -> Option<crate::tensor::Isa> {
        match self {
            CompiledKernel::QConv(k) => k.simd_isa(),
            CompiledKernel::QGemm(k) => k.simd_isa(),
            CompiledKernel::QMatMul(k) => k.simd_isa(),
            _ => None,
        }
    }
}

/// A `Reshape` whose compile-time-constant target baked the declared
/// batch of 1 into its leading dimension (the CNV conv→FC flatten chain:
/// `[1, 256]`, or `[1, -1]` for the cleaned raw export).
///
/// The batch-symbolic pass rewrites the leading `1` to ONNX's `0`
/// ("copy the input's dim 0") so the same plan serves any leading batch:
/// `[n, 256, 1, 1] -> [n, 256]` instead of failing the element-count
/// check. Two modes keep it bit-identical to the generic kernel:
///
/// * **fallback** (`try_orig_first`) — the original target is attempted
///   first and wins whenever it resolves, so every input the unrewritten
///   plan accepted produces byte-identical output; only inputs the
///   original target *rejects* (a larger batch) take the rewritten form.
/// * **always** — targets containing a `-1` wildcard resolve against any
///   element count (collapsing the batch into the wildcard), so the
///   fallback can't discriminate. The compile pass only emits this mode
///   when shape inference proves the data input's leading dim is 1 at
///   declared shapes, where both forms agree.
#[derive(Debug)]
pub struct BatchReshape {
    /// The node's original target (leading dim literally 1).
    orig: Vec<i64>,
    /// Batch-preserving form: leading dim 0 (= copy input dim 0).
    batched: Vec<i64>,
    try_orig_first: bool,
}

impl BatchReshape {
    pub(crate) fn new(orig: &[i64], try_orig_first: bool) -> BatchReshape {
        let mut batched = orig.to_vec();
        batched[0] = 0;
        BatchReshape { orig: orig.to_vec(), batched, try_orig_first }
    }

    // Verifier introspection: well-formedness of a compiled batch-symbolic
    // target is re-checked from these.
    pub(crate) fn orig(&self) -> &[i64] {
        &self.orig
    }

    pub(crate) fn batched(&self) -> &[i64] {
        &self.batched
    }

    pub(crate) fn try_orig_first(&self) -> bool {
        self.try_orig_first
    }

    /// Resolve and apply the target against `x` (same data, new shape —
    /// byte-identical to [`crate::ops::shape_ops::reshape`]).
    pub fn run(&self, x: &Tensor) -> Result<Tensor> {
        if self.try_orig_first {
            if let Ok(shape) = resolve_reshape(x.shape(), &self.orig) {
                return x.reshape(shape);
            }
        }
        let shape = resolve_reshape(x.shape(), &self.batched)?;
        x.reshape(shape)
    }
}

/// A fused elementwise stage applied in the conv scatter loop.
///
/// Each variant replays the corresponding generic op's per-element f32
/// arithmetic exactly (same operation order, same f64 widening), so a
/// fused plan is bit-identical to the unfused one.
#[derive(Debug, Clone)]
pub(crate) enum Epilogue {
    /// `Relu`: `v.max(0.0)`.
    Relu,
    /// Scalar-parameter `Quant` (the [`crate::ops::quant::quant_op`]
    /// fast path, hoisted to compile time). Divides by the scale —
    /// never multiplies by the reciprocal — so it stays bit-identical
    /// to the generic op at rounding-boundary ties.
    Quant { s: f64, z: f64, qmin: f64, qmax: f64, mode: RoundingMode },
    /// Scalar-scale `BipolarQuant`.
    Bipolar { s: f64 },
    /// `BatchNormalization` with per-channel constants; `denom` is
    /// `sqrt(var + eps)` precomputed per channel.
    BatchNorm { mean: Vec<f32>, denom: Vec<f32>, scale: Vec<f32>, bias: Vec<f32> },
}

impl Epilogue {
    /// Whether the stage reads the channel index at all. Channel-indexed
    /// stages (BatchNorm) only fuse into kernels whose output channel
    /// axis is statically known (conv NCHW, rank-2 Gemm) — a batched
    /// MatMul's output rank isn't known at compile time, so it only
    /// absorbs channel-independent stages.
    pub(crate) fn channel_independent(&self) -> bool {
        !matches!(self, Epilogue::BatchNorm { .. })
    }

    #[inline]
    fn apply(&self, v: f32, oc: usize) -> f32 {
        match self {
            Epilogue::Relu => v.max(0.0),
            Epilogue::Quant { s, z, qmin, qmax, mode } => {
                let q = mode.apply(f64::from(v) / s + z).clamp(*qmin, *qmax);
                ((q - z) * s) as f32
            }
            Epilogue::Bipolar { s } => {
                let q = if v >= 0.0 { 1.0 } else { -1.0 };
                (q * s) as f32
            }
            Epilogue::BatchNorm { mean, denom, scale, bias } => {
                ((v - mean[oc]) / denom[oc]) * scale[oc] + bias[oc]
            }
        }
    }

    /// Try to compile `node` into an epilogue stage. `resolve` maps an
    /// input name to its compile-time constant (if any); `out_channels`
    /// is the producer's channel count (conv `M`). Returns `None` when
    /// the node is not a fusable elementwise op, when its parameters are
    /// not constant (or not the supported scalar/per-channel layout), or
    /// when parameter validation would fail — in that last case fusion is
    /// declined so the generic kernel reports the error with full parity.
    pub(crate) fn try_build<'t>(
        node: &Node,
        resolve: impl Fn(&str) -> Option<&'t Tensor>,
        out_channels: usize,
    ) -> Option<Epilogue> {
        if node.outputs.len() != 1 {
            return None;
        }
        let const_in = |i: usize| -> Option<&'t Tensor> {
            let name = node.inputs.get(i)?;
            if name.is_empty() {
                return None;
            }
            resolve(name)
        };
        match node.op_type.as_str() {
            "Relu" if node.present_inputs().count() == 1 => Some(Epilogue::Relu),
            "Quant" if node.inputs.len() == 4 => {
                let (scale, zp, bw) = (const_in(1)?, const_in(2)?, const_in(3)?);
                // scalar params only — and rank <= 1 so broadcasting cannot
                // change the generic op's output rank
                if [scale, zp, bw].iter().any(|t| t.numel() != 1 || t.rank() > 1) {
                    return None;
                }
                let signed = node.attr_int_or("signed", 1) != 0;
                let narrow = node.attr_int_or("narrow", 0) != 0;
                let mode = RoundingMode::from_str(&node.attr_str_or("rounding_mode", "ROUND"))
                    .ok()?;
                let s = scale.to_f64_vec()[0];
                let z = zp.to_f64_vec()[0];
                let b = bw.to_f64_vec()[0];
                // same validations as quant_op; invalid params run generic
                if s <= 0.0 || !(b >= 2.0 || (!signed && b >= 1.0)) {
                    return None;
                }
                let (qmin, qmax) = quant_bounds(signed, narrow, b);
                Some(Epilogue::Quant { s, z, qmin, qmax, mode })
            }
            "BipolarQuant" if node.inputs.len() == 2 => {
                let scale = const_in(1)?;
                if scale.numel() != 1 || scale.rank() > 1 {
                    return None;
                }
                let s = scale.to_f64_vec()[0];
                if s <= 0.0 {
                    return None;
                }
                Some(Epilogue::Bipolar { s })
            }
            "BatchNormalization" if node.inputs.len() == 5 => {
                if node.attr_str_or("data_layout", "NCHW") == "NHWC" {
                    return None;
                }
                let eps = node.attr_float_or("epsilon", 1e-5);
                let mut chans: Vec<Vec<f32>> = Vec::with_capacity(4);
                for i in 1..5 {
                    let t = const_in(i)?;
                    if t.numel() != out_channels {
                        return None;
                    }
                    chans.push(t.as_f32().ok()?.to_vec());
                }
                let var = chans.pop().unwrap();
                let mean = chans.pop().unwrap();
                let bias = chans.pop().unwrap();
                let scale = chans.pop().unwrap();
                let denom: Vec<f32> = var.iter().map(|&v| (v + eps).sqrt()).collect();
                Some(Epilogue::BatchNorm { mean, denom, scale, bias })
            }
            _ => None,
        }
    }
}

/// Conv with compile-time-constant weights (and bias): hyper-params
/// resolved once, per-group weight matrices transposed to `[k, mg]` and
/// panel-packed once, scratch drawn from the arena, optional fused
/// elementwise epilogue applied in the scatter loop.
#[derive(Debug)]
pub struct PackedConv {
    p: ConvParams,
    m: usize,
    cg: usize,
    mg: usize,
    k: usize,
    /// One packed `[k, mg]` weight matrix per group.
    weights: Vec<PackedB>,
    /// Bias resolved to a dense `[m]` vector.
    bias: Option<Vec<f32>>,
    epilogue: Vec<Epilogue>,
}

impl PackedConv {
    /// Build from a conv node whose weight (and bias, when present) are
    /// compile-time constants. Returns `None` whenever anything about the
    /// node is unsupported — the caller then keeps the generic kernel,
    /// which either handles the case (NHWC wrapper) or reports the same
    /// error the interpreter would.
    pub(crate) fn try_build(node: &Node, w: &Tensor, bias: Option<&Tensor>) -> Option<PackedConv> {
        if node.attr_str_or("data_layout", "NCHW") != "NCHW" {
            return None; // channels-last wrapper runs generic
        }
        if w.rank() != 4 {
            return None;
        }
        let p = conv_params(node, w.shape()).ok()?;
        let ws = w.as_f32().ok()?;
        let m = w.shape()[0];
        let cg = w.shape()[1];
        if p.group == 0 || m % p.group != 0 {
            return None;
        }
        let mg = m / p.group;
        let k = cg * p.kh * p.kw;
        let bias = match bias {
            None => None,
            Some(b) => {
                if b.numel() != m {
                    return None; // generic path reports the mismatch
                }
                Some(b.as_f32().ok()?.to_vec())
            }
        };
        // per-group [mg, k] weight rows transposed to [k, mg] (the same
        // shared helper the generic conv uses per request), packed once
        let mut weights = Vec::with_capacity(p.group);
        for g in 0..p.group {
            let wt = crate::ops::linalg::transpose_group_weights(ws, g, mg, k);
            weights.push(PackedB::pack(k, mg, &wt));
        }
        Some(PackedConv { p, m, cg, mg, k, weights, bias, epilogue: Vec::new() })
    }

    /// Reassemble from persisted parts (artifact loading): the exact
    /// state [`PackedConv::try_build`] + fusion would have produced,
    /// minus the transpose/pack work.
    #[allow(clippy::too_many_arguments)]
    pub(crate) fn from_parts(
        p: ConvParams,
        m: usize,
        cg: usize,
        mg: usize,
        k: usize,
        weights: Vec<PackedB>,
        bias: Option<Vec<f32>>,
        epilogue: Vec<Epilogue>,
    ) -> PackedConv {
        PackedConv { p, m, cg, mg, k, weights, bias, epilogue }
    }

    /// Conv hyper-parameters (artifact writing).
    pub(crate) fn params(&self) -> &ConvParams {
        &self.p
    }

    /// `(m, cg, mg, k)` dims (artifact writing).
    pub(crate) fn dims(&self) -> (usize, usize, usize, usize) {
        (self.m, self.cg, self.mg, self.k)
    }

    /// Per-group packed weight matrices (artifact writing).
    pub(crate) fn weights(&self) -> &[PackedB] {
        &self.weights
    }

    /// Dense bias vector, when present (artifact writing).
    pub(crate) fn bias(&self) -> Option<&[f32]> {
        self.bias.as_deref()
    }

    /// Append a fused elementwise stage (compile-time fusion pass).
    pub(crate) fn push_epilogue(&mut self, e: Epilogue) {
        self.epilogue.push(e);
    }

    /// Output channels (`M`) — the channel axis the epilogue indexes.
    pub(crate) fn out_channels(&self) -> usize {
        self.m
    }

    /// The fused stage chain in application order (verifier introspection).
    pub(crate) fn epilogue(&self) -> &[Epilogue] {
        &self.epilogue
    }

    /// Number of fused epilogue stages.
    pub fn epilogue_len(&self) -> usize {
        self.epilogue.len()
    }

    /// Execute on an NCHW input of any batch size.
    pub fn run(&self, x: &Tensor, scratch: &mut ScratchArena) -> Result<Tensor> {
        ensure!(x.rank() == 4, "Conv input must be NCHW, got {:?}", x.shape());
        let (n, c, h, w) = (x.shape()[0], x.shape()[1], x.shape()[2], x.shape()[3]);
        ensure!(
            c == self.cg * self.p.group,
            "channel mismatch: x has {c}, w wants {} x group {}",
            self.cg,
            self.p.group
        );
        let xs = x.as_f32()?;
        let p = &self.p;
        let oh = conv_out_dim(h, p.kh, p.stride_h, p.pads[0], p.pads[2]);
        let ow = conv_out_dim(w, p.kw, p.stride_w, p.pads[1], p.pads[3]);
        let rows = n * oh * ow;
        // scatter overwrites every out element — skip the zeroing memset;
        // cols needs zeros (padding) and prod is a GEMM accumulator
        let mut out = scratch.take_uninit(n * self.m * oh * ow);
        let mut cols = scratch.take(rows * self.k);
        let mut prod = scratch.take(rows * self.mg);
        for g in 0..p.group {
            if g > 0 {
                prod.fill(0.0); // gemm accumulates; cols' padding zeros persist
            }
            im2col_group_into(
                xs, n, c, h, w, g * self.cg, self.cg, p.kh, p.kw, p.stride_h, p.stride_w,
                p.pads, &mut cols,
            );
            gemm_prepacked(rows, self.k, &self.weights[g], &cols, &mut prod);
            // scatter [rows, mg] -> NCHW, fusing bias + epilogue per element
            for b in 0..n {
                for mi in 0..self.mg {
                    let oc = g * self.mg + mi;
                    let bias_v = self.bias.as_ref().map(|bv| bv[oc]);
                    let dst = (b * self.m + oc) * oh * ow;
                    let src0 = b * oh * ow;
                    for pix in 0..oh * ow {
                        let mut v = prod[(src0 + pix) * self.mg + mi];
                        if let Some(bv) = bias_v {
                            v += bv;
                        }
                        for e in &self.epilogue {
                            v = e.apply(v, oc);
                        }
                        out[dst + pix] = v;
                    }
                }
            }
        }
        scratch.give(cols);
        scratch.give(prod);
        Ok(Tensor::new(vec![n, self.m, oh, ow], out))
    }
}

/// How a Gemm node's `C` input is bound.
#[derive(Debug)]
pub(crate) enum GemmBias {
    /// No C input.
    None,
    /// Constant C, pre-scaled by `beta` at compile time.
    Folded(Tensor),
    /// Runtime C: arrives as the step's second runtime input.
    Runtime,
}

/// `Gemm` with a compile-time-constant `B`: `transB` applied at pack
/// time, `beta` folded into the pre-scaled bias, `alpha` applied in the
/// write-back (after the full accumulation, matching the generic op's
/// rounding order exactly), and an optional fused elementwise epilogue
/// chain applied per output element (channel = output column), the same
/// fusion [`PackedConv`] has had since PR 2.
#[derive(Debug)]
pub struct PackedGemm {
    k: usize,
    n: usize,
    bp: PackedB,
    alpha: f32,
    beta: f32,
    trans_a: bool,
    bias: GemmBias,
    epilogue: Vec<Epilogue>,
}

/// Apply a fused epilogue chain in place over row-major `[.., n]` data
/// (channel = column). Replays each stage's per-element arithmetic in
/// node order — identical to running the original elementwise nodes as
/// separate full-tensor passes.
fn apply_epilogue_columns(data: &mut [f32], n: usize, epilogue: &[Epilogue]) {
    if epilogue.is_empty() {
        return;
    }
    for (i, v) in data.iter_mut().enumerate() {
        let oc = i % n;
        for e in epilogue {
            *v = e.apply(*v, oc);
        }
    }
}

impl PackedGemm {
    /// Build from a Gemm node with constant `B` (and optionally constant
    /// `C`). `c` is `None` when the node has no C input, `Some(None)`
    /// when C exists but is a runtime value, `Some(Some(t))` when C is
    /// constant.
    pub(crate) fn try_build(
        node: &Node,
        b: &Tensor,
        c: Option<Option<&Tensor>>,
    ) -> Option<PackedGemm> {
        let alpha = node.attr_float_or("alpha", 1.0);
        let beta = node.attr_float_or("beta", 1.0);
        let trans_a = node.attr_int_or("transA", 0) != 0;
        let trans_b = node.attr_int_or("transB", 0) != 0;
        let bt: Cow<Tensor> =
            if trans_b { Cow::Owned(b.transpose(&[1, 0]).ok()?) } else { Cow::Borrowed(b) };
        if bt.rank() != 2 {
            return None;
        }
        let (k, n) = (bt.shape()[0], bt.shape()[1]);
        let bp = PackedB::pack(k, n, bt.as_f32().ok()?);
        let bias = match c {
            None => GemmBias::None,
            Some(None) => GemmBias::Runtime,
            Some(Some(ct)) => {
                let pre = if beta != 1.0 { ct.map(|v| v * beta).ok()? } else { ct.clone() };
                GemmBias::Folded(pre)
            }
        };
        Some(PackedGemm { k, n, bp, alpha, beta, trans_a, bias, epilogue: Vec::new() })
    }

    /// Reassemble from persisted parts (artifact loading).
    #[allow(clippy::too_many_arguments)]
    pub(crate) fn from_parts(
        k: usize,
        n: usize,
        bp: PackedB,
        alpha: f32,
        beta: f32,
        trans_a: bool,
        bias: GemmBias,
        epilogue: Vec<Epilogue>,
    ) -> PackedGemm {
        PackedGemm { k, n, bp, alpha, beta, trans_a, bias, epilogue }
    }

    /// `(k, n, alpha, beta, trans_a)` scalars (artifact writing).
    pub(crate) fn scalars(&self) -> (usize, usize, f32, f32, bool) {
        (self.k, self.n, self.alpha, self.beta, self.trans_a)
    }

    /// The packed B matrix (artifact writing).
    pub(crate) fn packed_b(&self) -> &PackedB {
        &self.bp
    }

    /// The C binding (artifact writing).
    pub(crate) fn bias(&self) -> &GemmBias {
        &self.bias
    }

    /// Append a fused elementwise stage (compile-time fusion pass).
    pub(crate) fn push_epilogue(&mut self, e: Epilogue) {
        self.epilogue.push(e);
    }

    /// Output features (`N`) — the channel axis the epilogue indexes.
    pub(crate) fn out_channels(&self) -> usize {
        self.n
    }

    /// The fused stage chain in application order (verifier introspection).
    pub(crate) fn epilogue(&self) -> &[Epilogue] {
        &self.epilogue
    }

    /// Number of fused epilogue stages.
    pub fn epilogue_len(&self) -> usize {
        self.epilogue.len()
    }

    /// Whether C arrives as a second runtime input (step arity 2).
    pub(crate) fn runtime_bias(&self) -> bool {
        matches!(self.bias, GemmBias::Runtime)
    }

    /// `inputs[0]` is A; `inputs[1]` (when present) is a runtime C.
    pub fn run(&self, inputs: &[&Tensor], scratch: &mut ScratchArena) -> Result<Tensor> {
        ensure!(!inputs.is_empty(), "PackedGemm wants the A tensor");
        let a: Cow<Tensor> = if self.trans_a {
            Cow::Owned(inputs[0].transpose(&[1, 0])?)
        } else {
            Cow::Borrowed(inputs[0])
        };
        ensure!(a.rank() == 2, "matmul2d wants rank-2");
        let (m, ak) = (a.shape()[0], a.shape()[1]);
        ensure!(ak == self.k, "matmul2d inner dim mismatch {ak} vs {}", self.k);
        let mut out = scratch.take(m * self.n);
        gemm_prepacked(m, self.k, &self.bp, a.as_f32()?, &mut out);
        if self.alpha != 1.0 {
            for v in out.iter_mut() {
                *v *= self.alpha;
            }
        }
        let y = Tensor::new(vec![m, self.n], out);
        let mut summed = match &self.bias {
            GemmBias::None => {
                let mut y = y;
                apply_epilogue_columns(y.as_f32_mut()?, self.n, &self.epilogue);
                return Ok(y);
            }
            GemmBias::Folded(c) => y.binary_op(c, |p, q| p + q)?,
            GemmBias::Runtime => {
                ensure!(inputs.len() >= 2, "PackedGemm wants the runtime C tensor");
                let c = inputs[1];
                let scaled: Cow<Tensor> = if self.beta != 1.0 {
                    Cow::Owned(c.map(|v| v * self.beta)?)
                } else {
                    Cow::Borrowed(c)
                };
                y.binary_op(&scaled, |p, q| p + q)?
            }
        };
        if let Some(buf) = y.into_f32_vec() {
            scratch.give(buf); // pre-bias accumulator goes back to the pool
        }
        apply_epilogue_columns(summed.as_f32_mut()?, self.n, &self.epilogue);
        Ok(summed)
    }
}

/// `MatMul` with a compile-time-constant rank-2 rhs, packed once.
/// Batched (>2-D) lhs is flattened by view — no reshape copy. An
/// optional fused elementwise epilogue applies per output element
/// (channel = last-axis column); the compile pass only fuses
/// channel-independent stages here, since a batched lhs changes which
/// axis a channel-indexed op like BatchNorm would read.
#[derive(Debug)]
pub struct PackedMatMul {
    k: usize,
    n: usize,
    bp: PackedB,
    epilogue: Vec<Epilogue>,
}

impl PackedMatMul {
    pub(crate) fn try_build(b: &Tensor) -> Option<PackedMatMul> {
        if b.rank() != 2 {
            return None;
        }
        let (k, n) = (b.shape()[0], b.shape()[1]);
        Some(PackedMatMul { k, n, bp: PackedB::pack(k, n, b.as_f32().ok()?), epilogue: Vec::new() })
    }

    /// Reassemble from persisted parts (artifact loading).
    pub(crate) fn from_parts(
        k: usize,
        n: usize,
        bp: PackedB,
        epilogue: Vec<Epilogue>,
    ) -> PackedMatMul {
        PackedMatMul { k, n, bp, epilogue }
    }

    /// `(k, n)` dims (artifact writing).
    pub(crate) fn dims(&self) -> (usize, usize) {
        (self.k, self.n)
    }

    /// The packed rhs matrix (artifact writing).
    pub(crate) fn packed_b(&self) -> &PackedB {
        &self.bp
    }

    /// Append a fused elementwise stage (compile-time fusion pass).
    pub(crate) fn push_epilogue(&mut self, e: Epilogue) {
        self.epilogue.push(e);
    }

    /// Output features (`N`) — the channel axis the epilogue indexes.
    pub(crate) fn out_channels(&self) -> usize {
        self.n
    }

    /// The fused stage chain in application order (verifier introspection).
    pub(crate) fn epilogue(&self) -> &[Epilogue] {
        &self.epilogue
    }

    /// Number of fused epilogue stages.
    pub fn epilogue_len(&self) -> usize {
        self.epilogue.len()
    }

    pub fn run(&self, a: &Tensor, scratch: &mut ScratchArena) -> Result<Tensor> {
        if a.rank() == 2 {
            let (m, ak) = (a.shape()[0], a.shape()[1]);
            ensure!(ak == self.k, "matmul2d inner dim mismatch {ak} vs {}", self.k);
            let mut out = scratch.take(m * self.n);
            gemm_prepacked(m, self.k, &self.bp, a.as_f32()?, &mut out);
            apply_epilogue_columns(&mut out, self.n, &self.epilogue);
            return Ok(Tensor::new(vec![m, self.n], out));
        }
        // batched lhs [batch.., m, k] over the shared 2-D rhs
        ensure!(
            a.rank() > 2,
            "unsupported MatMul ranks {:?} x {:?}",
            a.shape(),
            [self.k, self.n]
        );
        let ak = *a.shape().last().unwrap();
        ensure!(ak == self.k, "matmul2d inner dim mismatch {ak} vs {}", self.k);
        let rows = a.numel() / ak;
        let mut out = scratch.take(rows * self.n);
        gemm_prepacked(rows, self.k, &self.bp, a.as_f32()?, &mut out);
        apply_epilogue_columns(&mut out, self.n, &self.epilogue);
        let mut out_shape = a.shape().to_vec();
        *out_shape.last_mut().unwrap() = self.n;
        Ok(Tensor::new(out_shape, out))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ops;

    #[test]
    fn invokes_resolved_op() {
        let node = Node::new("Relu", &["x"], &["y"]);
        let k = CompiledKernel::Op(ops::kernel_for(&node).unwrap());
        let x = Tensor::new(vec![3], vec![-1.0, 0.0, 2.0]);
        let mut scratch = ScratchArena::new();
        let out = k.invoke(&node, &[&x], &mut scratch).unwrap();
        assert_eq!(out[0].as_f32().unwrap(), &[0.0, 0.0, 2.0]);
    }

    #[test]
    fn packed_conv_matches_generic_op() {
        let node = Node::new("Conv", &["x", "w", "b"], &["y"])
            .with_attr("kernel_shape", vec![3i64, 3])
            .with_attr("pads", vec![1i64, 1, 1, 1]);
        let x = Tensor::new(vec![2, 3, 5, 5], (0..150).map(|v| (v % 11) as f32 - 5.0).collect());
        let w = Tensor::new(vec![4, 3, 3, 3], (0..108).map(|v| (v % 7) as f32 - 3.0).collect());
        let b = Tensor::new(vec![4], vec![0.5, -1.0, 2.0, 0.0]);
        let want = ops::linalg::conv(&node, &[&x, &w, &b]).unwrap();
        let pc = PackedConv::try_build(&node, &w, Some(&b)).unwrap();
        let mut scratch = ScratchArena::new();
        let got = pc.run(&x, &mut scratch).unwrap();
        assert_eq!(got, want[0]);
        // second run reuses pooled scratch and still matches
        assert_eq!(pc.run(&x, &mut scratch).unwrap(), want[0]);
    }

    #[test]
    fn packed_grouped_conv_matches_generic_op() {
        let node = Node::new("Conv", &["x", "w"], &["y"])
            .with_attr("kernel_shape", vec![2i64, 2])
            .with_attr("group", 2i64);
        let x = Tensor::new(vec![1, 4, 4, 4], (0..64).map(|v| (v % 9) as f32 - 4.0).collect());
        let w = Tensor::new(vec![6, 2, 2, 2], (0..48).map(|v| (v % 5) as f32 - 2.0).collect());
        let want = ops::linalg::conv(&node, &[&x, &w]).unwrap();
        let pc = PackedConv::try_build(&node, &w, None).unwrap();
        let got = pc.run(&x, &mut ScratchArena::new()).unwrap();
        assert_eq!(got, want[0]);
    }

    #[test]
    fn packed_conv_with_quant_epilogue_matches_two_pass() {
        let conv_node = Node::new("Conv", &["x", "w"], &["c"])
            .with_attr("kernel_shape", vec![3i64, 3]);
        let quant_node = Node::new("Quant", &["c", "s", "z", "bw"], &["y"])
            .with_attr("signed", 1i64)
            .with_attr("rounding_mode", "ROUND");
        let x = Tensor::new(vec![1, 2, 6, 6], (0..72).map(|v| (v % 13) as f32 * 0.3 - 2.0).collect());
        let w = Tensor::new(vec![3, 2, 3, 3], (0..54).map(|v| (v % 5) as f32 * 0.25 - 0.5).collect());
        let s = Tensor::scalar(0.5);
        let z = Tensor::scalar(0.0);
        let bw = Tensor::scalar(4.0);
        let conv_out = ops::linalg::conv(&conv_node, &[&x, &w]).unwrap();
        let want = ops::quant::quant_op(&quant_node, &[&conv_out[0], &s, &z, &bw]).unwrap();
        let mut pc = PackedConv::try_build(&conv_node, &w, None).unwrap();
        let resolve = |name: &str| match name {
            "s" => Some(&s),
            "z" => Some(&z),
            "bw" => Some(&bw),
            _ => None,
        };
        let ep = Epilogue::try_build(&quant_node, resolve, pc.out_channels()).unwrap();
        pc.push_epilogue(ep);
        let got = pc.run(&x, &mut ScratchArena::new()).unwrap();
        assert_eq!(got, want[0]);
    }

    #[test]
    fn packed_gemm_matches_generic_op() {
        let node = Node::new("Gemm", &["a", "b", "c"], &["y"])
            .with_attr("alpha", 2.0f32)
            .with_attr("beta", 3.0f32)
            .with_attr("transA", 1i64)
            .with_attr("transB", 1i64);
        let a = Tensor::new(vec![3, 2], (0..6).map(|v| v as f32 * 0.7 - 1.0).collect());
        let b = Tensor::new(vec![4, 3], (0..12).map(|v| (v % 5) as f32 - 2.0).collect());
        let c = Tensor::new(vec![1, 4], vec![1.0, -1.0, 0.5, 2.0]);
        let want = ops::linalg::gemm_op(&node, &[&a, &b, &c]).unwrap();
        let pg = PackedGemm::try_build(&node, &b, Some(Some(&c))).unwrap();
        let got = pg.run(&[&a], &mut ScratchArena::new()).unwrap();
        assert_eq!(got, want[0]);
    }

    #[test]
    fn packed_gemm_with_quant_epilogue_matches_two_pass() {
        let gemm_node = Node::new("Gemm", &["a", "b", "c"], &["g"]).with_attr("beta", 2.0f32);
        let quant_node = Node::new("Quant", &["g", "s", "z", "bw"], &["y"])
            .with_attr("signed", 1i64)
            .with_attr("rounding_mode", "ROUND");
        let a = Tensor::new(vec![3, 5], (0..15).map(|v| (v % 7) as f32 * 0.4 - 1.0).collect());
        let b = Tensor::new(vec![5, 4], (0..20).map(|v| (v % 9) as f32 * 0.3 - 1.2).collect());
        let c = Tensor::new(vec![1, 4], vec![0.5, -0.5, 1.0, 0.0]);
        let s = Tensor::scalar(0.25);
        let z = Tensor::scalar(0.0);
        let bw = Tensor::scalar(4.0);
        let g_out = ops::linalg::gemm_op(&gemm_node, &[&a, &b, &c]).unwrap();
        let want = ops::quant::quant_op(&quant_node, &[&g_out[0], &s, &z, &bw]).unwrap();
        let mut pg = PackedGemm::try_build(&gemm_node, &b, Some(Some(&c))).unwrap();
        let resolve = |name: &str| match name {
            "s" => Some(&s),
            "z" => Some(&z),
            "bw" => Some(&bw),
            _ => None,
        };
        let ep = Epilogue::try_build(&quant_node, resolve, pg.out_channels()).unwrap();
        pg.push_epilogue(ep);
        assert_eq!(pg.epilogue_len(), 1);
        let got = pg.run(&[&a], &mut ScratchArena::new()).unwrap();
        assert_eq!(got, want[0]);
    }

    #[test]
    fn packed_matmul_with_relu_epilogue_matches_two_pass_batched() {
        let node = Node::new("MatMul", &["a", "b"], &["m"]);
        let b = Tensor::new(vec![3, 4], (0..12).map(|v| v as f32 - 6.0).collect());
        let a3 = Tensor::new(vec![2, 2, 3], (0..12).map(|v| v as f32 * 0.25 - 1.0).collect());
        let m_out = ops::linalg::matmul(&node, &[&a3, &b]).unwrap();
        let relu_node = Node::new("Relu", &["m"], &["y"]);
        let want = ops::eltwise::relu(&relu_node, &[&m_out[0]]).unwrap();
        let mut pm = PackedMatMul::try_build(&b).unwrap();
        pm.push_epilogue(Epilogue::Relu);
        let got = pm.run(&a3, &mut ScratchArena::new()).unwrap();
        assert_eq!(got, want[0]);
    }

    #[test]
    fn packed_matmul_matches_generic_including_batched() {
        let node = Node::new("MatMul", &["a", "b"], &["y"]);
        let b = Tensor::new(vec![3, 4], (0..12).map(|v| v as f32 - 6.0).collect());
        let pm = PackedMatMul::try_build(&b).unwrap();
        let a2 = Tensor::new(vec![2, 3], (0..6).map(|v| v as f32 * 0.5).collect());
        let want = ops::linalg::matmul(&node, &[&a2, &b]).unwrap();
        assert_eq!(pm.run(&a2, &mut ScratchArena::new()).unwrap(), want[0]);
        let a3 = Tensor::new(vec![2, 2, 3], (0..12).map(|v| v as f32 * 0.25 - 1.0).collect());
        let want3 = ops::linalg::matmul(&node, &[&a3, &b]).unwrap();
        assert_eq!(pm.run(&a3, &mut ScratchArena::new()).unwrap(), want3[0]);
    }

    #[test]
    fn batch_reshape_preserves_leading_dim() {
        // fallback mode: the original [1, 6] wins whenever it resolves
        let br = BatchReshape::new(&[1, 6], true);
        let x1 = Tensor::new(vec![1, 2, 3], (0..6).map(|v| v as f32).collect());
        let y1 = br.run(&x1).unwrap();
        assert_eq!(y1.shape(), &[1, 6]);
        assert_eq!(y1.as_f32().unwrap(), x1.as_f32().unwrap());
        // ... and a batch the original rejects takes the batched form
        let x4 = Tensor::new(vec![4, 2, 3], (0..24).map(|v| v as f32).collect());
        let y4 = br.run(&x4).unwrap();
        assert_eq!(y4.shape(), &[4, 6]);
        assert_eq!(y4.as_f32().unwrap(), x4.as_f32().unwrap());
        // wildcard targets run the batched form unconditionally
        let brw = BatchReshape::new(&[1, -1], false);
        let y = brw.run(&x4).unwrap();
        assert_eq!(y.shape(), &[4, 6]);
        let y = brw.run(&x1).unwrap();
        assert_eq!(y.shape(), &[1, 6]);
        // element-count mismatches still error
        assert!(br.run(&Tensor::new(vec![1, 5], vec![0.0; 5])).is_err());
    }

    #[test]
    fn unsupported_shapes_decline_packing() {
        // NHWC conv wrapper stays generic
        let nhwc = Node::new("Conv", &["x", "w"], &["y"])
            .with_attr("data_layout", "NHWC")
            .with_attr("kernel_shape", vec![1i64, 1]);
        let w = Tensor::zeros(vec![1, 1, 1, 1]);
        assert!(PackedConv::try_build(&nhwc, &w, None).is_none());
        // rank-3 rhs declines MatMul packing
        assert!(PackedMatMul::try_build(&Tensor::zeros(vec![2, 2, 2])).is_none());
        // i64 weights decline
        assert!(PackedMatMul::try_build(&Tensor::new_i64(vec![1, 1], vec![1])).is_none());
    }
}

//! Compiled execution plans: the slot-indexed, arena-backed graph
//! executor.
//!
//! The reference interpreter ([`crate::exec::interpret_with`]) re-derives
//! everything per request: it clones/looks up tensors through a
//! `BTreeMap<String, Tensor>`, recomputes the topological order, and
//! string-matches `op_type` on every node. Following the compiler-approach
//! literature (Jain et al. 2020; FINN-R's backend-agnostic schedules), this
//! module lowers a [`ModelGraph`] *once* into an [`ExecutionPlan`]:
//!
//! * **names → slots** — every tensor is resolved at compile time to a
//!   dense physical slot index; the hot loop indexes a flat vector.
//! * **frozen schedule** — the topological order is computed once and
//!   stored as a step table.
//! * **resolved dispatch** — each node's kernel is looked up once and
//!   stored as a [`CompiledKernel`] function pointer
//!   (see [`crate::ops::kernel_for`]).
//! * **constant preloads** — initializers are *borrowed* from the graph
//!   (or held by `Arc` in an owned plan) instead of being cloned into the
//!   context per call; whole constant subgraphs — including the weight
//!   `Quant` nodes that [`crate::transforms::fold_constants`] deliberately
//!   leaves in the graph representation — are evaluated at compile time,
//!   so quantized weights are computed once, not per request.
//! * **identity elision** — single-input `Identity` nodes become slot
//!   aliases; no runtime step is emitted.
//! * **buffer lifetimes** — a last-use pass releases each slot after its
//!   final read and recycles it through a [`SlotArena`], so intermediate
//!   tensors are freed mid-run and peak live memory is the schedule's
//!   high-water mark, not the tensor count.
//!
//! # Kernel specialization tiers
//!
//! Each node lands on the strongest tier its operands allow:
//!
//! 1. **Folded** — all inputs are compile-time constants: the node runs
//!    once at compile time and its outputs become resident constants
//!    (this is how weight-quantizer subgraphs vanish from the schedule).
//! 2. **Quantized** — the node's constant weights fit `i8` *and* the
//!    value-range proofs from [`crate::transforms::infer_ranges`] show
//!    its runtime input on a literal integer grid (the form
//!    [`crate::streamline`] produces): `Conv`/`Gemm`/`MatMul` lower to
//!    integer-domain kernels ([`qkernel::QuantConv`] & co. — `i8` weight
//!    panels, `i32` accumulation, a sole-consumer `MultiThreshold`
//!    fused as the scatter-loop epilogue). Accumulators are bounded
//!    below `2^24` at compile time, so the integer result is exactly the
//!    float result: this tier is byte-identical on the graphs it accepts.
//! 3. **Packed (+ fused)** — the node's *weight* operands are constants
//!    but its data input is runtime float: `Conv`/`Gemm`/`MatMul` become
//!    stateful prepacked kernels ([`kernel::PackedConv`],
//!    [`kernel::PackedGemm`], [`kernel::PackedMatMul`]) with hyper-params
//!    resolved once and weights transposed/panel-packed once
//!    ([`crate::tensor::PackedB`]); all three absorb chains of
//!    sole-consumer elementwise stages (BatchNorm, Quant, BipolarQuant,
//!    Relu) into their write-back epilogues, deleting those steps from
//!    the schedule (MatMul, whose output rank is batch-dependent, only
//!    absorbs channel-independent stages).
//! 4. **Generic** — everything else dispatches through the registry
//!    function pointer resolved at compile time.
//!
//! All tiers are bit-exact with the reference interpreter: the packed
//! GEMM keeps the interpreter's ascending-k accumulation order, each
//! fused epilogue replays the generic op's per-element arithmetic, and
//! the quantized tier's integer math is exact under its `2^24` bound
//! (`tests/plan_equiv.rs` asserts byte equality across the zoo).
//!
//! # Dtype-aware slots (integer residency)
//!
//! Plan slots carry a container type ([`crate::tensor::DType`]): the
//! residency pass (`compile.rs::plan_residency`) proves, per runtime
//! value, the narrowest container every consumer accepts, and the
//! quantized tier then keeps activations **resident** in `i8`/`i32`
//! between layers — a streamlined `MultiThreshold` (fused epilogue or
//! the standalone [`qkernel::ThresholdKernel`]) writes integer levels
//! straight into integer storage, pass-through ops (`MaxPool`,
//! `Reshape`, `Relu`, ...) carry them unchanged, and the next
//! `QuantConv`/`QuantGemm` consumes them directly (`i8` activation
//! panels — no f32 detour, no per-element grid re-validation).
//! Containers convert only at tier boundaries, inside the boundary
//! kernels: the graph-input `MultiThreshold` ingests f32, and any
//! quantized kernel feeding a float consumer (the residual de-scale
//! `Mul`, a graph output, a float-tier neighbor) emits f32 in its
//! scatter loop. Because the emitted integers are exactly representable
//! in f32 (the `2^24` bound), residency changes *traffic*, not values —
//! byte-identity with the interpreter is preserved. Slot recycling and
//! the [`ScratchArena`] pools are dtype-keyed, so an `i8` buffer is
//! never handed back as `f32` storage;
//! [`ExecutionPlan::slot_dtypes`] exposes the resulting table.
//!
//! # Batch-symbolic plans
//!
//! Compilation additionally rewrites batch-1-baked constant `Reshape`
//! targets (the conv-net flatten chains of the paper's Fig. 1/2) into
//! batch-preserving [`kernel::BatchReshape`] kernels, making the plan
//! *symbolic over the leading batch dim*: every other kernel — packed
//! conv/matmul, pools, elementwise — already iterates over the leading
//! axis against the same packed weights. A plan compiled from a batch-1
//! graph therefore executes `[n, c, h, w]` batches in ONE invocation
//! when run under [`ShapeCheck::FreeBatch`] (rank and trailing dims
//! still validated; [`ShapeCheck::Exact`] keeps interpreter error
//! parity for the one-shot wrapper). This is what lets
//! [`crate::coordinator::PlannedEngine`] serve batched conv-net
//! requests natively instead of looping per sample at the NCHW edge,
//! and — because the plan is immutable after compile — what lets
//! sharded batcher workers share one `Arc`'d plan (packed weights
//! resident once) with only a per-worker [`ScratchArena`].
//!
//! # Arena scratch contract
//!
//! Kernels receive a `&mut` [`ScratchArena`] at invocation and draw
//! *all* working memory from it: im2col matrices, GEMM accumulators and
//! output buffers come from [`ScratchArena::take`] and transient buffers
//! go back via [`ScratchArena::give`]. The executor closes the loop by
//! returning each released intermediate's storage to the same arena, so
//! kernel scratch on a warm plan reaches a zero-allocation steady state
//! (buffers that leave as graph outputs, and per-run bookkeeping, still
//! allocate). [`ExecutionPlan::run_cfg_scratch`] lets engines keep one
//! arena across requests ([`crate::coordinator::PlannedEngine`] does);
//! `run`/`run_cfg` use a per-call arena.
//!
//! The same plan serves every scenario (QONNX, QCDQ, quantized-op and
//! FINN graphs alike): [`crate::exec::execute_with`] is a thin wrapper
//! that compiles a borrowed plan per call, while
//! [`crate::coordinator::PlannedEngine`] compiles once (owned, `'static`)
//! and serves any batch size through the batcher.

pub mod arena;
pub mod artifact;
mod compile;
pub mod kernel;
pub mod qkernel;

pub use arena::{ScratchArena, ScratchCounters, SlotArena};
pub use kernel::CompiledKernel;
pub(crate) use compile::residency_passthrough;

use crate::ir::{ModelGraph, Node};
use crate::tensor::{DType, Tensor};
use anyhow::{anyhow, bail, Context, Result};
use std::borrow::Cow;
use std::collections::BTreeMap;
use std::fmt::Write as _;
use std::sync::Arc;
use std::time::Instant;

/// Plan compilation options.
#[derive(Debug, Clone)]
pub struct PlanOptions {
    /// Reject QONNX/FINN-domain nodes — emulates a stock ONNX backend
    /// (same semantics as [`crate::exec::ExecOptions::standard_onnx_only`]).
    pub standard_onnx_only: bool,
    /// Lower constant-weight `Conv`/`Gemm`/`MatMul` nodes to prepacked
    /// kernels (tier 2). Disable to get the PR-1-style generic-dispatch
    /// plan (the benchmark baseline).
    pub specialize: bool,
    /// Absorb sole-consumer elementwise stages into a packed conv's
    /// scatter-loop epilogue. Implies nothing unless `specialize` is on.
    /// Callers that need every intermediate recorded by name disable
    /// this (fused steps only record their final output).
    pub fuse_epilogues: bool,
    /// Rewrite batch-1-baked constant `Reshape` targets (conv-net
    /// flatten chains) into batch-preserving [`kernel::BatchReshape`]
    /// kernels, making the compiled plan symbolic over the leading batch
    /// dim. Independent of `specialize`; bit-identical at declared
    /// shapes (see [`kernel::BatchReshape`] for the exact contract).
    pub batch_symbolic: bool,
    /// Lower integer-proven `Conv`/`Gemm`/`MatMul` nodes to the
    /// quantized `i8`/`i32` kernel tier ([`qkernel`]). Only applies
    /// where [`crate::transforms::infer_ranges`] proves a literal
    /// integer grid, so it is a no-op on ordinary float graphs.
    /// Requires `specialize` (the generic baseline disables both).
    pub quantize: bool,
    /// Keep quantized-tier activations **resident in integer
    /// containers** between kernels: the residency pass assigns each
    /// plan slot the container its value provably fits (`i8`/`i32`),
    /// `MultiThreshold` emissions land there directly, and the next
    /// integer kernel consumes them with no float detour. Disable for
    /// the convert-per-call baseline (the PR-4 behavior) or when every
    /// intermediate must be observable as f32
    /// ([`crate::exec::ExecOptions::keep_intermediates`] does).
    /// Requires `quantize`; a no-op on graphs without integer proofs.
    pub int_residency: bool,
    /// Run the static plan verifier ([`crate::verify`]) over the freshly
    /// compiled plan and fail compilation on any `Error`-severity
    /// diagnostic. Defaults to **on in debug builds** — every plan the
    /// unit suite compiles is re-proved — and off in release, where
    /// verification is explicit (`qonnx verify`, `plan --verify`, the
    /// `verify_zoo` suite).
    pub verify: bool,
}

impl Default for PlanOptions {
    fn default() -> PlanOptions {
        PlanOptions {
            standard_onnx_only: false,
            specialize: true,
            fuse_epilogues: true,
            batch_symbolic: true,
            quantize: true,
            int_residency: true,
            verify: cfg!(debug_assertions),
        }
    }
}

/// How bound inputs are validated against the graph's declared shapes.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ShapeCheck {
    /// Inputs must match declared shapes exactly (interpreter error
    /// parity — the default).
    Exact,
    /// The leading (batch) axis is free; rank and trailing dims must
    /// match. This is the batched-serving mode: a batch-symbolic plan
    /// compiled from a batch-1 graph accepts `[n, …]` inputs.
    FreeBatch,
    /// No validation. For engines re-batching arbitrary graphs where
    /// the kernels themselves enforce shape agreement.
    Skip,
}

/// Per-run configuration.
#[derive(Debug, Clone)]
pub struct RunConfig {
    /// Input-shape validation mode (see [`ShapeCheck`]).
    pub shape_check: ShapeCheck,
    /// Record every loaded/computed tensor by name (shape inference and
    /// debugging). Includes preloads, step outputs, compile-time-folded
    /// constants and identity aliases. Initializers consumed *only* by
    /// folded subgraphs are not part of the plan; callers that need full
    /// interpreter-context parity overlay `graph.initializers` themselves
    /// (as [`crate::exec::execute_with`] does).
    pub record_intermediates: bool,
}

impl Default for RunConfig {
    fn default() -> RunConfig {
        RunConfig { shape_check: ShapeCheck::Exact, record_intermediates: false }
    }
}

/// A constant resident in the plan: borrowed from the source graph, or
/// shared by `Arc` once the plan is made owning (see
/// [`ExecutionPlan::into_owned`]). Either way it is *never* cloned per run.
#[derive(Debug, Clone)]
pub(crate) enum PlanConst<'g> {
    Borrowed(&'g Tensor),
    Shared(Arc<Tensor>),
}

impl PlanConst<'_> {
    pub(crate) fn as_tensor(&self) -> &Tensor {
        match self {
            PlanConst::Borrowed(t) => t,
            PlanConst::Shared(a) => a,
        }
    }

    fn into_shared(self) -> PlanConst<'static> {
        match self {
            PlanConst::Borrowed(t) => PlanConst::Shared(Arc::new(t.clone())),
            PlanConst::Shared(a) => PlanConst::Shared(a),
        }
    }
}

/// A run-time slot value: borrowed (preloaded constants, caller inputs)
/// or owned (node outputs). Borrowing is what lets both executors avoid
/// cloning weights per request.
#[derive(Debug)]
pub enum RtVal<'a> {
    Ref(&'a Tensor),
    Owned(Tensor),
}

impl RtVal<'_> {
    #[inline]
    pub fn tensor(&self) -> &Tensor {
        match self {
            RtVal::Ref(t) => t,
            RtVal::Owned(t) => t,
        }
    }

    pub fn into_tensor(self) -> Tensor {
        match self {
            RtVal::Ref(t) => t.clone(),
            RtVal::Owned(t) => t,
        }
    }
}

/// One scheduled node execution.
#[derive(Debug, Clone)]
pub(crate) struct Step {
    /// Index into the plan's node table (error context / dispatch).
    pub(crate) node_idx: usize,
    /// Node whose declared outputs this step produces — differs from
    /// `node_idx` when an epilogue chain was fused into the kernel.
    pub(crate) out_node_idx: usize,
    pub(crate) kernel: CompiledKernel,
    /// Slot of each runtime input (packed kernels bake constants in).
    pub(crate) inputs: Vec<u32>,
    /// Slot per declared output; `None` for dead outputs (dropped at once).
    pub(crate) outputs: Vec<Option<u32>>,
    /// Slots whose last use is this step — cleared after the kernel runs,
    /// before outputs are stored (an output may reuse a released slot).
    pub(crate) release: Vec<u32>,
}

/// A constant bound to a slot at the start of every run.
#[derive(Debug, Clone)]
pub(crate) struct Preload<'g> {
    pub(crate) name: String,
    pub(crate) slot: u32,
    pub(crate) value: PlanConst<'g>,
}

/// A graph input binding: checked (and stored, if used) at run start.
#[derive(Debug, Clone)]
pub(crate) struct PlanInput {
    pub(crate) name: String,
    pub(crate) shape: Option<Vec<usize>>,
    /// `None` when no runtime step (or output) reads the input — it is
    /// still required and shape-checked, but not stored.
    pub(crate) slot: Option<u32>,
}

/// A graph output: extracted from its slot at the end of the run.
#[derive(Debug, Clone)]
pub(crate) struct PlanOutput {
    pub(crate) name: String,
    pub(crate) slot: u32,
}

/// A compiled, immutable execution schedule for one [`ModelGraph`].
///
/// Borrowed plans (`ExecutionPlan<'g>`) reference the graph's nodes and
/// initializers directly — compiling one performs no tensor copies.
/// [`ExecutionPlan::into_owned`] detaches the plan from the graph
/// (`'static`), cloning each referenced constant exactly once into an
/// `Arc` so engines can cache the plan and share it across calls.
#[derive(Debug, Clone)]
pub struct ExecutionPlan<'g> {
    pub(crate) name: String,
    pub(crate) nodes: Cow<'g, [Node]>,
    pub(crate) steps: Vec<Step>,
    pub(crate) preloads: Vec<Preload<'g>>,
    pub(crate) inputs: Vec<PlanInput>,
    pub(crate) outputs: Vec<PlanOutput>,
    pub(crate) slot_count: usize,
    /// Container type per physical slot (index = slot id). Slot
    /// assignment is dtype-keyed, so this is a static property of the
    /// schedule: an `i8` slot only ever holds `i8` values.
    pub(crate) slot_dtypes: Vec<DType>,
    /// Best-known element count per slot (max over the values assigned
    /// to it, from declared/inferred shapes; `None` when unannotated).
    pub(crate) slot_numel: Vec<Option<usize>>,
    /// All compile-time-folded node outputs by name (for intermediates
    /// recording; `Arc`-shared with any preloads that use them).
    pub(crate) folded_outputs: Vec<(String, Arc<Tensor>)>,
    /// Elided `Identity` outputs: alias name → canonical runtime name.
    pub(crate) alias_outputs: Vec<(String, String)>,
    pub(crate) node_count: usize,
    pub(crate) folded_count: usize,
    pub(crate) elided_count: usize,
    pub(crate) packed_count: usize,
    pub(crate) quant_count: usize,
    pub(crate) fused_count: usize,
    pub(crate) resident_int_count: usize,
    pub(crate) batch_symbolic_count: usize,
    /// Reasons this plan can never serve a leading batch larger than its
    /// declared shapes (constant reshape targets that bake a batch).
    pub(crate) batch_blockers: Vec<String>,
}

/// Result of a plan run.
#[derive(Debug)]
pub struct PlanRunResult {
    pub outputs: BTreeMap<String, Tensor>,
    pub intermediates: BTreeMap<String, Tensor>,
}

/// One executed schedule step's measurements, recorded by a
/// [`StepObserver`] during [`ExecutionPlan::run_profiled`].
///
/// `wall_ns` covers the full step — input gather, kernel invocation,
/// slot release and output store — so summed samples account for the
/// whole hot loop. The arena counters are deltas of
/// [`ScratchArena::counters`] across the step (fused-epilogue scratch
/// included), which is what lets the profiler show whether a warm plan
/// actually reached its zero-allocation steady state.
#[derive(Debug, Clone)]
pub struct StepSample {
    /// Schedule step index.
    pub step: usize,
    /// Name of the dispatch node (a fused chain reports its head).
    pub node_name: String,
    /// The dispatch node's `op_type`.
    pub op_type: String,
    /// Kernel display tag (same string as [`ExecutionPlan::summary`]).
    pub kernel: String,
    /// Full-step wall time, nanoseconds (monotonic clock).
    pub wall_ns: u64,
    /// Fresh scratch-arena allocations during the step.
    pub arena_allocs: u64,
    /// Scratch-arena pool reuses during the step.
    pub arena_reuses: u64,
}

/// Collects [`StepSample`]s across one or more profiled runs
/// ([`ExecutionPlan::run_profiled`]) and optionally mirrors each step
/// into a [`crate::trace::TraceRecorder`] as an `exec`-category
/// complete event (one per step per run, timeline-placed).
///
/// The plain execution paths ([`ExecutionPlan::run_cfg_scratch`] & co.)
/// never construct one — profiling cost is strictly opt-in and the
/// unprofiled hot loop only tests an `Option` that is statically `None`.
#[derive(Debug, Default)]
pub struct StepObserver {
    samples: Vec<StepSample>,
    trace: Option<Arc<crate::trace::TraceRecorder>>,
}

impl StepObserver {
    /// Observer that aggregates samples only (no trace events).
    pub fn new() -> StepObserver {
        StepObserver::default()
    }

    /// Observer that additionally emits an `exec`-category complete
    /// event per step into `trace` (the recorder's clock timestamps the
    /// events, so they interleave with serving-lifecycle spans).
    pub fn with_trace(trace: Arc<crate::trace::TraceRecorder>) -> StepObserver {
        StepObserver { samples: Vec::new(), trace: Some(trace) }
    }

    /// Samples recorded so far (all runs, in execution order).
    pub fn samples(&self) -> &[StepSample] {
        &self.samples
    }

    /// Consume the observer, returning its samples.
    pub fn into_samples(self) -> Vec<StepSample> {
        self.samples
    }

    fn observe(
        &mut self,
        step: usize,
        node: &Node,
        kernel: String,
        wall_ns: u64,
        d: ScratchCounters,
    ) {
        if let Some(t) = &self.trace {
            let end = t.now_ns();
            t.complete(
                "exec",
                kernel.clone(),
                end.saturating_sub(wall_ns),
                wall_ns,
                &[("step", step as i64), ("arena_allocs", d.fresh_allocs as i64)],
            );
        }
        self.samples.push(StepSample {
            step,
            node_name: node.name.clone(),
            op_type: node.op_type.clone(),
            kernel,
            wall_ns,
            arena_allocs: d.fresh_allocs,
            arena_reuses: d.pool_reuses,
        });
    }
}

impl<'g> ExecutionPlan<'g> {
    /// Compile `graph` with default options.
    pub fn compile(graph: &'g ModelGraph) -> Result<ExecutionPlan<'g>> {
        Self::compile_with(graph, &PlanOptions::default())
    }

    /// Compile `graph` with explicit options. When [`PlanOptions::verify`]
    /// is set (the debug-build default), the compiled plan is handed to
    /// the static verifier and any `Error`-severity diagnostic fails the
    /// compile — a plan the verifier rejects never reaches an executor.
    pub fn compile_with(graph: &'g ModelGraph, opts: &PlanOptions) -> Result<ExecutionPlan<'g>> {
        let plan = compile::compile(graph, opts)?;
        if opts.verify {
            let report = crate::verify::verify_plan(&plan, graph);
            if report.has_errors() {
                bail!(
                    "plan verification failed for '{}':\n{}",
                    plan.name(),
                    report.render()
                );
            }
        }
        Ok(plan)
    }

    /// Detach the plan from its source graph: each borrowed constant is
    /// cloned exactly once into an `Arc`. The result can be cached and
    /// reused for the engine's lifetime with zero per-call weight copies.
    pub fn into_owned(self) -> ExecutionPlan<'static> {
        ExecutionPlan {
            name: self.name,
            nodes: Cow::Owned(self.nodes.into_owned()),
            steps: self.steps,
            preloads: self
                .preloads
                .into_iter()
                .map(|p| Preload { name: p.name, slot: p.slot, value: p.value.into_shared() })
                .collect(),
            inputs: self.inputs,
            outputs: self.outputs,
            slot_count: self.slot_count,
            slot_dtypes: self.slot_dtypes,
            slot_numel: self.slot_numel,
            folded_outputs: self.folded_outputs,
            alias_outputs: self.alias_outputs,
            node_count: self.node_count,
            folded_count: self.folded_count,
            elided_count: self.elided_count,
            packed_count: self.packed_count,
            quant_count: self.quant_count,
            fused_count: self.fused_count,
            resident_int_count: self.resident_int_count,
            batch_symbolic_count: self.batch_symbolic_count,
            batch_blockers: self.batch_blockers,
        }
    }

    pub fn name(&self) -> &str {
        &self.name
    }

    /// Runtime steps (after folding and elision).
    pub fn step_count(&self) -> usize {
        self.steps.len()
    }

    /// Physical slots — the high-water mark of simultaneously-live tensors.
    pub fn slot_count(&self) -> usize {
        self.slot_count
    }

    /// Nodes evaluated at compile time (constant subgraphs).
    pub fn folded_count(&self) -> usize {
        self.folded_count
    }

    /// `Identity` nodes elided into slot aliases.
    pub fn elided_count(&self) -> usize {
        self.elided_count
    }

    /// Constants bound to slots at run start (no per-run copies).
    pub fn preload_count(&self) -> usize {
        self.preloads.len()
    }

    /// Steps running a specialized prepacked float kernel.
    pub fn packed_count(&self) -> usize {
        self.packed_count
    }

    /// Steps running an integer-domain quantized kernel
    /// ([`qkernel::QuantConv`] / [`qkernel::QuantGemm`] /
    /// [`qkernel::QuantMatMul`]).
    pub fn quant_kernel_count(&self) -> usize {
        self.quant_count
    }

    /// Elementwise nodes absorbed into kernel epilogues (packed-float
    /// chains and `MultiThreshold` stages fused into quantized kernels).
    pub fn fused_epilogue_count(&self) -> usize {
        self.fused_count
    }

    /// Runtime values the residency pass keeps in integer containers
    /// (`i8`/`i32`) between kernels instead of f32.
    pub fn resident_int_count(&self) -> usize {
        self.resident_int_count
    }

    /// Container type per physical slot (index = slot id). Dtype-keyed
    /// slot recycling makes this a static fact of the schedule.
    pub fn slot_dtypes(&self) -> &[DType] {
        &self.slot_dtypes
    }

    /// Per-step view for reports/tests: display tag plus the output slot
    /// (if any) of each declared output.
    pub fn step_table(&self) -> Vec<(String, Vec<Option<u32>>)> {
        self.steps
            .iter()
            .map(|s| (s.kernel.tag(&self.nodes[s.node_idx]), s.outputs.clone()))
            .collect()
    }

    /// Why this plan can never serve a leading batch beyond its declared
    /// shapes: constant `Reshape` targets the batch-symbolic pass could
    /// not rewrite (baked batch > 1, wildcard without inferred shapes,
    /// positional copy-dims). Empty for batchable plans. Engines that
    /// promise batched serving check this at construction
    /// ([`crate::coordinator::PlannedEngine`] fails loudly on it).
    pub fn batch_blockers(&self) -> &[String] {
        &self.batch_blockers
    }

    /// `Reshape` nodes rewritten batch-preserving by the batch-symbolic
    /// pass. When > 0 (or the graph needed no rewrites), the plan serves
    /// any leading batch through [`ShapeCheck::FreeBatch`].
    pub fn batch_symbolic_count(&self) -> usize {
        self.batch_symbolic_count
    }

    /// Execute on named inputs, returning the graph outputs.
    pub fn run(&self, inputs: &BTreeMap<String, Tensor>) -> Result<BTreeMap<String, Tensor>> {
        Ok(self.run_cfg(|n| inputs.get(n), &RunConfig::default())?.outputs)
    }

    /// Execute with explicit configuration and a caller-controlled input
    /// binding (lets engines bind a batch tensor without cloning it into a
    /// map). Uses a fresh per-call scratch arena.
    pub fn run_cfg<'a>(
        &'a self,
        fetch: impl Fn(&str) -> Option<&'a Tensor>,
        cfg: &RunConfig,
    ) -> Result<PlanRunResult> {
        self.run_cfg_scratch(fetch, cfg, &mut ScratchArena::new())
    }

    /// Execute with a caller-owned [`ScratchArena`]. Engines that serve
    /// repeated requests keep one arena alive so kernel scratch and
    /// recycled intermediate buffers reach a zero-allocation steady
    /// state across calls.
    pub fn run_cfg_scratch<'a>(
        &'a self,
        fetch: impl Fn(&str) -> Option<&'a Tensor>,
        cfg: &RunConfig,
        scratch: &mut ScratchArena,
    ) -> Result<PlanRunResult> {
        self.run_inner(fetch, cfg, scratch, None)
    }

    /// Execute under a [`StepObserver`]: identical semantics (and
    /// result) to [`ExecutionPlan::run_cfg_scratch`], but every
    /// schedule step additionally records a [`StepSample`] — wall
    /// time, kernel tag, arena alloc-vs-reuse deltas — into `obs`,
    /// and, when the observer carries a trace recorder, an
    /// `exec`-category timeline event. Feed the accumulated samples to
    /// [`crate::trace::profile::StepProfile::build`] for the
    /// GMAC/s-vs-Eq.-5 join. The unprofiled paths share this body with
    /// a statically-`None` observer, so they pay one branch per step.
    pub fn run_profiled<'a>(
        &'a self,
        fetch: impl Fn(&str) -> Option<&'a Tensor>,
        cfg: &RunConfig,
        scratch: &mut ScratchArena,
        obs: &mut StepObserver,
    ) -> Result<PlanRunResult> {
        self.run_inner(fetch, cfg, scratch, Some(obs))
    }

    fn run_inner<'a>(
        &'a self,
        fetch: impl Fn(&str) -> Option<&'a Tensor>,
        cfg: &RunConfig,
        scratch: &mut ScratchArena,
        mut obs: Option<&mut StepObserver>,
    ) -> Result<PlanRunResult> {
        let mut slots: Vec<Option<RtVal<'a>>> = Vec::with_capacity(self.slot_count);
        slots.resize_with(self.slot_count, || None);
        let mut intermediates: BTreeMap<String, Tensor> = BTreeMap::new();

        // Bind resident constants (borrow — never cloned).
        for p in &self.preloads {
            slots[p.slot as usize] = Some(RtVal::Ref(p.value.as_tensor()));
            if cfg.record_intermediates {
                intermediates.insert(p.name.clone(), p.value.as_tensor().clone());
            }
        }
        // Bind caller inputs (same error surface as the interpreter).
        for pi in &self.inputs {
            let t = fetch(&pi.name)
                .with_context(|| format!("missing input tensor '{}'", pi.name))?;
            if let Some(shape) = &pi.shape {
                let ok = match cfg.shape_check {
                    ShapeCheck::Skip => true,
                    ShapeCheck::Exact => t.shape() == shape.as_slice(),
                    // leading (batch) axis free, rank + trailing dims
                    // fixed; never stricter than Exact (scalars pass)
                    ShapeCheck::FreeBatch => {
                        t.shape() == shape.as_slice()
                            || (!shape.is_empty()
                                && t.rank() == shape.len()
                                && t.shape()[1..] == shape[1..])
                    }
                };
                if !ok {
                    bail!(
                        "input '{}' shape {:?} does not match declared {:?}{}",
                        pi.name,
                        t.shape(),
                        shape,
                        if cfg.shape_check == ShapeCheck::FreeBatch {
                            " (batch axis free)"
                        } else {
                            ""
                        }
                    );
                }
            }
            if let Some(slot) = pi.slot {
                slots[slot as usize] = Some(RtVal::Ref(t));
            }
            if cfg.record_intermediates {
                intermediates.insert(pi.name.clone(), t.clone());
            }
        }

        // The hot loop: slot-indexed, dispatch pre-resolved, scratch
        // drawn from (and released intermediates recycled into) the arena.
        for (step_idx, step) in self.steps.iter().enumerate() {
            let node = &self.nodes[step.node_idx];
            // Profiling probe: one `Option` test on the unprofiled path.
            let probe = if obs.is_some() {
                Some((Instant::now(), scratch.counters()))
            } else {
                None
            };
            let mut ins: Vec<&Tensor> = Vec::with_capacity(step.inputs.len());
            for &sl in &step.inputs {
                ins.push(
                    slots[sl as usize]
                        .as_ref()
                        .ok_or_else(|| {
                            anyhow!("plan invariant violated: empty slot {sl} feeding node '{}'", node.name)
                        })?
                        .tensor(),
                );
            }
            let outs = step
                .kernel
                .invoke(node, &ins, scratch)
                .with_context(|| format!("executing node '{}' ({})", node.name, node.op_type))?;
            // fused steps produce the *last* absorbed node's outputs
            let out_node = &self.nodes[step.out_node_idx];
            if outs.len() != out_node.outputs.len() {
                bail!(
                    "node '{}' produced {} outputs, declared {}",
                    node.name,
                    outs.len(),
                    out_node.outputs.len()
                );
            }
            drop(ins);
            // Free dead slots before storing: an output may reuse one.
            // Owned buffers go back to the scratch pool for later kernels
            // — routed to the pool matching their container, so an i8
            // activation buffer never resurfaces as f32 scratch.
            for &sl in &step.release {
                if let Some(RtVal::Owned(t)) = slots[sl as usize].take() {
                    scratch.recycle(t);
                }
            }
            for (j, t) in outs.into_iter().enumerate() {
                if cfg.record_intermediates {
                    intermediates.insert(out_node.outputs[j].clone(), t.clone());
                }
                if let Some(sl) = step.outputs[j] {
                    slots[sl as usize] = Some(RtVal::Owned(t));
                }
            }
            if let (Some(o), Some((t0, c0))) = (obs.as_deref_mut(), probe) {
                let wall_ns = t0.elapsed().as_nanos() as u64;
                let delta = scratch.counters() - c0;
                o.observe(step_idx, node, step.kernel.tag(node), wall_ns, delta);
            }
        }

        let mut outputs = BTreeMap::new();
        for po in &self.outputs {
            let v = slots[po.slot as usize]
                .as_ref()
                .ok_or_else(|| anyhow!("graph output '{}' was not produced", po.name))?;
            outputs.insert(po.name.clone(), v.tensor().clone());
        }
        if cfg.record_intermediates {
            for (name, t) in &self.folded_outputs {
                intermediates.insert(name.clone(), (**t).clone());
            }
            for (alias, canon) in &self.alias_outputs {
                if let Some(t) = intermediates.get(canon).cloned() {
                    intermediates.insert(alias.clone(), t);
                }
            }
        }
        Ok(PlanRunResult { outputs, intermediates })
    }

    /// Human-readable schedule listing (with the per-slot dtype + bytes
    /// table the `plan` CLI prints).
    pub fn summary(&self) -> String {
        let mut s = format!(
            "plan '{}': {} graph nodes -> {} steps ({} const-folded, {} identity-elided, \
             {} packed, {} quantized, {} epilogue-fused, {} int-resident, {} batch-symbolic)\n",
            self.name,
            self.node_count,
            self.steps.len(),
            self.folded_count,
            self.elided_count,
            self.packed_count,
            self.quant_count,
            self.fused_count,
            self.resident_int_count,
            self.batch_symbolic_count
        );
        for b in &self.batch_blockers {
            let _ = writeln!(s, "  ! batch-blocked: {b}");
        }
        let _ = writeln!(
            s,
            "  {} physical slots, {} preloaded constants, {} inputs, {} outputs",
            self.slot_count,
            self.preloads.len(),
            self.inputs.len(),
            self.outputs.len()
        );
        for (i, step) in self.steps.iter().enumerate() {
            let node = &self.nodes[step.node_idx];
            let outs: Vec<String> = step
                .outputs
                .iter()
                .map(|o| o.map(|sl| sl.to_string()).unwrap_or_else(|| "-".to_string()))
                .collect();
            let _ = writeln!(
                s,
                "  s{i:<3} {:<18} slots {:?} -> [{}]  release {:?}",
                step.kernel.tag(node),
                step.inputs,
                outs.join(", "),
                step.release
            );
        }
        // per-slot dtype + bytes column: the residency pass's memory story
        // at a glance (bytes at declared shapes; '?' when unannotated)
        let mut resident = 0usize;
        let mut all_f32 = 0usize;
        let _ = writeln!(s, "  slot dtypes (bytes at declared shapes):");
        for (i, dt) in self.slot_dtypes.iter().enumerate() {
            match self.slot_numel.get(i).copied().flatten() {
                Some(n) => {
                    let bytes = n * dt.size_bytes();
                    resident += bytes;
                    all_f32 += n * DType::F32.size_bytes();
                    let _ = writeln!(s, "    s{i:<3} {:<4} {bytes:>10} B", dt.name());
                }
                None => {
                    let _ = writeln!(s, "    s{i:<3} {:<4} {:>10} B", dt.name(), "?");
                }
            }
        }
        if all_f32 > 0 {
            let _ = writeln!(
                s,
                "    resident slot bytes {resident} (all-f32 layout would be {all_f32})"
            );
        }
        // kernel substrate: which microkernel the quantized tier will run
        // on and how wide the intra-op pool fans (see tensor::simd and
        // runtime::pool)
        let tiled = self.steps.iter().filter(|st| st.kernel.simd_isa().is_some()).count();
        let _ = writeln!(
            s,
            "  kernel substrate: isa {} ({}), intra-op threads {}, {tiled}/{} quantized kernels \
             simd-tiled",
            crate::tensor::simd::active_isa(),
            if crate::tensor::simd::force_scalar() { "forced scalar" } else { "detected" },
            crate::runtime::pool::effective_parallelism(),
            self.quant_count,
        );
        s
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ir::GraphBuilder;

    fn run_map(plan: &ExecutionPlan, inputs: &BTreeMap<String, Tensor>) -> BTreeMap<String, Tensor> {
        plan.run(inputs).unwrap()
    }

    #[test]
    fn chain_reuses_one_slot() {
        let mut b = GraphBuilder::new("chain");
        b.input("x", vec![1, 4]);
        b.node("Relu", &["x"], &["a"], &[]);
        b.node("Relu", &["a"], &["c"], &[]);
        b.node("Relu", &["c"], &["y"], &[]);
        b.output("y", vec![1, 4]);
        let g = b.finish().unwrap();
        let plan = ExecutionPlan::compile(&g).unwrap();
        assert_eq!(plan.step_count(), 3);
        // x, a, c, y all share one recycled physical slot
        assert_eq!(plan.slot_count(), 1, "{}", plan.summary());
        let mut m = BTreeMap::new();
        m.insert("x".to_string(), Tensor::new(vec![1, 4], vec![-2.0, -1.0, 0.5, 3.0]));
        let out = run_map(&plan, &m);
        assert_eq!(out["y"].as_f32().unwrap(), &[0.0, 0.0, 0.5, 3.0]);
    }

    #[test]
    fn weight_quant_folds_at_compile_time() {
        let mut b = GraphBuilder::new("fold");
        b.input("x", vec![1, 2]);
        b.node("Relu", &["x"], &["r"], &[]);
        b.initializer("w", Tensor::new(vec![2, 2], vec![0.3, -0.6, 0.9, 0.1]));
        b.quant("w", "wq", 0.25, 0.0, 4.0, true, true, "ROUND");
        b.node("MatMul", &["r", "wq"], &["y"], &[]);
        b.output("y", vec![1, 2]);
        let g = b.finish().unwrap();
        let plan = ExecutionPlan::compile(&g).unwrap();
        // the weight Quant (inputs all constant) ran at compile time
        assert_eq!(plan.folded_count(), 1, "{}", plan.summary());
        assert_eq!(plan.step_count(), 2);
        let mut m = BTreeMap::new();
        m.insert("x".to_string(), Tensor::new(vec![1, 2], vec![1.0, 2.0]));
        let got = run_map(&plan, &m);
        let want = crate::exec::interpret(&g, &m).unwrap();
        assert_eq!(want.outputs, got);
    }

    #[test]
    fn identity_is_elided_to_an_alias() {
        let mut b = GraphBuilder::new("ident");
        b.input("x", vec![1, 3]);
        b.node("Relu", &["x"], &["a"], &[]);
        b.node("Identity", &["a"], &["y"], &[]);
        b.output("y", vec![1, 3]);
        let g = b.finish().unwrap();
        let plan = ExecutionPlan::compile(&g).unwrap();
        assert_eq!(plan.elided_count(), 1);
        assert_eq!(plan.step_count(), 1);
        let mut m = BTreeMap::new();
        m.insert("x".to_string(), Tensor::new(vec![1, 3], vec![-1.0, 0.0, 5.0]));
        let out = run_map(&plan, &m);
        assert_eq!(out["y"].as_f32().unwrap(), &[0.0, 0.0, 5.0]);
    }

    #[test]
    fn fully_constant_graph_folds_to_preloaded_output() {
        let mut b = GraphBuilder::new("allconst");
        b.initializer("w", Tensor::new(vec![3], vec![-1.0, 0.0, 2.0]));
        b.node("Relu", &["w"], &["y"], &[]);
        b.output("y", vec![3]);
        let g = b.finish().unwrap();
        let plan = ExecutionPlan::compile(&g).unwrap();
        assert_eq!(plan.step_count(), 0);
        let out = run_map(&plan, &BTreeMap::new());
        assert_eq!(out["y"].as_f32().unwrap(), &[0.0, 0.0, 2.0]);
    }

    #[test]
    fn plan_is_reusable_and_owned_plan_matches() {
        let mut b = GraphBuilder::new("reuse");
        b.input("x", vec![1, 4]);
        b.quant("x", "y", 0.5, 0.0, 4.0, false, false, "ROUND");
        b.output("y", vec![1, 4]);
        let g = b.finish().unwrap();
        let plan = ExecutionPlan::compile(&g).unwrap();
        let owned = ExecutionPlan::compile(&g).unwrap().into_owned();
        let mut m = BTreeMap::new();
        m.insert("x".to_string(), Tensor::new(vec![1, 4], vec![-1.0, 0.3, 0.26, 99.0]));
        let first = run_map(&plan, &m);
        let second = run_map(&plan, &m);
        assert_eq!(first, second, "slot state resets between runs");
        assert_eq!(first, run_map(&owned, &m));
    }

    #[test]
    fn unchecked_shapes_allow_rebatching() {
        let mut b = GraphBuilder::new("rebatch");
        b.input("x", vec![1, 4]);
        b.node("Relu", &["x"], &["y"], &[]);
        b.output("y", vec![1, 4]);
        let g = b.finish().unwrap();
        let plan = ExecutionPlan::compile(&g).unwrap();
        let batch = Tensor::full(vec![5, 4], -1.0);
        for check in [ShapeCheck::Skip, ShapeCheck::FreeBatch] {
            let cfg = RunConfig { shape_check: check, record_intermediates: false };
            let r = plan.run_cfg(|n| (n == "x").then_some(&batch), &cfg).unwrap();
            assert_eq!(r.outputs["y"].shape(), &[5, 4]);
        }
        // FreeBatch still validates rank and trailing dims
        let bad = Tensor::full(vec![5, 3], -1.0);
        let cfg = RunConfig { shape_check: ShapeCheck::FreeBatch, record_intermediates: false };
        let err =
            plan.run_cfg(|n| (n == "x").then_some(&bad), &cfg).unwrap_err().to_string();
        assert!(err.contains("does not match declared"), "{err}");
        // and the exact (default) path still rejects re-batching
        let mut m = BTreeMap::new();
        m.insert("x".to_string(), batch);
        assert!(plan.run(&m).is_err());
    }

    #[test]
    fn intermediates_cover_folds_and_aliases() {
        let mut b = GraphBuilder::new("record");
        b.input("x", vec![1, 2]);
        b.node("Relu", &["x"], &["r"], &[]);
        b.initializer("w", Tensor::new(vec![2, 2], vec![1.0, 0.0, 0.0, 1.0]));
        b.quant("w", "wq", 0.5, 0.0, 4.0, true, false, "ROUND");
        b.node("MatMul", &["r", "wq"], &["mm"], &[]);
        b.node("Identity", &["mm"], &["y"], &[]);
        b.output("y", vec![1, 2]);
        let g = b.finish().unwrap();
        let plan = ExecutionPlan::compile(&g).unwrap();
        let mut m = BTreeMap::new();
        m.insert("x".to_string(), Tensor::new(vec![1, 2], vec![2.0, -1.0]));
        let cfg = RunConfig { shape_check: ShapeCheck::Exact, record_intermediates: true };
        let r = plan.run_cfg(|n| m.get(n), &cfg).unwrap();
        for name in ["x", "r", "wq", "mm", "y"] {
            assert!(r.intermediates.contains_key(name), "missing '{name}'");
        }
    }

    #[test]
    fn dead_node_still_executes_but_output_is_dropped() {
        // Sign's output is unused: the step still runs (error parity with
        // the interpreter) but gets no slot.
        let mut b = GraphBuilder::new("dead");
        b.input("x", vec![1, 2]);
        b.node("Sign", &["x"], &["unused"], &[]);
        b.node("Relu", &["x"], &["y"], &[]);
        b.output("y", vec![1, 2]);
        let g = b.finish().unwrap();
        let plan = ExecutionPlan::compile(&g).unwrap();
        assert_eq!(plan.step_count(), 2);
        let mut m = BTreeMap::new();
        m.insert("x".to_string(), Tensor::new(vec![1, 2], vec![-3.0, 4.0]));
        let out = run_map(&plan, &m);
        assert_eq!(out.len(), 1);
        assert_eq!(out["y"].as_f32().unwrap(), &[0.0, 4.0]);
    }

    #[test]
    fn run_profiled_matches_plain_run_and_records_samples() {
        let mut b = GraphBuilder::new("prof");
        b.input("x", vec![1, 4]);
        b.node("Relu", &["x"], &["a"], &[]);
        b.node("Sign", &["a"], &["y"], &[]);
        b.output("y", vec![1, 4]);
        let g = b.finish().unwrap();
        let plan = ExecutionPlan::compile(&g).unwrap();
        let mut m = BTreeMap::new();
        m.insert("x".to_string(), Tensor::new(vec![1, 4], vec![-2.0, -1.0, 0.5, 3.0]));
        let plain = run_map(&plan, &m);

        let mut obs = StepObserver::new();
        let mut scratch = ScratchArena::new();
        let cfg = RunConfig::default();
        let r = plan.run_profiled(|n| m.get(n), &cfg, &mut scratch, &mut obs).unwrap();
        assert_eq!(r.outputs, plain, "profiling must not change results");
        assert_eq!(obs.samples().len(), plan.step_count());
        assert!(obs.samples().iter().all(|s| !s.kernel.is_empty()));
        assert_eq!(obs.samples()[0].step, 0);
        // a second profiled run appends another full set of samples
        plan.run_profiled(|n| m.get(n), &cfg, &mut scratch, &mut obs).unwrap();
        assert_eq!(obs.samples().len(), 2 * plan.step_count());
    }

    #[test]
    fn run_profiled_with_trace_emits_one_exec_event_per_step() {
        let mut b = GraphBuilder::new("prof-trace");
        b.input("x", vec![1, 4]);
        b.node("Relu", &["x"], &["y"], &[]);
        b.output("y", vec![1, 4]);
        let g = b.finish().unwrap();
        let plan = ExecutionPlan::compile(&g).unwrap();
        let mut m = BTreeMap::new();
        m.insert("x".to_string(), Tensor::new(vec![1, 4], vec![1.0, -1.0, 0.0, 2.0]));

        let rec = Arc::new(crate::trace::TraceRecorder::new(64));
        let mut obs = StepObserver::with_trace(rec.clone());
        plan.run_profiled(|n| m.get(n), &RunConfig::default(), &mut ScratchArena::new(), &mut obs)
            .unwrap();
        let dump = rec.drain();
        let events: Vec<_> = dump.iter().flat_map(|t| t.events.iter()).collect();
        assert_eq!(events.len(), plan.step_count());
        assert!(events.iter().all(|e| {
            e.cat == "exec" && e.kind == crate::trace::EventKind::Complete
        }));
    }
}

//! The quantized (tier-3) kernels: integer-domain `Conv`/`Gemm`/`MatMul`
//! with `i8` weight panels, `i32` accumulation, and the streamlined
//! `MultiThreshold` activation fused as the scatter-loop epilogue.
//!
//! The plan compiler selects these whenever the value-range proofs from
//! [`crate::transforms::infer_ranges`] show that a linear op's runtime
//! input lives on a literal integer grid and its constant weights fit
//! `i8` — the form [`crate::streamline`] produces. Compared with the
//! packed float tier this moves 4-byte weight traffic to 1 byte, turns
//! the inner loop into integer MACs (no rounding, so no accumulation-
//! order contract is needed), and replaces the separate full-tensor
//! `MultiThreshold` pass with a per-element binary search over `i32`
//! thresholds inside the scatter loop.
//!
//! # Dtype-aware residency (PR 5)
//!
//! Every kernel here is **container-polymorphic on both sides**. Inputs:
//! an `f32` tensor is validated against the compile-time range proof and
//! converted once (the classic path — now only the plan-boundary case); an
//! `i32`-resident tensor feeds the integer GEMM directly with *zero*
//! conversion; an `i8`-resident tensor feeds the `i8`-activation GEMM
//! ([`crate::tensor::qgemm_prepacked_i8`]) — 1-byte activation panels, the
//! ROADMAP's "resident `i8` activation path". Outputs: the residency pass
//! in `plan/compile.rs` tells each kernel which container its consumers
//! accept (`QuantConv::set_out_dtype` & co.), so a fused
//! `MultiThreshold` writes its integer levels straight into `i8`/`i32`
//! storage instead of round-tripping through floats. The standalone
//! [`ThresholdKernel`] is the tier's entry boundary: it ingests the f32
//! graph edge and emits resident integer levels in one pass.
//!
//! # Exactness contract
//!
//! Selection requires every accumulator magnitude (including any folded
//! integer bias) to stay below `2^24`. Under that bound the `i32` result
//! is exactly representable in the f32 container, so a quantized plan is
//! **byte-identical** to running the same streamlined graph through the
//! float kernels or the reference interpreter — `tests/plan_equiv.rs`
//! asserts this across the zoo. Integer residency preserves the contract:
//! integer emission replays the f32 arithmetic and casts the (exactly
//! representable) result, and integer-resident inputs are trusted by
//! construction — their producing kernel proved the grid, so the
//! per-element runtime re-validation only remains on the f32 boundary.
//!
//! # SIMD microkernel dispatch (PR 6)
//!
//! Weight packing prebuilds interleaved SIMD tiles ([`crate::tensor::simd`])
//! whenever the detected ISA supports them, and `qgemm_any` narrows wide
//! activations to `i8` whenever the compile-time range proof fits the
//! container — so the f32 boundary and `i32`-resident paths reach the
//! microkernel too, not just resident-`i8` plans. Packing also records a
//! sparsity hint from the activation range (`dense_activations`): 1–2 bit
//! grids keep the scalar path's `av == 0` skip, wider grids take the
//! branch-free loop. None of this changes a single byte — `i32`
//! accumulation is order-free, so scalar, AVX2, and NEON plans are
//! byte-identical (`QONNX_FORCE_SCALAR=1` flips any compiled plan back to
//! the scalar panels at run time; `tests/plan_equiv.rs` asserts equality).

use super::arena::ScratchArena;
use crate::ir::Node;
use crate::ops::linalg::{conv_params, ConvParams};
use crate::ops::multithreshold::{threshold_count, threshold_count_i32};
use crate::tensor::{
    conv_out_dim, im2col_group_into, qgemm_prepacked, qgemm_prepacked_i8, DType, Isa, PackedBi8,
    Tensor,
};
use crate::transforms::ValueRange;
use anyhow::{ensure, Result};

/// Largest magnitude exactly representable on the f32 integer grid; the
/// compile-time accumulator bound AND the runtime input-validation bound.
const EXACT_F32_LIMIT: f64 = crate::tensor::F32_EXACT_INT_LIMIT; // 2^24

/// Extract a tensor's values as `i8`, or `None` if any value is off the
/// integer grid or outside `[-128, 127]`.
fn to_i8(vals: &[f32]) -> Option<Vec<i8>> {
    let mut out = Vec::with_capacity(vals.len());
    for &v in vals {
        let vf = f64::from(v);
        if vf.fract() != 0.0 || !(-128.0..=127.0).contains(&vf) {
            return None;
        }
        out.push(v as i8);
    }
    Some(out)
}

/// Compile-time sparsity hint for the scalar qgemm path: on 1–2 bit
/// activation grids (range span ≤ 3 levels) zeros are frequent enough
/// that the `av == 0` skip wins, so the packed weights keep it; wider
/// grids take the branch-free loop. SIMD tiles ignore the hint entirely.
fn dense_activations(r: ValueRange) -> bool {
    r.hi - r.lo > 3.0
}

/// Max absolute value of an integral range (None when unusable).
fn range_abs(r: ValueRange) -> Option<f64> {
    if !r.integral || !r.lo.is_finite() || !r.hi.is_finite() {
        return None;
    }
    Some(r.lo.abs().max(r.hi.abs()))
}

/// Narrowest integer container that exactly holds integer levels in
/// `[lo, hi]` (which must stay inside the f32-exact `±2^24` window so the
/// emitted value is the f32 value, bit for bit, after any cast). `None`
/// means: keep the f32 container.
fn int_container(lo: f64, hi: f64) -> Option<DType> {
    if lo <= -EXACT_F32_LIMIT || hi >= EXACT_F32_LIMIT {
        return None;
    }
    if lo >= f64::from(i8::MIN) && hi <= f64::from(i8::MAX) {
        Some(DType::I8)
    } else {
        Some(DType::I32)
    }
}

/// Container of a `MultiThreshold` emission `out_scale * count + out_bias`
/// over `count` in `0..=steps` — the ONE level-range rule shared by fused
/// epilogues ([`QThreshold`]) and standalone [`ThresholdKernel`]s, so the
/// two can never disagree about a proven container. `F32` when the out
/// params are not integral (or the levels leave the f32-exact window).
fn level_container(out_scale: f32, out_bias: f32, steps: usize) -> DType {
    let os = f64::from(out_scale);
    let ob = f64::from(out_bias);
    if os.fract() != 0.0 || ob.fract() != 0.0 {
        return DType::F32;
    }
    let (a, b) = (ob, os * steps as f64 + ob);
    int_container(a.min(b), a.max(b)).unwrap_or(DType::F32)
}

/// Convert a proven-integral f32 slice into `i32`, re-validating the
/// compile-time range proof per element.
fn to_i32_checked(src: &[f32], lo: f64, hi: f64, out: &mut [i32]) -> Result<()> {
    debug_assert_eq!(src.len(), out.len());
    for (&v, o) in src.iter().zip(out.iter_mut()) {
        let vf = f64::from(v);
        ensure!(
            vf.fract() == 0.0 && vf >= lo && vf <= hi,
            "quantized-tier input value {v} is off the proven integer grid [{lo}, {hi}] \
             (the bound datatype annotation does not match the runtime data)"
        );
        *o = v as i32;
    }
    Ok(())
}

/// Same re-validation, narrowing to `i8` — the activation container the
/// SIMD microkernel consumes. Only called when the compile-time proof
/// already bounds the range inside `[-128, 127]`, so the cast is exact.
fn to_i8_checked(src: &[f32], lo: f64, hi: f64, out: &mut [i8]) -> Result<()> {
    debug_assert_eq!(src.len(), out.len());
    debug_assert!(lo >= f64::from(i8::MIN) && hi <= f64::from(i8::MAX));
    for (&v, o) in src.iter().zip(out.iter_mut()) {
        let vf = f64::from(v);
        ensure!(
            vf.fract() == 0.0 && vf >= lo && vf <= hi,
            "quantized-tier input value {v} is off the proven integer grid [{lo}, {hi}] \
             (the bound datatype annotation does not match the runtime data)"
        );
        *o = v as i8;
    }
    Ok(())
}

/// Whether `qgemm_any` should narrow wide activations to `i8`: the packed
/// weights carry prebuilt SIMD tiles (so the 1-byte path actually hits the
/// microkernel) and the compile-time range proof fits the container.
fn narrows_to_i8(bp: &PackedBi8, lo: f64, hi: f64) -> bool {
    bp.simd_isa().is_some() && lo >= f64::from(i8::MIN) && hi <= f64::from(i8::MAX)
}

/// Accumulate `rows x k` activations against a packed `i8` weight matrix
/// into `prod`, dispatching on the activation container: `i8`-resident
/// panels take the 1-byte path, `i32`-resident ones multiply directly, and
/// the f32 boundary validates + converts through arena scratch.
#[allow(clippy::too_many_arguments)]
fn qgemm_any(
    a: &Tensor,
    rows: usize,
    k: usize,
    bp: &PackedBi8,
    in_lo: f64,
    in_hi: f64,
    prod: &mut [i32],
    scratch: &mut ScratchArena,
) -> Result<()> {
    match a.dtype() {
        DType::I8 => qgemm_prepacked_i8(rows, k, bp, a.as_i8()?, prod),
        DType::I32 => {
            let xs = a.as_i32()?;
            if narrows_to_i8(bp, in_lo, in_hi) {
                // integer-resident values are trusted by construction (the
                // producing kernel proved the grid), so the narrowing cast
                // is exact under the compile-time range proof
                let mut xb = scratch.take_i8_uninit(xs.len());
                for (o, &v) in xb.iter_mut().zip(xs) {
                    *o = v as i8;
                }
                qgemm_prepacked_i8(rows, k, bp, &xb, prod);
                scratch.give_i8(xb);
            } else {
                qgemm_prepacked(rows, k, bp, xs, prod);
            }
        }
        _ => {
            let xs = a.as_f32()?;
            if narrows_to_i8(bp, in_lo, in_hi) {
                let mut xb = scratch.take_i8_uninit(xs.len());
                to_i8_checked(xs, in_lo, in_hi, &mut xb)?;
                qgemm_prepacked_i8(rows, k, bp, &xb, prod);
                scratch.give_i8(xb);
            } else {
                let mut xi = scratch.take_i32_uninit(xs.len());
                to_i32_checked(xs, in_lo, in_hi, &mut xi)?;
                qgemm_prepacked(rows, k, bp, &xi, prod);
                scratch.give_i32(xi);
            }
        }
    }
    Ok(())
}

/// A `MultiThreshold` stage fused into a quantized kernel's scatter loop:
/// per-channel sorted `i32` threshold rows, counted by binary search,
/// with the node's `out_scale`/`out_bias` replayed in f32 exactly as the
/// generic op computes them.
#[derive(Debug, Clone)]
pub(crate) struct QThreshold {
    channels: usize,
    steps: usize,
    rows: Vec<i32>,
    out_scale: f32,
    out_bias: f32,
}

impl QThreshold {
    /// Compile a `MultiThreshold` node whose thresholds are a compile-time
    /// constant into a fused integer epilogue. Declines (`None`) whenever
    /// anything deviates from the integer-domain form — the node then
    /// stays a separate generic step with full error parity.
    pub(crate) fn try_build(node: &Node, th: &Tensor, out_channels: usize) -> Option<QThreshold> {
        if node.op_type != "MultiThreshold" || node.inputs.len() != 2 || node.outputs.len() != 1 {
            return None;
        }
        if node.attr_str_or("data_layout", "NCHW") != "NCHW" {
            return None;
        }
        if th.rank() != 2 {
            return None;
        }
        let (tc, tt) = (th.shape()[0], th.shape()[1]);
        if (tc != out_channels && tc != 1) || tt == 0 {
            return None;
        }
        let vals = th.as_f32().ok()?;
        let mut rows = Vec::with_capacity(vals.len());
        for &v in vals {
            let vf = f64::from(v);
            if vf.fract() != 0.0 || vf.abs() >= EXACT_F32_LIMIT {
                return None;
            }
            rows.push(v as i32);
        }
        for c in 0..tc {
            let row = &rows[c * tt..(c + 1) * tt];
            if !row.windows(2).all(|w| w[0] <= w[1]) {
                return None; // unsorted: generic op reports the error
            }
        }
        Some(QThreshold {
            channels: tc,
            steps: tt,
            rows,
            out_scale: node.attr_float_or("out_scale", 1.0),
            out_bias: node.attr_float_or("out_bias", 0.0),
        })
    }

    /// Reassemble from persisted parts (artifact loading). Trusts the
    /// caller's rows the way a compiled plan trusts `try_build`'s —
    /// `qonnx verify --artifact` re-checks monotonicity independently.
    pub(crate) fn from_parts(
        channels: usize,
        steps: usize,
        rows: Vec<i32>,
        out_scale: f32,
        out_bias: f32,
    ) -> QThreshold {
        QThreshold { channels, steps, rows, out_scale, out_bias }
    }

    /// `(out_scale, out_bias)` emission params (artifact writing).
    pub(crate) fn out_params(&self) -> (f32, f32) {
        (self.out_scale, self.out_bias)
    }

    /// Narrowest container that exactly holds every emitted level.
    pub(crate) fn preferred_container(&self) -> DType {
        level_container(self.out_scale, self.out_bias, self.steps)
    }

    // Verifier introspection: the plan verifier re-checks row monotonicity
    // and level-container fit from these without re-running try_build.
    pub(crate) fn channels(&self) -> usize {
        self.channels
    }

    pub(crate) fn steps(&self) -> usize {
        self.steps
    }

    pub(crate) fn rows(&self) -> &[i32] {
        &self.rows
    }

    /// Mutation-harness hook: corrupt threshold rows in place.
    pub(crate) fn rows_mut(&mut self) -> &mut Vec<i32> {
        &mut self.rows
    }

    #[inline]
    fn apply(&self, acc: i32, oc: usize) -> f32 {
        let c = if self.channels == 1 { 0 } else { oc };
        let row = &self.rows[c * self.steps..(c + 1) * self.steps];
        // identical expression to ops::multithreshold::multi_threshold
        self.out_scale * threshold_count_i32(row, acc) as f32 + self.out_bias
    }
}

/// The per-element write-back value in f32 — integer containers cast this
/// exact value, so every container holds the same number.
#[inline]
fn emit(epilogue: &Option<QThreshold>, acc: i32, oc: usize) -> f32 {
    match epilogue {
        None => acc as f32, // exact: |acc| < 2^24 by the compile-time bound
        Some(t) => t.apply(acc, oc),
    }
}

/// Preferred output container of a quantized linear kernel: the fused
/// threshold's level container when one is fused, otherwise the raw
/// (`< 2^24`-bounded) `i32` accumulator.
fn preferred_out(epilogue: &Option<QThreshold>) -> DType {
    match epilogue {
        Some(t) => t.preferred_container(),
        None => DType::I32,
    }
}

/// Emit a row-major `[.., n]` accumulator (plus optional per-column bias
/// and fused threshold) into a tensor of container `dt`. The `I32` case
/// rewrites the accumulator buffer in place — zero extra traffic.
fn emit_rowmajor(
    shape: Vec<usize>,
    prod: Vec<i32>,
    n: usize,
    bias: Option<&[i32]>,
    epilogue: &Option<QThreshold>,
    dt: DType,
    scratch: &mut ScratchArena,
) -> Tensor {
    let acc_at = |i: usize, a: i32| -> i32 {
        match bias {
            Some(b) => a + b[i % n],
            None => a,
        }
    };
    match dt {
        DType::I32 => {
            let mut out = prod;
            for (i, v) in out.iter_mut().enumerate() {
                *v = emit(epilogue, acc_at(i, *v), i % n) as i32;
            }
            Tensor::new_i32(shape, out)
        }
        DType::I8 => {
            let mut out = scratch.take_i8_uninit(prod.len());
            for (i, (o, &a)) in out.iter_mut().zip(prod.iter()).enumerate() {
                *o = emit(epilogue, acc_at(i, a), i % n) as i8;
            }
            scratch.give_i32(prod);
            Tensor::new_i8(shape, out)
        }
        _ => {
            let mut out = scratch.take_uninit(prod.len());
            for (i, (o, &a)) in out.iter_mut().zip(prod.iter()).enumerate() {
                *o = emit(epilogue, acc_at(i, a), i % n);
            }
            scratch.give_i32(prod);
            Tensor::new(shape, out)
        }
    }
}

/// Integer-domain conv: `i8` weight panels per group, `i32` (or resident
/// `i8`) im2col + accumulate, fused `MultiThreshold` in the scatter loop.
#[derive(Debug)]
pub struct QuantConv {
    p: ConvParams,
    m: usize,
    cg: usize,
    mg: usize,
    k: usize,
    weights: Vec<PackedBi8>,
    in_lo: f64,
    in_hi: f64,
    epilogue: Option<QThreshold>,
    out_dtype: DType,
}

impl QuantConv {
    /// Build from a conv node with constant `i8`-grid weights and a
    /// proven-integral input range. Declines on anything unsupported
    /// (bias input, NHWC wrapper, non-integer weights, accumulator bound):
    /// the caller then falls back to the packed float tier.
    pub(crate) fn try_build(node: &Node, w: &Tensor, r: ValueRange) -> Option<QuantConv> {
        if node.inputs.get(2).map(String::as_str).is_some_and(|s| !s.is_empty()) {
            return None; // streamlined graphs carry no conv bias
        }
        if node.attr_str_or("data_layout", "NCHW") != "NCHW" {
            return None;
        }
        if w.rank() != 4 {
            return None;
        }
        let p = conv_params(node, w.shape()).ok()?;
        let ws = to_i8(w.as_f32().ok()?)?;
        let m = w.shape()[0];
        let cg = w.shape()[1];
        if p.group == 0 || m % p.group != 0 {
            return None;
        }
        let mg = m / p.group;
        let k = cg * p.kh * p.kw;
        let in_abs = range_abs(r)?;
        let w_abs = ws.iter().map(|&v| i32::from(v).abs()).max().unwrap_or(0) as f64;
        if in_abs * w_abs * k as f64 >= EXACT_F32_LIMIT {
            return None;
        }
        // per-group [mg, k] weight rows transposed to [k, mg] (the same
        // shared helper the f32 paths use), packed once
        let dense = dense_activations(r);
        let mut weights = Vec::with_capacity(p.group);
        for g in 0..p.group {
            let wt = crate::ops::linalg::transpose_group_weights(&ws, g, mg, k);
            weights.push(PackedBi8::pack_with(k, mg, &wt, dense));
        }
        Some(QuantConv {
            p,
            m,
            cg,
            mg,
            k,
            weights,
            in_lo: r.lo,
            in_hi: r.hi,
            epilogue: None,
            out_dtype: DType::F32,
        })
    }

    /// Reassemble from persisted parts (artifact loading).
    #[allow(clippy::too_many_arguments)]
    pub(crate) fn from_parts(
        p: ConvParams,
        m: usize,
        cg: usize,
        mg: usize,
        k: usize,
        weights: Vec<PackedBi8>,
        in_range: (f64, f64),
        epilogue: Option<QThreshold>,
        out_dtype: DType,
    ) -> QuantConv {
        QuantConv { p, m, cg, mg, k, weights, in_lo: in_range.0, in_hi: in_range.1, epilogue, out_dtype }
    }

    /// Conv hyper-parameters (artifact writing).
    pub(crate) fn params(&self) -> &ConvParams {
        &self.p
    }

    /// `(m, cg, mg, k)` dims (artifact writing).
    pub(crate) fn dims(&self) -> (usize, usize, usize, usize) {
        (self.m, self.cg, self.mg, self.k)
    }

    /// Per-group packed weight matrices (artifact writing).
    pub(crate) fn weights(&self) -> &[PackedBi8] {
        &self.weights
    }

    /// Output channels (`M`) — the axis a fused threshold indexes.
    pub(crate) fn out_channels(&self) -> usize {
        self.m
    }

    pub(crate) fn set_epilogue(&mut self, t: QThreshold) {
        self.epilogue = Some(t);
    }

    /// The fused `MultiThreshold` stage, if any (verifier introspection).
    pub(crate) fn epilogue(&self) -> Option<&QThreshold> {
        self.epilogue.as_ref()
    }

    /// The proven input range `[lo, hi]` the accumulator bound rests on.
    pub(crate) fn input_range(&self) -> (f64, f64) {
        (self.in_lo, self.in_hi)
    }

    /// `(max |weight|, accumulation depth)` — the `w_abs` / `k` terms of
    /// the compile-time `< 2^24` accumulator bound, max'd over groups.
    pub(crate) fn acc_terms(&self) -> (f64, usize) {
        let w = self.weights.iter().map(PackedBi8::max_abs).max().unwrap_or(0);
        (f64::from(w), self.k)
    }

    /// Mutation-harness hook: forge the claimed input range.
    pub(crate) fn set_input_range(&mut self, lo: f64, hi: f64) {
        self.in_lo = lo;
        self.in_hi = hi;
    }

    /// Whether a `MultiThreshold` stage is fused in.
    pub fn has_fused_threshold(&self) -> bool {
        self.epilogue.is_some()
    }

    /// Narrowest container the kernel can emit without changing values.
    pub(crate) fn preferred_out_dtype(&self) -> DType {
        preferred_out(&self.epilogue)
    }

    /// Container the residency pass chose for this kernel's output.
    pub(crate) fn set_out_dtype(&mut self, dt: DType) {
        self.out_dtype = dt;
    }

    /// The output container (f32 unless the residency pass chose tighter).
    pub fn out_dtype(&self) -> DType {
        self.out_dtype
    }

    /// ISA whose interleaved weight tiles were prebuilt at pack time
    /// (`None` when packing ran under forced-scalar / unsupported ISAs).
    pub fn simd_isa(&self) -> Option<Isa> {
        self.weights.first().and_then(PackedBi8::simd_isa)
    }

    /// Execute on an NCHW input (f32, or integer-resident) of any batch
    /// size.
    pub fn run(&self, x: &Tensor, scratch: &mut ScratchArena) -> Result<Tensor> {
        ensure!(x.rank() == 4, "Conv input must be NCHW, got {:?}", x.shape());
        let (n, c, h, w) = (x.shape()[0], x.shape()[1], x.shape()[2], x.shape()[3]);
        ensure!(
            c == self.cg * self.p.group,
            "channel mismatch: x has {c}, w wants {} x group {}",
            self.cg,
            self.p.group
        );
        let p = &self.p;
        let oh = conv_out_dim(h, p.kh, p.stride_h, p.pads[0], p.pads[2]);
        let ow = conv_out_dim(w, p.kw, p.stride_w, p.pads[1], p.pads[3]);
        let out_shape = vec![n, self.m, oh, ow];
        let out_len = n * self.m * oh * ow;
        match self.out_dtype {
            DType::I8 => {
                let mut out = scratch.take_i8_uninit(out_len);
                self.conv_into(x, (n, c, h, w, oh, ow), scratch, |acc, oc| {
                    emit(&self.epilogue, acc, oc) as i8
                }, &mut out)?;
                Ok(Tensor::new_i8(out_shape, out))
            }
            DType::I32 => {
                let mut out = scratch.take_i32_uninit(out_len);
                self.conv_into(x, (n, c, h, w, oh, ow), scratch, |acc, oc| {
                    emit(&self.epilogue, acc, oc) as i32
                }, &mut out)?;
                Ok(Tensor::new_i32(out_shape, out))
            }
            _ => {
                let mut out = scratch.take_uninit(out_len);
                self.conv_into(x, (n, c, h, w, oh, ow), scratch, |acc, oc| {
                    emit(&self.epilogue, acc, oc)
                }, &mut out)?;
                Ok(Tensor::new(out_shape, out))
            }
        }
    }

    /// Core walk shared by every container combination: pick the
    /// activation path by input dtype, then im2col + qgemm + scatter per
    /// group, writing through `f`.
    fn conv_into<T: Copy>(
        &self,
        x: &Tensor,
        dims: (usize, usize, usize, usize, usize, usize),
        scratch: &mut ScratchArena,
        f: impl Fn(i32, usize) -> T,
        out: &mut [T],
    ) -> Result<()> {
        let (n, _c, _h, _w, oh, ow) = dims;
        let rows = n * oh * ow;
        let mut prod = scratch.take_i32(rows * self.mg);
        match x.dtype() {
            DType::I8 => {
                // resident i8 activations: 1-byte im2col panels
                let src = x.as_i8()?;
                let mut cols = scratch.take_i8(rows * self.k);
                self.groups(src, dims, &mut cols, &mut prod, qgemm_prepacked_i8, &f, out);
                scratch.give_i8(cols);
            }
            DType::I32 => {
                let src = x.as_i32()?;
                let mut cols = scratch.take_i32(rows * self.k);
                self.groups(src, dims, &mut cols, &mut prod, qgemm_prepacked, &f, out);
                scratch.give_i32(cols);
            }
            _ => {
                // float boundary: validate against the compile-time range
                // proof, then run on the converted i32 activations
                let xs = x.as_f32()?;
                let mut xi = scratch.take_i32_uninit(xs.len());
                to_i32_checked(xs, self.in_lo, self.in_hi, &mut xi)?;
                let mut cols = scratch.take_i32(rows * self.k);
                self.groups(&xi, dims, &mut cols, &mut prod, qgemm_prepacked, &f, out);
                scratch.give_i32(cols);
                scratch.give_i32(xi);
            }
        }
        scratch.give_i32(prod);
        Ok(())
    }

    #[allow(clippy::too_many_arguments)]
    fn groups<A: Copy + Send + Sync, T: Copy>(
        &self,
        src: &[A],
        dims: (usize, usize, usize, usize, usize, usize),
        cols: &mut [A],
        prod: &mut [i32],
        gemm: impl Fn(usize, usize, &PackedBi8, &[A], &mut [i32]),
        f: &impl Fn(i32, usize) -> T,
        out: &mut [T],
    ) {
        let (n, c, h, w, oh, ow) = dims;
        let p = &self.p;
        let rows = n * oh * ow;
        for g in 0..p.group {
            if g > 0 {
                prod.fill(0); // qgemm accumulates; cols' padding zeros persist
            }
            im2col_group_into(
                src, n, c, h, w, g * self.cg, self.cg, p.kh, p.kw, p.stride_h, p.stride_w,
                p.pads, cols,
            );
            gemm(rows, self.k, &self.weights[g], &*cols, &mut *prod);
            // scatter [rows, mg] -> NCHW, fusing the threshold per element
            for b in 0..n {
                for mi in 0..self.mg {
                    let oc = g * self.mg + mi;
                    let dst = (b * self.m + oc) * oh * ow;
                    let src0 = b * oh * ow;
                    for pix in 0..oh * ow {
                        out[dst + pix] = f(prod[(src0 + pix) * self.mg + mi], oc);
                    }
                }
            }
        }
    }
}

/// Integer-domain `Gemm` (no runtime `C`): constant `i8` `B` with `transB`
/// applied at pack time; a constant integral `beta * C` folds into an
/// `i32` per-column bias inside the accumulator.
#[derive(Debug)]
pub struct QuantGemm {
    k: usize,
    n: usize,
    bp: PackedBi8,
    bias: Option<Vec<i32>>,
    in_lo: f64,
    in_hi: f64,
    epilogue: Option<QThreshold>,
    out_dtype: DType,
}

impl QuantGemm {
    /// `c` is `None` when the node has no C input, `Some(None)` when C is
    /// a runtime value (declines — the float tier handles it),
    /// `Some(Some(t))` when C is constant.
    pub(crate) fn try_build(
        node: &Node,
        b: &Tensor,
        c: Option<Option<&Tensor>>,
        r: ValueRange,
    ) -> Option<QuantGemm> {
        if node.attr_float_or("alpha", 1.0) != 1.0 || node.attr_int_or("transA", 0) != 0 {
            return None;
        }
        let beta = f64::from(node.attr_float_or("beta", 1.0));
        let trans_b = node.attr_int_or("transB", 0) != 0;
        if b.rank() != 2 {
            return None;
        }
        let bt = if trans_b { b.transpose(&[1, 0]).ok()? } else { b.clone() };
        let (k, n) = (bt.shape()[0], bt.shape()[1]);
        let bi = to_i8(bt.as_f32().ok()?)?;
        let in_abs = range_abs(r)?;
        let w_abs = bi.iter().map(|&v| i32::from(v).abs()).max().unwrap_or(0) as f64;
        let bias = match c {
            None => None,
            Some(None) => return None, // runtime C stays on the float tier
            Some(Some(ct)) => {
                // per-column broadcast only ([n] / [1, n] / scalar); a
                // per-row or full-matrix C stays on the float tier
                let per_column = ct.numel() == 1
                    || (ct.numel() == n
                        && (ct.rank() == 1 || (ct.rank() == 2 && ct.shape()[0] == 1)));
                if !per_column {
                    return None;
                }
                let cv = ct.as_f32().ok()?;
                let mut out = Vec::with_capacity(n);
                for j in 0..n {
                    let v = beta * f64::from(cv[j % cv.len()]);
                    if v.fract() != 0.0 || v.abs() >= EXACT_F32_LIMIT {
                        return None;
                    }
                    out.push(v as i32);
                }
                Some(out)
            }
        };
        let c_abs = bias
            .as_ref()
            .map(|b| b.iter().map(|&v| v.abs()).max().unwrap_or(0) as f64)
            .unwrap_or(0.0);
        if in_abs * w_abs * k as f64 + c_abs >= EXACT_F32_LIMIT {
            return None;
        }
        Some(QuantGemm {
            k,
            n,
            bp: PackedBi8::pack_with(k, n, &bi, dense_activations(r)),
            bias,
            in_lo: r.lo,
            in_hi: r.hi,
            epilogue: None,
            out_dtype: DType::F32,
        })
    }

    /// Reassemble from persisted parts (artifact loading).
    pub(crate) fn from_parts(
        k: usize,
        n: usize,
        bp: PackedBi8,
        bias: Option<Vec<i32>>,
        in_range: (f64, f64),
        epilogue: Option<QThreshold>,
        out_dtype: DType,
    ) -> QuantGemm {
        QuantGemm { k, n, bp, bias, in_lo: in_range.0, in_hi: in_range.1, epilogue, out_dtype }
    }

    /// `(k, n)` dims (artifact writing).
    pub(crate) fn dims(&self) -> (usize, usize) {
        (self.k, self.n)
    }

    /// The packed B matrix (artifact writing).
    pub(crate) fn packed_b(&self) -> &PackedBi8 {
        &self.bp
    }

    /// The folded `beta * C` per-column bias (artifact writing).
    pub(crate) fn bias(&self) -> Option<&[i32]> {
        self.bias.as_deref()
    }

    pub(crate) fn out_channels(&self) -> usize {
        self.n
    }

    pub(crate) fn set_epilogue(&mut self, t: QThreshold) {
        self.epilogue = Some(t);
    }

    /// The fused `MultiThreshold` stage, if any (verifier introspection).
    pub(crate) fn epilogue(&self) -> Option<&QThreshold> {
        self.epilogue.as_ref()
    }

    /// The proven input range `[lo, hi]` the accumulator bound rests on.
    pub(crate) fn input_range(&self) -> (f64, f64) {
        (self.in_lo, self.in_hi)
    }

    /// `(max |weight|, accumulation depth)` of the accumulator bound.
    pub(crate) fn acc_terms(&self) -> (f64, usize) {
        (f64::from(self.bp.max_abs()), self.k)
    }

    /// Largest `|beta * C|` folded into the per-column bias (the `c_abs`
    /// term of the accumulator bound; 0 when there is no C).
    pub(crate) fn bias_abs(&self) -> f64 {
        self.bias
            .as_ref()
            .map(|b| b.iter().map(|&v| v.abs()).max().unwrap_or(0) as f64)
            .unwrap_or(0.0)
    }

    /// Mutation-harness hook: forge the claimed input range.
    pub(crate) fn set_input_range(&mut self, lo: f64, hi: f64) {
        self.in_lo = lo;
        self.in_hi = hi;
    }

    /// Whether a `MultiThreshold` stage is fused in.
    pub fn has_fused_threshold(&self) -> bool {
        self.epilogue.is_some()
    }

    /// Narrowest container the kernel can emit without changing values.
    pub(crate) fn preferred_out_dtype(&self) -> DType {
        preferred_out(&self.epilogue)
    }

    /// Container the residency pass chose for this kernel's output.
    pub(crate) fn set_out_dtype(&mut self, dt: DType) {
        self.out_dtype = dt;
    }

    /// The output container (f32 unless the residency pass chose tighter).
    pub fn out_dtype(&self) -> DType {
        self.out_dtype
    }

    /// ISA whose interleaved weight tiles were prebuilt at pack time.
    pub fn simd_isa(&self) -> Option<Isa> {
        self.bp.simd_isa()
    }

    pub fn run(&self, a: &Tensor, scratch: &mut ScratchArena) -> Result<Tensor> {
        ensure!(a.rank() == 2, "matmul2d wants rank-2");
        let (m, ak) = (a.shape()[0], a.shape()[1]);
        ensure!(ak == self.k, "matmul2d inner dim mismatch {ak} vs {}", self.k);
        let mut prod = scratch.take_i32(m * self.n);
        qgemm_any(a, m, self.k, &self.bp, self.in_lo, self.in_hi, &mut prod, scratch)?;
        Ok(emit_rowmajor(
            vec![m, self.n],
            prod,
            self.n,
            self.bias.as_deref(),
            &self.epilogue,
            self.out_dtype,
            scratch,
        ))
    }
}

/// Integer-domain `MatMul` with a constant rank-2 `i8` rhs; batched
/// (>2-D) lhs is flattened by view like the packed float kernel.
#[derive(Debug)]
pub struct QuantMatMul {
    k: usize,
    n: usize,
    bp: PackedBi8,
    in_lo: f64,
    in_hi: f64,
    epilogue: Option<QThreshold>,
    out_dtype: DType,
}

impl QuantMatMul {
    pub(crate) fn try_build(b: &Tensor, r: ValueRange) -> Option<QuantMatMul> {
        if b.rank() != 2 {
            return None;
        }
        let (k, n) = (b.shape()[0], b.shape()[1]);
        let bi = to_i8(b.as_f32().ok()?)?;
        let in_abs = range_abs(r)?;
        let w_abs = bi.iter().map(|&v| i32::from(v).abs()).max().unwrap_or(0) as f64;
        if in_abs * w_abs * k as f64 >= EXACT_F32_LIMIT {
            return None;
        }
        Some(QuantMatMul {
            k,
            n,
            bp: PackedBi8::pack_with(k, n, &bi, dense_activations(r)),
            in_lo: r.lo,
            in_hi: r.hi,
            epilogue: None,
            out_dtype: DType::F32,
        })
    }

    /// Reassemble from persisted parts (artifact loading).
    pub(crate) fn from_parts(
        k: usize,
        n: usize,
        bp: PackedBi8,
        in_range: (f64, f64),
        epilogue: Option<QThreshold>,
        out_dtype: DType,
    ) -> QuantMatMul {
        QuantMatMul { k, n, bp, in_lo: in_range.0, in_hi: in_range.1, epilogue, out_dtype }
    }

    /// `(k, n)` dims (artifact writing).
    pub(crate) fn dims(&self) -> (usize, usize) {
        (self.k, self.n)
    }

    /// The packed rhs matrix (artifact writing).
    pub(crate) fn packed_b(&self) -> &PackedBi8 {
        &self.bp
    }

    pub(crate) fn out_channels(&self) -> usize {
        self.n
    }

    pub(crate) fn set_epilogue(&mut self, t: QThreshold) {
        self.epilogue = Some(t);
    }

    /// The fused `MultiThreshold` stage, if any (verifier introspection).
    pub(crate) fn epilogue(&self) -> Option<&QThreshold> {
        self.epilogue.as_ref()
    }

    /// The proven input range `[lo, hi]` the accumulator bound rests on.
    pub(crate) fn input_range(&self) -> (f64, f64) {
        (self.in_lo, self.in_hi)
    }

    /// `(max |weight|, accumulation depth)` of the accumulator bound.
    pub(crate) fn acc_terms(&self) -> (f64, usize) {
        (f64::from(self.bp.max_abs()), self.k)
    }

    /// Mutation-harness hook: forge the claimed input range.
    pub(crate) fn set_input_range(&mut self, lo: f64, hi: f64) {
        self.in_lo = lo;
        self.in_hi = hi;
    }

    /// Whether a `MultiThreshold` stage is fused in.
    pub fn has_fused_threshold(&self) -> bool {
        self.epilogue.is_some()
    }

    /// Narrowest container the kernel can emit without changing values.
    pub(crate) fn preferred_out_dtype(&self) -> DType {
        preferred_out(&self.epilogue)
    }

    /// Container the residency pass chose for this kernel's output.
    pub(crate) fn set_out_dtype(&mut self, dt: DType) {
        self.out_dtype = dt;
    }

    /// The output container (f32 unless the residency pass chose tighter).
    pub fn out_dtype(&self) -> DType {
        self.out_dtype
    }

    /// ISA whose interleaved weight tiles were prebuilt at pack time.
    pub fn simd_isa(&self) -> Option<Isa> {
        self.bp.simd_isa()
    }

    pub fn run(&self, a: &Tensor, scratch: &mut ScratchArena) -> Result<Tensor> {
        if a.rank() > 2 && self.epilogue.is_some() {
            // the generic MultiThreshold op only supports rank-2/4 inputs;
            // keep the fused path's error surface aligned with it
            anyhow::bail!("unsupported MultiThreshold input rank {} after batched MatMul", a.rank());
        }
        ensure!(a.rank() >= 2, "unsupported MatMul lhs rank {:?}", a.shape());
        let ak = *a.shape().last().unwrap();
        ensure!(ak == self.k, "matmul2d inner dim mismatch {ak} vs {}", self.k);
        let rows = a.numel() / ak;
        let mut prod = scratch.take_i32(rows * self.n);
        qgemm_any(a, rows, self.k, &self.bp, self.in_lo, self.in_hi, &mut prod, scratch)?;
        let mut out_shape = a.shape().to_vec();
        *out_shape.last_mut().unwrap() = self.n;
        Ok(emit_rowmajor(out_shape, prod, self.n, None, &self.epilogue, self.out_dtype, scratch))
    }
}

/// A standalone `MultiThreshold` step compiled for the resident-integer
/// plan: constant sorted threshold rows (in the producer's f32 domain),
/// binary-searched per element, with the level written directly into its
/// proven container — or f32, in which case it replays the generic op
/// verbatim.
///
/// This is the tier's **entry boundary**: a streamlined graph's input
/// `MultiThreshold` ingests the f32 graph edge and emits resident
/// `i8`/`i32` levels in one pass, so no downstream quantized kernel ever
/// sees a float activation. Integer-resident *inputs* are also accepted
/// (fuse-disabled plans chain integer kernels through standalone
/// thresholds): the value converts to f32 for the row search — exact
/// below `2^24` — keeping bit parity with the generic op.
#[derive(Debug)]
pub struct ThresholdKernel {
    channels: usize,
    steps: usize,
    rows: Vec<f32>,
    out_scale: f32,
    out_bias: f32,
    out_dtype: DType,
}

impl ThresholdKernel {
    /// Compile a standalone `MultiThreshold` with constant thresholds.
    /// Declines (`None`) on anything the generic op would reject or that
    /// it handles differently (NHWC layout, unsorted rows) — the step then
    /// stays generic with full error parity.
    pub(crate) fn try_build(node: &Node, th: &Tensor) -> Option<ThresholdKernel> {
        if node.op_type != "MultiThreshold" || node.inputs.len() != 2 || node.outputs.len() != 1 {
            return None;
        }
        if node.attr_str_or("data_layout", "NCHW") != "NCHW" {
            return None;
        }
        if th.rank() != 2 {
            return None;
        }
        let (tc, tt) = (th.shape()[0], th.shape()[1]);
        if tt == 0 {
            return None;
        }
        let vals = th.as_f32().ok()?;
        for c in 0..tc {
            let row = &vals[c * tt..(c + 1) * tt];
            if !row.windows(2).all(|w| w[0] <= w[1]) {
                return None; // unsorted: generic op reports the error
            }
        }
        Some(ThresholdKernel {
            channels: tc,
            steps: tt,
            rows: vals.to_vec(),
            out_scale: node.attr_float_or("out_scale", 1.0),
            out_bias: node.attr_float_or("out_bias", 0.0),
            out_dtype: DType::F32,
        })
    }

    /// Reassemble from persisted parts (artifact loading).
    pub(crate) fn from_parts(
        channels: usize,
        steps: usize,
        rows: Vec<f32>,
        out_scale: f32,
        out_bias: f32,
        out_dtype: DType,
    ) -> ThresholdKernel {
        ThresholdKernel { channels, steps, rows, out_scale, out_bias, out_dtype }
    }

    /// `(out_scale, out_bias)` emission params (artifact writing).
    pub(crate) fn out_params(&self) -> (f32, f32) {
        (self.out_scale, self.out_bias)
    }

    /// Narrowest container that exactly holds every emitted level.
    pub(crate) fn preferred_out_dtype(&self) -> DType {
        level_container(self.out_scale, self.out_bias, self.steps)
    }

    /// Container the residency pass chose for this kernel's output.
    pub(crate) fn set_out_dtype(&mut self, dt: DType) {
        self.out_dtype = dt;
    }

    /// The output container (f32 unless the residency pass chose tighter).
    pub fn out_dtype(&self) -> DType {
        self.out_dtype
    }

    // Verifier introspection: monotonicity and container fit are
    // re-checked from these without re-running try_build.
    pub(crate) fn channels(&self) -> usize {
        self.channels
    }

    pub(crate) fn steps(&self) -> usize {
        self.steps
    }

    pub(crate) fn rows(&self) -> &[f32] {
        &self.rows
    }

    /// Mutation-harness hook: corrupt threshold rows in place.
    pub(crate) fn rows_mut(&mut self) -> &mut Vec<f32> {
        &mut self.rows
    }

    #[inline]
    fn level(&self, v: f32, c: usize) -> f32 {
        let row = &self.rows[c * self.steps..(c + 1) * self.steps];
        // identical expression to ops::multithreshold::multi_threshold
        self.out_scale * threshold_count(row, v) as f32 + self.out_bias
    }

    pub fn run(&self, x: &Tensor, scratch: &mut ScratchArena) -> Result<Tensor> {
        // same shape/layout contract as the generic op (NCHW enforced at
        // compile time)
        let channels = match x.rank() {
            2 | 4 => x.shape()[1],
            r => anyhow::bail!("unsupported MultiThreshold input rank {r} / layout NCHW"),
        };
        ensure!(
            self.channels == channels || self.channels == 1,
            "threshold channels {} != input channels {channels}",
            self.channels
        );
        enum Src<'a> {
            F(&'a [f32]),
            B(&'a [i8]),
            W(&'a [i32]),
        }
        let src = match x.dtype() {
            DType::I8 => Src::B(x.as_i8()?),
            DType::I32 => Src::W(x.as_i32()?),
            _ => Src::F(x.as_f32()?),
        };
        // value at flat index i, in the f32 compare domain (exact for the
        // < 2^24 integer-resident containers)
        let at = |i: usize| -> f32 {
            match &src {
                Src::F(v) => v[i],
                Src::B(v) => f32::from(v[i]),
                Src::W(v) => v[i] as f32,
            }
        };
        let inner = if x.rank() == 4 { x.shape()[2] * x.shape()[3] } else { 1 };
        let chan_of =
            |flat: usize| -> usize { if self.channels == 1 { 0 } else { (flat / inner) % channels } };
        let numel = x.numel();
        let shape = x.shape().to_vec();
        Ok(match self.out_dtype {
            DType::I8 => {
                let mut out = scratch.take_i8_uninit(numel);
                for (i, o) in out.iter_mut().enumerate() {
                    *o = self.level(at(i), chan_of(i)) as i8;
                }
                Tensor::new_i8(shape, out)
            }
            DType::I32 => {
                let mut out = scratch.take_i32_uninit(numel);
                for (i, o) in out.iter_mut().enumerate() {
                    *o = self.level(at(i), chan_of(i)) as i32;
                }
                Tensor::new_i32(shape, out)
            }
            _ => {
                let mut out = scratch.take_uninit(numel);
                for (i, o) in out.iter_mut().enumerate() {
                    *o = self.level(at(i), chan_of(i));
                }
                Tensor::new(shape, out)
            }
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ops;

    fn int_range(lo: f64, hi: f64) -> ValueRange {
        ValueRange { lo, hi, integral: true }
    }

    fn int_tensor(shape: Vec<usize>, seed: u64, span: i32) -> Tensor {
        let mut s = seed.wrapping_mul(0x9E3779B97F4A7C15).wrapping_add(7);
        let n: usize = shape.iter().product();
        let data: Vec<f32> = (0..n)
            .map(|_| {
                s = s.wrapping_mul(6364136223846793005).wrapping_add(1442695040888963407);
                (((s >> 40) as i32).rem_euclid(2 * span + 1) - span) as f32
            })
            .collect();
        Tensor::new(shape, data)
    }

    #[test]
    fn quant_matmul_matches_float_matmul_exactly() {
        let node = Node::new("MatMul", &["a", "b"], &["y"]);
        let a = int_tensor(vec![5, 33], 1, 7);
        let b = int_tensor(vec![33, 9], 2, 3);
        let want = ops::linalg::matmul(&node, &[&a, &b]).unwrap();
        let qm = QuantMatMul::try_build(&b, int_range(-7.0, 7.0)).unwrap();
        let mut scratch = ScratchArena::new();
        let got = qm.run(&a, &mut scratch).unwrap();
        assert_eq!(got, want[0]);
        // warm second run reuses pooled i32 scratch
        assert_eq!(qm.run(&a, &mut scratch).unwrap(), want[0]);
        // batched lhs
        let a3 = int_tensor(vec![2, 4, 33], 3, 7);
        let want3 = ops::linalg::matmul(&node, &[&a3, &b]).unwrap();
        assert_eq!(qm.run(&a3, &mut scratch).unwrap(), want3[0]);
    }

    #[test]
    fn resident_integer_inputs_match_float_inputs() {
        // the same activations fed as f32, i32-resident, and i8-resident
        // containers produce identical results through every input path
        let node = Node::new("MatMul", &["a", "b"], &["y"]);
        let af = int_tensor(vec![5, 33], 4, 7);
        let b = int_tensor(vec![33, 9], 5, 3);
        let want = ops::linalg::matmul(&node, &[&af, &b]).unwrap();
        let qm = QuantMatMul::try_build(&b, int_range(-7.0, 7.0)).unwrap();
        let mut scratch = ScratchArena::new();
        let a32 = Tensor::new_i32(
            af.shape().to_vec(),
            af.as_f32().unwrap().iter().map(|&v| v as i32).collect(),
        );
        let a8 = Tensor::new_i8(
            af.shape().to_vec(),
            af.as_f32().unwrap().iter().map(|&v| v as i8).collect(),
        );
        assert_eq!(qm.run(&af, &mut scratch).unwrap(), want[0]);
        assert_eq!(qm.run(&a32, &mut scratch).unwrap(), want[0]);
        assert_eq!(qm.run(&a8, &mut scratch).unwrap(), want[0]);
    }

    #[test]
    fn integer_emission_matches_f32_emission() {
        // i8/i32 output containers hold exactly the f32 values
        let mm = Node::new("MatMul", &["a", "b"], &["acc"]);
        let mt = Node::new("MultiThreshold", &["acc", "t"], &["y"])
            .with_attr("out_scale", 1.0f32)
            .with_attr("out_bias", -2.0f32);
        let a = int_tensor(vec![3, 16], 6, 7);
        let b = int_tensor(vec![16, 4], 7, 1);
        let th = Tensor::new(vec![1, 3], vec![-5.0, 0.0, 5.0]);
        let mut qm = QuantMatMul::try_build(&b, int_range(-7.0, 7.0)).unwrap();
        let qt = QThreshold::try_build(&mt, &th, qm.out_channels()).unwrap();
        qm.set_epilogue(qt);
        assert_eq!(qm.preferred_out_dtype(), DType::I8, "levels in [-2, 1] fit i8");
        let mut scratch = ScratchArena::new();
        let yf = qm.run(&a, &mut scratch).unwrap();
        qm.set_out_dtype(DType::I8);
        let y8 = qm.run(&a, &mut scratch).unwrap();
        assert_eq!(y8.dtype(), DType::I8);
        let as_f: Vec<f32> = y8.as_i8().unwrap().iter().map(|&v| f32::from(v)).collect();
        assert_eq!(as_f.as_slice(), yf.as_f32().unwrap());
        qm.set_out_dtype(DType::I32);
        let y32 = qm.run(&a, &mut scratch).unwrap();
        let as_f: Vec<f32> = y32.as_i32().unwrap().iter().map(|&v| v as f32).collect();
        assert_eq!(as_f.as_slice(), yf.as_f32().unwrap());
        // without an epilogue the raw accumulator prefers i32
        let qm2 = QuantMatMul::try_build(&b, int_range(-7.0, 7.0)).unwrap();
        assert_eq!(qm2.preferred_out_dtype(), DType::I32);
    }

    #[test]
    fn quant_conv_matches_float_conv_exactly() {
        let node = Node::new("Conv", &["x", "w"], &["y"])
            .with_attr("kernel_shape", vec![3i64, 3])
            .with_attr("pads", vec![1i64, 1, 1, 1]);
        let x = int_tensor(vec![2, 3, 6, 6], 4, 15);
        let w = int_tensor(vec![4, 3, 3, 3], 5, 2);
        let want = ops::linalg::conv(&node, &[&x, &w]).unwrap();
        let qc = QuantConv::try_build(&node, &w, int_range(-15.0, 15.0)).unwrap();
        let got = qc.run(&x, &mut ScratchArena::new()).unwrap();
        assert_eq!(got, want[0]);
    }

    #[test]
    fn quant_conv_resident_i8_input_matches() {
        let node = Node::new("Conv", &["x", "w"], &["y"])
            .with_attr("kernel_shape", vec![2i64, 2]);
        let xf = int_tensor(vec![2, 3, 5, 5], 8, 7);
        let w = int_tensor(vec![4, 3, 2, 2], 9, 2);
        let want = ops::linalg::conv(&node, &[&xf, &w]).unwrap();
        let qc = QuantConv::try_build(&node, &w, int_range(-7.0, 7.0)).unwrap();
        let mut scratch = ScratchArena::new();
        let x8 = Tensor::new_i8(
            xf.shape().to_vec(),
            xf.as_f32().unwrap().iter().map(|&v| v as i8).collect(),
        );
        let x32 = Tensor::new_i32(
            xf.shape().to_vec(),
            xf.as_f32().unwrap().iter().map(|&v| v as i32).collect(),
        );
        assert_eq!(qc.run(&x8, &mut scratch).unwrap(), want[0]);
        assert_eq!(qc.run(&x32, &mut scratch).unwrap(), want[0]);
        // warm reruns through the pooled i8 panels stay exact
        assert_eq!(qc.run(&x8, &mut scratch).unwrap(), want[0]);
    }

    #[test]
    fn quant_grouped_conv_matches_float() {
        let node = Node::new("Conv", &["x", "w"], &["y"])
            .with_attr("kernel_shape", vec![2i64, 2])
            .with_attr("group", 2i64);
        let x = int_tensor(vec![1, 4, 5, 5], 6, 7);
        let w = int_tensor(vec![6, 2, 2, 2], 7, 3);
        let want = ops::linalg::conv(&node, &[&x, &w]).unwrap();
        let qc = QuantConv::try_build(&node, &w, int_range(-7.0, 7.0)).unwrap();
        assert_eq!(qc.run(&x, &mut ScratchArena::new()).unwrap(), want[0]);
    }

    #[test]
    fn fused_threshold_matches_two_pass() {
        let mm = Node::new("MatMul", &["a", "b"], &["acc"]);
        let mt = Node::new("MultiThreshold", &["acc", "t"], &["y"])
            .with_attr("out_scale", 1.0f32)
            .with_attr("out_bias", -2.0f32);
        let a = int_tensor(vec![3, 16], 8, 7);
        let b = int_tensor(vec![16, 4], 9, 1);
        // per-column thresholds (4 channels x 3 steps), sorted
        let th = Tensor::new(
            vec![4, 3],
            vec![-5., 0., 5., -9., -1., 2., 0., 1., 3., -2., -2., 8.],
        );
        let acc = ops::linalg::matmul(&mm, &[&a, &b]).unwrap();
        let want = ops::multithreshold::multi_threshold(&mt, &[&acc[0], &th]).unwrap();
        let mut qm = QuantMatMul::try_build(&b, int_range(-7.0, 7.0)).unwrap();
        let qt = QThreshold::try_build(&mt, &th, qm.out_channels()).unwrap();
        qm.set_epilogue(qt);
        let got = qm.run(&a, &mut ScratchArena::new()).unwrap();
        assert_eq!(got, want[0]);
    }

    #[test]
    fn quant_gemm_with_integral_bias_matches_float() {
        let node = Node::new("Gemm", &["a", "b", "c"], &["y"])
            .with_attr("transB", 1i64)
            .with_attr("beta", 2.0f32);
        let a = int_tensor(vec![3, 5], 10, 7);
        let b = int_tensor(vec![4, 5], 11, 3); // transB: [n, k]
        let c = int_tensor(vec![1, 4], 12, 6);
        let want = ops::linalg::gemm_op(&node, &[&a, &b, &c]).unwrap();
        let qg = QuantGemm::try_build(&node, &b, Some(Some(&c)), int_range(-7.0, 7.0)).unwrap();
        let got = qg.run(&a, &mut ScratchArena::new()).unwrap();
        assert_eq!(got, want[0]);
    }

    #[test]
    fn builders_decline_non_integer_forms() {
        let r = int_range(-7.0, 7.0);
        // fractional weights
        let wf = Tensor::new(vec![2, 2], vec![0.5, 1.0, -1.0, 2.0]);
        assert!(QuantMatMul::try_build(&wf, r).is_none());
        // weights beyond i8
        let wb = Tensor::new(vec![2, 2], vec![200.0, 1.0, -1.0, 2.0]);
        assert!(QuantMatMul::try_build(&wb, r).is_none());
        // non-integral input range
        let wi = Tensor::new(vec![2, 2], vec![1.0, -1.0, 0.0, 1.0]);
        assert!(QuantMatMul::try_build(&wi, ValueRange { lo: -1.0, hi: 1.0, integral: false })
            .is_none());
        // accumulator bound: 2^20 * 127 * k blows past 2^24
        let big = ValueRange { lo: 0.0, hi: 1_048_576.0, integral: true };
        let w = Tensor::new(vec![4, 1], vec![127.0, 1.0, 1.0, 1.0]);
        assert!(QuantMatMul::try_build(&w, big).is_none());
        // conv with a bias input declines
        let node = Node::new("Conv", &["x", "w", "bias"], &["y"])
            .with_attr("kernel_shape", vec![1i64, 1]);
        let w4 = Tensor::new(vec![1, 1, 1, 1], vec![1.0]);
        assert!(QuantConv::try_build(&node, &w4, r).is_none());
        // gemm with runtime C declines
        let gn = Node::new("Gemm", &["a", "b", "c"], &["y"]);
        let b2 = Tensor::new(vec![2, 2], vec![1.0, 0.0, 0.0, 1.0]);
        assert!(QuantGemm::try_build(&gn, &b2, Some(None), r).is_none());
        // gemm with fractional beta*C declines
        let gb = Node::new("Gemm", &["a", "b", "c"], &["y"]).with_attr("beta", 0.5f32);
        let c = Tensor::new(vec![1, 2], vec![1.0, 3.0]);
        assert!(QuantGemm::try_build(&gb, &b2, Some(Some(&c)), r).is_none());
    }

    #[test]
    fn runtime_rejects_values_off_the_proven_grid() {
        let b = Tensor::new(vec![2, 2], vec![1.0, -1.0, 0.0, 1.0]);
        let qm = QuantMatMul::try_build(&b, int_range(-4.0, 4.0)).unwrap();
        let mut scratch = ScratchArena::new();
        let frac = Tensor::new(vec![1, 2], vec![0.5, 1.0]);
        let err = qm.run(&frac, &mut scratch).unwrap_err().to_string();
        assert!(err.contains("off the proven integer grid"), "{err}");
        let oob = Tensor::new(vec![1, 2], vec![5.0, 1.0]);
        assert!(qm.run(&oob, &mut scratch).is_err());
    }

    #[test]
    fn threshold_builder_declines_bad_rows() {
        let mt = Node::new("MultiThreshold", &["x", "t"], &["y"]);
        // unsorted
        let bad = Tensor::new(vec![1, 2], vec![3.0, 1.0]);
        assert!(QThreshold::try_build(&mt, &bad, 4).is_none());
        // fractional
        let frac = Tensor::new(vec![1, 2], vec![0.5, 1.0]);
        assert!(QThreshold::try_build(&mt, &frac, 4).is_none());
        // channel mismatch (neither 1 nor out_channels)
        let two = Tensor::new(vec![2, 1], vec![0.0, 1.0]);
        assert!(QThreshold::try_build(&mt, &two, 4).is_none());
        // NHWC layout
        let nhwc = Node::new("MultiThreshold", &["x", "t"], &["y"]).with_attr("data_layout", "NHWC");
        let ok = Tensor::new(vec![1, 1], vec![0.0]);
        assert!(QThreshold::try_build(&nhwc, &ok, 4).is_none());
        assert!(QThreshold::try_build(&mt, &ok, 4).is_some());
    }

    #[test]
    fn threshold_kernel_matches_generic_op() {
        // standalone MT with fractional thresholds (the float graph edge)
        // but integral out params: emits integer levels exactly
        let mt = Node::new("MultiThreshold", &["x", "t"], &["y"])
            .with_attr("out_scale", 1.0f32)
            .with_attr("out_bias", 0.0f32);
        let th = Tensor::new(vec![2, 3], vec![0.5, 1.5, 2.5, -0.25, 0.75, 1.75]);
        let x = Tensor::new(
            vec![2, 2, 2, 2],
            (0..16).map(|v| v as f32 * 0.4 - 1.3).collect(),
        );
        let want = ops::multithreshold::multi_threshold(&mt, &[&x, &th]).unwrap();
        let mut tk = ThresholdKernel::try_build(&mt, &th).unwrap();
        assert_eq!(tk.preferred_out_dtype(), DType::I8, "levels 0..=3 fit i8");
        let mut scratch = ScratchArena::new();
        // f32 emission replays the generic op bit for bit
        let got_f = tk.run(&x, &mut scratch).unwrap();
        assert_eq!(got_f, want[0]);
        // i8 emission holds the identical values
        tk.set_out_dtype(DType::I8);
        let got8 = tk.run(&x, &mut scratch).unwrap();
        assert_eq!(got8.dtype(), DType::I8);
        let as_f: Vec<f32> = got8.as_i8().unwrap().iter().map(|&v| f32::from(v)).collect();
        assert_eq!(as_f.as_slice(), want[0].as_f32().unwrap());
        // integer-resident input: compare domain converts exactly
        let xi = Tensor::new_i32(vec![1, 2], vec![1, 2]);
        let want_i =
            ops::multithreshold::multi_threshold(&mt, &[&Tensor::new(vec![1, 2], vec![1.0, 2.0]), &th])
                .unwrap();
        tk.set_out_dtype(DType::F32);
        assert_eq!(tk.run(&xi, &mut scratch).unwrap(), want_i[0]);
        // rank/channel errors keep generic-op parity
        let bad_rank = Tensor::new(vec![4], vec![0.0; 4]);
        let err = tk.run(&bad_rank, &mut scratch).unwrap_err().to_string();
        assert!(err.contains("unsupported MultiThreshold input rank"), "{err}");
        let bad_ch = Tensor::new(vec![1, 3], vec![0.0; 3]);
        let err = tk.run(&bad_ch, &mut scratch).unwrap_err().to_string();
        assert!(err.contains("threshold channels"), "{err}");
    }

    #[test]
    fn threshold_kernel_container_boundaries() {
        // 255 steps with bias 0 -> levels 0..=255: i8 cannot hold them
        let mt = Node::new("MultiThreshold", &["x", "t"], &["y"]);
        let th = Tensor::new(vec![1, 255], (0..255).map(|v| v as f32 + 0.5).collect());
        let tk = ThresholdKernel::try_build(&mt, &th).unwrap();
        assert_eq!(tk.preferred_out_dtype(), DType::I32);
        // 127 steps stays i8
        let th8 = Tensor::new(vec![1, 127], (0..127).map(|v| v as f32 + 0.5).collect());
        let tk8 = ThresholdKernel::try_build(&mt, &th8).unwrap();
        assert_eq!(tk8.preferred_out_dtype(), DType::I8);
        // fractional out_scale keeps f32
        let mtf = Node::new("MultiThreshold", &["x", "t"], &["y"]).with_attr("out_scale", 0.5f32);
        let tkf = ThresholdKernel::try_build(&mtf, &th8).unwrap();
        assert_eq!(tkf.preferred_out_dtype(), DType::F32);
        // unsorted rows decline
        let bad = Tensor::new(vec![1, 2], vec![3.0, 1.0]);
        assert!(ThresholdKernel::try_build(&mt, &bad).is_none());
    }
}

//! The quantized (tier-3) kernels: integer-domain `Conv`/`Gemm`/`MatMul`
//! with `i8` weight panels, `i32` accumulation, and the streamlined
//! `MultiThreshold` activation fused as the scatter-loop epilogue.
//!
//! The plan compiler selects these whenever the value-range proofs from
//! [`crate::transforms::infer_ranges`] show that a linear op's runtime
//! input lives on a literal integer grid and its constant weights fit
//! `i8` — the form [`crate::streamline`] produces. Compared with the
//! packed float tier this moves 4-byte weight traffic to 1 byte, turns
//! the inner loop into integer MACs (no rounding, so no accumulation-
//! order contract is needed), and replaces the separate full-tensor
//! `MultiThreshold` pass with a per-element binary search over `i32`
//! thresholds inside the scatter loop.
//!
//! # Exactness contract
//!
//! Selection requires every accumulator magnitude (including any folded
//! integer bias) to stay below `2^24`. Under that bound the `i32` result
//! is exactly representable in the f32 container, so a quantized plan is
//! **byte-identical** to running the same streamlined graph through the
//! float kernels or the reference interpreter — `tests/plan_equiv.rs`
//! asserts this across the zoo. The runtime conversion re-checks that
//! bound: a caller binding values off the proven grid (violating the
//! graph's datatype annotations) gets an error, not silent truncation.

use super::arena::ScratchArena;
use crate::ir::Node;
use crate::ops::linalg::{conv_params, ConvParams};
use crate::ops::multithreshold::threshold_count_i32;
use crate::tensor::{conv_out_dim, im2col_group_into, qgemm_prepacked, PackedBi8, Tensor};
use crate::transforms::ValueRange;
use anyhow::{ensure, Result};

/// Largest magnitude exactly representable on the f32 integer grid; the
/// compile-time accumulator bound AND the runtime input-validation bound.
const EXACT_F32_LIMIT: f64 = 16_777_216.0; // 2^24

/// Extract a tensor's values as `i8`, or `None` if any value is off the
/// integer grid or outside `[-128, 127]`.
fn to_i8(vals: &[f32]) -> Option<Vec<i8>> {
    let mut out = Vec::with_capacity(vals.len());
    for &v in vals {
        let vf = f64::from(v);
        if vf.fract() != 0.0 || !(-128.0..=127.0).contains(&vf) {
            return None;
        }
        out.push(v as i8);
    }
    Some(out)
}

/// Max absolute value of an integral range (None when unusable).
fn range_abs(r: ValueRange) -> Option<f64> {
    if !r.integral || !r.lo.is_finite() || !r.hi.is_finite() {
        return None;
    }
    Some(r.lo.abs().max(r.hi.abs()))
}

/// Convert a proven-integral f32 slice into `i32`, re-validating the
/// compile-time range proof per element.
fn to_i32_checked(src: &[f32], lo: f64, hi: f64, out: &mut [i32]) -> Result<()> {
    debug_assert_eq!(src.len(), out.len());
    for (&v, o) in src.iter().zip(out.iter_mut()) {
        let vf = f64::from(v);
        ensure!(
            vf.fract() == 0.0 && vf >= lo && vf <= hi,
            "quantized-tier input value {v} is off the proven integer grid [{lo}, {hi}] \
             (the bound datatype annotation does not match the runtime data)"
        );
        *o = v as i32;
    }
    Ok(())
}

/// A `MultiThreshold` stage fused into a quantized kernel's scatter loop:
/// per-channel sorted `i32` threshold rows, counted by binary search,
/// with the node's `out_scale`/`out_bias` replayed in f32 exactly as the
/// generic op computes them.
#[derive(Debug, Clone)]
pub(crate) struct QThreshold {
    channels: usize,
    steps: usize,
    rows: Vec<i32>,
    out_scale: f32,
    out_bias: f32,
}

impl QThreshold {
    /// Compile a `MultiThreshold` node whose thresholds are a compile-time
    /// constant into a fused integer epilogue. Declines (`None`) whenever
    /// anything deviates from the integer-domain form — the node then
    /// stays a separate generic step with full error parity.
    pub(crate) fn try_build(node: &Node, th: &Tensor, out_channels: usize) -> Option<QThreshold> {
        if node.op_type != "MultiThreshold" || node.inputs.len() != 2 || node.outputs.len() != 1 {
            return None;
        }
        if node.attr_str_or("data_layout", "NCHW") != "NCHW" {
            return None;
        }
        if th.rank() != 2 {
            return None;
        }
        let (tc, tt) = (th.shape()[0], th.shape()[1]);
        if (tc != out_channels && tc != 1) || tt == 0 {
            return None;
        }
        let vals = th.as_f32().ok()?;
        let mut rows = Vec::with_capacity(vals.len());
        for &v in vals {
            let vf = f64::from(v);
            if vf.fract() != 0.0 || vf.abs() >= EXACT_F32_LIMIT {
                return None;
            }
            rows.push(v as i32);
        }
        for c in 0..tc {
            let row = &rows[c * tt..(c + 1) * tt];
            if !row.windows(2).all(|w| w[0] <= w[1]) {
                return None; // unsorted: generic op reports the error
            }
        }
        Some(QThreshold {
            channels: tc,
            steps: tt,
            rows,
            out_scale: node.attr_float_or("out_scale", 1.0),
            out_bias: node.attr_float_or("out_bias", 0.0),
        })
    }

    #[inline]
    fn apply(&self, acc: i32, oc: usize) -> f32 {
        let c = if self.channels == 1 { 0 } else { oc };
        let row = &self.rows[c * self.steps..(c + 1) * self.steps];
        // identical expression to ops::multithreshold::multi_threshold
        self.out_scale * threshold_count_i32(row, acc) as f32 + self.out_bias
    }
}

#[inline]
fn emit(epilogue: &Option<QThreshold>, acc: i32, oc: usize) -> f32 {
    match epilogue {
        None => acc as f32, // exact: |acc| < 2^24 by the compile-time bound
        Some(t) => t.apply(acc, oc),
    }
}

/// Integer-domain conv: `i8` weight panels per group, `i32` im2col +
/// accumulate, fused `MultiThreshold` in the scatter loop.
#[derive(Debug)]
pub struct QuantConv {
    p: ConvParams,
    m: usize,
    cg: usize,
    mg: usize,
    k: usize,
    weights: Vec<PackedBi8>,
    in_lo: f64,
    in_hi: f64,
    epilogue: Option<QThreshold>,
}

impl QuantConv {
    /// Build from a conv node with constant `i8`-grid weights and a
    /// proven-integral input range. Declines on anything unsupported
    /// (bias input, NHWC wrapper, non-integer weights, accumulator bound):
    /// the caller then falls back to the packed float tier.
    pub(crate) fn try_build(node: &Node, w: &Tensor, r: ValueRange) -> Option<QuantConv> {
        if node.inputs.get(2).map(String::as_str).is_some_and(|s| !s.is_empty()) {
            return None; // streamlined graphs carry no conv bias
        }
        if node.attr_str_or("data_layout", "NCHW") != "NCHW" {
            return None;
        }
        if w.rank() != 4 {
            return None;
        }
        let p = conv_params(node, w.shape()).ok()?;
        let ws = to_i8(w.as_f32().ok()?)?;
        let m = w.shape()[0];
        let cg = w.shape()[1];
        if p.group == 0 || m % p.group != 0 {
            return None;
        }
        let mg = m / p.group;
        let k = cg * p.kh * p.kw;
        let in_abs = range_abs(r)?;
        let w_abs = ws.iter().map(|&v| i32::from(v).abs()).max().unwrap_or(0) as f64;
        if in_abs * w_abs * k as f64 >= EXACT_F32_LIMIT {
            return None;
        }
        // per-group [mg, k] weight rows transposed to [k, mg] (the same
        // shared helper the f32 paths use), packed once
        let mut weights = Vec::with_capacity(p.group);
        for g in 0..p.group {
            let wt = crate::ops::linalg::transpose_group_weights(&ws, g, mg, k);
            weights.push(PackedBi8::pack(k, mg, &wt));
        }
        Some(QuantConv {
            p,
            m,
            cg,
            mg,
            k,
            weights,
            in_lo: r.lo,
            in_hi: r.hi,
            epilogue: None,
        })
    }

    /// Output channels (`M`) — the axis a fused threshold indexes.
    pub(crate) fn out_channels(&self) -> usize {
        self.m
    }

    pub(crate) fn set_epilogue(&mut self, t: QThreshold) {
        self.epilogue = Some(t);
    }

    /// Whether a `MultiThreshold` stage is fused in.
    pub fn has_fused_threshold(&self) -> bool {
        self.epilogue.is_some()
    }

    /// Execute on an NCHW input of any batch size.
    pub fn run(&self, x: &Tensor, scratch: &mut ScratchArena) -> Result<Tensor> {
        ensure!(x.rank() == 4, "Conv input must be NCHW, got {:?}", x.shape());
        let (n, c, h, w) = (x.shape()[0], x.shape()[1], x.shape()[2], x.shape()[3]);
        ensure!(
            c == self.cg * self.p.group,
            "channel mismatch: x has {c}, w wants {} x group {}",
            self.cg,
            self.p.group
        );
        let xs = x.as_f32()?;
        let mut xi = scratch.take_i32_uninit(xs.len());
        to_i32_checked(xs, self.in_lo, self.in_hi, &mut xi)?;
        let p = &self.p;
        let oh = conv_out_dim(h, p.kh, p.stride_h, p.pads[0], p.pads[2]);
        let ow = conv_out_dim(w, p.kw, p.stride_w, p.pads[1], p.pads[3]);
        let rows = n * oh * ow;
        let mut out = scratch.take_uninit(n * self.m * oh * ow);
        let mut cols = scratch.take_i32(rows * self.k);
        let mut prod = scratch.take_i32(rows * self.mg);
        for g in 0..p.group {
            if g > 0 {
                prod.fill(0); // qgemm accumulates; cols' padding zeros persist
            }
            im2col_group_into(
                &xi, n, c, h, w, g * self.cg, self.cg, p.kh, p.kw, p.stride_h, p.stride_w,
                p.pads, &mut cols,
            );
            qgemm_prepacked(rows, self.k, &self.weights[g], &cols, &mut prod);
            // scatter [rows, mg] -> NCHW, fusing the threshold per element
            for b in 0..n {
                for mi in 0..self.mg {
                    let oc = g * self.mg + mi;
                    let dst = (b * self.m + oc) * oh * ow;
                    let src0 = b * oh * ow;
                    for pix in 0..oh * ow {
                        out[dst + pix] = emit(&self.epilogue, prod[(src0 + pix) * self.mg + mi], oc);
                    }
                }
            }
        }
        scratch.give_i32(xi);
        scratch.give_i32(cols);
        scratch.give_i32(prod);
        Ok(Tensor::new(vec![n, self.m, oh, ow], out))
    }
}

/// Integer-domain `Gemm` (no runtime `C`): constant `i8` `B` with `transB`
/// applied at pack time; a constant integral `beta * C` folds into an
/// `i32` per-column bias inside the accumulator.
#[derive(Debug)]
pub struct QuantGemm {
    k: usize,
    n: usize,
    bp: PackedBi8,
    bias: Option<Vec<i32>>,
    in_lo: f64,
    in_hi: f64,
    epilogue: Option<QThreshold>,
}

impl QuantGemm {
    /// `c` is `None` when the node has no C input, `Some(None)` when C is
    /// a runtime value (declines — the float tier handles it),
    /// `Some(Some(t))` when C is constant.
    pub(crate) fn try_build(
        node: &Node,
        b: &Tensor,
        c: Option<Option<&Tensor>>,
        r: ValueRange,
    ) -> Option<QuantGemm> {
        if node.attr_float_or("alpha", 1.0) != 1.0 || node.attr_int_or("transA", 0) != 0 {
            return None;
        }
        let beta = f64::from(node.attr_float_or("beta", 1.0));
        let trans_b = node.attr_int_or("transB", 0) != 0;
        if b.rank() != 2 {
            return None;
        }
        let bt = if trans_b { b.transpose(&[1, 0]).ok()? } else { b.clone() };
        let (k, n) = (bt.shape()[0], bt.shape()[1]);
        let bi = to_i8(bt.as_f32().ok()?)?;
        let in_abs = range_abs(r)?;
        let w_abs = bi.iter().map(|&v| i32::from(v).abs()).max().unwrap_or(0) as f64;
        let bias = match c {
            None => None,
            Some(None) => return None, // runtime C stays on the float tier
            Some(Some(ct)) => {
                // per-column broadcast only ([n] / [1, n] / scalar); a
                // per-row or full-matrix C stays on the float tier
                let per_column = ct.numel() == 1
                    || (ct.numel() == n
                        && (ct.rank() == 1 || (ct.rank() == 2 && ct.shape()[0] == 1)));
                if !per_column {
                    return None;
                }
                let cv = ct.as_f32().ok()?;
                let mut out = Vec::with_capacity(n);
                for j in 0..n {
                    let v = beta * f64::from(cv[j % cv.len()]);
                    if v.fract() != 0.0 || v.abs() >= EXACT_F32_LIMIT {
                        return None;
                    }
                    out.push(v as i32);
                }
                Some(out)
            }
        };
        let c_abs = bias
            .as_ref()
            .map(|b| b.iter().map(|&v| v.abs()).max().unwrap_or(0) as f64)
            .unwrap_or(0.0);
        if in_abs * w_abs * k as f64 + c_abs >= EXACT_F32_LIMIT {
            return None;
        }
        Some(QuantGemm {
            k,
            n,
            bp: PackedBi8::pack(k, n, &bi),
            bias,
            in_lo: r.lo,
            in_hi: r.hi,
            epilogue: None,
        })
    }

    pub(crate) fn out_channels(&self) -> usize {
        self.n
    }

    pub(crate) fn set_epilogue(&mut self, t: QThreshold) {
        self.epilogue = Some(t);
    }

    /// Whether a `MultiThreshold` stage is fused in.
    pub fn has_fused_threshold(&self) -> bool {
        self.epilogue.is_some()
    }

    pub fn run(&self, a: &Tensor, scratch: &mut ScratchArena) -> Result<Tensor> {
        ensure!(a.rank() == 2, "matmul2d wants rank-2");
        let (m, ak) = (a.shape()[0], a.shape()[1]);
        ensure!(ak == self.k, "matmul2d inner dim mismatch {ak} vs {}", self.k);
        let xs = a.as_f32()?;
        let mut xi = scratch.take_i32_uninit(xs.len());
        to_i32_checked(xs, self.in_lo, self.in_hi, &mut xi)?;
        let mut prod = scratch.take_i32(m * self.n);
        qgemm_prepacked(m, self.k, &self.bp, &xi, &mut prod);
        let mut out = scratch.take_uninit(m * self.n);
        for (i, (o, &acc)) in out.iter_mut().zip(prod.iter()).enumerate() {
            let oc = i % self.n;
            let acc = match &self.bias {
                Some(bv) => acc + bv[oc],
                None => acc,
            };
            *o = emit(&self.epilogue, acc, oc);
        }
        scratch.give_i32(xi);
        scratch.give_i32(prod);
        Ok(Tensor::new(vec![m, self.n], out))
    }
}

/// Integer-domain `MatMul` with a constant rank-2 `i8` rhs; batched
/// (>2-D) lhs is flattened by view like the packed float kernel.
#[derive(Debug)]
pub struct QuantMatMul {
    k: usize,
    n: usize,
    bp: PackedBi8,
    in_lo: f64,
    in_hi: f64,
    epilogue: Option<QThreshold>,
}

impl QuantMatMul {
    pub(crate) fn try_build(b: &Tensor, r: ValueRange) -> Option<QuantMatMul> {
        if b.rank() != 2 {
            return None;
        }
        let (k, n) = (b.shape()[0], b.shape()[1]);
        let bi = to_i8(b.as_f32().ok()?)?;
        let in_abs = range_abs(r)?;
        let w_abs = bi.iter().map(|&v| i32::from(v).abs()).max().unwrap_or(0) as f64;
        if in_abs * w_abs * k as f64 >= EXACT_F32_LIMIT {
            return None;
        }
        Some(QuantMatMul {
            k,
            n,
            bp: PackedBi8::pack(k, n, &bi),
            in_lo: r.lo,
            in_hi: r.hi,
            epilogue: None,
        })
    }

    pub(crate) fn out_channels(&self) -> usize {
        self.n
    }

    pub(crate) fn set_epilogue(&mut self, t: QThreshold) {
        self.epilogue = Some(t);
    }

    /// Whether a `MultiThreshold` stage is fused in.
    pub fn has_fused_threshold(&self) -> bool {
        self.epilogue.is_some()
    }

    pub fn run(&self, a: &Tensor, scratch: &mut ScratchArena) -> Result<Tensor> {
        if a.rank() > 2 && self.epilogue.is_some() {
            // the generic MultiThreshold op only supports rank-2/4 inputs;
            // keep the fused path's error surface aligned with it
            anyhow::bail!("unsupported MultiThreshold input rank {} after batched MatMul", a.rank());
        }
        ensure!(a.rank() >= 2, "unsupported MatMul lhs rank {:?}", a.shape());
        let ak = *a.shape().last().unwrap();
        ensure!(ak == self.k, "matmul2d inner dim mismatch {ak} vs {}", self.k);
        let rows = a.numel() / ak;
        let xs = a.as_f32()?;
        let mut xi = scratch.take_i32_uninit(xs.len());
        to_i32_checked(xs, self.in_lo, self.in_hi, &mut xi)?;
        let mut prod = scratch.take_i32(rows * self.n);
        qgemm_prepacked(rows, self.k, &self.bp, &xi, &mut prod);
        let mut out = scratch.take_uninit(rows * self.n);
        for (i, (o, &acc)) in out.iter_mut().zip(prod.iter()).enumerate() {
            *o = emit(&self.epilogue, acc, i % self.n);
        }
        scratch.give_i32(xi);
        scratch.give_i32(prod);
        let mut out_shape = a.shape().to_vec();
        *out_shape.last_mut().unwrap() = self.n;
        Ok(Tensor::new(out_shape, out))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ops;

    fn int_range(lo: f64, hi: f64) -> ValueRange {
        ValueRange { lo, hi, integral: true }
    }

    fn int_tensor(shape: Vec<usize>, seed: u64, span: i32) -> Tensor {
        let mut s = seed.wrapping_mul(0x9E3779B97F4A7C15).wrapping_add(7);
        let n: usize = shape.iter().product();
        let data: Vec<f32> = (0..n)
            .map(|_| {
                s = s.wrapping_mul(6364136223846793005).wrapping_add(1442695040888963407);
                (((s >> 40) as i32).rem_euclid(2 * span + 1) - span) as f32
            })
            .collect();
        Tensor::new(shape, data)
    }

    #[test]
    fn quant_matmul_matches_float_matmul_exactly() {
        let node = Node::new("MatMul", &["a", "b"], &["y"]);
        let a = int_tensor(vec![5, 33], 1, 7);
        let b = int_tensor(vec![33, 9], 2, 3);
        let want = ops::linalg::matmul(&node, &[&a, &b]).unwrap();
        let qm = QuantMatMul::try_build(&b, int_range(-7.0, 7.0)).unwrap();
        let mut scratch = ScratchArena::new();
        let got = qm.run(&a, &mut scratch).unwrap();
        assert_eq!(got, want[0]);
        // warm second run reuses pooled i32 scratch
        assert_eq!(qm.run(&a, &mut scratch).unwrap(), want[0]);
        // batched lhs
        let a3 = int_tensor(vec![2, 4, 33], 3, 7);
        let want3 = ops::linalg::matmul(&node, &[&a3, &b]).unwrap();
        assert_eq!(qm.run(&a3, &mut scratch).unwrap(), want3[0]);
    }

    #[test]
    fn quant_conv_matches_float_conv_exactly() {
        let node = Node::new("Conv", &["x", "w"], &["y"])
            .with_attr("kernel_shape", vec![3i64, 3])
            .with_attr("pads", vec![1i64, 1, 1, 1]);
        let x = int_tensor(vec![2, 3, 6, 6], 4, 15);
        let w = int_tensor(vec![4, 3, 3, 3], 5, 2);
        let want = ops::linalg::conv(&node, &[&x, &w]).unwrap();
        let qc = QuantConv::try_build(&node, &w, int_range(-15.0, 15.0)).unwrap();
        let got = qc.run(&x, &mut ScratchArena::new()).unwrap();
        assert_eq!(got, want[0]);
    }

    #[test]
    fn quant_grouped_conv_matches_float() {
        let node = Node::new("Conv", &["x", "w"], &["y"])
            .with_attr("kernel_shape", vec![2i64, 2])
            .with_attr("group", 2i64);
        let x = int_tensor(vec![1, 4, 5, 5], 6, 7);
        let w = int_tensor(vec![6, 2, 2, 2], 7, 3);
        let want = ops::linalg::conv(&node, &[&x, &w]).unwrap();
        let qc = QuantConv::try_build(&node, &w, int_range(-7.0, 7.0)).unwrap();
        assert_eq!(qc.run(&x, &mut ScratchArena::new()).unwrap(), want[0]);
    }

    #[test]
    fn fused_threshold_matches_two_pass() {
        let mm = Node::new("MatMul", &["a", "b"], &["acc"]);
        let mt = Node::new("MultiThreshold", &["acc", "t"], &["y"])
            .with_attr("out_scale", 1.0f32)
            .with_attr("out_bias", -2.0f32);
        let a = int_tensor(vec![3, 16], 8, 7);
        let b = int_tensor(vec![16, 4], 9, 1);
        // per-column thresholds (4 channels x 3 steps), sorted
        let th = Tensor::new(
            vec![4, 3],
            vec![-5., 0., 5., -9., -1., 2., 0., 1., 3., -2., -2., 8.],
        );
        let acc = ops::linalg::matmul(&mm, &[&a, &b]).unwrap();
        let want = ops::multithreshold::multi_threshold(&mt, &[&acc[0], &th]).unwrap();
        let mut qm = QuantMatMul::try_build(&b, int_range(-7.0, 7.0)).unwrap();
        let qt = QThreshold::try_build(&mt, &th, qm.out_channels()).unwrap();
        qm.set_epilogue(qt);
        let got = qm.run(&a, &mut ScratchArena::new()).unwrap();
        assert_eq!(got, want[0]);
    }

    #[test]
    fn quant_gemm_with_integral_bias_matches_float() {
        let node = Node::new("Gemm", &["a", "b", "c"], &["y"])
            .with_attr("transB", 1i64)
            .with_attr("beta", 2.0f32);
        let a = int_tensor(vec![3, 5], 10, 7);
        let b = int_tensor(vec![4, 5], 11, 3); // transB: [n, k]
        let c = int_tensor(vec![1, 4], 12, 6);
        let want = ops::linalg::gemm_op(&node, &[&a, &b, &c]).unwrap();
        let qg = QuantGemm::try_build(&node, &b, Some(Some(&c)), int_range(-7.0, 7.0)).unwrap();
        let got = qg.run(&a, &mut ScratchArena::new()).unwrap();
        assert_eq!(got, want[0]);
    }

    #[test]
    fn builders_decline_non_integer_forms() {
        let r = int_range(-7.0, 7.0);
        // fractional weights
        let wf = Tensor::new(vec![2, 2], vec![0.5, 1.0, -1.0, 2.0]);
        assert!(QuantMatMul::try_build(&wf, r).is_none());
        // weights beyond i8
        let wb = Tensor::new(vec![2, 2], vec![200.0, 1.0, -1.0, 2.0]);
        assert!(QuantMatMul::try_build(&wb, r).is_none());
        // non-integral input range
        let wi = Tensor::new(vec![2, 2], vec![1.0, -1.0, 0.0, 1.0]);
        assert!(QuantMatMul::try_build(&wi, ValueRange { lo: -1.0, hi: 1.0, integral: false })
            .is_none());
        // accumulator bound: 2^20 * 127 * k blows past 2^24
        let big = ValueRange { lo: 0.0, hi: 1_048_576.0, integral: true };
        let w = Tensor::new(vec![4, 1], vec![127.0, 1.0, 1.0, 1.0]);
        assert!(QuantMatMul::try_build(&w, big).is_none());
        // conv with a bias input declines
        let node = Node::new("Conv", &["x", "w", "bias"], &["y"])
            .with_attr("kernel_shape", vec![1i64, 1]);
        let w4 = Tensor::new(vec![1, 1, 1, 1], vec![1.0]);
        assert!(QuantConv::try_build(&node, &w4, r).is_none());
        // gemm with runtime C declines
        let gn = Node::new("Gemm", &["a", "b", "c"], &["y"]);
        let b2 = Tensor::new(vec![2, 2], vec![1.0, 0.0, 0.0, 1.0]);
        assert!(QuantGemm::try_build(&gn, &b2, Some(None), r).is_none());
        // gemm with fractional beta*C declines
        let gb = Node::new("Gemm", &["a", "b", "c"], &["y"]).with_attr("beta", 0.5f32);
        let c = Tensor::new(vec![1, 2], vec![1.0, 3.0]);
        assert!(QuantGemm::try_build(&gb, &b2, Some(Some(&c)), r).is_none());
    }

    #[test]
    fn runtime_rejects_values_off_the_proven_grid() {
        let b = Tensor::new(vec![2, 2], vec![1.0, -1.0, 0.0, 1.0]);
        let qm = QuantMatMul::try_build(&b, int_range(-4.0, 4.0)).unwrap();
        let mut scratch = ScratchArena::new();
        let frac = Tensor::new(vec![1, 2], vec![0.5, 1.0]);
        let err = qm.run(&frac, &mut scratch).unwrap_err().to_string();
        assert!(err.contains("off the proven integer grid"), "{err}");
        let oob = Tensor::new(vec![1, 2], vec![5.0, 1.0]);
        assert!(qm.run(&oob, &mut scratch).is_err());
    }

    #[test]
    fn threshold_builder_declines_bad_rows() {
        let mt = Node::new("MultiThreshold", &["x", "t"], &["y"]);
        // unsorted
        let bad = Tensor::new(vec![1, 2], vec![3.0, 1.0]);
        assert!(QThreshold::try_build(&mt, &bad, 4).is_none());
        // fractional
        let frac = Tensor::new(vec![1, 2], vec![0.5, 1.0]);
        assert!(QThreshold::try_build(&mt, &frac, 4).is_none());
        // channel mismatch (neither 1 nor out_channels)
        let two = Tensor::new(vec![2, 1], vec![0.0, 1.0]);
        assert!(QThreshold::try_build(&mt, &two, 4).is_none());
        // NHWC layout
        let nhwc = Node::new("MultiThreshold", &["x", "t"], &["y"]).with_attr("data_layout", "NHWC");
        let ok = Tensor::new(vec![1, 1], vec![0.0]);
        assert!(QThreshold::try_build(&nhwc, &ok, 4).is_none());
        assert!(QThreshold::try_build(&mt, &ok, 4).is_some());
    }
}

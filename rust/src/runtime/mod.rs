//! PJRT artifact runtime — the serving hot path.
//!
//! Loads the HLO-text artifacts produced by `python/compile/aot.py` (via
//! `make artifacts`), compiles them once on the PJRT CPU client, and
//! executes them with zero Python involvement. See /opt/xla-example for
//! the interchange-format rationale (HLO text, not serialized protos).

pub mod pool;

// The PJRT bindings this module was written against are not available as
// a crate dependency in this build; the typed stub keeps the artifact
// runtime compiling (every entry point reports the missing backend at
// run time, and all PJRT paths sit behind artifact-existence guards).
// Swapping in the real crate is this one line.
mod xla_stub;
use xla_stub as xla;

use crate::tensor::Tensor;
use anyhow::{ensure, Context, Result};
use std::path::{Path, PathBuf};

/// Artifact metadata sidecar (`<tag>.meta.json`) written by aot.py:
/// shapes plus a probe input/output vector for end-to-end self-checks.
#[derive(Debug, Clone)]
pub struct ArtifactMeta {
    pub name: String,
    pub batch: usize,
    pub input_shape: Vec<usize>,
    pub output_shape: Vec<usize>,
    pub probe_input: Vec<f32>,
    pub probe_output: Vec<f32>,
}

impl ArtifactMeta {
    pub fn load(path: &Path) -> Result<ArtifactMeta> {
        let text = std::fs::read_to_string(path).with_context(|| format!("reading {path:?}"))?;
        let j = crate::ir::json::Json::parse(&text)?;
        let shape = |key: &str| -> Result<Vec<usize>> {
            j.req(key)?
                .as_arr()?
                .iter()
                .map(|v| v.as_i64().map(|x| x as usize))
                .collect()
        };
        let floats = |key: &str| -> Result<Vec<f32>> {
            j.req(key)?.as_arr()?.iter().map(|v| v.as_f64().map(|x| x as f32)).collect()
        };
        Ok(ArtifactMeta {
            name: j.req("name")?.as_str()?.to_string(),
            batch: j.req("batch")?.as_i64()? as usize,
            input_shape: shape("input_shape")?,
            output_shape: shape("output_shape")?,
            probe_input: floats("probe_input")?,
            probe_output: floats("probe_output")?,
        })
    }
}

/// A compiled PJRT executable with fixed input/output shapes.
pub struct CompiledModel {
    exe: xla::PjRtLoadedExecutable,
    pub input_shape: Vec<usize>,
    pub output_shape: Vec<usize>,
}

/// PJRT CPU runtime wrapper. One client, many compiled models.
pub struct PjrtRuntime {
    client: xla::PjRtClient,
}

impl PjrtRuntime {
    /// Create a CPU PJRT client.
    pub fn cpu() -> Result<PjrtRuntime> {
        let client = xla::PjRtClient::cpu().map_err(|e| anyhow::anyhow!("PJRT client: {e:?}"))?;
        Ok(PjrtRuntime { client })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Load + compile an HLO-text artifact with declared shapes.
    pub fn load_hlo_text(
        &self,
        path: &Path,
        input_shape: Vec<usize>,
        output_shape: Vec<usize>,
    ) -> Result<CompiledModel> {
        ensure!(path.exists(), "artifact {path:?} not found — run `make artifacts`");
        let proto = xla::HloModuleProto::from_text_file(path.to_str().context("non-utf8 path")?)
            .map_err(|e| anyhow::anyhow!("parsing {path:?}: {e:?}"))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .map_err(|e| anyhow::anyhow!("compiling {path:?}: {e:?}"))?;
        Ok(CompiledModel { exe, input_shape, output_shape })
    }

    /// Load a model artifact pair (`<stem>.hlo.txt` + `<stem>.meta.json`).
    pub fn load_artifact(&self, stem: &Path) -> Result<(CompiledModel, ArtifactMeta)> {
        let meta = ArtifactMeta::load(&stem.with_extension("meta.json"))?;
        let model = self.load_hlo_text(
            &stem.with_extension("hlo.txt"),
            meta.input_shape.clone(),
            meta.output_shape.clone(),
        )?;
        Ok((model, meta))
    }
}

impl CompiledModel {
    /// Execute on one input tensor (shape must match the artifact).
    pub fn execute(&self, x: &Tensor) -> Result<Tensor> {
        ensure!(
            x.shape() == self.input_shape.as_slice(),
            "input shape {:?} != artifact shape {:?}",
            x.shape(),
            self.input_shape
        );
        let dims: Vec<i64> = x.shape().iter().map(|&d| d as i64).collect();
        let lit = xla::Literal::vec1(x.as_f32()?)
            .reshape(&dims)
            .map_err(|e| anyhow::anyhow!("literal reshape: {e:?}"))?;
        let result = self
            .exe
            .execute::<xla::Literal>(&[lit])
            .map_err(|e| anyhow::anyhow!("execute: {e:?}"))?[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow::anyhow!("to_literal: {e:?}"))?;
        // aot.py lowers with return_tuple=True
        let out = result.to_tuple1().map_err(|e| anyhow::anyhow!("to_tuple1: {e:?}"))?;
        let values = out.to_vec::<f32>().map_err(|e| anyhow::anyhow!("to_vec: {e:?}"))?;
        Ok(Tensor::new(self.output_shape.clone(), values))
    }

    /// Run the artifact's probe vector and compare against the recorded
    /// expected output (build-time oracle). Returns max abs error.
    pub fn self_check(&self, meta: &ArtifactMeta) -> Result<f32> {
        let x = Tensor::new(meta.input_shape.clone(), meta.probe_input.clone());
        let y = self.execute(&x)?;
        let got = y.as_f32()?;
        ensure!(got.len() == meta.probe_output.len(), "probe length mismatch");
        let mut max_err = 0f32;
        for (a, b) in got.iter().zip(&meta.probe_output) {
            max_err = max_err.max((a - b).abs());
        }
        Ok(max_err)
    }
}

/// Default artifact directory (repo-rooted, overridable via QONNX_ARTIFACTS).
pub fn artifacts_dir() -> PathBuf {
    std::env::var("QONNX_ARTIFACTS")
        .map(PathBuf::from)
        .unwrap_or_else(|_| PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts"))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn artifact(stem: &str) -> Option<PathBuf> {
        let p = artifacts_dir().join(stem);
        p.with_extension("hlo.txt").exists().then_some(p)
    }

    #[test]
    fn meta_roundtrip() {
        let Some(stem) = artifact("tfc_w2a2") else {
            eprintln!("skipping: artifacts not built");
            return;
        };
        let meta = ArtifactMeta::load(&stem.with_extension("meta.json")).unwrap();
        assert_eq!(meta.input_shape, vec![8, 784]);
        assert_eq!(meta.output_shape, vec![8, 10]);
        assert_eq!(meta.probe_input.len(), 8 * 784);
    }

    #[test]
    fn pjrt_executes_tfc_artifact() {
        let Some(stem) = artifact("tfc_w2a2") else {
            eprintln!("skipping: artifacts not built");
            return;
        };
        let rt = PjrtRuntime::cpu().unwrap();
        let (model, meta) = rt.load_artifact(&stem).unwrap();
        let err = model.self_check(&meta).unwrap();
        assert!(err < 1e-4, "probe mismatch: max abs err {err}");
    }

    #[test]
    fn pjrt_quant_kernel_artifact() {
        let p = artifacts_dir().join("quant_b4_256x256.hlo.txt");
        if !p.exists() {
            eprintln!("skipping: artifacts not built");
            return;
        }
        let rt = PjrtRuntime::cpu().unwrap();
        let model = rt.load_hlo_text(&p, vec![256, 256], vec![256, 256]).unwrap();
        let x = Tensor::full(vec![256, 256], 0.3);
        let y = model.execute(&x).unwrap();
        // quant(0.3, scale 0.125, int4) = round(2.4)*0.125 = 0.25
        assert!((y.as_f32().unwrap()[0] - 0.25).abs() < 1e-6);
    }

    #[test]
    fn shape_mismatch_rejected() {
        let Some(stem) = artifact("tfc_w2a2") else {
            eprintln!("skipping: artifacts not built");
            return;
        };
        let rt = PjrtRuntime::cpu().unwrap();
        let (model, _) = rt.load_artifact(&stem).unwrap();
        assert!(model.execute(&Tensor::zeros(vec![4, 784])).is_err());
    }
}

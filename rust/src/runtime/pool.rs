//! Persistent intra-op worker pool for the tensor substrate.
//!
//! Before this module, every large GEMM paid `std::thread::scope` spawn
//! + join costs per invocation. Here a process-wide pool is created once
//! ([`global`]), sized by `available_parallelism` (overridable with the
//! `QONNX_INTRAOP_THREADS` env knob, or injectable per-pool for tests),
//! and [`crate::tensor::gemm`](crate::tensor::gemm()) /
//! [`crate::tensor::qgemm_prepacked`] / im2col fan their row/column
//! chunks onto it instead of spawning.
//!
//! # Scoped execution
//!
//! [`WorkerPool::run_scoped`] accepts *borrowing* closures (non-`'static`
//! jobs over the caller's slices) and only returns once every job has
//! finished — the same guarantee `std::thread::scope` gives, provided by
//! a completion latch. Internally the borrowed jobs are lifetime-erased
//! to sit in the shared queue; soundness rests on the latch: no borrow
//! outlives the call because the call does not return (even on panic)
//! until all jobs are done. Panics inside jobs are caught, forwarded,
//! and re-raised on the calling thread after the latch drains; the latch
//! release itself is RAII-guaranteed (an unwinding job wrapper still
//! releases it), every pool lock recovers from poison, and the worker
//! loop catches anything that slips through — so a panicking job can
//! neither strand a `run_scoped` caller nor kill a worker thread
//! ([`WorkerPool::live_workers`] stays at full strength).
//!
//! The **caller participates**: a pool of `threads = T` spawns `T − 1`
//! OS workers and runs one job chunk inline, so `T = 1` degenerates to
//! fully-inline serial execution (that is what the CI job pinning
//! `QONNX_INTRAOP_THREADS=1` exercises). Jobs that themselves call
//! `run_scoped` (nested intra-op fan-out) run inline rather than
//! re-queueing, so pool workers can never deadlock waiting on their own
//! queue.
//!
//! # Request- vs intra-op parallelism
//!
//! The pool is shared by all batcher shards. Each shard worker declares
//! its budget via [`set_thread_intraop_limit`] (the coordinator sets
//! `cores / shards`, so *shards × intra-op threads ≤ cores*);
//! [`effective_parallelism`] is what the GEMMs consult when deciding the
//! fan-out width. The limit is thread-local: it caps how wide a caller
//! *fans out*, while the worker set itself stays shared and persistent.

use std::collections::VecDeque;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Condvar, Mutex, MutexGuard, OnceLock, PoisonError};

type Job = Box<dyn FnOnce() + Send + 'static>;

/// Lock, recovering from poison: a job that panicked while a lock was
/// held must not wedge the pool for every later caller (the guarded
/// state — job queue, latch count — is valid at every unlock point).
fn lock_recover<T>(m: &Mutex<T>) -> MutexGuard<'_, T> {
    m.lock().unwrap_or_else(PoisonError::into_inner)
}

struct Shared {
    queue: Mutex<(VecDeque<Job>, bool)>, // (jobs, shutdown)
    available: Condvar,
    jobs_executed: AtomicU64,
}

/// Completion latch for one `run_scoped` batch.
struct Latch {
    pending: Mutex<(usize, Option<Box<dyn std::any::Any + Send>>)>,
    done: Condvar,
}

impl Latch {
    fn new(count: usize) -> Latch {
        Latch { pending: Mutex::new((count, None)), done: Condvar::new() }
    }

    fn complete(&self, panic: Option<Box<dyn std::any::Any + Send>>) {
        let mut g = lock_recover(&self.pending);
        g.0 -= 1;
        if g.1.is_none() {
            g.1 = panic;
        }
        if g.0 == 0 {
            self.done.notify_all();
        }
    }

    fn wait(&self) -> Option<Box<dyn std::any::Any + Send>> {
        let mut g = lock_recover(&self.pending);
        while g.0 > 0 {
            g = self.done.wait(g).unwrap_or_else(PoisonError::into_inner);
        }
        g.1.take()
    }
}

/// Releases its latch exactly once, on drop: even if the queued job
/// wrapper unwinds at an unexpected point, the `run_scoped` caller
/// blocked on the latch can never hang.
struct CompleteOnDrop {
    latch: Arc<Latch>,
    panic: Option<Box<dyn std::any::Any + Send>>,
}

impl Drop for CompleteOnDrop {
    fn drop(&mut self) {
        self.latch.complete(self.panic.take());
    }
}

thread_local! {
    /// Set while a pool worker (or inline caller) is inside a job:
    /// nested fan-out then runs inline instead of re-queueing.
    static IN_POOL_JOB: std::cell::Cell<bool> = const { std::cell::Cell::new(false) };
    /// Per-thread fan-out cap (0 = uncapped). See module docs.
    static INTRAOP_LIMIT: std::cell::Cell<usize> = const { std::cell::Cell::new(0) };
}

fn run_job_tracked(job: impl FnOnce()) -> Option<Box<dyn std::any::Any + Send>> {
    let prev = IN_POOL_JOB.with(|f| f.replace(true));
    let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(job));
    IN_POOL_JOB.with(|f| f.set(prev));
    result.err()
}

/// A persistent set of worker threads executing scoped job batches.
pub struct WorkerPool {
    shared: Arc<Shared>,
    handles: Vec<std::thread::JoinHandle<()>>,
    threads: usize,
}

impl WorkerPool {
    /// Pool with an injected parallelism of `threads` (clamped to ≥ 1).
    /// Spawns `threads − 1` OS workers; the caller is the last lane.
    pub fn new(threads: usize) -> WorkerPool {
        let threads = threads.max(1);
        let shared = Arc::new(Shared {
            queue: Mutex::new((VecDeque::new(), false)),
            available: Condvar::new(),
            jobs_executed: AtomicU64::new(0),
        });
        let handles = (0..threads - 1)
            .map(|i| {
                let sh = Arc::clone(&shared);
                std::thread::Builder::new()
                    .name(format!("qonnx-intraop-{i}"))
                    .spawn(move || {
                        // give this worker a named track in any globally
                        // installed trace before it runs its first job
                        crate::trace::register_worker_thread();
                        worker_loop(&sh)
                    })
                    .expect("spawning intra-op worker")
            })
            .collect();
        WorkerPool { shared, handles, threads }
    }

    /// The pool's parallelism (worker threads + the participating caller).
    pub fn threads(&self) -> usize {
        self.threads
    }

    /// OS worker threads owned by the pool (`threads() − 1`). Constant
    /// for the pool's lifetime — the "no spawn per invocation" witness.
    pub fn worker_count(&self) -> usize {
        self.handles.len()
    }

    /// Total jobs the pool has executed (workers + inline lanes).
    pub fn jobs_executed(&self) -> u64 {
        self.shared.jobs_executed.load(Ordering::Relaxed)
    }

    /// Worker threads still running. Equal to [`WorkerPool::worker_count`]
    /// in a healthy pool — panicking jobs are caught at two layers
    /// (wrapper and worker loop), so a job can never kill its worker.
    pub fn live_workers(&self) -> usize {
        self.handles.iter().filter(|h| !h.is_finished()).count()
    }

    /// Run every job to completion, in parallel where workers are free.
    /// Blocks until all jobs finished; panics (after draining) if any
    /// job panicked. Jobs may borrow caller state — see module docs.
    pub fn run_scoped<'s>(&self, mut jobs: Vec<Box<dyn FnOnce() + Send + 's>>) {
        if jobs.is_empty() {
            return;
        }
        // Serial lanes: nothing to gain (or nested fan-out) — run inline.
        if jobs.len() == 1 || self.worker_count() == 0 || IN_POOL_JOB.with(|f| f.get()) {
            let mut panic = None;
            for job in jobs {
                if let Some(p) = run_job_tracked(job) {
                    panic = panic.or(Some(p));
                }
            }
            if let Some(p) = panic {
                std::panic::resume_unwind(p);
            }
            return;
        }
        let first = jobs.remove(0);
        let latch = Arc::new(Latch::new(jobs.len()));
        {
            let mut q = lock_recover(&self.shared.queue);
            for job in jobs {
                // SAFETY: lifetime erasure of the borrowed job. The latch
                // below guarantees every queued job has completed before
                // this call returns (including the panic path), so no
                // borrow inside the closure outlives the caller's frame.
                let job: Job = unsafe {
                    std::mem::transmute::<
                        Box<dyn FnOnce() + Send + 's>,
                        Box<dyn FnOnce() + Send + 'static>,
                    >(job)
                };
                let l = Arc::clone(&latch);
                let sh = Arc::clone(&self.shared);
                q.0.push_back(Box::new(move || {
                    // RAII: the latch is released when `guard` drops, on
                    // every exit path — a panicking job (or even a panic
                    // in this wrapper) cannot strand the caller's wait
                    let mut guard = CompleteOnDrop { latch: l, panic: None };
                    guard.panic = run_job_tracked(job);
                    sh.jobs_executed.fetch_add(1, Ordering::Relaxed);
                }));
            }
            self.shared.available.notify_all();
        }
        // the caller is a lane too: run the first chunk inline
        let inline_panic = run_job_tracked(first);
        self.shared.jobs_executed.fetch_add(1, Ordering::Relaxed);
        let worker_panic = latch.wait();
        if let Some(p) = inline_panic.or(worker_panic) {
            std::panic::resume_unwind(p);
        }
    }
}

impl Drop for WorkerPool {
    fn drop(&mut self) {
        {
            let mut q = lock_recover(&self.shared.queue);
            q.1 = true;
            self.shared.available.notify_all();
        }
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
    }
}

fn worker_loop(shared: &Shared) {
    loop {
        let job = {
            let mut q = lock_recover(&shared.queue);
            loop {
                if let Some(job) = q.0.pop_front() {
                    break job;
                }
                if q.1 {
                    return;
                }
                q = shared.available.wait(q).unwrap_or_else(PoisonError::into_inner);
            }
        };
        // belt-and-braces: job wrappers already catch panics, but the
        // worker thread itself must survive anything that slips through
        let _ = std::panic::catch_unwind(std::panic::AssertUnwindSafe(job));
    }
}

/// Parallelism for the process pool: `QONNX_INTRAOP_THREADS` when set
/// (≥ 1), else `available_parallelism`.
fn default_threads() -> usize {
    if let Some(v) = std::env::var_os("QONNX_INTRAOP_THREADS") {
        if let Ok(n) = v.to_string_lossy().parse::<usize>() {
            if n >= 1 {
                return n;
            }
        }
    }
    std::thread::available_parallelism().map(|t| t.get()).unwrap_or(1)
}

/// The shared process-wide pool, created on first use.
pub fn global() -> &'static WorkerPool {
    static GLOBAL: OnceLock<WorkerPool> = OnceLock::new();
    GLOBAL.get_or_init(|| WorkerPool::new(default_threads()))
}

/// Cap this thread's intra-op fan-out (0 clears the cap). Batcher shard
/// workers call this with `cores / shards` so concurrent shards don't
/// oversubscribe: shards × intra-op threads ≤ cores.
pub fn set_thread_intraop_limit(limit: usize) {
    INTRAOP_LIMIT.with(|l| l.set(limit));
}

/// This thread's intra-op fan-out cap (0 = uncapped).
pub fn thread_intraop_limit() -> usize {
    INTRAOP_LIMIT.with(|l| l.get())
}

/// The fan-out width tensor kernels should use from this thread:
/// the global pool's parallelism, clamped by the thread's budget.
pub fn effective_parallelism() -> usize {
    let t = global().threads();
    match thread_intraop_limit() {
        0 => t,
        cap => t.min(cap),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicUsize;

    #[test]
    fn pool_runs_borrowed_jobs_and_persists_workers() {
        let pool = WorkerPool::new(4);
        assert_eq!(pool.threads(), 4);
        assert_eq!(pool.worker_count(), 3);
        let mut data = vec![0usize; 64];
        let chunks: Vec<&mut [usize]> = data.chunks_mut(16).collect();
        let mut jobs: Vec<Box<dyn FnOnce() + Send + '_>> = Vec::new();
        for (ci, chunk) in chunks.into_iter().enumerate() {
            jobs.push(Box::new(move || {
                for (i, v) in chunk.iter_mut().enumerate() {
                    *v = ci * 100 + i;
                }
            }));
        }
        pool.run_scoped(jobs);
        for (i, &v) in data.iter().enumerate() {
            assert_eq!(v, (i / 16) * 100 + i % 16);
        }
        // a second batch reuses the same workers — nothing respawned
        let before = pool.worker_count();
        let executed = pool.jobs_executed();
        let counter = AtomicUsize::new(0);
        let jobs: Vec<Box<dyn FnOnce() + Send + '_>> = (0..8)
            .map(|_| {
                Box::new(|| {
                    counter.fetch_add(1, Ordering::SeqCst);
                }) as Box<dyn FnOnce() + Send + '_>
            })
            .collect();
        pool.run_scoped(jobs);
        assert_eq!(counter.load(Ordering::SeqCst), 8);
        assert_eq!(pool.worker_count(), before);
        assert!(pool.jobs_executed() > executed);
    }

    #[test]
    fn single_thread_pool_runs_inline() {
        let pool = WorkerPool::new(1);
        assert_eq!(pool.worker_count(), 0);
        let mut hits = 0usize;
        let h = &mut hits;
        let jobs: Vec<Box<dyn FnOnce() + Send + '_>> = vec![Box::new(move || *h += 1)];
        pool.run_scoped(jobs);
        assert_eq!(hits, 1);
    }

    #[test]
    fn nested_fan_out_runs_inline_without_deadlock() {
        let pool = WorkerPool::new(2);
        let outer = AtomicUsize::new(0);
        let jobs: Vec<Box<dyn FnOnce() + Send + '_>> = (0..4)
            .map(|_| {
                Box::new(|| {
                    // nested run_scoped from inside a pool job: must run
                    // inline (the global pool is a different pool, but the
                    // IN_POOL_JOB guard is process-wide per thread)
                    let inner = AtomicUsize::new(0);
                    let inner_jobs: Vec<Box<dyn FnOnce() + Send + '_>> = (0..3)
                        .map(|_| {
                            Box::new(|| {
                                inner.fetch_add(1, Ordering::SeqCst);
                            })
                                as Box<dyn FnOnce() + Send + '_>
                        })
                        .collect();
                    global().run_scoped(inner_jobs);
                    outer.fetch_add(inner.load(Ordering::SeqCst), Ordering::SeqCst);
                }) as Box<dyn FnOnce() + Send + '_>
            })
            .collect();
        pool.run_scoped(jobs);
        assert_eq!(outer.load(Ordering::SeqCst), 12);
    }

    #[test]
    fn job_panics_propagate_after_draining() {
        let pool = WorkerPool::new(3);
        let finished = Arc::new(AtomicUsize::new(0));
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            let f = Arc::clone(&finished);
            let g = Arc::clone(&finished);
            let jobs: Vec<Box<dyn FnOnce() + Send + '_>> = vec![
                Box::new(move || {
                    f.fetch_add(1, Ordering::SeqCst);
                }),
                Box::new(|| panic!("intentional")),
                Box::new(move || {
                    g.fetch_add(1, Ordering::SeqCst);
                }),
            ];
            pool.run_scoped(jobs);
        }));
        assert!(result.is_err(), "panic must propagate to the caller");
        assert_eq!(finished.load(Ordering::SeqCst), 2, "other jobs still ran");
        // the pool survives a panicking batch
        let ok = AtomicUsize::new(0);
        let jobs: Vec<Box<dyn FnOnce() + Send + '_>> = (0..4)
            .map(|_| {
                Box::new(|| {
                    ok.fetch_add(1, Ordering::SeqCst);
                }) as Box<dyn FnOnce() + Send + '_>
            })
            .collect();
        pool.run_scoped(jobs);
        assert_eq!(ok.load(Ordering::SeqCst), 4);
    }

    #[test]
    fn panicking_jobs_release_latch_and_keep_workers_alive() {
        let pool = WorkerPool::new(4);
        assert_eq!(pool.live_workers(), 3);
        // several rounds of mostly-panicking batches: each run_scoped must
        // RETURN (latch fully released — a hang here is the old bug), and
        // no worker thread may die
        for round in 0..3 {
            let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
                let jobs: Vec<Box<dyn FnOnce() + Send + '_>> = (0..6)
                    .map(|i| {
                        Box::new(move || {
                            if i % 2 == 0 {
                                panic!("intentional pool panic (round {round}, job {i})");
                            }
                        }) as Box<dyn FnOnce() + Send + '_>
                    })
                    .collect();
                pool.run_scoped(jobs);
            }));
            assert!(result.is_err(), "round {round}: panic must propagate");
            assert_eq!(pool.worker_count(), 3, "round {round}: worker set must be stable");
            assert_eq!(pool.live_workers(), 3, "round {round}: a job panic killed a worker");
        }
        // and the pool still runs fresh jobs to completion afterwards
        let ok = AtomicUsize::new(0);
        let jobs: Vec<Box<dyn FnOnce() + Send + '_>> = (0..8)
            .map(|_| {
                Box::new(|| {
                    ok.fetch_add(1, Ordering::SeqCst);
                }) as Box<dyn FnOnce() + Send + '_>
            })
            .collect();
        pool.run_scoped(jobs);
        assert_eq!(ok.load(Ordering::SeqCst), 8);
        assert_eq!(pool.live_workers(), 3);
    }

    #[test]
    fn intraop_limit_caps_effective_parallelism() {
        let unlimited = effective_parallelism();
        assert!(unlimited >= 1);
        set_thread_intraop_limit(1);
        assert_eq!(effective_parallelism(), 1);
        assert_eq!(thread_intraop_limit(), 1);
        set_thread_intraop_limit(0);
        assert_eq!(effective_parallelism(), unlimited);
    }
}

//! Typed stand-in for the `xla` PJRT bindings.
//!
//! The artifact runtime in this module tree was written against the
//! `xla` crate (PJRT CPU client + HLO-text compilation), but that crate
//! is not available as a dependency of this build. This stub mirrors the
//! exact API surface `super` uses so the runtime keeps compiling; every
//! entry point ([`PjRtClient::cpu`], [`HloModuleProto::from_text_file`])
//! reports the missing backend with a typed error at run time, and the
//! artifact-existence guards in tests/benches/CLI skip PJRT paths long
//! before reaching it. Replacing this with the real crate is a one-line
//! change in `super` (`use xla_stub as xla` → `use xla`).

// Most stub types are never constructed (the entry points error before
// anything downstream runs) — that is the point of the stub, not rot.
#![allow(dead_code)]

/// Error carrier matching how `super` consumes the real crate's errors
/// (`{e:?}` formatting only).
pub struct XlaError(pub &'static str);

impl std::fmt::Debug for XlaError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.0)
    }
}

const UNAVAILABLE: XlaError = XlaError(
    "the xla/PJRT backend is not linked into this build — serve models \
     through the compiled ExecutionPlan engine instead",
);

/// PJRT CPU client handle (stub: construction always fails).
pub struct PjRtClient;

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient, XlaError> {
        Err(UNAVAILABLE)
    }

    pub fn platform_name(&self) -> String {
        "unavailable".to_string()
    }

    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable, XlaError> {
        Err(UNAVAILABLE)
    }
}

/// Parsed HLO module (stub: parsing always fails).
pub struct HloModuleProto;

impl HloModuleProto {
    pub fn from_text_file(_path: &str) -> Result<HloModuleProto, XlaError> {
        Err(UNAVAILABLE)
    }
}

/// Computation wrapper around a parsed HLO module.
pub struct XlaComputation;

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation
    }
}

/// Compiled executable (stub: unreachable — `compile` never succeeds).
pub struct PjRtLoadedExecutable;

impl PjRtLoadedExecutable {
    pub fn execute<T>(&self, _args: &[T]) -> Result<Vec<Vec<PjRtBuffer>>, XlaError> {
        Err(UNAVAILABLE)
    }
}

/// Device-resident result buffer.
pub struct PjRtBuffer;

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal, XlaError> {
        Err(UNAVAILABLE)
    }
}

/// Host literal value.
pub struct Literal;

impl Literal {
    pub fn vec1(_values: &[f32]) -> Literal {
        Literal
    }

    pub fn reshape(&self, _dims: &[i64]) -> Result<Literal, XlaError> {
        Err(UNAVAILABLE)
    }

    pub fn to_tuple1(self) -> Result<Literal, XlaError> {
        Err(UNAVAILABLE)
    }

    pub fn to_vec<T>(&self) -> Result<Vec<T>, XlaError> {
        Err(UNAVAILABLE)
    }
}

//! Streamlining: rewrite a quantized `ModelGraph` into **integer-domain**
//! form (paper §VI-D; FINN's streamlining, NEMO's integer deployment
//! stage, TVM QNN's QNN-to-integer legalization).
//!
//! The float graphs the exporters produce interleave real-valued scale
//! factors with what is, underneath, pure integer arithmetic: a `Quant`
//! activation computes `s * clamp(round(x / s + z))`, a quantized weight
//! is `s_w * I` for an integer matrix `I`. Streamlining separates the two
//! worlds:
//!
//! * every activation `Quant`/`BipolarQuant` becomes a FINN
//!   [`MultiThreshold`](crate::ops::multithreshold) emitting the **raw
//!   integer level** (`out_scale`/`out_bias` integral), with the
//!   producer's accumulated scale absorbed into the thresholds — computed
//!   in the producer's *integer* domain, so the thresholds themselves are
//!   integers everywhere except at the float graph edge;
//! * weight quantizers over initializers are folded to **integer
//!   initializers** (`w = s_w * I` stores `I`, annotated with its
//!   datatype), their scale tracked symbolically;
//! * `BatchNormalization` disappears: its per-channel affine folds into
//!   the tracked interpretation and thence into the next activation's
//!   per-channel thresholds;
//! * the one residual output scale is pushed to the graph edge as a
//!   single `Mul` (the only float multiply left in the graph).
//!
//! Between the edges the graph is pure integer arithmetic in float
//! containers, which is exactly what the plan compiler's quantized tier
//! ([`crate::plan::qkernel`]) proves and exploits: `i8` weight panels,
//! `i32` accumulators, thresholds fused into the scatter loop.
//!
//! # Semantics tracked per tensor
//!
//! The pass walks the topo order maintaining, for every tensor, an affine
//! interpretation `float_value = scale[c] * int_value + bias[c]` (scalar
//! or per-channel). Linear ops require a scalar zero-bias interpretation
//! (a per-channel scale cannot pass *through* an integer matmul without
//! un-integering the weights); activations absorb any per-channel affine
//! into per-channel threshold rows; monotone ops (`Relu`, `MaxPool`)
//! pass positive-scale interpretations through untouched.
//!
//! # Exactness
//!
//! Where every scale in the model is a power of two, float arithmetic is
//! exact and the streamlined graph is **bit-identical** to the original
//! (tests assert this). With non-dyadic scales (e.g. the zoo's `1/255`
//! input quant), the original float graph itself rounds per layer while
//! the integer form is exact, so outputs can differ by a grid step at
//! rounding boundaries — the documented tolerance at the scaled output
//! edge. Either way, the streamlined graph run through the quantized
//! plan, the float plan, or the reference interpreter is byte-identical
//! to itself (`tests/plan_equiv.rs`).

use crate::datatypes::DataType;
use crate::ir::{ModelGraph, Node, DOMAIN_FINN};
use crate::ops::quant::{next_up, quant_bounds};
use crate::tensor::Tensor;
use crate::transforms;
use anyhow::{Context, Result};
use std::collections::BTreeMap;
use std::fmt::Write as _;

/// Affine interpretation of a tensor: `float = scale[c] * int + bias[c]`.
#[derive(Debug, Clone)]
struct Affine {
    /// Per-channel scale (len 1 = scalar).
    scale: Vec<f64>,
    /// Per-channel bias (len 1 = scalar).
    bias: Vec<f64>,
    /// The tensor's values are literal integers (false only at the float
    /// graph edge, where the interpretation is the identity).
    integral: bool,
}

impl Affine {
    fn identity() -> Affine {
        Affine { scale: vec![1.0], bias: vec![0.0], integral: false }
    }

    fn scalar_int(scale: f64) -> Affine {
        Affine { scale: vec![scale], bias: vec![0.0], integral: true }
    }

    fn channels(&self) -> usize {
        self.scale.len().max(self.bias.len())
    }

    fn is_scalar(&self) -> bool {
        self.channels() == 1
    }

    fn scale_at(&self, c: usize) -> f64 {
        self.scale[c % self.scale.len()]
    }

    fn bias_at(&self, c: usize) -> f64 {
        self.bias[c % self.bias.len()]
    }

    fn all_positive(&self) -> bool {
        self.scale.iter().all(|&s| s > 0.0)
    }

    fn zero_bias(&self) -> bool {
        self.bias.iter().all(|&b| b == 0.0)
    }

    fn is_exact_identity(&self) -> bool {
        self.is_scalar() && self.scale[0] == 1.0 && self.bias[0] == 0.0
    }
}

/// What `try_streamline` found, node by node.
#[derive(Debug, Clone)]
pub struct StreamlineReport {
    pub model: String,
    /// One line per lowered / rewritten node.
    pub lowered: Vec<String>,
    /// Why streamlining stopped (empty when `ok`).
    pub blockers: Vec<String>,
    /// The whole graph reached integer-domain form.
    pub ok: bool,
}

impl StreamlineReport {
    /// Human-readable rendering (the `streamline` CLI prints this).
    pub fn render(&self) -> String {
        let mut s = format!(
            "streamline '{}': {}\n",
            self.model,
            if self.ok { "integer-domain form reached ✓" } else { "NOT streamlined" }
        );
        for l in &self.lowered {
            let _ = writeln!(s, "  {l}");
        }
        for b in &self.blockers {
            let _ = writeln!(s, "  ! blocker: {b}");
        }
        s
    }
}

/// A streamlining attempt: the (possibly rewritten) graph plus the
/// node-by-node report. When `report.ok` is false the graph is the
/// *cleaned* input with no integer lowering applied (semantically
/// equivalent to the original; callers that need the verbatim source
/// keep their own copy — [`streamline`] does).
#[derive(Debug)]
pub struct Streamlined {
    pub graph: ModelGraph,
    pub report: StreamlineReport,
}

/// Streamline in place when (and only when) the whole graph lowers
/// cleanly; the returned report says what happened either way.
pub fn streamline(graph: &mut ModelGraph) -> Result<StreamlineReport> {
    let att = try_streamline(graph)?;
    if att.report.ok {
        *graph = att.graph;
    }
    Ok(att.report)
}

/// Attempt to streamline a copy of `src` (which is cleaned first — shape
/// inference must succeed for threshold channel counts and the final
/// annotation pass). Never fails on *unsupported* graphs: those come
/// back with `report.ok == false` and the blocking node named.
pub fn try_streamline(src: &ModelGraph) -> Result<Streamlined> {
    let mut g = src.clone();
    transforms::cleanup(&mut g).context("streamline: cleanup")?;
    let mut report = StreamlineReport {
        model: g.name.clone(),
        lowered: Vec::new(),
        blockers: Vec::new(),
        ok: false,
    };
    match build(&g, &mut report) {
        Ok(Some(graph)) => {
            report.ok = true;
            Ok(Streamlined { graph, report })
        }
        // hand back the cleaned working copy — no point cloning the
        // full weight set again just to discard the lowering attempt
        Ok(None) => Ok(Streamlined { graph: g, report }),
        Err(e) => Err(e),
    }
}

/// Scalar static quant params of a node, as f64.
struct QParams {
    s: f64,
    z: f64,
    qmin: f64,
    qmax: f64,
    mode_round: bool,
}

fn quant_params(g: &ModelGraph, node: &Node) -> Result<QParams, String> {
    let p = transforms::quant_params_static(g, node)
        .map_err(|_| "non-scalar or dynamic quant parameters".to_string())?;
    let s = f64::from(p.scale);
    let z = f64::from(p.zero_point);
    if s <= 0.0 {
        return Err(format!("non-positive scale {s}"));
    }
    if z.fract() != 0.0 {
        return Err(format!("fractional zero point {z} (integer grid needed)"));
    }
    let mode_round = match p.rounding_mode.as_str() {
        "ROUND" => true,
        "FLOOR" => false,
        other => return Err(format!("unsupported rounding mode '{other}'")),
    };
    let (qmin, qmax) = quant_bounds(p.signed, p.narrow, p.bit_width);
    if (qmax - qmin).fract() != 0.0 || qmax - qmin < 1.0 {
        return Err(format!("fractional bit width {} has no threshold grid", p.bit_width));
    }
    Ok(QParams { s, z, qmin, qmax, mode_round })
}

/// One threshold for entering output level `m`, in the *producer's
/// domain*: the smallest value `t` such that `count(t <= v)` reproduces
/// the quantizer's decision `round(float(v)/s + z) >= m`.
///
/// `tau` is the exact real boundary `(s*(m - z - offset) - bias_c) /
/// scale_c`. For integral producers the threshold snaps to an integer
/// (`ceil`, with the half-even tie excluded for odd `m` under ROUND); at
/// the float edge the f32 threshold gets the one-ULP tie nudge instead.
fn level_threshold(q: &QParams, m: f64, scale_c: f64, bias_c: f64, integral: bool) -> f32 {
    let offset = if q.mode_round { 0.5 } else { 0.0 };
    let tau = (q.s * (m - q.z - offset) - bias_c) / scale_c;
    if integral {
        // integer inputs: t <= v  <=>  ceil(tau) <= v; an exact tie
        // (tau integral) is included for even m (half-even rounds up
        // into the level) and excluded for odd m
        if q.mode_round && tau.fract() == 0.0 && m.rem_euclid(2.0) != 0.0 {
            (tau + 1.0) as f32
        } else {
            tau.ceil() as f32
        }
    } else {
        let t = tau as f32;
        if q.mode_round && m.rem_euclid(2.0) != 0.0 {
            next_up(t)
        } else {
            t
        }
    }
}

/// The core rewrite walk. Returns `Ok(None)` (with a blocker recorded)
/// when any node cannot be lowered; the caller then leaves the original
/// graph untouched.
#[allow(clippy::too_many_lines)]
fn build(g: &ModelGraph, report: &mut StreamlineReport) -> Result<Option<ModelGraph>> {
    let mut interp: BTreeMap<String, Affine> = BTreeMap::new();
    // graph-wiring renames for deleted nodes (BatchNorm pass-through)
    let mut rename: BTreeMap<String, String> = BTreeMap::new();
    let mut nodes: Vec<Node> = Vec::new();
    let mut new_inits: BTreeMap<String, Tensor> = BTreeMap::new();
    let mut weight_dtypes: Vec<(String, DataType)> = Vec::new();

    for vi in &g.inputs {
        if !g.initializers.contains_key(&vi.name) {
            interp.insert(vi.name.clone(), Affine::identity());
        }
    }

    let resolve = |rename: &BTreeMap<String, String>, name: &str| -> String {
        rename.get(name).cloned().unwrap_or_else(|| name.to_string())
    };
    // interpretation of a data input: tracked affine, or the identity
    // for constants that are not quantized weights (shape targets etc.)
    let lookup = |interp: &BTreeMap<String, Affine>, name: &str| -> Option<Affine> {
        interp.get(name).cloned()
    };
    macro_rules! block {
        ($($arg:tt)*) => {{
            report.blockers.push(format!($($arg)*));
            return Ok(None);
        }};
    }

    for node in &g.nodes {
        let nm = if node.name.is_empty() { node.op_type.clone() } else { node.name.clone() };
        let min_arity = match node.op_type.as_str() {
            "Quant" => 4,
            "BipolarQuant" | "MatMul" | "Conv" | "Gemm" => 2,
            _ => 1,
        };
        if node.inputs.len() < min_arity || node.outputs.is_empty() {
            block!("'{nm}': malformed {} node", node.op_type);
        }
        match node.op_type.as_str() {
            // ---------------- weight quantizers over initializers -------
            "Quant" | "BipolarQuant" if g.initializers.contains_key(&node.inputs[0]) => {
                let w = &g.initializers[&node.inputs[0]];
                let wv = match w.as_f32() {
                    Ok(v) => v,
                    Err(_) => block!("'{nm}': non-f32 weight initializer"),
                };
                let (ints, scale, dt) = if node.op_type == "BipolarQuant" {
                    let s = match g.initializer(&node.inputs[1]).and_then(|t| t.scalar_value().ok())
                    {
                        Some(s) if s > 0.0 => f64::from(s),
                        _ => block!("'{nm}': non-scalar or non-positive bipolar weight scale"),
                    };
                    let ints: Vec<f32> =
                        wv.iter().map(|&v| if v >= 0.0 { 1.0 } else { -1.0 }).collect();
                    (ints, s, DataType::Bipolar)
                } else {
                    let q = match quant_params(g, node) {
                        Ok(q) => q,
                        Err(why) => block!("'{nm}': {why}"),
                    };
                    if !q.mode_round {
                        block!("'{nm}': FLOOR weight rounding unsupported");
                    }
                    let mut ints = Vec::with_capacity(wv.len());
                    for &v in wv {
                        let lvl = crate::ops::quant::round_half_even(f64::from(v) / q.s + q.z)
                            .clamp(q.qmin, q.qmax);
                        ints.push((lvl - q.z) as f32);
                    }
                    let dt = DataType::smallest_covering(q.qmin - q.z, q.qmax - q.z);
                    (ints, q.s, dt)
                };
                let out = node.outputs[0].clone();
                new_inits.insert(out.clone(), Tensor::new(w.shape().to_vec(), ints));
                weight_dtypes.push((out.clone(), dt));
                interp.insert(out.clone(), Affine::scalar_int(scale));
                report
                    .lowered
                    .push(format!("{nm:<24} {} -> {} weights, scale {scale}", node.op_type, dt));
            }
            // ---------------- activation quantizers ---------------------
            "Quant" | "BipolarQuant" => {
                let Some(a) = lookup(&interp, &node.inputs[0]) else {
                    block!("'{nm}': input has no tracked interpretation");
                };
                if !a.all_positive() {
                    block!("'{nm}': non-positive input scale cannot map to thresholds");
                }
                let channels = a.channels();
                let src = resolve(&rename, &node.inputs[0]);
                let (th, steps, out_scale, out_bias, scale, note) = if node.op_type
                    == "BipolarQuant"
                {
                    let s = match g.initializer(&node.inputs[1]).and_then(|t| t.scalar_value().ok())
                    {
                        Some(s) if s > 0.0 => f64::from(s),
                        _ => block!("'{nm}': non-scalar or non-positive bipolar scale"),
                    };
                    // v >= 0 (inclusive): threshold at -bias/scale
                    let mut th = Vec::with_capacity(channels);
                    for c in 0..channels {
                        let tau = -a.bias_at(c) / a.scale_at(c);
                        th.push(if a.integral { tau.ceil() as f32 } else { tau as f32 });
                    }
                    (th, 1usize, 2.0f32, -1.0f32, s, "bipolar sign".to_string())
                } else {
                    let q = match quant_params(g, node) {
                        Ok(q) => q,
                        Err(why) => block!("'{nm}': {why}"),
                    };
                    if q.qmax - q.qmin > 65536.0 {
                        block!(
                            "'{nm}': {} threshold steps is past the practical MultiThreshold range",
                            q.qmax - q.qmin
                        );
                    }
                    let steps = (q.qmax - q.qmin) as usize;
                    let mut th = Vec::with_capacity(channels * steps);
                    for c in 0..channels {
                        let (sc, bc) = (a.scale_at(c), a.bias_at(c));
                        for i in 1..=steps {
                            th.push(level_threshold(&q, q.qmin + i as f64, sc, bc, a.integral));
                        }
                    }
                    let ob = q.qmin - q.z;
                    (th, steps, 1.0f32, ob as f32, q.s, format!("{steps} steps"))
                };
                let out = node.outputs[0].clone();
                let th_name = g.fresh_name(&format!("{out}_ithresh"));
                new_inits.insert(th_name.clone(), Tensor::new(vec![channels, steps], th));
                nodes.push(
                    Node::new("MultiThreshold", &[&src, &th_name], &[&out])
                        .with_domain(DOMAIN_FINN)
                        .with_name(&format!("{nm}_imt"))
                        .with_attr("out_scale", out_scale)
                        .with_attr("out_bias", out_bias),
                );
                interp.insert(out, Affine::scalar_int(scale));
                report.lowered.push(format!(
                    "{nm:<24} {} -> MultiThreshold [{channels} x {note}], scale {scale} absorbed",
                    node.op_type
                ));
            }
            // ---------------- integer linear ops -------------------------
            "MatMul" | "Conv" | "Gemm" => {
                let Some(w) = lookup(&interp, &node.inputs[1]) else {
                    block!("'{nm}': weights are not integer-quantized constants");
                };
                if !(w.is_scalar() && w.zero_bias() && w.integral) {
                    block!("'{nm}': per-channel weight scale cannot pass through integer {}",
                        node.op_type);
                }
                let Some(a) = lookup(&interp, &node.inputs[0]) else {
                    block!("'{nm}': data input has no tracked interpretation");
                };
                if !a.integral {
                    block!("'{nm}': data input is not integer-valued (float edge reaches a linear op)");
                }
                if !(a.is_scalar() && a.zero_bias()) {
                    block!(
                        "'{nm}': per-channel input interpretation cannot pass through integer {}",
                        node.op_type
                    );
                }
                if node.op_type == "Conv" {
                    if node.inputs.get(2).map(String::as_str).is_some_and(|s| !s.is_empty()) {
                        block!("'{nm}': conv bias is not representable on the accumulator grid");
                    }
                    if node.attr_str_or("data_layout", "NCHW") != "NCHW" {
                        block!("'{nm}': channels-last conv unsupported");
                    }
                }
                let scale = a.scale[0] * w.scale[0];
                let mut n = node.clone();
                for inp in n.inputs.iter_mut() {
                    *inp = resolve(&rename, inp);
                }
                let mut note = String::new();
                if node.op_type == "Gemm" {
                    // alpha scales the integer product off the grid
                    let alpha = f64::from(node.attr_float_or("alpha", 1.0));
                    if alpha != 1.0 {
                        block!("'{nm}': Gemm alpha {alpha} != 1 leaves the integer grid");
                    }
                    // an integer-provable bias folds into the i32
                    // accumulator: beta * C must land on the accumulator
                    // grid s_a * s_w exactly
                    if let Some(cname) =
                        node.inputs.get(2).map(String::as_str).filter(|s| !s.is_empty())
                    {
                        let Some(ct) = g.initializer(cname) else {
                            block!("'{nm}': Gemm bias must be a constant initializer");
                        };
                        let cv = match ct.as_f32() {
                            Ok(v) => v,
                            Err(_) => block!("'{nm}': non-f32 Gemm bias"),
                        };
                        let beta = f64::from(node.attr_float_or("beta", 1.0));
                        let mut ints = Vec::with_capacity(cv.len());
                        let (mut lo, mut hi) = (0.0f64, 0.0f64);
                        for &v in cv {
                            let ci = beta * f64::from(v) / scale;
                            if ci.fract() != 0.0 || ci.abs() >= crate::tensor::F32_EXACT_INT_LIMIT {
                                block!(
                                    "'{nm}': Gemm bias beta*C is not on the integer \
                                     accumulator grid (scale {scale})"
                                );
                            }
                            lo = lo.min(ci);
                            hi = hi.max(ci);
                            ints.push(ci as f32);
                        }
                        let cint = g.fresh_name(&format!("{}_ibias", node.outputs[0]));
                        new_inits.insert(cint.clone(), Tensor::new(ct.shape().to_vec(), ints));
                        weight_dtypes.push((cint.clone(), DataType::smallest_covering(lo, hi)));
                        n.inputs[2] = cint;
                        if beta != 1.0 {
                            n.attrs.insert("beta".to_string(), crate::ir::AttrValue::Float(1.0));
                        }
                        note = ", integer bias folded into the accumulator".to_string();
                    }
                }
                nodes.push(n);
                interp.insert(node.outputs[0].clone(), Affine::scalar_int(scale));
                report.lowered.push(format!(
                    "{nm:<24} {} -> integer accumulator, scale {scale}{note}",
                    node.op_type
                ));
            }
            // ---------------- BatchNorm folds into the interpretation ----
            "BatchNormalization" => {
                if node.inputs.len() != 5 {
                    block!("'{nm}': BatchNorm needs 5 static inputs");
                }
                if g.is_output(&node.outputs[0]) {
                    block!("'{nm}': BatchNorm feeding a graph output cannot be absorbed");
                }
                let mut params: Vec<Vec<f64>> = Vec::with_capacity(4);
                for i in 1..5 {
                    match g.initializer(&node.inputs[i]) {
                        Some(t) => params.push(t.to_f64_vec()),
                        None => block!("'{nm}': BatchNorm parameters must be constants"),
                    }
                }
                let eps = f64::from(node.attr_float_or("epsilon", 1e-5));
                let (gamma, beta, mean, var) =
                    (&params[0], &params[1], &params[2], &params[3]);
                let c = gamma.len();
                if [beta.len(), mean.len(), var.len()].iter().any(|&l| l != c) {
                    block!("'{nm}': BatchNorm parameter lengths disagree");
                }
                let Some(a) = lookup(&interp, &node.inputs[0]) else {
                    block!("'{nm}': BatchNorm input has no tracked interpretation");
                };
                if a.channels() != 1 && a.channels() != c {
                    block!("'{nm}': channel count mismatch absorbing BatchNorm");
                }
                let mut scale = Vec::with_capacity(c);
                let mut bias = Vec::with_capacity(c);
                for ch in 0..c {
                    let g_c = gamma[ch] / (var[ch] + eps).sqrt();
                    if g_c <= 0.0 {
                        block!("'{nm}': non-positive BatchNorm gain flips threshold order");
                    }
                    scale.push(g_c * a.scale_at(ch));
                    bias.push(g_c * a.bias_at(ch) + (beta[ch] - mean[ch] * g_c));
                }
                // uniform per-channel affines collapse back to scalar
                let uniform = scale.windows(2).all(|w| w[0] == w[1])
                    && bias.windows(2).all(|w| w[0] == w[1]);
                let aff = if uniform {
                    Affine { scale: vec![scale[0]], bias: vec![bias[0]], integral: a.integral }
                } else {
                    Affine { scale, bias, integral: a.integral }
                };
                let src = resolve(&rename, &node.inputs[0]);
                rename.insert(node.outputs[0].clone(), src);
                interp.insert(node.outputs[0].clone(), aff);
                report.lowered.push(format!(
                    "{nm:<24} BatchNormalization -> absorbed into downstream thresholds"
                ));
            }
            // ---------------- monotone / structural pass-through ---------
            "Relu" => {
                let Some(a) = lookup(&interp, &node.inputs[0]) else {
                    block!("'{nm}': input has no tracked interpretation");
                };
                if !(a.all_positive() && a.zero_bias() && a.integral) {
                    block!("'{nm}': Relu only passes positive zero-bias integer interpretations");
                }
                let mut n = node.clone();
                for inp in n.inputs.iter_mut() {
                    *inp = resolve(&rename, inp);
                }
                nodes.push(n);
                interp.insert(node.outputs[0].clone(), a);
            }
            "MaxPool" => {
                let Some(a) = lookup(&interp, &node.inputs[0]) else {
                    block!("'{nm}': input has no tracked interpretation");
                };
                if !a.all_positive() {
                    block!("'{nm}': MaxPool needs positive scales (order-preserving)");
                }
                let mut n = node.clone();
                for inp in n.inputs.iter_mut() {
                    *inp = resolve(&rename, inp);
                }
                nodes.push(n);
                interp.insert(node.outputs[0].clone(), a);
            }
            "Reshape" | "Flatten" | "Squeeze" | "Unsqueeze" => {
                let Some(a) = lookup(&interp, &node.inputs[0]) else {
                    block!("'{nm}': input has no tracked interpretation");
                };
                if !a.is_scalar() {
                    block!("'{nm}': per-channel interpretation does not survive {}", node.op_type);
                }
                let mut n = node.clone();
                // only the data input is renamed; shape targets stay
                n.inputs[0] = resolve(&rename, &n.inputs[0]);
                nodes.push(n);
                interp.insert(node.outputs[0].clone(), a);
            }
            "Identity" => {
                // cleanup removes these; tolerate stragglers as renames
                let src = resolve(&rename, &node.inputs[0]);
                rename.insert(node.outputs[0].clone(), src);
                if let Some(a) = lookup(&interp, &node.inputs[0]) {
                    interp.insert(node.outputs[0].clone(), a);
                }
            }
            other => {
                block!("'{nm}': op '{other}' has no integer-domain lowering");
            }
        }
    }

    // ---------------- push residual scales to the graph edge ------------
    let mut edge_fixups: Vec<(String, f64, f64)> = Vec::new();
    for vi in &g.outputs {
        let Some(a) = lookup(&interp, &vi.name) else {
            block!("output '{}': not produced by the streamlined path", vi.name);
        };
        if rename.contains_key(&vi.name) {
            block!("output '{}': produced by an absorbed node", vi.name);
        }
        if a.is_exact_identity() {
            continue;
        }
        if !a.is_scalar() {
            block!("output '{}': per-channel scale at the graph edge unsupported", vi.name);
        }
        edge_fixups.push((vi.name.clone(), a.scale[0], a.bias[0]));
    }
    for (out, scale, bias) in &edge_fixups {
        // reroute the producer (and any internal consumers) to the raw
        // integer tensor, then append the de-scaling Mul/Add chain that
        // re-produces the declared output name
        let int_name = g.fresh_name(&format!("{out}_int"));
        for n in nodes.iter_mut() {
            for o in n.outputs.iter_mut() {
                if o == out {
                    *o = int_name.clone();
                }
            }
            for i in n.inputs.iter_mut() {
                if i == out {
                    *i = int_name.clone();
                }
            }
        }
        let scale_name = g.fresh_name(&format!("{out}_scale_out"));
        new_inits.insert(scale_name.clone(), Tensor::scalar(*scale as f32));
        if *bias == 0.0 {
            nodes.push(
                Node::new("Mul", &[&int_name, &scale_name], &[out])
                    .with_name(&format!("{out}_descale")),
            );
        } else {
            let scaled_name = g.fresh_name(&format!("{out}_scaled"));
            let bias_name = g.fresh_name(&format!("{out}_bias_out"));
            new_inits.insert(bias_name.clone(), Tensor::scalar(*bias as f32));
            nodes.push(
                Node::new("Mul", &[&int_name, &scale_name], &[&scaled_name])
                    .with_name(&format!("{out}_descale")),
            );
            nodes.push(
                Node::new("Add", &[&scaled_name, &bias_name], &[out])
                    .with_name(&format!("{out}_debias")),
            );
        }
        report
            .lowered
            .push(format!("output '{out}': residual scale {scale} pushed to the graph edge"));
    }

    // ---------------- assemble + annotate -------------------------------
    let mut sg = ModelGraph::new(&g.name);
    sg.doc = if g.doc.is_empty() {
        "streamlined to integer-domain form".to_string()
    } else {
        format!("{} [streamlined to integer-domain form]", g.doc)
    };
    sg.inputs = g.inputs.clone();
    sg.outputs = g.outputs.clone();
    sg.initializers = g.initializers.clone();
    for (k, t) in new_inits {
        sg.initializers.insert(k, t);
    }
    sg.nodes = nodes;
    transforms::remove_dead_nodes(&mut sg)?;
    sg.sort_topologically()?;
    sg.validate().context("streamlined graph failed validation")?;
    transforms::infer_shapes(&mut sg).context("streamlined graph shape inference")?;
    transforms::infer_datatypes(&mut sg)?;
    for (name, dt) in weight_dtypes {
        if sg.initializers.contains_key(&name) {
            sg.set_tensor_datatype(&name, dt);
        }
    }
    Ok(Some(sg))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::exec;
    use crate::plan::ExecutionPlan;
    use crate::testutil::random_tensor;
    use crate::zoo::rng::Rng;
    use std::collections::BTreeMap as Map;

    fn run1(g: &ModelGraph, x: &Tensor) -> Tensor {
        exec::execute_simple(g, x).unwrap()
    }

    /// Power-of-two scales end to end: the float graph computes exactly,
    /// so streamlining must be bit-identical — the "exact where the grid
    /// guarantees it" case.
    #[test]
    fn dyadic_scale_model_is_bit_exact() {
        let mut b = crate::ir::GraphBuilder::new("dyadic");
        b.input("x", vec![1, 12]);
        b.quant("x", "xq", 0.25, 0.0, 8.0, false, false, "ROUND");
        b.initializer(
            "w0",
            Tensor::new(vec![12, 6], (0..72).map(|v| ((v % 9) as f32 - 4.0) * 0.6).collect()),
        );
        b.quant("w0", "w0q", 0.5, 0.0, 3.0, true, true, "ROUND");
        b.node("MatMul", &["xq", "w0q"], &["h"], &[]);
        b.quant("h", "hq", 0.5, 0.0, 4.0, true, false, "ROUND");
        b.initializer(
            "w1",
            Tensor::new(vec![6, 4], (0..24).map(|v| ((v % 7) as f32 - 3.0) * 0.4).collect()),
        );
        b.quant("w1", "w1q", 0.5, 0.0, 3.0, true, true, "ROUND");
        b.node("MatMul", &["hq", "w1q"], &["y"], &[]);
        b.output("y", vec![1, 4]);
        let g = b.finish().unwrap();

        let att = try_streamline(&g).unwrap();
        assert!(att.report.ok, "{}", att.report.render());
        let sg = att.graph;
        let h = sg.op_histogram();
        assert!(!h.contains_key("Quant"), "{h:?}");
        assert_eq!(h.get("MultiThreshold"), Some(&2));
        assert_eq!(h.get("Mul"), Some(&1), "one residual de-scale at the edge: {h:?}");

        let mut rng = Rng::new(3);
        for trial in 0..5 {
            let x = random_tensor(&mut rng, vec![1, 12], -3.0, 3.0);
            let y0 = run1(&g, &x);
            let y1 = run1(&sg, &x);
            assert_eq!(y0, y1, "trial {trial}: dyadic streamlining must be bit-exact");
        }

        // ... and the quantized plan is byte-identical to the float
        // interpreter on the streamlined graph
        let plan = ExecutionPlan::compile(&sg).unwrap();
        assert!(plan.quant_kernel_count() >= 2, "{}", plan.summary());
        let x = random_tensor(&mut rng, vec![1, 12], -3.0, 3.0);
        let mut m = Map::new();
        m.insert("x".to_string(), x.clone());
        let got = plan.run(&m).unwrap();
        assert_eq!(exec::interpret(&sg, &m).unwrap().outputs, got);
    }

    #[test]
    fn bipolar_w1a1_style_model_streamlines() {
        let mut b = crate::ir::GraphBuilder::new("bip");
        b.input("x", vec![1, 8]);
        b.quant("x", "xq", 0.125, 0.0, 8.0, false, false, "ROUND");
        b.initializer(
            "w",
            Tensor::new(vec![8, 4], (0..32).map(|v| ((v % 5) as f32 - 2.0) * 0.3).collect()),
        );
        b.bipolar_quant("w", "wq", 0.25);
        b.node("MatMul", &["xq", "wq"], &["h"], &[]);
        b.bipolar_quant("h", "hq", 1.0);
        b.initializer(
            "w2",
            Tensor::new(vec![4, 3], (0..12).map(|v| ((v % 3) as f32 - 1.0) * 0.7).collect()),
        );
        b.bipolar_quant("w2", "w2q", 0.5);
        b.node("MatMul", &["hq", "w2q"], &["y"], &[]);
        b.output("y", vec![1, 3]);
        let g = b.finish().unwrap();
        let att = try_streamline(&g).unwrap();
        assert!(att.report.ok, "{}", att.report.render());
        let sg = att.graph;
        // bipolar weights are ±1 integers
        assert_eq!(sg.tensor_datatype("wq"), DataType::Bipolar);
        assert!(sg.initializers["wq"].as_f32().unwrap().iter().all(|&v| v == 1.0 || v == -1.0));
        let mut rng = Rng::new(9);
        let x = random_tensor(&mut rng, vec![1, 8], -2.0, 2.0);
        // dyadic scales: exact here too
        assert_eq!(run1(&g, &x), run1(&sg, &x));
    }

    #[test]
    fn batchnorm_folds_into_per_channel_thresholds() {
        let mut b = crate::ir::GraphBuilder::new("bnfold");
        b.input("x", vec![1, 2, 4, 4]);
        b.quant("x", "xq", 0.25, 0.0, 4.0, false, false, "ROUND");
        b.initializer(
            "w",
            Tensor::new(vec![3, 2, 3, 3], (0..54).map(|v| ((v % 5) as f32 - 2.0) * 0.5).collect()),
        );
        b.quant("w", "wq", 0.5, 0.0, 3.0, true, true, "ROUND");
        b.node(
            "Conv",
            &["xq", "wq"],
            &["c"],
            &[("kernel_shape", crate::ir::AttrValue::Ints(vec![3, 3]))],
        );
        // real (non-identity) per-channel BN parameters, positive gains
        b.initializer("bn_scale", Tensor::new(vec![3], vec![0.5, 1.0, 2.0]));
        b.initializer("bn_bias", Tensor::new(vec![3], vec![0.25, -0.5, 0.0]));
        b.initializer("bn_mean", Tensor::new(vec![3], vec![0.125, 0.0, -0.25]));
        b.initializer("bn_var", Tensor::new(vec![3], vec![1.0, 4.0, 0.25]));
        b.node(
            "BatchNormalization",
            &["c", "bn_scale", "bn_bias", "bn_mean", "bn_var"],
            &["bn"],
            &[("epsilon", crate::ir::AttrValue::Float(0.0))],
        );
        b.quant("bn", "y", 0.5, 0.0, 4.0, true, false, "ROUND");
        b.output("y", vec![1, 3, 2, 2]);
        let g = b.finish().unwrap();
        let att = try_streamline(&g).unwrap();
        assert!(att.report.ok, "{}", att.report.render());
        let sg = att.graph;
        assert!(!sg.op_histogram().contains_key("BatchNormalization"));
        // the activation thresholds went per-channel (3 rows)
        let mt = sg
            .nodes
            .iter()
            .filter(|n| n.op_type == "MultiThreshold")
            .find(|n| n.outputs[0].contains("y"))
            .expect("activation MultiThreshold");
        assert_eq!(sg.initializers[&mt.inputs[1]].shape()[0], 3);
        // numerically close to the original (non-dyadic sqrt scales make
        // exactness impossible in general; the tolerance is one output
        // grid step)
        let mut rng = Rng::new(5);
        let x = random_tensor(&mut rng, vec![1, 2, 4, 4], 0.0, 2.0);
        let y0 = run1(&g, &x);
        let y1 = run1(&sg, &x);
        for (a, b) in y0.as_f32().unwrap().iter().zip(y1.as_f32().unwrap()) {
            assert!((a - b).abs() <= 0.5 + 1e-6, "{a} vs {b}");
        }
    }

    /// Gemm-with-bias: an integer-provable `beta * C` folds into the i32
    /// accumulator grid instead of blocking the lowering (ROADMAP "widen
    /// streamlining" item).
    #[test]
    fn gemm_with_integer_bias_streamlines_and_runs_quantized() {
        let mut b = crate::ir::GraphBuilder::new("gemmbias");
        b.input("x", vec![1, 8]);
        b.quant("x", "xq", 0.25, 0.0, 6.0, true, false, "ROUND");
        b.initializer(
            "w",
            Tensor::new(vec![4, 8], (0..32).map(|v| ((v % 7) as f32 - 3.0) * 0.4).collect()),
        );
        b.quant("w", "wq", 0.5, 0.0, 3.0, true, true, "ROUND");
        // accumulator grid is 0.25 * 0.5 = 0.125; beta*C/0.125 = [2,-4,0,8]
        b.initializer("c", Tensor::new(vec![1, 4], vec![0.25, -0.5, 0.0, 1.0]));
        b.node(
            "Gemm",
            &["xq", "wq", "c"],
            &["y"],
            &[("transB", crate::ir::AttrValue::Int(1))],
        );
        b.output("y", vec![1, 4]);
        let g = b.finish().unwrap();
        let att = try_streamline(&g).unwrap();
        assert!(att.report.ok, "{}", att.report.render());
        assert!(
            att.report.lowered.iter().any(|l| l.contains("integer bias folded")),
            "{}",
            att.report.render()
        );
        let sg = att.graph;
        // the rewritten bias initializer is integer-valued
        let gemm = sg.nodes.iter().find(|n| n.op_type == "Gemm").unwrap();
        let cint = &sg.initializers[&gemm.inputs[2]];
        assert_eq!(cint.as_f32().unwrap(), &[2.0, -4.0, 0.0, 8.0]);
        // dyadic scales end to end: bit-exact vs the original float graph
        let mut rng = Rng::new(11);
        for trial in 0..4 {
            let x = random_tensor(&mut rng, vec![1, 8], -2.0, 2.0);
            assert_eq!(run1(&g, &x), run1(&sg, &x), "trial {trial}");
        }
        // and the plan executes the Gemm on the quantized tier
        let plan = ExecutionPlan::compile(&sg).unwrap();
        assert!(plan.quant_kernel_count() >= 1, "{}", plan.summary());
        let x = random_tensor(&mut rng, vec![1, 8], -2.0, 2.0);
        let mut m = Map::new();
        m.insert("x".to_string(), x);
        assert_eq!(exec::interpret(&sg, &m).unwrap().outputs, plan.run(&m).unwrap());
    }

    #[test]
    fn gemm_bias_off_the_accumulator_grid_blocks() {
        let mut b = crate::ir::GraphBuilder::new("gemmbad");
        b.input("x", vec![1, 4]);
        b.quant("x", "xq", 0.25, 0.0, 4.0, true, false, "ROUND");
        b.initializer("w", Tensor::new(vec![4, 2], vec![0.5; 8]));
        b.quant("w", "wq", 0.5, 0.0, 3.0, true, true, "ROUND");
        // 0.3 / 0.125 = 2.4: not on the accumulator grid
        b.initializer("c", Tensor::new(vec![1, 2], vec![0.3, 0.5]));
        b.node("Gemm", &["xq", "wq", "c"], &["y"], &[]);
        b.output("y", vec![1, 2]);
        let g = b.finish().unwrap();
        let att = try_streamline(&g).unwrap();
        assert!(!att.report.ok);
        assert!(
            att.report.blockers.iter().any(|b| b.contains("accumulator grid")),
            "{}",
            att.report.render()
        );
        // alpha != 1 blocks too
        let mut b2 = crate::ir::GraphBuilder::new("gemmalpha");
        b2.input("x", vec![1, 4]);
        b2.quant("x", "xq", 0.5, 0.0, 4.0, true, false, "ROUND");
        b2.initializer("w", Tensor::new(vec![4, 2], vec![0.5; 8]));
        b2.quant("w", "wq", 0.5, 0.0, 3.0, true, true, "ROUND");
        b2.node(
            "Gemm",
            &["xq", "wq"],
            &["y"],
            &[("alpha", crate::ir::AttrValue::Float(2.0))],
        );
        b2.output("y", vec![1, 2]);
        let g2 = b2.finish().unwrap();
        let att2 = try_streamline(&g2).unwrap();
        assert!(!att2.report.ok);
        assert!(
            att2.report.blockers.iter().any(|b| b.contains("alpha")),
            "{}",
            att2.report.render()
        );
    }

    #[test]
    fn blockers_are_reported_and_graph_untouched() {
        let mut b = crate::ir::GraphBuilder::new("blocked");
        b.input("x", vec![1, 4]);
        b.node("Sigmoid", &["x"], &["s"], &[]);
        b.quant("s", "y", 0.5, 0.0, 4.0, false, false, "ROUND");
        b.output("y", vec![1, 4]);
        let g = b.finish().unwrap();
        let att = try_streamline(&g).unwrap();
        assert!(!att.report.ok);
        assert!(
            att.report.blockers.iter().any(|b| b.contains("Sigmoid")),
            "{}",
            att.report.render()
        );
        // in-place variant leaves the graph alone
        let mut g2 = g.clone();
        let rep = streamline(&mut g2).unwrap();
        assert!(!rep.ok);
        assert_eq!(g2.nodes.len(), g.nodes.len());
    }

    #[test]
    fn negative_bn_gain_blocks() {
        let mut b = crate::ir::GraphBuilder::new("negbn");
        b.input("x", vec![1, 1, 2, 2]);
        b.quant("x", "xq", 1.0, 0.0, 4.0, false, false, "ROUND");
        b.initializer("w", Tensor::new(vec![1, 1, 1, 1], vec![1.0]));
        b.quant("w", "wq", 1.0, 0.0, 3.0, true, false, "ROUND");
        b.node(
            "Conv",
            &["xq", "wq"],
            &["c"],
            &[("kernel_shape", crate::ir::AttrValue::Ints(vec![1, 1]))],
        );
        b.initializer("bn_scale", Tensor::new(vec![1], vec![-1.0]));
        b.initializer("bn_bias", Tensor::new(vec![1], vec![0.0]));
        b.initializer("bn_mean", Tensor::new(vec![1], vec![0.0]));
        b.initializer("bn_var", Tensor::new(vec![1], vec![1.0]));
        b.node(
            "BatchNormalization",
            &["c", "bn_scale", "bn_bias", "bn_mean", "bn_var"],
            &["bn"],
            &[],
        );
        b.quant("bn", "y", 1.0, 0.0, 4.0, true, false, "ROUND");
        b.output("y", vec![1, 1, 2, 2]);
        let g = b.finish().unwrap();
        let att = try_streamline(&g).unwrap();
        assert!(!att.report.ok);
        assert!(
            att.report.blockers.iter().any(|b| b.contains("non-positive BatchNorm gain")),
            "{}",
            att.report.render()
        );
    }

    /// The integer-threshold construction must reproduce the quantizer's
    /// half-even tie behavior exactly when the producer domain is exact.
    #[test]
    fn integer_thresholds_respect_half_even_ties() {
        // producer: integers scaled by 0.25 (exact); quantizer s = 0.5
        // puts ties at v/s = m - 0.5 i.e. v = 0.25 * odd integers
        let mut b = crate::ir::GraphBuilder::new("ties");
        b.input("x", vec![1, 9]);
        b.quant("x", "xq", 0.25, 0.0, 6.0, true, false, "ROUND");
        b.initializer("w", Tensor::new(vec![9, 9], {
            let mut id = vec![0.0f32; 81];
            for i in 0..9 {
                id[i * 9 + i] = 0.5;
            }
            id
        }));
        b.quant("w", "wq", 0.5, 0.0, 2.0, true, false, "ROUND");
        b.node("MatMul", &["xq", "wq"], &["h"], &[]);
        b.quant("h", "y", 0.5, 0.0, 4.0, true, false, "ROUND");
        b.output("y", vec![1, 9]);
        let g = b.finish().unwrap();
        let att = try_streamline(&g).unwrap();
        assert!(att.report.ok, "{}", att.report.render());
        // inputs sitting exactly on quantizer ties after the 0.25 grid:
        // x = 0.25*q, h = 0.25*q (identity weights); h/0.5 = q/2 ties at
        // odd q
        let xs: Vec<f32> =
            (-4..5).map(|q| q as f32 * 0.25).collect();
        let x = Tensor::new(vec![1, 9], xs);
        assert_eq!(run1(&g, &x), run1(&att.graph, &x), "tie handling diverged");
    }
}

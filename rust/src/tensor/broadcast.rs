//! Numpy/ONNX-style broadcasting: shape unification and flat-offset
//! iteration of a tensor as if broadcast to a larger shape.

use anyhow::{bail, Result};

/// Unify two shapes under numpy broadcasting rules.
pub fn broadcast_shapes(a: &[usize], b: &[usize]) -> Result<Vec<usize>> {
    let rank = a.len().max(b.len());
    let mut out = vec![0usize; rank];
    for d in 0..rank {
        let da = if d < rank - a.len() { 1 } else { a[d - (rank - a.len())] };
        let db = if d < rank - b.len() { 1 } else { b[d - (rank - b.len())] };
        out[d] = if da == db {
            da
        } else if da == 1 {
            db
        } else if db == 1 {
            da
        } else {
            bail!("shapes {a:?} and {b:?} are not broadcastable (dim {d}: {da} vs {db})");
        };
    }
    Ok(out)
}

/// True if `small` broadcasts to `big` (one-directional, ONNX attr style).
pub fn broadcastable_to(small: &[usize], big: &[usize]) -> bool {
    match broadcast_shapes(small, big) {
        Ok(s) => s == big,
        Err(_) => false,
    }
}

/// Iterates flat offsets into a tensor of shape `src` as if it were
/// broadcast to `dst`, in row-major order of `dst`.
pub struct BroadcastIter {
    /// stride to apply per dst dim (0 where src is broadcast)
    strides: Vec<usize>,
    shape: Vec<usize>,
    idx: Vec<usize>,
    offset: usize,
    remaining: usize,
}

impl BroadcastIter {
    pub fn new(src: &[usize], dst: &[usize]) -> BroadcastIter {
        let rank = dst.len();
        let pad = rank - src.len();
        // row-major strides of src, padded to dst rank
        let mut src_strides = vec![0usize; rank];
        let mut acc = 1usize;
        for d in (0..src.len()).rev() {
            src_strides[pad + d] = if src[d] == 1 { 0 } else { acc };
            acc *= src[d];
        }
        BroadcastIter {
            strides: src_strides,
            shape: dst.to_vec(),
            idx: vec![0; rank],
            offset: 0,
            remaining: dst.iter().product(),
        }
    }
}

impl Iterator for BroadcastIter {
    type Item = usize;

    fn next(&mut self) -> Option<usize> {
        if self.remaining == 0 {
            return None;
        }
        let cur = self.offset;
        self.remaining -= 1;
        // increment multi-index (row-major, last dim fastest)
        for d in (0..self.shape.len()).rev() {
            self.idx[d] += 1;
            self.offset += self.strides[d];
            if self.idx[d] < self.shape[d] {
                break;
            }
            self.offset -= self.strides[d] * self.shape[d];
            self.idx[d] = 0;
        }
        Some(cur)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn unify_basic() {
        assert_eq!(broadcast_shapes(&[2, 3], &[3]).unwrap(), vec![2, 3]);
        assert_eq!(broadcast_shapes(&[2, 1], &[1, 3]).unwrap(), vec![2, 3]);
        assert_eq!(broadcast_shapes(&[], &[4]).unwrap(), vec![4]);
        assert!(broadcast_shapes(&[2], &[3]).is_err());
    }

    #[test]
    fn unify_channelwise() {
        // channel-wise scale [64,1,1] against activation [1,64,8,8]
        assert_eq!(
            broadcast_shapes(&[64, 1, 1], &[1, 64, 8, 8]).unwrap(),
            vec![1, 64, 8, 8]
        );
    }

    #[test]
    fn one_directional() {
        assert!(broadcastable_to(&[3], &[2, 3]));
        assert!(broadcastable_to(&[], &[2, 3]));
        assert!(!broadcastable_to(&[2, 3], &[3]));
    }

    #[test]
    fn iter_scalar() {
        let offs: Vec<usize> = BroadcastIter::new(&[], &[2, 2]).collect();
        assert_eq!(offs, vec![0, 0, 0, 0]);
    }

    #[test]
    fn iter_row() {
        let offs: Vec<usize> = BroadcastIter::new(&[3], &[2, 3]).collect();
        assert_eq!(offs, vec![0, 1, 2, 0, 1, 2]);
    }

    #[test]
    fn iter_col() {
        let offs: Vec<usize> = BroadcastIter::new(&[2, 1], &[2, 3]).collect();
        assert_eq!(offs, vec![0, 0, 0, 1, 1, 1]);
    }

    #[test]
    fn iter_identity() {
        let offs: Vec<usize> = BroadcastIter::new(&[2, 2], &[2, 2]).collect();
        assert_eq!(offs, vec![0, 1, 2, 3]);
    }
}

//! GEMM substrate: cache-blocked, panel-packed matrix multiply.
//!
//! This is the executor's dominant kernel — conv lowers onto it via
//! im2col, and every `MatMul`/`Gemm` node ends here. Two entry points:
//!
//! * [`gemm`] — the general `out += a * b` used by
//!   [`super::Tensor::matmul2d`]. Small problems take an unpacked serial
//!   i-k-j loop; large ones pack `b` into panels first.
//! * [`gemm_prepacked`] — the compiled-plan hot path: `b` was packed
//!   **once at plan-compile time** into a [`PackedB`]
//!   (see [`crate::plan::CompiledKernel`]), so per-request work is only
//!   the multiply itself.
//!
//! Blocking follows the classic MC/KC/NC scheme: `b` is tiled into
//! `KC x NC` panels stored contiguously, the row dimension is walked in
//! `MC`-row blocks (and fanned out over threads for large problems), and
//! the inner kernel streams one contiguous panel row per `k` step.
//!
//! **Determinism contract:** for every output element `out[i, j]` the
//! products `a[i, kk] * b[kk, j]` are accumulated in ascending-`kk` order
//! with `a[i, kk] == 0.0` terms skipped (quantized operands are often
//! sparse), *regardless* of path (serial/packed/threaded) or block sizes.
//! That is what lets the compiled plan, the interpreter, and the naive
//! triple loop produce bit-identical f32 results — the equivalence tests
//! rely on it.

/// Rows-block: each thread/chunk walks its rows in MC-row groups.
pub const GEMM_MC: usize = 64;
/// Depth-block: `k` is split into KC runs so a panel stays cache-resident.
pub const GEMM_KC: usize = 256;
/// Column-block: panel width; also the serial path's j-block width.
pub const GEMM_NC: usize = 128;

/// Below this many FLOPs the thread-spawn (and packing) overhead dominates.
const PAR_FLOP_THRESHOLD: usize = 4_000_000;

/// A `[k, n]` matrix packed into contiguous `KC x NC` panels.
///
/// Layout: for each `KC` row-block (outer), for each `NC` column-block
/// (inner), the `kc_len x nc_len` tile is stored row-major and
/// contiguously. The compute kernel then reads one contiguous `nc_len`
/// strip per `k` step instead of striding across the full row length.
#[derive(Debug, Clone, PartialEq)]
pub struct PackedB {
    k: usize,
    n: usize,
    data: Vec<f32>,
}

impl PackedB {
    /// Pack a row-major `[k, n]` matrix. A pure reordering copy — values
    /// are untouched, so packed and unpacked GEMM agree bit-for-bit.
    pub fn pack(k: usize, n: usize, b: &[f32]) -> PackedB {
        debug_assert_eq!(b.len(), k * n);
        let mut data = Vec::with_capacity(k * n);
        for kc0 in (0..k).step_by(GEMM_KC) {
            let kc1 = (kc0 + GEMM_KC).min(k);
            for nc0 in (0..n).step_by(GEMM_NC) {
                let nc1 = (nc0 + GEMM_NC).min(n);
                for kk in kc0..kc1 {
                    data.extend_from_slice(&b[kk * n + nc0..kk * n + nc1]);
                }
            }
        }
        PackedB { k, n, data }
    }

    pub fn k(&self) -> usize {
        self.k
    }

    pub fn n(&self) -> usize {
        self.n
    }

    /// The contiguous `kc_len x nc_len` tile at block origin `(kc0, nc0)`.
    #[inline]
    fn tile(&self, kc0: usize, kc_len: usize, nc0: usize) -> &[f32] {
        // preceding KC blocks hold kc_block_len * n elements each; within
        // this block, preceding NC tiles hold kc_len * nc0 elements.
        let off = kc0 * self.n + kc_len * nc0;
        let nc_len = (self.n - nc0).min(GEMM_NC);
        &self.data[off..off + kc_len * nc_len]
    }
}

/// Blocked GEMM: `out[m,n] += a[m,k] * b[k,n]`, `out` assumed zeroed.
///
/// Small problems run the unpacked serial kernel; large ones pack `b`
/// once and fan out over row chunks on `available_parallelism` threads.
pub fn gemm(m: usize, k: usize, n: usize, a: &[f32], b: &[f32], out: &mut [f32]) {
    debug_assert_eq!(a.len(), m * k);
    debug_assert_eq!(b.len(), k * n);
    debug_assert_eq!(out.len(), m * n);
    if m == 0 || n == 0 || k == 0 {
        return;
    }
    let flops = 2 * m * k * n;
    if flops < PAR_FLOP_THRESHOLD || m < 2 {
        gemm_serial_rows(k, n, a, b, out);
        return;
    }
    let bp = PackedB::pack(k, n, b);
    gemm_prepacked(m, k, &bp, a, out);
}

/// GEMM against a pre-packed `b` panel set: `out[m,n] += a[m,k] * bp`.
///
/// The plan's packed kernels call this with a `PackedB` built at
/// compile time; [`gemm`] calls it after packing per-call. Threads split
/// the row range; each output element is owned by exactly one thread, so
/// results are independent of the thread count.
pub fn gemm_prepacked(m: usize, k: usize, bp: &PackedB, a: &[f32], out: &mut [f32]) {
    debug_assert_eq!(bp.k, k);
    debug_assert_eq!(a.len(), m * k);
    debug_assert_eq!(out.len(), m * bp.n);
    let n = bp.n;
    if m == 0 || n == 0 || k == 0 {
        return;
    }
    let flops = 2 * m * k * n;
    let threads = std::thread::available_parallelism().map(|t| t.get()).unwrap_or(1);
    if threads <= 1 || flops < PAR_FLOP_THRESHOLD || m < 2 {
        gemm_packed_rows(k, a, bp, out);
        return;
    }
    let threads = threads.min(m);
    let rows_per = m.div_ceil(threads);
    std::thread::scope(|scope| {
        let mut rest = out;
        let mut row0 = 0usize;
        for _ in 0..threads {
            let rows = rows_per.min(m - row0);
            if rows == 0 {
                break;
            }
            let (chunk, tail) = rest.split_at_mut(rows * n);
            rest = tail;
            let a_chunk = &a[row0 * k..(row0 + rows) * k];
            scope.spawn(move || gemm_packed_rows(k, a_chunk, bp, chunk));
            row0 += rows;
        }
    });
}

/// Serial unpacked GEMM over however many rows `a`/`out` contain.
/// i-k-j loop order with NC-wide j blocks keeps the hot `b` strip in L1.
fn gemm_serial_rows(k: usize, n: usize, a: &[f32], b: &[f32], out: &mut [f32]) {
    if n == 0 {
        return;
    }
    let m = out.len() / n;
    for j0 in (0..n).step_by(GEMM_NC) {
        let j1 = (j0 + GEMM_NC).min(n);
        for i in 0..m {
            let arow = &a[i * k..(i + 1) * k];
            let orow = &mut out[i * n + j0..i * n + j1];
            for (kk, &av) in arow.iter().enumerate() {
                if av == 0.0 {
                    continue; // quantized operands are often sparse
                }
                let brow = &b[kk * n + j0..kk * n + j1];
                // zipped slices: bounds checks hoisted, inner loop
                // autovectorizes cleanly
                for (o, &bv) in orow.iter_mut().zip(brow) {
                    *o += av * bv;
                }
            }
        }
    }
}

/// Serial blocked kernel over the rows in `out`, reading packed panels.
///
/// Loop nest: MC row-blocks (outer) -> KC depth-blocks (ascending, which
/// preserves the per-element accumulation order) -> NC panels -> rows ->
/// panel strips. The `KC x NC` tile plus the MC-row `a` slab stay
/// cache-resident across the inner sweeps.
fn gemm_packed_rows(k: usize, a: &[f32], bp: &PackedB, out: &mut [f32]) {
    let n = bp.n;
    if n == 0 {
        return;
    }
    let m = out.len() / n;
    for ic0 in (0..m).step_by(GEMM_MC) {
        let ic1 = (ic0 + GEMM_MC).min(m);
        for kc0 in (0..k).step_by(GEMM_KC) {
            let kc_len = (k - kc0).min(GEMM_KC);
            for nc0 in (0..n).step_by(GEMM_NC) {
                let nc_len = (n - nc0).min(GEMM_NC);
                let tile = bp.tile(kc0, kc_len, nc0);
                for i in ic0..ic1 {
                    let arow = &a[i * k + kc0..i * k + kc0 + kc_len];
                    let orow = &mut out[i * n + nc0..i * n + nc0 + nc_len];
                    for (kk, &av) in arow.iter().enumerate() {
                        if av == 0.0 {
                            continue;
                        }
                        let brow = &tile[kk * nc_len..(kk + 1) * nc_len];
                        for (o, &bv) in orow.iter_mut().zip(brow) {
                            *o += av * bv;
                        }
                    }
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Naive i-j-k triple loop, ascending k with the same zero-skip rule —
    /// the reference the blocked paths must match bit-for-bit.
    fn gemm_naive(m: usize, k: usize, n: usize, a: &[f32], b: &[f32]) -> Vec<f32> {
        let mut out = vec![0f32; m * n];
        for i in 0..m {
            for j in 0..n {
                let mut acc = 0f32;
                for kk in 0..k {
                    let av = a[i * k + kk];
                    if av == 0.0 {
                        continue;
                    }
                    acc += av * b[kk * n + j];
                }
                out[i * n + j] = acc;
            }
        }
        out
    }

    fn fill(len: usize, seed: u64) -> Vec<f32> {
        // nonzero pseudo-random values (zero-skip makes zeros a special case
        // tested separately)
        let mut s = seed.wrapping_mul(0x9E3779B97F4A7C15).wrapping_add(1);
        (0..len)
            .map(|_| {
                s = s.wrapping_mul(6364136223846793005).wrapping_add(1442695040888963407);
                let v = ((s >> 40) as i32 % 1000 - 500) as f32 / 97.0;
                if v == 0.0 {
                    0.5
                } else {
                    v
                }
            })
            .collect()
    }

    /// Property: blocked/packed/threaded gemm matches the naive triple
    /// loop bit-for-bit on shapes that are *not* multiples of the block
    /// sizes (odd edges exercise every partial-tile path).
    #[test]
    fn prop_blocked_matches_naive_on_odd_shapes() {
        let shapes = [
            (1, 1, 1),
            (1, 7, 3),
            (3, 5, 2),
            (7, 1000, 3),
            (13, 130, 17),
            (64, 256, 128),             // exact block multiples
            (65, 257, 129),             // one past each block edge
            (GEMM_MC + 3, GEMM_KC + 5, GEMM_NC + 7),
            (130, 300, 7),
        ];
        for &(m, k, n) in &shapes {
            let a = fill(m * k, (m * 31 + k) as u64);
            let b = fill(k * n, (k * 17 + n) as u64);
            let want = gemm_naive(m, k, n, &a, &b);

            let mut got = vec![0f32; m * n];
            gemm(m, k, n, &a, &b, &mut got);
            assert_eq!(got, want, "gemm() diverged at m={m} k={k} n={n}");

            let bp = PackedB::pack(k, n, &b);
            let mut got_p = vec![0f32; m * n];
            gemm_prepacked(m, k, &bp, &a, &mut got_p);
            assert_eq!(got_p, want, "gemm_prepacked() diverged at m={m} k={k} n={n}");
        }
    }

    #[test]
    fn zero_rows_are_skipped_consistently() {
        // a containing zeros: both paths skip them identically
        let (m, k, n) = (5, 9, 11);
        let mut a = fill(m * k, 3);
        for v in a.iter_mut().step_by(3) {
            *v = 0.0;
        }
        let b = fill(k * n, 4);
        let want = gemm_naive(m, k, n, &a, &b);
        let mut got = vec![0f32; m * n];
        gemm(m, k, n, &a, &b, &mut got);
        assert_eq!(got, want);
        let bp = PackedB::pack(k, n, &b);
        let mut got_p = vec![0f32; m * n];
        gemm_prepacked(m, k, &bp, &a, &mut got_p);
        assert_eq!(got_p, want);
    }

    #[test]
    fn degenerate_dims_are_noops() {
        let mut out: Vec<f32> = vec![];
        gemm(0, 4, 0, &[], &[], &mut out);
        let bp = PackedB::pack(0, 3, &[]);
        assert_eq!(bp.k(), 0);
        assert_eq!(bp.n(), 3);
        gemm_prepacked(0, 0, &bp, &[], &mut out);
        // k == 0: out stays zeroed
        let mut out2 = vec![0f32; 4];
        gemm(2, 0, 2, &[], &[], &mut out2);
        assert_eq!(out2, vec![0.0; 4]);
    }

    #[test]
    fn pack_roundtrips_values() {
        let (k, n) = (GEMM_KC + 2, GEMM_NC + 5);
        let b = fill(k * n, 9);
        let bp = PackedB::pack(k, n, &b);
        // identity multiply recovers each row of b
        let mut a = vec![0f32; k];
        a[3] = 1.0;
        let mut out = vec![0f32; n];
        gemm_prepacked(1, k, &bp, &a, &mut out);
        assert_eq!(out, b[3 * n..4 * n].to_vec());
    }
}

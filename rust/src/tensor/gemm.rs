//! GEMM substrate: cache-blocked, panel-packed matrix multiply.
//!
//! This is the executor's dominant kernel — conv lowers onto it via
//! im2col, and every `MatMul`/`Gemm` node ends here. Two entry points:
//!
//! * [`gemm`] — the general `out += a * b` used by
//!   [`super::Tensor::matmul2d`]. Small problems take an unpacked serial
//!   i-k-j loop; large ones pack `b` into panels first.
//! * [`gemm_prepacked`] — the compiled-plan hot path: `b` was packed
//!   **once at plan-compile time** into a [`PackedB`]
//!   (see [`crate::plan::CompiledKernel`]), so per-request work is only
//!   the multiply itself.
//!
//! Blocking follows the classic MC/KC/NC scheme: `b` is tiled into
//! `KC x NC` panels stored contiguously, the row dimension is walked in
//! `MC`-row blocks, and the inner kernel streams one contiguous panel
//! row per `k` step. Large problems fan row × `NC`-aligned column chunks
//! onto the persistent intra-op pool ([`crate::runtime::pool`]) — no
//! per-call thread spawn, and short-row/wide-column shapes (batch-1
//! inference: `m = 1`) still use every core via the column split.
//!
//! **Determinism contract:** for every output element `out[i, j]` the
//! products `a[i, kk] * b[kk, j]` are accumulated in ascending-`kk` order
//! with `a[i, kk] == 0.0` terms skipped (quantized operands are often
//! sparse), *regardless* of path (serial/packed/threaded) or block sizes.
//! Column splitting respects this: each output element is still owned by
//! exactly one job, which walks its `KC` blocks in ascending order. That
//! is what lets the compiled plan, the interpreter, and the naive triple
//! loop produce bit-identical f32 results — the equivalence tests rely
//! on it.

use super::qgemm::{par_grid, SendPtr};
use super::store::WeightStore;
use crate::runtime::pool;

/// Rows-block: each thread/chunk walks its rows in MC-row groups.
pub const GEMM_MC: usize = 64;
/// Depth-block: `k` is split into KC runs so a panel stays cache-resident.
pub const GEMM_KC: usize = 256;
/// Column-block: panel width; also the serial path's j-block width.
pub const GEMM_NC: usize = 128;

/// Below this many FLOPs the thread-spawn (and packing) overhead dominates.
const PAR_FLOP_THRESHOLD: usize = 4_000_000;

/// A `[k, n]` matrix packed into contiguous `KC x NC` panels.
///
/// Layout: for each `KC` row-block (outer), for each `NC` column-block
/// (inner), the `kc_len x nc_len` tile is stored row-major and
/// contiguously. The compute kernel then reads one contiguous `nc_len`
/// strip per `k` step instead of striding across the full row length.
#[derive(Debug, Clone, PartialEq)]
pub struct PackedB {
    k: usize,
    n: usize,
    data: WeightStore<f32>,
}

impl PackedB {
    /// Pack a row-major `[k, n]` matrix. A pure reordering copy — values
    /// are untouched, so packed and unpacked GEMM agree bit-for-bit.
    pub fn pack(k: usize, n: usize, b: &[f32]) -> PackedB {
        debug_assert_eq!(b.len(), k * n);
        let mut data = Vec::with_capacity(k * n);
        for kc0 in (0..k).step_by(GEMM_KC) {
            let kc1 = (kc0 + GEMM_KC).min(k);
            for nc0 in (0..n).step_by(GEMM_NC) {
                let nc1 = (nc0 + GEMM_NC).min(n);
                for kk in kc0..kc1 {
                    data.extend_from_slice(&b[kk * n + nc0..kk * n + nc1]);
                }
            }
        }
        PackedB { k, n, data: data.into() }
    }

    /// Reconstruct from already-packed panel storage (artifact loading:
    /// `data` is typically a zero-copy view into the mapping, holding the
    /// exact byte layout [`PackedB::pack`] produced).
    pub(crate) fn from_store(k: usize, n: usize, data: WeightStore<f32>) -> PackedB {
        assert_eq!(data.len(), k * n, "packed panel length must be k*n");
        PackedB { k, n, data }
    }

    /// The packed panel bytes in layout order (artifact writing / the
    /// zero-copy provenance checks).
    pub(crate) fn store(&self) -> &WeightStore<f32> {
        &self.data
    }

    pub fn k(&self) -> usize {
        self.k
    }

    pub fn n(&self) -> usize {
        self.n
    }

    /// The contiguous `kc_len x nc_len` tile at block origin `(kc0, nc0)`.
    #[inline]
    fn tile(&self, kc0: usize, kc_len: usize, nc0: usize) -> &[f32] {
        // preceding KC blocks hold kc_block_len * n elements each; within
        // this block, preceding NC tiles hold kc_len * nc0 elements.
        let off = kc0 * self.n + kc_len * nc0;
        let nc_len = (self.n - nc0).min(GEMM_NC);
        &self.data[off..off + kc_len * nc_len]
    }
}

/// Blocked GEMM: `out[m,n] += a[m,k] * b[k,n]`, `out` assumed zeroed.
///
/// Small problems run the unpacked serial kernel; large ones pack `b`
/// once and fan out over row chunks on `available_parallelism` threads.
pub fn gemm(m: usize, k: usize, n: usize, a: &[f32], b: &[f32], out: &mut [f32]) {
    debug_assert_eq!(a.len(), m * k);
    debug_assert_eq!(b.len(), k * n);
    debug_assert_eq!(out.len(), m * n);
    if m == 0 || n == 0 || k == 0 {
        return;
    }
    let flops = 2 * m * k * n;
    if flops < PAR_FLOP_THRESHOLD {
        gemm_serial_rows(k, n, a, b, out);
        return;
    }
    // packing is a pure reorder, so this path is bit-identical — and it
    // lets even m = 1 problems column-split across the pool
    let bp = PackedB::pack(k, n, b);
    gemm_prepacked(m, k, &bp, a, out);
}

/// GEMM against a pre-packed `b` panel set: `out[m,n] += a[m,k] * bp`.
///
/// The plan's packed kernels call this with a `PackedB` built at
/// compile time; [`gemm`] calls it after packing per-call. Large
/// problems fan a row × `NC`-aligned column grid onto the persistent
/// intra-op pool; each output element is owned by exactly one job (and
/// accumulated ascending-`k` within it), so results are independent of
/// the fan-out.
pub fn gemm_prepacked(m: usize, k: usize, bp: &PackedB, a: &[f32], out: &mut [f32]) {
    debug_assert_eq!(bp.k, k);
    debug_assert_eq!(a.len(), m * k);
    debug_assert_eq!(out.len(), m * bp.n);
    let n = bp.n;
    if m == 0 || n == 0 || k == 0 {
        return;
    }
    let flops = 2 * m * k * n;
    let threads = pool::effective_parallelism();
    let (row_chunks, col_chunks) = par_grid(m, n, threads);
    let base = SendPtr(out.as_mut_ptr());
    if threads <= 1 || flops < PAR_FLOP_THRESHOLD || row_chunks * col_chunks <= 1 {
        // SAFETY: the single "job" covers the whole (rows × cols) rect.
        unsafe { gemm_packed_rect(k, a, bp, 0, m, 0, n, base.0) };
        return;
    }
    let rows_per = m.div_ceil(row_chunks);
    let nc_blocks = n.div_ceil(GEMM_NC);
    let blocks_per = nc_blocks.div_ceil(col_chunks);
    let mut jobs: Vec<Box<dyn FnOnce() + Send + '_>> = Vec::new();
    let mut r0 = 0usize;
    while r0 < m {
        let r1 = (r0 + rows_per).min(m);
        let mut blk = 0usize;
        while blk < nc_blocks {
            let c0 = blk * GEMM_NC;
            let c1 = ((blk + blocks_per) * GEMM_NC).min(n);
            let p = base;
            jobs.push(Box::new(move || {
                // SAFETY: this job exclusively owns rows r0..r1 of
                // columns c0..c1; rectangles of distinct jobs are
                // disjoint and the pool joins before `out` is reused.
                unsafe { gemm_packed_rect(k, a, bp, r0, r1, c0, c1, p.0) }
            }));
            blk += blocks_per;
        }
        r0 = r1;
    }
    pool::global().run_scoped(jobs);
}

/// Serial unpacked GEMM over however many rows `a`/`out` contain.
/// i-k-j loop order with NC-wide j blocks keeps the hot `b` strip in L1.
fn gemm_serial_rows(k: usize, n: usize, a: &[f32], b: &[f32], out: &mut [f32]) {
    if n == 0 {
        return;
    }
    let m = out.len() / n;
    for j0 in (0..n).step_by(GEMM_NC) {
        let j1 = (j0 + GEMM_NC).min(n);
        for i in 0..m {
            let arow = &a[i * k..(i + 1) * k];
            let orow = &mut out[i * n + j0..i * n + j1];
            for (kk, &av) in arow.iter().enumerate() {
                if av == 0.0 {
                    continue; // quantized operands are often sparse
                }
                let brow = &b[kk * n + j0..kk * n + j1];
                // zipped slices: bounds checks hoisted, inner loop
                // autovectorizes cleanly
                for (o, &bv) in orow.iter_mut().zip(brow) {
                    *o += av * bv;
                }
            }
        }
    }
}

/// Blocked kernel over the `(row0..row1) × (col0..col1)` rectangle of
/// the full `[m, n]` output (`col0` is `NC`-panel aligned), reading
/// packed panels.
///
/// Loop nest: MC row-blocks (outer) -> KC depth-blocks (ascending, which
/// preserves the per-element accumulation order) -> NC panels -> rows ->
/// panel strips. The `KC x NC` tile plus the MC-row `a` slab stay
/// cache-resident across the inner sweeps.
///
/// # Safety
/// `out` must point at the full `[m, n]` output and the caller must own
/// the rectangle exclusively for the duration of the call.
unsafe fn gemm_packed_rect(
    k: usize,
    a: &[f32],
    bp: &PackedB,
    row0: usize,
    row1: usize,
    col0: usize,
    col1: usize,
    out: *mut f32,
) {
    let n = bp.n;
    debug_assert_eq!(col0 % GEMM_NC, 0);
    for ic0 in (row0..row1).step_by(GEMM_MC) {
        let ic1 = (ic0 + GEMM_MC).min(row1);
        for kc0 in (0..k).step_by(GEMM_KC) {
            let kc_len = (k - kc0).min(GEMM_KC);
            for nc0 in (col0..col1).step_by(GEMM_NC) {
                let nc_len = (col1 - nc0).min(GEMM_NC);
                let tile = bp.tile(kc0, kc_len, nc0);
                for i in ic0..ic1 {
                    let arow = &a[i * k + kc0..i * k + kc0 + kc_len];
                    // SAFETY: `out` spans the full `[m, n]` buffer and this
                    // call owns rows `row0..row1` × cols `col0..col1`
                    // exclusively (fn contract), so the `nc_len` elements at
                    // `i * n + nc0` are in bounds and unaliased.
                    let orow =
                        unsafe { std::slice::from_raw_parts_mut(out.add(i * n + nc0), nc_len) };
                    for (kk, &av) in arow.iter().enumerate() {
                        if av == 0.0 {
                            continue;
                        }
                        let brow = &tile[kk * nc_len..(kk + 1) * nc_len];
                        for (o, &bv) in orow.iter_mut().zip(brow) {
                            *o += av * bv;
                        }
                    }
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Naive i-j-k triple loop, ascending k with the same zero-skip rule —
    /// the reference the blocked paths must match bit-for-bit.
    fn gemm_naive(m: usize, k: usize, n: usize, a: &[f32], b: &[f32]) -> Vec<f32> {
        let mut out = vec![0f32; m * n];
        for i in 0..m {
            for j in 0..n {
                let mut acc = 0f32;
                for kk in 0..k {
                    let av = a[i * k + kk];
                    if av == 0.0 {
                        continue;
                    }
                    acc += av * b[kk * n + j];
                }
                out[i * n + j] = acc;
            }
        }
        out
    }

    fn fill(len: usize, seed: u64) -> Vec<f32> {
        // nonzero pseudo-random values (zero-skip makes zeros a special case
        // tested separately)
        let mut s = seed.wrapping_mul(0x9E3779B97F4A7C15).wrapping_add(1);
        (0..len)
            .map(|_| {
                s = s.wrapping_mul(6364136223846793005).wrapping_add(1442695040888963407);
                let v = ((s >> 40) as i32 % 1000 - 500) as f32 / 97.0;
                if v == 0.0 {
                    0.5
                } else {
                    v
                }
            })
            .collect()
    }

    /// Property: blocked/packed/threaded gemm matches the naive triple
    /// loop bit-for-bit on shapes that are *not* multiples of the block
    /// sizes (odd edges exercise every partial-tile path).
    #[test]
    fn prop_blocked_matches_naive_on_odd_shapes() {
        let shapes = [
            (1, 1, 1),
            (1, 7, 3),
            (3, 5, 2),
            (7, 1000, 3),
            (13, 130, 17),
            (64, 256, 128),             // exact block multiples
            (65, 257, 129),             // one past each block edge
            (GEMM_MC + 3, GEMM_KC + 5, GEMM_NC + 7),
            (130, 300, 7),
        ];
        for &(m, k, n) in &shapes {
            let a = fill(m * k, (m * 31 + k) as u64);
            let b = fill(k * n, (k * 17 + n) as u64);
            let want = gemm_naive(m, k, n, &a, &b);

            let mut got = vec![0f32; m * n];
            gemm(m, k, n, &a, &b, &mut got);
            assert_eq!(got, want, "gemm() diverged at m={m} k={k} n={n}");

            let bp = PackedB::pack(k, n, &b);
            let mut got_p = vec![0f32; m * n];
            gemm_prepacked(m, k, &bp, &a, &mut got_p);
            assert_eq!(got_p, want, "gemm_prepacked() diverged at m={m} k={k} n={n}");
        }
    }

    #[test]
    fn zero_rows_are_skipped_consistently() {
        // a containing zeros: both paths skip them identically
        let (m, k, n) = (5, 9, 11);
        let mut a = fill(m * k, 3);
        for v in a.iter_mut().step_by(3) {
            *v = 0.0;
        }
        let b = fill(k * n, 4);
        let want = gemm_naive(m, k, n, &a, &b);
        let mut got = vec![0f32; m * n];
        gemm(m, k, n, &a, &b, &mut got);
        assert_eq!(got, want);
        let bp = PackedB::pack(k, n, &b);
        let mut got_p = vec![0f32; m * n];
        gemm_prepacked(m, k, &bp, &a, &mut got_p);
        assert_eq!(got_p, want);
    }

    #[test]
    fn degenerate_dims_are_noops() {
        let mut out: Vec<f32> = vec![];
        gemm(0, 4, 0, &[], &[], &mut out);
        let bp = PackedB::pack(0, 3, &[]);
        assert_eq!(bp.k(), 0);
        assert_eq!(bp.n(), 3);
        gemm_prepacked(0, 0, &bp, &[], &mut out);
        // k == 0: out stays zeroed
        let mut out2 = vec![0f32; 4];
        gemm(2, 0, 2, &[], &[], &mut out2);
        assert_eq!(out2, vec![0.0; 4]);
    }

    #[test]
    fn single_row_wide_output_splits_columns_bit_identically() {
        // m = 1 used to pin gemm_prepacked to a single thread
        // (threads.min(m)); the NC-aligned column split must stay on the
        // ascending-k + zero-skip contract bit-for-bit on every machine,
        // whichever fan-out engages.
        let (m, k, n) = (1usize, 2000usize, 1100usize);
        assert!(2 * m * k * n >= PAR_FLOP_THRESHOLD);
        let mut a = fill(m * k, 21);
        for v in a.iter_mut().step_by(7) {
            *v = 0.0; // exercise the zero-skip on the parallel path too
        }
        let b = fill(k * n, 22);
        let want = gemm_naive(m, k, n, &a, &b);
        let bp = PackedB::pack(k, n, &b);
        let mut got = vec![0f32; m * n];
        gemm_prepacked(m, k, &bp, &a, &mut got);
        assert_eq!(got, want);
        let mut got2 = vec![0f32; m * n];
        gemm(m, k, n, &a, &b, &mut got2);
        assert_eq!(got2, want);
    }

    #[test]
    fn pack_roundtrips_values() {
        let (k, n) = (GEMM_KC + 2, GEMM_NC + 5);
        let b = fill(k * n, 9);
        let bp = PackedB::pack(k, n, &b);
        // identity multiply recovers each row of b
        let mut a = vec![0f32; k];
        a[3] = 1.0;
        let mut out = vec![0f32; n];
        gemm_prepacked(1, k, &bp, &a, &mut out);
        assert_eq!(out, b[3 * n..4 * n].to_vec());
    }
}

//! im2col lowering for convolution: turns NCHW conv into GEMM, the same
//! strategy FINN uses (Im2Col + MatMul) and the executor's conv hot path.

use super::Tensor;
use anyhow::{ensure, Result};

/// Output spatial dim for a conv/pool window.
pub fn conv_out_dim(in_dim: usize, k: usize, stride: usize, pad_begin: usize, pad_end: usize) -> usize {
    (in_dim + pad_begin + pad_end - k) / stride + 1
}

/// im2col over an NCHW input.
///
/// Returns a `[n * oh * ow, c * kh * kw]` matrix whose rows are flattened
/// receptive fields, so conv = im2col(x) × W^T with W `[m, c*kh*kw]`.
/// Padding is zero-fill (compatible with integer zero-points merged into
/// bias, per paper §II).
#[allow(clippy::too_many_arguments)]
pub fn im2col_nchw(
    x: &Tensor,
    kh: usize,
    kw: usize,
    stride_h: usize,
    stride_w: usize,
    pad_top: usize,
    pad_left: usize,
    pad_bottom: usize,
    pad_right: usize,
) -> Result<Tensor> {
    ensure!(x.rank() == 4, "im2col wants NCHW rank-4, got {:?}", x.shape());
    let (n, c, h, w) = (x.shape()[0], x.shape()[1], x.shape()[2], x.shape()[3]);
    let oh = conv_out_dim(h, kh, stride_h, pad_top, pad_bottom);
    let ow = conv_out_dim(w, kw, stride_w, pad_left, pad_right);
    let row_len = c * kh * kw;
    let mut out = vec![0f32; n * oh * ow * row_len];
    im2col_group_into(
        x.as_f32()?,
        n,
        c,
        h,
        w,
        0,
        c,
        kh,
        kw,
        stride_h,
        stride_w,
        [pad_top, pad_left, pad_bottom, pad_right],
        &mut out,
    );
    Ok(Tensor::new(vec![n * oh * ow, row_len], out))
}

/// im2col of a channel window `[c0, c0 + cg)` of an NCHW input, written
/// into a caller-provided (zeroed) `[n * oh * ow, cg * kh * kw]` buffer.
///
/// This is the allocation-free core shared by the generic conv op and
/// the plan's `PackedConv` kernel: grouped convolution slices its per-group
/// input channels *here* instead of materializing a per-group input
/// tensor, and the output buffer is typically drawn from a
/// [`crate::plan::ScratchArena`]. Padding positions are left untouched —
/// the caller's buffer must already be zero-filled.
///
/// Generic over the element type (a pure gather): the float kernels run it
/// over `f32`/`i8`/`i32` as needed by the calling tier.
///
/// Large multi-image gathers fan per-batch chunks onto the persistent
/// intra-op pool ([`crate::runtime::pool`]) — each image's rows are a
/// contiguous, disjoint slice of `out`, and a gather is exact, so the
/// fan-out cannot change a single byte.
#[allow(clippy::too_many_arguments)]
pub fn im2col_group_into<T: Copy + Send + Sync>(
    src: &[T],
    n: usize,
    c: usize,
    h: usize,
    w: usize,
    c0: usize,
    cg: usize,
    kh: usize,
    kw: usize,
    stride_h: usize,
    stride_w: usize,
    pads: [usize; 4], // top, left, bottom, right
    out: &mut [T],
) {
    let [pad_top, pad_left, _, _] = pads;
    let oh = conv_out_dim(h, kh, stride_h, pads[0], pads[2]);
    let ow = conv_out_dim(w, kw, stride_w, pads[1], pads[3]);
    let row_len = cg * kh * kw;
    debug_assert!(c0 + cg <= c);
    debug_assert_eq!(src.len(), n * c * h * w);
    debug_assert_eq!(out.len(), n * oh * ow * row_len);
    let threads = crate::runtime::pool::effective_parallelism();
    let per_image = oh * ow * row_len;
    if n > 1 && threads > 1 && n * per_image >= IM2COL_PAR_ELEMS {
        let batches_per = n.div_ceil(threads.min(n));
        let mut jobs: Vec<Box<dyn FnOnce() + Send + '_>> = Vec::new();
        for (ci, chunk) in out.chunks_mut(batches_per * per_image).enumerate() {
            let b0 = ci * batches_per;
            jobs.push(Box::new(move || {
                let nb = chunk.len() / per_image;
                im2col_batch_range(
                    src, c, h, w, c0, cg, kh, kw, stride_h, stride_w, pad_top, pad_left, oh, ow,
                    b0, nb, chunk,
                );
            }));
        }
        crate::runtime::pool::global().run_scoped(jobs);
        return;
    }
    im2col_batch_range(
        src, c, h, w, c0, cg, kh, kw, stride_h, stride_w, pad_top, pad_left, oh, ow, 0, n, out,
    );
}

/// Below this many gathered elements the fan-out overhead dominates.
const IM2COL_PAR_ELEMS: usize = 1 << 20;

/// The serial gather over images `[b0, b0 + nb)`; `out` holds exactly
/// those images' rows.
#[allow(clippy::too_many_arguments)]
fn im2col_batch_range<T: Copy>(
    src: &[T],
    c: usize,
    h: usize,
    w: usize,
    c0: usize,
    cg: usize,
    kh: usize,
    kw: usize,
    stride_h: usize,
    stride_w: usize,
    pad_top: usize,
    pad_left: usize,
    oh: usize,
    ow: usize,
    b0: usize,
    nb: usize,
    out: &mut [T],
) {
    let row_len = cg * kh * kw;
    for bi in 0..nb {
        let b = b0 + bi;
        for oy in 0..oh {
            for ox in 0..ow {
                let row = ((b * oh + oy) * ow + ox) * row_len;
                for ch in 0..cg {
                    for ky in 0..kh {
                        let iy = oy * stride_h + ky;
                        if iy < pad_top || iy - pad_top >= h {
                            continue; // zero padding
                        }
                        let iy = iy - pad_top;
                        let src_base = ((b * c + c0 + ch) * h + iy) * w;
                        let dst_base = row + (ch * kh + ky) * kw;
                        for kx in 0..kw {
                            let ix = ox * stride_w + kx;
                            if ix < pad_left || ix - pad_left >= w {
                                continue;
                            }
                            out[dst_base + kx] = src[src_base + (ix - pad_left)];
                        }
                    }
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn out_dims() {
        assert_eq!(conv_out_dim(32, 3, 1, 0, 0), 30);
        assert_eq!(conv_out_dim(32, 3, 1, 1, 1), 32);
        assert_eq!(conv_out_dim(28, 2, 2, 0, 0), 14);
    }

    #[test]
    fn identity_kernel_1x1() {
        let x = Tensor::new(vec![1, 1, 2, 2], vec![1., 2., 3., 4.]);
        let m = im2col_nchw(&x, 1, 1, 1, 1, 0, 0, 0, 0).unwrap();
        assert_eq!(m.shape(), &[4, 1]);
        assert_eq!(m.as_f32().unwrap(), &[1., 2., 3., 4.]);
    }

    #[test]
    fn k2_no_pad() {
        let x = Tensor::new(vec![1, 1, 3, 3], (1..=9).map(|v| v as f32).collect());
        let m = im2col_nchw(&x, 2, 2, 1, 1, 0, 0, 0, 0).unwrap();
        assert_eq!(m.shape(), &[4, 4]);
        // first receptive field: [1,2,4,5]
        assert_eq!(&m.as_f32().unwrap()[0..4], &[1., 2., 4., 5.]);
        // last: [5,6,8,9]
        assert_eq!(&m.as_f32().unwrap()[12..16], &[5., 6., 8., 9.]);
    }

    #[test]
    fn padding_zero_fill() {
        let x = Tensor::new(vec![1, 1, 2, 2], vec![1., 2., 3., 4.]);
        let m = im2col_nchw(&x, 3, 3, 1, 1, 1, 1, 1, 1).unwrap();
        assert_eq!(m.shape(), &[4, 9]);
        // top-left output: window centered at (0,0) — corners padded
        let row0 = &m.as_f32().unwrap()[0..9];
        assert_eq!(row0, &[0., 0., 0., 0., 1., 2., 0., 3., 4.]);
    }

    #[test]
    fn multichannel_layout() {
        // 2 channels, row layout must be [c0 window | c1 window]
        let x = Tensor::new(vec![1, 2, 2, 2], vec![1., 2., 3., 4., 10., 20., 30., 40.]);
        let m = im2col_nchw(&x, 2, 2, 1, 1, 0, 0, 0, 0).unwrap();
        assert_eq!(m.shape(), &[1, 8]);
        assert_eq!(m.as_f32().unwrap(), &[1., 2., 3., 4., 10., 20., 30., 40.]);
    }

    #[test]
    fn group_window_matches_sliced_input() {
        // channel window [1, 3) of a 4-channel input == im2col of the slice
        let (n, c, h, w) = (2usize, 4usize, 3usize, 3usize);
        let x = Tensor::new(vec![n, c, h, w], (0..n * c * h * w).map(|v| v as f32).collect());
        let xs = x.as_f32().unwrap();
        let (c0, cg) = (1usize, 2usize);
        // reference: materialize the channel slice, run the full im2col
        let mut sliced = Vec::new();
        for b in 0..n {
            let base = (b * c + c0) * h * w;
            sliced.extend_from_slice(&xs[base..base + cg * h * w]);
        }
        let xg = Tensor::new(vec![n, cg, h, w], sliced);
        let want = im2col_nchw(&xg, 2, 2, 1, 1, 1, 1, 0, 0).unwrap();
        let mut got = vec![0f32; want.numel()];
        im2col_group_into(xs, n, c, h, w, c0, cg, 2, 2, 1, 1, [1, 1, 0, 0], &mut got);
        assert_eq!(&got, want.as_f32().unwrap());
    }

    #[test]
    fn stride_two() {
        let x = Tensor::new(vec![1, 1, 4, 4], (0..16).map(|v| v as f32).collect());
        let m = im2col_nchw(&x, 2, 2, 2, 2, 0, 0, 0, 0).unwrap();
        assert_eq!(m.shape(), &[4, 4]);
        assert_eq!(&m.as_f32().unwrap()[0..4], &[0., 1., 4., 5.]);
        assert_eq!(&m.as_f32().unwrap()[4..8], &[2., 3., 6., 7.]);
    }
}

//! Data-layout conversions between channels-first (NCHW, ONNX default) and
//! channels-last (NHWC, what FINN / hls4ml FPGA backends expect) — the
//! tensor-level primitive behind the paper's Fig. 3 transformation.

use super::Tensor;
use anyhow::{ensure, Result};

/// NCHW → NHWC.
pub fn nchw_to_nhwc(x: &Tensor) -> Result<Tensor> {
    ensure!(x.rank() == 4, "nchw_to_nhwc wants rank-4, got {:?}", x.shape());
    x.transpose(&[0, 2, 3, 1])
}

/// NHWC → NCHW.
pub fn nhwc_to_nchw(x: &Tensor) -> Result<Tensor> {
    ensure!(x.rank() == 4, "nhwc_to_nchw wants rank-4, got {:?}", x.shape());
    x.transpose(&[0, 3, 1, 2])
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip() {
        let x = Tensor::new(vec![2, 3, 4, 5], (0..120).map(|v| v as f32).collect());
        let y = nchw_to_nhwc(&x).unwrap();
        assert_eq!(y.shape(), &[2, 4, 5, 3]);
        let z = nhwc_to_nchw(&y).unwrap();
        assert_eq!(z, x);
    }

    #[test]
    fn channels_move_last() {
        // shape [1, 256, 1, 1] -> [1, 1, 1, 256], the Fig. 3 example shape
        let x = Tensor::new(vec![1, 256, 1, 1], (0..256).map(|v| v as f32).collect());
        let y = nchw_to_nhwc(&x).unwrap();
        assert_eq!(y.shape(), &[1, 1, 1, 256]);
        assert_eq!(y.as_f32().unwrap()[7], 7.0);
    }

    #[test]
    fn rejects_non_4d() {
        assert!(nchw_to_nhwc(&Tensor::zeros(vec![2, 3])).is_err());
    }
}
